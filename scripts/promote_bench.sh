#!/usr/bin/env bash
# Promote measured bench artifacts over the committed BENCH_*.json
# placeholders (ROADMAP open item: the placeholders carry
# status:"pending" because the authoring container has no Rust
# toolchain; CI's bench jobs regenerate the real files and upload them
# as the `bench-smoke` / `bench-simd` workflow artifacts).
#
# Usage:
#   scripts/promote_bench.sh <artifact-dir>
#
# where <artifact-dir> is a downloaded workflow-artifact directory
# containing one or more measured BENCH_*.json files. Each candidate is
# matched to its committed placeholder by its "bench" field (never by
# filename), validated (status == "measured", non-empty rows, host
# info present), and checked against its own embedded `acceptance`
# block before the copy happens. Any gate failure leaves the repo
# untouched and exits non-zero, so a regression can't be promoted by
# accident.

set -euo pipefail

if [ $# -ne 1 ] || [ ! -d "${1:-}" ]; then
    echo "usage: $0 <artifact-dir>" >&2
    exit 2
fi
ARTIFACT_DIR=$1
REPO_ROOT=$(cd -- "$(dirname -- "$0")/.." && pwd)

ARTIFACT_DIR="$ARTIFACT_DIR" REPO_ROOT="$REPO_ROOT" python3 - <<'PY'
import glob
import json
import os
import shutil
import sys

artifact_dir = os.environ["ARTIFACT_DIR"]
repo_root = os.environ["REPO_ROOT"]

failures = []
notes = []


def gate(ok, label):
    (notes if ok else failures).append(("PASS " if ok else "FAIL ") + label)
    return ok


def max_speedup(rows, key="speedup", **filters):
    best = None
    for r in rows:
        if all(r.get(k) is not None and pred(r[k]) for k, pred in filters.items()):
            v = r.get(key)
            if v is not None and (best is None or v > best):
                best = v
    return best


def check(doc):
    """Per-bench acceptance gates, thresholds read from the artifact's
    own `acceptance` block (the bench embeds them at measurement time)."""
    bench = doc["bench"]
    acc = doc.get("acceptance", {})
    rows = doc.get("rows", [])
    if bench == "perf_parallel_kernels":
        need = acc.get("backward_fused_min_speedup", 1.25)
        b, n = acc.get("at_batch_ge", 64), acc.get("at_nnz_ge", 40000)
        best = max_speedup(
            rows,
            kernel=lambda v: v == "backward_fused",
            batch=lambda v: v >= b,
            nnz=lambda v: v >= n,
        )
        return gate(
            best is not None and best >= need,
            f"{bench}: backward_fused {best} >= {need} at batch>={b}, nnz>={n}",
        )
    if bench == "perf_evolution":
        need = acc.get("engine_min_speedup_vs_oracle", 1.5)
        t, n = acc.get("at_threads_ge", 4), acc.get("at_nnz_ge", 100000)
        best = max_speedup(
            rows,
            op=lambda v: str(v).startswith("evolve_epoch"),
            threads=lambda v: v >= t,
            nnz=lambda v: v >= n,
        )
        return gate(
            best is not None and best >= need,
            f"{bench}: engine-vs-oracle {best} >= {need} at threads>={t}, nnz>={n}",
        )
    if bench == "perf_pool":
        d_need = acc.get("pool_dispatch_vs_spawn_min_ratio", 10.0)
        e_need = acc.get("epoch_min_speedup", 1.2)
        d_best = max_speedup(rows, key="ratio", op=lambda v: v == "dispatch")
        e_best = max_speedup(rows, op=lambda v: v == "epoch")
        ok = gate(
            d_best is not None and d_best >= d_need,
            f"{bench}: dispatch ratio {d_best} >= {d_need}",
        )
        return (
            gate(
                e_best is not None and e_best >= e_need,
                f"{bench}: epoch speedup {e_best} >= {e_need}",
            )
            and ok
        )
    if bench == "perf_serving":
        need = acc.get("batched_peak_vs_batch1_min_ratio", 1.5)
        peaks = {r.get("mode"): r.get("peak_qps") for r in rows if r.get("op") == "peak"}
        batched, batch1 = peaks.get("batched"), peaks.get("batch1")
        ratio = batched / batch1 if batched and batch1 else None
        return gate(
            ratio is not None and ratio >= need,
            f"{bench}: batched/batch1 peak {ratio and round(ratio, 3)} >= {need}",
        )
    if bench == "perf_simd":
        need = acc.get("simd_vs_scalar_min_speedup", 1.3)
        best = max_speedup(rows, op=lambda v: v in ("isa_kernel", "isa_dense"))
        if best is None and len(doc.get("isa_available", [])) <= 1:
            notes.append(
                f"SKIP {bench}: scalar-only host ({doc.get('isa_detected')}) — the "
                "speedup gate applies on vector-ISA hosts; scalar rows still promoted"
            )
            return True
        return gate(
            best is not None and best >= need,
            f"{bench}: best vector-ISA speedup {best} >= {need}",
        )
    if bench == "perf_outofcore":
        res = next((r for r in rows if r.get("op") == "residency"), None)
        if res is None:
            failures.append(f"FAIL {bench}: no residency row")
            return False
        seg, bud, peak = (
            res.get("segment_bytes"),
            res.get("budget_bytes"),
            res.get("peak_rss_bytes"),
        )
        if None in (seg, bud, peak):
            failures.append(f"FAIL {bench}: residency row missing bytes fields")
            return False
        ok = True
        if acc.get("require_segments_exceed_budget", True):
            ok = gate(
                seg > bud,
                f"{bench}: segments {seg} B exceed budget {bud} B",
            ) and ok
        if acc.get("require_peak_rss_under_budget", True):
            ok = gate(
                peak < bud,
                f"{bench}: peak RSS {peak} B under budget {bud} B",
            ) and ok
        parity = next((r for r in rows if r.get("op") == "parity"), None)
        return (
            gate(
                parity is not None and parity.get("equal") is True,
                f"{bench}: mapped-vs-RAM parity row equal",
            )
            and ok
        )
    failures.append(f"FAIL {bench}: no acceptance checker for this bench")
    return False


promoted = []
candidates = sorted(glob.glob(os.path.join(artifact_dir, "BENCH_*.json")))
if not candidates:
    print(f"no BENCH_*.json files under {artifact_dir}", file=sys.stderr)
    sys.exit(2)

# committed placeholders, keyed by their "bench" field
targets = {}
for path in sorted(glob.glob(os.path.join(repo_root, "BENCH_*.json"))):
    with open(path) as f:
        targets[json.load(f)["bench"]] = path

for path in candidates:
    with open(path) as f:
        doc = json.load(f)
    bench = doc.get("bench")
    label = f"{os.path.basename(path)} ({bench})"
    if bench not in targets:
        failures.append(f"FAIL {label}: no committed placeholder with this bench name")
        continue
    if doc.get("status") != "measured":
        failures.append(f"FAIL {label}: status is {doc.get('status')!r}, not 'measured'")
        continue
    if not doc.get("rows"):
        failures.append(f"FAIL {label}: empty rows")
        continue
    if not doc.get("host"):
        failures.append(f"FAIL {label}: missing host info")
        continue
    if check(doc):
        promoted.append((path, targets[bench]))

for line in notes:
    print(line)
for line in failures:
    print(line, file=sys.stderr)
if failures:
    print("promotion aborted: acceptance gates failed, repo left untouched", file=sys.stderr)
    sys.exit(1)

for src, dst in promoted:
    shutil.copyfile(src, dst)
    print(f"promoted {os.path.basename(src)} -> {os.path.relpath(dst, repo_root)}")
print(f"{len(promoted)} bench file(s) promoted")
PY
