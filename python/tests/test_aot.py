"""AOT pipeline: lowering produces loadable, well-formed HLO text."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model as M


def test_to_hlo_text_roundtrip(tmp_path):
    """Lowered HLO text must contain an ENTRY computation and f32 IO."""
    fwd = M.make_forward((4, 6, 3))
    args = [jax.ShapeDtypeStruct((2, 4), jnp.float32)]
    for l in range(2):
        shp = ((4, 6), (6,), (4, 6)) if l == 0 else ((6, 3), (3,), (6, 3))
        args += [jax.ShapeDtypeStruct(s, jnp.float32) for s in shp]
    text = aot.to_hlo_text(jax.jit(fwd).lower(*args))
    assert "ENTRY" in text
    assert "f32[2,4]" in text  # input batch


def test_lower_arch_writes_artifacts(tmp_path):
    entry = aot.lower_arch(
        "tiny", dict(sizes=(6, 8, 3), batch=4, act="relu", alpha=0.0),
        str(tmp_path))
    assert (tmp_path / "tiny_fwd.hlo.txt").exists()
    assert (tmp_path / "tiny_train.hlo.txt").exists()
    assert entry["sizes"] == [6, 8, 3]
    assert entry["train_outputs"].startswith("loss, acc")


def test_manifest_matches_architectures(tmp_path):
    # Lower just the tiny config via main()-equivalent path
    entry = aot.lower_arch(
        "tiny", dict(sizes=(5, 7, 2), batch=3), str(tmp_path))
    manifest = {"format": "hlo-text", "entries": [entry]}
    p = tmp_path / "manifest.json"
    p.write_text(json.dumps(manifest))
    loaded = json.loads(p.read_text())
    e = loaded["entries"][0]
    assert e["forward_hlo"] == "tiny_fwd.hlo.txt"
    assert e["batch"] == 3


def test_repo_artifacts_exist_if_built():
    """If `make artifacts` has run, the manifest must be coherent."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man = os.path.join(art, "manifest.json")
    if not os.path.exists(man):
        return  # artifacts not built yet; covered by make test
    m = json.loads(open(man).read())
    for e in m["entries"]:
        assert os.path.exists(os.path.join(art, e["forward_hlo"]))
        assert os.path.exists(os.path.join(art, e["train_hlo"]))


def test_lowered_train_step_numerics(tmp_path):
    """Executing the jitted train step (same fn that is lowered) learns."""
    sizes = (8, 12, 3)
    step = jax.jit(M.make_train_step(sizes, weight_decay=0.0))
    st = M.init_state(sizes, 0.6, seed=0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 3, 16).astype(np.int32))
    first = last = None
    for _ in range(40):
        out = step(x, y, jnp.float32(0.1), *st)
        if first is None:
            first = float(out[0])
        last = float(out[0])
        new = list(out[2:])
        st = [new[4 * i + j] if j < 4 else st[5 * i + 4]
              for i in range(2) for j in range(5)]
    assert last < first
