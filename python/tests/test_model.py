"""L2 correctness: masked MLP model, loss, train step semantics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M

SIZES = (12, 16, 8, 4)


def _state(density=0.5, seed=0):
    return M.init_state(SIZES, density, seed=seed)


def _data(batch=8, seed=1):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((batch, SIZES[0])).astype(np.float32))
    y = jnp.asarray(rng.integers(0, SIZES[-1], batch).astype(np.int32))
    return x, y


class TestForward:
    def test_shapes(self):
        st = _state()
        flat = [t for i in range(3) for t in (st[5 * i], st[5 * i + 1], st[5 * i + 4])]
        x, _ = _data()
        logits = M.forward(x, flat, sizes=SIZES, act="allrelu", alpha=0.6)
        assert logits.shape == (8, 4)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_activation_kinds_differ(self):
        st = _state()
        flat = [t for i in range(3) for t in (st[5 * i], st[5 * i + 1], st[5 * i + 4])]
        x, _ = _data()
        lr = M.forward(x, flat, sizes=SIZES, act="relu", alpha=0.6)
        la = M.forward(x, flat, sizes=SIZES, act="allrelu", alpha=0.6)
        assert not np.allclose(lr, la)

    def test_unknown_activation_raises(self):
        with pytest.raises(ValueError):
            M.activation(jnp.zeros(3), "swish", 0.1, 1)


class TestLoss:
    def test_cross_entropy_uniform(self):
        logits = jnp.zeros((4, 10))
        y = jnp.asarray([0, 3, 7, 9], jnp.int32)
        np.testing.assert_allclose(
            M.softmax_cross_entropy(logits, y), np.log(10.0), rtol=1e-6)

    def test_cross_entropy_confident(self):
        logits = jnp.asarray([[100.0, 0.0], [0.0, 100.0]])
        y = jnp.asarray([0, 1], jnp.int32)
        assert float(M.softmax_cross_entropy(logits, y)) < 1e-6

    def test_stability_large_logits(self):
        logits = jnp.asarray([[1e4, -1e4]])
        y = jnp.asarray([0], jnp.int32)
        assert np.isfinite(float(M.softmax_cross_entropy(logits, y)))


class TestTrainStep:
    def test_loss_decreases(self):
        step = jax.jit(M.make_train_step(SIZES, weight_decay=0.0))
        st = _state()
        x, y = _data(batch=16)
        losses = []
        for _ in range(60):
            out = step(x, y, jnp.float32(0.05), *st)
            losses.append(float(out[0]))
            new = list(out[2:])
            # re-attach masks (unchanged by the step)
            st = [new[4 * i + j] if j < 4 else st[5 * i + 4]
                  for i in range(3) for j in range(5)]
        assert losses[-1] < losses[0] * 0.5, losses[::10]

    def test_masks_preserved_by_update(self):
        """No weight may appear outside the mask after any update."""
        step = jax.jit(M.make_train_step(SIZES))
        st = _state(density=0.3)
        x, y = _data()
        out = step(x, y, jnp.float32(0.1), *st)
        for i in range(3):
            m = st[5 * i + 4]
            nw, nvw = out[2 + 4 * i], out[2 + 4 * i + 2]
            assert float(jnp.abs(nw * (1 - m)).max()) == 0.0
            assert float(jnp.abs(nvw * (1 - m)).max()) == 0.0

    def test_accuracy_in_unit_interval(self):
        step = jax.jit(M.make_train_step(SIZES))
        st = _state()
        x, y = _data()
        out = step(x, y, jnp.float32(0.01), *st)
        assert 0.0 <= float(out[1]) <= 1.0

    def test_zero_lr_freezes_weights(self):
        step = jax.jit(M.make_train_step(SIZES, weight_decay=0.0))
        st = _state()
        x, y = _data()
        out = step(x, y, jnp.float32(0.0), *st)
        for i in range(3):
            np.testing.assert_allclose(out[2 + 4 * i], st[5 * i], rtol=0, atol=0)

    def test_momentum_accumulates(self):
        step = jax.jit(M.make_train_step(SIZES, momentum=0.9, weight_decay=0.0))
        st = _state()
        x, y = _data()
        out1 = step(x, y, jnp.float32(0.01), *st)
        v1 = out1[2 + 2]  # vw of layer 0
        assert float(jnp.abs(v1).max()) > 0.0


class TestInitState:
    @pytest.mark.parametrize("scheme", ["he_uniform", "xavier", "normal"])
    def test_schemes(self, scheme):
        st = M.init_state(SIZES, 0.4, scheme=scheme)
        assert len(st) == 15
        for i in range(3):
            w, m = st[5 * i], st[5 * i + 4]
            assert float(jnp.abs(w * (1 - m)).max()) == 0.0

    def test_density_controls_nnz(self):
        lo = M.init_state(SIZES, 0.1, seed=3)
        hi = M.init_state(SIZES, 0.9, seed=3)
        assert float(lo[4].sum()) < float(hi[4].sum())
