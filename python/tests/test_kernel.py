"""L1 correctness: Pallas kernels vs pure-jnp oracle.

The CORE correctness signal for the kernel layer: every kernel must match
``ref.py`` to tight f32 tolerances across hypothesis-generated shapes,
tile sizes, sparsity levels, alphas, and parities.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.masked_mlp import (
    masked_matmul,
    masked_mlp_layer,
    mxu_utilisation,
    vmem_bytes,
)
from compile.kernels.ref import (
    all_relu_ref,
    masked_matmul_ref,
    masked_mlp_layer_ref,
    srelu_ref,
)

RTOL = 1e-5
ATOL = 1e-5


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


def _mask(rng, density, *shape):
    return jnp.asarray((rng.random(shape) < density).astype(np.float32))


# ---------------------------------------------------------------------------
# Deterministic unit tests
# ---------------------------------------------------------------------------


class TestMaskedMatmul:
    def test_exact_tiles(self):
        rng = np.random.default_rng(1)
        x, w, m = _rand(rng, 32, 32), _rand(rng, 32, 32), _mask(rng, 0.2, 32, 32)
        np.testing.assert_allclose(
            masked_matmul(x, w, m, tm=16, tn=16, tk=16),
            masked_matmul_ref(x, w, m), rtol=RTOL, atol=ATOL)

    def test_ragged_tiles(self):
        rng = np.random.default_rng(2)
        x, w, m = _rand(rng, 20, 70), _rand(rng, 70, 33), _mask(rng, 0.1, 70, 33)
        np.testing.assert_allclose(
            masked_matmul(x, w, m, tm=16, tn=16, tk=16),
            masked_matmul_ref(x, w, m), rtol=RTOL, atol=ATOL)

    def test_tiles_larger_than_shape(self):
        rng = np.random.default_rng(3)
        x, w, m = _rand(rng, 4, 6), _rand(rng, 6, 5), _mask(rng, 0.5, 6, 5)
        np.testing.assert_allclose(
            masked_matmul(x, w, m),  # default 128 tiles clamp to shape
            masked_matmul_ref(x, w, m), rtol=RTOL, atol=ATOL)

    def test_zero_mask_is_zero(self):
        rng = np.random.default_rng(4)
        x, w = _rand(rng, 8, 16), _rand(rng, 16, 8)
        out = masked_matmul(x, w, jnp.zeros((16, 8)), tm=8, tn=8, tk=8)
        assert float(jnp.abs(out).max()) == 0.0

    def test_full_mask_is_dense(self):
        rng = np.random.default_rng(5)
        x, w = _rand(rng, 8, 16), _rand(rng, 16, 8)
        np.testing.assert_allclose(
            masked_matmul(x, w, jnp.ones((16, 8)), tm=8, tn=8, tk=8),
            x @ w, rtol=RTOL, atol=ATOL)

    def test_mask_zeros_block_weight_values(self):
        """Masked-out weights must not influence the product at all."""
        rng = np.random.default_rng(6)
        x = _rand(rng, 8, 16)
        w1, m = _rand(rng, 16, 8), _mask(rng, 0.3, 16, 8)
        w2 = w1 + (1.0 - m) * 1e6  # garbage outside topology
        np.testing.assert_allclose(
            masked_matmul(x, w1, m, tm=8, tn=8, tk=8),
            masked_matmul(x, w2, m, tm=8, tn=8, tk=8), rtol=RTOL, atol=ATOL)


class TestMaskedLayer:
    @pytest.mark.parametrize("parity", [0, 1])
    @pytest.mark.parametrize("alpha", [0.0, 0.05, 0.6, 0.75])
    def test_fused_layer_matches_ref(self, parity, alpha):
        rng = np.random.default_rng(7)
        x, w = _rand(rng, 24, 40), _rand(rng, 40, 24)
        m, b = _mask(rng, 0.2, 40, 24), _rand(rng, 24)
        np.testing.assert_allclose(
            masked_mlp_layer(x, w, m, b, alpha=alpha, parity=parity,
                             tm=16, tn=16, tk=16),
            masked_mlp_layer_ref(x, w, m, b, alpha, parity),
            rtol=RTOL, atol=ATOL)

    def test_alpha_zero_parity1_is_relu(self):
        rng = np.random.default_rng(8)
        x, w = _rand(rng, 8, 8), _rand(rng, 8, 8)
        m, b = jnp.ones((8, 8)), jnp.zeros(8)
        out = masked_mlp_layer(x, w, m, b, alpha=0.0, parity=1, tm=8, tn=8, tk=8)
        np.testing.assert_allclose(out, jnp.maximum(x @ w, 0.0),
                                   rtol=RTOL, atol=ATOL)

    def test_even_parity_flips_negative_sign(self):
        """Paper Eq.3: even layers use slope -alpha, odd layers +alpha."""
        z = jnp.asarray([-2.0, -1.0, 0.0, 1.0])
        even = all_relu_ref(z, 0.5, 0)
        odd = all_relu_ref(z, 0.5, 1)
        np.testing.assert_allclose(even, [1.0, 0.5, 0.0, 1.0])
        np.testing.assert_allclose(odd, [-1.0, -0.5, 0.0, 1.0])

    def test_positive_side_identity(self):
        z = jnp.asarray([0.1, 3.0, 100.0])
        for p in (0, 1):
            np.testing.assert_allclose(all_relu_ref(z, 0.9, p), z)


class TestSReLURef:
    def test_identity_region(self):
        z = jnp.asarray([-0.5, 0.0, 0.5])
        np.testing.assert_allclose(srelu_ref(z, -1.0, 0.1, 1.0, 0.1), z)

    def test_saturating_regions(self):
        np.testing.assert_allclose(
            srelu_ref(jnp.asarray([-3.0]), -1.0, 0.1, 1.0, 0.2), [-1.2])
        np.testing.assert_allclose(
            srelu_ref(jnp.asarray([3.0]), -1.0, 0.1, 1.0, 0.2), [1.4])


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, tiles, densities, alphas
# ---------------------------------------------------------------------------


@st.composite
def matmul_case(draw):
    b = draw(st.integers(1, 48))
    n_in = draw(st.integers(1, 96))
    n_out = draw(st.integers(1, 64))
    tm = draw(st.sampled_from([8, 16, 32, 128]))
    tn = draw(st.sampled_from([8, 16, 32, 128]))
    tk = draw(st.sampled_from([8, 16, 32, 128]))
    density = draw(st.sampled_from([0.0, 0.05, 0.3, 1.0]))
    seed = draw(st.integers(0, 2**31 - 1))
    return b, n_in, n_out, tm, tn, tk, density, seed


@given(matmul_case())
@settings(max_examples=25, deadline=None)
def test_hypothesis_masked_matmul(case):
    b, n_in, n_out, tm, tn, tk, density, seed = case
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, b, n_in), _rand(rng, n_in, n_out)
    m = _mask(rng, density, n_in, n_out)
    np.testing.assert_allclose(
        masked_matmul(x, w, m, tm=tm, tn=tn, tk=tk),
        masked_matmul_ref(x, w, m), rtol=1e-4, atol=1e-4)


@given(matmul_case(), st.sampled_from([0.05, 0.25, 0.6, 0.75]),
       st.integers(0, 1))
@settings(max_examples=25, deadline=None)
def test_hypothesis_fused_layer(case, alpha, parity):
    b, n_in, n_out, tm, tn, tk, density, seed = case
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, b, n_in), _rand(rng, n_in, n_out)
    m, bias = _mask(rng, density, n_in, n_out), _rand(rng, n_out)
    np.testing.assert_allclose(
        masked_mlp_layer(x, w, m, bias, alpha=alpha, parity=parity,
                         tm=tm, tn=tn, tk=tk),
        masked_mlp_layer_ref(x, w, m, bias, alpha, parity),
        rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Roofline bookkeeping
# ---------------------------------------------------------------------------


class TestRoofline:
    def test_vmem_budget(self):
        # default tiling must leave double-buffering headroom in 16 MiB VMEM
        assert vmem_bytes() * 2 < 16 * 1024 * 1024

    def test_mxu_utilisation_exact(self):
        assert mxu_utilisation(128, 128, 128) == 1.0

    def test_mxu_utilisation_ragged(self):
        u = mxu_utilisation(100, 100, 100)
        assert 0 < u < 1
        np.testing.assert_allclose(u, 100**3 / 128**3)
