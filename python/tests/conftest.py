"""Make `compile.*` importable regardless of pytest invocation directory
(`cd python && pytest tests/` and `pytest python/tests/` both work)."""

import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
