"""AOT lowering: JAX model -> HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

For each entry in ``model.ARCHITECTURES`` this emits:

  artifacts/<name>_fwd.hlo.txt    forward(x, [w,b,m]*L) -> (logits,)
  artifacts/<name>_train.hlo.txt  train_step(x, y, lr, [w,b,vw,vb,m]*L)
                                  -> (loss, acc, [w,b,vw,vb]*L)

plus ``artifacts/manifest.json`` describing shapes and argument order so
the Rust loader can allocate buffers without re-deriving the convention.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_arch(name: str, cfg: dict, out_dir: str) -> dict:
    """Lower forward + train_step for one architecture; return manifest entry."""
    sizes = cfg["sizes"]
    batch = cfg["batch"]
    act = cfg.get("act", "allrelu")
    alpha = cfg.get("alpha", 0.6)
    use_pallas = cfg.get("use_pallas_first_layer", False)
    n_layers = len(sizes) - 1

    # ---- forward ----
    fwd = M.make_forward(sizes, act=act, alpha=alpha,
                         use_pallas_first_layer=use_pallas)
    fwd_args = [_spec((batch, sizes[0]))]
    for l in range(n_layers):
        shp = (sizes[l], sizes[l + 1])
        fwd_args += [_spec(shp), _spec((sizes[l + 1],)), _spec(shp)]
    fwd_path = os.path.join(out_dir, f"{name}_fwd.hlo.txt")
    with open(fwd_path, "w") as f:
        f.write(to_hlo_text(jax.jit(fwd).lower(*fwd_args)))

    # ---- train step ----
    step = M.make_train_step(
        sizes, act=act, alpha=alpha,
        momentum=cfg.get("momentum", 0.9),
        weight_decay=cfg.get("weight_decay", 0.0002),
    )
    st_args = [_spec((batch, sizes[0])), _spec((batch,), jnp.int32), _spec(())]
    for l in range(n_layers):
        shp = (sizes[l], sizes[l + 1])
        st_args += [_spec(shp), _spec((sizes[l + 1],)), _spec(shp),
                    _spec((sizes[l + 1],)), _spec(shp)]
    train_path = os.path.join(out_dir, f"{name}_train.hlo.txt")
    with open(train_path, "w") as f:
        f.write(to_hlo_text(jax.jit(step).lower(*st_args)))

    return {
        "name": name,
        "sizes": list(sizes),
        "batch": batch,
        "act": act,
        "alpha": alpha,
        "momentum": cfg.get("momentum", 0.9),
        "weight_decay": cfg.get("weight_decay", 0.0002),
        "use_pallas_first_layer": bool(use_pallas),
        "forward_hlo": os.path.basename(fwd_path),
        "train_hlo": os.path.basename(train_path),
        "forward_args": "x, then per layer: w, b, m",
        "train_args": "x, y:i32, lr:f32[], then per layer: w, b, vw, vb, m",
        "train_outputs": "loss, acc, then per layer: w, b, vw, vb",
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--arch", action="append", default=None,
        help="subset of architectures to lower (default: all)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = args.arch or list(M.ARCHITECTURES)
    manifest = {"format": "hlo-text", "entries": []}
    for name in names:
        cfg = M.ARCHITECTURES[name]
        print(f"lowering {name} sizes={cfg['sizes']} batch={cfg['batch']} ...")
        manifest["entries"].append(lower_arch(name, cfg, args.out))

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(names)} architectures to {args.out}")


if __name__ == "__main__":
    main()
