"""Layer-2: masked-dense sparse-MLP compute graph in JAX.

This is the paper's *comparator* path — "simulate sparsity with a binary
mask over dense matrices" (the Keras rows of Tables 2-3) — expressed in
JAX so it AOT-lowers (``aot.py``) to HLO text that the Rust coordinator
executes via PJRT. Python never runs at training time.

Two entry points are lowered per architecture:

* ``forward(x, *params_and_masks)``      -> logits            (eval path)
* ``train_step(x, y, lr, *state)``       -> (loss, acc, *new_state)

Masks are *runtime inputs*, so the Rust side can run SET topology
evolution (prune/regrow on the mask) between steps without recompiling
the executable. The quickstart artifact routes its first layer through
the Pallas fused kernel (interpret=True lowers it into plain HLO) to
prove the L1 -> L2 -> L3 composition.

Flat argument convention (what Rust feeds, in order):

  forward:    x, then per layer l: w_l, b_l, m_l
  train_step: x, y(int32), lr(f32 scalar), then per layer l:
              w_l, b_l, vw_l, vb_l, m_l
  returns:    loss(f32), acc(f32), then per layer l: w_l, b_l, vw_l, vb_l

Hyperparameters baked at lowering time (static): layer sizes, alpha,
momentum, weight decay, activation kind.
"""

from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels import masked_mlp as pk
from .kernels.ref import all_relu_ref


# ---------------------------------------------------------------------------
# Activations (match rust/src/nn/activations.rs semantics)
# ---------------------------------------------------------------------------


def activation(z, kind: str, alpha: float, layer_index: int):
    """Hidden-layer activation dispatch.

    ``layer_index`` is the 1-based hidden layer index; All-ReLU alternates
    the negative-side slope sign with its parity (paper Eq. 3).
    """
    if kind == "relu":
        return jnp.maximum(z, 0.0)
    if kind == "lrelu":
        return jnp.where(z > 0, z, alpha * z)
    if kind == "allrelu":
        return all_relu_ref(z, alpha, layer_index % 2)
    raise ValueError(f"unknown activation kind: {kind}")


def softmax_cross_entropy(logits, labels):
    """Mean softmax cross-entropy with integer labels (stable log-sum-exp)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - picked)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


def _unflatten(flat, n_layers, per_layer):
    """Group a flat arg tail into per-layer tuples of width ``per_layer``."""
    assert len(flat) == n_layers * per_layer, (len(flat), n_layers, per_layer)
    return [tuple(flat[i * per_layer : (i + 1) * per_layer]) for i in range(n_layers)]


def forward(x, flat_params, *, sizes: Sequence[int], act: str, alpha: float,
            use_pallas_first_layer: bool = False):
    """Masked MLP forward -> logits. ``flat_params`` = [w,b,m] per layer."""
    n_layers = len(sizes) - 1
    layers = _unflatten(list(flat_params), n_layers, 3)
    h = x
    for l, (w, b, m) in enumerate(layers, start=1):
        is_output = l == n_layers
        if use_pallas_first_layer and l == 1 and not is_output:
            # L1 kernel: fused masked matmul + All-ReLU tile kernel.
            # With act == "relu", parity=1/alpha=0.0 reduces AllReLU to ReLU.
            h = pk.masked_mlp_layer(
                h, w, m, b,
                alpha=alpha if act == "allrelu" else 0.0,
                parity=l % 2 if act == "allrelu" else 1,
            )
            continue
        z = h @ (w * m) + b
        h = z if is_output else activation(z, act, alpha, l)
    return h


def make_forward(sizes, act="allrelu", alpha=0.6, use_pallas_first_layer=False):
    """Positional-flat forward fn ready for jit/lowering."""

    def fn(x, *flat_params):
        return (
            forward(
                x, flat_params, sizes=sizes, act=act, alpha=alpha,
                use_pallas_first_layer=use_pallas_first_layer,
            ),
        )

    return fn


def make_train_step(sizes, act="allrelu", alpha=0.6, momentum=0.9,
                    weight_decay=0.0002):
    """Momentum-SGD masked train step (paper Eq. 1 + weight decay).

    v <- mu*v - lr*(g + wd*w);  w <- w + v.  Gradients are masked so
    update energy never leaks outside the sparse topology.
    """
    n_layers = len(sizes) - 1

    def loss_fn(wb, masks, x, y):
        flat = []
        for (w, b), m in zip(wb, masks):
            flat += [w, b, m]
        logits = forward(x, flat, sizes=sizes, act=act, alpha=alpha)
        return softmax_cross_entropy(logits, y), logits

    def fn(x, y, lr, *state):
        per = _unflatten(list(state), n_layers, 5)
        wb = [(w, b) for (w, b, vw, vb, m) in per]
        vel = [(vw, vb) for (w, b, vw, vb, m) in per]
        masks = [m for (w, b, vw, vb, m) in per]

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            wb, masks, x, y
        )
        acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))

        out = [loss, acc]
        for (w, b), (gw, gb), (vw, vb), m in zip(wb, grads, vel, masks):
            gw = gw * m  # keep updates inside the topology
            nvw = momentum * vw - lr * (gw + weight_decay * w)
            nvb = momentum * vb - lr * gb
            nw = (w + nvw) * m
            nb = b + nvb
            out += [nw, nb, nvw * m, nvb]
        return tuple(out)

    return fn


def init_state(sizes, density, seed=0, scheme="he_uniform"):
    """Reference initialiser (mirrors rust nn::init) used by tests/aot.

    Returns the flat per-layer [w, b, vw, vb, m] list for train_step.
    Mask is Erdős–Rényi with the given density.
    """
    key = jax.random.PRNGKey(seed)
    flat = []
    for l in range(len(sizes) - 1):
        fan_in, fan_out = sizes[l], sizes[l + 1]
        key, kw, km = jax.random.split(key, 3)
        if scheme == "he_uniform":
            lim = jnp.sqrt(6.0 / fan_in)
            w = jax.random.uniform(kw, (fan_in, fan_out), jnp.float32, -lim, lim)
        elif scheme == "xavier":
            lim = jnp.sqrt(6.0 / (fan_in + fan_out))
            w = jax.random.uniform(kw, (fan_in, fan_out), jnp.float32, -lim, lim)
        else:  # normal
            w = 0.05 * jax.random.normal(kw, (fan_in, fan_out), jnp.float32)
        m = (jax.random.uniform(km, (fan_in, fan_out)) < density).astype(jnp.float32)
        b = jnp.zeros((fan_out,), jnp.float32)
        flat += [w * m, b, jnp.zeros_like(w), jnp.zeros_like(b), m]
    return flat


# Architectures lowered by aot.py. Names appear in artifacts/manifest.json
# and in rust/src/runtime/. "small"/"quickstart" keep tests fast; the rest
# are the paper's Table 2 architectures (the masked-dense comparator).
ARCHITECTURES = {
    "small": dict(sizes=(64, 128, 64, 10), batch=32, act="allrelu", alpha=0.6),
    "quickstart": dict(sizes=(64, 128, 10), batch=32, act="allrelu", alpha=0.6,
                       use_pallas_first_layer=True),
    "higgs": dict(sizes=(28, 1000, 1000, 1000, 2), batch=128, act="allrelu",
                  alpha=0.05),
    "fashion": dict(sizes=(784, 1000, 1000, 1000, 10), batch=128, act="allrelu",
                    alpha=0.6),
    "cifar": dict(sizes=(3072, 4000, 1000, 4000, 10), batch=128, act="allrelu",
                  alpha=0.75),
}
