"""Pure-jnp oracles for the Pallas kernels (the correctness signal).

These are the straight-line jax.numpy definitions of everything in
``masked_mlp.py``; ``python/tests/test_kernel.py`` asserts allclose
between kernel and oracle across hypothesis-generated shape/dtype/seed
sweeps. Keep these boring and obviously-correct.
"""

import jax.numpy as jnp


def masked_matmul_ref(x, w, mask):
    """o = x @ (w * mask) in f32 accumulation."""
    return jnp.dot(
        x, (w * mask).astype(x.dtype), preferred_element_type=jnp.float32
    ).astype(x.dtype)


def all_relu_ref(z, alpha: float, parity: int):
    """All-ReLU, paper Eq. 3.

    Layer parity 0 (l % 2 == 0): negative side slope is -alpha.
    Layer parity 1 (l % 2 == 1): negative side slope is +alpha.
    """
    sign = -1.0 if parity == 0 else 1.0
    return jnp.where(z > 0, z, jnp.asarray(sign * alpha, z.dtype) * z)


def masked_mlp_layer_ref(x, w, mask, b, alpha: float, parity: int):
    """Fused layer oracle: AllReLU(x @ (w*mask) + b)."""
    z = jnp.dot(
        x, (w * mask).astype(x.dtype), preferred_element_type=jnp.float32
    ) + b.astype(jnp.float32)
    return all_relu_ref(z, alpha, parity).astype(x.dtype)


def srelu_ref(z, tl, al, tr, ar):
    """SReLU (Jin et al. 2016) oracle — used by the activation ablations.

    f(z) = tl + al*(z - tl)   z <= tl
           z                  tl < z < tr
           tr + ar*(z - tr)   z >= tr
    """
    below = tl + al * (z - tl)
    above = tr + ar * (z - tr)
    return jnp.where(z <= tl, below, jnp.where(z >= tr, above, z))
