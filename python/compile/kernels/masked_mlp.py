"""Layer-1 Pallas kernels: tiled masked matmul with fused All-ReLU.

The paper's "simulated sparsity" compute — dense weights with a binary
mask — is exactly what today's accelerators support (NVIDIA 2:4, TPU
masked-dense). This kernel is the hot-spot of the masked-dense baseline
(the "Keras" comparator of Tables 2-3) and the hardware-adaptation story
of DESIGN.md: the HBM<->VMEM schedule the paper's GPU peers express with
threadblocks is expressed here with a BlockSpec grid over MXU-shaped
(128x128) tiles.

All kernels are lowered with ``interpret=True`` so they execute on the
CPU PJRT backend (real TPU lowering emits a Mosaic custom-call the CPU
plugin cannot run). Correctness is pinned against ``ref.py`` by
``python/tests/test_kernel.py`` including hypothesis shape sweeps.

VMEM accounting (f32, per grid step, default TM=TN=TK=128):
    x tile   TM*TK*4 =  64 KiB
    w tile   TK*TN*4 =  64 KiB
    m tile   TK*TN*4 =  64 KiB
    acc      TM*TN*4 =  64 KiB
    total             256 KiB  << 16 MiB VMEM -> double-buffering head-room.
MXU estimate: each grid step issues a TMxTKxTN = 128^3 MAC block, i.e.
128 MXU-systolic passes at full 128x128 occupancy when shapes divide the
tile; ragged edges are padded by BlockSpec so utilisation = true_flops /
padded_flops (reported by ``mxu_utilisation`` below).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: MXU-shaped. TK is the contraction tile.
TM = 128
TN = 128
TK = 128


def _vmem_scratch(shape, dtype):
    """VMEM scratch allocation, portable across jax versions.

    On TPU this is ``pltpu.VMEM(shape, dtype)``; interpret mode emulates
    it with a plain buffer.
    """
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _masked_matmul_kernel(x_ref, w_ref, m_ref, o_ref, acc_ref, *, n_k: int):
    """One (i, j, k) grid step of o = x @ (w * m).

    Accumulates partial products over the k grid axis in an f32 VMEM
    scratch accumulator and writes the tile out on the last k step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xw = jnp.dot(
        x_ref[...],
        (w_ref[...] * m_ref[...]).astype(x_ref.dtype),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] += xw

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _alternated_left_relu(z, alpha, layer_parity):
    """All-ReLU (paper Eq. 3): slope sign alternates with layer parity.

    parity 0 (even layer index): f(z) = -alpha*z for z<=0
    parity 1 (odd  layer index): f(z) = +alpha*z for z<=0
    positive side is identity in both cases.
    """
    sign = jnp.where(layer_parity == 0, -1.0, 1.0).astype(z.dtype)
    return jnp.where(z > 0, z, sign * alpha * z)


def _masked_layer_kernel(
    x_ref, w_ref, m_ref, b_ref, o_ref, acc_ref, *, n_k: int, alpha: float, parity: int
):
    """Fused layer tile: o = AllReLU(x @ (w*m) + b)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...],
        (w_ref[...] * m_ref[...]).astype(x_ref.dtype),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _flush():
        z = acc_ref[...] + b_ref[...].astype(jnp.float32)
        a = _alternated_left_relu(z, jnp.float32(alpha), jnp.int32(parity))
        o_ref[...] = a.astype(o_ref.dtype)


def _grid(b, n_in, n_out, tm, tn, tk):
    return (pl.cdiv(b, tm), pl.cdiv(n_out, tn), pl.cdiv(n_in, tk))


def _pad_to(a, mults):
    """Zero-pad each axis of ``a`` up to a multiple of ``mults[axis]``.

    Ragged tile edges read out-of-bounds inside pallas (interpret mode
    surfaces them as NaN); zero padding outside the kernel is free under
    jit (fuses) and keeps the kernel branch-free. Zero padding is exact
    for matmul (0-contributions) and for All-ReLU applied to sliced-away
    rows/cols.
    """
    pads = []
    for dim, mult in zip(a.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return a
    return jnp.pad(a, pads)


@partial(jax.jit, static_argnames=("tm", "tn", "tk"))
def masked_matmul(x, w, mask, *, tm: int = TM, tn: int = TN, tk: int = TK):
    """o[b, n_out] = x[b, n_in] @ (w * mask)[n_in, n_out], Pallas-tiled.

    ``mask`` is the binary sparsity pattern (same shape as ``w``); this is
    the paper's "binary mask to simulate sparsity" compute path.
    """
    b, n_in = x.shape
    n_in2, n_out = w.shape
    assert n_in == n_in2 and w.shape == mask.shape
    tm, tn, tk = min(tm, b), min(tn, n_out), min(tk, n_in)
    xp = _pad_to(x, (tm, tk))
    wp = _pad_to(w, (tk, tn))
    mp = _pad_to(mask, (tk, tn))
    bp, n_inp = xp.shape
    n_outp = wp.shape[1]
    grid = _grid(bp, n_inp, n_outp, tm, tn, tk)
    out = pl.pallas_call(
        partial(_masked_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, n_outp), x.dtype),
        scratch_shapes=[_vmem_scratch((tm, tn), jnp.float32)],
        interpret=True,
    )(xp, wp, mp)
    return out[:b, :n_out]


@partial(jax.jit, static_argnames=("alpha", "parity", "tm", "tn", "tk"))
def masked_mlp_layer(
    x,
    w,
    mask,
    b,
    *,
    alpha: float = 0.6,
    parity: int = 0,
    tm: int = TM,
    tn: int = TN,
    tk: int = TK,
):
    """Fused masked layer with All-ReLU: AllReLU(x @ (w*mask) + b).

    ``parity`` is ``layer_index % 2`` (paper Eq. 3). Bias is broadcast
    along the batch tile; it rides in as a (1, tn) block.
    """
    bsz, n_in = x.shape
    n_in2, n_out = w.shape
    assert n_in == n_in2 and w.shape == mask.shape and b.shape == (n_out,)
    tm, tn, tk = min(tm, bsz), min(tn, n_out), min(tk, n_in)
    xp = _pad_to(x, (tm, tk))
    wp = _pad_to(w, (tk, tn))
    mp = _pad_to(mask, (tk, tn))
    bvp = _pad_to(b.reshape(1, -1), (1, tn))
    bp, n_inp = xp.shape
    n_outp = wp.shape[1]
    grid = _grid(bp, n_inp, n_outp, tm, tn, tk)
    out = pl.pallas_call(
        partial(_masked_layer_kernel, n_k=grid[2], alpha=alpha, parity=parity),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, tn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, n_outp), x.dtype),
        scratch_shapes=[_vmem_scratch((tm, tn), jnp.float32)],
        interpret=True,
    )(xp, wp, mp, bvp)
    return out[:bsz, :n_out]


def vmem_bytes(tm: int = TM, tn: int = TN, tk: int = TK, dtype_bytes: int = 4) -> int:
    """Per-grid-step VMEM footprint of the fused layer kernel."""
    return dtype_bytes * (tm * tk + 2 * tk * tn + tn + tm * tn) + 4 * tm * tn


def mxu_utilisation(b: int, n_in: int, n_out: int, tm=TM, tn=TN, tk=TK) -> float:
    """Analytic MXU utilisation: useful MACs / padded-tile MACs."""
    import math

    padded = (
        math.ceil(b / tm) * tm * math.ceil(n_in / tk) * tk * math.ceil(n_out / tn) * tn
    )
    return (b * n_in * n_out) / padded
