//! Quickstart: train a truly-sparse MLP with All-ReLU and Importance
//! Pruning on a synthetic FashionMNIST-like dataset, then checkpoint it.
//!
//! Run: `cargo run --release --example quickstart`

use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::importance::ImportanceConfig;
use tsnn::prelude::*;
use tsnn::train::train_sequential;

fn main() -> Result<()> {
    // 1. Generate a small image-like dataset (784 features, 10 classes).
    let spec = DatasetSpec::small("fashion");
    let mut rng = Rng::new(42);
    let data = datasets::generate(&spec, &mut rng)?;
    println!(
        "dataset: {} features, {} classes, {} train / {} test samples",
        data.n_features,
        data.n_classes,
        data.n_train(),
        data.n_test()
    );

    // 2. Configure SET training with the paper's three contributions:
    //    truly-sparse layers (ε), All-ReLU, and Importance Pruning.
    let mut cfg = TrainConfig::small_preset("fashion");
    cfg.epochs = 30;
    cfg.importance = Some(ImportanceConfig {
        start_epoch: 15,
        period: 5,
        percentile: 5.0,
        min_connections: 32,
    });

    // 3. Train on one core.
    let report = train_sequential(&cfg, &data, &mut rng)?;
    println!(
        "\nbest test accuracy : {:.2}%",
        100.0 * report.best_test_accuracy
    );
    println!("weights start -> end: {} -> {}", report.start_weights, report.end_weights);
    println!(
        "dense equivalent    : {} weights",
        data.n_features * 256 + 256 * 256 + 256 * 256 + 256 * data.n_classes
    );
    for (phase, secs) in report.phases.iter() {
        println!("time[{phase:<10}] = {secs:.2}s");
    }

    // 4. Save + reload the sparse checkpoint (never densified).
    let path = std::env::temp_dir().join("tsnn_quickstart.tsnn");
    tsnn::model::checkpoint::save(&report.model, &path)?;
    let reloaded = tsnn::model::checkpoint::load(&path)?;
    let mut ws = reloaded.alloc_workspace(256);
    let (_, acc) = reloaded.evaluate(&data.x_test, &data.y_test, 256, &mut ws);
    println!("\ncheckpoint reloaded; test accuracy {:.2}%", 100.0 * acc);
    assert!((acc - report.final_test_accuracy).abs() < 1e-6);
    Ok(())
}
