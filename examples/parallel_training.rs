//! WASAP-SGD vs WASSP-SGD vs sequential — the §2.3 comparison.
//!
//! Trains the same sparse model three ways on the synthetic Higgs-like
//! dataset and prints the Table-3-style comparison: accuracy, wall time,
//! staleness statistics and dropped-update counts.
//!
//! Run: `cargo run --release --example parallel_training [-- workers]`

use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::coordinator::{run_parallel, ParallelConfig};
use tsnn::prelude::*;
use tsnn::train::train_sequential;
use tsnn::util::Timer;

fn main() -> Result<()> {
    let workers: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);

    let spec = DatasetSpec::small("higgs");
    let mut rng = Rng::new(7);
    let data = datasets::generate(&spec, &mut rng)?;
    let mut cfg = TrainConfig::small_preset("higgs");
    cfg.epochs = 20;

    // --- sequential baseline ---
    let t = Timer::start();
    let seq = train_sequential(&cfg, &data, &mut Rng::new(7))?;
    let seq_time = t.secs();

    // --- WASAP (asynchronous phase 1) ---
    let pcfg = ParallelConfig {
        workers,
        phase1_epochs: 16,
        phase2_epochs: 4,
        synchronous: false,
            hot_start: true,
            grad_clip: 5.0,
        };
    let t = Timer::start();
    let wasap = run_parallel(&cfg, &pcfg, &data, &mut Rng::new(7))?;
    let wasap_time = t.secs();

    // --- WASSP (synchronous phase 1) ---
    let t = Timer::start();
    let wassp = run_parallel(
        &cfg,
        &ParallelConfig {
            synchronous: true,
            ..pcfg
        },
        &data,
        &mut Rng::new(7),
    )?;
    let wassp_time = t.secs();

    let mut table = tsnn::bench::Table::new(
        "Parallel vs sequential (higgs-like)",
        &["algorithm", "workers", "test acc", "time [s]", "staleness", "dropped"],
    );
    table.row(vec![
        "Sequential".into(),
        "1".into(),
        format!("{:.4}", seq.best_test_accuracy),
        format!("{seq_time:.1}"),
        "-".into(),
        "-".into(),
    ]);
    table.row(vec![
        "WASAP-SGD".into(),
        workers.to_string(),
        format!("{:.4}", wasap.final_test_accuracy),
        format!("{wasap_time:.1}"),
        format!("{:.2}", wasap.server_stats.mean_staleness),
        wasap.server_stats.dropped_entries.to_string(),
    ]);
    table.row(vec![
        "WASSP-SGD".into(),
        workers.to_string(),
        format!("{:.4}", wassp.final_test_accuracy),
        format!("{wassp_time:.1}"),
        format!("{:.2}", wassp.server_stats.mean_staleness),
        wassp.server_stats.dropped_entries.to_string(),
    ]);
    println!("{}", table.to_markdown());
    println!(
        "note: on a single-core host the wall-clock advantage of parallel\n\
         training is limited; staleness/dropped columns show the async\n\
         semantics are fully exercised regardless."
    );
    Ok(())
}
