//! End-to-end driver: proves every layer of the stack composes on one
//! real workload. This is the run recorded in EXPERIMENTS.md §E2E.
//!
//! Pipeline exercised:
//!   1. synthetic FashionMNIST-like data (L3 data substrate)
//!   2. truly-sparse sequential SET training with All-ReLU + Importance
//!      Pruning, several hundred epochs, loss curve logged (L3 engine)
//!   3. WASAP-SGD parallel training of the same task (L3 coordinator)
//!   4. masked-dense baseline via the AOT JAX/XLA artifacts — the L2
//!      graph embedding the L1 Pallas kernel — executed through PJRT
//!      from Rust ("Keras" comparator)
//!   5. sparse checkpoint round-trip
//!
//! Run: `cargo run --release --example end_to_end [-- epochs]`
//! Writes results/e2e_curve.csv with the loss curve.

use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::coordinator::{run_parallel, ParallelConfig};
use tsnn::importance::ImportanceConfig;
use tsnn::prelude::*;
use tsnn::runtime::{default_artifacts_dir, Manifest, MaskedDenseTrainer};
use tsnn::train::train_sequential;
use tsnn::util::Timer;

fn main() -> Result<()> {
    let epochs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    println!("=== [1/5] dataset ===");
    let spec = DatasetSpec::small("fashion");
    let mut rng = Rng::new(42);
    let data = datasets::generate(&spec, &mut rng)?;
    println!(
        "fashion-like: {} features, {} classes, {}+{} samples ({:.0} MiB)",
        data.n_features,
        data.n_classes,
        data.n_train(),
        data.n_test(),
        data.memory_mib()
    );

    println!("\n=== [2/5] truly-sparse sequential SET training ({epochs} epochs) ===");
    let mut cfg = TrainConfig::small_preset("fashion");
    cfg.epochs = epochs;
    cfg.importance = Some(ImportanceConfig {
        start_epoch: epochs / 2,
        period: 10,
        percentile: 5.0,
        min_connections: 64,
    });
    let t = Timer::start();
    let seq = train_sequential(&cfg, &data, &mut Rng::new(42))?;
    println!(
        "sequential: best acc {:.4}, weights {} -> {}, {:.1}s",
        seq.best_test_accuracy,
        seq.start_weights,
        seq.end_weights,
        t.secs()
    );
    // loss-curve log (every 10th epoch to keep output readable)
    println!("loss curve (every 10th epoch):");
    for e in seq.epochs.iter().step_by(10) {
        println!(
            "  epoch {:>4}: train_loss {:.4} train_acc {:.4} test_acc {:.4} weights {}",
            e.epoch, e.train_loss, e.train_accuracy, e.test_accuracy, e.weight_count
        );
    }
    let path = tsnn::bench::write_artifact("e2e_curve.csv", &seq.curves_csv())?;
    println!("full curve written to {}", path.display());

    println!("\n=== [3/5] WASAP-SGD parallel training ===");
    let pcfg = ParallelConfig {
        workers: 5,
        phase1_epochs: (epochs * 4 / 5).max(1),
        phase2_epochs: (epochs / 5).max(1),
        synchronous: false,
            hot_start: true,
            grad_clip: 5.0,
        };
    let t = Timer::start();
    let par = run_parallel(&cfg, &pcfg, &data, &mut Rng::new(42))?;
    println!(
        "WASAP: final acc {:.4} (phase1 {:.4}), staleness {:.2}, dropped {}, {:.1}s",
        par.final_test_accuracy,
        par.phase1_test_accuracy,
        par.server_stats.mean_staleness,
        par.server_stats.dropped_entries,
        t.secs()
    );

    println!("\n=== [4/5] masked-dense XLA baseline (L1 pallas -> L2 jax -> L3 rust) ===");
    let manifest = Manifest::load(&default_artifacts_dir())?;
    let arch = manifest
        .get("fashion")
        .expect("fashion artifact missing; run `make artifacts`");
    let mut baseline = MaskedDenseTrainer::new(arch, cfg.epsilon, &mut Rng::new(42))?;
    println!(
        "masked-dense state: {:.1} MiB (CSR equivalent: {:.1} MiB)",
        baseline.memory_bytes() as f64 / 1048576.0,
        seq.model.memory_bytes() as f64 / 1048576.0
    );
    let base_epochs = 3.min(epochs);
    let t = Timer::start();
    let mut last = None;
    for _ in 0..base_epochs {
        let ep = baseline.train_epoch(&data, 0.01, &mut rng)?;
        baseline.evolve(0.3, &mut rng);
        last = Some(ep);
    }
    let per_epoch = t.secs() / base_epochs as f64;
    let seq_per_epoch = seq.phases.get("train") / epochs as f64;
    println!(
        "masked-dense: {:.2}s/epoch vs truly-sparse {:.2}s/epoch ({}x)",
        per_epoch,
        seq_per_epoch,
        (per_epoch / seq_per_epoch.max(1e-9)).round()
    );
    if let Some(ep) = last {
        println!("masked-dense last epoch: loss {:.4} acc {:.4}", ep.loss, ep.accuracy);
    }
    let base_acc = baseline.evaluate(&data)?;
    println!("masked-dense test acc after {base_epochs} epochs: {base_acc:.4}");

    println!("\n=== [5/5] checkpoint round-trip ===");
    let ckpt = std::env::temp_dir().join("tsnn_e2e.tsnn");
    tsnn::model::checkpoint::save(&seq.model, &ckpt)?;
    let reloaded = tsnn::model::checkpoint::load(&ckpt)?;
    let mut ws = reloaded.alloc_workspace(256);
    let (_, acc) = reloaded.evaluate(&data.x_test, &data.y_test, 256, &mut ws);
    assert!((acc - seq.final_test_accuracy).abs() < 1e-6);
    println!("reload OK: acc {acc:.4} == {:.4}", seq.final_test_accuracy);

    println!("\nE2E: all five stages passed.");
    Ok(())
}
