//! Extreme-scale sparse MLPs (§2.4): build million-neuron truly-sparse
//! models, measure the four phases the paper reports (weight init /
//! training / inference / weight evolution) and show where the dense
//! equivalent would OOM.
//!
//! Run: `cargo run --release --example extreme_scale [-- neurons_millions]`
//! (defaults to 1M neurons; the table4_extreme bench sweeps further)

use tsnn::config::DatasetSpec;
use tsnn::nn::MomentumSgd;
use tsnn::prelude::*;
use tsnn::set::{evolve_model, EvolutionConfig};
use tsnn::util::Timer;

fn main() -> Result<()> {
    let millions: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);

    // 65536-feature binary task (scaled-down row count of the paper's
    // "big artificial dataset"); hidden width chosen to hit the target
    // neuron count with two hidden layers.
    let n_features = 65_536usize;
    let hidden = (((millions * 1e6) as usize).saturating_sub(n_features + 2) / 2).max(1000);
    let sizes = vec![n_features, hidden, hidden, 2];
    let epsilon = 5.0;

    let spec = DatasetSpec {
        name: "extreme".into(),
        generator: "extreme".into(),
        n_features,
        n_classes: 2,
        n_train: 512,
        n_test: 128,
    };
    println!("generating {} features x {} samples ...", n_features, 640);
    let mut rng = Rng::new(1);
    let data = datasets::generate(&spec, &mut rng)?;

    // --- weight initialisation (vectorised per-row: §2.4's bottleneck) ---
    let t = Timer::start();
    let mut model = SparseMlp::new(
        &sizes,
        epsilon,
        Activation::AllRelu { alpha: 0.6 },
        &WeightInit::HeUniform,
        &mut rng,
    )?;
    let init_secs = t.secs();

    let neurons = model.neuron_count();
    let weights = model.weight_count();
    let dense_weights: usize = sizes.windows(2).map(|w| w[0] * w[1]).sum();
    println!("\nneurons          : {neurons} ({:.2}M)", neurons as f64 / 1e6);
    println!("sparse weights   : {weights} ({:.1} MiB CSR)", model.memory_bytes() as f64 / 1048576.0);
    println!(
        "dense equivalent : {dense_weights} weights = {:.0} GiB f32 (+{:.0} GiB momentum) -> OOM on this host",
        dense_weights as f64 * 4.0 / 1073741824.0,
        dense_weights as f64 * 4.0 / 1073741824.0
    );
    println!("init time        : {init_secs:.1}s");

    // --- one training epoch (batch 128) ---
    let batch = 128;
    let mut ws = model.alloc_workspace(batch);
    let opt = MomentumSgd::default();
    let mut batcher = Batcher::new(data.n_train(), n_features, batch);
    batcher.reset(&mut rng);
    let t = Timer::start();
    let mut steps = 0;
    let mut last_loss = 0.0;
    while let Some((x, y)) = batcher.next_batch(&data.x_train, &data.y_train) {
        let s = model.train_step(x, y, &opt, 0.01, None, &mut ws, &mut rng);
        last_loss = s.loss;
        steps += 1;
    }
    let train_secs = t.secs();
    println!("train epoch      : {train_secs:.1}s ({steps} steps, loss {last_loss:.4})");

    // --- inference over the test split ---
    let t = Timer::start();
    let (_, acc) = model.evaluate(&data.x_test, &data.y_test, batch, &mut ws);
    println!("inference        : {:.1}s (acc {acc:.3})", t.secs());

    // --- topology evolution ---
    let t = Timer::start();
    evolve_model(&mut model, &EvolutionConfig::default(), &mut rng)?;
    println!("weight evolution : {:.1}s", t.secs());

    Ok(())
}
