//! Importance Pruning demo: during-training (Algorithm 2) vs
//! post-training (§5.3) on the Madelon dataset — the paper's flagship
//! pruning result (≈80% fewer parameters, *better* accuracy).
//!
//! Run: `cargo run --release --example importance_pruning`

use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::importance::{self, ImportanceConfig};
use tsnn::prelude::*;
use tsnn::train::train_sequential;

fn main() -> Result<()> {
    let spec = DatasetSpec::small("madelon");
    let mut rng = Rng::new(11);
    let data = datasets::generate(&spec, &mut rng)?;

    let mut base_cfg = TrainConfig::small_preset("madelon");
    base_cfg.epochs = 40;
    base_cfg.importance = None;

    // --- no pruning ---
    let base = train_sequential(&base_cfg, &data, &mut Rng::new(11))?;

    // --- Importance Pruning during training (Algorithm 2) ---
    let mut during_cfg = base_cfg.clone();
    during_cfg.importance = Some(ImportanceConfig {
        start_epoch: 15,
        period: 5,
        percentile: 10.0,
        min_connections: 64,
    });
    let during = train_sequential(&during_cfg, &data, &mut Rng::new(11))?;

    // --- post-training percentile sweep on the unpruned model (§5.3) ---
    println!("### Post-training pruning sweep (Table 6 style)\n");
    println!("| threshold | test acc | remaining weights |");
    println!("|-----------|----------|-------------------|");
    let mut ws = base.model.alloc_workspace(256);
    for pct in [5.0, 10.0, 15.0, 20.0, 25.0] {
        let mut m = base.model.clone();
        let (_, remaining) = importance::prune_post_training(&mut m, pct);
        let (_, acc) = m.evaluate(&data.x_test, &data.y_test, 256, &mut ws);
        println!("| {pct:>4}th    | {:.4}   | {remaining:>8}          |", acc);
    }

    println!("\n### During-training vs baseline\n");
    println!(
        "baseline : acc {:.4}, weights {} -> {}",
        base.best_test_accuracy, base.start_weights, base.end_weights
    );
    println!(
        "integrated: acc {:.4}, weights {} -> {}  ({:.0}% params removed)",
        during.best_test_accuracy,
        during.start_weights,
        during.end_weights,
        100.0 * (1.0 - during.end_weights as f64 / during.start_weights as f64)
    );
    println!(
        "\ntrain-time: baseline {:.1}s vs integrated {:.1}s",
        base.phases.get("train"),
        during.phases.get("train")
    );
    Ok(())
}
