//! Crash/chaos harness for the fault-tolerance layer (DESIGN.md §13).
//!
//! In-process legs pin the resume-parity contract: a run checkpointed
//! at every epoch boundary, stopped at an arbitrary one and resumed,
//! lands on the bit-identical final model the uninterrupted run
//! produces — across kernel budgets (or the single `KERNEL_THREADS`
//! budget CI pins) and with dropout drawing from the restored RNG.
//!
//! Process legs drive the real binary: SIGKILL a `tsnn train` run
//! mid-training and resume it; corrupt a durable state and watch the
//! resume be refused; SIGKILL a supervised `tsnn worker` child
//! mid-phase-1 and assert the respawned worker rejoins without changing
//! the applied-update trajectory (same saved checkpoint bytes, same
//! printed accuracy as the unharmed run).

use std::path::{Path, PathBuf};

use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::data::{datasets, Dataset};
use tsnn::model::SparseMlp;
use tsnn::nn::LrSchedule;
use tsnn::train::{
    load_state, train_model_hooked, train_resume, train_sequential_opts, CheckpointPolicy,
    HookAction, TrainOptions, TrainState,
};
use tsnn::util::{PhaseTimes, Rng};

mod common;

const SEED: u64 = 40;

/// Per-test scratch directory, unique per process so parallel CI legs
/// sharing a host never collide.
fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tsnn_chaos_{}_{}", name, std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Small madelon-style toy set: these tests pin recovery machinery, not
/// learning capacity.
fn toy_data() -> Dataset {
    let spec = DatasetSpec {
        name: "toy".into(),
        generator: "madelon".into(),
        n_features: 60,
        n_classes: 2,
        n_train: 400,
        n_test: 160,
    };
    datasets::generate(&spec, &mut Rng::new(1)).unwrap()
}

fn quick_cfg() -> TrainConfig {
    TrainConfig {
        hidden: vec![32, 16],
        epsilon: 8.0,
        epochs: 8,
        batch: 50,
        dropout: 0.0,
        lr: LrSchedule::Constant(0.05),
        ..TrainConfig::default()
    }
}

fn assert_models_bit_equal(a: &SparseMlp, b: &SparseMlp, what: &str) {
    assert_eq!(a.sizes, b.sizes, "{what}: sizes differ");
    for (l, (la, lb)) in a.layers.iter().zip(b.layers.iter()).enumerate() {
        assert_eq!(la.weights, lb.weights, "{what}: layer {l} weights differ");
        assert_eq!(la.bias, lb.bias, "{what}: layer {l} bias differs");
        assert_eq!(la.velocity, lb.velocity, "{what}: layer {l} velocity differs");
        assert_eq!(
            la.bias_velocity, lb.bias_velocity,
            "{what}: layer {l} bias velocity differs"
        );
    }
}

/// The staging sibling the durable-write protocol renames from. Pinned
/// by name here: resume-time crash hygiene deletes exactly this path.
fn stale_tmp_sibling(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// A run checkpointed every epoch and stopped at an arbitrary boundary
/// resumes to the bit-identical final model: same weights, velocities,
/// accuracies and epoch logs as the run that never died. Dropout in the
/// last case proves the restored RNG replays regularisation draws too.
#[test]
fn resume_from_a_mid_run_checkpoint_matches_the_uninterrupted_run() {
    let data = toy_data();
    let dir = tmp_dir("resume_parity");
    let cases: &[(usize, f32)] = &[(0, 0.0), (5, 0.0), (3, 0.2)];
    for &threads in &common::thread_counts() {
        for (case, &(stop, dropout)) in cases.iter().enumerate() {
            let mut cfg = quick_cfg();
            cfg.kernel_threads = threads;
            cfg.dropout = dropout;
            let what = format!("threads {threads} stop {stop} dropout {dropout}");

            let reference =
                train_sequential_opts(&cfg, &data, &mut Rng::new(SEED), TrainOptions::default())
                    .unwrap();

            // the "killed" run: same model construction and RNG stream
            // as train_sequential_opts, every-epoch checkpoints, stopped
            // at the chosen epoch boundary
            let path = dir.join(format!("resume_{threads}_{case}.tsnt"));
            let mut rng = Rng::new(SEED);
            let sizes = cfg.sizes(data.n_features, data.n_classes);
            let mut model =
                SparseMlp::new(&sizes, cfg.epsilon, cfg.activation, &cfg.init, &mut rng).unwrap();
            let opts = TrainOptions {
                checkpoint: Some(CheckpointPolicy { path: path.clone(), every: 1 }),
                ..TrainOptions::default()
            };
            let mut stop_hook = |epoch: usize, _: &SparseMlp| {
                if epoch == stop {
                    HookAction::Stop
                } else {
                    HookAction::Continue
                }
            };
            let mut phases = PhaseTimes::new();
            train_model_hooked(
                &cfg,
                &data,
                &mut model,
                &mut rng,
                opts,
                &mut phases,
                Some(&mut stop_hook),
            )
            .unwrap();
            drop(model); // the predecessor process is gone

            let state = load_state(&path).unwrap();
            assert_eq!(state.next_epoch, stop + 1, "{what}: checkpoint cadence");
            let mut phases = PhaseTimes::new();
            let resumed =
                train_resume(&cfg, &data, state, TrainOptions::default(), &mut phases).unwrap();

            assert_models_bit_equal(&reference.model, &resumed.model, &what);
            assert_eq!(reference.epochs.len(), resumed.epochs.len(), "{what}: epoch logs");
            assert_eq!(reference.end_weights, resumed.end_weights, "{what}: end weights");
            assert_eq!(
                reference.final_test_accuracy.to_bits(),
                resumed.final_test_accuracy.to_bits(),
                "{what}: final accuracy"
            );
            assert_eq!(
                reference.best_test_accuracy.to_bits(),
                resumed.best_test_accuracy.to_bits(),
                "{what}: best accuracy"
            );
            std::fs::remove_file(&path).unwrap();
        }
    }
}

/// A crash between the temp write and the atomic rename leaves a
/// `PATH.tmp` sibling. Only the renamed file is ever trusted: loading
/// ignores the sibling, and resume-time hygiene removes it.
#[test]
fn a_stale_temp_from_a_crashed_save_is_ignored_and_cleaned() {
    let data = toy_data();
    let mut cfg = quick_cfg();
    cfg.epochs = 2;
    let dir = tmp_dir("stale_tmp");
    let path = dir.join("run.tsnt");
    let opts = TrainOptions {
        checkpoint: Some(CheckpointPolicy { path: path.clone(), every: 1 }),
        ..TrainOptions::default()
    };
    train_sequential_opts(&cfg, &data, &mut Rng::new(SEED), opts).unwrap();

    let tmp = stale_tmp_sibling(&path);
    std::fs::write(&tmp, b"torn half-written image").unwrap();
    let state = load_state(&path).unwrap();
    assert_eq!(state.next_epoch, 2, "stale temp must not shadow the real state");
    TrainState::clean_stale_tmp(&path);
    assert!(!tmp.exists(), "stale temp must be removed");
    assert!(load_state(&path).is_ok());
    std::fs::remove_file(&path).unwrap();
}

/// Process-level chaos against the real binary (SIGKILL semantics).
#[cfg(unix)]
mod cli {
    use std::process::{Command, Output, Stdio};
    use std::thread;
    use std::time::{Duration, Instant};

    use super::tmp_dir;

    fn tsnn() -> Command {
        Command::new(env!("CARGO_BIN_EXE_tsnn"))
    }

    fn stderr_of(out: &Output) -> String {
        String::from_utf8_lossy(&out.stderr).into_owned()
    }

    /// `tsnn train` arguments sized so a debug-mode run lasts long
    /// enough to be killed mid-training yet stays CI-cheap.
    fn train_args(epochs: usize) -> Vec<String> {
        vec![
            "train".into(),
            "madelon".into(),
            "--seed".into(),
            "40".into(),
            format!("epochs={epochs}"),
            "hidden=32x16".into(),
            "epsilon=2".into(),
            "batch=100".into(),
            "dropout=0".into(),
            "kernel_threads=1".into(),
        ]
    }

    /// SIGKILL a `tsnn train --state … --checkpoint-every 1` process as
    /// soon as its first durable state lands, then `--resume` it: the
    /// resumed run's saved final model is byte-identical to a run that
    /// was never interrupted.
    #[test]
    fn a_sigkilled_trainer_resumes_to_the_uninterrupted_final_model() {
        let dir = tmp_dir("cli_kill_trainer");
        let state = dir.join("run.tsnt");
        let reference = dir.join("reference.tsnn");
        let resumed = dir.join("resumed.tsnn");

        let out = tsnn().args(train_args(5)).arg("--save").arg(&reference).output().unwrap();
        assert!(out.status.success(), "reference run failed: {}", stderr_of(&out));

        let mut child = tsnn()
            .args(train_args(5))
            .arg("--state")
            .arg(&state)
            .args(["--checkpoint-every", "1"])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .unwrap();
        let deadline = Instant::now() + Duration::from_secs(120);
        while !state.exists() && Instant::now() < deadline {
            if matches!(child.try_wait(), Ok(Some(_))) {
                break;
            }
            thread::sleep(Duration::from_millis(2));
        }
        assert!(state.exists(), "trainer never wrote a durable state");
        let _ = child.kill(); // SIGKILL mid-run (no-op if it already finished)
        let _ = child.wait();

        let out = tsnn()
            .args(train_args(5))
            .arg("--resume")
            .arg(&state)
            .arg("--save")
            .arg(&resumed)
            .output()
            .unwrap();
        assert!(out.status.success(), "resume failed: {}", stderr_of(&out));
        let a = std::fs::read(&reference).unwrap();
        let b = std::fs::read(&resumed).unwrap();
        assert!(a == b, "resumed final model differs from the uninterrupted run");
    }

    /// A flipped bit anywhere in a durable state is refused at resume
    /// with the typed checksum error — never a half-restored run.
    #[test]
    fn resuming_from_a_corrupt_state_is_refused_with_a_checksum_error() {
        let dir = tmp_dir("cli_corrupt_state");
        let state = dir.join("run.tsnt");
        let out = tsnn().args(train_args(2)).arg("--state").arg(&state).output().unwrap();
        assert!(out.status.success(), "seed run failed: {}", stderr_of(&out));

        let mut bytes = std::fs::read(&state).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x20;
        std::fs::write(&state, &bytes).unwrap();

        let out = tsnn().args(train_args(2)).arg("--resume").arg(&state).output().unwrap();
        assert!(!out.status.success(), "corrupt state must not resume");
        let err = stderr_of(&out);
        assert!(err.contains("checksum mismatch"), "unexpected error: {err}");
    }

    /// Kernel budget for the multiprocess leg: the pinned
    /// `KERNEL_THREADS` when CI sets one, else 2.
    #[cfg(target_os = "linux")]
    fn pinned_kernel_threads() -> usize {
        std::env::var("KERNEL_THREADS")
            .ok()
            .and_then(|s| s.trim().parse().ok())
            .filter(|&t| t >= 1)
            .unwrap_or(2)
    }

    /// Find a live `tsnn worker` child of this run (argv `worker
    /// --connect …` mentioning the run's unique socket path) via /proc.
    #[cfg(target_os = "linux")]
    fn find_worker_pid(marker: &str, coordinator: u32) -> Option<u32> {
        for entry in std::fs::read_dir("/proc").ok()?.flatten() {
            let Some(pid) = entry.file_name().to_str().and_then(|s| s.parse::<u32>().ok()) else {
                continue;
            };
            if pid == coordinator {
                continue;
            }
            let Ok(cmdline) = std::fs::read(format!("/proc/{pid}/cmdline")) else {
                continue;
            };
            let is_worker = cmdline.split(|&b| b == 0).nth(1) == Some(&b"worker"[..]);
            if is_worker && cmdline.windows(marker.len()).any(|w| w == marker.as_bytes()) {
                return Some(pid);
            }
        }
        None
    }

    #[cfg(target_os = "linux")]
    fn final_acc(stdout: &[u8]) -> String {
        let text = String::from_utf8_lossy(stdout);
        text.split_whitespace()
            .find(|t| t.starts_with("final_acc="))
            .map(str::to_string)
            .unwrap_or_else(|| panic!("no final_acc in output: {text}"))
    }

    /// SIGKILL one of two supervised worker processes mid-run: the
    /// supervisor respawns it, the coordinator holds its shard for
    /// rejoin, and the run completes with exactly the unharmed run's
    /// final model and accuracy (the rejoin cursor keeps the crash off
    /// the applied-update trajectory).
    #[cfg(target_os = "linux")]
    #[test]
    fn a_sigkilled_worker_is_respawned_and_the_run_completes_identically() {
        let dir = tmp_dir("cli_kill_worker");
        let sock = dir.join("coord.sock");
        let reference = dir.join("reference.tsnn");
        let harmed = dir.join("harmed.tsnn");
        let kt = pinned_kernel_threads();

        let base: Vec<String> = vec![
            "parallel".into(),
            "madelon".into(),
            "--seed".into(),
            "7".into(),
            "epochs=4".into(),
            "hidden=32x16".into(),
            "epsilon=2".into(),
            "batch=100".into(),
            "dropout=0".into(),
            format!("kernel_threads={kt}"),
            "--workers".into(),
            "2".into(),
            "--phase1".into(),
            "2".into(),
            "--phase2".into(),
            "1".into(),
            "--sync".into(),
        ];

        let out = tsnn().args(&base).arg("--save").arg(&reference).output().unwrap();
        assert!(out.status.success(), "in-process reference failed: {}", stderr_of(&out));
        let ref_acc = final_acc(&out.stdout);

        let transport = format!("unix:{}", sock.display());
        let mut child = tsnn()
            .args(&base)
            .args(["--transport", &transport, "--supervise", "--max-restarts", "3"])
            .arg("--save")
            .arg(&harmed)
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .unwrap();

        let marker = sock.display().to_string();
        let mut victim = None;
        let deadline = Instant::now() + Duration::from_secs(30);
        while victim.is_none() && Instant::now() < deadline {
            if matches!(child.try_wait(), Ok(Some(_))) {
                break;
            }
            victim = find_worker_pid(&marker, child.id());
            thread::sleep(Duration::from_millis(2));
        }
        let victim = victim.expect("no worker process appeared to kill");
        let killed = Command::new("kill")
            .args(["-9", &victim.to_string()])
            .status()
            .map(|s| s.success())
            .unwrap_or(false);
        assert!(killed, "kill -9 {victim} failed");

        let out = child.wait_with_output().unwrap();
        assert!(out.status.success(), "supervised run did not complete after the kill");
        assert_eq!(final_acc(&out.stdout), ref_acc, "accuracy diverged after the worker kill");
        let a = std::fs::read(&reference).unwrap();
        let b = std::fs::read(&harmed).unwrap();
        assert!(a == b, "final model diverged after the worker kill");
    }
}
