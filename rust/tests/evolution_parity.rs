//! Evolution-parity suite: the worker-sharded in-place evolution engine
//! (DESIGN.md §8) must reproduce the sequential oracles bit-for-bit —
//! exact topology, weight values, remapped velocity, bias state and
//! caller-RNG consumption — at every thread count, across shapes ×
//! ζ ∈ {0.0, 0.3, 0.9} × threads {1, 2, 8} (plus the `KERNEL_THREADS`
//! environment override CI sweeps), including the empty-layer,
//! fully-dense-layer and single-surviving-neuron edge cases.
//!
//! Mirrors the fused-backward vs two-kernel-oracle pattern of
//! `kernel_parity.rs` (DESIGN.md §5): the oracles stay in-tree as the
//! semantics definition, the engine is the hot path.

use tsnn::importance::{self, ImportanceConfig};
use tsnn::model::SparseMlp;
use tsnn::nn::Activation;
use tsnn::set::{self, EvolutionConfig, EvolutionEngine};
use tsnn::sparse::WeightInit;
use tsnn::util::Rng;

mod common;
use common::thread_counts;

fn assert_models_equal(a: &SparseMlp, b: &SparseMlp, label: &str) {
    assert_eq!(a.layers.len(), b.layers.len(), "{label}: layer count");
    for (l, (la, lb)) in a.layers.iter().zip(b.layers.iter()).enumerate() {
        assert_eq!(la.weights, lb.weights, "{label}: layer {l} weights");
        assert_eq!(la.velocity, lb.velocity, "{label}: layer {l} velocity");
        assert_eq!(la.bias, lb.bias, "{label}: layer {l} bias");
        assert_eq!(
            la.bias_velocity, lb.bias_velocity,
            "{label}: layer {l} bias velocity"
        );
    }
}

/// Model with non-trivial aligned state so a velocity-remap bug cannot
/// hide behind zeros.
fn model(sizes: &[usize], eps: f64, seed: u64) -> SparseMlp {
    let mut rng = Rng::new(seed);
    let mut m = SparseMlp::new(
        sizes,
        eps,
        Activation::Relu,
        &WeightInit::Normal(0.5),
        &mut rng,
    )
    .unwrap();
    for layer in m.layers.iter_mut() {
        for (k, v) in layer.velocity.iter_mut().enumerate() {
            *v = 0.01 * (k + 1) as f32;
        }
        for (j, b) in layer.bias.iter_mut().enumerate() {
            *b = 0.1 * (j + 1) as f32;
        }
        for (j, b) in layer.bias_velocity.iter_mut().enumerate() {
            *b = -0.2 * (j + 1) as f32;
        }
    }
    m
}

/// Engine vs oracle on `base` for one SET epoch at every thread count:
/// exact model match, stats match, and identical caller-RNG advance.
fn assert_set_parity(base: &SparseMlp, zeta: f64, seed: u64, label: &str) {
    let cfg = EvolutionConfig {
        zeta,
        init: WeightInit::HeUniform,
    };
    let mut oracle = base.clone();
    let mut r_oracle = Rng::new(seed);
    let o_stats = set::evolve_model(&mut oracle, &cfg, &mut r_oracle).unwrap();
    oracle.validate().unwrap();
    for threads in thread_counts() {
        let mut m = base.clone();
        let mut engine = EvolutionEngine::new();
        let mut r = Rng::new(seed);
        let stats = engine.evolve_model(&mut m, &cfg, &mut r, threads).unwrap();
        let label = format!("{label} zeta {zeta} threads {threads}");
        m.validate().unwrap();
        assert_models_equal(&oracle, &m, &label);
        for (l, s) in stats.iter().enumerate() {
            assert_eq!(s.pruned, o_stats[l].pruned, "{label}: layer {l} pruned");
            assert_eq!(s.regrown, o_stats[l].regrown, "{label}: layer {l} regrown");
            assert_eq!(s.importance_pruned, 0, "{label}: layer {l}");
        }
        // the caller's generator advanced by exactly the same draws
        assert_eq!(
            r.next_u64(),
            r_oracle.clone().next_u64(),
            "{label}: caller RNG diverged"
        );
    }
}

#[test]
fn threaded_evolution_matches_sequential_oracle_exactly() {
    let shapes: &[&[usize]] = &[
        &[20, 30, 5],
        &[64, 48, 32, 10],
        &[7, 250, 3], // wide hidden layer: row sharding with few classes
    ];
    for (si, sizes) in shapes.iter().enumerate() {
        for zeta in [0.0f64, 0.3, 0.9] {
            let base = model(sizes, 6.0, 40 + si as u64);
            assert_set_parity(&base, zeta, 1_000 + si as u64, &format!("sizes {sizes:?}"));
        }
    }
}

#[test]
fn parity_holds_above_rebuild_shard_crossover() {
    // big enough that the engine's row-sharded rebuild genuinely runs
    // rather than falling back to the sequential pass — the crossover is
    // gated on a SINGLE layer's nnz (2^17), so guard the per-layer max,
    // not the model total
    let base = model(&[512, 640, 16], 120.0, 44);
    let max_layer_nnz = base.layers.iter().map(|l| l.weights.nnz()).max().unwrap();
    assert!(
        max_layer_nnz >= 1 << 17,
        "test must cross the per-layer rebuild crossover, max layer nnz = {max_layer_nnz}"
    );
    for zeta in [0.3f64, 0.9] {
        assert_set_parity(&base, zeta, 2_000, "crossover");
    }
}

#[test]
fn engine_workspace_reuse_stays_exact_across_epochs() {
    // the engine reuses (and swaps through) its workspace buffers; four
    // chained epochs must still track the oracle exactly
    let base = model(&[40, 60, 40, 8], 8.0, 77);
    let cfg = EvolutionConfig::default();
    for threads in thread_counts() {
        let mut oracle = base.clone();
        let mut m = base.clone();
        let mut engine = EvolutionEngine::new();
        let mut r_oracle = Rng::new(5);
        let mut r = Rng::new(5);
        for epoch in 0..4 {
            set::evolve_model(&mut oracle, &cfg, &mut r_oracle).unwrap();
            engine.evolve_model(&mut m, &cfg, &mut r, threads).unwrap();
            assert_models_equal(&oracle, &m, &format!("epoch {epoch} threads {threads}"));
        }
    }
}

#[test]
fn fused_importance_evolution_matches_prune_model_then_evolve() {
    let imp = ImportanceConfig {
        start_epoch: 0,
        period: 1,
        percentile: 20.0,
        min_connections: 4,
    };
    let cfg = EvolutionConfig {
        zeta: 0.3,
        init: WeightInit::HeUniform,
    };
    let base = model(&[30, 50, 40, 6], 6.0, 91);
    let mut oracle = base.clone();
    let mut r_oracle = Rng::new(9);
    let removed = importance::prune_model(&mut oracle, &imp);
    assert!(removed > 0, "test needs a real importance prune");
    let o_stats = set::evolve_model(&mut oracle, &cfg, &mut r_oracle).unwrap();
    for threads in thread_counts() {
        let mut m = base.clone();
        let mut engine = EvolutionEngine::new();
        let mut r = Rng::new(9);
        let stats = engine
            .evolve_epoch(&mut m, Some(&cfg), Some(&imp), &mut r, threads)
            .unwrap();
        let label = format!("fused importance threads {threads}");
        m.validate().unwrap();
        assert_models_equal(&oracle, &m, &label);
        let imp_total: usize = stats.iter().map(|s| s.importance_pruned).sum();
        assert_eq!(imp_total, removed, "{label}: importance-pruned total");
        for (l, s) in stats.iter().enumerate() {
            assert_eq!(s.pruned, o_stats[l].pruned, "{label}: layer {l} pruned");
            assert_eq!(s.regrown, o_stats[l].regrown, "{label}: layer {l} regrown");
        }
        assert_eq!(
            r.next_u64(),
            r_oracle.clone().next_u64(),
            "{label}: caller RNG diverged"
        );
    }
}

#[test]
fn importance_only_epoch_matches_prune_model() {
    let imp = ImportanceConfig {
        start_epoch: 0,
        period: 1,
        percentile: 35.0,
        min_connections: 0,
    };
    let base = model(&[25, 40, 40, 5], 5.0, 17);
    let mut oracle = base.clone();
    let removed = importance::prune_model(&mut oracle, &imp);
    assert!(removed > 0);
    for threads in thread_counts() {
        let mut m = base.clone();
        let mut engine = EvolutionEngine::new();
        let mut rng = Rng::new(3);
        let probe = rng.clone();
        let stats = engine
            .evolve_epoch(&mut m, None, Some(&imp), &mut rng, threads)
            .unwrap();
        let label = format!("importance-only threads {threads}");
        assert_models_equal(&oracle, &m, &label);
        assert!(stats.iter().all(|s| s.pruned == 0 && s.regrown == 0));
        // no SET step -> no caller randomness consumed (like prune_model)
        assert_eq!(rng.next_u64(), probe.clone().next_u64(), "{label}");
    }
}

#[test]
fn empty_layer_edge_case_matches_oracle() {
    let mut base = model(&[10, 12, 4], 4.0, 55);
    base.layers[0].retain_entries(|_| false);
    assert_eq!(base.layers[0].weights.nnz(), 0);
    for zeta in [0.0f64, 0.3, 0.9] {
        assert_set_parity(&base, zeta, 21, "empty layer");
    }
}

#[test]
fn fully_dense_layer_regrows_exactly_min_pruned_capacity() {
    // Fully dense layers: every post-prune empty position is a freshly
    // pruned slot, so capacity == pruned and gap sampling must regrow
    // exactly min(pruned, capacity) = pruned links. The old rejection
    // sampler could exhaust max_attempts here and under-regrow; the
    // deterministic gap path cannot.
    let base = model(&[16, 16, 16], 1e9, 60); // ε clamps density to 1.0
    for layer in &base.layers {
        assert_eq!(layer.weights.nnz(), layer.n_in() * layer.n_out());
    }
    assert_set_parity(&base, 0.3, 22, "dense");
    for threads in thread_counts() {
        let mut m = base.clone();
        let mut engine = EvolutionEngine::new();
        let cfg = EvolutionConfig {
            zeta: 0.3,
            init: WeightInit::HeUniform,
        };
        let stats = engine
            .evolve_model(&mut m, &cfg, &mut Rng::new(3), threads)
            .unwrap();
        for (l, s) in stats.iter().enumerate() {
            assert!(s.pruned > 0, "layer {l} must prune");
            assert_eq!(s.regrown, s.pruned, "layer {l}: dense capacity == pruned");
            assert_eq!(
                m.layers[l].weights.nnz(),
                m.layers[l].n_in() * m.layers[l].n_out(),
                "layer {l} must return to full density"
            );
        }
        m.validate().unwrap();
    }
}

#[test]
fn single_surviving_neuron_edge_case_matches_oracle() {
    // importance pruning collapses layer 0 to (essentially) one hub
    // column; the fused epoch must still match the two-call oracle
    let mut base = model(&[8, 10, 3], 20.0, 58);
    {
        let layer = &mut base.layers[0];
        let cols = layer.weights.col_idx.clone();
        for (k, &j) in cols.iter().enumerate() {
            // hub column 4 dominates; every other importance is distinct
            // and strictly below it (no percentile ties)
            layer.weights.values[k] = if j == 4 {
                5.0 + 0.01 * k as f32
            } else {
                1e-4 * (k as f32 + 1.0)
            };
        }
    }
    let imp = ImportanceConfig {
        start_epoch: 0,
        period: 1,
        percentile: 100.0, // threshold = max importance -> only the hub
        min_connections: 0,
    };
    {
        let mut only_imp = base.clone();
        importance::prune_model(&mut only_imp, &imp);
        let counts = only_imp.layers[0].weights.column_counts();
        assert_eq!(
            counts.iter().filter(|&&c| c > 0).count(),
            1,
            "importance pruning must leave a single surviving neuron"
        );
    }
    let cfg = EvolutionConfig {
        zeta: 0.5,
        init: WeightInit::HeUniform,
    };
    let mut oracle = base.clone();
    let mut r_oracle = Rng::new(12);
    importance::prune_model(&mut oracle, &imp);
    set::evolve_model(&mut oracle, &cfg, &mut r_oracle).unwrap();
    // the hub (and any percentile ties) survive; the layer stays alive
    assert!(oracle.layers[0].weights.nnz() > 0);
    for threads in thread_counts() {
        let mut m = base.clone();
        let mut engine = EvolutionEngine::new();
        let mut r = Rng::new(12);
        engine
            .evolve_epoch(&mut m, Some(&cfg), Some(&imp), &mut r, threads)
            .unwrap();
        m.validate().unwrap();
        assert_models_equal(&oracle, &m, &format!("single-neuron threads {threads}"));
    }
}

#[test]
fn evolve_step_reuses_workspace_buffers_in_steady_state() {
    // Acceptance gate: zero steady-state heap allocation on the hot path.
    // The engine counts every workspace-buffer capacity growth; after the
    // first (warm-up) epoch the count must never move again — nnz only
    // shrinks under SET, and every buffer reserves its first-epoch bound.
    let mut m = model(&[50, 80, 60, 10], 8.0, 70);
    let mut engine = EvolutionEngine::new();
    let cfg = EvolutionConfig::default();
    let mut rng = Rng::new(4);
    engine.evolve_model(&mut m, &cfg, &mut rng, 4).unwrap();
    let warm = engine.buffer_growth_events();
    assert!(warm > 0, "first epoch must size the workspace");
    for _ in 0..6 {
        engine.evolve_model(&mut m, &cfg, &mut rng, 4).unwrap();
    }
    assert_eq!(
        engine.buffer_growth_events(),
        warm,
        "steady-state evolution must not grow workspace buffers"
    );
    // the fused importance path rides the same buffers
    let imp = ImportanceConfig {
        start_epoch: 0,
        period: 1,
        percentile: 10.0,
        min_connections: 8,
    };
    engine
        .evolve_epoch(&mut m, Some(&cfg), Some(&imp), &mut rng, 4)
        .unwrap();
    let warm_imp = engine.buffer_growth_events();
    for _ in 0..4 {
        engine
            .evolve_epoch(&mut m, Some(&cfg), Some(&imp), &mut rng, 4)
            .unwrap();
    }
    assert_eq!(engine.buffer_growth_events(), warm_imp);
}

#[test]
fn engine_with_shared_training_pool_matches_oracle() {
    // the train loop hands its kernel pool to the engine
    // (EvolutionEngine::with_pool) — evolution dispatched on that shared
    // pool must still be bit-exact at every pool size
    use std::sync::Arc;
    use tsnn::sparse::WorkerPool;

    let base = model(&[30, 60, 40, 6], 6.0, 123);
    let cfg = EvolutionConfig::default();
    let mut oracle = base.clone();
    set::evolve_model(&mut oracle, &cfg, &mut Rng::new(9)).unwrap();
    for threads in thread_counts() {
        let pool = Arc::new(WorkerPool::new(threads));
        let mut m = base.clone();
        let mut engine = EvolutionEngine::with_pool(Arc::clone(&pool));
        engine
            .evolve_model(&mut m, &cfg, &mut Rng::new(9), threads)
            .unwrap();
        assert_models_equal(&oracle, &m, &format!("shared pool threads {threads}"));
        if threads > 1 {
            assert!(
                pool.dispatch_events() > 0,
                "threads {threads}: the layer pass must dispatch on the shared pool"
            );
        }
    }
}

#[test]
fn thread_count_zero_means_auto_and_stays_exact() {
    let base = model(&[30, 40, 6], 6.0, 33);
    let cfg = EvolutionConfig::default();
    let mut oracle = base.clone();
    set::evolve_model(&mut oracle, &cfg, &mut Rng::new(8)).unwrap();
    let mut m = base.clone();
    let mut engine = EvolutionEngine::new();
    engine
        .evolve_model(&mut m, &cfg, &mut Rng::new(8), 0)
        .unwrap();
    assert_models_equal(&oracle, &m, "threads=0 (auto)");
}
