//! Parity and protocol tests for the transport-based coordinator.
//!
//! The heart of this suite is bit-exactness: the channel-transport WASSP
//! run must reproduce the pre-transport thread coordinator's float
//! trajectory to the last bit, and a fault-injected run must reproduce
//! the clean run (idempotent retries change traffic, never the applied
//! update sequence). The remaining tests pin the protocol's elasticity
//! and admission rules.

use std::sync::Arc;
use std::time::Duration;

use tsnn::config::TrainConfig;
use tsnn::coordinator::transport::channel::ChannelHub;
use tsnn::coordinator::transport::fault::{FaultCounters, FaultPlan};
use tsnn::coordinator::transport::socket::{Addr, SocketClient, SocketHub};
use tsnn::coordinator::transport::{Client, RetryPolicy, Transport};
use tsnn::coordinator::{
    clip_gradients, run_parallel, run_parallel_listener, run_parallel_opts, run_worker,
    shard_bounds, worker_kernel_budgets, CoordinatorOptions, CoordinatorService, ParallelConfig,
    ParallelOptions, ParameterServer, WorkerJob,
};
use tsnn::data::Dataset;
use tsnn::model::{Batcher, SparseMlp, Workspace};
use tsnn::nn::LrSchedule;
use tsnn::prelude::Rng;

/// Cleanly separable two-blob data (same construction as the coordinator
/// unit tests): these tests pin machinery, not learning capacity.
fn blob_data() -> Dataset {
    let (n_train, n_test, nf) = (400usize, 160usize, 20usize);
    let mut rng = Rng::new(1);
    let gen = |n: usize, rng: &mut Rng| {
        let mut x = vec![0.0f32; n * nf];
        let mut y = vec![0u32; n];
        for s in 0..n {
            let c = (s % 2) as u32;
            y[s] = c;
            let shift = if c == 0 { -1.5 } else { 1.5 };
            for f in 0..nf {
                x[s * nf + f] = rng.normal() + if f < 6 { shift } else { 0.0 };
            }
        }
        (x, y)
    };
    let (x_train, y_train) = gen(n_train, &mut rng);
    let (x_test, y_test) = gen(n_test, &mut rng);
    Dataset {
        name: "blobs".into(),
        n_features: nf,
        n_classes: 2,
        x_train,
        y_train,
        x_test,
        y_test,
    }
}

fn quick_cfg() -> TrainConfig {
    TrainConfig {
        hidden: vec![32, 16],
        epsilon: 8.0,
        batch: 40,
        dropout: 0.0,
        epochs: 0, // unused by the parallel driver
        lr: LrSchedule::Constant(0.05),
        kernel_threads: 2,
        ..TrainConfig::default()
    }
}

/// A retry policy tight enough that injected faults resolve in tens of
/// milliseconds instead of the production 2-second timeout.
fn tight_retry() -> RetryPolicy {
    RetryPolicy {
        timeout: Duration::from_millis(50),
        retries: 12,
        backoff: 1.5,
    }
}

fn assert_models_bit_equal(a: &SparseMlp, b: &SparseMlp, what: &str) {
    assert_eq!(a.sizes, b.sizes, "{what}: sizes differ");
    for (l, (la, lb)) in a.layers.iter().zip(b.layers.iter()).enumerate() {
        assert_eq!(la.weights, lb.weights, "{what}: layer {l} weights differ");
        assert_eq!(la.bias, lb.bias, "{what}: layer {l} bias differs");
        assert_eq!(la.velocity, lb.velocity, "{what}: layer {l} velocity differs");
        assert_eq!(
            la.bias_velocity, lb.bias_velocity,
            "{what}: layer {l} bias velocity differs"
        );
    }
}

/// The pre-transport thread coordinator's WASSP phase 1, reimplemented
/// against the public `ParameterServer` API: every step, all K workers
/// compute a gradient on the same snapshot, the gradients are summed in
/// worker order starting from worker 0's buffers, scaled by 1/K, clipped
/// once, and applied with the server-epoch warmup learning rate. The
/// transport run must reproduce this trajectory bit for bit.
fn reference_wassp_phase1(
    cfg: &TrainConfig,
    pcfg: &ParallelConfig,
    data: &Dataset,
    seed: u64,
) -> SparseMlp {
    let mut rng = Rng::new(seed);
    let sizes = cfg.sizes(data.n_features, data.n_classes);
    let model = SparseMlp::new(&sizes, cfg.epsilon, cfg.activation, &cfg.init, &mut rng).unwrap();
    let pushes_per_epoch = data.n_train().div_ceil(cfg.batch).max(1);
    let ps = ParameterServer::new(
        model,
        cfg.optimizer,
        cfg.evolution,
        cfg.importance,
        pushes_per_epoch,
        cfg.seed,
    );
    let base = match cfg.lr {
        LrSchedule::Constant(eta) => eta,
        other => other.at(0),
    };
    let schedule = LrSchedule::Warmup {
        base,
        scale: (pcfg.workers as f32).max(1.0).min(4.0),
        warmup_epochs: 5,
    };
    let budgets = worker_kernel_budgets(cfg, pcfg.workers);
    let mut states: Vec<(Rng, Batcher, Workspace)> = (0..pcfg.workers)
        .map(|w| {
            let mut wrng = Rng::new(cfg.seed).split(w as u64);
            let (lo, hi) = shard_bounds(data.n_train(), pcfg.workers, w);
            let mut b = Batcher::shard(data.n_train(), data.n_features, cfg.batch, lo, hi);
            b.reset(&mut wrng);
            (wrng, b, Workspace::with_threads(budgets[w]))
        })
        .collect();

    for _ in 0..pcfg.phase1_epochs * pushes_per_epoch {
        let snap = ps.fetch();
        let mut grads: Vec<(Vec<Vec<f32>>, Vec<Vec<f32>>)> = Vec::with_capacity(pcfg.workers);
        for (wrng, batcher, ws) in states.iter_mut() {
            let batch = match batcher.next_batch(&data.x_train, &data.y_train) {
                Some(b) => b,
                None => {
                    batcher.reset(wrng);
                    batcher.next_batch(&data.x_train, &data.y_train).unwrap()
                }
            };
            snap.model.compute_gradients(batch.0, batch.1, None, ws, wrng);
            grads.push((ws.grad_w.clone(), ws.grad_b.clone()));
        }
        let mut it = grads.into_iter();
        let (mut agg_w, mut agg_b) = it.next().unwrap();
        for (gw, gb) in it {
            for (a, g) in agg_w.iter_mut().zip(gw.iter()) {
                for (x, y) in a.iter_mut().zip(g.iter()) {
                    *x += y;
                }
            }
            for (a, g) in agg_b.iter_mut().zip(gb.iter()) {
                for (x, y) in a.iter_mut().zip(g.iter()) {
                    *x += y;
                }
            }
        }
        let inv_k = 1.0f32 / pcfg.workers as f32;
        for a in agg_w.iter_mut().flat_map(|v| v.iter_mut()) {
            *a *= inv_k;
        }
        for a in agg_b.iter_mut().flat_map(|v| v.iter_mut()) {
            *a *= inv_k;
        }
        clip_gradients(&mut agg_w, &mut agg_b, pcfg.grad_clip);
        let lr = schedule.at(ps.epoch());
        ps.apply_aligned(&agg_w, &agg_b, lr).unwrap();
    }
    ps.finish().0
}

/// Tentpole acceptance: WASSP over the channel transport reproduces the
/// thread coordinator bit for bit — with SET evolution on, so the run
/// crosses topology generations and exercises both the values-only delta
/// (same gen) and full-model (gen bump) snapshot paths.
#[test]
fn wassp_channel_is_bit_exact_with_thread_reference() {
    let cfg = quick_cfg(); // evolution stays on (TrainConfig::default)
    let data = blob_data();
    let pcfg = ParallelConfig {
        workers: 2,
        phase1_epochs: 3,
        phase2_epochs: 0,
        synchronous: true,
        hot_start: true,
        grad_clip: 5.0,
    };
    let reference = reference_wassp_phase1(&cfg, &pcfg, &data, 21);
    let report = run_parallel(&cfg, &pcfg, &data, &mut Rng::new(21)).unwrap();
    assert_models_bit_equal(&reference, &report.model, "wassp channel vs thread reference");
    assert_eq!(report.server_stats.epochs, 3);
    assert_eq!(report.coord_stats.joins, 2);
    assert_eq!(report.coord_stats.leaves, 2);
    // gen bumps happened, so both snapshot flavours were served
    assert!(report.coord_stats.full_snapshots > 0);
    assert!(report.coord_stats.delta_snapshots > 0);
}

/// Fault-injection parity: with one worker, a run under deterministic
/// drops / duplicates / reorders / truncations / lost replies applies the
/// exact same update sequence as a clean run — the seq/reply cache makes
/// every retransmit idempotent.
#[test]
fn wasap_fault_injection_is_bit_exact_for_one_worker() {
    let cfg = quick_cfg();
    let data = blob_data();
    let pcfg = ParallelConfig {
        workers: 1,
        phase1_epochs: 3,
        phase2_epochs: 0,
        synchronous: false,
        hot_start: true,
        grad_clip: 5.0,
    };
    let clean = run_parallel(&cfg, &pcfg, &data, &mut Rng::new(9)).unwrap();

    let counters = Arc::new(FaultCounters::default());
    let opts = ParallelOptions {
        coord: CoordinatorOptions {
            retry: tight_retry(),
            ..CoordinatorOptions::default()
        },
        fault: FaultPlan {
            drop_every: 7,
            dup_every: 5,
            delay_every: 4,
            truncate_every: 9,
            drop_reply_every: 6,
        },
        fault_counters: Some(Arc::clone(&counters)),
    };
    let faulty = run_parallel_opts(&cfg, &pcfg, &data, &mut Rng::new(9), &opts).unwrap();

    assert!(counters.total() > 0, "no faults fired — plan misconfigured");
    assert!(
        faulty.coord_stats.dup_requests > 0,
        "faults fired but no retransmit was deduplicated"
    );
    assert_eq!(clean.server_stats.steps, faulty.server_stats.steps);
    assert_models_bit_equal(&clean.model, &faulty.model, "faulty vs clean wasap");
}

/// Multi-worker WASAP under sustained fault injection still completes and
/// learns: the protocol survives lost frames in both directions at K > 1.
#[test]
fn wasap_multiworker_survives_faults_and_learns() {
    let cfg = TrainConfig {
        evolution: None, // keep the short run's convergence reliable
        ..quick_cfg()
    };
    let data = blob_data();
    let pcfg = ParallelConfig {
        workers: 3,
        phase1_epochs: 15,
        phase2_epochs: 2,
        synchronous: false,
        hot_start: true,
        grad_clip: 5.0,
    };
    let counters = Arc::new(FaultCounters::default());
    let opts = ParallelOptions {
        coord: CoordinatorOptions {
            retry: tight_retry(),
            ..CoordinatorOptions::default()
        },
        fault: FaultPlan {
            drop_every: 13,
            dup_every: 11,
            delay_every: 8,
            truncate_every: 17,
            drop_reply_every: 15,
        },
        fault_counters: Some(Arc::clone(&counters)),
    };
    let report = run_parallel_opts(&cfg, &pcfg, &data, &mut Rng::new(5), &opts).unwrap();
    assert!(counters.total() > 0);
    assert!(report.server_stats.steps > 0);
    assert!(
        report.final_test_accuracy > 0.55,
        "accuracy {} under faults",
        report.final_test_accuracy
    );
}

/// Elasticity: workers that leave after a budget of pushes end the run
/// early (no configured-epoch wait), and every applied push is counted.
#[test]
fn elastic_workers_leave_early_and_the_run_still_finishes() {
    let cfg = TrainConfig {
        evolution: None, // gen never bumps, so every push is applied
        ..quick_cfg()
    };
    let data = blob_data();
    let pcfg = ParallelConfig {
        workers: 2,
        phase1_epochs: 50, // far more than the workers will serve
        phase2_epochs: 0,
        synchronous: false,
        hot_start: false,
        grad_clip: 5.0,
    };
    let sizes = cfg.sizes(data.n_features, data.n_classes);
    let model =
        SparseMlp::new(&sizes, cfg.epsilon, cfg.activation, &cfg.init, &mut Rng::new(3)).unwrap();
    let service = CoordinatorService::new(
        &cfg,
        &pcfg,
        model,
        data.n_train(),
        None,
        &CoordinatorOptions::default(),
    );
    let (hub, connector) = ChannelHub::new();
    let data_ref = &data;
    let outcome = std::thread::scope(|scope| {
        let coord = scope.spawn(move || {
            let mut hub = hub;
            service.run(&mut hub)
        });
        let mut handles = Vec::new();
        for k in 0..2u32 {
            let mut job = WorkerJob::new(k, 1, &cfg, &pcfg);
            job.max_phase1_pushes = Some(6);
            job.skip_phase2 = true;
            let t: Box<dyn Transport> = Box::new(connector.connect());
            let retry = RetryPolicy::default();
            handles.push(scope.spawn(move || run_worker(t, retry, &job, data_ref)));
        }
        drop(connector);
        for h in handles {
            let report = h.join().unwrap().unwrap();
            assert_eq!(report.pushes, 6);
        }
        coord.join().unwrap().unwrap()
    });
    assert_eq!(outcome.server_stats.steps, 12); // 2 workers × 6 pushes
    assert_eq!(outcome.coord.joins, 2);
    assert_eq!(outcome.coord.leaves, 2);
    // the elastic run finished phase 1 with what was applied
    assert!(outcome.server_stats.epochs < pcfg.phase1_epochs);
}

/// Admission control: out-of-range worker ids and duplicate ids of an
/// active worker are refused at join; the run still completes cleanly
/// once the legitimately-joined worker leaves.
#[test]
fn join_rejects_bad_and_duplicate_worker_ids() {
    let cfg = quick_cfg();
    let data = blob_data();
    let pcfg = ParallelConfig {
        workers: 1,
        phase1_epochs: 1,
        phase2_epochs: 0,
        synchronous: false,
        hot_start: false,
        grad_clip: 5.0,
    };
    let sizes = cfg.sizes(data.n_features, data.n_classes);
    let model =
        SparseMlp::new(&sizes, cfg.epsilon, cfg.activation, &cfg.init, &mut Rng::new(4)).unwrap();
    let service = CoordinatorService::new(
        &cfg,
        &pcfg,
        model,
        data.n_train(),
        None,
        &CoordinatorOptions::default(),
    );
    let (hub, connector) = ChannelHub::new();
    let outcome = std::thread::scope(|scope| {
        let coord = scope.spawn(move || {
            let mut hub = hub;
            service.run(&mut hub)
        });
        let mut a = Client::new(Box::new(connector.connect()), 0, RetryPolicy::default());
        assert!(a.join().is_ok());
        // same id while worker 0 is active: refused
        let mut dup = Client::new(Box::new(connector.connect()), 0, RetryPolicy::default());
        assert!(dup.join().is_err());
        // id beyond the shard count: refused
        let mut oor = Client::new(Box::new(connector.connect()), 5, RetryPolicy::default());
        assert!(oor.join().is_err());
        a.leave().unwrap();
        drop(connector);
        coord.join().unwrap().unwrap()
    });
    assert_eq!(outcome.coord.joins, 1);
    assert_eq!(outcome.coord.leaves, 1);
    assert_eq!(outcome.server_stats.steps, 0);
}

/// The socket transport and the channel transport run the same protocol:
/// a synchronous 2-worker run over a real TCP loopback socket (workers in
/// threads driving `SocketClient`s, coordinator on a `SocketHub`) lands
/// on the same final model, bit for bit, as the in-process channel run —
/// including phase-2 replica upload and union-averaging.
#[test]
fn wassp_over_tcp_socket_matches_channel() {
    let cfg = quick_cfg();
    let data = blob_data();
    let pcfg = ParallelConfig {
        workers: 2,
        phase1_epochs: 2,
        phase2_epochs: 1,
        synchronous: true,
        hot_start: true,
        grad_clip: 5.0,
    };
    let channel_report = run_parallel(&cfg, &pcfg, &data, &mut Rng::new(77)).unwrap();

    let mut hub = SocketHub::bind(&Addr::Tcp("127.0.0.1:0".into())).unwrap();
    let connect = Addr::Tcp(hub.local_tcp.clone().expect("tcp bind reports its port"));
    let budgets = worker_kernel_budgets(&cfg, pcfg.workers);
    let data_ref = &data;
    let socket_report = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for k in 0..pcfg.workers {
            let job = WorkerJob::new(k as u32, budgets[k], &cfg, &pcfg);
            let connect = connect.clone();
            handles.push(scope.spawn(move || {
                let client = SocketClient::connect(&connect).unwrap();
                run_worker(Box::new(client), RetryPolicy::default(), &job, data_ref)
            }));
        }
        let report = run_parallel_listener(
            &cfg,
            &pcfg,
            &data,
            &mut Rng::new(77),
            &mut hub,
            None,
            &CoordinatorOptions::default(),
        );
        for h in handles {
            h.join().unwrap().unwrap();
        }
        report
    })
    .unwrap();

    assert_models_bit_equal(&channel_report.model, &socket_report.model, "socket vs channel");
    assert_eq!(
        channel_report.server_stats.steps,
        socket_report.server_stats.steps
    );
}

/// Multi-node posture: the coordinator binds the wildcard interface
/// (`tcp:0.0.0.0:PORT`, how a real cross-host run is launched — see the
/// CLI docs for `tsnn worker --connect tcp:HOST:PORT`) and workers dial
/// in over an explicit host:port exactly as a remote machine would. The
/// run must land bit-equal to the in-process channel reference: the
/// bound interface changes reachability, never the protocol or the
/// applied-update trajectory.
#[test]
fn wassp_bound_to_wildcard_interface_matches_channel() {
    let cfg = quick_cfg();
    let data = blob_data();
    let pcfg = ParallelConfig {
        workers: 2,
        phase1_epochs: 2,
        phase2_epochs: 1,
        synchronous: true,
        hot_start: true,
        grad_clip: 5.0,
    };
    let channel_report = run_parallel(&cfg, &pcfg, &data, &mut Rng::new(53)).unwrap();

    let mut hub = SocketHub::bind(&Addr::Tcp("0.0.0.0:0".into())).unwrap();
    let bound = hub.local_tcp.clone().expect("tcp bind reports its port");
    let port = bound.rsplit(':').next().unwrap().to_string();
    // a remote worker would dial the coordinator's routable address;
    // loopback is this test's stand-in for it
    let connect = Addr::Tcp(format!("127.0.0.1:{port}"));
    let budgets = worker_kernel_budgets(&cfg, pcfg.workers);
    let data_ref = &data;
    let socket_report = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for k in 0..pcfg.workers {
            let job = WorkerJob::new(k as u32, budgets[k], &cfg, &pcfg);
            let connect = connect.clone();
            handles.push(scope.spawn(move || {
                let client = SocketClient::connect(&connect).unwrap();
                run_worker(Box::new(client), RetryPolicy::default(), &job, data_ref)
            }));
        }
        let report = run_parallel_listener(
            &cfg,
            &pcfg,
            &data,
            &mut Rng::new(53),
            &mut hub,
            None,
            &CoordinatorOptions::default(),
        );
        for h in handles {
            h.join().unwrap().unwrap();
        }
        report
    })
    .unwrap();

    assert_models_bit_equal(
        &channel_report.model,
        &socket_report.model,
        "wildcard-bound socket vs channel",
    );
    assert_eq!(
        channel_report.server_stats.steps,
        socket_report.server_stats.steps
    );
}

/// Startup race: workers that launch *before* the coordinator is
/// listening connect via `connect_retry` and the run is still bit-exact
/// with the channel reference — worker-first startup order changes
/// connection timing, never the applied-update trajectory.
#[test]
fn workers_started_before_coordinator_listens_still_match() {
    let cfg = quick_cfg();
    let data = blob_data();
    let pcfg = ParallelConfig {
        workers: 2,
        phase1_epochs: 2,
        phase2_epochs: 0,
        synchronous: true,
        hot_start: true,
        grad_clip: 5.0,
    };
    let channel_report = run_parallel(&cfg, &pcfg, &data, &mut Rng::new(31)).unwrap();

    // reserve a port, then free it: the workers start dialing an address
    // nothing listens on yet
    let port = {
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        probe.local_addr().unwrap().port()
    };
    let hostport = format!("127.0.0.1:{port}");
    let connect = Addr::Tcp(hostport.clone());
    let budgets = worker_kernel_budgets(&cfg, pcfg.workers);
    let data_ref = &data;
    let socket_report = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for k in 0..pcfg.workers {
            let job = WorkerJob::new(k as u32, budgets[k], &cfg, &pcfg);
            let connect = connect.clone();
            handles.push(scope.spawn(move || {
                let client =
                    SocketClient::connect_retry(&connect, Duration::from_secs(20)).unwrap();
                run_worker(Box::new(client), RetryPolicy::default(), &job, data_ref)
            }));
        }
        // workers are already retrying against a dead address; bind late
        std::thread::sleep(Duration::from_millis(250));
        let mut hub = SocketHub::bind(&Addr::Tcp(hostport)).unwrap();
        let report = run_parallel_listener(
            &cfg,
            &pcfg,
            &data,
            &mut Rng::new(31),
            &mut hub,
            None,
            &CoordinatorOptions::default(),
        );
        for h in handles {
            h.join().unwrap().unwrap();
        }
        report
    })
    .unwrap();

    assert_models_bit_equal(
        &channel_report.model,
        &socket_report.model,
        "worker-first socket vs channel",
    );
}

/// Satellite 1 regression: a non-finite gradient norm zeroes the buffers
/// (even with clipping off) instead of silently skipping the scale and
/// letting NaNs through; finite gradients behave as before.
#[test]
fn clip_gradients_zeroes_nonfinite_and_scales_finite() {
    // over the clip: scaled down to the clip norm
    let mut gw = vec![vec![3.0f32, 4.0]];
    let mut gb = vec![vec![0.0f32]];
    assert!(!clip_gradients(&mut gw, &mut gb, 2.5));
    let norm = gw
        .iter()
        .chain(gb.iter())
        .flat_map(|v| v.iter())
        .map(|x| x * x)
        .sum::<f32>()
        .sqrt();
    assert!((norm - 2.5).abs() < 1e-5, "clipped norm {norm}");

    // under the clip: untouched
    let mut gw = vec![vec![0.5f32]];
    let mut gb = vec![vec![0.5f32]];
    assert!(!clip_gradients(&mut gw, &mut gb, 5.0));
    assert_eq!(gw[0][0], 0.5);
    assert_eq!(gb[0][0], 0.5);

    // NaN with clipping OFF: the old code forwarded it; now it zeroes
    let mut gw = vec![vec![1.0f32, f32::NAN]];
    let mut gb = vec![vec![2.0f32]];
    assert!(clip_gradients(&mut gw, &mut gb, 0.0));
    assert!(gw[0].iter().all(|&x| x == 0.0));
    assert!(gb[0].iter().all(|&x| x == 0.0));

    // Inf with clipping on: same zeroing path
    let mut gw = vec![vec![1.0f32, f32::INFINITY]];
    let mut gb = vec![vec![0.0f32]];
    assert!(clip_gradients(&mut gw, &mut gb, 5.0));
    assert!(gw[0].iter().all(|&x| x == 0.0));
}
