//! Adversarial decode tests for the transport wire format: every
//! truncation, corruption, and implausible-length input must come back
//! as a typed `TsnnError` — never a panic, never an unbounded
//! allocation. (A panicking decode would let one corrupt frame kill the
//! coordinator; an unguarded length would let a 25-byte frame OOM it.)

use tsnn::coordinator::transport::wire::{
    decode_frame, decode_header, encode_frame, FetchAck, Message, ModelDelta, PushMsg,
    HEADER_BYTES, MAX_PAYLOAD_BYTES, NONE_U64,
};
use tsnn::model::SparseMlp;
use tsnn::nn::Activation;
use tsnn::prelude::Rng;
use tsnn::sparse::WeightInit;

fn tiny_model(seed: u64) -> SparseMlp {
    SparseMlp::new(
        &[12, 16, 4],
        6.0,
        Activation::AllRelu { alpha: 0.6 },
        &WeightInit::HeUniform,
        &mut Rng::new(seed),
    )
    .unwrap()
}

fn assert_models_equal(a: &SparseMlp, b: &SparseMlp) {
    assert_eq!(a.sizes, b.sizes);
    for (la, lb) in a.layers.iter().zip(b.layers.iter()) {
        assert_eq!(la.weights, lb.weights, "weights differ");
        assert_eq!(la.bias, lb.bias, "bias differs");
        assert_eq!(la.velocity, lb.velocity, "velocity differs");
        assert_eq!(la.bias_velocity, lb.bias_velocity, "bias velocity differs");
    }
}

/// Representative frames of every payload-bearing message kind.
fn sample_frames() -> Vec<Vec<u8>> {
    let model = tiny_model(11);
    let grad_w: Vec<Vec<f32>> = model
        .layers
        .iter()
        .map(|l| (0..l.weights.nnz()).map(|i| i as f32 * 0.25 - 1.0).collect())
        .collect();
    let grad_b: Vec<Vec<f32>> = model
        .layers
        .iter()
        .map(|l| (0..l.bias.len()).map(|i| -(i as f32) * 0.5).collect())
        .collect();
    vec![
        encode_frame(0, 1, &Message::Join),
        encode_frame(
            0,
            2,
            &Message::JoinAck {
                job: Some("{\"k\":1}".into()),
                resume_pushes: 0,
                resume_step: NONE_U64,
            },
        ),
        encode_frame(
            4,
            11,
            &Message::JoinAck {
                job: None,
                resume_pushes: 17,
                resume_step: 9,
            },
        ),
        encode_frame(4, 12, &Message::Ping),
        encode_frame(4, 13, &Message::Pong),
        encode_frame(1, 3, &Message::Fetch { have_gen: 7, have_step: NONE_U64 }),
        encode_frame(
            1,
            4,
            &Message::FetchAck(FetchAck {
                phase2: false,
                gen: 7,
                step: 42,
                epoch: 3,
                delta: ModelDelta::Values {
                    values: grad_w.clone(),
                    bias: grad_b.clone(),
                },
            }),
        ),
        encode_frame(
            2,
            5,
            &Message::FetchAck(FetchAck {
                phase2: true,
                gen: 0,
                step: 0,
                epoch: 20,
                delta: ModelDelta::Full {
                    model: model.clone(),
                    velocity: true,
                },
            }),
        ),
        encode_frame(
            2,
            6,
            &Message::Push(PushMsg {
                gen: 7,
                fetched_step: 42,
                lr: 0.05,
                sync: false,
                grad_w,
                grad_b,
            }),
        ),
        encode_frame(3, 7, &Message::Replica { model }),
        encode_frame(3, 8, &Message::Err { message: "worker 3 out of range".into() }),
    ]
}

#[test]
fn every_sample_frame_roundtrips() {
    for frame in sample_frames() {
        let (h, msg) = decode_frame(&frame).unwrap();
        let re = encode_frame(h.worker, h.seq, &msg);
        assert_eq!(re, frame, "re-encode of {msg:?} is not canonical");
    }
}

#[test]
fn full_model_with_velocity_roundtrips_bit_exact() {
    let model = tiny_model(23);
    let frame = encode_frame(
        0,
        9,
        &Message::FetchAck(FetchAck {
            phase2: true,
            gen: 3,
            step: 100,
            epoch: 9,
            delta: ModelDelta::Full { model: model.clone(), velocity: true },
        }),
    );
    let (_, msg) = decode_frame(&frame).unwrap();
    match msg {
        Message::FetchAck(FetchAck { delta: ModelDelta::Full { model: got, .. }, .. }) => {
            assert_models_equal(&model, &got)
        }
        other => panic!("wrong decode: {other:?}"),
    }
}

/// Truncate every sample frame at EVERY byte boundary. The raw prefix
/// must fail (payload length no longer matches the header), and the
/// header-patched prefix (length field rewritten to match, so the decoder
/// walks into the cut payload) must fail too — at every single offset.
#[test]
fn truncation_at_every_byte_boundary_is_a_typed_error() {
    for frame in sample_frames() {
        for cut in 0..frame.len() {
            let prefix = &frame[..cut];
            assert!(
                decode_frame(prefix).is_err(),
                "raw truncation at {cut}/{} decoded",
                frame.len()
            );
            if cut >= HEADER_BYTES {
                let mut patched = prefix.to_vec();
                let plen = (cut - HEADER_BYTES) as u32;
                patched[21..25].copy_from_slice(&plen.to_le_bytes());
                // either a decode error or a valid shorter message whose
                // canonical encoding is itself — never a panic; for these
                // payloads every strict prefix is malformed
                assert!(
                    decode_frame(&patched).is_err(),
                    "patched truncation at {cut}/{} decoded",
                    frame.len()
                );
            }
        }
    }
}

#[test]
fn garbage_magic_and_version_are_rejected() {
    let frame = encode_frame(0, 1, &Message::Join);
    for b in 0..4 {
        let mut bad = frame.clone();
        bad[b] ^= 0xff;
        assert!(decode_header(&bad).is_err(), "magic byte {b} accepted");
        assert!(decode_frame(&bad).is_err());
    }
    let mut bad_version = frame.clone();
    bad_version[4..8].copy_from_slice(&999u32.to_le_bytes());
    assert!(decode_header(&bad_version).is_err());
    let mut bad_kind = frame;
    bad_kind[8] = 0xee;
    assert!(decode_header(&bad_kind).is_err());
}

#[test]
fn implausible_lengths_fail_fast_without_allocating() {
    // header claims a payload beyond the global cap: rejected from the
    // header alone, before any payload buffer exists
    let mut huge = encode_frame(0, 1, &Message::Join);
    huge[21..25].copy_from_slice(&((MAX_PAYLOAD_BYTES as u32) + 1).to_le_bytes());
    assert!(decode_header(&huge).is_err());

    // a Push whose per-layer nnz claims u64::MAX: the element count is
    // validated against the bytes actually present before the Vec is
    // sized, so this returns an error instantly instead of OOMing
    let model = tiny_model(5);
    let grads: Vec<Vec<f32>> = model.layers.iter().map(|l| vec![0.5; l.weights.nnz()]).collect();
    let biases: Vec<Vec<f32>> = model.layers.iter().map(|l| vec![0.1; l.bias.len()]).collect();
    let mut frame = encode_frame(
        0,
        2,
        &Message::Push(PushMsg {
            gen: 0,
            fetched_step: 0,
            lr: 0.01,
            sync: false,
            grad_w: grads,
            grad_b: biases,
        }),
    );
    // payload layout: gen u64 | fetched_step u64 | lr f32 | sync u8 | n_layers u32 | nnz u64 ...
    let nnz_at = HEADER_BYTES + 8 + 8 + 4 + 1 + 4;
    frame[nnz_at..nnz_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
    assert!(decode_frame(&frame).is_err());
}

/// Random byte corruption must never panic (it may decode, since some
/// bytes are free-form f32 payload — the invariant is totality, not
/// rejection).
#[test]
fn single_byte_corruption_never_panics() {
    for frame in sample_frames() {
        for i in 0..frame.len() {
            let mut bad = frame.clone();
            bad[i] ^= 0x55;
            let _ = decode_frame(&bad); // must return, Ok or Err
        }
    }
}

#[test]
fn trailing_bytes_are_rejected() {
    let mut frame = encode_frame(0, 1, &Message::Fetch { have_gen: 0, have_step: 3 });
    frame.push(0);
    // payload longer than the header claims
    assert!(decode_frame(&frame).is_err());
    // header patched to cover the junk byte: now the payload itself is
    // too long for the message
    let plen = (frame.len() - HEADER_BYTES) as u32;
    frame[21..25].copy_from_slice(&plen.to_le_bytes());
    assert!(decode_frame(&frame).is_err());
}
