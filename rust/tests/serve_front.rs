//! Serving front-end behavior suite (DESIGN.md §10.2): bounded-queue
//! backpressure fails fast without blocking on the pool, adaptive batch
//! formation handles the edge windows (empty, single request, expired
//! deadline, over-capacity burst), and shutdown drains every in-flight
//! request before the batcher exits.

use std::time::{Duration, Instant};

use tsnn::model::SparseMlp;
use tsnn::nn::Activation;
use tsnn::serve::{
    LayoutOptions, ServeConfig, ServeEngine, ServeModel, ServeWorkspace, SubmitError,
};
use tsnn::sparse::WeightInit;
use tsnn::util::Rng;

const N_FEAT: usize = 12;

fn small_model(seed: u64) -> ServeModel {
    let mlp = SparseMlp::new(
        &[N_FEAT, 24, 4],
        4.0,
        Activation::Relu,
        &WeightInit::HeUniform,
        &mut Rng::new(seed),
    )
    .unwrap();
    ServeModel::from_mlp(&mlp, &LayoutOptions::default())
}

fn features(rng: &mut Rng) -> Vec<f32> {
    (0..N_FEAT).map(|_| rng.normal()).collect()
}

#[test]
fn full_queue_fails_fast_without_blocking() {
    // a long max_wait parks the batcher on its adaptive deadline after
    // the first request, so the queue genuinely fills up behind it
    let cfg = ServeConfig {
        max_batch: 64,
        max_queue: 2,
        max_wait: Duration::from_secs(5),
        kernel_threads: 1,
        ..ServeConfig::default()
    };
    let mut engine = ServeEngine::new(small_model(1), cfg);
    let mut rng = Rng::new(2);
    let t1 = engine.submit(features(&mut rng)).unwrap();
    let t2 = engine.submit(features(&mut rng)).unwrap();
    // the batcher may have already drained the first submission into
    // its forming batch; top the queue back up before asserting
    let mut extra = Vec::new();
    let rejected_at = loop {
        let started = Instant::now();
        match engine.submit(features(&mut rng)) {
            Ok(t) => extra.push(t),
            Err(SubmitError::QueueFull) => break started.elapsed(),
            Err(e) => panic!("unexpected submit error: {e}"),
        }
        assert!(extra.len() <= 64 + 2, "queue bound never enforced");
    };
    // fail-fast: rejection must return immediately, nowhere near the
    // 5 s batching deadline (generous bound for loaded CI runners)
    assert!(rejected_at < Duration::from_millis(500), "rejection took {rejected_at:?}");
    assert!(engine.stats().rejected >= 1);
    // draining shutdown completes everything that was accepted
    engine.shutdown();
    assert!(t1.wait().is_ok());
    assert!(t2.wait().is_ok());
    for t in extra {
        assert!(t.wait().is_ok());
    }
}

#[test]
fn empty_window_idles_cleanly() {
    // no traffic at all: the batcher must park (not spin or panic) and
    // shut down from the empty-queue wait
    let cfg = ServeConfig {
        max_batch: 8,
        max_queue: 8,
        max_wait: Duration::from_millis(1),
        kernel_threads: 1,
        ..ServeConfig::default()
    };
    let mut engine = ServeEngine::new(small_model(3), cfg);
    std::thread::sleep(Duration::from_millis(20));
    let stats = engine.stats();
    assert_eq!(stats.completed, 0);
    assert_eq!(stats.batches, 0);
    assert_eq!(engine.latency().count, 0);
    engine.shutdown();
}

#[test]
fn single_request_completes_after_deadline_alone() {
    // max_batch 8 but only one request: the deadline must expire and
    // run a batch of one — the request cannot wait for peers forever
    let cfg = ServeConfig {
        max_batch: 8,
        max_queue: 8,
        max_wait: Duration::from_millis(5),
        kernel_threads: 1,
        ..ServeConfig::default()
    };
    let engine = ServeEngine::new(small_model(4), cfg);
    let mut rng = Rng::new(5);
    let y = engine.infer(features(&mut rng)).unwrap();
    assert_eq!(y.len(), 4);
    let stats = engine.stats();
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.batches, 1);
    assert_eq!(engine.latency().count, 1);
}

#[test]
fn deadline_expired_partial_batch_runs_as_one_batch() {
    // three requests land well inside one 200 ms window: the batcher
    // must run them as a single partial batch when the deadline expires
    let cfg = ServeConfig {
        max_batch: 8,
        max_queue: 16,
        max_wait: Duration::from_millis(200),
        kernel_threads: 1,
        ..ServeConfig::default()
    };
    let engine = ServeEngine::new(small_model(6), cfg);
    let mut rng = Rng::new(7);
    let tickets: Vec<_> = (0..3)
        .map(|_| engine.submit(features(&mut rng)).unwrap())
        .collect();
    let start = Instant::now();
    for t in tickets {
        assert_eq!(t.wait().unwrap().len(), 4);
    }
    // they completed via the deadline, not a full batch
    assert!(start.elapsed() >= Duration::from_millis(50));
    let stats = engine.stats();
    assert_eq!(stats.completed, 3);
    assert_eq!(stats.batches, 1, "partial batch must run as ONE forward");
}

#[test]
fn over_capacity_burst_splits_into_full_batches() {
    // 10 requests into max_batch 4: ceil(10/4) = 3 batches minimum,
    // every request completes, order of delivery per ticket is correct
    let cfg = ServeConfig {
        max_batch: 4,
        max_queue: 32,
        max_wait: Duration::from_millis(2),
        kernel_threads: 1,
        ..ServeConfig::default()
    };
    let model = small_model(8);
    let oracle_model = model.clone();
    let engine = ServeEngine::new(model, cfg);
    let mut rng = Rng::new(9);
    let xs: Vec<Vec<f32>> = (0..10).map(|_| features(&mut rng)).collect();
    let tickets: Vec<_> = xs.iter().map(|x| engine.submit(x.clone()).unwrap()).collect();
    let mut ws = ServeWorkspace::with_threads(1);
    for (x, t) in xs.iter().zip(tickets) {
        let y = t.wait().unwrap();
        assert_eq!(oracle_model.forward(x, 1, &mut ws), &y[..]);
    }
    let stats = engine.stats();
    assert_eq!(stats.completed, 10);
    assert!(stats.batches >= 3, "10 requests / max_batch 4 ⇒ ≥ 3 batches");
    assert_eq!(stats.rejected, 0);
}

#[test]
fn shutdown_drains_queued_requests() {
    // park the batcher on a long deadline with requests queued behind
    // it, then shut down: every accepted request must still complete
    let cfg = ServeConfig {
        max_batch: 64,
        max_queue: 16,
        max_wait: Duration::from_secs(5),
        kernel_threads: 1,
        ..ServeConfig::default()
    };
    let mut engine = ServeEngine::new(small_model(10), cfg);
    let mut rng = Rng::new(11);
    let tickets: Vec<_> = (0..5)
        .map(|_| engine.submit(features(&mut rng)).unwrap())
        .collect();
    let start = Instant::now();
    engine.shutdown();
    // drain must not wait out the 5 s deadline
    assert!(start.elapsed() < Duration::from_secs(4));
    for t in tickets {
        assert_eq!(t.wait().unwrap().len(), 4);
    }
    assert_eq!(engine.stats().completed, 5);
    // post-shutdown submissions are refused with the typed error
    assert_eq!(
        engine.submit(features(&mut rng)).unwrap_err(),
        SubmitError::Shutdown
    );
    // idempotent
    engine.shutdown();
}

#[test]
fn bad_shape_is_rejected_before_queueing() {
    let cfg = ServeConfig {
        kernel_threads: 1,
        ..ServeConfig::default()
    };
    let engine = ServeEngine::new(small_model(12), cfg);
    assert_eq!(
        engine.submit(vec![0.0; N_FEAT + 1]).unwrap_err(),
        SubmitError::BadShape {
            expected: N_FEAT,
            got: N_FEAT + 1
        }
    );
    assert_eq!(engine.stats().completed, 0);
}

#[test]
fn metrics_reset_between_measurement_steps() {
    let cfg = ServeConfig {
        max_batch: 4,
        max_queue: 16,
        max_wait: Duration::from_millis(1),
        kernel_threads: 1,
        ..ServeConfig::default()
    };
    let engine = ServeEngine::new(small_model(13), cfg);
    let mut rng = Rng::new(14);
    for _ in 0..4 {
        engine.infer(features(&mut rng)).unwrap();
    }
    assert_eq!(engine.stats().completed, 4);
    assert_eq!(engine.latency().count, 4);
    engine.reset_metrics();
    assert_eq!(engine.stats(), Default::default());
    assert_eq!(engine.latency().count, 0);
    engine.infer(features(&mut rng)).unwrap();
    assert_eq!(engine.stats().completed, 1);
}
