//! Mapped-vs-RAM training parity (DESIGN.md §14.8): a [`BigModel`]
//! trained out-of-core and a plain [`SparseMlp`] trained in RAM from
//! equal seeds must be **bit-identical** — same epoch logs, same final
//! weights, byte-for-byte equal checkpoints — across kernel-thread
//! budgets (the CI parity matrix additionally sweeps `TSNN_ISA` and
//! pins `KERNEL_THREADS` per process, which this suite honors through
//! `common::thread_counts`). No tolerances anywhere: the out-of-core
//! path is the same arithmetic over mapped memory, so `assert_eq!` is
//! the only acceptable comparison.

#![cfg(all(target_os = "linux", target_pointer_width = "64"))]

mod common;

use std::path::PathBuf;

use tsnn::bigmodel::{train_big, BigModel, BigTrainOptions};
use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::data::datasets;
use tsnn::model::checkpoint;
use tsnn::train::{train_sequential_opts, TrainOptions};
use tsnn::util::Rng;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tsnn_ooc_parity_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Small wide-sparse recommender split — the out-of-core subsystem's
/// native dataset, scaled down to suite size.
fn spec() -> DatasetSpec {
    DatasetSpec {
        name: "recommender-parity".into(),
        generator: "recommender".into(),
        n_features: 256,
        n_classes: 4,
        n_train: 300,
        n_test: 100,
    }
}

/// Exercise everything the epoch loop can do: SET evolution AND
/// importance pruning (fused and solo epochs), dropout off (its RNG is
/// identical anyway), evaluation on a cadence with skipped epochs.
fn config(threads: usize) -> TrainConfig {
    let mut cfg = TrainConfig::small_preset("recommender");
    for (k, v) in [
        ("epochs", "6"),
        ("batch", "32"),
        ("hidden", "48x24"),
        ("epsilon", "6"),
        ("zeta", "0.3"),
        ("importance", "on"),
        ("importance_start", "1"),
        ("importance_period", "2"),
        ("importance_min", "0"),
        ("eval_every", "2"),
        ("seed", "90210"),
    ] {
        cfg.set(k, v).unwrap();
    }
    cfg.set("kernel_threads", &threads.to_string()).unwrap();
    cfg
}

#[test]
fn mapped_training_matches_in_ram_training_bit_for_bit() {
    for &threads in &common::thread_counts() {
        let cfg = config(threads);
        let spec = spec();

        // in-RAM reference: generate → SparseMlp::new → train_model
        let mut rng = Rng::new(cfg.seed);
        let data = datasets::generate(&spec, &mut rng).unwrap();
        let report =
            train_sequential_opts(&cfg, &data, &mut rng, TrainOptions::default()).unwrap();

        // mapped run: same seed, same RNG consumption at every point
        let dir = tmp_dir(&format!("t{threads}"));
        let mut rng2 = Rng::new(cfg.seed);
        let data2 = datasets::generate(&spec, &mut rng2).unwrap();
        let sizes = cfg.sizes(data2.n_features, data2.n_classes);
        let mut big = BigModel::create(
            &dir,
            &sizes,
            cfg.epsilon,
            cfg.activation,
            &cfg.init,
            &mut rng2,
        )
        .unwrap();
        let big_report =
            train_big(&cfg, &data2, &mut big, &mut rng2, &BigTrainOptions::default()).unwrap();

        // epoch logs bit-equal (timings excluded; NaN test metrics on
        // skipped epochs compare equal through to_bits)
        assert_eq!(report.epochs.len(), big_report.epochs.len());
        for (a, b) in report.epochs.iter().zip(big_report.epochs.iter()) {
            assert_eq!(a.epoch, b.epoch);
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "train loss diverged at threads={threads} epoch={}",
                a.epoch
            );
            assert_eq!(a.train_accuracy.to_bits(), b.train_accuracy.to_bits());
            assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits());
            assert_eq!(a.test_accuracy.to_bits(), b.test_accuracy.to_bits());
            assert_eq!(
                a.weight_count, b.weight_count,
                "topology diverged at threads={threads} epoch={}",
                a.epoch
            );
        }
        assert_eq!(
            report.final_test_accuracy.to_bits(),
            big_report.final_test_accuracy.to_bits()
        );
        assert_eq!(
            report.best_test_accuracy.to_bits(),
            big_report.best_test_accuracy.to_bits()
        );
        assert_eq!(report.end_weights, big_report.end_weights);

        // final models byte-identical through the checkpoint format —
        // the strongest equality the formats can express
        let p_ram = dir.join("ram.tsnn");
        let p_map = dir.join("mapped.tsnn");
        checkpoint::save(&report.model, &p_ram).unwrap();
        big.save_checkpoint(&p_map).unwrap();
        assert_eq!(
            std::fs::read(&p_ram).unwrap(),
            std::fs::read(&p_map).unwrap(),
            "checkpoint bytes diverged at threads={threads}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// `weight_decay = 0` arms the activity-gated optimizer update
/// (DESIGN.md §14.6) on both sides — the skip decision is a pure
/// function of (gradients, liveness bitmap), identical in RAM and
/// mapped runs, and a skipped row is a provable no-op of the dense
/// update. Pin that end to end: gated mapped training must still be
/// byte-identical to gated in-RAM training.
#[test]
fn gated_update_parity_with_zero_weight_decay() {
    let mut cfg = config(1);
    cfg.set("weight_decay", "0").unwrap();
    let spec = spec();

    let mut rng = Rng::new(cfg.seed);
    let data = datasets::generate(&spec, &mut rng).unwrap();
    let report = train_sequential_opts(&cfg, &data, &mut rng, TrainOptions::default()).unwrap();

    let dir = tmp_dir("gated");
    let mut rng2 = Rng::new(cfg.seed);
    let data2 = datasets::generate(&spec, &mut rng2).unwrap();
    let sizes = cfg.sizes(data2.n_features, data2.n_classes);
    let mut big = BigModel::create(
        &dir,
        &sizes,
        cfg.epsilon,
        cfg.activation,
        &cfg.init,
        &mut rng2,
    )
    .unwrap();
    train_big(&cfg, &data2, &mut big, &mut rng2, &BigTrainOptions::default()).unwrap();

    let p_ram = dir.join("ram.tsnn");
    let p_map = dir.join("mapped.tsnn");
    checkpoint::save(&report.model, &p_ram).unwrap();
    big.save_checkpoint(&p_map).unwrap();
    assert_eq!(
        std::fs::read(&p_ram).unwrap(),
        std::fs::read(&p_map).unwrap(),
        "gated-update checkpoints diverged"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Training dirties mapped pages in place; `train_big` reseals at the
/// end, so a cold [`BigModel::open`] of the directory must verify CRCs
/// and produce the identical model.
#[test]
fn trained_directory_reopens_bit_identical() {
    let mut cfg = config(1);
    cfg.set("epochs", "4").unwrap();
    let spec = spec();
    let dir = tmp_dir("reopen");

    let mut rng = Rng::new(cfg.seed);
    let data = datasets::generate(&spec, &mut rng).unwrap();
    let sizes = cfg.sizes(data.n_features, data.n_classes);
    let mut big = BigModel::create(
        &dir,
        &sizes,
        cfg.epsilon,
        cfg.activation,
        &cfg.init,
        &mut rng,
    )
    .unwrap();
    train_big(&cfg, &data, &mut big, &mut rng, &BigTrainOptions::default()).unwrap();

    let p_live = dir.join("live.tsnn");
    big.save_checkpoint(&p_live).unwrap();
    drop(big);

    let reopened = BigModel::open(&dir).unwrap();
    let p_cold = dir.join("cold.tsnn");
    reopened.save_checkpoint(&p_cold).unwrap();
    assert_eq!(
        std::fs::read(&p_live).unwrap(),
        std::fs::read(&p_cold).unwrap()
    );
    std::fs::remove_dir_all(&dir).ok();
}
