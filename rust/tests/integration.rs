//! Cross-module integration tests: data → training → pruning →
//! checkpointing → parallel coordination → XLA runtime, exercised
//! together the way the examples and benches use them.

use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::coordinator::{run_parallel, ParallelConfig};
use tsnn::importance::ImportanceConfig;
use tsnn::nn::LrSchedule;
use tsnn::prelude::*;
use tsnn::train::train_sequential;

fn toy_data(seed: u64) -> tsnn::data::Dataset {
    let spec = DatasetSpec {
        name: "toy".into(),
        generator: "madelon".into(),
        n_features: 60,
        n_classes: 2,
        n_train: 600,
        n_test: 200,
    };
    datasets::generate(&spec, &mut Rng::new(seed)).unwrap()
}

fn toy_cfg(epochs: usize) -> TrainConfig {
    TrainConfig {
        hidden: vec![64, 32],
        epsilon: 8.0,
        epochs,
        batch: 64,
        dropout: 0.0,
        lr: LrSchedule::Constant(0.05),
        ..TrainConfig::default()
    }
}

#[test]
fn full_pipeline_train_checkpoint_reload_evaluate() {
    let data = toy_data(1);
    let cfg = toy_cfg(15);
    let report = train_sequential(&cfg, &data, &mut Rng::new(2)).unwrap();
    assert!(report.best_test_accuracy > 0.55);

    let path = std::env::temp_dir().join("tsnn_integration.tsnn");
    tsnn::model::checkpoint::save(&report.model, &path).unwrap();
    let reloaded = tsnn::model::checkpoint::load(&path).unwrap();
    let mut ws = reloaded.alloc_workspace(128);
    let (_, acc) = reloaded.evaluate(&data.x_test, &data.y_test, 128, &mut ws);
    assert!((acc - report.final_test_accuracy).abs() < 1e-6);
    std::fs::remove_file(&path).ok();
}

#[test]
fn sequential_and_parallel_reach_similar_accuracy() {
    let data = toy_data(3);
    let cfg = toy_cfg(16);
    let seq = train_sequential(&cfg, &data, &mut Rng::new(4)).unwrap();
    let par = run_parallel(
        &cfg,
        &ParallelConfig {
            workers: 3,
            phase1_epochs: 12,
            phase2_epochs: 4,
            synchronous: false,
            hot_start: true,
            grad_clip: 5.0,
        },
        &data,
        &mut Rng::new(4),
    )
    .unwrap();
    // parallel training must land in the same accuracy regime
    assert!(
        (seq.best_test_accuracy - par.final_test_accuracy).abs() < 0.25,
        "seq {} vs par {}",
        seq.best_test_accuracy,
        par.final_test_accuracy
    );
}

#[test]
fn importance_pruning_integrates_with_evolution_and_parallel() {
    let data = toy_data(5);
    let mut cfg = toy_cfg(14);
    cfg.importance = Some(ImportanceConfig {
        start_epoch: 6,
        period: 3,
        percentile: 10.0,
        min_connections: 16,
    });
    let par = run_parallel(
        &cfg,
        &ParallelConfig {
            workers: 2,
            phase1_epochs: 10,
            phase2_epochs: 4,
            synchronous: true,
            hot_start: true,
            grad_clip: 5.0,
        },
        &data,
        &mut Rng::new(6),
    )
    .unwrap();
    assert!(par.end_weights < par.start_weights);
    for layer in &par.model.layers {
        layer.weights.validate().unwrap();
        assert_eq!(layer.velocity.len(), layer.weights.nnz());
    }
}

#[test]
fn evolution_preserves_learning_across_long_runs() {
    // the SET cycle (prune+regrow every epoch) must not break the model
    // structure over many generations
    let data = toy_data(7);
    let mut cfg = toy_cfg(30);
    cfg.evolution = Some(tsnn::set::EvolutionConfig {
        zeta: 0.4,
        ..Default::default()
    });
    let report = train_sequential(&cfg, &data, &mut Rng::new(8)).unwrap();
    for layer in &report.model.layers {
        layer.weights.validate().unwrap();
    }
    assert!(report.best_test_accuracy > 0.55);
    // weight budget stays roughly constant under evolution
    let ratio = report.end_weights as f64 / report.start_weights as f64;
    assert!((0.9..=1.1).contains(&ratio), "ratio {ratio}");
}

#[test]
fn masked_dense_runtime_agrees_with_truly_sparse_on_same_topology() {
    // Cross-engine consistency: run the XLA fwd executable against the
    // truly-sparse forward on an identical (dense-ified) topology.
    let dir = tsnn::runtime::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let manifest = tsnn::runtime::Manifest::load(&dir).unwrap();
    let Some(arch) = manifest.get("small") else { return };

    // build a sparse model with matching sizes
    let mut rng = Rng::new(9);
    let model = SparseMlp::new(
        &arch.sizes,
        6.0,
        Activation::AllRelu { alpha: arch.alpha as f32 },
        &WeightInit::HeUniform,
        &mut rng,
    )
    .unwrap();

    // densify into (w, b, mask) literals for the XLA engine
    let exe = tsnn::runtime::HloExecutable::load(&arch.forward_hlo).unwrap();
    let batch = arch.batch;
    let x: Vec<f32> = (0..batch * arch.sizes[0]).map(|_| rng.normal()).collect();
    let mut inputs = vec![tsnn::runtime::engine::literal_f32(
        &x,
        &[batch as i64, arch.sizes[0] as i64],
    )
    .unwrap()];
    for layer in &model.layers {
        let (ni, no) = (layer.n_in(), layer.n_out());
        let mut w = vec![0.0f32; ni * no];
        let mut m = vec![0.0f32; ni * no];
        for (i, j, v) in layer.weights.iter() {
            w[i * no + j as usize] = v;
            m[i * no + j as usize] = 1.0;
        }
        inputs.push(
            tsnn::runtime::engine::literal_f32(&w, &[ni as i64, no as i64]).unwrap(),
        );
        inputs
            .push(tsnn::runtime::engine::literal_f32(&layer.bias, &[no as i64]).unwrap());
        inputs.push(
            tsnn::runtime::engine::literal_f32(&m, &[ni as i64, no as i64]).unwrap(),
        );
    }
    let out = exe.run(&inputs).unwrap();
    let xla_logits = tsnn::runtime::engine::to_vec_f32(&out[0]).unwrap();

    let mut ws = model.alloc_workspace(batch);
    let sparse_logits = model.forward(&x, batch, &mut ws, None);

    assert_eq!(xla_logits.len(), sparse_logits.len());
    for (k, (a, b)) in xla_logits.iter().zip(sparse_logits.iter()).enumerate() {
        assert!(
            (a - b).abs() < 1e-3 * (1.0 + b.abs()),
            "logit {k}: xla {a} vs sparse {b}"
        );
    }
}

#[test]
fn config_file_roundtrip_drives_training() {
    let dir = std::env::temp_dir();
    let cfg_path = dir.join("tsnn_itest.cfg");
    std::fs::write(
        &cfg_path,
        "epochs = 5\nhidden = 32x16\nlr = 0.05\ndropout = 0\nactivation = allrelu:0.6\n",
    )
    .unwrap();
    let mut cfg = TrainConfig::default();
    cfg.apply_file(&std::fs::read_to_string(&cfg_path).unwrap()).unwrap();
    assert_eq!(cfg.epochs, 5);
    assert_eq!(cfg.hidden, vec![32, 16]);
    let data = toy_data(11);
    let report = train_sequential(&cfg, &data, &mut Rng::new(12)).unwrap();
    assert_eq!(report.epochs.len(), 5);
    std::fs::remove_file(&cfg_path).ok();
}

#[test]
fn gradflow_instrumentation_composes_with_pruning() {
    let data = toy_data(13);
    let mut cfg = toy_cfg(12);
    cfg.importance = Some(ImportanceConfig {
        start_epoch: 4,
        period: 2,
        percentile: 15.0,
        min_connections: 16,
    });
    let report = tsnn::train::train_sequential_opts(
        &cfg,
        &data,
        &mut Rng::new(14),
        tsnn::train::TrainOptions {
            gradflow_every: 3,
            verbose: false,
            ..Default::default()
        },
    )
    .unwrap();
    let gf = report.gradflow.unwrap();
    assert!(gf.points.len() >= 3);
    assert!(gf.points.iter().all(|p| p.grad_norm_sq.is_finite()));
}
