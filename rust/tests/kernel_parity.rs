//! Kernel-parity suite: the worker-sharded kernels (DESIGN.md §4) and
//! the fused one-pass backward (DESIGN.md §5) must reproduce the
//! sequential kernels across random shapes, densities and thread counts,
//! including the degenerate edge cases.
//!
//! The sharding and fusion designs guarantee *exact* equality (disjoint
//! writes with unchanged per-slot accumulation order), so most assertions
//! use `==`; one oracle check also pins both paths against the dense
//! reference within 1e-5 to guard against a shared systematic error.
//!
//! Every parity assertion runs the sharded kernels on BOTH dispatch
//! backends — the cold scoped-spawn fallback and a persistent
//! [`WorkerPool`] of the same size (DESIGN.md §9) — across pool sizes
//! {1, 2, 8} (or the single `KERNEL_THREADS` budget CI pins), and on
//! EVERY microkernel ISA the host supports (scalar always; AVX2/AVX-512
//! or NEON where detected, DESIGN.md §11) — the sequential oracles are
//! the scalar kernels, so every assertion is a cross-ISA bit-exactness
//! check, with `TSNN_ISA` covering the forced legs in CI.

use tsnn::sparse::{erdos_renyi, ops, CsrMatrix, Isa, WeightInit, WorkerPool};
use tsnn::util::Rng;

mod common;
use common::thread_counts;

fn random_x(rng: &mut Rng, batch: usize, n: usize, zero_frac: f64) -> Vec<f32> {
    (0..batch * n)
        .map(|_| if rng.bernoulli(zero_frac) { 0.0 } else { rng.normal() })
        .collect()
}

/// Run all three kernels sequentially and sharded at `threads` — on the
/// scoped fallback AND on a pool of the same size, at every supported
/// microkernel ISA — asserting exact agreement on every output buffer
/// (the sequential oracles are the scalar kernels).
fn assert_parity(w: &CsrMatrix, batch: usize, rng: &mut Rng, threads: usize) {
    let (n_in, n_out) = (w.n_rows, w.n_cols);
    let x = random_x(rng, batch, n_in, 0.3);
    let dz = random_x(rng, batch, n_out, 0.0);
    let pool = WorkerPool::new(threads);
    for (path, exec) in [
        ("scoped", ops::Exec::scoped(threads)),
        ("pooled", ops::Exec::pooled(&pool)),
    ] {
        for isa in Isa::available() {
            let exec = exec.with_isa(isa);
            let label = format!(
                "{n_in}x{n_out} nnz={} batch={batch} threads={threads} {path} {}",
                w.nnz(),
                isa.name()
            );

            let mut seq = vec![0.0f32; batch * n_out];
            let mut par = vec![0.0f32; batch * n_out];
            ops::spmm_forward(&x, batch, w, &mut seq);
            ops::spmm_forward_exec(&x, batch, w, &mut par, exec);
            assert_eq!(seq, par, "forward mismatch ({label})");

            let mut seq = vec![0.0f32; batch * n_in];
            let mut par = vec![0.0f32; batch * n_in];
            ops::spmm_grad_input(&dz, batch, w, &mut seq);
            ops::spmm_grad_input_exec(&dz, batch, w, &mut par, exec);
            assert_eq!(seq, par, "grad_input mismatch ({label})");

            let mut seq = vec![0.0f32; w.nnz()];
            let mut par = vec![0.0f32; w.nnz()];
            ops::spmm_grad_weights(&x, &dz, batch, w, &mut seq);
            ops::spmm_grad_weights_exec(&x, &dz, batch, w, &mut par, exec);
            assert_eq!(seq, par, "grad_weights mismatch ({label})");
        }
    }
}

/// Run the fused one-pass backward at `threads` — scoped and pooled —
/// against the sequential two-kernel oracle (`spmm_grad_input` +
/// `spmm_grad_weights`), asserting exact agreement on both outputs. `dx`
/// starts NaN-poisoned so any slot the fused kernel fails to overwrite
/// (e.g. an all-empty row's column) trips the comparison.
fn assert_fused_parity(w: &CsrMatrix, batch: usize, rng: &mut Rng, threads: usize) {
    let (n_in, n_out) = (w.n_rows, w.n_cols);
    let x = random_x(rng, batch, n_in, 0.3);
    let dz = random_x(rng, batch, n_out, 0.0);

    let mut dx_oracle = vec![0.0f32; batch * n_in];
    let mut dw_oracle = vec![0.0f32; w.nnz()];
    ops::spmm_grad_input(&dz, batch, w, &mut dx_oracle);
    ops::spmm_grad_weights(&x, &dz, batch, w, &mut dw_oracle);

    let pool = WorkerPool::new(threads);
    for (path, exec) in [
        ("scoped", ops::Exec::scoped(threads)),
        ("pooled", ops::Exec::pooled(&pool)),
    ] {
        for isa in Isa::available() {
            let exec = exec.with_isa(isa);
            let label = format!(
                "{n_in}x{n_out} nnz={} batch={batch} threads={threads} {path} {}",
                w.nnz(),
                isa.name()
            );
            let mut dx = vec![f32::NAN; batch * n_in];
            let mut dw = vec![0.0f32; w.nnz()];
            ops::spmm_backward_fused_exec(&x, &dz, batch, w, &mut dx, &mut dw, exec);
            assert_eq!(dx, dx_oracle, "fused dx mismatch ({label})");
            assert_eq!(dw, dw_oracle, "fused dw mismatch ({label})");
        }
    }
}

#[test]
fn parity_across_random_shapes_densities_and_threads() {
    let mut rng = Rng::new(20250729);
    // (n_in, n_out, density, batch): mixes sub-crossover problems (the
    // threaded entry points must fall back cleanly) with problems big
    // enough that the sharded path genuinely runs at threads ≥ 2.
    let grid = [
        (17usize, 13usize, 0.3f64, 5usize),
        (64, 64, 0.1, 32),
        (128, 96, 0.02, 64),
        (300, 200, 0.5, 48),
        (256, 512, 0.35, 64),  // ≥ PAR_MIN_WORK: sharded path active
        (512, 256, 0.35, 128), // ≥ PAR_MIN_WORK, uneven shard tails
        (1000, 100, 0.2, 129), // batch not divisible by thread counts
    ];
    for &(n_in, n_out, density, batch) in &grid {
        let w = erdos_renyi(n_in, n_out, density, &mut rng, &WeightInit::Normal(0.5));
        for threads in thread_counts() {
            assert_parity(&w, batch, &mut rng, threads);
        }
    }
}

#[test]
fn parity_holds_against_dense_oracle_above_crossover() {
    // Both paths must also agree with the dense reference (within 1e-5),
    // not merely with each other.
    let mut rng = Rng::new(31);
    let (n_in, n_out, batch) = (256usize, 512usize, 64usize);
    let w = erdos_renyi(n_in, n_out, 0.35, &mut rng, &WeightInit::Normal(0.5));
    assert!(batch * w.nnz() >= ops::PAR_MIN_WORK);
    let x = random_x(&mut rng, batch, n_in, 0.3);
    let dense = ops::dense_matmul(&x, batch, &w.to_dense(), n_in, n_out);
    let mut par = vec![0.0f32; batch * n_out];
    ops::spmm_forward_threaded(&x, batch, &w, &mut par, 8);
    for (i, (&a, &b)) in par.iter().zip(dense.iter()).enumerate() {
        assert!(
            (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
            "idx {i}: sharded {a} vs dense {b}"
        );
    }
}

#[test]
fn parity_with_empty_matrix() {
    let mut rng = Rng::new(32);
    let w = CsrMatrix::empty(40, 50);
    for threads in thread_counts() {
        assert_parity(&w, 7, &mut rng, threads);
    }
}

#[test]
fn parity_with_zero_batch() {
    let mut rng = Rng::new(33);
    let w = erdos_renyi(30, 20, 0.4, &mut rng, &WeightInit::Normal(1.0));
    for threads in thread_counts() {
        assert_parity(&w, 0, &mut rng, threads);
    }
}

#[test]
fn parity_with_more_threads_than_batch() {
    let mut rng = Rng::new(34);
    // batch 2 with 8 requested threads: work is large enough to shard,
    // but the batch dimension caps the forward/grad_input shard count.
    let w = erdos_renyi(1024, 1024, 0.7, &mut rng, &WeightInit::Normal(0.5));
    assert!(2 * w.nnz() >= ops::PAR_MIN_WORK);
    assert_parity(&w, 2, &mut rng, 8);
}

#[test]
fn parity_with_single_row_matrix() {
    let mut rng = Rng::new(35);
    // one CSR row: grad_weights cannot shard (max_shards = n_rows = 1)
    // and must fall back; batch sharding still applies to the others.
    let w = erdos_renyi(1, 2048, 0.9, &mut rng, &WeightInit::Normal(0.5));
    assert_parity(&w, 600, &mut rng, 8);
}

#[test]
fn parity_with_highly_irregular_rows() {
    // Hand-built pattern with one nnz-heavy row and many empty rows, so
    // the balanced-nnz row partition produces empty shards.
    let mut triplets = Vec::new();
    for j in 0..1500u32 {
        triplets.push((3u32, j, 0.01 * j as f32 - 5.0));
    }
    for i in [0u32, 7, 63] {
        triplets.push((i, 0, 1.0));
    }
    let w = CsrMatrix::from_coo(64, 1500, triplets).unwrap();
    let mut rng = Rng::new(36);
    for threads in thread_counts() {
        assert_parity(&w, 800, &mut rng, threads);
    }
}

// ---------------------------------------------------------------------------
// Fused one-pass backward vs the sequential two-kernel oracle (DESIGN.md §5).

#[test]
fn fused_parity_across_random_shapes_densities_threads_and_ragged_batches() {
    let mut rng = Rng::new(20260729);
    // (n_in, n_out, density, batch): sub-crossover problems (sequential
    // fused path), problems big enough to row-shard at threads ≥ 2, and
    // ragged batches hitting assorted remainder widths of the BLOCK=8
    // microkernel (batch % 8 ∈ {0, 1, 5, 7}; the remaining widths are
    // covered by the unit tests in sparse/ops.rs and model/layer.rs).
    let grid = [
        (17usize, 13usize, 0.3f64, 5usize),
        (64, 64, 0.1, 33),
        (128, 96, 0.02, 63),
        (300, 200, 0.5, 48),
        (256, 512, 0.35, 64),  // ≥ PAR_MIN_WORK: sharded path active
        (512, 256, 0.35, 129), // ≥ PAR_MIN_WORK, ragged tail of 1
        (1000, 100, 0.2, 135), // batch not divisible by thread counts
    ];
    for &(n_in, n_out, density, batch) in &grid {
        let w = erdos_renyi(n_in, n_out, density, &mut rng, &WeightInit::Normal(0.5));
        for threads in thread_counts() {
            assert_fused_parity(&w, batch, &mut rng, threads);
        }
    }
}

#[test]
fn fused_parity_with_empty_matrix() {
    // no stored weights: dw is empty and every dx slot must still be
    // overwritten with 0.0 (the NaN poison in the helper catches misses)
    let mut rng = Rng::new(37);
    let w = CsrMatrix::empty(40, 50);
    for threads in thread_counts() {
        assert_fused_parity(&w, 7, &mut rng, threads);
    }
}

#[test]
fn fused_parity_with_zero_batch() {
    let mut rng = Rng::new(38);
    let w = erdos_renyi(30, 20, 0.4, &mut rng, &WeightInit::Normal(1.0));
    for threads in thread_counts() {
        assert_fused_parity(&w, 0, &mut rng, threads);
    }
}

#[test]
fn fused_parity_with_single_row_matrix() {
    // one CSR row: the row dimension cannot shard, so the fused kernel
    // must fall back to its sequential core at any thread count
    let mut rng = Rng::new(39);
    let w = erdos_renyi(1, 2048, 0.9, &mut rng, &WeightInit::Normal(0.5));
    for threads in thread_counts() {
        assert_fused_parity(&w, 600, &mut rng, threads);
    }
}

#[test]
fn fused_parity_with_highly_irregular_rows() {
    // one nnz-heavy row plus many empty rows: the balanced-nnz partition
    // produces shards whose rows carry zero nnz — they still own (and
    // must zero) their dx columns on the sharded path
    let mut triplets = Vec::new();
    for j in 0..1500u32 {
        triplets.push((3u32, j, 0.01 * j as f32 - 5.0));
    }
    for i in [0u32, 7, 63] {
        triplets.push((i, 0, 1.0));
    }
    let w = CsrMatrix::from_coo(64, 1500, triplets).unwrap();
    let mut rng = Rng::new(36);
    for threads in thread_counts() {
        assert_fused_parity(&w, 800, &mut rng, threads);
    }
}

#[test]
fn fused_parity_against_dense_oracle_above_crossover() {
    // The fused dx must also agree with the dense reference (within
    // 1e-5), not merely with the sparse oracle.
    let mut rng = Rng::new(44);
    let (n_in, n_out, batch) = (256usize, 512usize, 64usize);
    let w = erdos_renyi(n_in, n_out, 0.35, &mut rng, &WeightInit::Normal(0.5));
    assert!(batch * w.nnz() >= ops::PAR_MIN_WORK);
    let x = random_x(&mut rng, batch, n_in, 0.3);
    let dz = random_x(&mut rng, batch, n_out, 0.0);
    let wt = w.transpose();
    let dense = ops::dense_matmul(&dz, batch, &wt.to_dense(), n_out, n_in);
    let mut dx = vec![f32::NAN; batch * n_in];
    let mut dw = vec![0.0f32; w.nnz()];
    ops::spmm_backward_fused(&x, &dz, batch, &w, &mut dx, &mut dw, 8);
    for (i, (&a, &b)) in dx.iter().zip(dense.iter()).enumerate() {
        assert!(
            (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
            "idx {i}: fused {a} vs dense {b}"
        );
    }
}
