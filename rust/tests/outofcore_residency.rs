//! Residency-advisor behaviour under training load (DESIGN.md §14.4):
//! the advisor is correctness-neutral (a run squeezed by an impossible
//! RSS budget produces the bit-identical model to an unconstrained
//! run), trims actually fire under pressure, and a sync+drop cycle over
//! a trained model's segments releases resident pages without losing a
//! byte. The *quantitative* peak-RSS-under-budget claim lives in
//! `benches/perf_outofcore.rs` and the extreme-smoke CI job, where the
//! model is big enough for the ratios to be meaningful.

#![cfg(all(target_os = "linux", target_pointer_width = "64"))]

use std::path::PathBuf;

use tsnn::bigmodel::{train_big, vm_rss_bytes, BigModel, BigTrainOptions};
use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::data::datasets;
use tsnn::util::Rng;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tsnn_ooc_res_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn spec() -> DatasetSpec {
    DatasetSpec {
        name: "recommender-residency".into(),
        generator: "recommender".into(),
        n_features: 512,
        n_classes: 4,
        n_train: 200,
        n_test: 60,
    }
}

fn config() -> TrainConfig {
    let mut cfg = TrainConfig::small_preset("recommender");
    for (k, v) in [
        ("epochs", "3"),
        ("batch", "32"),
        ("hidden", "64x32"),
        ("epsilon", "8"),
        ("zeta", "0.3"),
        ("eval_every", "1"),
        ("seed", "5150"),
        ("kernel_threads", "1"),
    ] {
        cfg.set(k, v).unwrap();
    }
    cfg
}

fn run_with(
    dir: &PathBuf,
    cfg: &TrainConfig,
    spec: &DatasetSpec,
    opts: &BigTrainOptions,
) -> (BigModel, usize, Vec<u8>) {
    let mut rng = Rng::new(cfg.seed);
    let data = datasets::generate(spec, &mut rng).unwrap();
    let sizes = cfg.sizes(data.n_features, data.n_classes);
    let mut big = BigModel::create(
        dir,
        &sizes,
        cfg.epsilon,
        cfg.activation,
        &cfg.init,
        &mut rng,
    )
    .unwrap();
    let report = train_big(cfg, &data, &mut big, &mut rng, opts).unwrap();
    let ck = dir.join("final.tsnn");
    big.save_checkpoint(&ck).unwrap();
    let bytes = std::fs::read(&ck).unwrap();
    (big, report.trim_events, bytes)
}

fn run(dir: &PathBuf, opts: &BigTrainOptions) -> (BigModel, usize, Vec<u8>) {
    run_with(dir, &config(), &spec(), opts)
}

/// An impossible budget (0 bytes → every check is over budget) forces a
/// trim at every hook; the trained model must still be bit-identical to
/// an unconstrained run. This is the [`tsnn::sparse::Residency`]
/// contract — advisors may only change *when pages are resident*, never
/// what they contain.
#[test]
fn squeezed_run_is_bit_identical_to_unconstrained_run() {
    let dir_free = tmp_dir("free");
    let (_, trims_free, bytes_free) = run(&dir_free, &BigTrainOptions::default());
    assert_eq!(trims_free, 0, "no advisor, no trims");

    let dir_tight = tmp_dir("tight");
    let opts = BigTrainOptions {
        soft_budget_bytes: Some(0),
        residency_check_every: 1,
        ..BigTrainOptions::default()
    };
    let (_, trims_tight, bytes_tight) = run(&dir_tight, &opts);
    assert!(
        trims_tight > 0,
        "an over-budget run must actually trim (got {trims_tight})"
    );
    assert_eq!(
        bytes_free, bytes_tight,
        "residency pressure changed the trained model"
    );
    std::fs::remove_dir_all(&dir_free).ok();
    std::fs::remove_dir_all(&dir_tight).ok();
}

/// A comfortable budget (far above anything this process touches) must
/// never trigger the advisor.
#[test]
fn comfortable_budget_never_trims() {
    let dir = tmp_dir("comfy");
    let opts = BigTrainOptions {
        soft_budget_bytes: Some(u64::MAX),
        residency_check_every: 1,
        ..BigTrainOptions::default()
    };
    let (_, trims, _) = run(&dir, &opts);
    assert_eq!(trims, 0);
    std::fs::remove_dir_all(&dir).ok();
}

/// Sync+drop over a trained (sealed) model's regions releases resident
/// pages — RSS goes down, and a cold reopen still CRC-verifies and
/// yields the same checkpoint. The drop really is lossless.
#[test]
fn dropping_resident_pages_loses_nothing() {
    let dir = tmp_dir("drop");
    // bigger layer 0 (~6 MiB of segment) so the RSS delta of the drop
    // clears /proc's kilobyte granularity and allocator noise
    let mut cfg = config();
    cfg.set("epochs", "2").unwrap();
    cfg.set("hidden", "256").unwrap();
    cfg.set("epsilon", "32").unwrap();
    let mut spec = spec();
    spec.n_features = 16_384;
    spec.n_train = 128;
    spec.n_test = 32;
    let (big, _, bytes_live) = run_with(&dir, &cfg, &spec, &BigTrainOptions::default());

    // touch everything, then measure → drop → measure
    let mut resident_sum = 0u64;
    for layer in &big.mlp.layers {
        for &v in layer.weights.values.as_slice() {
            resident_sum = resident_sum.wrapping_add(v.to_bits() as u64);
        }
    }
    let before = vm_rss_bytes().unwrap();
    for region in big.regions() {
        region.sync(0, region.len()).unwrap();
        region.advise_dontneed(0, region.len());
    }
    let after = vm_rss_bytes().unwrap();
    assert!(
        after < before,
        "RSS did not shrink after dropping mapped pages \
         (before {before} B, after {after} B, touched-sum {resident_sum:x})"
    );

    drop(big);
    let reopened = BigModel::open(&dir).unwrap();
    let ck = dir.join("reopened.tsnn");
    reopened.save_checkpoint(&ck).unwrap();
    assert_eq!(bytes_live, std::fs::read(&ck).unwrap());
    std::fs::remove_dir_all(&dir).ok();
}
