//! Serving-parity suite (DESIGN.md §10): the inference-specialized
//! layout must reproduce the training forward path **bit-exactly** —
//! across shapes × densities (including layers dense enough to trigger
//! the dense-fallback format), pool sizes {1, 2, 8} (or the pinned
//! `KERNEL_THREADS` budget), every microkernel ISA the host supports
//! (forced via `ServeWorkspace::force_isa` against a default-ISA
//! training oracle, DESIGN.md §11.3), and any batch composition the
//! front end forms. Format selection is asserted, not assumed: every
//! grid case pins the expected per-layer CSR/dense choice.

use std::sync::mpsc::channel;
use std::time::Duration;

use tsnn::model::{SparseLayer, SparseMlp};
use tsnn::nn::Activation;
use tsnn::serve::{
    LayerFormat, LayoutOptions, ServeConfig, ServeEngine, ServeModel, ServeWorkspace,
};
use tsnn::sparse::{erdos_renyi, Isa, WeightInit};
use tsnn::util::Rng;

mod common;
use common::thread_counts;

/// Model with hand-picked per-layer densities (the grid needs exact
/// control over which layers cross the dense-fallback threshold).
fn mixed_model(sizes: &[usize], densities: &[f64], seed: u64) -> SparseMlp {
    assert_eq!(densities.len(), sizes.len() - 1);
    let mut rng = Rng::new(seed);
    let n_layers = densities.len();
    let layers = densities
        .iter()
        .enumerate()
        .map(|(l, &d)| {
            let weights =
                erdos_renyi(sizes[l], sizes[l + 1], d, &mut rng, &WeightInit::Normal(0.3));
            let activation = if l + 1 == n_layers {
                Activation::Linear
            } else {
                Activation::AllRelu { alpha: 0.6 }
            };
            let n_out = sizes[l + 1];
            SparseLayer {
                bias: (0..n_out).map(|_| rng.normal() * 0.1).collect(),
                velocity: vec![0.0; weights.nnz()].into(),
                bias_velocity: vec![0.0; n_out],
                weights,
                activation,
                srelu: None,
            }
        })
        .collect();
    SparseMlp {
        sizes: sizes.to_vec(),
        layers,
    }
}

fn random_x(rng: &mut Rng, batch: usize, n: usize) -> Vec<f32> {
    (0..batch * n)
        .map(|_| if rng.bernoulli(0.3) { 0.0 } else { rng.normal() })
        .collect()
}

/// Training-path logits (the sequential oracle).
fn training_logits(mlp: &SparseMlp, x: &[f32], batch: usize) -> Vec<f32> {
    let mut ws = mlp.alloc_workspace(batch);
    ws.kernel_threads = 1;
    mlp.forward(x, batch, &mut ws, None).to_vec()
}

#[test]
fn serving_forward_bit_exact_across_shapes_densities_and_pools() {
    // (sizes, densities, expected formats at the default crossover)
    let grid: &[(&[usize], &[f64], &[LayerFormat])] = &[
        (
            &[23, 17, 9],
            &[0.08, 0.5],
            &[LayerFormat::Csr, LayerFormat::Dense],
        ),
        (
            &[40, 64, 32, 10],
            &[0.05, 0.12, 0.9],
            &[LayerFormat::Csr, LayerFormat::Csr, LayerFormat::Dense],
        ),
        (
            &[7, 5, 3],
            &[1.0, 1.0],
            &[LayerFormat::Dense, LayerFormat::Dense],
        ),
        (
            &[12, 30, 4],
            &[0.0, 0.3],
            &[LayerFormat::Csr, LayerFormat::Dense],
        ),
    ];
    let mut rng = Rng::new(99);
    for (case, &(sizes, densities, formats)) in grid.iter().enumerate() {
        let mlp = mixed_model(sizes, densities, 1000 + case as u64);
        let serve = ServeModel::from_mlp(&mlp, &LayoutOptions::default());
        let picked: Vec<LayerFormat> = serve.layers.iter().map(|l| l.format()).collect();
        assert_eq!(picked, formats, "case {case}: format selection");
        for &batch in &[1usize, 5, 8, 19] {
            let x = random_x(&mut rng, batch, sizes[0]);
            let oracle = training_logits(&mlp, &x, batch);
            for threads in thread_counts() {
                for isa in Isa::available() {
                    let mut ws = ServeWorkspace::with_threads(threads);
                    ws.force_isa = Some(isa);
                    let got = serve.forward(&x, batch, &mut ws);
                    assert_eq!(
                        oracle, got,
                        "case {case} batch={batch} threads={threads} isa={}: serving \
                         forward must be bit-exact vs the training path",
                        isa.name()
                    );
                }
            }
        }
    }
}

#[test]
fn serving_formats_cover_both_csr_and_dense_fallback() {
    let mlp = mixed_model(&[23, 17, 9], &[0.08, 0.5], 5);
    let serve = ServeModel::from_mlp(&mlp, &LayoutOptions::default());
    assert_eq!(serve.layers[0].format(), LayerFormat::Csr);
    assert_eq!(serve.layers[1].format(), LayerFormat::Dense);
    assert!(serve.layers[0].density < serve.layers[1].density);
}

#[test]
fn checkpoint_loads_into_serving_layout_bit_exact() {
    let mut mlp = mixed_model(&[16, 24, 6], &[0.1, 0.6], 7);
    // optimizer state must not leak into (or be required by) serving
    for l in &mut mlp.layers {
        for v in &mut l.velocity {
            *v = 0.5;
        }
    }
    let dir = std::env::temp_dir().join("tsnn_serving_parity");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.tsnn");
    tsnn::model::checkpoint::save(&mlp, &path).unwrap();
    let serve = ServeModel::load(&path, &LayoutOptions::default()).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(serve.layers[0].format(), LayerFormat::Csr);
    assert_eq!(serve.layers[1].format(), LayerFormat::Dense);
    let mut rng = Rng::new(8);
    for &batch in &[1usize, 9] {
        let x = random_x(&mut rng, batch, 16);
        let oracle = training_logits(&mlp, &x, batch);
        for threads in thread_counts() {
            let mut ws = ServeWorkspace::with_threads(threads);
            assert_eq!(oracle, serve.forward(&x, batch, &mut ws), "threads={threads}");
        }
    }
}

#[test]
fn batch_formation_does_not_change_results() {
    // the same requests through (a) a batching engine, (b) a batch-1
    // engine, and (c) direct one-at-a-time forwards must agree bitwise
    let mlp = mixed_model(&[19, 28, 5], &[0.1, 0.55], 21);
    let serve = ServeModel::from_mlp(&mlp, &LayoutOptions::default());
    let mut rng = Rng::new(31);
    let n = 12usize;
    let requests: Vec<Vec<f32>> = (0..n).map(|_| random_x(&mut rng, 1, 19)).collect();

    let direct: Vec<Vec<f32>> = requests
        .iter()
        .map(|x| {
            let mut ws = ServeWorkspace::with_threads(1);
            serve.forward(x, 1, &mut ws).to_vec()
        })
        .collect();

    for threads in thread_counts() {
        for max_batch in [8usize, 1] {
            let cfg = ServeConfig {
                max_batch,
                max_queue: 64,
                max_wait: Duration::from_millis(30),
                kernel_threads: threads,
                ..ServeConfig::default()
            };
            let mut engine = ServeEngine::new(serve.clone(), cfg);
            let tickets: Vec<_> = requests
                .iter()
                .map(|x| engine.submit(x.clone()).expect("queue has room"))
                .collect();
            for (i, t) in tickets.into_iter().enumerate() {
                let got = t.wait().unwrap();
                assert_eq!(
                    direct[i], got,
                    "request {i} (max_batch={max_batch}, threads={threads})"
                );
            }
            let stats = engine.stats();
            assert_eq!(stats.completed, n as u64);
            assert_eq!(stats.rejected, 0);
            engine.shutdown();
        }
    }
}

#[test]
fn engine_results_arrive_for_concurrent_submitters() {
    // many client threads, one engine: every response must match the
    // direct forward of its own request (no cross-request mixups)
    let mlp = mixed_model(&[11, 16, 4], &[0.12, 0.6], 77);
    let serve = ServeModel::from_mlp(&mlp, &LayoutOptions::default());
    let cfg = ServeConfig {
        max_batch: 4,
        max_queue: 256,
        max_wait: Duration::from_millis(2),
        kernel_threads: 1,
        ..ServeConfig::default()
    };
    let engine = ServeEngine::new(serve.clone(), cfg);
    let (tx, rx) = channel::<(Vec<f32>, Vec<f32>)>();
    std::thread::scope(|scope| {
        for c in 0..4u64 {
            let engine = &engine;
            let tx = tx.clone();
            scope.spawn(move || {
                let mut rng = Rng::new(c + 1);
                for _ in 0..8 {
                    let x = random_x(&mut rng, 1, 11);
                    let y = engine.infer(x.clone()).unwrap();
                    tx.send((x, y)).unwrap();
                }
            });
        }
        drop(tx);
    });
    let mut ws = ServeWorkspace::with_threads(1);
    let mut seen = 0;
    while let Ok((x, y)) = rx.recv() {
        assert_eq!(serve.forward(&x, 1, &mut ws), &y[..]);
        seen += 1;
    }
    assert_eq!(seen, 32);
}
