//! Property-based tests over the sparse substrate and training
//! invariants. The offline vendor set has no proptest, so this uses the
//! same discipline by hand: generate many random cases from seeded RNG
//! streams, check the invariant, and report the failing seed (re-run
//! reproducibly with that seed to debug).

use tsnn::nn::{Activation, MomentumSgd};
use tsnn::prelude::*;
use tsnn::set::{evolve_layer, prune_thresholds, EvolutionConfig, EvolutionEngine};
use tsnn::sparse::{epsilon_density, erdos_renyi, ops, CsrMatrix};

const CASES: u64 = 60;

fn rand_csr(rng: &mut Rng) -> CsrMatrix {
    let n_rows = 1 + rng.below_usize(40);
    let n_cols = 1 + rng.below_usize(40);
    let density = rng.f64() * 0.6;
    erdos_renyi(n_rows, n_cols, density, rng, &WeightInit::Normal(1.0))
}

#[test]
fn prop_csr_structure_valid_after_random_construction() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let m = rand_csr(&mut rng);
        m.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        // round-trip through dense preserves everything
        let d = m.to_dense();
        let nnz_dense = d.iter().filter(|&&v| v != 0.0).count();
        assert!(nnz_dense <= m.nnz(), "seed {seed}"); // zeros may be stored
    }
}

#[test]
fn prop_transpose_is_involution() {
    for seed in 0..CASES {
        let mut rng = Rng::new(1000 + seed);
        let m = rand_csr(&mut rng);
        let tt = m.transpose().transpose();
        assert_eq!(m, tt, "seed {seed}");
    }
}

#[test]
fn prop_spmm_forward_matches_dense_oracle() {
    for seed in 0..CASES {
        let mut rng = Rng::new(2000 + seed);
        let w = rand_csr(&mut rng);
        let batch = 1 + rng.below_usize(8);
        let x: Vec<f32> = (0..batch * w.n_rows)
            .map(|_| if rng.bernoulli(0.3) { 0.0 } else { rng.normal() })
            .collect();
        let mut out = vec![0.0f32; batch * w.n_cols];
        ops::spmm_forward(&x, batch, &w, &mut out);
        let oracle = ops::dense_matmul(&x, batch, &w.to_dense(), w.n_rows, w.n_cols);
        for (k, (a, b)) in out.iter().zip(oracle.iter()).enumerate() {
            assert!(
                (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                "seed {seed} idx {k}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn prop_grad_input_is_transpose_forward() {
    for seed in 0..CASES {
        let mut rng = Rng::new(3000 + seed);
        let w = rand_csr(&mut rng);
        let batch = 1 + rng.below_usize(6);
        let dz: Vec<f32> = (0..batch * w.n_cols).map(|_| rng.normal()).collect();
        let mut dx = vec![0.0f32; batch * w.n_rows];
        ops::spmm_grad_input(&dz, batch, &w, &mut dx);
        let wt = w.transpose();
        let mut oracle = vec![0.0f32; batch * w.n_rows];
        ops::spmm_forward(&dz, batch, &wt, &mut oracle);
        for (k, (a, b)) in dx.iter().zip(oracle.iter()).enumerate() {
            assert!(
                (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                "seed {seed} idx {k}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn prop_retain_insert_roundtrip_preserves_survivors() {
    for seed in 0..CASES {
        let mut rng = Rng::new(4000 + seed);
        let mut m = rand_csr(&mut rng);
        if m.nnz() == 0 {
            continue;
        }
        let original = m.clone();
        // drop a random half
        let drop: Vec<bool> = (0..m.nnz()).map(|_| rng.bernoulli(0.5)).collect();
        let kept = m.retain(|k| !drop[k]);
        m.validate().unwrap();
        // every survivor keeps its value
        for (new_idx, &old_idx) in kept.iter().enumerate() {
            assert_eq!(m.values[new_idx], original.values[old_idx], "seed {seed}");
        }
        // re-insert what was dropped
        let mut additions = Vec::new();
        for (k, (i, j, v)) in original.iter().enumerate() {
            if drop[k] {
                additions.push((i as u32, j, v));
            }
        }
        m.insert(additions).unwrap();
        m.validate().unwrap();
        assert_eq!(m, original, "seed {seed}: retain+insert roundtrip");
    }
}

#[test]
fn prop_epsilon_density_bounds() {
    for seed in 0..CASES {
        let mut rng = Rng::new(5000 + seed);
        let n_in = 1 + rng.below_usize(5000);
        let n_out = 1 + rng.below_usize(5000);
        let eps = rng.f64() * 50.0;
        let d = epsilon_density(eps, n_in, n_out);
        assert!((0.0..=1.0).contains(&d), "seed {seed}: {d}");
    }
}

#[test]
fn prop_evolution_invariants() {
    for seed in 0..CASES {
        let mut rng = Rng::new(6000 + seed);
        let n_in = 4 + rng.below_usize(30);
        let n_out = 4 + rng.below_usize(30);
        let mut layer = tsnn::model::SparseLayer::erdos_renyi(
            n_in,
            n_out,
            2.0 + rng.f64() * 6.0,
            Activation::Relu,
            &WeightInit::Normal(1.0),
            &mut rng,
        );
        let before = layer.weights.nnz();
        let zeta = rng.f64() * 0.5;
        let stats = evolve_layer(
            &mut layer,
            &EvolutionConfig {
                zeta,
                init: WeightInit::Normal(1.0),
            },
            &mut rng,
        )
        .unwrap();
        layer.weights.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        // invariant 1: regrown <= pruned (capacity may bind)
        assert!(stats.regrown <= stats.pruned, "seed {seed}");
        // invariant 2: nnz conserved up to capacity shortfall
        assert_eq!(
            layer.weights.nnz(),
            before - stats.pruned + stats.regrown,
            "seed {seed}"
        );
        // invariant 3: velocity stays aligned
        assert_eq!(layer.velocity.len(), layer.weights.nnz(), "seed {seed}");
    }
}

#[test]
fn prop_optimizer_state_follows_survivors_through_evolution() {
    // Velocity must ride the survivor remap exactly: every surviving link
    // keeps its (uniquely tagged) velocity AND its weight at the same
    // (row, col); every regrown link starts at zero velocity. Bias state
    // is per-output-neuron and must come through untouched.
    for seed in 0..CASES {
        let mut rng = Rng::new(11_000 + seed);
        let n_in = 4 + rng.below_usize(30);
        let n_out = 4 + rng.below_usize(30);
        let mut layer = tsnn::model::SparseLayer::erdos_renyi(
            n_in,
            n_out,
            2.0 + rng.f64() * 6.0,
            Activation::Relu,
            &WeightInit::Normal(1.0),
            &mut rng,
        );
        for (k, v) in layer.velocity.iter_mut().enumerate() {
            *v = (k + 1) as f32; // unique, non-zero tags
        }
        for (j, b) in layer.bias.iter_mut().enumerate() {
            *b = 0.5 + j as f32;
        }
        for (j, b) in layer.bias_velocity.iter_mut().enumerate() {
            *b = -1.0 - j as f32;
        }
        let old: std::collections::HashMap<(usize, u32), (f32, f32)> = layer
            .weights
            .iter()
            .enumerate()
            .map(|(k, (i, j, v))| ((i, j), (v, layer.velocity[k])))
            .collect();
        let bias_before = layer.bias.clone();
        let bvel_before = layer.bias_velocity.clone();
        let stats = evolve_layer(
            &mut layer,
            &EvolutionConfig {
                zeta: rng.f64() * 0.6,
                init: WeightInit::Normal(1.0),
            },
            &mut rng,
        )
        .unwrap();
        layer.weights.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let mut survivors = 0usize;
        for (k, (i, j, v)) in layer.weights.iter().enumerate() {
            let vel = layer.velocity[k];
            if vel != 0.0 {
                let &(ov, ovel) = old
                    .get(&(i, j))
                    .unwrap_or_else(|| panic!("seed {seed}: survivor ({i},{j}) not in old"));
                assert_eq!(v, ov, "seed {seed}: survivor weight moved");
                assert_eq!(vel, ovel, "seed {seed}: velocity did not follow survivor");
                survivors += 1;
            }
        }
        assert_eq!(
            survivors + stats.regrown,
            layer.weights.nnz(),
            "seed {seed}: every link is a tagged survivor or a zero-velocity regrow"
        );
        assert_eq!(layer.bias, bias_before, "seed {seed}: bias changed");
        assert_eq!(layer.bias_velocity, bvel_before, "seed {seed}: bias velocity changed");
    }
}

#[test]
fn prop_regrown_entries_never_collide_with_survivors() {
    // 100 random seeds through the threaded engine: regrown links (zero
    // velocity) only occupy positions that were empty after pruning —
    // survivors (tagged velocity) never move and are never overwritten,
    // and the CSR stays structurally valid (no duplicate positions).
    for seed in 0..100u64 {
        let mut rng = Rng::new(12_000 + seed);
        let sizes = [
            4 + rng.below_usize(20),
            4 + rng.below_usize(20),
            3 + rng.below_usize(10),
        ];
        let mut mlp = SparseMlp::new(
            &sizes,
            2.0 + rng.f64() * 5.0,
            Activation::Relu,
            &WeightInit::Normal(1.0),
            &mut rng,
        )
        .unwrap();
        for layer in mlp.layers.iter_mut() {
            for v in layer.velocity.iter_mut() {
                *v = 7.0;
            }
        }
        let before: Vec<std::collections::HashSet<(usize, u32)>> = mlp
            .layers
            .iter()
            .map(|l| l.weights.iter().map(|(i, j, _)| (i, j)).collect())
            .collect();
        let mut engine = EvolutionEngine::new();
        let stats = engine
            .evolve_model(
                &mut mlp,
                &EvolutionConfig {
                    zeta: 0.4,
                    init: WeightInit::Normal(1.0),
                },
                &mut Rng::new(100_000 + seed),
                8,
            )
            .unwrap();
        for (l, layer) in mlp.layers.iter().enumerate() {
            layer
                .weights
                .validate()
                .unwrap_or_else(|e| panic!("seed {seed} layer {l}: {e}"));
            let mut regrown = 0usize;
            for (k, (i, j, _)) in layer.weights.iter().enumerate() {
                if layer.velocity[k] == 0.0 {
                    regrown += 1;
                } else {
                    assert!(
                        before[l].contains(&(i, j)),
                        "seed {seed} layer {l}: survivor ({i},{j}) not in original topology"
                    );
                }
            }
            assert_eq!(
                regrown, stats[l].regrown,
                "seed {seed} layer {l}: regrown count mismatch"
            );
        }
    }
}

#[test]
fn prop_prune_thresholds_split_fraction() {
    for seed in 0..CASES {
        let mut rng = Rng::new(7000 + seed);
        let n = 10 + rng.below_usize(500);
        let values: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let zeta = rng.f64() * 0.9;
        let (pos_cut, neg_cut) = prune_thresholds(&values, zeta);
        let pos: Vec<f32> = values.iter().copied().filter(|v| *v > 0.0).collect();
        let neg: Vec<f32> = values.iter().copied().filter(|v| *v < 0.0).collect();
        let pruned_pos = pos.iter().filter(|&&v| v <= pos_cut).count();
        let pruned_neg = neg.iter().filter(|&&v| v >= neg_cut && v < 0.0).count();
        // prune counts land within one duplicate-cluster of zeta fraction
        let kp = (pos.len() as f64 * zeta).floor() as usize;
        let kn = (neg.len() as f64 * zeta).floor() as usize;
        assert!(pruned_pos >= kp.min(pos.len()), "seed {seed}");
        assert!(pruned_neg >= kn.min(neg.len()), "seed {seed}");
    }
}

#[test]
fn prop_importance_pruning_only_removes_weak_neurons() {
    for seed in 0..CASES {
        let mut rng = Rng::new(8000 + seed);
        let mut layer = tsnn::model::SparseLayer::erdos_renyi(
            10 + rng.below_usize(20),
            10 + rng.below_usize(20),
            3.0,
            Activation::Relu,
            &WeightInit::Normal(1.0),
            &mut rng,
        );
        let importance = tsnn::importance::neuron_importance(&layer);
        let threshold = 0.5;
        tsnn::importance::prune_neurons_below(&mut layer, threshold);
        let counts = layer.weights.column_counts();
        for (j, &c) in counts.iter().enumerate() {
            if importance[j] >= threshold {
                continue;
            }
            assert_eq!(c, 0, "seed {seed}: weak neuron {j} kept connections");
        }
        layer.weights.validate().unwrap();
    }
}

#[test]
fn prop_training_never_produces_nonfinite_state() {
    for seed in 0..12 {
        let mut rng = Rng::new(9000 + seed);
        let mut model = SparseMlp::new(
            &[10, 24, 12, 3],
            6.0,
            Activation::AllRelu { alpha: 0.75 },
            &WeightInit::HeUniform,
            &mut rng,
        )
        .unwrap();
        let mut ws = model.alloc_workspace(16);
        let opt = MomentumSgd::default();
        let x: Vec<f32> = (0..16 * 10).map(|_| rng.normal() * 3.0).collect();
        let y: Vec<u32> = (0..16).map(|i| (i % 3) as u32).collect();
        for step in 0..50 {
            // lr chosen inside the stable region for this scale of inputs;
            // divergence at hot rates is legitimate SGD behaviour, not a
            // finiteness bug.
            let stats = model.train_step(&x, &y, &opt, 0.02, None, &mut ws, &mut rng);
            assert!(stats.loss.is_finite(), "seed {seed} step {step}");
        }
        for layer in &model.layers {
            assert!(layer.weights.values.iter().all(|v| v.is_finite()), "seed {seed}");
            assert!(layer.velocity.iter().all(|v| v.is_finite()), "seed {seed}");
        }
    }
}

#[test]
fn prop_model_averaging_bounded_by_inputs() {
    // averaged value of a link never exceeds the max of contributors
    for seed in 0..CASES {
        let mut rng = Rng::new(10_000 + seed);
        let mk = |r: &mut Rng| {
            SparseMlp::new(
                &[8, 12, 3],
                4.0,
                Activation::Relu,
                &WeightInit::Normal(1.0),
                r,
            )
            .unwrap()
        };
        let a = mk(&mut rng);
        let b = mk(&mut rng);
        let targets: Vec<usize> = a.layers.iter().map(|l| l.weights.nnz()).collect();
        let avg =
            tsnn::coordinator::average_and_resparsify(&[a.clone(), b.clone()], &targets).unwrap();
        let max_abs = |m: &SparseMlp| -> f32 {
            m.layers
                .iter()
                .flat_map(|l| l.weights.values.iter())
                .fold(0.0f32, |acc, v| acc.max(v.abs()))
        };
        assert!(
            max_abs(&avg) <= max_abs(&a).max(max_abs(&b)) + 1e-6,
            "seed {seed}"
        );
        for (l, layer) in avg.layers.iter().enumerate() {
            assert!(layer.weights.nnz() <= targets[l], "seed {seed}");
        }
    }
}
