//! Pool-specific integration suite (DESIGN.md §9): barrier correctness
//! under reuse, clean shutdown, coordinator-style sub-pool nesting, and
//! the PR-4 acceptance pin — the steady-state training loop (forward,
//! fused backward, topology evolution) issues ZERO scoped-thread spawns
//! once the persistent pool is warm.
//!
//! Every test is `pool_`-prefixed so CI's wakeup-race stress job can
//! re-run exactly this surface 20× (`cargo test --release pool_ --
//! --test-threads=1`).
//!
//! NOTE: `pool_steady_state_train_loop_spawns_no_scoped_threads` asserts
//! a ZERO delta of the process-global scoped-dispatch counter, so no
//! other test in this binary may trigger a scoped (pool-less) sharded
//! dispatch — everything here dispatches on pools only.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use tsnn::model::SparseMlp;
use tsnn::nn::{Activation, MomentumSgd};
use tsnn::set::{EvolutionConfig, EvolutionEngine};
use tsnn::sparse::{ops, WeightInit, WorkerPool};
use tsnn::util::Rng;

mod common;
use common::thread_counts;

#[test]
fn pool_runs_every_shard_exactly_once_at_every_size() {
    for threads in thread_counts() {
        let pool = WorkerPool::new(threads);
        for &n in &[0usize, 1, 2, threads, 3 * threads + 1, 97] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, |s| {
                hits[s].fetch_add(1, Ordering::Relaxed);
            });
            for (s, h) in hits.iter().enumerate() {
                assert_eq!(
                    h.load(Ordering::Relaxed),
                    1,
                    "shard {s} of {n} (pool size {threads})"
                );
            }
        }
    }
}

#[test]
fn pool_reuse_across_hundreds_of_dispatches_stays_exact() {
    // same pool, alternating shard counts and shapes — the barrier must
    // not leak state between epochs (wakeup-race stress surface)
    let pool = WorkerPool::new(4);
    let mut total = 0usize;
    let sum = AtomicUsize::new(0);
    for round in 0..300 {
        let n = 2 + (round % 7);
        pool.run(n, |s| {
            sum.fetch_add(s + 1, Ordering::Relaxed);
        });
        total += (1..=n).sum::<usize>();
    }
    assert_eq!(sum.load(Ordering::Relaxed), total);
    assert_eq!(pool.dispatch_events(), 300);
}

#[test]
fn pool_drop_joins_workers_cleanly() {
    // churn pools (with and without intervening dispatches): every drop
    // must join its workers without hanging or panicking
    for i in 0..40 {
        let pool = WorkerPool::new(1 + (i % 5));
        if i % 2 == 0 {
            let n = AtomicUsize::new(0);
            pool.run(8, |_| {
                n.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(n.load(Ordering::Relaxed), 8);
        }
        drop(pool);
    }
}

#[test]
fn pool_nested_coordinator_subpools_do_not_deadlock() {
    // coordinator topology: K scoped data-parallel workers, each owning
    // a private kernel sub-pool (DESIGN.md §9.4), all dispatching at once
    std::thread::scope(|scope| {
        for k in 0..3 {
            scope.spawn(move || {
                let pool = WorkerPool::new(2);
                let mut rng = Rng::new(k as u64);
                let mlp = SparseMlp::new(
                    &[64, 128, 8],
                    8.0,
                    Activation::Relu,
                    &WeightInit::HeUniform,
                    &mut rng,
                )
                .unwrap();
                let mut ws = mlp.alloc_workspace(16);
                ws.kernel_threads = 2;
                let x: Vec<f32> = (0..16 * 64).map(|_| rng.normal()).collect();
                let y: Vec<u32> = (0..16).map(|i| (i % 8) as u32).collect();
                for _ in 0..50 {
                    pool.run(4, |_| std::hint::black_box(()));
                    let mut r = Rng::new(1);
                    mlp.compute_gradients(&x, &y, None, &mut ws, &mut r);
                }
            });
        }
    });
}

#[test]
fn pool_steady_state_train_loop_spawns_no_scoped_threads() {
    // PR-4 acceptance pin: all four sharded kernel entry points AND both
    // evolution passes dispatch through the shared pool — the warm
    // steady-state loop never moves the scoped-spawn counter.
    let mut rng = Rng::new(7);
    let mut mlp = SparseMlp::new(
        &[256, 512, 64, 10],
        30.0,
        Activation::AllRelu { alpha: 0.6 },
        &WeightInit::HeUniform,
        &mut rng,
    )
    .unwrap();
    let batch = 64;
    // the first hidden layer must clear even the old scoped crossover so
    // this loop genuinely exercises sharded dispatch, and the rebuild
    // must clear the pooled evolution crossover
    let nnz0 = mlp.layers[0].weights.nnz();
    assert!(batch * nnz0 >= ops::PAR_MIN_WORK, "nnz0 = {nnz0}");
    let x: Vec<f32> = (0..batch * 256).map(|_| rng.normal()).collect();
    let y: Vec<u32> = (0..batch).map(|i| (i % 10) as u32).collect();

    let mut ws = mlp.alloc_workspace(batch);
    ws.kernel_threads = 4;
    ws.ensure_pool();
    let pool = ws.pool().expect("multi-thread budget installs a pool");
    let mut evolver = EvolutionEngine::with_pool(Arc::clone(&pool));
    let opt = MomentumSgd::default();
    let evo = EvolutionConfig::default();

    // warm up: first dispatches, workspace sizing, engine buffers
    for _ in 0..2 {
        mlp.train_step(&x, &y, &opt, 0.01, None, &mut ws, &mut rng);
    }
    evolver.evolve_model(&mut mlp, &evo, &mut rng, 4).unwrap();

    let scoped_before = ops::scoped_dispatch_events();
    let pool_before = pool.dispatch_events();
    for _ in 0..3 {
        for _ in 0..2 {
            mlp.train_step(&x, &y, &opt, 0.01, None, &mut ws, &mut rng);
        }
        evolver.evolve_model(&mut mlp, &evo, &mut rng, 4).unwrap();
    }
    assert_eq!(
        ops::scoped_dispatch_events(),
        scoped_before,
        "steady-state train loop must not spawn scoped threads"
    );
    let pool_dispatches = pool.dispatch_events() - pool_before;
    // per step: forward shards layer 0 (and possibly layer 1) + fused
    // backward ditto; per evolution: the layer pass + heavy rebuilds —
    // at minimum the 6 train steps and 3 evolution layer passes all hit
    // the pool
    assert!(
        pool_dispatches >= 6 + 3,
        "expected the hot loop on the pool, saw {pool_dispatches} dispatches"
    );
}

#[test]
fn pool_kernel_threads_env_budget_is_exercised() {
    // KERNEL_THREADS pins thread_counts(); make sure the pinned budget
    // builds a working pool (CI sweeps 1/4/8)
    for threads in thread_counts() {
        let pool = WorkerPool::new(threads);
        assert_eq!(pool.threads(), ops::resolve_threads(threads));
        let n = AtomicUsize::new(0);
        pool.run(2 * threads, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 2 * threads);
    }
}
