//! Helpers shared by the parity suites (`kernel_parity.rs`,
//! `evolution_parity.rs`).

/// Thread grid for the parity suites: the built-in {1, 2, 8} by
/// default, or — when the `KERNEL_THREADS` environment variable is set —
/// exactly that single thread count, so CI can pin every parity
/// assertion to one budget (it sweeps 1 and 8 on top of the default
/// unpinned run).
pub fn thread_counts() -> Vec<usize> {
    if let Ok(s) = std::env::var("KERNEL_THREADS") {
        if let Ok(t) = s.trim().parse::<usize>() {
            if t >= 1 {
                return vec![t];
            }
        }
    }
    vec![1, 2, 8]
}
