//! Zero-allocation gate for the pool's dispatch path (DESIGN.md §9): a
//! warm [`WorkerPool`] must serve ≥ 100 scatter-gather dispatches
//! without a single heap allocation — the growth-counter pattern of the
//! evolution engine (PR 3), applied at the allocator level because the
//! pool owns no growable buffers to count.
//!
//! Lives in its own integration binary so the process-global counting
//! allocator sees no concurrent allocations from unrelated tests (this
//! file deliberately contains exactly one test).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use tsnn::sparse::WorkerPool;

/// System allocator with a process-global allocation-event counter.
struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

// SAFETY: defers to the System allocator for every operation.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static COUNTING_ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn pool_dispatch_allocates_nothing_after_warmup() {
    let pool = WorkerPool::new(4);
    let hits: Vec<AtomicUsize> = (0..16).map(|_| AtomicUsize::new(0)).collect();
    // warm up: worker stacks, lazy TLS, condvar internals
    for _ in 0..20 {
        pool.run(16, |s| {
            hits[s].fetch_add(1, Ordering::Relaxed);
        });
    }
    let before = ALLOC_EVENTS.load(Ordering::SeqCst);
    for _ in 0..150 {
        pool.run(16, |s| {
            hits[s].fetch_add(1, Ordering::Relaxed);
        });
    }
    let grown = ALLOC_EVENTS.load(Ordering::SeqCst) - before;
    assert_eq!(
        grown, 0,
        "warm pool dispatch must be allocation-free (saw {grown} allocation events \
         across 150 dispatches)"
    );
    // and the dispatches really ran
    assert_eq!(hits[0].load(Ordering::Relaxed), 170);
}
