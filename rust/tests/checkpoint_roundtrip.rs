//! Property tests for the `TSNN` checkpoint format: save→load→save is
//! byte-identical and load→model is bit-identical across random shapes,
//! densities and empty-row edges; malformed files — truncated at every
//! boundary, garbage magic, wrong version, corrupt header lengths —
//! come back as typed [`TsnnError`]s, never a panic or an OOM attempt.

use std::path::PathBuf;

use tsnn::error::TsnnError;
use tsnn::model::{checkpoint, SparseLayer, SparseMlp};
use tsnn::nn::Activation;
use tsnn::sparse::{erdos_renyi, CsrMatrix, WeightInit};
use tsnn::util::Rng;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("tsnn_ckpt_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Random model: 2–4 layers, widths 1–40, per-layer density 0–1, all
/// activation kinds, non-trivial bias/velocity state.
fn random_model(rng: &mut Rng) -> SparseMlp {
    let n_layers = 2 + rng.below(3) as usize;
    let sizes: Vec<usize> = (0..=n_layers).map(|_| 1 + rng.below(40) as usize).collect();
    let layers = (0..n_layers)
        .map(|l| {
            let density = rng.f64();
            let weights = erdos_renyi(
                sizes[l],
                sizes[l + 1],
                density,
                rng,
                &WeightInit::Normal(0.5),
            );
            let activation = match rng.below(4) {
                0 => Activation::Relu,
                1 => Activation::LeakyRelu { alpha: 0.25 },
                2 => Activation::AllRelu { alpha: 0.75 },
                _ => Activation::Linear,
            };
            let n_out = sizes[l + 1];
            SparseLayer {
                bias: (0..n_out).map(|_| rng.normal()).collect(),
                velocity: (0..weights.nnz()).map(|_| rng.normal()).collect(),
                bias_velocity: (0..n_out).map(|_| rng.normal()).collect(),
                weights,
                activation,
                srelu: None,
            }
        })
        .collect();
    SparseMlp { sizes, layers }
}

fn assert_models_bit_identical(a: &SparseMlp, b: &SparseMlp) {
    assert_eq!(a.sizes, b.sizes);
    assert_eq!(a.layers.len(), b.layers.len());
    for (la, lb) in a.layers.iter().zip(b.layers.iter()) {
        assert_eq!(la.weights, lb.weights);
        assert_eq!(la.bias, lb.bias);
        assert_eq!(la.velocity, lb.velocity);
        assert_eq!(la.bias_velocity, lb.bias_velocity);
        assert_eq!(la.activation, lb.activation);
    }
}

#[test]
fn save_load_save_is_byte_identical_across_random_models() {
    let mut rng = Rng::new(424242);
    for case in 0..20 {
        let model = random_model(&mut rng);
        let p1 = tmp(&format!("prop_{case}_a.tsnn"));
        let p2 = tmp(&format!("prop_{case}_b.tsnn"));
        checkpoint::save(&model, &p1).unwrap();
        let loaded = checkpoint::load(&p1).unwrap();
        assert_models_bit_identical(&model, &loaded);
        checkpoint::save(&loaded, &p2).unwrap();
        let bytes1 = std::fs::read(&p1).unwrap();
        let bytes2 = std::fs::read(&p2).unwrap();
        assert_eq!(bytes1, bytes2, "case {case}: save→load→save must be byte-identical");
        std::fs::remove_file(&p1).unwrap();
        std::fs::remove_file(&p2).unwrap();
    }
}

#[test]
fn empty_rows_and_empty_layers_roundtrip() {
    // hand-built topology: populated, empty, populated rows — plus a
    // second layer with zero connections at all
    let w0 = CsrMatrix {
        n_rows: 3,
        n_cols: 4,
        row_ptr: vec![0, 2, 2, 3].into(),
        col_idx: vec![0, 3, 1].into(),
        values: vec![1.5, -2.5, 0.5].into(),
    };
    w0.validate().unwrap();
    let w1 = CsrMatrix::empty(4, 2);
    let model = SparseMlp {
        sizes: vec![3, 4, 2],
        layers: vec![
            SparseLayer {
                bias: vec![0.1, 0.2, 0.3, 0.4],
                velocity: vec![0.0; 3].into(),
                bias_velocity: vec![0.0; 4],
                weights: w0,
                activation: Activation::Relu,
                srelu: None,
            },
            SparseLayer {
                bias: vec![-1.0, 1.0],
                velocity: Vec::new().into(),
                bias_velocity: vec![0.0, 0.0],
                weights: w1,
                activation: Activation::Linear,
                srelu: None,
            },
        ],
    };
    let p = tmp("empty_rows.tsnn");
    checkpoint::save(&model, &p).unwrap();
    let loaded = checkpoint::load(&p).unwrap();
    std::fs::remove_file(&p).unwrap();
    assert_models_bit_identical(&model, &loaded);
    assert_eq!(loaded.layers[1].weights.nnz(), 0);
}

#[test]
fn truncation_at_every_boundary_is_a_typed_error() {
    let mut rng = Rng::new(7);
    let model = random_model(&mut rng);
    let p = tmp("trunc_src.tsnn");
    checkpoint::save(&model, &p).unwrap();
    let full = std::fs::read(&p).unwrap();
    std::fs::remove_file(&p).unwrap();
    // structural boundaries plus a sweep of interior cuts
    let mut cuts = vec![0usize, 1, 3, 4, 7, 8, 11, 12];
    for f in 1..8 {
        cuts.push(full.len() * f / 8);
    }
    cuts.push(full.len() - 1);
    let pt = tmp("trunc.tsnn");
    for &cut in &cuts {
        if cut >= full.len() {
            continue;
        }
        std::fs::write(&pt, &full[..cut]).unwrap();
        match checkpoint::load(&pt) {
            // pre-header cuts die in Io/Checkpoint; any cut past the
            // version field breaks the CRC-32 trailer first
            Err(TsnnError::Io(_))
            | Err(TsnnError::Checkpoint(_))
            | Err(TsnnError::ChecksumMismatch(_)) => {}
            Err(other) => panic!("cut {cut}: unexpected error kind {other}"),
            Ok(_) => panic!("cut {cut}: truncated checkpoint must not load"),
        }
    }
    std::fs::remove_file(&pt).unwrap();
}

#[test]
fn garbage_magic_is_a_checkpoint_error() {
    let mut rng = Rng::new(8);
    let model = random_model(&mut rng);
    let p = tmp("magic.tsnn");
    checkpoint::save(&model, &p).unwrap();
    let mut bytes = std::fs::read(&p).unwrap();
    bytes[0..4].copy_from_slice(b"XSNN");
    std::fs::write(&p, &bytes).unwrap();
    let err = checkpoint::load(&p).unwrap_err();
    std::fs::remove_file(&p).unwrap();
    match err {
        TsnnError::Checkpoint(m) => assert!(m.contains("bad magic"), "{m}"),
        other => panic!("expected Checkpoint error, got {other}"),
    }
}

#[test]
fn wrong_version_is_a_checkpoint_error() {
    let mut rng = Rng::new(9);
    let model = random_model(&mut rng);
    let p = tmp("version.tsnn");
    checkpoint::save(&model, &p).unwrap();
    let mut bytes = std::fs::read(&p).unwrap();
    bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
    std::fs::write(&p, &bytes).unwrap();
    let err = checkpoint::load(&p).unwrap_err();
    std::fs::remove_file(&p).unwrap();
    match err {
        TsnnError::Checkpoint(m) => assert!(m.contains("unsupported version 99"), "{m}"),
        other => panic!("expected Checkpoint error, got {other}"),
    }
}

#[test]
fn implausible_header_length_fails_without_allocating() {
    // magic + version + a 4 GiB header length and nothing else: the
    // loader must refuse before trying to allocate the claimed header
    let p = tmp("hlen.tsnn");
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"TSNN");
    bytes.extend_from_slice(&1u32.to_le_bytes());
    bytes.extend_from_slice(&u32::MAX.to_le_bytes());
    std::fs::write(&p, &bytes).unwrap();
    let err = checkpoint::load(&p).unwrap_err();
    std::fs::remove_file(&p).unwrap();
    match err {
        TsnnError::Checkpoint(m) => assert!(m.contains("implausible header length"), "{m}"),
        other => panic!("expected Checkpoint error, got {other}"),
    }
}

#[test]
fn corrupt_header_nnz_fails_without_allocating() {
    // a header whose nnz exceeds n_in × n_out must be refused before
    // the bulk-array reads size their buffers from it
    let mut rng = Rng::new(10);
    let model = random_model(&mut rng);
    let p = tmp("nnz.tsnn");
    checkpoint::save(&model, &p).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    let hlen = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
    let header = String::from_utf8(bytes[12..12 + hlen].to_vec()).unwrap();
    // inflate the first nnz entry beyond any plausible dense bound
    // (in place — the array length must stay consistent so the nnz
    // guard, not the header-shape check, is what fires)
    let key = "\"nnz\":[";
    let start = header.find(key).expect("header carries nnz") + key.len();
    let end = start
        + header[start..]
            .find([',', ']'])
            .expect("nnz array is non-empty");
    let corrupted = format!("{}99999999{}", &header[..start], &header[end..]);
    let mut out = Vec::new();
    out.extend_from_slice(&bytes[..8]);
    out.extend_from_slice(&(corrupted.len() as u32).to_le_bytes());
    out.extend_from_slice(corrupted.as_bytes());
    out.extend_from_slice(&bytes[12 + hlen..]);
    // re-seal the CRC-32 trailer so the nnz guard, not the integrity
    // check, is what rejects the file
    let body_end = out.len() - 4;
    let crc = tsnn::util::crc::crc32(&out[..body_end]).to_le_bytes();
    out[body_end..].copy_from_slice(&crc);
    std::fs::write(&p, &out).unwrap();
    let err = checkpoint::load(&p).unwrap_err();
    std::fs::remove_file(&p).unwrap();
    match err {
        TsnnError::Checkpoint(m) => assert!(m.contains("exceeds"), "{m}"),
        other => panic!("expected Checkpoint error, got {other}"),
    }
}
