//! Zero-allocation gate for the latency-recording hot path
//! (DESIGN.md §10.3): once its fixed window is allocated, a
//! [`LatencyRecorder`] must absorb an unbounded stream of `record`
//! calls — fills, ring wraps, counter bumps — without a single heap
//! allocation, in the style of `pool_alloc.rs`.
//!
//! Lives in its own integration binary so the process-global counting
//! allocator sees no concurrent allocations from unrelated tests (this
//! file deliberately contains exactly one test).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use tsnn::serve::LatencyRecorder;

/// System allocator with a process-global allocation-event counter.
struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

// SAFETY: defers to the System allocator for every operation.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static COUNTING_ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn latency_recording_allocates_nothing_after_construction() {
    let mut rec = LatencyRecorder::with_capacity(4096);
    // construction reserved the whole window up front; from here on the
    // hot path must be allocation-free — through the initial fill, the
    // ring wrap, and a clear+refill cycle
    let before = ALLOC_EVENTS.load(Ordering::SeqCst);
    for i in 0..100_000u64 {
        rec.record(i * 37 % 10_000);
    }
    rec.clear();
    for i in 0..10_000u64 {
        rec.record(i);
    }
    let grown = ALLOC_EVENTS.load(Ordering::SeqCst) - before;
    assert_eq!(
        grown, 0,
        "latency recording must be allocation-free after construction \
         (saw {grown} allocation events across 110k records)"
    );
    // and the recording really happened
    assert_eq!(rec.count(), 10_000);
    assert_eq!(rec.percentile(100.0), Some(9_999));
}
