//! Coordinator transport layer: the wire format, the `Transport` /
//! `Listener` traits, a retrying request/response client, and the two
//! implementations (in-process channels, Unix/TCP sockets) plus a
//! deterministic fault injector.
//!
//! All phase-1 (gradient push / model fetch) and phase-2 (replica
//! upload) traffic flows through these traits, so "multi-node" means
//! "write a transport", not "rewrite the coordinator". DESIGN.md §12
//! documents the protocol; the short version:
//!
//! * every request carries a per-connection monotonic `seq`; retransmits
//!   repeat it, and the server caches its last reply per connection so a
//!   retried request is answered idempotently — lost or duplicated
//!   frames never duplicate a gradient application;
//! * a worker that disconnects (or sends `Leave`) is removed from the
//!   active set; the run finishes when every worker that ever joined has
//!   left, so worker churn degrades capacity, not correctness.

pub mod channel;
pub mod fault;
pub mod service;
pub mod socket;
pub mod wire;
pub mod worker;

use std::time::{Duration, Instant};

use crate::config::{DatasetSpec, TrainConfig};
use crate::error::{Result, TsnnError};
use crate::model::SparseMlp;
use crate::util::json::{self, Json};

use super::ParallelConfig;
use wire::{FetchAck, Message, PushMsg, PushStatus};

/// One direction of a worker↔coordinator link (worker side).
///
/// `send` ships one encoded frame; `recv` returns the next inbound frame,
/// `Ok(None)` on timeout, `Err` when the peer is gone for good.
pub trait Transport: Send {
    /// Send one encoded frame.
    fn send(&mut self, frame: &[u8]) -> Result<()>;
    /// Receive the next frame, waiting at most `timeout`.
    fn recv(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>>;
}

/// Inbound event on the coordinator side of a connection.
#[derive(Debug)]
pub enum Inbound {
    /// A frame arrived.
    Frame(Vec<u8>),
    /// The connection closed (worker process died or hung up) — an
    /// implicit leave.
    Closed,
}

/// Coordinator side: a multiplexed set of worker connections keyed by a
/// transport-assigned connection id.
pub trait Listener: Send {
    /// Next inbound event from any connection; `Ok(None)` on timeout.
    fn recv(&mut self, timeout: Duration) -> Result<Option<(u64, Inbound)>>;
    /// Send a frame to one connection. Sending to a dead connection is
    /// not an error (the `Closed` event is the authoritative signal).
    fn send(&mut self, conn: u64, frame: &[u8]) -> Result<()>;
}

/// Per-frame timeout + bounded retry with multiplicative backoff.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// First-attempt reply timeout.
    pub timeout: Duration,
    /// Retransmits after the first attempt.
    pub retries: u32,
    /// Timeout multiplier per retry.
    pub backoff: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            timeout: Duration::from_secs(2),
            retries: 8,
            backoff: 1.5,
        }
    }
}

/// Request/response client over any [`Transport`].
///
/// Each logical request gets a fresh `seq`; a retransmit repeats the
/// same bytes, and replies tagged with an older seq (stale duplicates)
/// or failing to decode (injected corruption) are discarded while the
/// attempt's deadline runs down.
pub struct Client {
    t: Box<dyn Transport>,
    policy: RetryPolicy,
    worker: u32,
    seq: u64,
    /// Retransmits performed over the client's lifetime.
    pub retries: u64,
}

impl Client {
    /// Wrap a transport for the given worker id.
    pub fn new(t: Box<dyn Transport>, worker: u32, policy: RetryPolicy) -> Client {
        Client {
            t,
            policy,
            worker,
            seq: 0,
            retries: 0,
        }
    }

    /// Send `msg` and wait for its reply, retransmitting per the policy.
    pub fn request(&mut self, msg: &Message) -> Result<Message> {
        self.seq += 1;
        let seq = self.seq;
        let frame = wire::encode_frame(self.worker, seq, msg);
        let mut timeout = self.policy.timeout;
        for attempt in 0..=self.policy.retries {
            if attempt > 0 {
                self.retries += 1;
            }
            self.t.send(&frame)?;
            let deadline = Instant::now() + timeout;
            loop {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let Some(raw) = self.t.recv(deadline - now)? else {
                    break;
                };
                let (h, reply) = match wire::decode_frame(&raw) {
                    Ok(x) => x,
                    // corrupt reply (injected truncation): keep waiting,
                    // the retransmit path will recover
                    Err(_) => continue,
                };
                if h.seq < seq {
                    // stale duplicate of an earlier reply
                    continue;
                }
                if h.seq > seq {
                    return Err(TsnnError::Transport(format!(
                        "reply seq {} ahead of request seq {seq}",
                        h.seq
                    )));
                }
                if let Message::Err { message } = reply {
                    return Err(TsnnError::Transport(message));
                }
                return Ok(reply);
            }
            timeout = timeout.mul_f64(self.policy.backoff);
        }
        Err(TsnnError::Transport(format!(
            "worker {}: no reply after {} attempts",
            self.worker,
            self.policy.retries + 1
        )))
    }

    /// Join the run; returns the coordinator's join reply: the job spec
    /// (if any) plus the rejoin cursor (`resume_pushes`, `resume_step`)
    /// a respawned worker needs to fast-forward its streams.
    pub fn join(&mut self) -> Result<JoinReply> {
        match self.request(&Message::Join)? {
            Message::JoinAck {
                job,
                resume_pushes,
                resume_step,
            } => Ok(JoinReply {
                job,
                resume_pushes,
                resume_step,
            }),
            other => Err(unexpected("JoinAck", &other)),
        }
    }

    /// Liveness heartbeat (phase-2 workers, which otherwise go silent
    /// while training locally).
    pub fn ping(&mut self) -> Result<()> {
        match self.request(&Message::Ping)? {
            Message::Pong => Ok(()),
            other => Err(unexpected("Pong", &other)),
        }
    }

    /// Fetch a model snapshot.
    pub fn fetch(&mut self, have_gen: u64, have_step: u64) -> Result<FetchAck> {
        match self.request(&Message::Fetch { have_gen, have_step })? {
            Message::FetchAck(f) => Ok(f),
            other => Err(unexpected("FetchAck", &other)),
        }
    }

    /// Push a gradient; returns `(status, server_step, server_epoch)`.
    pub fn push(&mut self, p: PushMsg) -> Result<(PushStatus, u64, u64)> {
        match self.request(&Message::Push(p))? {
            Message::PushAck { status, step, epoch } => Ok((status, step, epoch)),
            other => Err(unexpected("PushAck", &other)),
        }
    }

    /// Upload a phase-2 replica.
    pub fn replica(&mut self, model: &SparseMlp) -> Result<()> {
        match self.request(&Message::Replica {
            model: model.clone(),
        })? {
            Message::ReplicaAck => Ok(()),
            other => Err(unexpected("ReplicaAck", &other)),
        }
    }

    /// Leave the run.
    pub fn leave(&mut self) -> Result<()> {
        match self.request(&Message::Leave)? {
            Message::LeaveAck => Ok(()),
            other => Err(unexpected("LeaveAck", &other)),
        }
    }
}

fn unexpected(want: &str, got: &Message) -> TsnnError {
    TsnnError::Transport(format!("expected {want}, got {got:?}"))
}

/// Decoded `JoinAck`: the job spec plus the rejoin cursor.
#[derive(Debug, Clone)]
pub struct JoinReply {
    /// JSON job spec for external workers (`None` in-process).
    pub job: Option<String>,
    /// Phase-1 batches already applied under this worker id (0 on a
    /// first join) — the fast-forward count for a respawned worker.
    pub resume_pushes: u64,
    /// Step a parked synchronous contribution is waiting at
    /// ([`wire::NONE_U64`] = none).
    pub resume_step: u64,
}

/// Everything an external worker process needs to reproduce its shard of
/// the run: the full training config (as `key=value` text), the dataset
/// spec (workers regenerate the dataset deterministically from the
/// seed), the parallel config, and per-worker kernel-thread budgets.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// `TrainConfig::dump_kv` output.
    pub config_kv: String,
    /// Dataset to regenerate.
    pub dataset: DatasetSpec,
    /// Parallel run shape.
    pub pcfg: ParallelConfig,
    /// Kernel-thread budget per worker id.
    pub budgets: Vec<usize>,
}

impl JobSpec {
    /// Build from run inputs.
    pub fn new(
        cfg: &TrainConfig,
        dataset: &DatasetSpec,
        pcfg: &ParallelConfig,
        budgets: Vec<usize>,
    ) -> JobSpec {
        JobSpec {
            config_kv: cfg.dump_kv(),
            dataset: dataset.clone(),
            pcfg: pcfg.clone(),
            budgets,
        }
    }

    /// Serialize to the JSON carried in `JoinAck`.
    pub fn to_json(&self) -> String {
        json::obj(vec![
            ("config", Json::Str(self.config_kv.clone())),
            (
                "dataset",
                json::obj(vec![
                    ("name", Json::Str(self.dataset.name.clone())),
                    ("generator", Json::Str(self.dataset.generator.clone())),
                    ("n_features", Json::from(self.dataset.n_features)),
                    ("n_classes", Json::from(self.dataset.n_classes)),
                    ("n_train", Json::from(self.dataset.n_train)),
                    ("n_test", Json::from(self.dataset.n_test)),
                ]),
            ),
            (
                "parallel",
                json::obj(vec![
                    ("workers", Json::from(self.pcfg.workers)),
                    ("phase1_epochs", Json::from(self.pcfg.phase1_epochs)),
                    ("phase2_epochs", Json::from(self.pcfg.phase2_epochs)),
                    ("synchronous", Json::from(self.pcfg.synchronous)),
                    ("hot_start", Json::from(self.pcfg.hot_start)),
                    ("grad_clip", Json::from(f64::from(self.pcfg.grad_clip))),
                ]),
            ),
            (
                "budgets",
                Json::Arr(self.budgets.iter().map(|&b| Json::from(b)).collect()),
            ),
        ])
        .dump()
    }

    /// Parse the `JoinAck` job JSON.
    pub fn from_json(text: &str) -> Result<JobSpec> {
        let bad = |m: &str| TsnnError::Transport(format!("job spec: {m}"));
        let j = json::parse(text).map_err(|e| bad(&e))?;
        let config_kv = j
            .get("config")
            .and_then(|v| v.as_str())
            .ok_or_else(|| bad("missing config"))?
            .to_string();
        let d = j.get("dataset").ok_or_else(|| bad("missing dataset"))?;
        let field = |v: Option<usize>, name: &str| {
            v.ok_or_else(|| bad(&format!("missing dataset.{name}")))
        };
        let dataset = DatasetSpec {
            name: d
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| bad("missing dataset.name"))?
                .to_string(),
            generator: d
                .get("generator")
                .and_then(|v| v.as_str())
                .ok_or_else(|| bad("missing dataset.generator"))?
                .to_string(),
            n_features: field(d.get("n_features").and_then(|v| v.as_usize()), "n_features")?,
            n_classes: field(d.get("n_classes").and_then(|v| v.as_usize()), "n_classes")?,
            n_train: field(d.get("n_train").and_then(|v| v.as_usize()), "n_train")?,
            n_test: field(d.get("n_test").and_then(|v| v.as_usize()), "n_test")?,
        };
        let p = j.get("parallel").ok_or_else(|| bad("missing parallel"))?;
        let pfield = |v: Option<usize>, name: &str| {
            v.ok_or_else(|| bad(&format!("missing parallel.{name}")))
        };
        let pcfg = ParallelConfig {
            workers: pfield(p.get("workers").and_then(|v| v.as_usize()), "workers")?,
            phase1_epochs: pfield(
                p.get("phase1_epochs").and_then(|v| v.as_usize()),
                "phase1_epochs",
            )?,
            phase2_epochs: pfield(
                p.get("phase2_epochs").and_then(|v| v.as_usize()),
                "phase2_epochs",
            )?,
            synchronous: p
                .get("synchronous")
                .and_then(|v| v.as_bool())
                .ok_or_else(|| bad("missing parallel.synchronous"))?,
            hot_start: p
                .get("hot_start")
                .and_then(|v| v.as_bool())
                .ok_or_else(|| bad("missing parallel.hot_start"))?,
            grad_clip: p
                .get("grad_clip")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| bad("missing parallel.grad_clip"))? as f32,
        };
        let budgets: Vec<usize> = j
            .get("budgets")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| bad("missing budgets"))?
            .iter()
            .filter_map(|v| v.as_usize())
            .collect();
        Ok(JobSpec {
            config_kv,
            dataset,
            pcfg,
            budgets,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_spec_roundtrips() {
        let cfg = TrainConfig::small_preset("madelon");
        let spec = DatasetSpec::small("madelon");
        let pcfg = ParallelConfig {
            workers: 3,
            phase1_epochs: 8,
            phase2_epochs: 2,
            synchronous: true,
            hot_start: false,
            grad_clip: 5.0,
        };
        let job = JobSpec::new(&cfg, &spec, &pcfg, vec![2, 1, 1]);
        let parsed = JobSpec::from_json(&job.to_json()).unwrap();
        assert_eq!(parsed.config_kv, cfg.dump_kv());
        assert_eq!(parsed.dataset.n_features, 500);
        assert_eq!(parsed.pcfg.workers, 3);
        assert!(parsed.pcfg.synchronous);
        assert_eq!(parsed.pcfg.grad_clip, 5.0);
        assert_eq!(parsed.budgets, vec![2, 1, 1]);

        let mut back = TrainConfig::default();
        back.apply_file(&parsed.config_kv).unwrap();
        assert_eq!(back.dump_kv(), cfg.dump_kv());

        assert!(JobSpec::from_json("{}").is_err());
        assert!(JobSpec::from_json("not json").is_err());
    }
}
