//! The worker side of the transport protocol: one `run_worker` call is
//! one full WASAP/WASSP worker lifetime — join, phase-1 fetch/push loop,
//! phase-2 local training + replica upload, leave.
//!
//! The loop mirrors the original thread-coordinator semantics exactly
//! (same RNG streams, same batch order, same clip-then-push) so an
//! in-process channel run is bit-identical to the pre-transport
//! coordinator, and a multi-process socket run differs only by async
//! scheduling.

use crate::config::TrainConfig;
use crate::coordinator::{clip_gradients, shard_bounds, shard_dataset, ParallelConfig};
use crate::data::Dataset;
use crate::error::{Result, TsnnError};
use crate::model::{Batcher, SparseMlp};
use crate::nn::LrSchedule;
use crate::train::{self, HookAction, TrainOptions};
use crate::util::{PhaseTimes, Rng};

use super::wire::{ModelDelta, PushMsg, PushStatus, NONE_U64};
use super::{Client, JoinReply, RetryPolicy, Transport};

/// Everything a worker needs to run its shard of a parallel job.
#[derive(Debug, Clone)]
pub struct WorkerJob {
    /// This worker's id (also its shard index), `< pcfg.workers`.
    pub worker: u32,
    /// Kernel threads for this worker's workspace sub-pool.
    pub kernel_threads: usize,
    /// Training configuration (shared across the job).
    pub cfg: TrainConfig,
    /// Parallel configuration (shared across the job).
    pub pcfg: ParallelConfig,
    /// Leave after this many applied pushes (elasticity tests).
    pub max_phase1_pushes: Option<u64>,
    /// Leave after phase 1 without training/uploading a replica.
    pub skip_phase2: bool,
}

impl WorkerJob {
    /// Job for worker `k` of a run, with its kernel budget.
    pub fn new(
        worker: u32,
        kernel_threads: usize,
        cfg: &TrainConfig,
        pcfg: &ParallelConfig,
    ) -> WorkerJob {
        WorkerJob {
            worker,
            kernel_threads,
            cfg: cfg.clone(),
            pcfg: *pcfg,
            max_phase1_pushes: None,
            skip_phase2: false,
        }
    }
}

/// What one worker did.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerReport {
    /// Gradient pushes the server applied.
    pub pushes: u64,
    /// Request retransmissions (timeouts / dropped replies).
    pub retries: u64,
    /// Gradients zeroed worker-side because their norm was non-finite.
    pub zeroed_nonfinite: u64,
}

/// Join and run a full worker lifetime over `transport`.
pub fn run_worker(
    transport: Box<dyn Transport>,
    retry: RetryPolicy,
    job: &WorkerJob,
    data: &Dataset,
) -> Result<WorkerReport> {
    let mut client = Client::new(transport, job.worker, retry);
    let reply = client.join()?;
    run_worker_joined(&mut client, job, data, &reply)
}

/// Run a worker lifetime on an already-joined client (the `tsnn worker`
/// subcommand joins first to obtain the job spec, then calls this).
/// `reply` is the join acknowledgement: its resume cursor is zero for a
/// first join and positions a supervisor-respawned worker back onto the
/// exact trajectory of its crashed predecessor (DESIGN.md §13.4).
pub fn run_worker_joined(
    client: &mut Client,
    job: &WorkerJob,
    data: &Dataset,
    reply: &JoinReply,
) -> Result<WorkerReport> {
    let cfg = &job.cfg;
    let sync = job.pcfg.synchronous;
    let mut report = WorkerReport::default();

    // identical RNG/batcher streams to the thread coordinator
    let mut rng = Rng::new(cfg.seed).split(job.worker as u64);
    let (lo, hi) = shard_bounds(data.n_train(), job.pcfg.workers, job.worker as usize);
    let mut batcher = Batcher::shard(data.n_train(), data.n_features, cfg.batch, lo, hi);
    batcher.reset(&mut rng);
    let dropout = if cfg.dropout > 0.0 {
        Some(crate::nn::Dropout::new(cfg.dropout))
    } else {
        None
    };
    let mut ws = crate::model::Workspace::with_threads(job.kernel_threads);
    // WASAP hot-start (paper §2.3); WASSP's warmup schedule lives
    // server-side so every contributor of a step shares one rate
    let schedule = match cfg.lr {
        LrSchedule::Constant(eta) if job.pcfg.hot_start && !sync => LrSchedule::HotStart {
            hot: eta * 2.0,
            base: eta,
            hot_epochs: 3,
        },
        other => other,
    };

    // ---- rejoin fast-forward ----
    // The server counted `resume_pushes` of this id's batches before the
    // predecessor process died. Gradient computation is deterministic
    // given (server snapshot, batch), and the server state only reflects
    // pushes it actually saw — so replaying exactly the counted batches
    // (data draws + dropout draws) puts this process's streams where the
    // predecessor's next iteration would have been, and anything it
    // computed but never delivered is simply recomputed.
    let sizes = cfg.sizes(data.n_features, data.n_classes);
    for _ in 0..reply.resume_pushes {
        let rows = match batcher.next_batch(&data.x_train, &data.y_train) {
            Some((_, y)) => y.len(),
            None => {
                batcher.reset(&mut rng);
                let (_, y) = batcher.next_batch(&data.x_train, &data.y_train).unwrap();
                y.len()
            }
        };
        if let Some(d) = &dropout {
            // forward() draws one bernoulli per hidden activation
            for l in 0..sizes.len().saturating_sub(2) {
                for _ in 0..rows * sizes[l + 1] {
                    rng.bernoulli(d.rate as f64);
                }
            }
        }
    }

    // ---- phase 1: fetch / compute / push ----
    let mut cached: Option<(SparseMlp, u64)> = None;
    // a parked sync contribution means our first fetch must wait at the
    // step it was stored for, exactly like the predecessor's would have
    let mut last_step = reply.resume_step;
    let phase1_model: SparseMlp = loop {
        let have_gen = cached.as_ref().map_or(NONE_U64, |(_, g)| *g);
        // synchronous workers report the step they last trained on; the
        // server parks the fetch until the barrier advances past it
        let have_step = if sync { last_step } else { NONE_U64 };
        let ack = client.fetch(have_gen, have_step)?;
        if ack.phase2 {
            match ack.delta {
                ModelDelta::Full { model, .. } => break model,
                ModelDelta::Values { .. } => {
                    return Err(TsnnError::Transport(
                        "phase-2 fetch must carry a full model".into(),
                    ))
                }
            }
        }
        match ack.delta {
            ModelDelta::Full { model, .. } => cached = Some((model, ack.gen)),
            ModelDelta::Values { values, bias } => {
                let ok = cached.as_ref().is_some_and(|(m, _)| {
                    values.len() == m.layers.len()
                        && bias.len() == m.layers.len()
                        && m.layers.iter().enumerate().all(|(l, layer)| {
                            values[l].len() == layer.weights.values.len()
                                && bias[l].len() == layer.bias.len()
                        })
                });
                if !ok {
                    // topology moved under us without a gen bump (or the
                    // cache is gone): drop it and re-fetch a full model
                    cached = None;
                    continue;
                }
                let (m, g) = cached.as_mut().expect("checked above");
                for (l, layer) in m.layers.iter_mut().enumerate() {
                    layer.weights.values.copy_from_slice(&values[l]);
                    layer.bias.copy_from_slice(&bias[l]);
                }
                *g = ack.gen;
            }
        }
        last_step = ack.step;
        let (model, gen) = cached.as_ref().expect("set above");

        let batch = match batcher.next_batch(&data.x_train, &data.y_train) {
            Some(b) => b,
            None => {
                batcher.reset(&mut rng);
                batcher.next_batch(&data.x_train, &data.y_train).unwrap()
            }
        };
        model.compute_gradients(batch.0, batch.1, dropout.as_ref(), &mut ws, &mut rng);
        let mut grad_w = ws.grad_w.clone();
        let mut grad_b = ws.grad_b.clone();
        let lr = if sync {
            0.0 // server-side warmup schedule decides; raw gradients travel
        } else {
            if clip_gradients(&mut grad_w, &mut grad_b, job.pcfg.grad_clip) {
                report.zeroed_nonfinite += 1;
            }
            schedule.at(ack.epoch as usize)
        };
        let (status, _, _) = client.push(PushMsg {
            gen: *gen,
            fetched_step: ack.step,
            lr,
            sync,
            grad_w,
            grad_b,
        })?;
        match status {
            PushStatus::Applied => report.pushes += 1,
            PushStatus::Ignored => {} // raced the phase boundary; next fetch says phase 2
            PushStatus::RejectedNonFinite => {} // server-side guard fired
            PushStatus::RejectedStaleGen => cached = None, // fell out of the topology ring
            PushStatus::RejectedShape => {
                return Err(TsnnError::Transport(
                    "server rejected gradient shape — worker/server topology diverged".into(),
                ))
            }
        }
        if let Some(max) = job.max_phase1_pushes {
            if report.pushes >= max {
                client.leave()?;
                report.retries = client.retries;
                return Ok(report);
            }
        }
    };

    // ---- phase 2: local training, replica upload ----
    if job.skip_phase2 || job.pcfg.phase2_epochs == 0 {
        client.leave()?;
        report.retries = client.retries;
        return Ok(report);
    }
    let mut local_cfg = cfg.clone();
    local_cfg.epochs = job.pcfg.phase2_epochs;
    local_cfg.eval_every = 0;
    local_cfg.kernel_threads = job.kernel_threads;
    let mut local_model = phase1_model;
    let mut local_rng = Rng::new(cfg.seed).split(1000 + job.worker as u64);
    let shard = shard_dataset(data, lo, hi);
    let mut local_phases = PhaseTimes::new();
    // phase 2 is local: heartbeat once per epoch so a supervised
    // coordinator can tell "training" from "dead" during the silence
    let mut ping_err: Option<crate::error::TsnnError> = None;
    {
        let client_ref = &mut *client;
        let mut heartbeat = |_epoch: usize, _m: &SparseMlp| match client_ref.ping() {
            Ok(()) => HookAction::Continue,
            Err(e) => {
                ping_err = Some(e);
                HookAction::Stop
            }
        };
        train::train_model_hooked(
            &local_cfg,
            &shard,
            &mut local_model,
            &mut local_rng,
            TrainOptions::default(),
            &mut local_phases,
            Some(&mut heartbeat),
        )?;
    }
    if let Some(e) = ping_err {
        return Err(e);
    }
    client.replica(&local_model)?;
    client.leave()?;
    report.retries = client.retries;
    Ok(report)
}
