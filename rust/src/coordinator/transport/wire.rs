//! Serialized sparse-delta wire format for the coordinator transport.
//!
//! Frames reuse the hardened checkpoint encoding discipline from
//! `model/checkpoint.rs` — a magic/version header, explicit
//! length-guarded payloads, and per-array little-endian layouts written
//! through the same `checkpoint::write_*` helpers — so a transport frame
//! and a checkpoint agree byte-for-byte on how a sparse array is laid
//! out, and a corrupt or truncated frame surfaces as a typed
//! [`TsnnError`] before any unbounded allocation.
//!
//! Frame layout (little-endian, [`HEADER_BYTES`] = 25):
//!
//! ```text
//! magic "TSNW" | version u32 | kind u8 | worker u32 | seq u64 | payload_len u32
//! payload bytes (payload_len)
//! ```
//!
//! Models and deltas never densify: a full model ships the CSR arrays
//! exactly as a checkpoint would lay them out semantically, and a
//! values-only delta (topology generation unchanged) ships just the new
//! CSR values + biases — the sparse-delta exchange the paper's MPI
//! implementation used, kept topology-first per Nerva/Hoefler.
//!
//! Version 2 compresses the full-model topology (the post-topology-bump
//! snapshot that used to ship raw `row_ptr` u64s + `col_idx` u32s): row
//! *lengths* go as LEB128 varints, and each row's columns go as a first
//! absolute column + ascending-gap varints. On ε-sparse layers the gaps
//! are small, so most entries fit one byte instead of four. The encoder
//! always emits minimal-length varints, so decode→re-encode is
//! byte-identical (pinned by `tests/transport_wire.rs`), and every
//! length is still validated against the remaining payload *before* any
//! allocation.

use std::io::Write;

use crate::error::{Result, TsnnError};
use crate::model::checkpoint::{write_f32_slice, write_u32, write_u64, write_usize_slice_as_u64};
use crate::model::{SparseLayer, SparseMlp};
use crate::nn::Activation;
use crate::sparse::CsrMatrix;

/// Frame magic: "TSNW" (TSNN Wire) — deliberately distinct from the
/// checkpoint magic so a checkpoint file is never mistaken for a frame.
pub const MAGIC: &[u8; 4] = b"TSNW";
/// Wire protocol version. v2: varint-compressed full-model topology,
/// heartbeat (Ping/Pong) kinds, rejoin cursor in JoinAck.
pub const VERSION: u32 = 2;
/// Fixed frame-header size in bytes.
pub const HEADER_BYTES: usize = 25;
/// Hard cap on a single frame payload: a corrupt length field must
/// surface as a typed error, not an allocation attempt.
pub const MAX_PAYLOAD_BYTES: usize = 1 << 30;
/// Hard cap on layer counts carried in a frame.
pub const MAX_LAYERS: usize = 256;

/// `have_gen` / `have_step` sentinel: "I have nothing / reply now".
pub const NONE_U64: u64 = u64::MAX;

/// Frame kind tags.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Worker → server: join the run (worker id in the header).
    Join = 0,
    /// Server → worker: join accepted, optional job spec attached.
    JoinAck = 1,
    /// Worker → server: fetch a model snapshot.
    Fetch = 2,
    /// Server → worker: snapshot (values-only delta or full model).
    FetchAck = 3,
    /// Worker → server: gradient push.
    Push = 4,
    /// Server → worker: push outcome.
    PushAck = 5,
    /// Worker → server: phase-2 local replica upload.
    Replica = 6,
    /// Server → worker: replica stored.
    ReplicaAck = 7,
    /// Worker → server: leaving the run.
    Leave = 8,
    /// Server → worker: leave acknowledged.
    LeaveAck = 9,
    /// Server → worker: request-level error (protocol misuse).
    Err = 10,
    /// Worker → server: liveness heartbeat (phase-2 workers, which
    /// otherwise go silent while training locally).
    Ping = 11,
    /// Server → worker: heartbeat acknowledged.
    Pong = 12,
}

impl Kind {
    fn from_u8(v: u8) -> Option<Kind> {
        Some(match v {
            0 => Kind::Join,
            1 => Kind::JoinAck,
            2 => Kind::Fetch,
            3 => Kind::FetchAck,
            4 => Kind::Push,
            5 => Kind::PushAck,
            6 => Kind::Replica,
            7 => Kind::ReplicaAck,
            8 => Kind::Leave,
            9 => Kind::LeaveAck,
            10 => Kind::Err,
            11 => Kind::Ping,
            12 => Kind::Pong,
            _ => return None,
        })
    }
}

/// Decoded frame header.
#[derive(Debug, Clone, Copy)]
pub struct Header {
    /// Message kind.
    pub kind: Kind,
    /// Worker id the frame belongs to (`u32::MAX` before assignment).
    pub worker: u32,
    /// Per-connection monotonic request sequence number (requests and
    /// their replies share the seq; retransmits repeat it).
    pub seq: u64,
    /// Payload length in bytes.
    pub len: usize,
}

/// Push outcome codes carried in [`Message::PushAck`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushStatus {
    /// Gradient applied (or parked for the synchronous barrier).
    Applied = 0,
    /// Rejected: non-finite values on the receive path.
    RejectedNonFinite = 1,
    /// Rejected: topology generation no longer in the server's ring.
    RejectedStaleGen = 2,
    /// Rejected: gradient shape does not match the claimed topology.
    RejectedShape = 3,
    /// Ignored: phase 1 already completed.
    Ignored = 4,
}

impl PushStatus {
    fn from_u8(v: u8) -> Option<PushStatus> {
        Some(match v {
            0 => PushStatus::Applied,
            1 => PushStatus::RejectedNonFinite,
            2 => PushStatus::RejectedStaleGen,
            3 => PushStatus::RejectedShape,
            4 => PushStatus::Ignored,
            _ => return None,
        })
    }
}

/// Model snapshot payload: values-only when the worker's cached
/// topology generation matches, full CSR otherwise.
#[derive(Debug, Clone)]
pub enum ModelDelta {
    /// Topology unchanged: new CSR values + biases per layer.
    Values {
        /// Per-layer CSR values (aligned to the cached topology).
        values: Vec<Vec<f32>>,
        /// Per-layer biases.
        bias: Vec<Vec<f32>>,
    },
    /// Full model (topology + values; optimizer state iff `velocity`).
    Full {
        /// The model.
        model: SparseMlp,
        /// Whether velocity / bias_velocity arrays were shipped.
        velocity: bool,
    },
}

/// Decoded fetch reply.
#[derive(Debug, Clone)]
pub struct FetchAck {
    /// True once phase 1 completed: `delta` is the full phase-1 model
    /// (with optimizer state) and the worker should move to phase 2.
    pub phase2: bool,
    /// Topology generation of the snapshot.
    pub gen: u64,
    /// Server step of the snapshot.
    pub step: u64,
    /// Server epoch of the snapshot.
    pub epoch: u64,
    /// The model payload.
    pub delta: ModelDelta,
}

/// Decoded gradient push.
#[derive(Debug, Clone)]
pub struct PushMsg {
    /// Topology generation the gradients are aligned to.
    pub gen: u64,
    /// Server step the worker fetched at (staleness accounting).
    pub fetched_step: u64,
    /// Worker-computed learning rate (async; ignored for sync pushes —
    /// the server computes the warmup schedule itself).
    pub lr: f32,
    /// Synchronous (WASSP barrier) contribution.
    pub sync: bool,
    /// Per-layer weight gradients aligned to the topology's CSR values.
    pub grad_w: Vec<Vec<f32>>,
    /// Per-layer bias gradients.
    pub grad_b: Vec<Vec<f32>>,
}

/// A decoded wire message.
#[derive(Debug, Clone)]
pub enum Message {
    /// Worker joins (id in the frame header).
    Join,
    /// Join accepted; `job` is a JSON job spec for external workers.
    JoinAck {
        /// JSON job spec (config + dataset + parallel config + budgets);
        /// `None` for in-process workers that already hold the job.
        job: Option<String>,
        /// Phase-1 batches this worker id already had applied before a
        /// crash — a respawned worker fast-forwards its data/RNG streams
        /// this many iterations so the applied-update trajectory is
        /// unchanged. 0 for a first join.
        resume_pushes: u64,
        /// Server step a parked synchronous contribution from this
        /// worker id is waiting at ([`NONE_U64`] = none): the rejoiner
        /// must report this as its `have_step` so it parks until the
        /// barrier advances rather than double-contributing.
        resume_step: u64,
    },
    /// Snapshot request.
    Fetch {
        /// Topology generation the worker has cached ([`NONE_U64`] = none).
        have_gen: u64,
        /// Last server step the worker observed; a synchronous worker
        /// parks until the step advances past it ([`NONE_U64`] = reply now).
        have_step: u64,
    },
    /// Snapshot reply.
    FetchAck(FetchAck),
    /// Gradient push.
    Push(PushMsg),
    /// Push outcome.
    PushAck {
        /// Outcome code.
        status: PushStatus,
        /// Server step after handling the push.
        step: u64,
        /// Server epoch after handling the push.
        epoch: u64,
    },
    /// Phase-2 replica upload (weights + biases, no optimizer state).
    Replica {
        /// The locally-trained model.
        model: SparseMlp,
    },
    /// Replica stored.
    ReplicaAck,
    /// Worker leaves.
    Leave,
    /// Leave acknowledged.
    LeaveAck,
    /// Request-level error.
    Err {
        /// Human-readable cause.
        message: String,
    },
    /// Liveness heartbeat.
    Ping,
    /// Heartbeat acknowledged.
    Pong,
}

impl Message {
    fn kind(&self) -> Kind {
        match self {
            Message::Join => Kind::Join,
            Message::JoinAck { .. } => Kind::JoinAck,
            Message::Fetch { .. } => Kind::Fetch,
            Message::FetchAck(_) => Kind::FetchAck,
            Message::Push(_) => Kind::Push,
            Message::PushAck { .. } => Kind::PushAck,
            Message::Replica { .. } => Kind::Replica,
            Message::ReplicaAck => Kind::ReplicaAck,
            Message::Leave => Kind::Leave,
            Message::LeaveAck => Kind::LeaveAck,
            Message::Err { .. } => Kind::Err,
            Message::Ping => Kind::Ping,
            Message::Pong => Kind::Pong,
        }
    }
}

// --- encoding ---------------------------------------------------------------

fn act_tag(a: &Activation) -> (u8, f32) {
    match *a {
        Activation::Relu => (0, 0.0),
        Activation::LeakyRelu { alpha } => (1, alpha),
        Activation::AllRelu { alpha } => (2, alpha),
        Activation::Linear => (3, 0.0),
    }
}

fn act_from_tag(tag: u8, alpha: f32) -> Option<Activation> {
    Some(match tag {
        0 => Activation::Relu,
        1 => Activation::LeakyRelu { alpha },
        2 => Activation::AllRelu { alpha },
        3 => Activation::Linear,
        _ => return None,
    })
}

/// Minimal-length LEB128 — the canonical form, so decode→re-encode of
/// any frame we produced is byte-identical.
fn write_varint(w: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            w.push(b);
            break;
        }
        w.push(b | 0x80);
    }
}

/// Varint-compressed CSR topology: per-row lengths, then per row a
/// first absolute column followed by ascending gaps minus one. CSR
/// validation guarantees strictly-ascending columns within a row, so
/// the gaps are non-negative and — at ε-sparse densities — small.
fn encode_topology(w: &mut Vec<u8>, m: &CsrMatrix) {
    for r in 0..m.n_rows {
        write_varint(w, (m.row_ptr[r + 1] - m.row_ptr[r]) as u64);
    }
    for r in 0..m.n_rows {
        let cols = &m.col_idx[m.row_ptr[r]..m.row_ptr[r + 1]];
        let mut prev: Option<u32> = None;
        for &c in cols {
            match prev {
                None => write_varint(w, u64::from(c)),
                Some(p) => write_varint(w, u64::from(c - p - 1)),
            }
            prev = Some(c);
        }
    }
}

fn encode_model(w: &mut Vec<u8>, m: &SparseMlp, velocity: bool) -> Result<()> {
    w.push(u8::from(velocity));
    write_u32(w, m.layers.len() as u32)?;
    write_usize_slice_as_u64(w, &m.sizes)?;
    for layer in &m.layers {
        let (tag, alpha) = act_tag(&layer.activation);
        w.push(tag);
        write_f32_slice(w, &[alpha])?;
        write_u64(w, layer.weights.nnz() as u64)?;
        encode_topology(w, &layer.weights);
        write_f32_slice(w, &layer.weights.values)?;
        write_f32_slice(w, &layer.bias)?;
        if velocity {
            write_f32_slice(w, &layer.velocity)?;
            write_f32_slice(w, &layer.bias_velocity)?;
        }
    }
    Ok(())
}

fn encode_layer_vecs(w: &mut Vec<u8>, per_nnz: &[Vec<f32>], per_out: &[Vec<f32>]) -> Result<()> {
    write_u32(w, per_nnz.len() as u32)?;
    for (v, b) in per_nnz.iter().zip(per_out.iter()) {
        write_u64(w, v.len() as u64)?;
        write_f32_slice(w, v)?;
        write_u32(w, b.len() as u32)?;
        write_f32_slice(w, b)?;
    }
    Ok(())
}

fn encode_payload(msg: &Message) -> Result<Vec<u8>> {
    let mut w: Vec<u8> = Vec::new();
    match msg {
        Message::Join
        | Message::ReplicaAck
        | Message::Leave
        | Message::LeaveAck
        | Message::Ping
        | Message::Pong => {}
        Message::JoinAck {
            job,
            resume_pushes,
            resume_step,
        } => {
            w.push(u8::from(job.is_some()));
            if let Some(j) = job {
                write_u32(&mut w, crate::sparse::storage::checked_u32(j.len(), "job name length")?)?;
                w.write_all(j.as_bytes())?;
            }
            write_u64(&mut w, *resume_pushes)?;
            write_u64(&mut w, *resume_step)?;
        }
        Message::Fetch { have_gen, have_step } => {
            write_u64(&mut w, *have_gen)?;
            write_u64(&mut w, *have_step)?;
        }
        Message::FetchAck(f) => {
            w.push(if f.phase2 { 2 } else { 1 });
            write_u64(&mut w, f.gen)?;
            write_u64(&mut w, f.step)?;
            write_u64(&mut w, f.epoch)?;
            match &f.delta {
                ModelDelta::Values { values, bias } => {
                    w.push(0);
                    encode_layer_vecs(&mut w, values, bias)?;
                }
                ModelDelta::Full { model, velocity } => {
                    w.push(1);
                    encode_model(&mut w, model, *velocity)?;
                }
            }
        }
        Message::Push(p) => {
            write_u64(&mut w, p.gen)?;
            write_u64(&mut w, p.fetched_step)?;
            write_f32_slice(&mut w, &[p.lr])?;
            w.push(u8::from(p.sync));
            encode_layer_vecs(&mut w, &p.grad_w, &p.grad_b)?;
        }
        Message::PushAck { status, step, epoch } => {
            w.push(*status as u8);
            write_u64(&mut w, *step)?;
            write_u64(&mut w, *epoch)?;
        }
        Message::Replica { model } => {
            encode_model(&mut w, model, false)?;
        }
        Message::Err { message } => {
            write_u32(
                &mut w,
                crate::sparse::storage::checked_u32(message.len(), "error message length")?,
            )?;
            w.write_all(message.as_bytes())?;
        }
    }
    Ok(w)
}

/// Encode a complete frame (header + payload), with the payload length
/// checked against the u32 header field and [`MAX_PAYLOAD_BYTES`] — a
/// hypothetical >4 GiB model snapshot becomes a typed
/// [`TsnnError::IndexOverflow`] instead of a silently truncated length.
pub fn try_encode_frame(worker: u32, seq: u64, msg: &Message) -> Result<Vec<u8>> {
    let payload = encode_payload(msg)?;
    if payload.len() > MAX_PAYLOAD_BYTES {
        return Err(TsnnError::IndexOverflow(format!(
            "frame payload of {} bytes exceeds the wire cap {MAX_PAYLOAD_BYTES}",
            payload.len()
        )));
    }
    let len32 = crate::sparse::storage::checked_u32(payload.len(), "frame payload length")?;
    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(msg.kind() as u8);
    out.extend_from_slice(&worker.to_le_bytes());
    out.extend_from_slice(&seq.to_le_bytes());
    out.extend_from_slice(&len32.to_le_bytes());
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Encode a complete frame (header + payload). Panics only on payloads
/// past the wire cap — every message the coordinator produces is far
/// below it; size-unbounded callers use [`try_encode_frame`].
pub fn encode_frame(worker: u32, seq: u64, msg: &Message) -> Vec<u8> {
    try_encode_frame(worker, seq, msg).expect("in-memory frame encode cannot fail")
}

// --- decoding ---------------------------------------------------------------

/// Bounds-checked slice cursor: every read validates the remaining
/// length *before* allocating, so implausible length fields surface as
/// typed errors — never a panic or an unbounded allocation.
struct Cur<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Cur<'a> {
        Cur { buf, off: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.off
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            return Err(TsnnError::Transport(format!(
                "truncated frame: need {n} bytes at offset {}, have {}",
                self.off,
                self.remaining()
            )));
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// LEB128 varint, capped at 10 bytes / 64 bits.
    fn varint(&mut self) -> Result<u64> {
        let mut v: u64 = 0;
        let mut shift = 0u32;
        loop {
            let b = self.u8()?;
            if shift == 63 && b > 1 {
                return Err(TsnnError::Transport("varint overflows u64".into()));
            }
            v |= u64::from(b & 0x7F) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
            if shift > 63 {
                return Err(TsnnError::Transport("varint too long".into()));
            }
        }
    }

    /// Length-guarded count: fails *before* allocation when the claimed
    /// element count cannot fit in the remaining bytes.
    fn checked_len(&self, n: u64, elem_bytes: usize, what: &str) -> Result<usize> {
        let n = usize::try_from(n)
            .ok()
            .and_then(|n| n.checked_mul(elem_bytes).map(|bytes| (n, bytes)))
            .filter(|&(_, bytes)| bytes <= self.remaining())
            .map(|(n, _)| n)
            .ok_or_else(|| {
                TsnnError::Transport(format!(
                    "implausible {what} length {n} ({} bytes remain)",
                    self.remaining()
                ))
            })?;
        Ok(n)
    }

    fn f32_vec(&mut self, n: u64, what: &str) -> Result<Vec<f32>> {
        let n = self.checked_len(n, 4, what)?;
        let b = self.take(n * 4)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn u64_vec(&mut self, n: u64, what: &str) -> Result<Vec<u64>> {
        let n = self.checked_len(n, 8, what)?;
        let b = self.take(n * 8)?;
        Ok(b.chunks_exact(8)
            .map(|c| {
                u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
            })
            .collect())
    }

    fn string(&mut self, n: u32, what: &str) -> Result<String> {
        let n = self.checked_len(u64::from(n), 1, what)?;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| TsnnError::Transport(format!("{what}: invalid utf8")))
    }
}

/// Decode the varint-compressed topology of one layer: row lengths must
/// sum to exactly `nnz`, and reconstructed columns must stay strictly
/// ascending below `n_out` — both checked as we go, so a corrupt stream
/// fails typed before `validate()` and never over-allocates (`nnz` was
/// already bounded by the caller).
fn decode_topology(
    c: &mut Cur,
    l: usize,
    n_in: usize,
    n_out: usize,
    nnz: usize,
) -> Result<(Vec<usize>, Vec<u32>)> {
    let mut row_ptr = Vec::with_capacity(n_in + 1);
    row_ptr.push(0usize);
    let mut acc = 0u64;
    for _ in 0..n_in {
        acc = acc.saturating_add(c.varint()?);
        if acc > nnz as u64 {
            return Err(TsnnError::Transport(format!(
                "layer {l}: row lengths exceed nnz {nnz}"
            )));
        }
        row_ptr.push(acc as usize);
    }
    if acc != nnz as u64 {
        return Err(TsnnError::Transport(format!(
            "layer {l}: row lengths sum to {acc}, nnz says {nnz}"
        )));
    }
    let mut col_idx = Vec::with_capacity(nnz);
    for r in 0..n_in {
        let len = row_ptr[r + 1] - row_ptr[r];
        let mut prev: Option<u64> = None;
        for _ in 0..len {
            let col = match prev {
                None => c.varint()?,
                Some(p) => p.saturating_add(1).saturating_add(c.varint()?),
            };
            if col >= n_out as u64 {
                return Err(TsnnError::Transport(format!(
                    "layer {l}: column {col} out of bounds (n_out {n_out})"
                )));
            }
            col_idx.push(col as u32);
            prev = Some(col);
        }
    }
    Ok((row_ptr, col_idx))
}

fn decode_model(c: &mut Cur) -> Result<SparseMlp> {
    let with_velocity = c.u8()? != 0;
    let n_layers = c.u32()? as usize;
    if n_layers == 0 || n_layers > MAX_LAYERS {
        return Err(TsnnError::Transport(format!(
            "implausible layer count {n_layers}"
        )));
    }
    let sizes: Vec<usize> = c
        .u64_vec((n_layers + 1) as u64, "sizes")?
        .into_iter()
        .map(|v| v as usize)
        .collect();
    // dimension cap: keeps `n_in + 1` and row_ptr allocation math safe
    if let Some(&bad) = sizes.iter().find(|&&s| s == 0 || s > (1 << 31)) {
        return Err(TsnnError::Transport(format!("implausible layer size {bad}")));
    }
    let mut layers = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let (n_in, n_out) = (sizes[l], sizes[l + 1]);
        let tag = c.u8()?;
        let alpha = c.f32()?;
        let activation = act_from_tag(tag, alpha)
            .ok_or_else(|| TsnnError::Transport(format!("layer {l}: bad activation tag {tag}")))?;
        let nnz64 = c.u64()?;
        // a corrupt nnz must not drive allocations or validate() cost;
        // every varint is >= 1 byte, so nnz (and n_in row lengths) must
        // also fit in the remaining payload before anything allocates
        if nnz64 > n_in.saturating_mul(n_out) as u64 || nnz64 > c.remaining() as u64 {
            return Err(TsnnError::Transport(format!(
                "layer {l}: implausible nnz {nnz64}"
            )));
        }
        if n_in > c.remaining() {
            return Err(TsnnError::Transport(format!(
                "layer {l}: truncated row lengths"
            )));
        }
        let (row_ptr, col_idx) = decode_topology(c, l, n_in, n_out, nnz64 as usize)?;
        let values = c.f32_vec(nnz64, "values")?;
        let bias = c.f32_vec(n_out as u64, "bias")?;
        let (velocity, bias_velocity) = if with_velocity {
            (
                c.f32_vec(nnz64, "velocity")?,
                c.f32_vec(n_out as u64, "bias_velocity")?,
            )
        } else {
            (vec![0.0; nnz64 as usize], vec![0.0; n_out])
        };
        let weights = CsrMatrix {
            n_rows: n_in,
            n_cols: n_out,
            row_ptr: row_ptr.into(),
            col_idx: col_idx.into(),
            values: values.into(),
        };
        weights
            .validate()
            .map_err(|e| TsnnError::Transport(format!("layer {l}: {e}")))?;
        layers.push(SparseLayer {
            weights,
            bias,
            velocity: velocity.into(),
            bias_velocity,
            activation,
            srelu: None,
        });
    }
    Ok(SparseMlp { sizes, layers })
}

fn decode_layer_vecs(c: &mut Cur) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>)> {
    let n_layers = c.u32()? as usize;
    if n_layers > MAX_LAYERS {
        return Err(TsnnError::Transport(format!(
            "implausible layer count {n_layers}"
        )));
    }
    let mut per_nnz = Vec::with_capacity(n_layers);
    let mut per_out = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let nnz = c.u64()?;
        per_nnz.push(c.f32_vec(nnz, "layer values")?);
        let n_out = c.u32()?;
        per_out.push(c.f32_vec(u64::from(n_out), "layer bias")?);
    }
    Ok((per_nnz, per_out))
}

/// Decode and validate a frame header from its fixed-size prefix.
pub fn decode_header(buf: &[u8]) -> Result<Header> {
    if buf.len() < HEADER_BYTES {
        return Err(TsnnError::Transport(format!(
            "truncated header: {} of {HEADER_BYTES} bytes",
            buf.len()
        )));
    }
    if &buf[0..4] != MAGIC {
        return Err(TsnnError::Transport("bad frame magic".into()));
    }
    let version = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]);
    if version != VERSION {
        return Err(TsnnError::Transport(format!(
            "unsupported wire version {version}"
        )));
    }
    let kind = Kind::from_u8(buf[8])
        .ok_or_else(|| TsnnError::Transport(format!("unknown frame kind {}", buf[8])))?;
    let worker = u32::from_le_bytes([buf[9], buf[10], buf[11], buf[12]]);
    let seq = u64::from_le_bytes([
        buf[13], buf[14], buf[15], buf[16], buf[17], buf[18], buf[19], buf[20],
    ]);
    let len = u32::from_le_bytes([buf[21], buf[22], buf[23], buf[24]]) as usize;
    if len > MAX_PAYLOAD_BYTES {
        return Err(TsnnError::Transport(format!(
            "implausible payload length {len}"
        )));
    }
    Ok(Header { kind, worker, seq, len })
}

/// Decode a complete frame (header + payload) into its message.
pub fn decode_frame(frame: &[u8]) -> Result<(Header, Message)> {
    let h = decode_header(frame)?;
    let payload = &frame[HEADER_BYTES.min(frame.len())..];
    if payload.len() != h.len {
        return Err(TsnnError::Transport(format!(
            "payload length mismatch: header says {}, frame carries {}",
            h.len,
            payload.len()
        )));
    }
    let mut c = Cur::new(payload);
    let msg = match h.kind {
        Kind::Join => Message::Join,
        Kind::JoinAck => {
            let has_job = c.u8()? != 0;
            let job = if has_job {
                let n = c.u32()?;
                Some(c.string(n, "job spec")?)
            } else {
                None
            };
            Message::JoinAck {
                job,
                resume_pushes: c.u64()?,
                resume_step: c.u64()?,
            }
        }
        Kind::Fetch => Message::Fetch {
            have_gen: c.u64()?,
            have_step: c.u64()?,
        },
        Kind::FetchAck => {
            let phase = c.u8()?;
            if phase != 1 && phase != 2 {
                return Err(TsnnError::Transport(format!("bad phase tag {phase}")));
            }
            let gen = c.u64()?;
            let step = c.u64()?;
            let epoch = c.u64()?;
            let delta = match c.u8()? {
                0 => {
                    let (values, bias) = decode_layer_vecs(&mut c)?;
                    ModelDelta::Values { values, bias }
                }
                1 => {
                    let velocity_peek = c.buf.get(c.off).copied().unwrap_or(0) != 0;
                    let model = decode_model(&mut c)?;
                    ModelDelta::Full {
                        model,
                        velocity: velocity_peek,
                    }
                }
                other => {
                    return Err(TsnnError::Transport(format!("bad delta tag {other}")));
                }
            };
            Message::FetchAck(FetchAck {
                phase2: phase == 2,
                gen,
                step,
                epoch,
                delta,
            })
        }
        Kind::Push => {
            let gen = c.u64()?;
            let fetched_step = c.u64()?;
            let lr = c.f32()?;
            let sync = c.u8()? != 0;
            let (grad_w, grad_b) = decode_layer_vecs(&mut c)?;
            Message::Push(PushMsg {
                gen,
                fetched_step,
                lr,
                sync,
                grad_w,
                grad_b,
            })
        }
        Kind::PushAck => {
            let s = c.u8()?;
            let status = PushStatus::from_u8(s)
                .ok_or_else(|| TsnnError::Transport(format!("bad push status {s}")))?;
            Message::PushAck {
                status,
                step: c.u64()?,
                epoch: c.u64()?,
            }
        }
        Kind::Replica => Message::Replica {
            model: decode_model(&mut c)?,
        },
        Kind::ReplicaAck => Message::ReplicaAck,
        Kind::Leave => Message::Leave,
        Kind::LeaveAck => Message::LeaveAck,
        Kind::Err => {
            let n = c.u32()?;
            Message::Err {
                message: c.string(n, "error message")?,
            }
        }
        Kind::Ping => Message::Ping,
        Kind::Pong => Message::Pong,
    };
    if c.remaining() != 0 {
        return Err(TsnnError::Transport(format!(
            "{} trailing bytes after payload",
            c.remaining()
        )));
    }
    Ok((h, msg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::WeightInit;
    use crate::util::Rng;

    /// Frame fields carrying row offsets / nnz totals past `u32::MAX`
    /// must roundtrip untruncated through both integer codecs — the
    /// LEB128 varints of the topology encoding and the fixed u64
    /// fields. Header-level only: no multi-gigabyte model is built.
    #[test]
    fn varints_and_u64_fields_roundtrip_past_u32_max() {
        let values: &[u64] = &[
            0,
            1,
            127,
            128,
            u64::from(u32::MAX) - 1,
            u64::from(u32::MAX),
            u64::from(u32::MAX) + 1,
            1u64 << 33,
            (1u64 << 42) + 987_654_321,
            u64::MAX,
        ];
        let mut buf = Vec::new();
        for &v in values {
            write_varint(&mut buf, v);
        }
        let mut c = Cur::new(&buf);
        for &v in values {
            assert_eq!(c.varint().unwrap(), v, "varint truncated {v}");
        }
        assert_eq!(c.remaining(), 0, "canonical varints leave no slack");

        let mut buf = Vec::new();
        for &v in values {
            write_u64(&mut buf, v).unwrap();
        }
        let mut c = Cur::new(&buf);
        for &v in values {
            assert_eq!(c.u64().unwrap(), v, "u64 field truncated {v}");
        }
    }

    fn model() -> SparseMlp {
        SparseMlp::new(
            &[8, 12, 3],
            4.0,
            Activation::AllRelu { alpha: 0.4 },
            &WeightInit::Xavier,
            &mut Rng::new(9),
        )
        .unwrap()
    }

    #[test]
    fn full_model_roundtrips_bit_exact() {
        let mut m = model();
        for l in &mut m.layers {
            for (i, v) in l.velocity.iter_mut().enumerate() {
                *v = 0.25 * i as f32;
            }
        }
        let msg = Message::FetchAck(FetchAck {
            phase2: true,
            gen: 7,
            step: 99,
            epoch: 3,
            delta: ModelDelta::Full {
                model: m.clone(),
                velocity: true,
            },
        });
        let frame = encode_frame(2, 41, &msg);
        let (h, decoded) = decode_frame(&frame).unwrap();
        assert_eq!(h.worker, 2);
        assert_eq!(h.seq, 41);
        match decoded {
            Message::FetchAck(f) => {
                assert!(f.phase2);
                let got = match f.delta {
                    ModelDelta::Full { model, velocity } => {
                        assert!(velocity);
                        model
                    }
                    _ => panic!("expected full model"),
                };
                assert_eq!(got.sizes, m.sizes);
                for (a, b) in got.layers.iter().zip(m.layers.iter()) {
                    assert_eq!(a.weights, b.weights);
                    assert_eq!(a.bias, b.bias);
                    assert_eq!(a.velocity, b.velocity);
                    assert_eq!(a.activation, b.activation);
                }
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn push_roundtrips() {
        let msg = Message::Push(PushMsg {
            gen: 3,
            fetched_step: 17,
            lr: 0.05,
            sync: true,
            grad_w: vec![vec![1.0, -2.0], vec![0.5]],
            grad_b: vec![vec![0.1], vec![-0.2, 0.3]],
        });
        let frame = encode_frame(0, 5, &msg);
        match decode_frame(&frame).unwrap().1 {
            Message::Push(p) => {
                assert_eq!(p.gen, 3);
                assert!(p.sync);
                assert_eq!(p.grad_w, vec![vec![1.0, -2.0], vec![0.5]]);
                assert_eq!(p.grad_b, vec![vec![0.1], vec![-0.2, 0.3]]);
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn header_rejects_garbage() {
        let mut f = encode_frame(0, 1, &Message::Join);
        f[0] = b'X';
        assert!(decode_frame(&f).is_err());
        let mut f = encode_frame(0, 1, &Message::Join);
        f[4] = 9; // version
        assert!(decode_frame(&f).is_err());
        let mut f = encode_frame(0, 1, &Message::Join);
        f[8] = 200; // kind
        assert!(decode_frame(&f).is_err());
    }

    #[test]
    fn implausible_lengths_fail_before_allocating() {
        // a Push whose layer-values length claims far more data than the
        // frame carries must fail in checked_len, not in Vec::with_capacity
        let msg = Message::Push(PushMsg {
            gen: 0,
            fetched_step: 0,
            lr: 0.1,
            sync: false,
            grad_w: vec![vec![1.0; 4]],
            grad_b: vec![vec![0.0; 2]],
        });
        let mut frame = encode_frame(0, 1, &msg);
        // the nnz u64 lives right after: 4 bytes n_layers following
        // gen(8) + step(8) + lr(4) + sync(1) in the payload
        let off = HEADER_BYTES + 8 + 8 + 4 + 1 + 4;
        frame[off..off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = decode_frame(&frame).unwrap_err();
        assert!(matches!(err, TsnnError::Transport(_)), "{err}");
    }
}
