//! Deterministic fault injection for transport tests.
//!
//! [`FaultyTransport`] wraps any [`Transport`] and perturbs traffic on
//! modulo counters — no RNG, so a given plan produces the identical
//! fault sequence on every run, which is what lets the parity tests pin
//! "trajectory under faults == trajectory without faults" exactly.
//!
//! Faults on the send path: `drop` (frame vanishes), `dup` (frame sent
//! twice), `delay` (frame held until the next send — a one-slot
//! reorder), `truncate` (frame cut mid-payload; channel transport only,
//! a byte-stream would desync). On the receive path: `drop_reply`
//! (reply vanishes, forcing the timeout/retransmit path).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::error::{Result, TsnnError};

use super::wire::HEADER_BYTES;
use super::Transport;

/// Which frames to perturb: every `n`-th send / receive (0 = off).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Drop every n-th sent frame.
    pub drop_every: u64,
    /// Duplicate every n-th sent frame.
    pub dup_every: u64,
    /// Hold every n-th sent frame until the next send (reorder-by-one).
    pub delay_every: u64,
    /// Truncate every n-th sent frame mid-payload (channel transport
    /// only: a truncated frame on a byte stream desyncs the connection).
    pub truncate_every: u64,
    /// Drop every n-th received reply.
    pub drop_reply_every: u64,
}

impl FaultPlan {
    /// Any fault enabled?
    pub fn is_active(&self) -> bool {
        *self != FaultPlan::default()
    }

    /// Parse `drop=7,dup=5,delay=11,truncate=13,drop_reply=9` (any
    /// subset, any order).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').filter(|p| !p.is_empty()) {
            let (key, val) = part.split_once('=').ok_or_else(|| {
                TsnnError::Config(format!("fault spec '{part}': expected key=N"))
            })?;
            let n: u64 = val
                .parse()
                .map_err(|_| TsnnError::Config(format!("fault spec '{part}': bad count")))?;
            match key.trim() {
                "drop" => plan.drop_every = n,
                "dup" => plan.dup_every = n,
                "delay" => plan.delay_every = n,
                "truncate" => plan.truncate_every = n,
                "drop_reply" => plan.drop_reply_every = n,
                other => {
                    return Err(TsnnError::Config(format!("unknown fault '{other}'")));
                }
            }
        }
        Ok(plan)
    }
}

/// Shared tallies of injected faults (assertable from tests).
#[derive(Debug, Default)]
pub struct FaultCounters {
    /// Sent frames dropped.
    pub dropped: AtomicU64,
    /// Sent frames duplicated.
    pub duplicated: AtomicU64,
    /// Sent frames delayed (reordered).
    pub delayed: AtomicU64,
    /// Sent frames truncated.
    pub truncated: AtomicU64,
    /// Received replies dropped.
    pub replies_dropped: AtomicU64,
}

impl FaultCounters {
    /// Total injected faults.
    pub fn total(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
            + self.duplicated.load(Ordering::Relaxed)
            + self.delayed.load(Ordering::Relaxed)
            + self.truncated.load(Ordering::Relaxed)
            + self.replies_dropped.load(Ordering::Relaxed)
    }
}

/// A transport wrapper that injects the plan's faults.
pub struct FaultyTransport {
    inner: Box<dyn Transport>,
    plan: FaultPlan,
    counters: Arc<FaultCounters>,
    sent: u64,
    rcvd: u64,
    held: Option<Vec<u8>>,
}

impl FaultyTransport {
    /// Wrap `inner` with the plan; `counters` is shared with the caller.
    pub fn new(
        inner: Box<dyn Transport>,
        plan: FaultPlan,
        counters: Arc<FaultCounters>,
    ) -> FaultyTransport {
        FaultyTransport {
            inner,
            plan,
            counters,
            sent: 0,
            rcvd: 0,
            held: None,
        }
    }

    fn flush_held(&mut self) -> Result<()> {
        if let Some(h) = self.held.take() {
            self.inner.send(&h)?;
        }
        Ok(())
    }
}

fn hits(every: u64, n: u64) -> bool {
    every > 0 && n % every == 0
}

impl Transport for FaultyTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.sent += 1;
        let n = self.sent;
        if hits(self.plan.truncate_every, n) {
            self.counters.truncated.fetch_add(1, Ordering::Relaxed);
            let body = frame.len().saturating_sub(HEADER_BYTES);
            let cut = HEADER_BYTES.min(frame.len()) + body / 2;
            self.inner.send(&frame[..cut])?;
        } else if hits(self.plan.drop_every, n) {
            self.counters.dropped.fetch_add(1, Ordering::Relaxed);
        } else if hits(self.plan.dup_every, n) {
            self.counters.duplicated.fetch_add(1, Ordering::Relaxed);
            self.inner.send(frame)?;
            self.inner.send(frame)?;
        } else if hits(self.plan.delay_every, n) {
            self.counters.delayed.fetch_add(1, Ordering::Relaxed);
            self.flush_held()?;
            self.held = Some(frame.to_vec());
            return Ok(()); // held frame goes out on the next send
        } else {
            self.inner.send(frame)?;
        }
        self.flush_held()
    }

    fn recv(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        match self.inner.recv(timeout)? {
            None => Ok(None),
            Some(frame) => {
                self.rcvd += 1;
                if hits(self.plan.drop_reply_every, self.rcvd) {
                    self.counters.replies_dropped.fetch_add(1, Ordering::Relaxed);
                    // swallowed: the caller sees a timeout and retransmits
                    Ok(None)
                } else {
                    Ok(Some(frame))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// Records sends; replays a scripted receive queue.
    struct Probe {
        sent: Arc<Mutex<Vec<Vec<u8>>>>,
        replies: Vec<Vec<u8>>,
    }

    impl Transport for Probe {
        fn send(&mut self, frame: &[u8]) -> Result<()> {
            self.sent.lock().unwrap().push(frame.to_vec());
            Ok(())
        }

        fn recv(&mut self, _timeout: Duration) -> Result<Option<Vec<u8>>> {
            Ok(if self.replies.is_empty() {
                None
            } else {
                Some(self.replies.remove(0))
            })
        }
    }

    #[test]
    fn parse_accepts_subsets_and_rejects_garbage() {
        let p = FaultPlan::parse("drop=7,dup=5").unwrap();
        assert_eq!(p.drop_every, 7);
        assert_eq!(p.dup_every, 5);
        assert_eq!(p.delay_every, 0);
        assert!(p.is_active());
        assert!(!FaultPlan::parse("").unwrap().is_active());
        assert!(FaultPlan::parse("drop").is_err());
        assert!(FaultPlan::parse("warp=3").is_err());
        assert!(FaultPlan::parse("drop=x").is_err());
    }

    #[test]
    fn faults_fire_on_schedule_and_are_counted() {
        let sent = Arc::new(Mutex::new(Vec::new()));
        let counters = Arc::new(FaultCounters::default());
        let mut t = FaultyTransport::new(
            Box::new(Probe {
                sent: sent.clone(),
                replies: vec![],
            }),
            FaultPlan {
                drop_every: 3,
                dup_every: 4,
                delay_every: 0,
                truncate_every: 0,
                drop_reply_every: 0,
            },
            counters.clone(),
        );
        for i in 0..12u8 {
            t.send(&[i]).unwrap();
        }
        // drops at 3,6,9,12 → 4; dups at 4,8 (12 already dropped) → 2
        assert_eq!(counters.dropped.load(Ordering::Relaxed), 4);
        assert_eq!(counters.duplicated.load(Ordering::Relaxed), 2);
        // 12 sends - 4 dropped + 2 extra dup copies = 10 on the wire
        assert_eq!(sent.lock().unwrap().len(), 10);
    }

    #[test]
    fn delay_reorders_by_one_slot() {
        let sent = Arc::new(Mutex::new(Vec::new()));
        let mut t = FaultyTransport::new(
            Box::new(Probe {
                sent: sent.clone(),
                replies: vec![],
            }),
            FaultPlan {
                delay_every: 2,
                ..FaultPlan::default()
            },
            Arc::new(FaultCounters::default()),
        );
        for i in 1..=4u8 {
            t.send(&[i]).unwrap();
        }
        // 2 held then flushed after 3; 4 held (still in flight)
        assert_eq!(*sent.lock().unwrap(), vec![vec![1], vec![3], vec![2]]);
    }

    #[test]
    fn dropped_replies_read_as_timeouts() {
        let mut t = FaultyTransport::new(
            Box::new(Probe {
                sent: Arc::new(Mutex::new(Vec::new())),
                replies: vec![vec![1], vec![2], vec![3]],
            }),
            FaultPlan {
                drop_reply_every: 2,
                ..FaultPlan::default()
            },
            Arc::new(FaultCounters::default()),
        );
        let d = Duration::from_millis(1);
        assert_eq!(t.recv(d).unwrap(), Some(vec![1]));
        assert_eq!(t.recv(d).unwrap(), None); // swallowed
        assert_eq!(t.recv(d).unwrap(), Some(vec![3]));
    }
}
