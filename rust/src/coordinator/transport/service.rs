//! The coordinator service: the parameter-server side of the transport
//! protocol.
//!
//! One service instance drives a whole WASAP/WASSP run over any
//! [`Listener`] — the in-process channel hub (worker threads) or the
//! socket hub (worker processes) — with identical semantics:
//!
//! * **Idempotent requests** — each connection's requests carry a
//!   monotonic seq; the last reply is cached per connection, so a
//!   retransmitted request (lost frame, lost reply, duplicate) is
//!   re-answered from the cache and gradient applications are never
//!   duplicated. This is what makes the fault-injection parity tests
//!   exact: faults change *traffic*, never the applied-update sequence.
//! * **Elasticity** — workers join with an id, leave explicitly, or
//!   vanish (connection close = implicit leave). The run finishes when
//!   every worker that ever joined has left; a synchronous barrier waits
//!   only for currently-active workers.
//! * **Straggler detection** (async phase) — per-worker push cadence is
//!   tracked; a worker whose silence exceeds `factor ×` its median gap
//!   is flagged and logged. Observability only: WASAP tolerates
//!   stragglers by design (RetainValidUpdates), so no action is taken.
//! * **Supervision** (opt-in, DESIGN.md §13.3) — escalates detection to
//!   action: a vanished or long-silent worker is held in an
//!   awaiting-rejoin set instead of shrinking the run; the WASSP barrier
//!   waits for held workers; a rejoining worker gets a resume cursor
//!   (its counted pushes + any parked sync step) so a supervisor-
//!   respawned process fast-forwards onto the exact trajectory; rejoin
//!   grace expiry abandons the worker, aborting only on lost quorum.

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::TrainConfig;
use crate::coordinator::{
    clip_gradients, ParallelConfig, ParameterServer, ServerStats, SparseGradient,
};
use crate::error::{Result, TsnnError};
use crate::model::SparseMlp;
use crate::nn::LrSchedule;

use super::wire::{self, FetchAck, Message, ModelDelta, PushMsg, PushStatus, NONE_U64};
use super::{Inbound, Listener, RetryPolicy};

/// How many topology generations of snapshots the server keeps for
/// `RetainValidUpdates` against stale pushes. Generations advance once
/// per epoch, so 8 generations of slack covers any sane staleness.
const TOPO_RING: usize = 8;

/// Coordinator-side knobs that are not part of [`ParallelConfig`]
/// (which external callers construct literally and must not change).
#[derive(Debug, Clone)]
pub struct CoordinatorOptions {
    /// Client-side retry policy handed to in-process workers.
    pub retry: RetryPolicy,
    /// Abort the run when no frame arrives for this long.
    pub idle_timeout: Duration,
    /// Flag a worker whose push gap exceeds `factor ×` its median gap.
    pub straggler_factor: f64,
    /// Worker supervision (DESIGN.md §13.3). `None` keeps the PR 7
    /// elastic semantics: a vanished worker is an implicit leave and the
    /// run shrinks around it. `Some` escalates detection to action:
    /// vanished workers are held for rejoin, the WASSP barrier waits for
    /// them, and losing quorum aborts the run.
    pub supervision: Option<SupervisionPolicy>,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            retry: RetryPolicy::default(),
            idle_timeout: Duration::from_secs(600),
            straggler_factor: 10.0,
            supervision: None,
        }
    }
}

/// Supervision parameters (DESIGN.md §13.3).
#[derive(Debug, Clone, Copy)]
pub struct SupervisionPolicy {
    /// An active worker silent for this long (no request of any kind,
    /// and no parked fetch waiting on the server) is presumed dead and
    /// moved to the awaiting-rejoin set.
    pub dead_after: Duration,
    /// How long a vanished worker may stay awaiting rejoin before the
    /// run abandons it and continues below full strength.
    pub rejoin_grace: Duration,
    /// Quorum: abandoning a worker aborts the run if fewer than this
    /// many workers remain (active + awaiting). Clean leaves never
    /// trigger the quorum rule — elasticity is still a feature.
    pub min_active: usize,
}

impl Default for SupervisionPolicy {
    fn default() -> Self {
        SupervisionPolicy {
            dead_after: Duration::from_secs(60),
            rejoin_grace: Duration::from_secs(30),
            min_active: 1,
        }
    }
}

/// Transport/coordination statistics for one run.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoordStats {
    /// Frames received (including duplicates and undecodable ones).
    pub frames_in: u64,
    /// Frames sent (including cached-reply resends).
    pub frames_out: u64,
    /// Bytes received.
    pub bytes_in: u64,
    /// Bytes sent.
    pub bytes_out: u64,
    /// Inbound frames that failed to decode.
    pub decode_errors: u64,
    /// Duplicate requests absorbed by the seq/reply cache.
    pub dup_requests: u64,
    /// Worker joins.
    pub joins: u64,
    /// Explicit leaves.
    pub leaves: u64,
    /// Connections that closed without a Leave.
    pub implicit_leaves: u64,
    /// Pushes rejected: topology generation fell out of the ring.
    pub rejected_stale_gen: u64,
    /// Pushes rejected: gradient shape mismatch.
    pub rejected_shape: u64,
    /// Pushes rejected: non-finite gradient entries (server-side guard).
    pub rejected_nonfinite: u64,
    /// Straggler flags raised (async phase).
    pub stragglers_flagged: u64,
    /// Heartbeat pings answered.
    pub pings: u64,
    /// Rejoins of previously-vanished workers (supervision).
    pub rejoins: u64,
    /// Active workers presumed dead after prolonged silence (supervision).
    pub presumed_dead: u64,
    /// Vanished workers abandoned after the rejoin grace (supervision).
    pub abandoned: u64,
    /// Fetches answered with a full model.
    pub full_snapshots: u64,
    /// Fetches answered with a values-only delta.
    pub delta_snapshots: u64,
    /// Phase-1 wall-clock seconds.
    pub phase1_secs: f64,
    /// Phase-2 wall-clock seconds.
    pub phase2_secs: f64,
}

/// What a completed run hands back.
#[derive(Debug)]
pub struct ServiceOutcome {
    /// Model at the end of phase 1.
    pub phase1_model: SparseMlp,
    /// Final model (union-averaged + re-sparsified when phase 2 ran).
    pub final_model: SparseMlp,
    /// Parameter-server statistics.
    pub server_stats: ServerStats,
    /// Transport statistics.
    pub coord: CoordStats,
}

/// Per-worker push-cadence tracker (pure: fed microsecond timestamps, so
/// it is unit-testable without clocks).
pub struct StragglerTracker {
    factor: f64,
    floor_us: u64,
    workers: BTreeMap<u32, Cadence>,
}

struct Cadence {
    last_us: u64,
    gaps: VecDeque<u64>,
    flagged: bool,
}

impl StragglerTracker {
    /// `factor`: flag when the current gap exceeds `factor × median gap`.
    pub fn new(factor: f64) -> StragglerTracker {
        StragglerTracker {
            factor,
            floor_us: 50_000, // never flag on gaps under 50 ms
            workers: BTreeMap::new(),
        }
    }

    /// Record a push from `worker` at `now_us`; clears any flag.
    pub fn observe(&mut self, worker: u32, now_us: u64) {
        let c = self.workers.entry(worker).or_insert(Cadence {
            last_us: now_us,
            gaps: VecDeque::new(),
            flagged: false,
        });
        let gap = now_us.saturating_sub(c.last_us);
        c.last_us = now_us;
        c.flagged = false;
        if gap > 0 {
            c.gaps.push_back(gap);
            if c.gaps.len() > 32 {
                c.gaps.pop_front();
            }
        }
    }

    /// Forget a departed worker.
    pub fn remove(&mut self, worker: u32) {
        self.workers.remove(&worker);
    }

    /// Workers newly overdue at `now_us` (each flagged once until it
    /// pushes again).
    pub fn check(&mut self, now_us: u64) -> Vec<u32> {
        let mut flagged = Vec::new();
        for (&w, c) in self.workers.iter_mut() {
            if c.flagged || c.gaps.len() < 8 {
                continue;
            }
            let mut sorted: Vec<u64> = c.gaps.iter().copied().collect();
            sorted.sort_unstable();
            let median = sorted[sorted.len() / 2];
            let threshold = ((median as f64 * self.factor) as u64).max(self.floor_us);
            if now_us.saturating_sub(c.last_us) > threshold {
                c.flagged = true;
                flagged.push(w);
            }
        }
        flagged
    }
}

#[derive(Default)]
struct ConnState {
    worker: Option<u32>,
    last_seq: u64,
    cached: Option<Vec<u8>>,
}

struct ParkedFetch {
    conn: u64,
    seq: u64,
    worker: u32,
    have_gen: u64,
    have_step: u64,
}

/// The coordinator service. Build with [`CoordinatorService::new`], then
/// drive to completion with [`CoordinatorService::run`].
pub struct CoordinatorService {
    ps: ParameterServer,
    pcfg: ParallelConfig,
    grad_clip: f32,
    sync_lr: LrSchedule,
    job_json: Option<String>,
    idle_timeout: Duration,
    supervision: Option<SupervisionPolicy>,

    conns: HashMap<u64, ConnState>,
    seen: BTreeSet<u32>,
    active: BTreeSet<u32>,
    /// Vanished workers held for rejoin (supervision), with the deadline
    /// after which each is abandoned.
    awaiting_rejoin: BTreeMap<u32, Instant>,
    /// Unique (deduplicated) Push requests dispatched per worker — the
    /// rejoin fast-forward cursor. One worker loop iteration consumes one
    /// batch and sends one push, so this count tells a respawned worker
    /// exactly how far to advance its data/RNG streams (DESIGN.md §13.4).
    /// Cleared on a clean Leave, kept across crashes.
    push_seen: BTreeMap<u32, u64>,
    /// Last time each active worker was heard from (any fresh request).
    last_heard: BTreeMap<u32, Instant>,
    topo_ring: VecDeque<(u64, Arc<SparseMlp>)>,
    pending_sync: BTreeMap<u32, (Vec<Vec<f32>>, Vec<Vec<f32>>)>,
    parked: Vec<ParkedFetch>,
    replicas: BTreeMap<u32, SparseMlp>,
    phase1_done: Option<(SparseMlp, ServerStats, Vec<usize>)>,
    straggler: StragglerTracker,
    stats: CoordStats,
    started: Instant,
    t_phase: Instant,
}

impl CoordinatorService {
    /// Build the service around an initial model. `job_json` is handed to
    /// joining workers (external processes need it; in-process workers
    /// already hold the job and get `None`).
    pub fn new(
        cfg: &TrainConfig,
        pcfg: &ParallelConfig,
        initial: SparseMlp,
        n_train: usize,
        job_json: Option<String>,
        opts: &CoordinatorOptions,
    ) -> CoordinatorService {
        let pushes_per_epoch = n_train.div_ceil(cfg.batch).max(1);
        // Asynchrony begets momentum (see run_parallel): K async workers
        // contribute an implicit ~1 − 1/K, so the explicit coefficient is
        // reduced to keep effective momentum at the configured value.
        let mut opt = cfg.optimizer;
        if !pcfg.synchronous && pcfg.workers > 1 {
            let k = pcfg.workers as f32;
            opt.momentum = (1.0 - (1.0 - opt.momentum) * k).max(0.0);
        }
        let ps = ParameterServer::new(
            initial,
            opt,
            cfg.evolution,
            cfg.importance,
            pushes_per_epoch,
            cfg.seed,
        );
        // WASSP learning rate lives server-side (Goyal warmup + linear
        // scaling, evaluated at the server epoch) so every contributor of
        // a step shares one rate.
        let base = match cfg.lr {
            LrSchedule::Constant(eta) => eta,
            other => other.at(0),
        };
        let sync_lr = LrSchedule::Warmup {
            base,
            scale: (pcfg.workers as f32).max(1.0).min(4.0),
            warmup_epochs: 5,
        };
        let now = Instant::now();
        let mut svc = CoordinatorService {
            ps,
            pcfg: *pcfg,
            grad_clip: pcfg.grad_clip,
            sync_lr,
            job_json,
            idle_timeout: opts.idle_timeout,
            supervision: opts.supervision,
            conns: HashMap::new(),
            seen: BTreeSet::new(),
            active: BTreeSet::new(),
            awaiting_rejoin: BTreeMap::new(),
            push_seen: BTreeMap::new(),
            last_heard: BTreeMap::new(),
            topo_ring: VecDeque::new(),
            pending_sync: BTreeMap::new(),
            parked: Vec::new(),
            replicas: BTreeMap::new(),
            phase1_done: None,
            straggler: StragglerTracker::new(opts.straggler_factor),
            stats: CoordStats::default(),
            started: now,
            t_phase: now,
        };
        svc.refresh_topo_ring();
        svc
    }

    fn now_us(&self) -> u64 {
        self.started.elapsed().as_micros() as u64
    }

    fn refresh_topo_ring(&mut self) {
        let snap = self.ps.fetch();
        if self.topo_ring.back().map(|(g, _)| *g) != Some(snap.gen) {
            self.topo_ring.push_back((snap.gen, snap.model));
            while self.topo_ring.len() > TOPO_RING {
                self.topo_ring.pop_front();
            }
        }
    }

    fn done(&self) -> bool {
        !self.seen.is_empty() && self.active.is_empty() && self.awaiting_rejoin.is_empty()
    }

    fn send_reply(
        &mut self,
        listener: &mut dyn Listener,
        conn: u64,
        worker: u32,
        seq: u64,
        msg: &Message,
    ) -> Result<()> {
        let frame = wire::encode_frame(worker, seq, msg);
        self.stats.frames_out += 1;
        self.stats.bytes_out += frame.len() as u64;
        if let Some(st) = self.conns.get_mut(&conn) {
            st.cached = Some(frame.clone());
        }
        listener.send(conn, &frame)
    }

    /// Drive the protocol until every joined worker has left; returns the
    /// phase-1 and final models.
    pub fn run(mut self, listener: &mut dyn Listener) -> Result<ServiceOutcome> {
        let mut last_activity = Instant::now();
        while !self.done() {
            self.check_liveness()?;
            match listener.recv(Duration::from_millis(50)) {
                Ok(Some((conn, Inbound::Frame(raw)))) => {
                    last_activity = Instant::now();
                    self.handle_frame(listener, conn, raw)?;
                    self.after_advance(listener)?;
                }
                Ok(Some((conn, Inbound::Closed))) => {
                    last_activity = Instant::now();
                    self.handle_closed(conn);
                    self.after_advance(listener)?;
                }
                Ok(None) => {
                    if last_activity.elapsed() > self.idle_timeout {
                        return Err(TsnnError::Transport(format!(
                            "coordinator idle for {:?} with {} active workers",
                            self.idle_timeout,
                            self.active.len()
                        )));
                    }
                    self.check_stragglers();
                    // an abandonment may have unblocked the sync barrier
                    self.after_advance(listener)?;
                }
                Err(e) => {
                    // listener died (e.g. all in-process clients dropped
                    // after a worker error); finish if finishable so the
                    // worker's own error surfaces instead of ours
                    if self.seen.is_empty() {
                        return Err(e);
                    }
                    break;
                }
            }
        }
        self.finalize()
    }

    fn handle_frame(
        &mut self,
        listener: &mut dyn Listener,
        conn: u64,
        raw: Vec<u8>,
    ) -> Result<()> {
        self.stats.frames_in += 1;
        self.stats.bytes_in += raw.len() as u64;
        let (h, msg) = match wire::decode_frame(&raw) {
            Ok(x) => x,
            Err(_) => {
                // an undecodable frame (e.g. injected truncation) is
                // dropped; the client retransmits and dedup handles it
                self.stats.decode_errors += 1;
                return Ok(());
            }
        };
        // request dedup: retransmits repeat the seq
        enum Disposition {
            Stale,
            Resend(Option<Vec<u8>>),
            Fresh,
        }
        let disposition = {
            let st = self.conns.entry(conn).or_default();
            if h.seq < st.last_seq {
                Disposition::Stale
            } else if h.seq == st.last_seq && st.last_seq != 0 {
                Disposition::Resend(st.cached.clone())
            } else {
                st.last_seq = h.seq;
                st.cached = None;
                Disposition::Fresh
            }
        };
        match disposition {
            Disposition::Stale => {
                self.stats.dup_requests += 1;
                Ok(())
            }
            Disposition::Resend(cached) => {
                self.stats.dup_requests += 1;
                if let Some(frame) = cached {
                    self.stats.frames_out += 1;
                    self.stats.bytes_out += frame.len() as u64;
                    listener.send(conn, &frame)?;
                }
                // no cached reply yet: the request is still in flight
                // (e.g. a parked sync fetch) — the reply goes out once
                Ok(())
            }
            Disposition::Fresh => self.dispatch(listener, conn, h.worker, h.seq, msg),
        }
    }

    fn dispatch(
        &mut self,
        listener: &mut dyn Listener,
        conn: u64,
        worker: u32,
        seq: u64,
        msg: Message,
    ) -> Result<()> {
        if !matches!(msg, Message::Join) {
            self.note_alive(conn, worker);
        }
        let reply = match msg {
            Message::Join => Some(self.handle_join(conn, worker)),
            Message::Ping => {
                self.stats.pings += 1;
                Some(Message::Pong)
            }
            Message::Fetch { have_gen, have_step } => {
                if self.phase1_done.is_none()
                    && have_step != NONE_U64
                    && self.ps.fetch().step <= have_step
                {
                    // synchronous worker waiting on the barrier: park the
                    // fetch; it is answered when the step advances
                    self.parked.push(ParkedFetch {
                        conn,
                        seq,
                        worker,
                        have_gen,
                        have_step,
                    });
                    None
                } else {
                    Some(Message::FetchAck(self.snapshot_reply(have_gen)))
                }
            }
            Message::Push(p) => {
                // counted per unique request (dedup already filtered
                // retransmits): this is the rejoin fast-forward cursor
                *self.push_seen.entry(worker).or_insert(0) += 1;
                Some(self.handle_push(worker, p)?)
            }
            Message::Replica { model } => Some(self.handle_replica(worker, model)),
            Message::Leave => {
                self.stats.leaves += 1;
                // a clean leave completes the worker's lifetime: a later
                // join under the same id starts from batch 0
                self.push_seen.remove(&worker);
                self.last_heard.remove(&worker);
                self.deactivate(worker, conn);
                Some(Message::LeaveAck)
            }
            // server-bound connections should never receive replies here
            _ => Some(Message::Err {
                message: "unexpected message kind".into(),
            }),
        };
        if let Some(m) = reply {
            self.send_reply(listener, conn, worker, seq, &m)?;
        }
        Ok(())
    }

    fn handle_join(&mut self, conn: u64, worker: u32) -> Message {
        if (worker as usize) >= self.pcfg.workers {
            return Message::Err {
                message: format!(
                    "worker id {worker} out of range (run has {} shards)",
                    self.pcfg.workers
                ),
            };
        }
        let usurp = self.active.contains(&worker);
        if usurp {
            if self.supervision.is_none() {
                return Message::Err {
                    message: format!("worker {worker} already joined"),
                };
            }
            // supervised respawn outracing the close notice for its
            // predecessor's connection: usurp the stale binding so the
            // old connection's eventual Closed is a no-op
            for st in self.conns.values_mut() {
                if st.worker == Some(worker) {
                    st.worker = None;
                }
            }
        }
        let rejoin = self.awaiting_rejoin.remove(&worker).is_some() || usurp;
        self.stats.joins += 1;
        if rejoin {
            self.stats.rejoins += 1;
            log::info!("worker {worker} rejoined");
        }
        self.seen.insert(worker);
        self.active.insert(worker);
        self.last_heard.insert(worker, Instant::now());
        if let Some(st) = self.conns.get_mut(&conn) {
            st.worker = Some(worker);
        }
        // resume cursor: pushes this id already had dispatched (kept
        // across crashes, cleared by a clean Leave) plus the step any
        // parked sync contribution waits at — a respawned worker replays
        // that many batches and parks its first fetch (DESIGN.md §13.4)
        let resume_pushes = self.push_seen.get(&worker).copied().unwrap_or(0);
        let resume_step = if self.pending_sync.contains_key(&worker) {
            self.ps.fetch().step
        } else {
            NONE_U64
        };
        Message::JoinAck {
            job: self.job_json.clone(),
            resume_pushes,
            resume_step,
        }
    }

    /// Any fresh request proves the sender alive; one arriving on the
    /// original connection of a presumed-dead worker resurrects it.
    fn note_alive(&mut self, conn: u64, worker: u32) {
        let bound = self.conns.get(&conn).and_then(|st| st.worker) == Some(worker);
        if !bound {
            return;
        }
        self.last_heard.insert(worker, Instant::now());
        if self.awaiting_rejoin.remove(&worker).is_some() {
            log::info!("worker {worker} resurfaced; restoring to the active set");
            self.active.insert(worker);
        }
    }

    /// Supervision sweep: presume silent workers dead, abandon vanished
    /// workers whose rejoin grace expired, abort on lost quorum.
    fn check_liveness(&mut self) -> Result<()> {
        let Some(sup) = self.supervision else {
            return Ok(());
        };
        let now = Instant::now();
        // presumed death: active and silent past dead_after, unless a
        // parked fetch shows the worker is waiting on *us*
        let silent: Vec<u32> = self
            .active
            .iter()
            .copied()
            .filter(|w| !self.parked.iter().any(|p| p.worker == *w))
            .filter(|w| {
                self.last_heard
                    .get(w)
                    .is_some_and(|t| now.duration_since(*t) > sup.dead_after)
            })
            .collect();
        for w in silent {
            self.stats.presumed_dead += 1;
            log::warn!(
                "worker {w} presumed dead after {:?} of silence; holding for rejoin",
                sup.dead_after
            );
            self.active.remove(&w);
            self.straggler.remove(w);
            self.awaiting_rejoin.insert(w, now + sup.rejoin_grace);
            // the connection stays bound: a request on it resurrects
        }
        // abandonment + quorum
        let expired: Vec<u32> = self
            .awaiting_rejoin
            .iter()
            .filter(|&(_, deadline)| now >= *deadline)
            .map(|(&w, _)| w)
            .collect();
        for w in expired {
            self.awaiting_rejoin.remove(&w);
            self.stats.abandoned += 1;
            log::warn!(
                "worker {w} abandoned (no rejoin within {:?}); continuing below strength",
                sup.rejoin_grace
            );
            // a stored sync contribution still counts once; the barrier
            // just stops waiting for this worker
            let remaining = self.active.len() + self.awaiting_rejoin.len();
            if remaining < sup.min_active {
                return Err(TsnnError::Transport(format!(
                    "quorum lost: {remaining} workers remain, {} required",
                    sup.min_active
                )));
            }
        }
        Ok(())
    }

    /// Build a fetch reply against the current phase/snapshot.
    fn snapshot_reply(&mut self, have_gen: u64) -> FetchAck {
        if let Some((phase1_model, _, _)) = &self.phase1_done {
            // phase 2: ship the full phase-1 model with optimizer state
            // (local training continues from the server's velocity)
            self.stats.full_snapshots += 1;
            return FetchAck {
                phase2: true,
                gen: 0,
                step: 0,
                epoch: self.ps.epoch() as u64,
                delta: ModelDelta::Full {
                    model: phase1_model.clone(),
                    velocity: true,
                },
            };
        }
        let snap = self.ps.fetch();
        let delta = if have_gen == snap.gen {
            self.stats.delta_snapshots += 1;
            ModelDelta::Values {
                values: snap
                    .model
                    .layers
                    .iter()
                    .map(|l| l.weights.values.to_vec())
                    .collect(),
                bias: snap.model.layers.iter().map(|l| l.bias.clone()).collect(),
            }
        } else {
            self.stats.full_snapshots += 1;
            ModelDelta::Full {
                model: (*snap.model).clone(),
                velocity: false,
            }
        };
        FetchAck {
            phase2: false,
            gen: snap.gen,
            step: snap.step,
            epoch: self.ps.epoch() as u64,
            delta,
        }
    }

    fn handle_push(&mut self, worker: u32, p: PushMsg) -> Result<Message> {
        let (step, epoch) = {
            let snap = self.ps.fetch();
            (snap.step, self.ps.epoch() as u64)
        };
        let ack = |status| Message::PushAck { status, step, epoch };
        if self.phase1_done.is_some() {
            // a push that raced past the phase boundary: acknowledged but
            // not applied (the next fetch moves the worker to phase 2)
            return Ok(ack(PushStatus::Ignored));
        }
        let Some(topo) = self
            .topo_ring
            .iter()
            .find(|(g, _)| *g == p.gen)
            .map(|(_, m)| Arc::clone(m))
        else {
            self.stats.rejected_stale_gen += 1;
            return Ok(ack(PushStatus::RejectedStaleGen));
        };
        // shape guard: transport input is untrusted
        let shape_ok = p.grad_w.len() == topo.layers.len()
            && p.grad_b.len() == topo.layers.len()
            && topo.layers.iter().enumerate().all(|(l, layer)| {
                p.grad_w[l].len() == layer.weights.nnz() && p.grad_b[l].len() == layer.bias.len()
            });
        if !shape_ok {
            self.stats.rejected_shape += 1;
            return Ok(ack(PushStatus::RejectedShape));
        }
        self.straggler.observe(worker, self.now_us());
        if p.sync {
            // WASSP contribution: parked until every active worker has
            // contributed; the finite guard runs on the averaged result
            // (matching the thread coordinator's single post-average clip)
            self.pending_sync.insert(worker, (p.grad_w, p.grad_b));
            return Ok(ack(PushStatus::Applied));
        }
        let applied = self.ps.push(
            SparseGradient {
                grad_w: p.grad_w,
                grad_b: p.grad_b,
                topo,
                gen: p.gen,
                fetched_step: p.fetched_step,
            },
            p.lr,
        )?;
        Ok(if applied {
            ack(PushStatus::Applied)
        } else {
            self.stats.rejected_nonfinite += 1;
            ack(PushStatus::RejectedNonFinite)
        })
    }

    fn handle_replica(&mut self, worker: u32, model: SparseMlp) -> Message {
        let reference = match &self.phase1_done {
            Some((m, _, _)) => m,
            None => {
                return Message::Err {
                    message: "replica upload before phase 1 finished".into(),
                }
            }
        };
        if model.sizes != reference.sizes {
            return Message::Err {
                message: "replica layer sizes do not match the run".into(),
            };
        }
        self.replicas.insert(worker, model);
        Message::ReplicaAck
    }

    fn deactivate(&mut self, worker: u32, conn: u64) {
        self.active.remove(&worker);
        self.straggler.remove(worker);
        // a parked fetch from a departed worker will never be answered
        self.parked.retain(|p| p.worker != worker);
        if let Some(st) = self.conns.get_mut(&conn) {
            st.worker = None;
        }
        // an already-stored sync contribution still counts once: the
        // work was done against the current step's snapshot
    }

    fn handle_closed(&mut self, conn: u64) {
        if let Some(st) = self.conns.get_mut(&conn) {
            if let Some(w) = st.worker.take() {
                self.stats.implicit_leaves += 1;
                self.active.remove(&w);
                self.straggler.remove(w);
                self.parked.retain(|p| p.worker != w);
                self.last_heard.remove(&w);
                if let Some(sup) = self.supervision {
                    log::warn!("worker {w} vanished; holding {:?} for rejoin", sup.rejoin_grace);
                    self.awaiting_rejoin
                        .insert(w, Instant::now() + sup.rejoin_grace);
                } else {
                    log::warn!("worker {w} disconnected without leaving");
                }
            }
        }
        self.conns.remove(&conn);
    }

    fn check_stragglers(&mut self) {
        if self.pcfg.synchronous || self.phase1_done.is_some() {
            return; // barrier waits are not straggling; phase 2 is local
        }
        for w in self.straggler.check(self.now_us()) {
            self.stats.stragglers_flagged += 1;
            log::warn!("worker {w} is straggling (push gap far above its median)");
        }
    }

    /// Post-dispatch bookkeeping: fire the sync barrier, cross the
    /// phase-1 boundary, refresh the topology ring, answer parked
    /// fetches.
    fn after_advance(&mut self, listener: &mut dyn Listener) -> Result<()> {
        // 1. synchronous barrier: every active worker contributed — and,
        // under supervision, every vanished worker being held for rejoin
        // (a respawn replays up to its counted pushes, so the barrier
        // waiting preserves the K-way average the reference run applies)
        if !self.pending_sync.is_empty()
            && self.phase1_done.is_none()
            && self
                .active
                .iter()
                .chain(self.awaiting_rejoin.keys())
                .all(|w| self.pending_sync.contains_key(w))
        {
            let n = self.pending_sync.len();
            let contributions: Vec<_> =
                std::mem::take(&mut self.pending_sync).into_values().collect();
            // identical float-op order to the thread coordinator: start
            // from worker 0's buffers, add the rest in worker order, then
            // scale, then clip once
            let mut it = contributions.into_iter();
            let (mut agg_w, mut agg_b) = it.next().expect("n >= 1");
            for (gw, gb) in it {
                for (a, g) in agg_w.iter_mut().zip(gw.iter()) {
                    for (x, y) in a.iter_mut().zip(g.iter()) {
                        *x += y;
                    }
                }
                for (a, g) in agg_b.iter_mut().zip(gb.iter()) {
                    for (x, y) in a.iter_mut().zip(g.iter()) {
                        *x += y;
                    }
                }
            }
            let inv_k = 1.0f32 / n as f32;
            for a in agg_w.iter_mut().flat_map(|v| v.iter_mut()) {
                *a *= inv_k;
            }
            for a in agg_b.iter_mut().flat_map(|v| v.iter_mut()) {
                *a *= inv_k;
            }
            clip_gradients(&mut agg_w, &mut agg_b, self.grad_clip);
            let lr = self.sync_lr.at(self.ps.epoch());
            self.ps.apply_aligned(&agg_w, &agg_b, lr)?;
        }

        // 2. phase-1 boundary
        if self.phase1_done.is_none() && self.ps.epoch() >= self.pcfg.phase1_epochs {
            let (model, stats) = self.ps.finish();
            let target_nnz = model.layers.iter().map(|l| l.weights.nnz()).collect();
            self.stats.phase1_secs = self.t_phase.elapsed().as_secs_f64();
            self.t_phase = Instant::now();
            self.pending_sync.clear();
            self.phase1_done = Some((model, stats, target_nnz));
        }

        // 3. topology ring
        self.refresh_topo_ring();

        // 4. parked fetches whose wait is over
        if !self.parked.is_empty() {
            let step = self.ps.fetch().step;
            let phase2 = self.phase1_done.is_some();
            let ready: Vec<ParkedFetch> = {
                let (ready, waiting) = std::mem::take(&mut self.parked)
                    .into_iter()
                    .partition(|p| phase2 || step > p.have_step);
                self.parked = waiting;
                ready
            };
            for p in ready {
                let ack = Message::FetchAck(self.snapshot_reply(p.have_gen));
                self.send_reply(listener, p.conn, p.worker, p.seq, &ack)?;
            }
        }
        Ok(())
    }

    fn finalize(mut self) -> Result<ServiceOutcome> {
        // elastic early end: if every worker left before the configured
        // phase-1 epochs, finish phase 1 with what was applied
        if self.phase1_done.is_none() {
            let (model, stats) = self.ps.finish();
            let target_nnz = model.layers.iter().map(|l| l.weights.nnz()).collect();
            self.stats.phase1_secs = self.t_phase.elapsed().as_secs_f64();
            self.t_phase = Instant::now();
            self.phase1_done = Some((model, stats, target_nnz));
        }
        let (phase1_model, server_stats, target_nnz) =
            self.phase1_done.take().expect("set above");
        let final_model = if self.replicas.is_empty() {
            phase1_model.clone()
        } else {
            // worker-id order = the thread coordinator's locals order
            let locals: Vec<SparseMlp> = std::mem::take(&mut self.replicas)
                .into_values()
                .collect();
            crate::coordinator::average_and_resparsify(&locals, &target_nnz)?
        };
        self.stats.phase2_secs = self.t_phase.elapsed().as_secs_f64();
        Ok(ServiceOutcome {
            phase1_model,
            final_model,
            server_stats,
            coord: self.stats,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straggler_tracker_flags_overdue_workers_once() {
        let mut t = StragglerTracker::new(10.0);
        // steady cadence: one push per 100ms for 10 pushes
        for i in 0..10u64 {
            t.observe(7, i * 100_000);
        }
        // just after the last push: nothing overdue
        assert!(t.check(950_000).is_empty());
        // 2s of silence >> 10 × 100ms median
        assert_eq!(t.check(2_900_000), vec![7]);
        // flagged once, not repeatedly
        assert!(t.check(3_900_000).is_empty());
        // a new push clears the flag and re-arms
        t.observe(7, 4_000_000);
        assert!(t.check(4_050_000).is_empty());
    }

    #[test]
    fn straggler_tracker_needs_history_and_respects_floor() {
        let mut t = StragglerTracker::new(10.0);
        // too few samples: never flags
        for i in 0..3u64 {
            t.observe(1, i * 1000);
        }
        assert!(t.check(10_000_000).is_empty());
        // tight cadence (1ms gaps): the 50ms floor suppresses flags at
        // 10×median = 10ms silence
        let mut t2 = StragglerTracker::new(10.0);
        for i in 0..20u64 {
            t2.observe(2, i * 1000);
        }
        assert!(t2.check(19_000 + 30_000).is_empty()); // 30ms < floor
        assert_eq!(t2.check(19_000 + 60_000), vec![2]); // 60ms > floor
    }

    #[test]
    fn removed_workers_are_forgotten() {
        let mut t = StragglerTracker::new(2.0);
        for i in 0..10u64 {
            t.observe(3, i * 100_000);
        }
        t.remove(3);
        assert!(t.check(100_000_000).is_empty());
    }
}
