//! In-process channel transport: mpsc-backed, zero-copy-ish, and the
//! reference implementation the socket transport must match bit-exactly.
//!
//! One [`ChannelHub`] lives on the coordinator thread; each worker
//! thread holds a [`ChannelClient`] from [`ChannelConnector::connect`].
//! Dropping a client delivers [`Inbound::Closed`] for its connection, so
//! a panicking worker thread reads as an implicit leave — the same
//! signal a dead worker process produces on the socket transport.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

use crate::error::{Result, TsnnError};

use super::{Inbound, Listener, Transport};

/// Coordinator side of the in-process transport.
pub struct ChannelHub {
    rx: Receiver<(u64, Inbound)>,
    reg_rx: Receiver<(u64, Sender<Vec<u8>>)>,
    conns: Vec<(u64, Sender<Vec<u8>>)>,
}

/// Cloneable connector handed to worker threads.
#[derive(Clone)]
pub struct ChannelConnector {
    tx: Sender<(u64, Inbound)>,
    reg_tx: Sender<(u64, Sender<Vec<u8>>)>,
    next: Arc<AtomicU64>,
}

/// Worker side of one in-process connection.
pub struct ChannelClient {
    conn: u64,
    tx: Sender<(u64, Inbound)>,
    rx: Receiver<Vec<u8>>,
}

impl ChannelHub {
    /// Create a hub and the connector that reaches it.
    pub fn new() -> (ChannelHub, ChannelConnector) {
        let (tx, rx) = channel();
        let (reg_tx, reg_rx) = channel();
        (
            ChannelHub {
                rx,
                reg_rx,
                conns: Vec::new(),
            },
            ChannelConnector {
                tx,
                reg_tx,
                next: Arc::new(AtomicU64::new(1)),
            },
        )
    }

    /// Pull newly-registered connections. Registration is enqueued before
    /// the client can send its first frame, so draining here first keeps
    /// `send` able to answer any frame `recv` returns.
    fn drain_registrations(&mut self) {
        loop {
            match self.reg_rx.try_recv() {
                Ok(pair) => self.conns.push(pair),
                Err(TryRecvError::Empty | TryRecvError::Disconnected) => break,
            }
        }
    }
}

impl Listener for ChannelHub {
    fn recv(&mut self, timeout: Duration) -> Result<Option<(u64, Inbound)>> {
        self.drain_registrations();
        match self.rx.recv_timeout(timeout) {
            Ok(ev) => {
                self.drain_registrations();
                Ok(Some(ev))
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(TsnnError::Transport(
                "all channel clients disconnected".into(),
            )),
        }
    }

    fn send(&mut self, conn: u64, frame: &[u8]) -> Result<()> {
        self.drain_registrations();
        if let Some((_, tx)) = self.conns.iter().find(|(id, _)| *id == conn) {
            // a dead receiver is not an error: its Closed event is the
            // authoritative signal and may already be queued
            let _ = tx.send(frame.to_vec());
        }
        Ok(())
    }
}

impl ChannelConnector {
    /// Open a new connection to the hub.
    pub fn connect(&self) -> ChannelClient {
        let conn = self.next.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = channel();
        // registration first: the hub drains registrations before
        // handling frames, so the reply path always exists
        let _ = self.reg_tx.send((conn, reply_tx));
        ChannelClient {
            conn,
            tx: self.tx.clone(),
            rx: reply_rx,
        }
    }
}

impl Transport for ChannelClient {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.tx
            .send((self.conn, Inbound::Frame(frame.to_vec())))
            .map_err(|_| TsnnError::Transport("coordinator hung up".into()))
    }

    fn recv(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        match self.rx.recv_timeout(timeout) {
            Ok(f) => Ok(Some(f)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                Err(TsnnError::Transport("coordinator hung up".into()))
            }
        }
    }
}

impl Drop for ChannelClient {
    fn drop(&mut self) {
        let _ = self.tx.send((self.conn, Inbound::Closed));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::wire::{encode_frame, Message};

    #[test]
    fn frames_flow_both_ways_and_drop_closes() {
        let (mut hub, connector) = ChannelHub::new();
        let mut a = connector.connect();
        let frame = encode_frame(0, 1, &Message::Join);
        a.send(&frame).unwrap();
        let (conn, ev) = hub.recv(Duration::from_secs(1)).unwrap().unwrap();
        match ev {
            Inbound::Frame(f) => assert_eq!(f, frame),
            Inbound::Closed => panic!("unexpected close"),
        }
        let reply = encode_frame(
            0,
            1,
            &Message::JoinAck {
                job: None,
                resume_pushes: 0,
                resume_step: u64::MAX,
            },
        );
        hub.send(conn, &reply).unwrap();
        assert_eq!(a.recv(Duration::from_secs(1)).unwrap().unwrap(), reply);

        drop(a);
        let (conn2, ev2) = hub.recv(Duration::from_secs(1)).unwrap().unwrap();
        assert_eq!(conn2, conn);
        assert!(matches!(ev2, Inbound::Closed));
        // sending to the dead connection is a no-op, not an error
        hub.send(conn, &reply).unwrap();
    }

    #[test]
    fn recv_times_out_quietly() {
        let (mut hub, _connector) = ChannelHub::new();
        assert!(hub.recv(Duration::from_millis(10)).unwrap().is_none());
    }
}
