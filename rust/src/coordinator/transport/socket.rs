//! Unix-socket / TCP transport: the same framed protocol as the channel
//! transport, over real byte streams between real processes.
//!
//! Each accepted connection gets a reader thread that reassembles frames
//! ([`HEADER_BYTES`]-prefixed, length-guarded — the header is validated
//! *before* the payload allocation) and forwards them to the hub's mpsc
//! queue, so [`SocketHub`] presents the same [`Listener`] surface as the
//! in-process hub. A read error or EOF becomes [`Inbound::Closed`] — a
//! dead worker process is an implicit leave, never a hang.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

use crate::error::{Result, TsnnError};

use super::wire::{decode_header, HEADER_BYTES};
use super::{Inbound, Listener, Transport};

/// A transport endpoint address.
#[derive(Debug, Clone)]
pub enum Addr {
    /// Unix-domain socket path.
    #[cfg(unix)]
    Unix(PathBuf),
    /// TCP `host:port`.
    Tcp(String),
}

impl std::fmt::Display for Addr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            #[cfg(unix)]
            Addr::Unix(p) => write!(f, "unix:{}", p.display()),
            Addr::Tcp(s) => write!(f, "tcp:{s}"),
        }
    }
}

#[cfg(unix)]
fn default_addr(spec: &str) -> Result<Addr> {
    Ok(Addr::Unix(PathBuf::from(spec)))
}

#[cfg(not(unix))]
fn default_addr(spec: &str) -> Result<Addr> {
    Err(TsnnError::Transport(format!(
        "unix sockets unavailable on this platform; use tcp:HOST:PORT (got '{spec}')"
    )))
}

/// Parse `tcp:HOST:PORT` or `unix:PATH` (a bare string means a unix path).
pub fn parse_addr(spec: &str) -> Result<Addr> {
    if let Some(hp) = spec.strip_prefix("tcp:") {
        if hp.is_empty() {
            return Err(TsnnError::Transport("empty tcp address".into()));
        }
        return Ok(Addr::Tcp(hp.to_string()));
    }
    default_addr(spec.strip_prefix("unix:").unwrap_or(spec))
}

enum Stream {
    #[cfg(unix)]
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    fn try_clone(&self) -> io::Result<Stream> {
        Ok(match self {
            #[cfg(unix)]
            Stream::Unix(s) => Stream::Unix(s.try_clone()?),
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
        })
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

/// Read one frame off a byte stream. `Ok(None)` on clean EOF at a frame
/// boundary; a malformed header is an error (the stream is desynced and
/// the connection must die — framing has no resync point).
fn read_frame(r: &mut impl Read) -> io::Result<Option<Vec<u8>>> {
    let mut header = [0u8; HEADER_BYTES];
    let mut got = 0;
    while got < HEADER_BYTES {
        let n = r.read(&mut header[got..])?;
        if n == 0 {
            return if got == 0 {
                Ok(None) // clean EOF between frames
            } else {
                Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "eof mid-header",
                ))
            };
        }
        got += n;
    }
    let h = decode_header(&header)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?;
    let mut frame = vec![0u8; HEADER_BYTES + h.len];
    frame[..HEADER_BYTES].copy_from_slice(&header);
    r.read_exact(&mut frame[HEADER_BYTES..])?;
    Ok(Some(frame))
}

/// Coordinator side of the socket transport.
pub struct SocketHub {
    rx: Receiver<(u64, Inbound)>,
    reg_rx: Receiver<(u64, Stream)>,
    conns: HashMap<u64, Stream>,
    shutdown: Arc<AtomicBool>,
    cleanup: Option<PathBuf>,
    /// Actual `host:port` for TCP binds (resolves `:0` to the real port).
    pub local_tcp: Option<String>,
}

impl SocketHub {
    /// Bind and start accepting connections on a background thread.
    pub fn bind(addr: &Addr) -> Result<SocketHub> {
        let (tx, rx) = channel::<(u64, Inbound)>();
        let (reg_tx, reg_rx) = channel::<(u64, Stream)>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let cleanup;
        let mut local_tcp = None;
        match addr {
            #[cfg(unix)]
            Addr::Unix(path) => {
                // a stale socket file from a previous run blocks bind
                let _ = std::fs::remove_file(path);
                let listener = UnixListener::bind(path)?;
                listener.set_nonblocking(true)?;
                cleanup = Some(path.clone());
                spawn_acceptor(shutdown.clone(), tx, reg_tx, move || {
                    listener.accept().map(|(s, _)| {
                        s.set_nonblocking(false).map(|()| Stream::Unix(s))
                    })
                });
            }
            Addr::Tcp(hostport) => {
                let listener = TcpListener::bind(hostport)?;
                listener.set_nonblocking(true)?;
                local_tcp = listener.local_addr().ok().map(|a| a.to_string());
                cleanup = None;
                spawn_acceptor(shutdown.clone(), tx, reg_tx, move || {
                    listener.accept().map(|(s, _)| {
                        s.set_nonblocking(false)
                            .and_then(|()| s.set_nodelay(true))
                            .map(|()| Stream::Tcp(s))
                    })
                });
            }
        }
        Ok(SocketHub {
            rx,
            reg_rx,
            conns: HashMap::new(),
            shutdown,
            cleanup,
            local_tcp,
        })
    }

    fn drain_registrations(&mut self) {
        loop {
            match self.reg_rx.try_recv() {
                Ok((id, s)) => {
                    self.conns.insert(id, s);
                }
                Err(TryRecvError::Empty | TryRecvError::Disconnected) => break,
            }
        }
    }
}

/// Accept loop: `accept` yields a ready connection or `WouldBlock`.
fn spawn_acceptor(
    shutdown: Arc<AtomicBool>,
    tx: Sender<(u64, Inbound)>,
    reg_tx: Sender<(u64, Stream)>,
    mut accept: impl FnMut() -> io::Result<io::Result<Stream>> + Send + 'static,
) {
    std::thread::spawn(move || {
        let mut next_conn = 1u64;
        while !shutdown.load(Ordering::Relaxed) {
            match accept() {
                Ok(Ok(stream)) => {
                    let conn = next_conn;
                    next_conn += 1;
                    let Ok(writer) = stream.try_clone() else {
                        continue;
                    };
                    if reg_tx.send((conn, writer)).is_err() {
                        return; // hub gone
                    }
                    let tx = tx.clone();
                    std::thread::spawn(move || {
                        let mut stream = stream;
                        loop {
                            match read_frame(&mut stream) {
                                Ok(Some(frame)) => {
                                    if tx.send((conn, Inbound::Frame(frame))).is_err() {
                                        return;
                                    }
                                }
                                Ok(None) | Err(_) => {
                                    let _ = tx.send((conn, Inbound::Closed));
                                    return;
                                }
                            }
                        }
                    });
                }
                Ok(Err(_)) => {} // handshake-time setup failure: drop it
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(_) => return,
            }
        }
    });
}

impl Listener for SocketHub {
    fn recv(&mut self, timeout: Duration) -> Result<Option<(u64, Inbound)>> {
        self.drain_registrations();
        match self.rx.recv_timeout(timeout) {
            Ok(ev) => {
                self.drain_registrations();
                Ok(Some(ev))
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                Err(TsnnError::Transport("socket acceptor died".into()))
            }
        }
    }

    fn send(&mut self, conn: u64, frame: &[u8]) -> Result<()> {
        self.drain_registrations();
        if let Some(s) = self.conns.get_mut(&conn) {
            // write failure = peer died mid-reply; its Closed event is
            // (or will be) queued by the reader thread
            if s.write_all(frame).and_then(|()| s.flush()).is_err() {
                self.conns.remove(&conn);
            }
        }
        Ok(())
    }
}

impl Drop for SocketHub {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(path) = &self.cleanup {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Worker side of a socket connection.
pub struct SocketClient {
    writer: Stream,
    rx: Receiver<Vec<u8>>,
}

impl SocketClient {
    /// Connect to a coordinator.
    pub fn connect(addr: &Addr) -> Result<SocketClient> {
        let stream = match addr {
            #[cfg(unix)]
            Addr::Unix(path) => Stream::Unix(UnixStream::connect(path)?),
            Addr::Tcp(hostport) => {
                let s = TcpStream::connect(hostport)?;
                s.set_nodelay(true)?;
                Stream::Tcp(s)
            }
        };
        let writer = stream.try_clone()?;
        let (tx, rx) = channel();
        std::thread::spawn(move || {
            let mut stream = stream;
            while let Ok(Some(frame)) = read_frame(&mut stream) {
                if tx.send(frame).is_err() {
                    return;
                }
            }
            // sender dropped here: recv() reports Disconnected
        });
        Ok(SocketClient { writer, rx })
    }

    /// Connect, retrying with exponential backoff while the coordinator
    /// is not (yet) listening. Covers the startup race where a worker
    /// process launches before the coordinator binds, and a supervisor
    /// respawn racing a coordinator restart. Gives up after `timeout`.
    pub fn connect_retry(addr: &Addr, timeout: Duration) -> Result<SocketClient> {
        let start = std::time::Instant::now();
        let mut delay = Duration::from_millis(50);
        loop {
            match SocketClient::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if start.elapsed() + delay > timeout {
                        return Err(TsnnError::Transport(format!(
                            "no coordinator at {addr} after {timeout:?}: {e}"
                        )));
                    }
                    std::thread::sleep(delay);
                    delay = (delay * 2).min(Duration::from_secs(2));
                }
            }
        }
    }
}

impl Transport for SocketClient {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.writer
            .write_all(frame)
            .and_then(|()| self.writer.flush())
            .map_err(|e| TsnnError::Transport(format!("socket send: {e}")))
    }

    fn recv(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        match self.rx.recv_timeout(timeout) {
            Ok(f) => Ok(Some(f)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(TsnnError::Transport(
                "coordinator closed the connection".into(),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::wire::{encode_frame, Message};

    fn roundtrip_over(addr: Addr) {
        let mut hub = SocketHub::bind(&addr).unwrap();
        let addr = match (&addr, &hub.local_tcp) {
            (Addr::Tcp(_), Some(actual)) => Addr::Tcp(actual.clone()),
            _ => addr,
        };
        let mut client = SocketClient::connect(&addr).unwrap();
        let frame = encode_frame(3, 1, &Message::Fetch {
            have_gen: 0,
            have_step: u64::MAX,
        });
        client.send(&frame).unwrap();
        let (conn, ev) = hub.recv(Duration::from_secs(5)).unwrap().unwrap();
        match ev {
            Inbound::Frame(f) => assert_eq!(f, frame),
            Inbound::Closed => panic!("unexpected close"),
        }
        let reply = encode_frame(3, 1, &Message::LeaveAck);
        hub.send(conn, &reply).unwrap();
        assert_eq!(client.recv(Duration::from_secs(5)).unwrap().unwrap(), reply);

        drop(client);
        let (conn2, ev2) = hub.recv(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(conn2, conn);
        assert!(matches!(ev2, Inbound::Closed));
    }

    #[test]
    fn tcp_roundtrip() {
        // port 0: the OS picks a free port; local_tcp reports it
        roundtrip_over(Addr::Tcp("127.0.0.1:0".into()));
    }

    #[cfg(unix)]
    #[test]
    fn unix_roundtrip_and_stale_socket_cleanup() {
        let path = std::env::temp_dir().join("tsnn_sock_test.sock");
        std::fs::write(&path, b"stale").unwrap(); // stale file must not block bind
        roundtrip_over(Addr::Unix(path.clone()));
        assert!(!path.exists(), "hub drop should remove the socket file");
    }

    #[cfg(unix)]
    #[test]
    fn connect_retry_waits_for_late_coordinator() {
        let path = std::env::temp_dir().join("tsnn_sock_retry_test.sock");
        let _ = std::fs::remove_file(&path);
        let addr = Addr::Unix(path.clone());
        let addr2 = addr.clone();
        let binder = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(300));
            SocketHub::bind(&addr2).unwrap()
        });
        // starts connecting while nothing is listening yet
        let mut client = SocketClient::connect_retry(&addr, Duration::from_secs(10)).unwrap();
        let mut hub = binder.join().unwrap();
        let frame = encode_frame(0, 1, &Message::Ping);
        client.send(&frame).unwrap();
        let (_, ev) = hub.recv(Duration::from_secs(5)).unwrap().unwrap();
        assert!(matches!(ev, Inbound::Frame(f) if f == frame));

        // and an endpoint that never appears is a typed timeout
        let missing = Addr::Unix(std::env::temp_dir().join("tsnn_never_bound.sock"));
        assert!(SocketClient::connect_retry(&missing, Duration::from_millis(200)).is_err());
    }

    #[test]
    fn addr_parsing() {
        assert!(matches!(parse_addr("tcp:127.0.0.1:9000"), Ok(Addr::Tcp(_))));
        assert!(parse_addr("tcp:").is_err());
        #[cfg(unix)]
        {
            assert!(matches!(parse_addr("unix:/tmp/x.sock"), Ok(Addr::Unix(_))));
            assert!(matches!(parse_addr("/tmp/x.sock"), Ok(Addr::Unix(_))));
        }
    }
}
