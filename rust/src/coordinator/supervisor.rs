//! Child-process supervision for multi-process parallel runs
//! (DESIGN.md §13.3).
//!
//! A [`Supervisor`] owns one slot per worker shard, spawns the initial
//! `tsnn worker` process for each, and monitors them from a background
//! thread. A child that exits *cleanly* (status 0) finished its worker
//! lifetime and is left alone; a child that dies any other way (crash,
//! SIGKILL, panic) is respawned after an exponentially-backed-off delay,
//! up to a bounded per-slot restart budget. The respawned process goes
//! through the ordinary join path and is re-admitted by the coordinator's
//! supervision state machine with a resume cursor, so the applied-update
//! trajectory is preserved (pinned by `tests/chaos.rs`).
//!
//! The supervisor is deliberately transport-agnostic: it knows how to
//! *spawn* a worker (a caller-supplied closure) and nothing about the
//! protocol. Crash detection on the coordinator side rides the existing
//! connection-close / heartbeat machinery.

use std::process::Child;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Result, TsnnError};

/// Bounded-restart policy with exponential backoff.
#[derive(Debug, Clone, Copy)]
pub struct RestartPolicy {
    /// Respawn budget per worker slot; exceeding it abandons the slot
    /// (the coordinator's rejoin grace then decides the run's fate).
    pub max_restarts: usize,
    /// Delay before the first respawn of a slot.
    pub backoff: Duration,
    /// Delay multiplier for successive respawns of the same slot.
    pub factor: f64,
}

impl Default for RestartPolicy {
    fn default() -> Self {
        RestartPolicy {
            max_restarts: 3,
            backoff: Duration::from_millis(200),
            factor: 2.0,
        }
    }
}

/// What one slot's lifetime looked like.
#[derive(Debug, Clone, Copy, Default)]
pub struct SlotReport {
    /// Respawns performed.
    pub restarts: usize,
    /// `true` once the slot's process exited with status 0.
    pub clean_exit: bool,
    /// `true` if the restart budget ran out with the process still dead.
    pub abandoned: bool,
}

struct Slot {
    worker: u32,
    child: Option<Child>,
    restarts: usize,
    /// When a pending respawn fires (backoff in progress).
    respawn_at: Option<Instant>,
    clean_exit: bool,
    abandoned: bool,
}

/// Spawns a worker process for slot `k`. Must be cheap to call again —
/// respawns reuse it verbatim.
pub type SpawnFn = dyn Fn(u32) -> std::io::Result<Child> + Send + 'static;

/// Handle to the monitor thread. Call [`Supervisor::finish`] after the
/// coordinator run returns.
pub struct Supervisor {
    shutdown: Arc<AtomicBool>,
    slots: Arc<Mutex<Vec<Slot>>>,
    monitor: Option<std::thread::JoinHandle<()>>,
}

impl Supervisor {
    /// Spawn the initial process for every slot and start monitoring.
    pub fn start(
        workers: usize,
        policy: RestartPolicy,
        spawn: Box<SpawnFn>,
    ) -> Result<Supervisor> {
        let mut slots = Vec::with_capacity(workers);
        for k in 0..workers {
            let child = spawn(k as u32).map_err(|e| {
                TsnnError::Transport(format!("spawning worker {k}: {e}"))
            })?;
            slots.push(Slot {
                worker: k as u32,
                child: Some(child),
                restarts: 0,
                respawn_at: None,
                clean_exit: false,
                abandoned: false,
            });
        }
        let slots = Arc::new(Mutex::new(slots));
        let shutdown = Arc::new(AtomicBool::new(false));
        let monitor = {
            let slots = Arc::clone(&slots);
            let shutdown = Arc::clone(&shutdown);
            std::thread::spawn(move || {
                while !shutdown.load(Ordering::Relaxed) {
                    sweep(&slots, &policy, spawn.as_ref());
                    std::thread::sleep(Duration::from_millis(50));
                }
            })
        };
        Ok(Supervisor {
            shutdown,
            slots,
            monitor: Some(monitor),
        })
    }

    /// Stop respawning, reap every remaining child (killing any that
    /// outlive `grace` — after a successful run they exit on their own),
    /// and report per-slot restart activity.
    pub fn finish(mut self, grace: Duration) -> Vec<SlotReport> {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(m) = self.monitor.take() {
            let _ = m.join();
        }
        let mut slots = self.slots.lock().expect("supervisor mutex");
        let deadline = Instant::now() + grace;
        for slot in slots.iter_mut() {
            let Some(child) = slot.child.as_mut() else {
                continue;
            };
            loop {
                match child.try_wait() {
                    Ok(Some(status)) => {
                        slot.clean_exit = status.success();
                        break;
                    }
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    _ => {
                        log::warn!("killing worker process {}", slot.worker);
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
            slot.child = None;
        }
        slots
            .iter()
            .map(|s| SlotReport {
                restarts: s.restarts,
                clean_exit: s.clean_exit,
                abandoned: s.abandoned,
            })
            .collect()
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(m) = self.monitor.take() {
            let _ = m.join();
        }
        // leave children to the caller's finish(); on a panic path, kill
        // them so a failed run never leaks worker processes
        if let Ok(mut slots) = self.slots.lock() {
            for slot in slots.iter_mut() {
                if let Some(mut child) = slot.child.take() {
                    let _ = child.kill();
                    let _ = child.wait();
                }
            }
        }
    }
}

/// One monitor pass: reap exits, schedule and fire respawns.
fn sweep(slots: &Mutex<Vec<Slot>>, policy: &RestartPolicy, spawn: &SpawnFn) {
    let mut slots = slots.lock().expect("supervisor mutex");
    let now = Instant::now();
    for slot in slots.iter_mut() {
        // fire a due respawn
        if slot.respawn_at.is_some_and(|t| now >= t) {
            slot.respawn_at = None;
            match spawn(slot.worker) {
                Ok(child) => {
                    slot.restarts += 1;
                    log::warn!(
                        "respawned worker {} (restart {}/{})",
                        slot.worker,
                        slot.restarts,
                        policy.max_restarts
                    );
                    slot.child = Some(child);
                }
                Err(e) => {
                    log::warn!("respawn of worker {} failed: {e}", slot.worker);
                    slot.abandoned = true;
                }
            }
            continue;
        }
        let Some(child) = slot.child.as_mut() else {
            continue;
        };
        match child.try_wait() {
            Ok(Some(status)) if status.success() => {
                // worker lifetime complete: never respawn a clean exit
                slot.clean_exit = true;
                slot.child = None;
            }
            Ok(Some(status)) => {
                slot.child = None;
                if slot.restarts >= policy.max_restarts {
                    log::warn!(
                        "worker {} died ({status}) with restart budget exhausted",
                        slot.worker
                    );
                    slot.abandoned = true;
                } else {
                    let delay = policy
                        .backoff
                        .mul_f64(policy.factor.powi(slot.restarts as i32));
                    log::warn!(
                        "worker {} died ({status}); respawn in {delay:?}",
                        slot.worker
                    );
                    slot.respawn_at = Some(now + delay);
                }
            }
            Ok(None) => {}  // still running
            Err(e) => log::warn!("polling worker {}: {e}", slot.worker),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spawn_sleep(secs: &str) -> std::io::Result<Child> {
        std::process::Command::new("sleep").arg(secs).spawn()
    }

    #[test]
    fn clean_exits_are_not_respawned() {
        let sup = Supervisor::start(
            2,
            RestartPolicy::default(),
            Box::new(|_| spawn_sleep("0")),
        )
        .unwrap();
        std::thread::sleep(Duration::from_millis(400));
        let reports = sup.finish(Duration::from_secs(2));
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert_eq!(r.restarts, 0, "clean exit must not trigger a respawn");
            assert!(r.clean_exit);
            assert!(!r.abandoned);
        }
    }

    #[test]
    fn crashes_are_respawned_within_budget() {
        // `false` exits 1 immediately: every death burns one restart
        let policy = RestartPolicy {
            max_restarts: 2,
            backoff: Duration::from_millis(10),
            factor: 2.0,
        };
        let sup = Supervisor::start(
            1,
            policy,
            Box::new(|_| std::process::Command::new("false").spawn()),
        )
        .unwrap();
        std::thread::sleep(Duration::from_millis(800));
        let reports = sup.finish(Duration::from_secs(2));
        assert_eq!(reports[0].restarts, 2, "budget of 2 must be fully used");
        assert!(reports[0].abandoned, "budget exhaustion abandons the slot");
    }

    #[test]
    fn finish_kills_stragglers_after_grace() {
        let sup = Supervisor::start(
            1,
            RestartPolicy::default(),
            Box::new(|_| spawn_sleep("600")),
        )
        .unwrap();
        let t0 = Instant::now();
        let reports = sup.finish(Duration::from_millis(100));
        assert!(t0.elapsed() < Duration::from_secs(30));
        assert!(!reports[0].clean_exit);
    }
}
