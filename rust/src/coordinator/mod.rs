//! WASAP-SGD / WASSP-SGD — the paper's parallel training contribution.
//!
//! Two-phase data-parallel training of truly-sparse models over a
//! shared-memory parameter server (the single-machine MPI setup of the
//! paper, realised with OS threads — see DESIGN.md §3):
//!
//! * **Phase 1** — K workers repeatedly fetch the model, compute a sparse
//!   gradient on a mini-batch of their shard, and push it. *WASAP* pushes
//!   asynchronously (no barrier; staleness handled by
//!   `RetainValidUpdates`); *WASSP* synchronises every step and averages
//!   the K gradients (with Goyal-style warmup + linear LR scaling).
//!   The server runs SET topology evolution every `n ÷ B` pushes.
//! * **Phase 2** — each worker trains its replica locally (topology
//!   evolving independently), after which the models are averaged over
//!   the union topology and magnitude-pruned back to the sparsity budget
//!   (Stochastic-Weight-Averaging-style generalisation boost).

pub mod average;
pub mod server;

use std::sync::Arc;

use crate::config::TrainConfig;
use crate::data::Dataset;
use crate::error::{Result, TsnnError};
use crate::model::Batcher;
use crate::model::SparseMlp;
use crate::nn::LrSchedule;
use crate::train::{self, TrainOptions};
use crate::util::{PhaseTimes, Rng, Timer};

pub use average::average_and_resparsify;
pub use server::{ParameterServer, ServerStats, Snapshot, SparseGradient};

/// Parallel-training configuration.
#[derive(Debug, Clone, Copy)]
pub struct ParallelConfig {
    /// Worker count K (paper: physical cores − 1).
    pub workers: usize,
    /// Epochs of phase 1 (τ₁).
    pub phase1_epochs: usize,
    /// Epochs of phase 2 (τ₂ − τ₁).
    pub phase2_epochs: usize,
    /// Synchronous phase 1 (WASSP) instead of asynchronous (WASAP).
    pub synchronous: bool,
    /// Wrap a constant LR into the paper's hot-start schedule for WASAP
    /// phase 1 ("benefits from larger learning rates for the first few
    /// epochs", §2.3). Disable when the caller tunes the schedule itself.
    pub hot_start: bool,
    /// L2 gradient clipping applied worker-side before each push (0 = off).
    /// Stabilises hot-start async SGD against stale-gradient overshoot.
    pub grad_clip: f32,
}

impl Default for ParallelConfig {
    fn default() -> Self {
        ParallelConfig {
            workers: 5,
            phase1_epochs: 20,
            phase2_epochs: 5,
            synchronous: false,
            hot_start: true,
            grad_clip: 5.0,
        }
    }
}

/// Scale all gradient buffers so the global L2 norm is at most `clip`.
fn clip_gradients(grad_w: &mut [Vec<f32>], grad_b: &mut [Vec<f32>], clip: f32) {
    if clip <= 0.0 {
        return;
    }
    let norm_sq: f32 = grad_w
        .iter()
        .chain(grad_b.iter())
        .flat_map(|g| g.iter())
        .map(|g| g * g)
        .sum();
    let norm = norm_sq.sqrt();
    if norm > clip && norm.is_finite() {
        let scale = clip / norm;
        for g in grad_w.iter_mut().chain(grad_b.iter_mut()) {
            for v in g.iter_mut() {
                *v *= scale;
            }
        }
    }
}

/// Result of a parallel run.
#[derive(Debug)]
pub struct ParallelReport {
    /// The final (averaged, re-sparsified) model.
    pub model: SparseMlp,
    /// Test accuracy after phase 1 (pre-averaging).
    pub phase1_test_accuracy: f32,
    /// Final test accuracy of the averaged model.
    pub final_test_accuracy: f32,
    /// Weights at start.
    pub start_weights: usize,
    /// Weights at end.
    pub end_weights: usize,
    /// Server-side statistics (staleness, dropped updates, ...).
    pub server_stats: ServerStats,
    /// Wall-clock per phase.
    pub phases: PhaseTimes,
}

/// Per-worker kernel-shard budgets: the machine's thread budget (the
/// config's `kernel_threads` knob, `0` = all cores) divided across the
/// K data-parallel workers with the division remainder distributed one
/// core per worker from the front — so the budgets sum to the resolved
/// total whenever `K ≤ total` (the old flooring division stranded
/// `total mod K` cores; e.g. 8 cores / 3 workers gave 2+2+2, leaving 2
/// idle — now 3+3+2). Each worker's `Workspace` turns its budget into a
/// persistent kernel sub-pool (DESIGN.md §9.4), so K workers × pool
/// shards never oversubscribes the host.
fn worker_kernel_budgets(cfg: &TrainConfig, workers: usize) -> Vec<usize> {
    let workers = workers.max(1);
    let total = crate::sparse::ops::resolve_threads(cfg.kernel_threads);
    let (base, rem) = (total / workers, total % workers);
    (0..workers)
        .map(|k| (base + usize::from(k < rem)).max(1))
        .collect()
}

fn shard_bounds(n: usize, workers: usize, k: usize) -> (usize, usize) {
    let per = n / workers;
    let lo = k * per;
    let hi = if k + 1 == workers { n } else { lo + per };
    (lo, hi)
}

/// Build a worker-local dataset containing only its shard of train data
/// (test split shared for evaluation convenience).
fn shard_dataset(data: &Dataset, lo: usize, hi: usize) -> Dataset {
    let nf = data.n_features;
    Dataset {
        name: format!("{}[{}..{}]", data.name, lo, hi),
        n_features: nf,
        n_classes: data.n_classes,
        x_train: data.x_train[lo * nf..hi * nf].to_vec(),
        y_train: data.y_train[lo..hi].to_vec(),
        x_test: data.x_test.clone(),
        y_test: data.y_test.clone(),
    }
}

/// Run WASAP-SGD (or WASSP-SGD when `pcfg.synchronous`).
pub fn run_parallel(
    cfg: &TrainConfig,
    pcfg: &ParallelConfig,
    data: &Dataset,
    rng: &mut Rng,
) -> Result<ParallelReport> {
    if pcfg.workers == 0 {
        return Err(TsnnError::Coordinator("need at least one worker".into()));
    }
    let mut phases = PhaseTimes::new();
    let sizes = cfg.sizes(data.n_features, data.n_classes);
    let model = phases.time("init", || {
        SparseMlp::new(&sizes, cfg.epsilon, cfg.activation, &cfg.init, rng)
    })?;
    let start_weights = model.weight_count();

    let pushes_per_epoch = data.n_train().div_ceil(cfg.batch);
    // Asynchrony begets momentum (Mitliagkas et al., cited by the paper):
    // K async workers contribute an implicit momentum of ~1 − 1/K, so the
    // explicit coefficient is reduced to keep the *effective* momentum at
    // the configured value: μ_explicit = 1 − (1 − μ)·K, clamped at 0.
    // Without this, μ=0.9 with K≥3 exceeds effective momentum 1 and the
    // server model diverges to a constant predictor.
    let mut opt = cfg.optimizer;
    if !pcfg.synchronous && pcfg.workers > 1 {
        let k = pcfg.workers as f32;
        opt.momentum = (1.0 - (1.0 - opt.momentum) * k).max(0.0);
    }
    let ps = ParameterServer::new(
        model,
        opt,
        cfg.evolution,
        cfg.importance,
        pushes_per_epoch,
        cfg.seed,
    );

    // ---- phase 1 ----
    let t1 = Timer::start();
    if pcfg.synchronous {
        run_phase1_sync(cfg, pcfg, data, &ps)?;
    } else {
        run_phase1_async(cfg, pcfg, data, &ps)?;
    }
    phases.add("phase1", t1.secs());

    let (phase1_model, server_stats) = ps.finish();
    // The averaging step restores the sparsity budget of the *phase-1*
    // model, so Importance Pruning reductions made during phase 1 persist
    // through phase 2's union-average.
    let target_nnz: Vec<usize> = phase1_model
        .layers
        .iter()
        .map(|l| l.weights.nnz())
        .collect();
    let mut ws = phase1_model.alloc_workspace(256);
    let (_, phase1_acc) = phases.time("test", || {
        phase1_model.evaluate(&data.x_test, &data.y_test, 256, &mut ws)
    });

    // ---- phase 2: local training per worker, then averaging ----
    let t2 = Timer::start();
    let final_model = if pcfg.phase2_epochs > 0 {
        let mut locals: Vec<SparseMlp> = Vec::with_capacity(pcfg.workers);
        let budgets = worker_kernel_budgets(cfg, pcfg.workers);
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for k in 0..pcfg.workers {
                let (lo, hi) = shard_bounds(data.n_train(), pcfg.workers, k);
                let shard = shard_dataset(data, lo, hi);
                let mut local_cfg = cfg.clone();
                local_cfg.epochs = pcfg.phase2_epochs;
                local_cfg.eval_every = 0; // no test eval inside workers
                local_cfg.kernel_threads = budgets[k];
                let mut local_model = phase1_model.clone();
                let mut local_rng = Rng::new(cfg.seed).split(1000 + k as u64);
                handles.push(scope.spawn(move || -> Result<SparseMlp> {
                    let mut local_phases = PhaseTimes::new();
                    train::train_model(
                        &local_cfg,
                        &shard,
                        &mut local_model,
                        &mut local_rng,
                        TrainOptions::default(),
                        &mut local_phases,
                    )?;
                    Ok(local_model)
                }));
            }
            for h in handles {
                locals.push(h.join().map_err(|_| {
                    TsnnError::Coordinator("phase-2 worker panicked".into())
                })??);
            }
            Ok(())
        })?;
        average_and_resparsify(&locals, &target_nnz)?
    } else {
        phase1_model
    };
    phases.add("phase2", t2.secs());

    let mut ws = final_model.alloc_workspace(256);
    let (_, final_acc) = phases.time("test", || {
        final_model.evaluate(&data.x_test, &data.y_test, 256, &mut ws)
    });

    Ok(ParallelReport {
        end_weights: final_model.weight_count(),
        start_weights,
        phase1_test_accuracy: phase1_acc,
        final_test_accuracy: final_acc,
        server_stats,
        phases,
        model: final_model,
    })
}

/// Phase 1, asynchronous (WASAP): workers fetch/push with no barrier.
fn run_phase1_async(
    cfg: &TrainConfig,
    pcfg: &ParallelConfig,
    data: &Dataset,
    ps: &ParameterServer,
) -> Result<()> {
    // WASAP benefits from a hot-start LR (paper §2.3); respect an explicit
    // schedule if the caller set one, otherwise wrap the constant rate.
    let schedule = match cfg.lr {
        LrSchedule::Constant(eta) if pcfg.hot_start => LrSchedule::HotStart {
            hot: eta * 2.0,
            base: eta,
            hot_epochs: 3,
        },
        other => other,
    };
    let budgets = worker_kernel_budgets(cfg, pcfg.workers);
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for k in 0..pcfg.workers {
            let (lo, hi) = shard_bounds(data.n_train(), pcfg.workers, k);
            let kernel_threads = budgets[k];
            let mut rng = Rng::new(cfg.seed).split(k as u64);
            let dropout = if cfg.dropout > 0.0 {
                Some(crate::nn::Dropout::new(cfg.dropout))
            } else {
                None
            };
            handles.push(scope.spawn(move || -> Result<()> {
                let mut batcher = Batcher::shard(data.n_train(), data.n_features, cfg.batch, lo, hi);
                batcher.reset(&mut rng);
                // Worker-owned persistent kernel sub-pool for the whole
                // phase (DESIGN.md §9.4): the workspace spawns it on the
                // first dispatch and parks it between steps.
                let mut ws = crate::model::Workspace::with_threads(kernel_threads);
                loop {
                    let epoch = ps.epoch();
                    if epoch >= pcfg.phase1_epochs {
                        return Ok(());
                    }
                    let snap = ps.fetch();
                    let batch = match batcher.next_batch(&data.x_train, &data.y_train) {
                        Some(b) => b,
                        None => {
                            batcher.reset(&mut rng);
                            batcher.next_batch(&data.x_train, &data.y_train).unwrap()
                        }
                    };
                    snap.model
                        .compute_gradients(batch.0, batch.1, dropout.as_ref(), &mut ws, &mut rng);
                    let mut grad_w = ws.grad_w.clone();
                    let mut grad_b = ws.grad_b.clone();
                    clip_gradients(&mut grad_w, &mut grad_b, pcfg.grad_clip);
                    let grad = SparseGradient {
                        grad_w,
                        grad_b,
                        topo: Arc::clone(&snap.model),
                        gen: snap.gen,
                        fetched_step: snap.step,
                    };
                    ps.push(grad, schedule.at(epoch))?;
                }
            }));
        }
        for h in handles {
            h.join()
                .map_err(|_| TsnnError::Coordinator("phase-1 worker panicked".into()))??;
        }
        Ok(())
    })
}

/// Phase 1, synchronous (WASSP): per step all K gradients are computed
/// against the same snapshot, averaged, and applied once (Goyal et al.
/// warmup + linear scaling on the LR).
fn run_phase1_sync(
    cfg: &TrainConfig,
    pcfg: &ParallelConfig,
    data: &Dataset,
    ps: &ParameterServer,
) -> Result<()> {
    let base = match cfg.lr {
        LrSchedule::Constant(eta) => eta,
        other => other.at(0),
    };
    let schedule = LrSchedule::Warmup {
        base,
        scale: (pcfg.workers as f32).max(1.0).min(4.0),
        warmup_epochs: 5,
    };
    let k = pcfg.workers;
    let steps_per_epoch = data.n_train().div_ceil(cfg.batch);

    // Per-worker persistent state across the run.
    let mut rngs: Vec<Rng> = (0..k).map(|i| Rng::new(cfg.seed).split(i as u64)).collect();
    let mut batchers: Vec<Batcher> = (0..k)
        .map(|i| {
            let (lo, hi) = shard_bounds(data.n_train(), k, i);
            Batcher::shard(data.n_train(), data.n_features, cfg.batch, lo, hi)
        })
        .collect();
    for (b, r) in batchers.iter_mut().zip(rngs.iter_mut()) {
        b.reset(r);
    }
    let dropout = if cfg.dropout > 0.0 {
        Some(crate::nn::Dropout::new(cfg.dropout))
    } else {
        None
    };
    // Persistent per-worker workspaces: each carries its kernel sub-pool
    // (DESIGN.md §9.4) and its forward/backward buffers across ALL steps
    // of the phase — the old per-step workspace would have re-spawned
    // pool workers (and reallocated every buffer) every step.
    let budgets = worker_kernel_budgets(cfg, k);
    let mut wss: Vec<crate::model::Workspace> = budgets
        .iter()
        .map(|&t| crate::model::Workspace::with_threads(t))
        .collect();

    for epoch in 0..pcfg.phase1_epochs {
        let lr = schedule.at(epoch);
        for _ in 0..steps_per_epoch {
            let snap = ps.fetch();
            // Barrier semantics: all K gradients computed against `snap`,
            // then averaged and applied once. Computation itself fans out
            // across scoped threads (real thread-parallelism on multicore
            // hosts; deterministic aggregation either way); gradients
            // stay in the persistent workspaces — no per-step clones
            // (a panicked worker propagates at the scope join).
            std::thread::scope(|scope| {
                for ((batcher, rng), ws) in
                    batchers.iter_mut().zip(rngs.iter_mut()).zip(wss.iter_mut())
                {
                    let model = Arc::clone(&snap.model);
                    let dref = dropout.as_ref();
                    scope.spawn(move || {
                        let batch = match batcher.next_batch(&data.x_train, &data.y_train) {
                            Some(b) => b,
                            None => {
                                batcher.reset(rng);
                                batcher.next_batch(&data.x_train, &data.y_train).unwrap()
                            }
                        };
                        model.compute_gradients(batch.0, batch.1, dref, ws, rng);
                    });
                }
            });
            // average K aligned gradients into worker 0's buffers (the
            // next step's backward_into re-zeroes them anyway)
            let inv_k = 1.0f32 / k as f32;
            let (agg, rest) = wss.split_first_mut().expect("workers >= 1");
            for ws in rest.iter() {
                for (a, g) in agg.grad_w.iter_mut().zip(ws.grad_w.iter()) {
                    for (x, y) in a.iter_mut().zip(g.iter()) {
                        *x += y;
                    }
                }
                for (a, g) in agg.grad_b.iter_mut().zip(ws.grad_b.iter()) {
                    for (x, y) in a.iter_mut().zip(g.iter()) {
                        *x += y;
                    }
                }
            }
            for a in agg.grad_w.iter_mut().flat_map(|v| v.iter_mut()) {
                *a *= inv_k;
            }
            for a in agg.grad_b.iter_mut().flat_map(|v| v.iter_mut()) {
                *a *= inv_k;
            }
            clip_gradients(&mut agg.grad_w, &mut agg.grad_b, pcfg.grad_clip);
            ps.apply_aligned(&agg.grad_w, &agg.grad_b, lr)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Cleanly separable two-blob data: the coordinator unit tests pin
    /// the *machinery* (phases, staleness, averaging), so the learning
    /// problem itself must converge reliably in a handful of epochs.
    fn blob_data() -> Dataset {
        let (n_train, n_test, nf) = (400usize, 160usize, 20usize);
        let mut rng = Rng::new(1);
        let gen = |n: usize, rng: &mut Rng| {
            let mut x = vec![0.0f32; n * nf];
            let mut y = vec![0u32; n];
            for s in 0..n {
                let c = (s % 2) as u32;
                y[s] = c;
                let shift = if c == 0 { -1.5 } else { 1.5 };
                for f in 0..nf {
                    x[s * nf + f] = rng.normal() + if f < 6 { shift } else { 0.0 };
                }
            }
            (x, y)
        };
        let (x_train, y_train) = gen(n_train, &mut rng);
        let (x_test, y_test) = gen(n_test, &mut rng);
        Dataset {
            name: "blobs".into(),
            n_features: nf,
            n_classes: 2,
            x_train,
            y_train,
            x_test,
            y_test,
        }
    }

    fn quick() -> (TrainConfig, Dataset) {
        let data = blob_data();
        // Unit tests here pin the *coordination* machinery (phases,
        // staleness, averaging); SET evolution is off and the LR hot so a
        // short async run converges reliably — evolution+parallel together
        // is covered by server tests and rust/tests/integration.rs.
        let cfg = TrainConfig {
            hidden: vec![48, 24],
            epsilon: 8.0,
            batch: 40,
            dropout: 0.0,
            epochs: 0, // unused by parallel driver
            lr: LrSchedule::Constant(0.05),
            evolution: None,
            ..TrainConfig::default()
        };
        (cfg, data)
    }

    #[test]
    fn wasap_trains_and_averages() {
        let (cfg, data) = quick();
        let pcfg = ParallelConfig {
            workers: 3,
            phase1_epochs: 25,
            phase2_epochs: 5,
            synchronous: false,
            hot_start: true,
            grad_clip: 5.0,
        };
        let report = run_parallel(&cfg, &pcfg, &data, &mut Rng::new(2)).unwrap();
        // async scheduling is nondeterministic; require clearly-above-chance
        // learning rather than a tight accuracy bar (integration tests pin
        // the stronger parity-with-sequential property).
        assert!(report.final_test_accuracy > 0.55, "{}", report.final_test_accuracy);
        assert!(report.server_stats.steps > 0);
        assert!(report.server_stats.epochs >= 25);
        // re-sparsification keeps the budget
        assert!(report.end_weights <= report.start_weights + report.start_weights / 10);
        assert!(report.phases.get("phase1") > 0.0);
        assert!(report.phases.get("phase2") > 0.0);
    }

    #[test]
    fn wassp_trains_synchronously() {
        let (cfg, data) = quick();
        let pcfg = ParallelConfig {
            workers: 2,
            phase1_epochs: 4,
            phase2_epochs: 1,
            synchronous: true,
            hot_start: true,
            grad_clip: 5.0,
        };
        let report = run_parallel(&cfg, &pcfg, &data, &mut Rng::new(3)).unwrap();
        assert!(report.final_test_accuracy > 0.5, "{}", report.final_test_accuracy);
        // synchronous path never produces stale pushes
        assert_eq!(report.server_stats.dropped_entries, 0);
    }

    #[test]
    fn single_worker_wasap_matches_sequential_semantics() {
        let (cfg, data) = quick();
        let pcfg = ParallelConfig {
            workers: 1,
            phase1_epochs: 5,
            phase2_epochs: 0,
            synchronous: false,
            hot_start: true,
            grad_clip: 5.0,
        };
        let report = run_parallel(&cfg, &pcfg, &data, &mut Rng::new(4)).unwrap();
        assert!(report.server_stats.mean_staleness <= 1.0);
        assert!(report.final_test_accuracy > 0.5);
    }

    #[test]
    fn zero_workers_rejected() {
        let (cfg, data) = quick();
        let pcfg = ParallelConfig {
            workers: 0,
            ..Default::default()
        };
        assert!(run_parallel(&cfg, &pcfg, &data, &mut Rng::new(5)).is_err());
    }

    #[test]
    fn worker_kernel_budgets_distribute_the_remainder() {
        let with_threads = |kernel_threads: usize| TrainConfig {
            kernel_threads,
            ..TrainConfig::default()
        };
        // 8 cores / 3 workers: the old flooring gave 2+2+2 (2 stranded);
        // the remainder now lands one core per worker from the front
        assert_eq!(worker_kernel_budgets(&with_threads(8), 3), vec![3, 3, 2]);
        assert_eq!(worker_kernel_budgets(&with_threads(8), 5), vec![2, 2, 2, 1, 1]);
        assert_eq!(worker_kernel_budgets(&with_threads(8), 8), vec![1; 8]);
        assert_eq!(worker_kernel_budgets(&with_threads(8), 1), vec![8]);
        // more workers than cores: everyone keeps the floor of 1
        assert_eq!(worker_kernel_budgets(&with_threads(8), 12), vec![1; 12]);
        assert_eq!(worker_kernel_budgets(&with_threads(7), 2), vec![4, 3]);
        // budgets sum to the resolved total whenever K <= total
        for (threads, workers) in [(8usize, 3usize), (8, 5), (7, 2), (6, 6), (9, 4)] {
            let budgets = worker_kernel_budgets(&with_threads(threads), workers);
            assert_eq!(budgets.iter().sum::<usize>(), threads, "{threads}/{workers}");
            assert!(budgets.windows(2).all(|w| w[0] >= w[1]));
        }
    }

    #[test]
    fn shard_bounds_cover_everything() {
        let mut covered = vec![false; 103];
        for k in 0..7 {
            let (lo, hi) = shard_bounds(103, 7, k);
            for c in covered[lo..hi].iter_mut() {
                assert!(!*c);
                *c = true;
            }
        }
        assert!(covered.into_iter().all(|c| c));
    }
}
