//! WASAP-SGD / WASSP-SGD — the paper's parallel training contribution.
//!
//! Two-phase data-parallel training of truly-sparse models over a
//! shared-memory parameter server (the single-machine MPI setup of the
//! paper, realised with OS threads — see DESIGN.md §3):
//!
//! * **Phase 1** — K workers repeatedly fetch the model, compute a sparse
//!   gradient on a mini-batch of their shard, and push it. *WASAP* pushes
//!   asynchronously (no barrier; staleness handled by
//!   `RetainValidUpdates`); *WASSP* synchronises every step and averages
//!   the K gradients (with Goyal-style warmup + linear LR scaling).
//!   The server runs SET topology evolution every `n ÷ B` pushes.
//! * **Phase 2** — each worker trains its replica locally (topology
//!   evolving independently), after which the models are averaged over
//!   the union topology and magnitude-pruned back to the sparsity budget
//!   (Stochastic-Weight-Averaging-style generalisation boost).

pub mod average;
pub mod server;
pub mod supervisor;
pub mod transport;

use std::sync::Arc;

use crate::config::TrainConfig;
use crate::data::Dataset;
use crate::error::{Result, TsnnError};
use crate::model::SparseMlp;
use crate::util::{PhaseTimes, Rng};

pub use average::average_and_resparsify;
pub use server::{ParameterServer, ServerStats, Snapshot, SparseGradient};
pub use transport::service::{
    CoordStats, CoordinatorOptions, CoordinatorService, SupervisionPolicy,
};
pub use transport::worker::{run_worker, WorkerJob, WorkerReport};

use transport::channel::ChannelHub;
use transport::fault::{FaultCounters, FaultPlan, FaultyTransport};
use transport::service::ServiceOutcome;
use transport::{Listener, Transport};

/// Parallel-training configuration.
#[derive(Debug, Clone, Copy)]
pub struct ParallelConfig {
    /// Worker count K (paper: physical cores − 1).
    pub workers: usize,
    /// Epochs of phase 1 (τ₁).
    pub phase1_epochs: usize,
    /// Epochs of phase 2 (τ₂ − τ₁).
    pub phase2_epochs: usize,
    /// Synchronous phase 1 (WASSP) instead of asynchronous (WASAP).
    pub synchronous: bool,
    /// Wrap a constant LR into the paper's hot-start schedule for WASAP
    /// phase 1 ("benefits from larger learning rates for the first few
    /// epochs", §2.3). Disable when the caller tunes the schedule itself.
    pub hot_start: bool,
    /// L2 gradient clipping applied worker-side before each push (0 = off).
    /// Stabilises hot-start async SGD against stale-gradient overshoot.
    pub grad_clip: f32,
}

impl Default for ParallelConfig {
    fn default() -> Self {
        ParallelConfig {
            workers: 5,
            phase1_epochs: 20,
            phase2_epochs: 5,
            synchronous: false,
            hot_start: true,
            grad_clip: 5.0,
        }
    }
}

/// Scale all gradient buffers so the global L2 norm is at most `clip`.
///
/// A non-finite norm (any NaN/±Inf entry) zeroes the whole gradient and
/// returns `true` — the old behaviour silently skipped scaling, letting a
/// single poisoned batch NaN the shared server model through `push`.
/// Zeroing runs regardless of `clip` so `grad_clip = 0` (clipping off)
/// still never forwards a poisoned gradient.
pub fn clip_gradients(grad_w: &mut [Vec<f32>], grad_b: &mut [Vec<f32>], clip: f32) -> bool {
    let norm_sq: f32 = grad_w
        .iter()
        .chain(grad_b.iter())
        .flat_map(|g| g.iter())
        .map(|g| g * g)
        .sum();
    if !norm_sq.is_finite() {
        for g in grad_w.iter_mut().chain(grad_b.iter_mut()) {
            g.fill(0.0);
        }
        return true;
    }
    if clip <= 0.0 {
        return false;
    }
    let norm = norm_sq.sqrt();
    if norm > clip {
        let scale = clip / norm;
        for g in grad_w.iter_mut().chain(grad_b.iter_mut()) {
            for v in g.iter_mut() {
                *v *= scale;
            }
        }
    }
    false
}

/// Result of a parallel run.
#[derive(Debug)]
pub struct ParallelReport {
    /// The final (averaged, re-sparsified) model.
    pub model: SparseMlp,
    /// Test accuracy after phase 1 (pre-averaging).
    pub phase1_test_accuracy: f32,
    /// Final test accuracy of the averaged model.
    pub final_test_accuracy: f32,
    /// Weights at start.
    pub start_weights: usize,
    /// Weights at end.
    pub end_weights: usize,
    /// Server-side statistics (staleness, dropped updates, ...).
    pub server_stats: ServerStats,
    /// Transport-side statistics (frames, retries absorbed, stragglers).
    pub coord_stats: CoordStats,
    /// Wall-clock per phase.
    pub phases: PhaseTimes,
}

/// Per-worker kernel-shard budgets: the machine's thread budget (the
/// config's `kernel_threads` knob, `0` = all cores) divided across the
/// K data-parallel workers with the division remainder distributed one
/// core per worker from the front — so the budgets sum to the resolved
/// total whenever `K ≤ total` (the old flooring division stranded
/// `total mod K` cores; e.g. 8 cores / 3 workers gave 2+2+2, leaving 2
/// idle — now 3+3+2). Each worker's `Workspace` turns its budget into a
/// persistent kernel sub-pool (DESIGN.md §9.4), so K workers × pool
/// shards never oversubscribes the host.
pub fn worker_kernel_budgets(cfg: &TrainConfig, workers: usize) -> Vec<usize> {
    let workers = workers.max(1);
    let total = crate::sparse::ops::resolve_threads(cfg.kernel_threads);
    let (base, rem) = (total / workers, total % workers);
    (0..workers)
        .map(|k| (base + usize::from(k < rem)).max(1))
        .collect()
}

/// Contiguous shard `k` of `n` samples split across `workers` workers
/// (the last worker absorbs the remainder).
pub fn shard_bounds(n: usize, workers: usize, k: usize) -> (usize, usize) {
    let per = n / workers;
    let lo = k * per;
    let hi = if k + 1 == workers { n } else { lo + per };
    (lo, hi)
}

/// Build a worker-local dataset containing only its shard of train data
/// (test split shared for evaluation convenience).
pub fn shard_dataset(data: &Dataset, lo: usize, hi: usize) -> Dataset {
    let nf = data.n_features;
    Dataset {
        name: format!("{}[{}..{}]", data.name, lo, hi),
        n_features: nf,
        n_classes: data.n_classes,
        x_train: data.x_train[lo * nf..hi * nf].to_vec(),
        y_train: data.y_train[lo..hi].to_vec(),
        x_test: data.x_test.clone(),
        y_test: data.y_test.clone(),
    }
}

/// Extra knobs for [`run_parallel_opts`] (fault injection is test/CLI
/// only; the defaults run clean).
#[derive(Default)]
pub struct ParallelOptions {
    /// Coordinator-side options (retry policy, idle timeout, straggler
    /// sensitivity).
    pub coord: CoordinatorOptions,
    /// Deterministic fault plan applied to every worker's transport.
    pub fault: FaultPlan,
    /// Share a counter sink to observe injected faults from tests.
    pub fault_counters: Option<Arc<FaultCounters>>,
}

/// Run WASAP-SGD (or WASSP-SGD when `pcfg.synchronous`).
pub fn run_parallel(
    cfg: &TrainConfig,
    pcfg: &ParallelConfig,
    data: &Dataset,
    rng: &mut Rng,
) -> Result<ParallelReport> {
    run_parallel_opts(cfg, pcfg, data, rng, &ParallelOptions::default())
}

/// Run WASAP/WASSP with in-process workers over the channel transport.
///
/// Phase 1 and phase 2 both flow through the [`transport`] protocol: the
/// coordinator thread runs a [`CoordinatorService`] on a [`ChannelHub`],
/// and each worker thread drives [`run_worker`] over its own channel
/// connection — the very same state machines a multi-process socket run
/// executes, so in-process tests pin the protocol, not a shortcut.
pub fn run_parallel_opts(
    cfg: &TrainConfig,
    pcfg: &ParallelConfig,
    data: &Dataset,
    rng: &mut Rng,
    opts: &ParallelOptions,
) -> Result<ParallelReport> {
    if pcfg.workers == 0 {
        return Err(TsnnError::Coordinator("need at least one worker".into()));
    }
    let mut phases = PhaseTimes::new();
    let sizes = cfg.sizes(data.n_features, data.n_classes);
    let model = phases.time("init", || {
        SparseMlp::new(&sizes, cfg.epsilon, cfg.activation, &cfg.init, rng)
    })?;
    let start_weights = model.weight_count();

    let service = CoordinatorService::new(cfg, pcfg, model, data.n_train(), None, &opts.coord);
    let (hub, connector) = ChannelHub::new();
    let budgets = worker_kernel_budgets(cfg, pcfg.workers);

    let outcome: ServiceOutcome = std::thread::scope(|scope| -> Result<ServiceOutcome> {
        let coordinator = scope.spawn(move || {
            let mut hub = hub;
            service.run(&mut hub)
        });
        let mut handles = Vec::new();
        for k in 0..pcfg.workers {
            let job = WorkerJob::new(k as u32, budgets[k], cfg, pcfg);
            let retry = opts.coord.retry;
            let mut t: Box<dyn Transport> = Box::new(connector.connect());
            if opts.fault.is_active() {
                let counters = opts
                    .fault_counters
                    .clone()
                    .unwrap_or_else(|| Arc::new(FaultCounters::default()));
                t = Box::new(FaultyTransport::new(t, opts.fault, counters));
            }
            handles.push(scope.spawn(move || run_worker(t, retry, &job, data)));
        }
        drop(connector);
        let mut worker_err = None;
        for h in handles {
            match h.join() {
                Ok(Ok(_report)) => {}
                Ok(Err(e)) => worker_err = worker_err.or(Some(e)),
                Err(_) => {
                    worker_err = worker_err.or(Some(TsnnError::Coordinator(
                        "phase-1 worker panicked".into(),
                    )))
                }
            }
        }
        let outcome = coordinator
            .join()
            .map_err(|_| TsnnError::Coordinator("coordinator thread panicked".into()))?;
        // a worker's own failure is the root cause; the coordinator error
        // (if any) is usually the knock-on "everyone disconnected"
        if let Some(e) = worker_err {
            return Err(e);
        }
        outcome
    })?;
    finish_report(data, phases, start_weights, outcome)
}

/// Run the coordinator side only, serving external workers over
/// `listener` (the multi-process socket path: workers are separate
/// `tsnn worker` processes that receive `job_json` at join).
pub fn run_parallel_listener(
    cfg: &TrainConfig,
    pcfg: &ParallelConfig,
    data: &Dataset,
    rng: &mut Rng,
    listener: &mut dyn Listener,
    job_json: Option<String>,
    opts: &CoordinatorOptions,
) -> Result<ParallelReport> {
    if pcfg.workers == 0 {
        return Err(TsnnError::Coordinator("need at least one worker".into()));
    }
    let mut phases = PhaseTimes::new();
    let sizes = cfg.sizes(data.n_features, data.n_classes);
    let model = phases.time("init", || {
        SparseMlp::new(&sizes, cfg.epsilon, cfg.activation, &cfg.init, rng)
    })?;
    let start_weights = model.weight_count();
    let service = CoordinatorService::new(cfg, pcfg, model, data.n_train(), job_json, opts);
    let outcome = service.run(listener)?;
    finish_report(data, phases, start_weights, outcome)
}

/// Shared tail of a parallel run: evaluate the phase-1 and final models
/// and assemble the report.
fn finish_report(
    data: &Dataset,
    mut phases: PhaseTimes,
    start_weights: usize,
    outcome: ServiceOutcome,
) -> Result<ParallelReport> {
    phases.add("phase1", outcome.coord.phase1_secs);
    phases.add("phase2", outcome.coord.phase2_secs);
    let mut ws = outcome.phase1_model.alloc_workspace(256);
    let (_, phase1_acc) = phases.time("test", || {
        outcome
            .phase1_model
            .evaluate(&data.x_test, &data.y_test, 256, &mut ws)
    });
    let final_model = outcome.final_model;
    let mut ws = final_model.alloc_workspace(256);
    let (_, final_acc) = phases.time("test", || {
        final_model.evaluate(&data.x_test, &data.y_test, 256, &mut ws)
    });
    Ok(ParallelReport {
        end_weights: final_model.weight_count(),
        start_weights,
        phase1_test_accuracy: phase1_acc,
        final_test_accuracy: final_acc,
        server_stats: outcome.server_stats,
        coord_stats: outcome.coord,
        phases,
        model: final_model,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LrSchedule;

    /// Cleanly separable two-blob data: the coordinator unit tests pin
    /// the *machinery* (phases, staleness, averaging), so the learning
    /// problem itself must converge reliably in a handful of epochs.
    fn blob_data() -> Dataset {
        let (n_train, n_test, nf) = (400usize, 160usize, 20usize);
        let mut rng = Rng::new(1);
        let gen = |n: usize, rng: &mut Rng| {
            let mut x = vec![0.0f32; n * nf];
            let mut y = vec![0u32; n];
            for s in 0..n {
                let c = (s % 2) as u32;
                y[s] = c;
                let shift = if c == 0 { -1.5 } else { 1.5 };
                for f in 0..nf {
                    x[s * nf + f] = rng.normal() + if f < 6 { shift } else { 0.0 };
                }
            }
            (x, y)
        };
        let (x_train, y_train) = gen(n_train, &mut rng);
        let (x_test, y_test) = gen(n_test, &mut rng);
        Dataset {
            name: "blobs".into(),
            n_features: nf,
            n_classes: 2,
            x_train,
            y_train,
            x_test,
            y_test,
        }
    }

    fn quick() -> (TrainConfig, Dataset) {
        let data = blob_data();
        // Unit tests here pin the *coordination* machinery (phases,
        // staleness, averaging); SET evolution is off and the LR hot so a
        // short async run converges reliably — evolution+parallel together
        // is covered by server tests and rust/tests/integration.rs.
        let cfg = TrainConfig {
            hidden: vec![48, 24],
            epsilon: 8.0,
            batch: 40,
            dropout: 0.0,
            epochs: 0, // unused by parallel driver
            lr: LrSchedule::Constant(0.05),
            evolution: None,
            ..TrainConfig::default()
        };
        (cfg, data)
    }

    #[test]
    fn wasap_trains_and_averages() {
        let (cfg, data) = quick();
        let pcfg = ParallelConfig {
            workers: 3,
            phase1_epochs: 25,
            phase2_epochs: 5,
            synchronous: false,
            hot_start: true,
            grad_clip: 5.0,
        };
        let report = run_parallel(&cfg, &pcfg, &data, &mut Rng::new(2)).unwrap();
        // async scheduling is nondeterministic; require clearly-above-chance
        // learning rather than a tight accuracy bar (integration tests pin
        // the stronger parity-with-sequential property).
        assert!(report.final_test_accuracy > 0.55, "{}", report.final_test_accuracy);
        assert!(report.server_stats.steps > 0);
        assert!(report.server_stats.epochs >= 25);
        // re-sparsification keeps the budget
        assert!(report.end_weights <= report.start_weights + report.start_weights / 10);
        assert!(report.phases.get("phase1") > 0.0);
        assert!(report.phases.get("phase2") > 0.0);
    }

    #[test]
    fn wassp_trains_synchronously() {
        let (cfg, data) = quick();
        let pcfg = ParallelConfig {
            workers: 2,
            phase1_epochs: 4,
            phase2_epochs: 1,
            synchronous: true,
            hot_start: true,
            grad_clip: 5.0,
        };
        let report = run_parallel(&cfg, &pcfg, &data, &mut Rng::new(3)).unwrap();
        assert!(report.final_test_accuracy > 0.5, "{}", report.final_test_accuracy);
        // synchronous path never produces stale pushes
        assert_eq!(report.server_stats.dropped_entries, 0);
    }

    #[test]
    fn single_worker_wasap_matches_sequential_semantics() {
        let (cfg, data) = quick();
        let pcfg = ParallelConfig {
            workers: 1,
            phase1_epochs: 5,
            phase2_epochs: 0,
            synchronous: false,
            hot_start: true,
            grad_clip: 5.0,
        };
        let report = run_parallel(&cfg, &pcfg, &data, &mut Rng::new(4)).unwrap();
        assert!(report.server_stats.mean_staleness <= 1.0);
        assert!(report.final_test_accuracy > 0.5);
    }

    #[test]
    fn zero_workers_rejected() {
        let (cfg, data) = quick();
        let pcfg = ParallelConfig {
            workers: 0,
            ..Default::default()
        };
        assert!(run_parallel(&cfg, &pcfg, &data, &mut Rng::new(5)).is_err());
    }

    #[test]
    fn worker_kernel_budgets_distribute_the_remainder() {
        let with_threads = |kernel_threads: usize| TrainConfig {
            kernel_threads,
            ..TrainConfig::default()
        };
        // 8 cores / 3 workers: the old flooring gave 2+2+2 (2 stranded);
        // the remainder now lands one core per worker from the front
        assert_eq!(worker_kernel_budgets(&with_threads(8), 3), vec![3, 3, 2]);
        assert_eq!(worker_kernel_budgets(&with_threads(8), 5), vec![2, 2, 2, 1, 1]);
        assert_eq!(worker_kernel_budgets(&with_threads(8), 8), vec![1; 8]);
        assert_eq!(worker_kernel_budgets(&with_threads(8), 1), vec![8]);
        // more workers than cores: everyone keeps the floor of 1
        assert_eq!(worker_kernel_budgets(&with_threads(8), 12), vec![1; 12]);
        assert_eq!(worker_kernel_budgets(&with_threads(7), 2), vec![4, 3]);
        // budgets sum to the resolved total whenever K <= total
        for (threads, workers) in [(8usize, 3usize), (8, 5), (7, 2), (6, 6), (9, 4)] {
            let budgets = worker_kernel_budgets(&with_threads(threads), workers);
            assert_eq!(budgets.iter().sum::<usize>(), threads, "{threads}/{workers}");
            assert!(budgets.windows(2).all(|w| w[0] >= w[1]));
        }
    }

    #[test]
    fn shard_bounds_cover_everything() {
        let mut covered = vec![false; 103];
        for k in 0..7 {
            let (lo, hi) = shard_bounds(103, 7, k);
            for c in covered[lo..hi].iter_mut() {
                assert!(!*c);
                *c = true;
            }
        }
        assert!(covered.into_iter().all(|c| c));
    }
}
