//! Phase-2 model averaging + re-sparsification (Algorithm 1, lines 36–37).
//!
//! After phase 2 each of the K workers holds a model whose topology has
//! evolved independently. Averaging `θ_f = (1/K) Σ θ_i` is taken over the
//! *union* of topologies (absent links contribute 0), which densifies the
//! model; the paper then prunes "unimportant connections, accounting for
//! a fraction S' − S … based on their magnitude, corresponding to the
//! largest negative weights and the smallest positive weights" to restore
//! each layer's original budget.

use std::collections::BTreeMap;

use crate::error::{Result, TsnnError};
use crate::model::{SparseLayer, SparseMlp};
use crate::sparse::CsrMatrix;

/// Average K worker models over the union topology; then magnitude-prune
/// each layer back to `target_nnz[l]` links.
pub fn average_and_resparsify(models: &[SparseMlp], target_nnz: &[usize]) -> Result<SparseMlp> {
    let k = models.len();
    if k == 0 {
        return Err(TsnnError::Coordinator("no models to average".into()));
    }
    let sizes = models[0].sizes.clone();
    for m in models {
        if m.sizes != sizes {
            return Err(TsnnError::Coordinator("model size mismatch".into()));
        }
    }
    let n_layers = sizes.len() - 1;
    if target_nnz.len() != n_layers {
        return Err(TsnnError::Coordinator("target_nnz length mismatch".into()));
    }

    let mut layers = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        // union-average weights row by row
        let (n_in, n_out) = (sizes[l], sizes[l + 1]);
        let mut triplets: Vec<(u32, u32, f32)> = Vec::new();
        let inv_k = 1.0f32 / k as f32;
        for i in 0..n_in {
            let mut row: BTreeMap<u32, f32> = BTreeMap::new();
            for m in models {
                let (cols, vals) = m.layers[l].weights.row(i);
                for (&c, &v) in cols.iter().zip(vals.iter()) {
                    *row.entry(c).or_insert(0.0) += v * inv_k;
                }
            }
            for (c, v) in row {
                triplets.push((i as u32, c, v));
            }
        }
        let mut weights = CsrMatrix::from_coo(n_in, n_out, triplets)?;

        // magnitude prune back to target: drop smallest positives and
        // largest negatives until <= target_nnz
        let excess = weights.nnz().saturating_sub(target_nnz[l]);
        if excess > 0 {
            let mut mags: Vec<f32> = weights.values.iter().map(|v| v.abs()).collect();
            let idx = excess - 1;
            let (_, cut, _) =
                mags.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
            let cut = *cut;
            let vals = weights.values.clone();
            let mut removed = 0usize;
            weights.retain(|kk| {
                let keep = vals[kk].abs() > cut || (vals[kk].abs() == cut && {
                    // keep ties only once the quota is filled
                    if removed < excess {
                        removed += 1;
                        false
                    } else {
                        true
                    }
                });
                keep
            });
        }
        let nnz = weights.nnz();

        // average biases
        let mut bias = vec![0.0f32; n_out];
        for m in models {
            for (b, &mb) in bias.iter_mut().zip(m.layers[l].bias.iter()) {
                *b += mb * inv_k;
            }
        }

        layers.push(SparseLayer {
            weights,
            bias,
            velocity: vec![0.0; nnz].into(),
            bias_velocity: vec![0.0; n_out],
            activation: models[0].layers[l].activation,
            srelu: None,
        });
    }
    Ok(SparseMlp { sizes, layers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Activation;
    use crate::sparse::WeightInit;
    use crate::util::Rng;

    fn model(seed: u64) -> SparseMlp {
        SparseMlp::new(
            &[8, 12, 3],
            4.0,
            Activation::Relu,
            &WeightInit::Normal(1.0),
            &mut Rng::new(seed),
        )
        .unwrap()
    }

    #[test]
    fn identical_models_average_to_themselves() {
        let m = model(1);
        let targets: Vec<usize> = m.layers.iter().map(|l| l.weights.nnz()).collect();
        let avg = average_and_resparsify(&[m.clone(), m.clone()], &targets).unwrap();
        for (a, b) in avg.layers.iter().zip(m.layers.iter()) {
            assert_eq!(a.weights.col_idx, b.weights.col_idx);
            for (x, y) in a.weights.values.iter().zip(b.weights.values.iter()) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn divergent_topologies_union_then_prune_to_target() {
        let a = model(2);
        let b = model(3); // different topology
        let targets: Vec<usize> = a.layers.iter().map(|l| l.weights.nnz()).collect();
        let avg = average_and_resparsify(&[a.clone(), b], &targets).unwrap();
        for (l, layer) in avg.layers.iter().enumerate() {
            layer.weights.validate().unwrap();
            assert!(
                layer.weights.nnz() <= targets[l],
                "layer {l}: {} > {}",
                layer.weights.nnz(),
                targets[l]
            );
        }
    }

    #[test]
    fn averaged_values_are_halved_on_disjoint_links() {
        // craft models with one known disjoint entry
        let mut a = model(4);
        let mut b = a.clone();
        // zero everything, set one entry in a only
        for m in [&mut a, &mut b] {
            for l in &mut m.layers {
                for v in &mut l.weights.values {
                    *v = 0.0;
                }
            }
        }
        a.layers[0].weights.values[0] = 2.0;
        b.layers[0].weights.values[1] = 4.0;
        let targets: Vec<usize> = a.layers.iter().map(|l| l.weights.nnz()).collect();
        let avg = average_and_resparsify(&[a.clone(), b], &targets).unwrap();
        // union-average: entry0 = 1.0, entry1 = 2.0 (identical topology here
        // so union == topology; values averaged)
        assert!((avg.layers[0].weights.values[0] - 1.0).abs() < 1e-6);
        assert!((avg.layers[0].weights.values[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn rejects_mismatched_models() {
        let a = model(5);
        let mut rng = Rng::new(6);
        let b = SparseMlp::new(
            &[8, 10, 3],
            4.0,
            Activation::Relu,
            &WeightInit::Normal(1.0),
            &mut rng,
        )
        .unwrap();
        let targets: Vec<usize> = a.layers.iter().map(|l| l.weights.nnz()).collect();
        assert!(average_and_resparsify(&[a, b], &targets).is_err());
        assert!(average_and_resparsify(&[], &[]).is_err());
    }
}
