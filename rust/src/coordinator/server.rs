//! Shared-memory parameter server (paper Fig. 2 / Fig. 8).
//!
//! The master owns the canonical sparse model; workers fetch snapshots
//! and push sparse gradients with atomic (lock-protected) read/write
//! operations. Because the master periodically runs the SET topology
//! evolution, a worker's gradient may reference links that no longer
//! exist — `RetainValidUpdates` (Algorithm 1 line 14) intersects the
//! worker's topology with the current one and applies only valid entries.

use std::sync::atomic::AtomicUsize;
use std::sync::{Arc, Mutex};

use crate::error::Result;
use crate::importance::ImportanceConfig;
use crate::model::SparseMlp;
use crate::nn::MomentumSgd;
use crate::set::{self, EvolutionConfig};
use crate::util::Rng;

/// A worker's snapshot of the server model.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// The model replica (topology + values).
    pub model: Arc<SparseMlp>,
    /// Topology generation at fetch time.
    pub gen: u64,
    /// Server step at fetch time (staleness accounting).
    pub step: u64,
}

/// Sparse gradient aligned to a snapshot's topology.
#[derive(Debug)]
pub struct SparseGradient {
    /// Per-layer weight gradients aligned to the snapshot CSR values.
    pub grad_w: Vec<Vec<f32>>,
    /// Per-layer bias gradients.
    pub grad_b: Vec<Vec<f32>>,
    /// The topology the gradients are aligned to.
    pub topo: Arc<SparseMlp>,
    /// Generation of that topology.
    pub gen: u64,
    /// Server step the worker fetched at (for staleness stats).
    pub fetched_step: u64,
}

struct ServerState {
    model: SparseMlp,
    /// In-place topology-evolution engine (DESIGN.md §8); lives under
    /// the state lock so its per-layer workspaces are reused across the
    /// server's evolution epochs.
    evolver: set::EvolutionEngine,
    snapshot: Arc<SparseMlp>,
    gen: u64,
    step: u64,
    epoch: usize,
    pushes_since_evolution: usize,
    dropped_entries: u64,
    applied_entries: u64,
    staleness_sum: u64,
    staleness_max: u64,
    nonfinite_rejected: u64,
}

/// Lock-protected parameter server.
pub struct ParameterServer {
    state: Mutex<ServerState>,
    opt: MomentumSgd,
    evolution: Option<EvolutionConfig>,
    importance: Option<ImportanceConfig>,
    /// Pushes per epoch (⌈n_train / batch⌉ — Algorithm 1's `n ÷ B`).
    pushes_per_epoch: usize,
    evo_rng: Mutex<Rng>,
    /// Count of topology evolutions performed.
    pub evolutions: AtomicUsize,
}

/// Aggregate statistics at the end of phase 1.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerStats {
    /// Total server updates applied.
    pub steps: u64,
    /// Epochs completed.
    pub epochs: usize,
    /// Gradient entries applied.
    pub applied_entries: u64,
    /// Gradient entries dropped by RetainValidUpdates.
    pub dropped_entries: u64,
    /// Mean staleness (server steps between fetch and push).
    pub mean_staleness: f64,
    /// Max staleness observed.
    pub max_staleness: u64,
    /// Topology generations.
    pub generations: u64,
    /// Pushes rejected because a gradient entry was NaN/Inf.
    pub nonfinite_rejected: u64,
}

/// All gradient entries finite? A single NaN would propagate through
/// `apply_update` into the canonical model and, via snapshots, to every
/// worker — so non-finite pushes are rejected wholesale at the server.
fn grads_finite(grad_w: &[Vec<f32>], grad_b: &[Vec<f32>]) -> bool {
    grad_w
        .iter()
        .chain(grad_b.iter())
        .flat_map(|g| g.iter())
        .all(|v| v.is_finite())
}

impl ParameterServer {
    /// Wrap an initial model.
    pub fn new(
        model: SparseMlp,
        opt: MomentumSgd,
        evolution: Option<EvolutionConfig>,
        importance: Option<ImportanceConfig>,
        pushes_per_epoch: usize,
        seed: u64,
    ) -> Self {
        let snapshot = Arc::new(model.clone());
        ParameterServer {
            state: Mutex::new(ServerState {
                model,
                evolver: set::EvolutionEngine::new(),
                snapshot,
                gen: 0,
                step: 0,
                epoch: 0,
                pushes_since_evolution: 0,
                dropped_entries: 0,
                applied_entries: 0,
                staleness_sum: 0,
                staleness_max: 0,
                nonfinite_rejected: 0,
            }),
            opt,
            evolution,
            importance,
            pushes_per_epoch: pushes_per_epoch.max(1),
            evo_rng: Mutex::new(Rng::new(seed ^ 0x5e17_c0de)),
            evolutions: AtomicUsize::new(0),
        }
    }

    /// Atomic read: fetch the current model snapshot.
    pub fn fetch(&self) -> Snapshot {
        let st = self.state.lock().unwrap();
        Snapshot {
            model: Arc::clone(&st.snapshot),
            gen: st.gen,
            step: st.step,
        }
    }

    /// Current epoch (workers poll this to decide when to stop).
    pub fn epoch(&self) -> usize {
        self.state.lock().unwrap().epoch
    }

    /// Algorithm 1 line 16: every n÷B pushes (= one "epoch"), run the
    /// fused evolution epoch on the in-place engine — bit-identical to
    /// `prune_model` + `evolve_model` but one structural pass per layer
    /// with workspace reuse, minimising time under the state lock. The
    /// kernel budget stays sequential: the data-parallel workers own the
    /// cores while the server evolves. Shared by [`ParameterServer::push`]
    /// and [`ParameterServer::apply_aligned`] so the two update paths
    /// cannot drift.
    fn end_of_epoch_evolution(&self, st: &mut ServerState) -> Result<()> {
        if st.pushes_since_evolution < self.pushes_per_epoch {
            return Ok(());
        }
        st.pushes_since_evolution = 0;
        st.epoch += 1;
        let mut rng = self.evo_rng.lock().unwrap();
        let imp_due = self.importance.as_ref().filter(|imp| imp.due(st.epoch));
        if self.evolution.is_some() || imp_due.is_some() {
            st.evolver
                .evolve_epoch(&mut st.model, self.evolution.as_ref(), imp_due, &mut rng, 1)?;
        }
        if self.evolution.is_some() {
            st.gen += 1;
        }
        Ok(())
    }

    /// Atomic write: push a gradient; the server applies valid entries
    /// (Algorithm 1 lines 13–21) and advances step/epoch/topology.
    /// Returns `false` (without touching the model or the step counter)
    /// when the gradient carries NaN/Inf entries — a diverged or
    /// corrupted worker must not poison the server model.
    pub fn push(&self, grad: SparseGradient, lr: f32) -> Result<bool> {
        let mut st = self.state.lock().unwrap();
        if !grads_finite(&grad.grad_w, &grad.grad_b) {
            st.nonfinite_rejected += 1;
            return Ok(false);
        }
        let staleness = st.step.saturating_sub(grad.fetched_step);
        st.staleness_sum += staleness;
        st.staleness_max = st.staleness_max.max(staleness);

        if grad.gen == st.gen {
            // fast path: same topology, gradients align with values
            for (l, layer) in st.model.layers.iter_mut().enumerate() {
                layer.apply_update(&self.opt, &grad.grad_w[l], &grad.grad_b[l], lr);
            }
            st.applied_entries += grad.grad_w.iter().map(|g| g.len() as u64).sum::<u64>();
        } else {
            // RetainValidUpdates: merge-intersect worker topology with the
            // current one per row; only entries present in BOTH receive
            // the update.
            let mut applied = 0u64;
            let mut dropped = 0u64;
            for (l, layer) in st.model.layers.iter_mut().enumerate() {
                let worker_w = &grad.topo.layers[l].weights;
                let gw = &grad.grad_w[l];
                let cur = &mut layer.weights;
                let (mu, wd) = (self.opt.momentum, self.opt.weight_decay);
                for i in 0..cur.n_rows {
                    let (ws_, we_) = (worker_w.row_ptr[i], worker_w.row_ptr[i + 1]);
                    let (cs, ce) = (cur.row_ptr[i], cur.row_ptr[i + 1]);
                    let (mut a, mut b) = (ws_, cs);
                    while a < we_ && b < ce {
                        let wc = worker_w.col_idx[a];
                        let cc = cur.col_idx[b];
                        if wc == cc {
                            let g = gw[a];
                            let v = &mut layer.velocity[b];
                            *v = mu * *v - lr * (g + wd * cur.values[b]);
                            cur.values[b] += *v;
                            applied += 1;
                            a += 1;
                            b += 1;
                        } else if wc < cc {
                            dropped += 1;
                            a += 1;
                        } else {
                            b += 1;
                        }
                    }
                    dropped += (we_ - a) as u64;
                }
                // biases always align (no bias topology)
                self.opt
                    .update_bias(&mut layer.bias, &grad.grad_b[l], &mut layer.bias_velocity, lr);
            }
            st.applied_entries += applied;
            st.dropped_entries += dropped;
        }

        st.step += 1;
        st.pushes_since_evolution += 1;

        self.end_of_epoch_evolution(&mut st)?;
        // publish a fresh snapshot for subsequent fetches
        st.snapshot = Arc::new(st.model.clone());
        Ok(true)
    }

    /// Synchronous update path (WASSP): apply an averaged dense-of-sparse
    /// gradient already aligned to the CURRENT topology. Returns `false`
    /// (model untouched) when the gradient carries NaN/Inf entries.
    pub fn apply_aligned(&self, grad_w: &[Vec<f32>], grad_b: &[Vec<f32>], lr: f32) -> Result<bool> {
        let mut st = self.state.lock().unwrap();
        if !grads_finite(grad_w, grad_b) {
            st.nonfinite_rejected += 1;
            return Ok(false);
        }
        for (l, layer) in st.model.layers.iter_mut().enumerate() {
            layer.apply_update(&self.opt, &grad_w[l], &grad_b[l], lr);
        }
        st.step += 1;
        st.pushes_since_evolution += 1;
        self.end_of_epoch_evolution(&mut st)?;
        st.snapshot = Arc::new(st.model.clone());
        Ok(true)
    }

    /// Take the final model + stats (consumes nothing; clones).
    pub fn finish(&self) -> (SparseMlp, ServerStats) {
        let st = self.state.lock().unwrap();
        let stats = ServerStats {
            steps: st.step,
            epochs: st.epoch,
            applied_entries: st.applied_entries,
            dropped_entries: st.dropped_entries,
            mean_staleness: if st.step > 0 {
                st.staleness_sum as f64 / st.step as f64
            } else {
                0.0
            },
            max_staleness: st.staleness_max,
            generations: st.gen,
            nonfinite_rejected: st.nonfinite_rejected,
        };
        (st.model.clone(), stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Activation;
    use crate::sparse::WeightInit;

    fn model(seed: u64) -> SparseMlp {
        SparseMlp::new(
            &[10, 16, 4],
            5.0,
            Activation::Relu,
            &WeightInit::Normal(0.5),
            &mut Rng::new(seed),
        )
        .unwrap()
    }

    fn zero_grad_like(m: &SparseMlp) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        (
            m.layers.iter().map(|l| vec![0.0; l.weights.nnz()]).collect(),
            m.layers.iter().map(|l| vec![0.0; l.n_out()]).collect(),
        )
    }

    #[test]
    fn fetch_then_aligned_push_updates_model() {
        let m = model(1);
        let ps = ParameterServer::new(
            m,
            MomentumSgd {
                momentum: 0.0,
                weight_decay: 0.0,
            },
            None,
            None,
            1000,
            0,
        );
        let snap = ps.fetch();
        let (mut gw, gb) = zero_grad_like(&snap.model);
        gw[0][0] = 1.0;
        let before = snap.model.layers[0].weights.values[0];
        ps.push(
            SparseGradient {
                grad_w: gw,
                grad_b: gb,
                topo: Arc::clone(&snap.model),
                gen: snap.gen,
                fetched_step: snap.step,
            },
            0.1,
        )
        .unwrap();
        let (after, stats) = ps.finish();
        assert!((after.layers[0].weights.values[0] - (before - 0.1)).abs() < 1e-6);
        assert_eq!(stats.steps, 1);
        assert_eq!(stats.dropped_entries, 0);
    }

    #[test]
    fn evolution_triggers_every_epoch_of_pushes() {
        let m = model(2);
        let ps = ParameterServer::new(
            m,
            MomentumSgd::default(),
            Some(EvolutionConfig::default()),
            None,
            3, // 3 pushes per epoch
            0,
        );
        for _ in 0..7 {
            let snap = ps.fetch();
            let (gw, gb) = zero_grad_like(&snap.model);
            ps.push(
                SparseGradient {
                    grad_w: gw,
                    grad_b: gb,
                    topo: Arc::clone(&snap.model),
                    gen: snap.gen,
                    fetched_step: snap.step,
                },
                0.01,
            )
            .unwrap();
        }
        let (_, stats) = ps.finish();
        assert_eq!(stats.epochs, 2); // 7 pushes / 3 per epoch
        assert_eq!(stats.generations, 2);
    }

    #[test]
    fn stale_gradient_intersects_topologies() {
        let m = model(3);
        let ps = ParameterServer::new(
            m,
            MomentumSgd {
                momentum: 0.0,
                weight_decay: 0.0,
            },
            Some(EvolutionConfig {
                zeta: 0.5,
                ..Default::default()
            }),
            None,
            1, // evolve after every push
            0,
        );
        let old_snap = ps.fetch();
        // push once to trigger evolution (gen 0 -> 1)
        {
            let (gw, gb) = zero_grad_like(&old_snap.model);
            ps.push(
                SparseGradient {
                    grad_w: gw,
                    grad_b: gb,
                    topo: Arc::clone(&old_snap.model),
                    gen: old_snap.gen,
                    fetched_step: old_snap.step,
                },
                0.01,
            )
            .unwrap();
        }
        // now push a gradient aligned to the OLD topology
        let (mut gw, gb) = zero_grad_like(&old_snap.model);
        for g in gw.iter_mut().flat_map(|v| v.iter_mut()) {
            *g = 1.0;
        }
        ps.push(
            SparseGradient {
                grad_w: gw,
                grad_b: gb,
                topo: Arc::clone(&old_snap.model),
                gen: old_snap.gen,
                fetched_step: old_snap.step,
            },
            0.01,
        )
        .unwrap();
        let (_, stats) = ps.finish();
        // zeta=0.5 pruned roughly half: some entries must be dropped, the
        // surviving intersection applied
        assert!(stats.dropped_entries > 0, "{stats:?}");
        assert!(stats.applied_entries > 0);
        assert!(stats.max_staleness >= 1);
    }

    #[test]
    fn nonfinite_pushes_are_rejected_and_counted() {
        let m = model(5);
        let ps = ParameterServer::new(m, MomentumSgd::default(), None, None, 10, 0);
        let snap = ps.fetch();
        let (mut gw, gb) = zero_grad_like(&snap.model);
        gw[0][0] = f32::NAN;
        let applied = ps
            .push(
                SparseGradient {
                    grad_w: gw,
                    grad_b: gb,
                    topo: Arc::clone(&snap.model),
                    gen: snap.gen,
                    fetched_step: snap.step,
                },
                0.1,
            )
            .unwrap();
        assert!(!applied);
        // aligned path rejects too
        let (gw2, mut gb2) = zero_grad_like(&snap.model);
        gb2[0][0] = f32::INFINITY;
        assert!(!ps.apply_aligned(&gw2, &gb2, 0.1).unwrap());
        let (after, stats) = ps.finish();
        assert_eq!(stats.steps, 0); // rejected pushes advance nothing
        assert_eq!(stats.nonfinite_rejected, 2);
        assert!(after
            .layers
            .iter()
            .flat_map(|l| l.weights.values.iter())
            .all(|v| v.is_finite()));
    }

    #[test]
    fn snapshots_are_cheap_arcs() {
        let m = model(4);
        let ps = ParameterServer::new(m, MomentumSgd::default(), None, None, 10, 0);
        let a = ps.fetch();
        let b = ps.fetch();
        assert!(Arc::ptr_eq(&a.model, &b.model));
    }
}
