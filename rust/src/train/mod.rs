//! Sequential training driver (the paper's §2.2 engine).
//!
//! One CPU core, truly-sparse SET training with optional All-ReLU and
//! Importance Pruning — the configuration space of Table 2. Records the
//! learning curves (Fig. 6/7), parameter trajectories (Fig. 4) and phase
//! timings (Table 4 columns) as it goes.

use crate::config::TrainConfig;
use crate::data::Dataset;
use crate::error::Result;
use crate::gradflow::GradFlowTracker;
use crate::importance;
use crate::model::{Batcher, SparseMlp};
use crate::nn::Dropout;
use crate::set;
use crate::util::{PhaseTimes, Rng, Timer};

pub mod state;

pub use state::{load_state, save_state, TrainState};

/// Per-epoch record (drives Figs. 4, 6, 7).
#[derive(Debug, Clone, Copy)]
pub struct EpochLog {
    /// Epoch index (0-based).
    pub epoch: usize,
    /// Mean training loss over the epoch's batches.
    pub train_loss: f32,
    /// Mean training accuracy over the epoch's batches.
    pub train_accuracy: f32,
    /// Test loss (NaN when not evaluated this epoch).
    pub test_loss: f32,
    /// Test accuracy (NaN when not evaluated this epoch).
    pub test_accuracy: f32,
    /// Stored weights after this epoch (tracks Importance Pruning).
    pub weight_count: usize,
    /// Wall seconds spent in this epoch (train only).
    pub seconds: f64,
}

/// Result of a full training run.
#[derive(Debug)]
pub struct TrainReport {
    /// The trained model.
    pub model: SparseMlp,
    /// Per-epoch logs.
    pub epochs: Vec<EpochLog>,
    /// Weights at start of training (`start_n^W` of Table 2).
    pub start_weights: usize,
    /// Weights at end (`end_n^W`).
    pub end_weights: usize,
    /// Best test accuracy observed.
    pub best_test_accuracy: f32,
    /// Final test accuracy.
    pub final_test_accuracy: f32,
    /// Phase timings: init / train / test / evolution / importance.
    pub phases: PhaseTimes,
    /// Gradient-flow series (present when tracking enabled).
    pub gradflow: Option<GradFlowTracker>,
}

impl TrainReport {
    /// Learning-curve CSV: Fig. 6/7 series.
    pub fn curves_csv(&self) -> String {
        let mut s = String::from(
            "epoch,train_loss,train_acc,test_loss,test_acc,weights,seconds\n",
        );
        for e in &self.epochs {
            s.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                e.epoch,
                e.train_loss,
                e.train_accuracy,
                e.test_loss,
                e.test_accuracy,
                e.weight_count,
                e.seconds
            ));
        }
        s
    }
}

/// Options beyond `TrainConfig` used by instrumentation-heavy benches
/// and by the fault-tolerance layer.
#[derive(Debug, Clone, Default)]
pub struct TrainOptions {
    /// Sample gradient flow on the train set every N epochs (0 = off).
    pub gradflow_every: usize,
    /// Print progress lines via `log`.
    pub verbose: bool,
    /// Periodic durable checkpointing (DESIGN.md §13.2). `None` = off.
    pub checkpoint: Option<CheckpointPolicy>,
}

/// Where and how often the train loop snapshots resumable state.
#[derive(Debug, Clone)]
pub struct CheckpointPolicy {
    /// Train-state file (atomic temp+fsync+rename, CRC-trailed).
    pub path: std::path::PathBuf,
    /// Save after every N completed epochs (0 = never).
    pub every: usize,
}

/// What an epoch-boundary hook tells the loop to do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HookAction {
    /// Keep training.
    Continue,
    /// Stop cleanly after this epoch (state already checkpointed if a
    /// policy is set — the chaos suite uses this to simulate a kill at
    /// an exact epoch boundary).
    Stop,
}

/// Epoch-boundary callback: `(completed_epoch, model)`. Runs after the
/// epoch fully completes (evolution, eval, checkpoint) — the worker
/// protocol hangs phase-2 heartbeats off this, the chaos suite uses it
/// to stop runs at a chosen boundary.
pub type EpochHook<'a> = &'a mut dyn FnMut(usize, &SparseMlp) -> HookAction;

/// Where a (possibly resumed) run starts and what it has accumulated.
#[derive(Debug, Clone)]
struct ResumeCursor {
    next_epoch: usize,
    start_weights: Option<usize>,
    best_test: f32,
    final_test: f32,
    epochs: Vec<EpochLog>,
}

impl ResumeCursor {
    fn fresh() -> ResumeCursor {
        ResumeCursor {
            next_epoch: 0,
            start_weights: None,
            best_test: 0.0,
            final_test: f32::NAN,
            epochs: Vec::new(),
        }
    }

    fn from_state(state: &TrainState) -> ResumeCursor {
        ResumeCursor {
            next_epoch: state.next_epoch,
            start_weights: Some(state.start_weights),
            best_test: state.best_test,
            final_test: state.final_test,
            epochs: state.epochs.clone(),
        }
    }
}

/// Train a fresh model per the config — the sequential baseline.
pub fn train_sequential(cfg: &TrainConfig, data: &Dataset, rng: &mut Rng) -> Result<TrainReport> {
    train_sequential_opts(cfg, data, rng, TrainOptions::default())
}

/// [`train_sequential`] with instrumentation options.
pub fn train_sequential_opts(
    cfg: &TrainConfig,
    data: &Dataset,
    rng: &mut Rng,
    opts: TrainOptions,
) -> Result<TrainReport> {
    let mut phases = PhaseTimes::new();
    let sizes = cfg.sizes(data.n_features, data.n_classes);
    let mut model = phases.time("init", || {
        SparseMlp::new(&sizes, cfg.epsilon, cfg.activation, &cfg.init, rng)
    })?;
    let report = train_model(cfg, data, &mut model, rng, opts, &mut phases)?;
    Ok(report)
}

/// Train an existing model (used by the coordinator's phase 2 and by
/// ablations that reuse initial topologies).
pub fn train_model(
    cfg: &TrainConfig,
    data: &Dataset,
    model: &mut SparseMlp,
    rng: &mut Rng,
    opts: TrainOptions,
    phases: &mut PhaseTimes,
) -> Result<TrainReport> {
    train_model_hooked(cfg, data, model, rng, opts, phases, None)
}

/// [`train_model`] with an epoch-boundary hook.
pub fn train_model_hooked(
    cfg: &TrainConfig,
    data: &Dataset,
    model: &mut SparseMlp,
    rng: &mut Rng,
    opts: TrainOptions,
    phases: &mut PhaseTimes,
    hook: Option<EpochHook<'_>>,
) -> Result<TrainReport> {
    train_model_from(cfg, data, model, rng, opts, phases, ResumeCursor::fresh(), hook)
}

/// Resume a run from a durable [`TrainState`]. The caller regenerates
/// the dataset exactly as the original run did (same seed, same spec);
/// the state supplies the model, RNG and report accumulators, and the
/// loop continues at `state.next_epoch` bit-exactly as if the original
/// process had never died (pinned by `tests/chaos.rs`).
pub fn train_resume(
    cfg: &TrainConfig,
    data: &Dataset,
    state: TrainState,
    opts: TrainOptions,
    phases: &mut PhaseTimes,
) -> Result<TrainReport> {
    let cursor = ResumeCursor::from_state(&state);
    let mut model = state.model;
    let mut rng = state.rng();
    train_model_from(cfg, data, &mut model, &mut rng, opts, phases, cursor, None)
}

#[allow(clippy::too_many_arguments)]
fn train_model_from(
    cfg: &TrainConfig,
    data: &Dataset,
    model: &mut SparseMlp,
    rng: &mut Rng,
    opts: TrainOptions,
    phases: &mut PhaseTimes,
    cursor: ResumeCursor,
    mut hook: Option<EpochHook<'_>>,
) -> Result<TrainReport> {
    let start_weights = cursor.start_weights.unwrap_or_else(|| model.weight_count());
    let mut ws = model.alloc_workspace(cfg.batch);
    // Kernel-shard budget rides in the workspace so every forward and
    // every fused backward (`SparseLayer::backward_into`, DESIGN.md §5)
    // below — train steps, eval, gradflow probes — inherits it. The
    // persistent worker pool (DESIGN.md §9) spawns once here and serves
    // every sharded dispatch of the whole run.
    ws.kernel_threads = cfg.kernel_threads;
    ws.ensure_pool();
    let mut batcher = Batcher::new(data.n_train(), data.n_features, cfg.batch);
    let dropout = if cfg.dropout > 0.0 {
        Some(Dropout::new(cfg.dropout))
    } else {
        None
    };
    let mut gradflow = if opts.gradflow_every > 0 {
        Some(GradFlowTracker::new())
    } else {
        None
    };
    // Topology evolution runs on the worker-sharded in-place engine
    // (DESIGN.md §8): importance pruning and the SET prune-regrow cycle
    // fused into one structural pass per layer, workspace buffers reused
    // across epochs — dispatched on the SAME persistent pool as the
    // kernels, so the steady-state loop never spawns a thread.
    let mut evolver = match ws.pool() {
        Some(pool) => set::EvolutionEngine::with_pool(pool),
        None => set::EvolutionEngine::new(),
    };

    let mut epochs = cursor.epochs;
    let mut best_test = cursor.best_test;
    let mut final_test = cursor.final_test;

    for epoch in cursor.next_epoch..cfg.epochs {
        let lr = cfg.lr.at(epoch);
        let timer = Timer::start();
        batcher.reset(rng);
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        let mut n_batches = 0usize;
        while let Some((x, y)) = batcher.next_batch(&data.x_train, &data.y_train) {
            let stats =
                model.train_step(x, y, &cfg.optimizer, lr, dropout.as_ref(), &mut ws, rng);
            loss_sum += stats.loss as f64;
            acc_sum += stats.accuracy as f64;
            n_batches += 1;
        }
        let train_secs = timer.secs();
        phases.add("train", train_secs);

        // gradient-flow probe (before evolution, like the paper's Fig. 5)
        if let Some(gf) = gradflow.as_mut() {
            if epoch % opts.gradflow_every == 0 {
                phases.time("gradflow", || {
                    gf.measure(
                        model,
                        epoch,
                        &data.x_train,
                        &data.y_train,
                        cfg.batch,
                        4,
                        &mut ws,
                    )
                });
            }
        }

        // Importance pruning (Algorithm 2: before the prune-regrow cycle)
        // and the SET pruning-regrowing cycle, fused into ONE in-place
        // structural pass per layer by the evolution engine. SET is
        // skipped after the final epoch so the evaluated model matches
        // the trained weights (as in SET); importance-only epochs still
        // run standalone in that case.
        let imp_due = cfg.importance.as_ref().filter(|imp| imp.due(epoch));
        let evo_due = cfg.evolution.as_ref().filter(|_| epoch + 1 < cfg.epochs);
        match (evo_due, imp_due) {
            (Some(evo), imp) => {
                let stats = phases.time("evolution", || {
                    evolver.evolve_epoch(model, Some(evo), imp, rng, cfg.kernel_threads)
                })?;
                if opts.verbose && imp.is_some() {
                    let removed: usize = stats.iter().map(|s| s.importance_pruned).sum();
                    log::info!("epoch {epoch}: importance pruning removed {removed}");
                }
            }
            (None, Some(imp)) => {
                let removed = phases.time("importance", || importance::prune_model(model, imp));
                if opts.verbose {
                    log::info!("epoch {epoch}: importance pruning removed {removed}");
                }
            }
            (None, None) => {}
        }

        // evaluation
        let (mut test_loss, mut test_acc) = (f32::NAN, f32::NAN);
        if cfg.eval_every > 0 && (epoch % cfg.eval_every == 0 || epoch + 1 == cfg.epochs) {
            let (l, a) = phases.time("test", || {
                model.evaluate(&data.x_test, &data.y_test, cfg.batch.max(256), &mut ws)
            });
            test_loss = l;
            test_acc = a;
            best_test = best_test.max(a);
            final_test = a;
        }

        let log_entry = EpochLog {
            epoch,
            train_loss: (loss_sum / n_batches.max(1) as f64) as f32,
            train_accuracy: (acc_sum / n_batches.max(1) as f64) as f32,
            test_loss,
            test_accuracy: test_acc,
            weight_count: model.weight_count(),
            seconds: train_secs,
        };
        if opts.verbose {
            log::info!(
                "epoch {:>4}  loss {:.4}  train_acc {:.4}  test_acc {:.4}  weights {}",
                epoch,
                log_entry.train_loss,
                log_entry.train_accuracy,
                log_entry.test_accuracy,
                log_entry.weight_count
            );
        }
        epochs.push(log_entry);

        // durable snapshot at the epoch boundary (model + RNG + report
        // accumulators) — written AFTER evolution and eval so a resumed
        // loop re-enters at exactly this point in the random stream
        if let Some(ck) = &opts.checkpoint {
            if ck.every > 0 && (epoch + 1) % ck.every == 0 {
                let snapshot = TrainState {
                    model: model.clone(),
                    rng: rng.state(),
                    next_epoch: epoch + 1,
                    start_weights,
                    best_test,
                    final_test,
                    epochs: epochs.clone(),
                };
                phases.time("checkpoint", || state::save_state(&snapshot, &ck.path))?;
            }
        }

        if let Some(h) = hook.as_mut() {
            if h(epoch, model) == HookAction::Stop {
                break;
            }
        }
    }

    Ok(TrainReport {
        end_weights: model.weight_count(),
        start_weights,
        best_test_accuracy: best_test,
        final_test_accuracy: final_test,
        epochs,
        phases: std::mem::take(phases),
        gradflow,
        model: model.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;
    use crate::data::datasets;
    use crate::importance::ImportanceConfig;

    fn quick_cfg() -> TrainConfig {
        // Short-horizon test config: SET regrowth (ζ=0.3/epoch) injects
        // fresh random weights every epoch, so a 12-epoch run at the
        // paper's η=0.01 bounces; a larger η lets the test converge fast
        // while still exercising the full evolution path.
        TrainConfig {
            hidden: vec![64, 32],
            epsilon: 8.0,
            epochs: 20,
            batch: 64,
            dropout: 0.0,
            lr: crate::nn::LrSchedule::Constant(0.05),
            ..TrainConfig::default()
        }
    }

    fn quick_data() -> crate::data::Dataset {
        let spec = DatasetSpec {
            name: "toy".into(),
            generator: "madelon".into(),
            n_features: 60,
            n_classes: 2,
            n_train: 500,
            n_test: 200,
        };
        datasets::generate(&spec, &mut Rng::new(1)).unwrap()
    }

    #[test]
    fn sequential_training_learns() {
        let data = quick_data();
        let report = train_sequential(&quick_cfg(), &data, &mut Rng::new(2)).unwrap();
        assert_eq!(report.epochs.len(), 20);
        let first = report.epochs.first().unwrap().train_loss;
        let last = report.epochs.last().unwrap().train_loss;
        assert!(last < first, "loss {first} -> {last}");
        assert!(report.best_test_accuracy > 0.55, "{}", report.best_test_accuracy);
        assert!(report.phases.get("train") > 0.0);
        assert!(report.phases.get("evolution") > 0.0);
    }

    #[test]
    fn importance_pruning_reduces_weights() {
        let data = quick_data();
        let mut cfg = quick_cfg();
        cfg.importance = Some(ImportanceConfig {
            start_epoch: 4,
            period: 2,
            percentile: 10.0,
            min_connections: 8,
        });
        let base = train_sequential(&quick_cfg(), &data, &mut Rng::new(3)).unwrap();
        let pruned = train_sequential(&cfg, &data, &mut Rng::new(3)).unwrap();
        assert!(
            pruned.end_weights < base.end_weights,
            "{} vs {}",
            pruned.end_weights,
            base.end_weights
        );
        // pruning shouldn't destroy the model
        assert!(pruned.best_test_accuracy > 0.5);
    }

    #[test]
    fn static_sparsity_keeps_weight_count() {
        let data = quick_data();
        let mut cfg = quick_cfg();
        cfg.evolution = None;
        let report = train_sequential(&cfg, &data, &mut Rng::new(4)).unwrap();
        assert_eq!(report.start_weights, report.end_weights);
    }

    #[test]
    fn gradflow_tracking_records_points() {
        let data = quick_data();
        let mut cfg = quick_cfg();
        cfg.epochs = 6;
        let report = train_sequential_opts(
            &cfg,
            &data,
            &mut Rng::new(5),
            TrainOptions {
                gradflow_every: 2,
                verbose: false,
                ..Default::default()
            },
        )
        .unwrap();
        let gf = report.gradflow.unwrap();
        assert_eq!(gf.points.len(), 3);
    }

    #[test]
    fn curves_csv_shape() {
        let data = quick_data();
        let mut cfg = quick_cfg();
        cfg.epochs = 3;
        let report = train_sequential(&cfg, &data, &mut Rng::new(6)).unwrap();
        let csv = report.curves_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("epoch,"));
    }

    #[test]
    fn kernel_threads_setting_preserves_results() {
        let data = quick_data();
        let mut cfg = quick_cfg();
        cfg.epochs = 4;
        cfg.kernel_threads = 1;
        let a = train_sequential(&cfg, &data, &mut Rng::new(8)).unwrap();
        cfg.kernel_threads = 8;
        let b = train_sequential(&cfg, &data, &mut Rng::new(8)).unwrap();
        assert_eq!(
            a.epochs.last().unwrap().train_loss,
            b.epochs.last().unwrap().train_loss
        );
        assert_eq!(a.end_weights, b.end_weights);
    }

    #[test]
    fn deterministic_given_seed() {
        let data = quick_data();
        let mut cfg = quick_cfg();
        cfg.epochs = 4;
        let a = train_sequential(&cfg, &data, &mut Rng::new(7)).unwrap();
        let b = train_sequential(&cfg, &data, &mut Rng::new(7)).unwrap();
        assert_eq!(
            a.epochs.last().unwrap().train_loss,
            b.epochs.last().unwrap().train_loss
        );
        assert_eq!(a.end_weights, b.end_weights);
    }
}
