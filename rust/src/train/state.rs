//! Durable, resumable training state (DESIGN.md §13.2).
//!
//! A `TrainState` is everything the sequential train loop needs to
//! continue **bit-exactly** from an epoch boundary: the full model
//! (weights, bias, velocities — the same image the `TSNN` checkpoint
//! carries), the raw xoshiro256** RNG state, the epoch cursor, and the
//! accumulated report fields (epoch logs, best/final test accuracy,
//! starting weight count). Topology-evolution state needs no extra
//! fields: the evolved topology lives in the model and the prune/regrow
//! draws replay from the restored RNG.
//!
//! Layout (little-endian, magic "TSNT", version 1):
//!   magic | version u32 | model image (checkpoint body) |
//!   rng [u64; 4] | next_epoch u64 | start_weights u64 |
//!   best_test f32 | final_test f32 |
//!   n_logs u64 | per log: epoch u64, train_loss f32, train_acc f32,
//!                         test_loss f32, test_acc f32,
//!                         weight_count u64, seconds f64
//!   | crc32 u32
//!
//! Binary throughout (no JSON): RNG words don't fit in f64-backed JSON
//! numbers and un-evaluated epochs carry NaN accuracies. Writes go
//! through the same atomic temp+fsync+rename protocol as model
//! checkpoints, and the CRC-32 trailer is mandatory from version 1.

use std::io::{Cursor, Read, Write};
use std::path::Path;

use crate::error::{Result, TsnnError};
use crate::model::checkpoint::{
    checked_image, read_f32, read_f64, read_framed, read_model, read_u64, tmp_path, write_durable,
    write_f32, write_f64, write_model, write_u32, write_u64,
};
use crate::model::SparseMlp;
use crate::util::Rng;

use super::EpochLog;

const MAGIC: &[u8; 4] = b"TSNT";
const VERSION: u32 = 1;

/// More epoch logs than any plausible run; a crafted length field past
/// this fails before allocation.
const MAX_LOGS: u64 = 1 << 24;

/// Full resumable snapshot of a sequential training run at an epoch
/// boundary (`next_epoch` epochs completed).
#[derive(Debug, Clone)]
pub struct TrainState {
    /// The model as of the end of epoch `next_epoch - 1`.
    pub model: SparseMlp,
    /// Raw RNG state at the epoch boundary.
    pub rng: [u64; 4],
    /// First epoch the resumed loop will run.
    pub next_epoch: usize,
    /// Weight count at the start of the original run.
    pub start_weights: usize,
    /// Best test accuracy observed so far.
    pub best_test: f32,
    /// Most recent test accuracy (NaN if never evaluated).
    pub final_test: f32,
    /// Per-epoch logs accumulated so far.
    pub epochs: Vec<EpochLog>,
}

/// Atomically save a training state to `path` (temp + fsync + rename +
/// CRC trailer, like model checkpoints).
pub fn save_state(state: &TrainState, path: &Path) -> Result<()> {
    let mut image = Vec::new();
    image.extend_from_slice(MAGIC);
    write_u32(&mut image, VERSION)?;
    write_state_body(&mut image, state)?;
    write_durable(path, image)
}

fn write_state_body(w: &mut impl Write, state: &TrainState) -> Result<()> {
    write_model(w, &state.model)?;
    for word in state.rng {
        write_u64(w, word)?;
    }
    write_u64(w, state.next_epoch as u64)?;
    write_u64(w, state.start_weights as u64)?;
    write_f32(w, state.best_test)?;
    write_f32(w, state.final_test)?;
    write_u64(w, state.epochs.len() as u64)?;
    for e in &state.epochs {
        write_u64(w, e.epoch as u64)?;
        write_f32(w, e.train_loss)?;
        write_f32(w, e.train_accuracy)?;
        write_f32(w, e.test_loss)?;
        write_f32(w, e.test_accuracy)?;
        write_u64(w, e.weight_count as u64)?;
        write_f64(w, e.seconds)?;
    }
    Ok(())
}

/// Load a training state; the CRC trailer is verified before any field
/// is parsed, so a torn write surfaces as
/// [`TsnnError::ChecksumMismatch`], never as a half-restored run.
pub fn load_state(path: &Path) -> Result<TrainState> {
    let (version, bytes) = read_framed(path, MAGIC)?;
    if version != VERSION {
        return Err(TsnnError::Checkpoint(format!(
            "unsupported train-state version {version}"
        )));
    }
    let (start, end) = checked_image(&bytes)?;
    let body = &bytes[start..end];
    let mut r = Cursor::new(body);
    let state = read_state_body(&mut r)?;
    if (r.position() as usize) != body.len() {
        return Err(TsnnError::Checkpoint(
            "trailing bytes after train state".into(),
        ));
    }
    // a zero RNG state can't come from a real run (xoshiro fixed point)
    if state.rng.iter().all(|&w| w == 0) {
        return Err(TsnnError::Checkpoint("all-zero rng state".into()));
    }
    Ok(state)
}

fn read_state_body(r: &mut impl Read) -> Result<TrainState> {
    let model = read_model(r)?;
    let mut rng = [0u64; 4];
    for word in &mut rng {
        *word = read_u64(r)?;
    }
    let next_epoch = read_u64(r)? as usize;
    let start_weights = read_u64(r)? as usize;
    let best_test = read_f32(r)?;
    let final_test = read_f32(r)?;
    let n_logs = read_u64(r)?;
    if n_logs > MAX_LOGS {
        return Err(TsnnError::Checkpoint(format!(
            "implausible epoch-log count {n_logs}"
        )));
    }
    let mut epochs = Vec::with_capacity(n_logs as usize);
    for _ in 0..n_logs {
        epochs.push(EpochLog {
            epoch: read_u64(r)? as usize,
            train_loss: read_f32(r)?,
            train_accuracy: read_f32(r)?,
            test_loss: read_f32(r)?,
            test_accuracy: read_f32(r)?,
            weight_count: read_u64(r)? as usize,
            seconds: read_f64(r)?,
        });
    }
    Ok(TrainState {
        model,
        rng,
        next_epoch,
        start_weights,
        best_test,
        final_test,
        epochs,
    })
}

impl TrainState {
    /// Restore the generator this state snapshotted.
    pub fn rng(&self) -> Rng {
        Rng::from_state(self.rng)
    }

    /// `true` if `path` has a state file and no stale temp sibling from
    /// an interrupted save (the temp is ignored either way — rename
    /// atomicity means only `path` itself is ever trusted).
    pub fn exists(path: &Path) -> bool {
        path.exists()
    }

    /// Remove a stale temp sibling left by a crash mid-save. Safe to
    /// call unconditionally before resuming.
    pub fn clean_stale_tmp(path: &Path) {
        let tmp = tmp_path(path);
        if tmp.exists() {
            let _ = std::fs::remove_file(&tmp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Activation;
    use crate::sparse::WeightInit;

    fn sample_state() -> TrainState {
        let mut rng = Rng::new(11);
        let model = SparseMlp::new(
            &[12, 8, 3],
            3.0,
            Activation::AllRelu { alpha: 0.6 },
            &WeightInit::Xavier,
            &mut rng,
        )
        .unwrap();
        for _ in 0..5 {
            rng.next_u64();
        }
        TrainState {
            model,
            rng: rng.state(),
            next_epoch: 7,
            start_weights: 123,
            best_test: 0.81,
            final_test: f32::NAN,
            epochs: vec![
                EpochLog {
                    epoch: 5,
                    train_loss: 0.4,
                    train_accuracy: 0.8,
                    test_loss: f32::NAN,
                    test_accuracy: f32::NAN,
                    weight_count: 120,
                    seconds: 0.25,
                },
                EpochLog {
                    epoch: 6,
                    train_loss: 0.35,
                    train_accuracy: 0.85,
                    test_loss: 0.5,
                    test_accuracy: 0.81,
                    weight_count: 118,
                    seconds: 0.27,
                },
            ],
        }
    }

    #[test]
    fn roundtrip_preserves_everything_including_nan_logs() {
        let state = sample_state();
        let dir = std::env::temp_dir().join("tsnn_state_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.tsnt");
        save_state(&state, &path).unwrap();
        let loaded = load_state(&path).unwrap();
        assert_eq!(loaded.rng, state.rng);
        assert_eq!(loaded.next_epoch, 7);
        assert_eq!(loaded.start_weights, 123);
        assert_eq!(loaded.best_test, 0.81);
        assert!(loaded.final_test.is_nan());
        assert_eq!(loaded.epochs.len(), 2);
        assert!(loaded.epochs[0].test_accuracy.is_nan());
        assert_eq!(loaded.epochs[1].weight_count, 118);
        assert_eq!(loaded.model.sizes, state.model.sizes);
        for (a, b) in loaded.model.layers.iter().zip(state.model.layers.iter()) {
            assert_eq!(a.weights, b.weights);
            assert_eq!(a.velocity, b.velocity);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncation_and_corruption_are_typed_errors() {
        let state = sample_state();
        let dir = std::env::temp_dir().join("tsnn_state_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.tsnt");
        save_state(&state, &path).unwrap();
        let good = std::fs::read(&path).unwrap();
        for cut in [0, 3, 7, 11, good.len() / 2, good.len() - 1] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(load_state(&path).is_err(), "cut at {cut} loaded");
        }
        let mut flipped = good.clone();
        let mid = flipped.len() / 3;
        flipped[mid] ^= 0x10;
        std::fs::write(&path, &flipped).unwrap();
        match load_state(&path) {
            Err(TsnnError::ChecksumMismatch(_)) => {}
            other => panic!("expected checksum mismatch, got {other:?}"),
        }
        std::fs::remove_file(&path).unwrap();
    }
}
