//! Hand-rolled CLI argument parsing (no clap in the offline vendor set).
//!
//! Grammar: `tsnn <subcommand> [positional] [--flag] [--key value]
//! [key=value ...]` — `key=value` pairs flow into `TrainConfig::set`.

use std::collections::BTreeMap;

use crate::error::{Result, TsnnError};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Subcommand (first non-flag token).
    pub command: String,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
    /// `--key value` / `--flag` options.
    pub options: BTreeMap<String, String>,
    /// `key=value` config overrides.
    pub overrides: Vec<(String, String)>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(TsnnError::Config("empty flag '--'".into()));
                }
                // --key=value or --key value or boolean --flag
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    let takes_value = it
                        .peek()
                        .map(|n| !n.starts_with("--") && !n.contains('='))
                        .unwrap_or(false);
                    if takes_value {
                        let v = it.next().unwrap();
                        args.options.insert(name.to_string(), v);
                    } else {
                        args.options.insert(name.to_string(), "true".to_string());
                    }
                }
            } else if let Some((k, v)) = tok.split_once('=') {
                args.overrides.push((k.to_string(), v.to_string()));
            } else if args.command.is_empty() {
                args.command = tok;
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// Option as string.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Option parsed to a type, with default.
    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| TsnnError::Config(format!("bad value '{v}' for --{key}"))),
        }
    }

    /// Boolean flag presence.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.options.get(key).map(String::as_str), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn full_grammar() {
        let a = parse("train fashion --workers 4 --verbose epochs=10 lr=0.01 --out=x.csv");
        assert_eq!(a.command, "train");
        assert_eq!(a.positional, vec!["fashion"]);
        assert_eq!(a.opt("workers"), Some("4"));
        assert!(a.flag("verbose"));
        assert_eq!(a.opt("out"), Some("x.csv"));
        assert_eq!(
            a.overrides,
            vec![("epochs".into(), "10".into()), ("lr".into(), "0.01".into())]
        );
    }

    #[test]
    fn opt_parse_types() {
        let a = parse("x --n 7");
        assert_eq!(a.opt_parse("n", 0usize).unwrap(), 7);
        assert_eq!(a.opt_parse("missing", 3usize).unwrap(), 3);
        let bad = parse("x --n seven");
        assert!(bad.opt_parse("n", 0usize).is_err());
    }

    #[test]
    fn boolean_flag_before_positional() {
        let a = parse("bench --quick table2");
        // --quick swallows nothing since 'table2' has no '='... it does
        // swallow: careful — document the behaviour: flags before
        // positionals take them as values.
        assert_eq!(a.opt("quick"), Some("table2"));
    }

    #[test]
    fn empty_flag_rejected() {
        assert!(Args::parse(vec!["--".to_string()]).is_err());
    }

    #[test]
    fn no_command_is_empty() {
        let a = parse("");
        assert_eq!(a.command, "");
    }
}
