//! Experiment configuration: dataset specs, training hyperparameters,
//! the paper's Table 7 presets, and a small `key=value` config parser so
//! experiments are reproducible from files or CLI overrides.

use crate::error::{Result, TsnnError};
use crate::importance::ImportanceConfig;
use crate::nn::{Activation, LrSchedule, MomentumSgd};
use crate::set::EvolutionConfig;
use crate::sparse::WeightInit;

/// What dataset to generate (see `data::datasets`).
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    /// Human-readable name (used in reports).
    pub name: String,
    /// Generator id: leukemia | higgs | madelon | fashion | cifar | extreme.
    pub generator: String,
    /// Feature dimensionality.
    pub n_features: usize,
    /// Class count.
    pub n_classes: usize,
    /// Train samples.
    pub n_train: usize,
    /// Test samples.
    pub n_test: usize,
}

impl DatasetSpec {
    /// Paper-scale spec (Table 1 shapes).
    pub fn paper(name: &str) -> DatasetSpec {
        match name {
            "leukemia" => DatasetSpec {
                name: name.into(),
                generator: "leukemia".into(),
                n_features: 54675,
                n_classes: 18,
                n_train: 1397,
                n_test: 699,
            },
            "higgs" => DatasetSpec {
                name: name.into(),
                generator: "higgs".into(),
                n_features: 28,
                n_classes: 2,
                n_train: 105_000,
                n_test: 50_000,
            },
            "madelon" => DatasetSpec {
                name: name.into(),
                generator: "madelon".into(),
                n_features: 500,
                n_classes: 2,
                n_train: 2000,
                n_test: 600,
            },
            "fashion" => DatasetSpec {
                name: name.into(),
                generator: "fashion".into(),
                n_features: 784,
                n_classes: 10,
                n_train: 60_000,
                n_test: 10_000,
            },
            "cifar" => DatasetSpec {
                name: name.into(),
                generator: "cifar".into(),
                n_features: 3072,
                n_classes: 10,
                n_train: 50_000,
                n_test: 10_000,
            },
            "extreme" => DatasetSpec {
                name: name.into(),
                generator: "extreme".into(),
                n_features: 65_536,
                n_classes: 2,
                n_train: 7000,
                n_test: 3000,
            },
            other => panic!("unknown paper dataset '{other}'"),
        }
    }

    /// Scaled-down spec for tests and default bench runs (same shape
    /// family, 1-core-friendly sample counts).
    pub fn small(name: &str) -> DatasetSpec {
        match name {
            "leukemia" => DatasetSpec {
                name: name.into(),
                generator: "leukemia".into(),
                n_features: 2048,
                n_classes: 18,
                n_train: 700,
                n_test: 350,
            },
            "higgs" => DatasetSpec {
                name: name.into(),
                generator: "higgs".into(),
                n_features: 28,
                n_classes: 2,
                n_train: 4000,
                n_test: 2000,
            },
            "madelon" => DatasetSpec {
                name: name.into(),
                generator: "madelon".into(),
                n_features: 500,
                n_classes: 2,
                n_train: 2000,
                n_test: 600,
            },
            "fashion" => DatasetSpec {
                name: name.into(),
                generator: "fashion".into(),
                n_features: 784,
                n_classes: 10,
                n_train: 4000,
                n_test: 1000,
            },
            "cifar" => DatasetSpec {
                name: name.into(),
                generator: "cifar".into(),
                n_features: 3072,
                n_classes: 10,
                n_train: 3000,
                n_test: 1000,
            },
            "extreme" => DatasetSpec {
                name: name.into(),
                generator: "extreme".into(),
                n_features: 4096,
                n_classes: 2,
                n_train: 1400,
                n_test: 600,
            },
            other => panic!("unknown small dataset '{other}'"),
        }
    }
}

/// Full training configuration (architecture + optimisation + the three
/// paper contributions' switches).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Hidden layer sizes (input/output come from the dataset).
    pub hidden: Vec<usize>,
    /// SET sparsity knob ε.
    pub epsilon: f64,
    /// Hidden activation.
    pub activation: Activation,
    /// Weight initialisation scheme.
    pub init: WeightInit,
    /// LR schedule.
    pub lr: LrSchedule,
    /// Optimiser hyperparameters.
    pub optimizer: MomentumSgd,
    /// Mini-batch size.
    pub batch: usize,
    /// Training epochs.
    pub epochs: usize,
    /// Dropout rate on hidden activations (0 disables).
    pub dropout: f32,
    /// SET evolution (None = static sparsity).
    pub evolution: Option<EvolutionConfig>,
    /// Importance pruning (None = off).
    pub importance: Option<ImportanceConfig>,
    /// RNG seed.
    pub seed: u64,
    /// Evaluate on test set every `eval_every` epochs.
    pub eval_every: usize,
    /// Worker budget for the sharded sparse kernels (DESIGN.md §4):
    /// `0` = one per available core, `1` = always sequential, `n` = at
    /// most n threads per kernel call. Results are identical at any
    /// setting; this only trades wall-clock for cores.
    pub kernel_threads: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            hidden: vec![256, 256],
            epsilon: 10.0,
            activation: Activation::AllRelu { alpha: 0.6 },
            init: WeightInit::HeUniform,
            lr: LrSchedule::Constant(0.01),
            optimizer: MomentumSgd::default(),
            batch: 128,
            epochs: 50,
            dropout: 0.3,
            evolution: Some(EvolutionConfig::default()),
            importance: None,
            seed: 42,
            eval_every: 1,
            kernel_threads: 0,
        }
    }
}

impl TrainConfig {
    /// Table 7 hyperparameters for a paper dataset (ε, η, batch, init, α),
    /// with the Table 2 architectures.
    pub fn paper_preset(dataset: &str) -> TrainConfig {
        let d = TrainConfig::default();
        match dataset {
            "leukemia" => TrainConfig {
                hidden: vec![27_500, 27_500],
                epsilon: 10.0,
                activation: Activation::AllRelu { alpha: 0.75 },
                init: WeightInit::Normal(0.05),
                lr: LrSchedule::Constant(0.005),
                batch: 5,
                ..d
            },
            "higgs" => TrainConfig {
                hidden: vec![1000, 1000, 1000],
                epsilon: 10.0,
                activation: Activation::AllRelu { alpha: 0.05 },
                init: WeightInit::Xavier,
                lr: LrSchedule::Constant(0.01),
                batch: 128,
                ..d
            },
            "madelon" => TrainConfig {
                hidden: vec![400, 100, 400],
                epsilon: 10.0,
                activation: Activation::AllRelu { alpha: 0.5 },
                init: WeightInit::Normal(0.05),
                lr: LrSchedule::Constant(0.01),
                batch: 32,
                ..d
            },
            "fashion" => TrainConfig {
                hidden: vec![1000, 1000, 1000],
                epsilon: 20.0,
                activation: Activation::AllRelu { alpha: 0.6 },
                init: WeightInit::HeUniform,
                lr: LrSchedule::Constant(0.01),
                batch: 128,
                ..d
            },
            "cifar" => TrainConfig {
                hidden: vec![4000, 1000, 4000],
                epsilon: 20.0,
                activation: Activation::AllRelu { alpha: 0.75 },
                init: WeightInit::HeUniform,
                lr: LrSchedule::Constant(0.01),
                batch: 128,
                ..d
            },
            _ => d,
        }
    }

    /// Scaled-down preset matching `DatasetSpec::small` (shorter, thinner).
    pub fn small_preset(dataset: &str) -> TrainConfig {
        let mut cfg = TrainConfig::paper_preset(dataset);
        cfg.epochs = 30;
        cfg.hidden = match dataset {
            "leukemia" => vec![512, 512],
            "higgs" => vec![256, 256, 256],
            "madelon" => vec![400, 100, 400],
            "fashion" => vec![256, 256, 256],
            "cifar" => vec![512, 256, 512],
            _ => cfg.hidden,
        };
        if let Some(imp) = cfg.importance.as_mut() {
            imp.start_epoch = 10;
            imp.period = 5;
        }
        cfg
    }

    /// Full layer-size vector for a dataset.
    pub fn sizes(&self, n_features: usize, n_classes: usize) -> Vec<usize> {
        let mut s = Vec::with_capacity(self.hidden.len() + 2);
        s.push(n_features);
        s.extend_from_slice(&self.hidden);
        s.push(n_classes);
        s
    }

    /// Apply a `key=value` override (CLI/config-file syntax). Supported
    /// keys: epochs, batch, epsilon, lr, seed, dropout, alpha, activation,
    /// init, hidden (e.g. `hidden=256x256x128`), zeta, importance
    /// (on/off), importance_start, importance_period, importance_pct,
    /// eval_every, momentum, weight_decay, kernel_threads.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let bad = |k: &str, v: &str| TsnnError::Config(format!("bad value '{v}' for '{k}'"));
        match key {
            "epochs" => self.epochs = value.parse().map_err(|_| bad(key, value))?,
            "batch" => self.batch = value.parse().map_err(|_| bad(key, value))?,
            "epsilon" => self.epsilon = value.parse().map_err(|_| bad(key, value))?,
            "seed" => self.seed = value.parse().map_err(|_| bad(key, value))?,
            "dropout" => self.dropout = value.parse().map_err(|_| bad(key, value))?,
            "eval_every" => self.eval_every = value.parse().map_err(|_| bad(key, value))?,
            "kernel_threads" => {
                self.kernel_threads = value.parse().map_err(|_| bad(key, value))?
            }
            "lr" => {
                let eta: f32 = value.parse().map_err(|_| bad(key, value))?;
                self.lr = LrSchedule::Constant(eta);
            }
            "momentum" => {
                self.optimizer.momentum = value.parse().map_err(|_| bad(key, value))?
            }
            "weight_decay" => {
                self.optimizer.weight_decay = value.parse().map_err(|_| bad(key, value))?
            }
            "activation" => {
                self.activation = Activation::parse(value).ok_or_else(|| bad(key, value))?
            }
            "alpha" => {
                let a: f32 = value.parse().map_err(|_| bad(key, value))?;
                self.activation = match self.activation {
                    Activation::AllRelu { .. } => Activation::AllRelu { alpha: a },
                    Activation::LeakyRelu { .. } => Activation::LeakyRelu { alpha: a },
                    other => other,
                };
            }
            "init" => self.init = WeightInit::parse(value).ok_or_else(|| bad(key, value))?,
            "hidden" => {
                let sizes: Option<Vec<usize>> =
                    value.split('x').map(|p| p.parse().ok()).collect();
                self.hidden = sizes.ok_or_else(|| bad(key, value))?;
            }
            "zeta" => {
                let z: f64 = value.parse().map_err(|_| bad(key, value))?;
                self.evolution.get_or_insert_with(Default::default).zeta = z;
            }
            "evolution" => match value {
                "on" => {
                    self.evolution.get_or_insert_with(Default::default);
                }
                "off" => self.evolution = None,
                _ => return Err(bad(key, value)),
            },
            "importance" => match value {
                "on" => {
                    self.importance.get_or_insert_with(Default::default);
                }
                "off" => self.importance = None,
                _ => return Err(bad(key, value)),
            },
            "importance_start" => {
                self.importance
                    .get_or_insert_with(Default::default)
                    .start_epoch = value.parse().map_err(|_| bad(key, value))?
            }
            "importance_period" => {
                self.importance.get_or_insert_with(Default::default).period =
                    value.parse().map_err(|_| bad(key, value))?
            }
            "importance_pct" => {
                self.importance
                    .get_or_insert_with(Default::default)
                    .percentile = value.parse().map_err(|_| bad(key, value))?
            }
            other => {
                return Err(TsnnError::Config(format!("unknown config key '{other}'")));
            }
        }
        Ok(())
    }

    /// Parse a config file: `key = value` lines, `#` comments.
    pub fn apply_file(&mut self, text: &str) -> Result<()> {
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                TsnnError::Config(format!("line {}: expected key=value", lineno + 1))
            })?;
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_presets_match_table7() {
        let c = TrainConfig::paper_preset("fashion");
        assert_eq!(c.epsilon, 20.0);
        assert_eq!(c.batch, 128);
        assert_eq!(c.activation, Activation::AllRelu { alpha: 0.6 });
        assert_eq!(c.init, WeightInit::HeUniform);
        let h = TrainConfig::paper_preset("higgs");
        assert_eq!(h.activation, Activation::AllRelu { alpha: 0.05 });
        assert_eq!(h.init, WeightInit::Xavier);
        let m = TrainConfig::paper_preset("madelon");
        assert_eq!(m.hidden, vec![400, 100, 400]);
        assert_eq!(m.batch, 32);
        let l = TrainConfig::paper_preset("leukemia");
        assert_eq!(l.batch, 5);
        assert!((match l.lr {
            LrSchedule::Constant(e) => e,
            _ => 0.0,
        } - 0.005)
            .abs()
            < 1e-9);
    }

    #[test]
    fn sizes_wraps_dataset_dims() {
        let c = TrainConfig::paper_preset("cifar");
        assert_eq!(c.sizes(3072, 10), vec![3072, 4000, 1000, 4000, 10]);
    }

    #[test]
    fn set_overrides() {
        let mut c = TrainConfig::default();
        c.set("epochs", "7").unwrap();
        c.set("hidden", "32x16").unwrap();
        c.set("activation", "relu").unwrap();
        c.set("importance", "on").unwrap();
        c.set("importance_pct", "10").unwrap();
        c.set("zeta", "0.25").unwrap();
        c.set("kernel_threads", "4").unwrap();
        assert_eq!(c.kernel_threads, 4);
        assert!(c.set("kernel_threads", "many").is_err());
        assert_eq!(c.epochs, 7);
        assert_eq!(c.hidden, vec![32, 16]);
        assert_eq!(c.activation, Activation::Relu);
        assert_eq!(c.importance.unwrap().percentile, 10.0);
        assert_eq!(c.evolution.unwrap().zeta, 0.25);
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("epochs", "x").is_err());
    }

    #[test]
    fn alpha_override_keeps_activation_kind() {
        let mut c = TrainConfig::default();
        c.set("alpha", "0.9").unwrap();
        assert_eq!(c.activation, Activation::AllRelu { alpha: 0.9 });
        c.set("activation", "relu").unwrap();
        c.set("alpha", "0.5").unwrap();
        assert_eq!(c.activation, Activation::Relu); // relu has no alpha
    }

    #[test]
    fn apply_file_parses_comments_and_blanks() {
        let mut c = TrainConfig::default();
        c.apply_file("# comment\n\nepochs = 3\nbatch=64 # inline\n")
            .unwrap();
        assert_eq!(c.epochs, 3);
        assert_eq!(c.batch, 64);
        assert!(c.apply_file("no_equals_here").is_err());
    }

    #[test]
    fn dataset_specs_paper_match_table1() {
        let d = DatasetSpec::paper("leukemia");
        assert_eq!((d.n_features, d.n_classes, d.n_train, d.n_test), (54675, 18, 1397, 699));
        let c = DatasetSpec::paper("cifar");
        assert_eq!(c.n_features, 3072);
        let e = DatasetSpec::paper("extreme");
        assert_eq!(e.n_features, 65536);
        assert_eq!(e.n_train + e.n_test, 10_000);
    }

    #[test]
    fn small_specs_are_smaller() {
        for name in ["leukemia", "higgs", "madelon", "fashion", "cifar", "extreme"] {
            let s = DatasetSpec::small(name);
            let p = DatasetSpec::paper(name);
            assert!(s.n_train <= p.n_train, "{name}");
            assert_eq!(s.n_classes, p.n_classes, "{name}");
        }
    }
}
