//! Experiment configuration: dataset specs, training hyperparameters,
//! the paper's Table 7 presets, and a small `key=value` config parser so
//! experiments are reproducible from files or CLI overrides.

use crate::error::{Result, TsnnError};
use crate::importance::ImportanceConfig;
use crate::nn::{Activation, LrSchedule, MomentumSgd};
use crate::set::EvolutionConfig;
use crate::sparse::WeightInit;

/// What dataset to generate (see `data::datasets`).
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    /// Human-readable name (used in reports).
    pub name: String,
    /// Generator id: leukemia | higgs | madelon | fashion | cifar |
    /// extreme | recommender.
    pub generator: String,
    /// Feature dimensionality.
    pub n_features: usize,
    /// Class count.
    pub n_classes: usize,
    /// Train samples.
    pub n_train: usize,
    /// Test samples.
    pub n_test: usize,
}

impl DatasetSpec {
    /// Paper-scale spec (Table 1 shapes).
    pub fn paper(name: &str) -> DatasetSpec {
        match name {
            "leukemia" => DatasetSpec {
                name: name.into(),
                generator: "leukemia".into(),
                n_features: 54675,
                n_classes: 18,
                n_train: 1397,
                n_test: 699,
            },
            "higgs" => DatasetSpec {
                name: name.into(),
                generator: "higgs".into(),
                n_features: 28,
                n_classes: 2,
                n_train: 105_000,
                n_test: 50_000,
            },
            "madelon" => DatasetSpec {
                name: name.into(),
                generator: "madelon".into(),
                n_features: 500,
                n_classes: 2,
                n_train: 2000,
                n_test: 600,
            },
            "fashion" => DatasetSpec {
                name: name.into(),
                generator: "fashion".into(),
                n_features: 784,
                n_classes: 10,
                n_train: 60_000,
                n_test: 10_000,
            },
            "cifar" => DatasetSpec {
                name: name.into(),
                generator: "cifar".into(),
                n_features: 3072,
                n_classes: 10,
                n_train: 50_000,
                n_test: 10_000,
            },
            "extreme" => DatasetSpec {
                name: name.into(),
                generator: "extreme".into(),
                n_features: 65_536,
                n_classes: 2,
                n_train: 7000,
                n_test: 3000,
            },
            // out-of-core workload (DESIGN.md §14.8): the very wide,
            // count-sparse input is what blows the first layer's
            // parameter count past RAM
            "recommender" => DatasetSpec {
                name: name.into(),
                generator: "recommender".into(),
                n_features: 262_144,
                n_classes: 8,
                n_train: 20_000,
                n_test: 4000,
            },
            other => panic!("unknown paper dataset '{other}'"),
        }
    }

    /// Scaled-down spec for tests and default bench runs (same shape
    /// family, 1-core-friendly sample counts).
    pub fn small(name: &str) -> DatasetSpec {
        match name {
            "leukemia" => DatasetSpec {
                name: name.into(),
                generator: "leukemia".into(),
                n_features: 2048,
                n_classes: 18,
                n_train: 700,
                n_test: 350,
            },
            "higgs" => DatasetSpec {
                name: name.into(),
                generator: "higgs".into(),
                n_features: 28,
                n_classes: 2,
                n_train: 4000,
                n_test: 2000,
            },
            "madelon" => DatasetSpec {
                name: name.into(),
                generator: "madelon".into(),
                n_features: 500,
                n_classes: 2,
                n_train: 2000,
                n_test: 600,
            },
            "fashion" => DatasetSpec {
                name: name.into(),
                generator: "fashion".into(),
                n_features: 784,
                n_classes: 10,
                n_train: 4000,
                n_test: 1000,
            },
            "cifar" => DatasetSpec {
                name: name.into(),
                generator: "cifar".into(),
                n_features: 3072,
                n_classes: 10,
                n_train: 3000,
                n_test: 1000,
            },
            "extreme" => DatasetSpec {
                name: name.into(),
                generator: "extreme".into(),
                n_features: 4096,
                n_classes: 2,
                n_train: 1400,
                n_test: 600,
            },
            "recommender" => DatasetSpec {
                name: name.into(),
                generator: "recommender".into(),
                n_features: 2048,
                n_classes: 8,
                n_train: 1200,
                n_test: 400,
            },
            other => panic!("unknown small dataset '{other}'"),
        }
    }
}

/// Full training configuration (architecture + optimisation + the three
/// paper contributions' switches).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Hidden layer sizes (input/output come from the dataset).
    pub hidden: Vec<usize>,
    /// SET sparsity knob ε.
    pub epsilon: f64,
    /// Hidden activation.
    pub activation: Activation,
    /// Weight initialisation scheme.
    pub init: WeightInit,
    /// LR schedule.
    pub lr: LrSchedule,
    /// Optimiser hyperparameters.
    pub optimizer: MomentumSgd,
    /// Mini-batch size.
    pub batch: usize,
    /// Training epochs.
    pub epochs: usize,
    /// Dropout rate on hidden activations (0 disables).
    pub dropout: f32,
    /// SET evolution (None = static sparsity).
    pub evolution: Option<EvolutionConfig>,
    /// Importance pruning (None = off).
    pub importance: Option<ImportanceConfig>,
    /// RNG seed.
    pub seed: u64,
    /// Evaluate on test set every `eval_every` epochs.
    pub eval_every: usize,
    /// Worker budget for the sharded sparse kernels (DESIGN.md §4):
    /// `0` = one per available core, `1` = always sequential, `n` = at
    /// most n threads per kernel call. Results are identical at any
    /// setting; this only trades wall-clock for cores.
    pub kernel_threads: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            hidden: vec![256, 256],
            epsilon: 10.0,
            activation: Activation::AllRelu { alpha: 0.6 },
            init: WeightInit::HeUniform,
            lr: LrSchedule::Constant(0.01),
            optimizer: MomentumSgd::default(),
            batch: 128,
            epochs: 50,
            dropout: 0.3,
            evolution: Some(EvolutionConfig::default()),
            importance: None,
            seed: 42,
            eval_every: 1,
            kernel_threads: 0,
        }
    }
}

impl TrainConfig {
    /// Table 7 hyperparameters for a paper dataset (ε, η, batch, init, α),
    /// with the Table 2 architectures.
    pub fn paper_preset(dataset: &str) -> TrainConfig {
        let d = TrainConfig::default();
        match dataset {
            "leukemia" => TrainConfig {
                hidden: vec![27_500, 27_500],
                epsilon: 10.0,
                activation: Activation::AllRelu { alpha: 0.75 },
                init: WeightInit::Normal(0.05),
                lr: LrSchedule::Constant(0.005),
                batch: 5,
                ..d
            },
            "higgs" => TrainConfig {
                hidden: vec![1000, 1000, 1000],
                epsilon: 10.0,
                activation: Activation::AllRelu { alpha: 0.05 },
                init: WeightInit::Xavier,
                lr: LrSchedule::Constant(0.01),
                batch: 128,
                ..d
            },
            "madelon" => TrainConfig {
                hidden: vec![400, 100, 400],
                epsilon: 10.0,
                activation: Activation::AllRelu { alpha: 0.5 },
                init: WeightInit::Normal(0.05),
                lr: LrSchedule::Constant(0.01),
                batch: 32,
                ..d
            },
            "fashion" => TrainConfig {
                hidden: vec![1000, 1000, 1000],
                epsilon: 20.0,
                activation: Activation::AllRelu { alpha: 0.6 },
                init: WeightInit::HeUniform,
                lr: LrSchedule::Constant(0.01),
                batch: 128,
                ..d
            },
            "cifar" => TrainConfig {
                hidden: vec![4000, 1000, 4000],
                epsilon: 20.0,
                activation: Activation::AllRelu { alpha: 0.75 },
                init: WeightInit::HeUniform,
                lr: LrSchedule::Constant(0.01),
                batch: 128,
                ..d
            },
            _ => d,
        }
    }

    /// Scaled-down preset matching `DatasetSpec::small` (shorter, thinner).
    pub fn small_preset(dataset: &str) -> TrainConfig {
        let mut cfg = TrainConfig::paper_preset(dataset);
        cfg.epochs = 30;
        cfg.hidden = match dataset {
            "leukemia" => vec![512, 512],
            "higgs" => vec![256, 256, 256],
            "madelon" => vec![400, 100, 400],
            "fashion" => vec![256, 256, 256],
            "cifar" => vec![512, 256, 512],
            _ => cfg.hidden,
        };
        if let Some(imp) = cfg.importance.as_mut() {
            imp.start_epoch = 10;
            imp.period = 5;
        }
        cfg
    }

    /// Full layer-size vector for a dataset.
    pub fn sizes(&self, n_features: usize, n_classes: usize) -> Vec<usize> {
        let mut s = Vec::with_capacity(self.hidden.len() + 2);
        s.push(n_features);
        s.extend_from_slice(&self.hidden);
        s.push(n_classes);
        s
    }

    /// Apply a `key=value` override (CLI/config-file syntax). Supported
    /// keys: epochs, batch, epsilon, lr, seed, dropout, alpha, activation,
    /// init, hidden (e.g. `hidden=256x256x128`), zeta, importance
    /// (on/off), importance_start, importance_period, importance_pct,
    /// eval_every, momentum, weight_decay, kernel_threads.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let bad = |k: &str, v: &str| TsnnError::Config(format!("bad value '{v}' for '{k}'"));
        match key {
            "epochs" => self.epochs = value.parse().map_err(|_| bad(key, value))?,
            "batch" => self.batch = value.parse().map_err(|_| bad(key, value))?,
            "epsilon" => self.epsilon = value.parse().map_err(|_| bad(key, value))?,
            "seed" => self.seed = value.parse().map_err(|_| bad(key, value))?,
            "dropout" => self.dropout = value.parse().map_err(|_| bad(key, value))?,
            "eval_every" => self.eval_every = value.parse().map_err(|_| bad(key, value))?,
            "kernel_threads" => {
                self.kernel_threads = value.parse().map_err(|_| bad(key, value))?
            }
            "lr" => self.lr = parse_lr(value).ok_or_else(|| bad(key, value))?,
            "momentum" => {
                self.optimizer.momentum = value.parse().map_err(|_| bad(key, value))?
            }
            "weight_decay" => {
                self.optimizer.weight_decay = value.parse().map_err(|_| bad(key, value))?
            }
            "activation" => {
                self.activation = Activation::parse(value).ok_or_else(|| bad(key, value))?
            }
            "alpha" => {
                let a: f32 = value.parse().map_err(|_| bad(key, value))?;
                self.activation = match self.activation {
                    Activation::AllRelu { .. } => Activation::AllRelu { alpha: a },
                    Activation::LeakyRelu { .. } => Activation::LeakyRelu { alpha: a },
                    other => other,
                };
            }
            "init" => self.init = WeightInit::parse(value).ok_or_else(|| bad(key, value))?,
            "hidden" => {
                if value == "none" {
                    self.hidden = Vec::new();
                } else {
                    let sizes: Option<Vec<usize>> =
                        value.split('x').map(|p| p.parse().ok()).collect();
                    self.hidden = sizes.ok_or_else(|| bad(key, value))?;
                }
            }
            "zeta" => {
                let z: f64 = value.parse().map_err(|_| bad(key, value))?;
                self.evolution.get_or_insert_with(Default::default).zeta = z;
            }
            "evolution_init" => {
                self.evolution.get_or_insert_with(Default::default).init =
                    WeightInit::parse(value).ok_or_else(|| bad(key, value))?
            }
            "evolution" => match value {
                "on" => {
                    self.evolution.get_or_insert_with(Default::default);
                }
                "off" => self.evolution = None,
                _ => return Err(bad(key, value)),
            },
            "importance" => match value {
                "on" => {
                    self.importance.get_or_insert_with(Default::default);
                }
                "off" => self.importance = None,
                _ => return Err(bad(key, value)),
            },
            "importance_start" => {
                self.importance
                    .get_or_insert_with(Default::default)
                    .start_epoch = value.parse().map_err(|_| bad(key, value))?
            }
            "importance_period" => {
                self.importance.get_or_insert_with(Default::default).period =
                    value.parse().map_err(|_| bad(key, value))?
            }
            "importance_pct" => {
                self.importance
                    .get_or_insert_with(Default::default)
                    .percentile = value.parse().map_err(|_| bad(key, value))?
            }
            "importance_min" => {
                self.importance
                    .get_or_insert_with(Default::default)
                    .min_connections = value.parse().map_err(|_| bad(key, value))?
            }
            other => {
                return Err(TsnnError::Config(format!("unknown config key '{other}'")));
            }
        }
        Ok(())
    }

    /// Dump every field as `key=value` lines that [`apply_file`] parses
    /// back into an identical config. Floats print via Rust's
    /// shortest-roundtrip `Display`, so dump → parse is bit-exact; the
    /// multi-process coordinator ships worker configs this way.
    ///
    /// [`apply_file`]: TrainConfig::apply_file
    pub fn dump_kv(&self) -> String {
        let mut out = String::new();
        let mut kv = |k: &str, v: String| {
            out.push_str(k);
            out.push('=');
            out.push_str(&v);
            out.push('\n');
        };
        let hidden = if self.hidden.is_empty() {
            "none".into()
        } else {
            self.hidden
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>()
                .join("x")
        };
        kv("hidden", hidden);
        kv("epsilon", self.epsilon.to_string());
        kv(
            "activation",
            crate::model::checkpoint::act_name(&self.activation),
        );
        kv("init", init_kv(&self.init));
        kv("lr", lr_kv(&self.lr));
        kv("momentum", self.optimizer.momentum.to_string());
        kv("weight_decay", self.optimizer.weight_decay.to_string());
        kv("batch", self.batch.to_string());
        kv("epochs", self.epochs.to_string());
        kv("dropout", self.dropout.to_string());
        kv("seed", self.seed.to_string());
        kv("eval_every", self.eval_every.to_string());
        kv("kernel_threads", self.kernel_threads.to_string());
        match &self.evolution {
            None => kv("evolution", "off".into()),
            Some(e) => {
                kv("evolution", "on".into());
                kv("zeta", e.zeta.to_string());
                kv("evolution_init", init_kv(&e.init));
            }
        }
        match &self.importance {
            None => kv("importance", "off".into()),
            Some(i) => {
                kv("importance", "on".into());
                kv("importance_start", i.start_epoch.to_string());
                kv("importance_period", i.period.to_string());
                kv("importance_pct", i.percentile.to_string());
                kv("importance_min", i.min_connections.to_string());
            }
        }
        out
    }

    /// Parse a config file: `key = value` lines, `#` comments.
    pub fn apply_file(&mut self, text: &str) -> Result<()> {
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                TsnnError::Config(format!("line {}: expected key=value", lineno + 1))
            })?;
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }
}

/// Parse an LR schedule: a plain float (constant), `warmup:BASE:SCALE:EPOCHS`,
/// or `hotstart:HOT:BASE:EPOCHS`.
fn parse_lr(value: &str) -> Option<LrSchedule> {
    fn three(rest: &str) -> Option<(f32, f32, usize)> {
        let mut it = rest.split(':');
        let a = it.next()?.parse().ok()?;
        let b = it.next()?.parse().ok()?;
        let c = it.next()?.parse().ok()?;
        if it.next().is_some() {
            return None;
        }
        Some((a, b, c))
    }
    if let Some(rest) = value.strip_prefix("warmup:") {
        let (base, scale, warmup_epochs) = three(rest)?;
        return Some(LrSchedule::Warmup {
            base,
            scale,
            warmup_epochs,
        });
    }
    if let Some(rest) = value.strip_prefix("hotstart:") {
        let (hot, base, hot_epochs) = three(rest)?;
        return Some(LrSchedule::HotStart {
            hot,
            base,
            hot_epochs,
        });
    }
    value.parse().ok().map(LrSchedule::Constant)
}

fn lr_kv(lr: &LrSchedule) -> String {
    match *lr {
        LrSchedule::Constant(eta) => eta.to_string(),
        LrSchedule::Warmup {
            base,
            scale,
            warmup_epochs,
        } => format!("warmup:{base}:{scale}:{warmup_epochs}"),
        LrSchedule::HotStart {
            hot,
            base,
            hot_epochs,
        } => format!("hotstart:{hot}:{base}:{hot_epochs}"),
    }
}

fn init_kv(init: &WeightInit) -> String {
    match *init {
        WeightInit::Normal(std) => format!("normal:{std}"),
        WeightInit::Xavier => "xavier".into(),
        WeightInit::HeUniform => "he_uniform".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_presets_match_table7() {
        let c = TrainConfig::paper_preset("fashion");
        assert_eq!(c.epsilon, 20.0);
        assert_eq!(c.batch, 128);
        assert_eq!(c.activation, Activation::AllRelu { alpha: 0.6 });
        assert_eq!(c.init, WeightInit::HeUniform);
        let h = TrainConfig::paper_preset("higgs");
        assert_eq!(h.activation, Activation::AllRelu { alpha: 0.05 });
        assert_eq!(h.init, WeightInit::Xavier);
        let m = TrainConfig::paper_preset("madelon");
        assert_eq!(m.hidden, vec![400, 100, 400]);
        assert_eq!(m.batch, 32);
        let l = TrainConfig::paper_preset("leukemia");
        assert_eq!(l.batch, 5);
        assert!((match l.lr {
            LrSchedule::Constant(e) => e,
            _ => 0.0,
        } - 0.005)
            .abs()
            < 1e-9);
    }

    #[test]
    fn sizes_wraps_dataset_dims() {
        let c = TrainConfig::paper_preset("cifar");
        assert_eq!(c.sizes(3072, 10), vec![3072, 4000, 1000, 4000, 10]);
    }

    #[test]
    fn set_overrides() {
        let mut c = TrainConfig::default();
        c.set("epochs", "7").unwrap();
        c.set("hidden", "32x16").unwrap();
        c.set("activation", "relu").unwrap();
        c.set("importance", "on").unwrap();
        c.set("importance_pct", "10").unwrap();
        c.set("zeta", "0.25").unwrap();
        c.set("kernel_threads", "4").unwrap();
        assert_eq!(c.kernel_threads, 4);
        assert!(c.set("kernel_threads", "many").is_err());
        assert_eq!(c.epochs, 7);
        assert_eq!(c.hidden, vec![32, 16]);
        assert_eq!(c.activation, Activation::Relu);
        assert_eq!(c.importance.unwrap().percentile, 10.0);
        assert_eq!(c.evolution.unwrap().zeta, 0.25);
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("epochs", "x").is_err());
    }

    #[test]
    fn alpha_override_keeps_activation_kind() {
        let mut c = TrainConfig::default();
        c.set("alpha", "0.9").unwrap();
        assert_eq!(c.activation, Activation::AllRelu { alpha: 0.9 });
        c.set("activation", "relu").unwrap();
        c.set("alpha", "0.5").unwrap();
        assert_eq!(c.activation, Activation::Relu); // relu has no alpha
    }

    #[test]
    fn dump_kv_roundtrips_exactly() {
        let mut c = TrainConfig::paper_preset("madelon");
        c.lr = LrSchedule::HotStart {
            hot: 0.02,
            base: 0.01,
            hot_epochs: 3,
        };
        c.importance = Some(ImportanceConfig {
            start_epoch: 11,
            period: 7,
            percentile: 2.5,
            min_connections: 3,
        });
        let dump = c.dump_kv();
        let mut parsed = TrainConfig::default();
        parsed.apply_file(&dump).unwrap();
        assert_eq!(parsed.dump_kv(), dump);
        assert_eq!(parsed.hidden, c.hidden);
        assert_eq!(parsed.init, c.init);
        assert_eq!(parsed.activation, c.activation);
        assert_eq!(parsed.importance.unwrap().min_connections, 3);

        // warmup schedule + disabled evolution + empty hidden
        let mut c2 = TrainConfig::default();
        c2.lr = LrSchedule::Warmup {
            base: 0.01,
            scale: 3.0,
            warmup_epochs: 5,
        };
        c2.evolution = None;
        c2.hidden = Vec::new();
        let dump2 = c2.dump_kv();
        let mut parsed2 = TrainConfig::default();
        parsed2.apply_file(&dump2).unwrap();
        assert_eq!(parsed2.dump_kv(), dump2);
        assert!(parsed2.hidden.is_empty());
        assert!(parsed2.evolution.is_none());
    }

    #[test]
    fn apply_file_parses_comments_and_blanks() {
        let mut c = TrainConfig::default();
        c.apply_file("# comment\n\nepochs = 3\nbatch=64 # inline\n")
            .unwrap();
        assert_eq!(c.epochs, 3);
        assert_eq!(c.batch, 64);
        assert!(c.apply_file("no_equals_here").is_err());
    }

    #[test]
    fn dataset_specs_paper_match_table1() {
        let d = DatasetSpec::paper("leukemia");
        assert_eq!((d.n_features, d.n_classes, d.n_train, d.n_test), (54675, 18, 1397, 699));
        let c = DatasetSpec::paper("cifar");
        assert_eq!(c.n_features, 3072);
        let e = DatasetSpec::paper("extreme");
        assert_eq!(e.n_features, 65536);
        assert_eq!(e.n_train + e.n_test, 10_000);
    }

    #[test]
    fn small_specs_are_smaller() {
        for name in ["leukemia", "higgs", "madelon", "fashion", "cifar", "extreme"] {
            let s = DatasetSpec::small(name);
            let p = DatasetSpec::paper(name);
            assert!(s.n_train <= p.n_train, "{name}");
            assert_eq!(s.n_classes, p.n_classes, "{name}");
        }
    }
}
