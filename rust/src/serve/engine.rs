//! Request-batching serving front end (DESIGN.md §10.2).
//!
//! One batcher thread owns the model's forward buffers and worker pool;
//! clients submit single requests into a **bounded** queue:
//!
//! * Backpressure is fail-fast: [`ServeEngine::submit`] on a full queue
//!   returns [`SubmitError::QueueFull`] immediately — it never blocks
//!   the caller on the pool, and sheds load instead of growing an
//!   unbounded backlog.
//! * Batch formation is adaptive: the batcher takes up to
//!   [`ServeConfig::max_batch`] requests, waiting at most
//!   [`ServeConfig::max_wait`] past the **oldest** queued request's
//!   arrival before running a partial batch — single requests pay at
//!   most one deadline, bursts fill batches immediately.
//! * Shutdown drains: queued and in-flight requests complete before the
//!   batcher exits; only new submissions are refused.
//!
//! Batch formation cannot change results — per-sample accumulation is
//! batch-composition-invariant (serving_parity pins this bitwise).

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::TsnnError;
use crate::serve::layout::{ServeModel, ServeWorkspace};
use crate::serve::metrics::{LatencyRecorder, LatencySummary};

/// Front-end tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Largest batch one forward runs (≥ 1).
    pub max_batch: usize,
    /// Submission-queue bound; a full queue fails fast (≥ 1).
    pub max_queue: usize,
    /// Longest a queued request waits for co-batched traffic.
    pub max_wait: Duration,
    /// Kernel thread budget of the batcher's workspace (`0` = all
    /// cores); the batcher installs one persistent pool for its
    /// lifetime.
    pub kernel_threads: usize,
    /// Latency-window size of the engine's recorder.
    pub latency_window: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 32,
            max_queue: 1024,
            max_wait: Duration::from_millis(2),
            kernel_threads: 0,
            latency_window: 4096,
        }
    }
}

/// Why a submission was refused (fail-fast, never blocking).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is at capacity — shed or retry later.
    QueueFull,
    /// The engine is shutting down (or already shut down).
    Shutdown,
    /// Feature vector length does not match the model input width.
    BadShape {
        /// Model input width.
        expected: usize,
        /// Submitted feature count.
        got: usize,
    },
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "submission queue full"),
            SubmitError::Shutdown => write!(f, "serving engine is shut down"),
            SubmitError::BadShape { expected, got } => {
                write!(f, "expected {expected} features, got {got}")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

impl From<SubmitError> for TsnnError {
    fn from(e: SubmitError) -> TsnnError {
        TsnnError::Serve(e.to_string())
    }
}

/// Completion handle for one submitted request.
pub struct Ticket {
    rx: Receiver<Vec<f32>>,
}

impl Ticket {
    /// Block until the logits arrive (errors only if the engine died
    /// without draining — a bug, not a protocol state).
    pub fn wait(self) -> crate::error::Result<Vec<f32>> {
        self.rx
            .recv()
            .map_err(|_| TsnnError::Serve("engine dropped an in-flight request".into()))
    }
}

/// One queued request: features in, a one-shot completion channel out.
struct QueuedRequest {
    features: Vec<f32>,
    enqueued: Instant,
    tx: SyncSender<Vec<f32>>,
}

/// Queue state guarded by the mutex half of the condvar pair.
struct QueueState {
    items: VecDeque<QueuedRequest>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    cv: Condvar,
    recorder: Mutex<LatencyRecorder>,
    completed: AtomicU64,
    rejected: AtomicU64,
    batches: AtomicU64,
}

/// Throughput counters (monotonic since construction/reset).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Requests completed (results delivered).
    pub completed: u64,
    /// Submissions refused by backpressure.
    pub rejected: u64,
    /// Forward batches run.
    pub batches: u64,
}

/// The serving engine: a loaded [`ServeModel`] behind a bounded queue
/// and one batcher thread. Dropping the engine shuts it down cleanly
/// (draining the queue first).
pub struct ServeEngine {
    shared: Arc<Shared>,
    model: Arc<ServeModel>,
    cfg: ServeConfig,
    batcher: Option<JoinHandle<()>>,
}

impl ServeEngine {
    /// Start serving `model` under `cfg` (spawns the batcher thread,
    /// which owns the forward buffers and the persistent worker pool).
    pub fn new(model: ServeModel, cfg: ServeConfig) -> ServeEngine {
        let cfg = ServeConfig {
            max_batch: cfg.max_batch.max(1),
            max_queue: cfg.max_queue.max(1),
            ..cfg
        };
        let model = Arc::new(model);
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                items: VecDeque::with_capacity(cfg.max_queue),
                shutdown: false,
            }),
            cv: Condvar::new(),
            recorder: Mutex::new(LatencyRecorder::with_capacity(cfg.latency_window)),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
        });
        let batcher = {
            let shared = Arc::clone(&shared);
            let model = Arc::clone(&model);
            std::thread::spawn(move || batcher_loop(&shared, &model, cfg))
        };
        ServeEngine {
            shared,
            model,
            cfg,
            batcher: Some(batcher),
        }
    }

    /// Submit one request. Fail-fast: a full queue or a shut-down
    /// engine returns immediately — the caller is never parked on the
    /// batcher or its pool.
    pub fn submit(&self, features: Vec<f32>) -> Result<Ticket, SubmitError> {
        let expected = self.model.n_features();
        if features.len() != expected {
            return Err(SubmitError::BadShape {
                expected,
                got: features.len(),
            });
        }
        let (tx, rx) = sync_channel(1);
        {
            let mut q = self.shared.state.lock().unwrap();
            if q.shutdown {
                return Err(SubmitError::Shutdown);
            }
            if q.items.len() >= self.cfg.max_queue {
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::QueueFull);
            }
            q.items.push_back(QueuedRequest {
                features,
                enqueued: Instant::now(),
                tx,
            });
        }
        self.shared.cv.notify_one();
        Ok(Ticket { rx })
    }

    /// Submit and block for the result (convenience wrapper).
    pub fn infer(&self, features: Vec<f32>) -> crate::error::Result<Vec<f32>> {
        let ticket = self.submit(features).map_err(TsnnError::from)?;
        ticket.wait()
    }

    /// The served model (formats, sizes — assertable).
    pub fn model(&self) -> &ServeModel {
        &self.model
    }

    /// The active configuration (bounds clamped to ≥ 1).
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Throughput counters.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            completed: self.shared.completed.load(Ordering::Relaxed),
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            batches: self.shared.batches.load(Ordering::Relaxed),
        }
    }

    /// Latency digest (enqueue → result delivery, per request).
    pub fn latency(&self) -> LatencySummary {
        self.shared.recorder.lock().unwrap().summary()
    }

    /// Zero the latency window and throughput counters (QPS-sweep steps
    /// measure in isolation).
    pub fn reset_metrics(&self) {
        self.shared.recorder.lock().unwrap().clear();
        self.shared.completed.store(0, Ordering::Relaxed);
        self.shared.rejected.store(0, Ordering::Relaxed);
        self.shared.batches.store(0, Ordering::Relaxed);
    }

    /// Stop accepting submissions, drain every queued request, join the
    /// batcher. Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        {
            let mut q = self.shared.state.lock().unwrap();
            if q.shutdown && self.batcher.is_none() {
                return;
            }
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The batcher: wait → form an adaptive batch → forward → deliver.
/// Reuses one workspace, one staging buffer and one batch vector, so
/// the steady-state per-batch work allocates only the per-request
/// result vectors.
fn batcher_loop(shared: &Shared, model: &ServeModel, cfg: ServeConfig) {
    let mut ws = ServeWorkspace::with_threads(cfg.kernel_threads);
    ws.ensure_pool();
    let n_feat = model.n_features();
    let n_classes = model.n_classes();
    let mut batch: Vec<QueuedRequest> = Vec::with_capacity(cfg.max_batch);
    let mut xbuf: Vec<f32> = Vec::with_capacity(cfg.max_batch * n_feat);
    loop {
        {
            let mut q = shared.state.lock().unwrap();
            // wait for the first request (or a drained shutdown)
            loop {
                if !q.items.is_empty() {
                    break;
                }
                if q.shutdown {
                    return;
                }
                q = shared.cv.wait(q).unwrap();
            }
            // adaptive fill: give co-batched traffic until the oldest
            // request's deadline, unless the batch is already full or
            // the engine is draining
            let deadline = q.items.front().unwrap().enqueued + cfg.max_wait;
            while q.items.len() < cfg.max_batch && !q.shutdown {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = shared.cv.wait_timeout(q, deadline - now).unwrap();
                q = guard;
            }
            let n = q.items.len().min(cfg.max_batch);
            batch.extend(q.items.drain(..n));
        }
        // forward + deliver outside the lock: submissions keep flowing
        let bsz = batch.len();
        xbuf.clear();
        for r in &batch {
            xbuf.extend_from_slice(&r.features);
        }
        let logits = model.forward(&xbuf, bsz, &mut ws);
        let done = Instant::now();
        shared.batches.fetch_add(1, Ordering::Relaxed);
        shared.completed.fetch_add(bsz as u64, Ordering::Relaxed);
        {
            let mut rec = shared.recorder.lock().unwrap();
            for r in &batch {
                rec.record(done.duration_since(r.enqueued).as_nanos() as u64);
            }
        }
        for (b, r) in batch.drain(..).enumerate() {
            // a dropped Ticket is a fire-and-forget client; ignore it
            let _ = r.tx.send(logits[b * n_classes..(b + 1) * n_classes].to_vec());
        }
    }
}
