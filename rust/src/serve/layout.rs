//! Inference-specialized model layout (DESIGN.md §10.1).
//!
//! A served model keeps **weights only** — velocity/optimizer state is
//! dropped at load — and selects a storage format per layer by measured
//! density crossover: CSR below [`LayoutOptions::dense_crossover`],
//! dense-fallback at or above it (Nerva, arXiv 2407.17437, shows the
//! crossover is real and layout-dependent; `benches/perf_serving.rs`
//! re-measures it per host into `BENCH_5.json`). The selection is
//! recorded on every layer ([`ServeLayer::format`]) so tests can assert
//! it rather than assume it.
//!
//! Parity: both formats reproduce the training forward
//! ([`SparseLayer::forward_into`](crate::model::SparseLayer::forward_into))
//! **bit-exactly**. The CSR path is the training kernel itself; the
//! dense path streams the densified rows in the same `i`-then-`j`
//! accumulation order with the same batch blocking and block-level
//! zero-skip, so stored entries contribute in the training kernel's
//! exact order and absent entries only add `±0.0` terms — a no-op for
//! every accumulator that is not `-0.0`, which bias-seeded accumulators
//! cannot become under round-to-nearest (the same argument the §4
//! sharded kernels rely on for shard-count invariance).

use std::path::Path;
use std::sync::Arc;

use crate::error::Result;
use crate::model::{checkpoint, SparseMlp};
use crate::nn::Activation;
use crate::sparse::ops::{self, Exec, ShardPtr};
use crate::sparse::simd::{self, Isa};
use crate::sparse::{CsrMatrix, WorkerPool};

/// Default density at or above which a layer is served dense. The
/// indirection-free dense row stream beats CSR well below 50% density
/// on every host measured so far; 0.25 is the conservative knee from
/// the `format_crossover` family of `benches/perf_serving.rs`.
pub const DENSE_CROSSOVER_DENSITY: f64 = 0.25;

/// Per-layer format-selection policy for [`ServeModel`] construction.
#[derive(Debug, Clone, Copy)]
pub struct LayoutOptions {
    /// Layers with `density >= dense_crossover` are densified; the rest
    /// stay CSR. `> 1.0` forces CSR everywhere, `0.0` forces dense.
    pub dense_crossover: f64,
}

impl Default for LayoutOptions {
    fn default() -> Self {
        LayoutOptions {
            dense_crossover: DENSE_CROSSOVER_DENSITY,
        }
    }
}

/// Storage format chosen for one served layer (recorded, assertable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerFormat {
    /// Truly-sparse CSR, served by the training kernel.
    Csr,
    /// Row-major dense fallback for dense-enough layers.
    Dense,
}

/// The weights of one served layer in their selected format.
#[derive(Debug, Clone)]
enum ServeWeights {
    Csr(CsrMatrix),
    Dense {
        n_in: usize,
        n_out: usize,
        /// Row-major `[n_in, n_out]`; absent entries are exactly `0.0`.
        values: Vec<f32>,
    },
}

/// One inference-ready layer: weights in the selected format, bias and
/// activation — no velocity, no optimizer state.
#[derive(Debug, Clone)]
pub struct ServeLayer {
    weights: ServeWeights,
    /// Per-output bias, broadcast into `pre` before the kernel (same
    /// fold as the training path).
    pub bias: Vec<f32>,
    /// Activation applied with the training path's 1-based layer index.
    pub activation: Activation,
    /// Density measured at selection time (decides [`ServeLayer::format`]).
    pub density: f64,
    nnz: usize,
}

impl ServeLayer {
    /// Build from a training layer, selecting the format by density.
    fn from_training(
        weights: &CsrMatrix,
        bias: &[f32],
        activation: Activation,
        opts: &LayoutOptions,
    ) -> ServeLayer {
        let density = weights.density();
        let nnz = weights.nnz();
        let weights = if density >= opts.dense_crossover && weights.n_rows * weights.n_cols > 0 {
            ServeWeights::Dense {
                n_in: weights.n_rows,
                n_out: weights.n_cols,
                values: weights.to_dense(),
            }
        } else {
            ServeWeights::Csr(weights.clone())
        };
        ServeLayer {
            weights,
            bias: bias.to_vec(),
            activation,
            density,
            nnz,
        }
    }

    /// The format selected for this layer.
    pub fn format(&self) -> LayerFormat {
        match self.weights {
            ServeWeights::Csr(_) => LayerFormat::Csr,
            ServeWeights::Dense { .. } => LayerFormat::Dense,
        }
    }

    /// Fan-in.
    pub fn n_in(&self) -> usize {
        match &self.weights {
            ServeWeights::Csr(w) => w.n_rows,
            ServeWeights::Dense { n_in, .. } => *n_in,
        }
    }

    /// Fan-out.
    pub fn n_out(&self) -> usize {
        match &self.weights {
            ServeWeights::Csr(w) => w.n_cols,
            ServeWeights::Dense { n_out, .. } => *n_out,
        }
    }

    /// Stored connections in the source topology (dense layers keep the
    /// logical count, not `n_in × n_out`).
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Bytes held by this layer's weight + bias storage.
    pub fn memory_bytes(&self) -> usize {
        let w = match &self.weights {
            ServeWeights::Csr(w) => w.memory_bytes(),
            ServeWeights::Dense { values, .. } => 4 * values.len(),
        };
        w + 4 * self.bias.len()
    }

    /// `pre = bias ⊕ x · W` — identical bias fold and accumulation order
    /// as [`SparseLayer::forward_into`](crate::model::SparseLayer::forward_into).
    pub fn forward_into(&self, x: &[f32], batch: usize, pre: &mut [f32], exec: Exec<'_>) {
        let n_out = self.n_out();
        for b in 0..batch {
            pre[b * n_out..(b + 1) * n_out].copy_from_slice(&self.bias);
        }
        match &self.weights {
            ServeWeights::Csr(w) => ops::spmm_forward_exec(x, batch, w, pre, exec),
            ServeWeights::Dense { n_in, n_out, values } => {
                dense_forward_exec(x, batch, *n_in, *n_out, values, pre, exec)
            }
        }
    }
}

/// Dense-fallback forward sharded over the batch dimension — the same
/// disjoint-row sharding as `spmm_forward_exec`, with the dense MAC
/// count `batch × n_in × n_out` as the crossover work metric, routed
/// through the context's dense microkernel ([`Exec::isa`], §11.2).
fn dense_forward_exec(
    x: &[f32],
    batch: usize,
    n_in: usize,
    n_out: usize,
    w: &[f32],
    out: &mut [f32],
    exec: Exec<'_>,
) {
    assert_eq!(x.len(), batch * n_in);
    assert_eq!(out.len(), batch * n_out);
    assert_eq!(w.len(), n_in * n_out);
    let table = simd::kernel_table(exec.isa());
    let work = batch.saturating_mul(n_in).saturating_mul(n_out);
    let shards = if exec.threads() <= 1 || batch <= 1 || work < exec.min_work() {
        1
    } else {
        exec.threads().min(batch)
    };
    if shards <= 1 {
        // SAFETY: lengths asserted above; kernel_table only hands out
        // tables whose ISA the host supports.
        return unsafe { (table.dense_forward)(x, batch, n_in, n_out, w, out) };
    }
    let rows_per = batch.div_ceil(shards);
    let out_ptr = ShardPtr(out.as_mut_ptr());
    exec.run(shards, |s| {
        let b0 = (s * rows_per).min(batch);
        let b1 = ((s + 1) * rows_per).min(batch);
        if b0 >= b1 {
            return;
        }
        // SAFETY: shard s writes only out rows [b0, b1) — contiguous,
        // pairwise-disjoint sample ranges of a buffer that outlives the
        // dispatch (the run() gather is the release point, §9.2).
        let oc = unsafe {
            std::slice::from_raw_parts_mut(out_ptr.0.add(b0 * n_out), (b1 - b0) * n_out)
        };
        // SAFETY: sub-slice lengths match the sub-batch; table as above.
        unsafe { (table.dense_forward)(&x[b0 * n_in..b1 * n_in], b1 - b0, n_in, n_out, w, oc) };
    });
}

/// Sequential scalar dense-row forward — now the §11 scalar table entry
/// [`simd::dense_forward_scalar`] (the body moved there so every ISA's
/// dense kernel lives beside its CSR siblings); kept as the local name
/// the parity tests exercise directly.
fn dense_forward(x: &[f32], batch: usize, n_in: usize, n_out: usize, w: &[f32], out: &mut [f32]) {
    simd::dense_forward_scalar(x, batch, n_in, n_out, w, out);
}

/// Reusable forward buffers for a served model: two ping-pong slabs
/// (activations in, pre-activations out) plus the kernel thread budget
/// and its persistent pool — the serving analogue of the training
/// [`Workspace`](crate::model::Workspace), without gradient state.
#[derive(Debug, Default)]
pub struct ServeWorkspace {
    act: Vec<f32>,
    pre: Vec<f32>,
    /// Worker budget for the sharded kernels (`0` = one per core,
    /// `1` = sequential) — a pure speed knob, results are bit-identical.
    pub kernel_threads: usize,
    /// Force a specific microkernel ISA for this workspace's forwards
    /// (`None` = process-detected). Unsupported requests clamp to
    /// scalar; results are bit-identical either way (§11.3) — this is
    /// the serving parity suite's per-ISA hook.
    pub force_isa: Option<Isa>,
    pool: Option<Arc<WorkerPool>>,
}

impl ServeWorkspace {
    /// Empty workspace with a kernel-shard budget; buffers are sized
    /// lazily per batch, the pool on the first forward.
    pub fn with_threads(kernel_threads: usize) -> Self {
        ServeWorkspace {
            kernel_threads,
            ..Default::default()
        }
    }

    /// Make the persistent pool match the current budget (same policy
    /// as the training workspace: one pool per resolved budget).
    pub fn ensure_pool(&mut self) {
        let t = ops::resolve_threads(self.kernel_threads);
        if t <= 1 {
            self.pool = None;
        } else if self.pool.as_ref().map(|p| p.threads()) != Some(t) {
            self.pool = Some(Arc::new(WorkerPool::new(t)));
        }
    }

    /// Shared handle to the persistent pool, if one is installed.
    pub fn pool(&self) -> Option<Arc<WorkerPool>> {
        self.pool.clone()
    }
}

/// A checkpoint loaded for serving: weights-only layers in their
/// selected formats. Construction is the only place formats are chosen;
/// they are immutable (and assertable) afterwards.
#[derive(Debug, Clone)]
pub struct ServeModel {
    /// Layer widths, `sizes[0]` = features, `sizes.last()` = classes.
    pub sizes: Vec<usize>,
    /// Inference-ready layers.
    pub layers: Vec<ServeLayer>,
}

impl ServeModel {
    /// Specialize a trained model for serving: clone weights/bias into
    /// per-layer selected formats, drop all optimizer state.
    pub fn from_mlp(mlp: &SparseMlp, opts: &LayoutOptions) -> ServeModel {
        let layers = mlp
            .layers
            .iter()
            .map(|l| ServeLayer::from_training(&l.weights, &l.bias, l.activation, opts))
            .collect();
        ServeModel {
            sizes: mlp.sizes.clone(),
            layers,
        }
    }

    /// Load a `TSNN` checkpoint straight into the serving layout.
    pub fn load(path: &Path, opts: &LayoutOptions) -> Result<ServeModel> {
        let mlp = checkpoint::load(path)?;
        Ok(ServeModel::from_mlp(&mlp, opts))
    }

    /// Input feature count.
    pub fn n_features(&self) -> usize {
        self.sizes[0]
    }

    /// Output class count.
    pub fn n_classes(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    /// Bytes held by all layers' weight + bias storage.
    pub fn memory_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.memory_bytes()).sum()
    }

    /// Batched forward: logits for `batch` row-major samples, borrowed
    /// from the workspace. Bit-exact vs the training forward path (and
    /// vs itself at any batch composition or pool size).
    pub fn forward<'w>(&self, x: &[f32], batch: usize, ws: &'w mut ServeWorkspace) -> &'w [f32] {
        assert_eq!(x.len(), batch * self.n_features());
        let widest = *self.sizes.iter().max().unwrap();
        if ws.act.len() < batch * widest {
            ws.act.resize(batch * widest, 0.0);
            ws.pre.resize(batch * widest, 0.0);
        }
        ws.ensure_pool();
        let pool = ws.pool();
        let mut exec = Exec::with(ws.kernel_threads, pool.as_deref());
        if let Some(isa) = ws.force_isa {
            exec = exec.with_isa(isa);
        }
        ws.act[..x.len()].copy_from_slice(x);
        for (l, layer) in self.layers.iter().enumerate() {
            let (n_in, n_out) = (layer.n_in(), layer.n_out());
            {
                let (act, pre) = (&ws.act, &mut ws.pre);
                layer.forward_into(&act[..batch * n_in], batch, &mut pre[..batch * n_out], exec);
            }
            {
                let (pre, act) = (&ws.pre, &mut ws.act);
                layer.activation.apply(&pre[..batch * n_out], &mut act[..batch * n_out], l + 1);
            }
        }
        &ws.act[..batch * self.n_classes()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{erdos_renyi, WeightInit};
    use crate::util::Rng;

    fn mlp(sizes: &[usize], eps: f64, seed: u64) -> SparseMlp {
        SparseMlp::new(sizes, eps, Activation::Relu, &WeightInit::HeUniform, &mut Rng::new(seed))
            .unwrap()
    }

    #[test]
    fn format_selection_follows_density_crossover() {
        let mut rng = Rng::new(3);
        let sparse = erdos_renyi(40, 30, 0.05, &mut rng, &WeightInit::Normal(0.1));
        let dense = erdos_renyi(40, 30, 0.6, &mut rng, &WeightInit::Normal(0.1));
        let opts = LayoutOptions::default();
        let b = vec![0.0f32; 30];
        let l_sparse = ServeLayer::from_training(&sparse, &b, Activation::Relu, &opts);
        let l_dense = ServeLayer::from_training(&dense, &b, Activation::Relu, &opts);
        assert_eq!(l_sparse.format(), LayerFormat::Csr);
        assert_eq!(l_dense.format(), LayerFormat::Dense);
        // the knob is honored in both directions
        let force_csr = LayoutOptions { dense_crossover: 2.0 };
        let force_dense = LayoutOptions { dense_crossover: 0.0 };
        assert_eq!(
            ServeLayer::from_training(&dense, &b, Activation::Relu, &force_csr).format(),
            LayerFormat::Csr
        );
        assert_eq!(
            ServeLayer::from_training(&sparse, &b, Activation::Relu, &force_dense).format(),
            LayerFormat::Dense
        );
    }

    #[test]
    fn empty_layer_stays_csr_even_when_forced_dense() {
        // density 0.0 of a 0-col layer must not densify a degenerate shape
        let w = CsrMatrix::empty(5, 0);
        let opts = LayoutOptions { dense_crossover: 0.0 };
        let l = ServeLayer::from_training(&w, &[], Activation::Linear, &opts);
        assert_eq!(l.format(), LayerFormat::Csr);
    }

    #[test]
    fn serving_layout_drops_optimizer_state() {
        let m = mlp(&[64, 128, 10], 8.0, 7);
        let s = ServeModel::from_mlp(&m, &LayoutOptions::default());
        // velocity + bias_velocity are gone: serving memory is strictly
        // below the training layout for a sparse model
        assert!(s.memory_bytes() < m.memory_bytes());
        assert_eq!(s.sizes, m.sizes);
        assert_eq!(s.n_features(), 64);
        assert_eq!(s.n_classes(), 10);
    }

    #[test]
    fn dense_forward_matches_csr_kernel_bitwise() {
        let mut rng = Rng::new(11);
        let cases = [(17usize, 13usize, 0.5f64), (8, 8, 1.0), (33, 5, 0.3), (3, 64, 0.7)];
        for &(n_in, n_out, density) in &cases {
            let w = erdos_renyi(n_in, n_out, density, &mut rng, &WeightInit::Normal(0.3));
            let wd = w.to_dense();
            for &batch in &[1usize, 3, 8, 19] {
                let x: Vec<f32> = (0..batch * n_in)
                    .map(|_| if rng.bernoulli(0.3) { 0.0 } else { rng.normal() })
                    .collect();
                let mut csr_out = vec![0.0f32; batch * n_out];
                let mut dense_out = vec![0.0f32; batch * n_out];
                ops::spmm_forward(&x, batch, &w, &mut csr_out);
                dense_forward(&x, batch, n_in, n_out, &wd, &mut dense_out);
                assert_eq!(csr_out, dense_out, "{n_in}x{n_out} d={density} batch={batch}");
            }
        }
    }

    #[test]
    fn dense_forward_sharded_matches_sequential() {
        let mut rng = Rng::new(13);
        let (n_in, n_out, batch) = (48, 40, 32);
        let w = erdos_renyi(n_in, n_out, 0.8, &mut rng, &WeightInit::Normal(0.2));
        let wd = w.to_dense();
        let x: Vec<f32> = (0..batch * n_in).map(|_| rng.normal()).collect();
        let mut seq = vec![0.0f32; batch * n_out];
        dense_forward(&x, batch, n_in, n_out, &wd, &mut seq);
        let pool = WorkerPool::new(4);
        for exec in [Exec::scoped(4), Exec::pooled(&pool)] {
            let mut par = vec![0.0f32; batch * n_out];
            // force sharding: the crossover would keep this size sequential
            let work = batch * n_in * n_out;
            assert!(work < exec.min_work() || exec.is_pooled());
            dense_forward_exec(&x, batch, n_in, n_out, &wd, &mut par, exec);
            assert_eq!(seq, par);
        }
    }

    #[test]
    fn forward_workspace_reuse_is_stable_across_batch_sizes() {
        let m = mlp(&[32, 48, 6], 6.0, 21);
        let s = ServeModel::from_mlp(&m, &LayoutOptions::default());
        let mut ws = ServeWorkspace::with_threads(1);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..19 * 32).map(|_| rng.normal()).collect();
        let full = s.forward(&x[..16 * 32], 16, &mut ws).to_vec();
        // shrink then regrow — buffers must stay consistent
        let one = s.forward(&x[..32], 1, &mut ws).to_vec();
        let again = s.forward(&x[..16 * 32], 16, &mut ws).to_vec();
        assert_eq!(full, again);
        assert_eq!(&full[..6], &one[..]);
    }
}
