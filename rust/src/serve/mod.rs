//! Sparse inference serving engine (DESIGN.md §10).
//!
//! Turns a trained `TSNN` checkpoint into a served model: an
//! inference-specialized, weights-only layout with per-layer CSR vs
//! dense-fallback format selection ([`layout`]), a request-batching
//! front end with a bounded submission queue and adaptive deadline
//! batching on the persistent [`WorkerPool`](crate::sparse::WorkerPool)
//! ([`engine`]), latency/throughput accounting ([`metrics`]) and a
//! closed-loop traffic generator for QPS sweeps ([`loadgen`],
//! `benches/perf_serving.rs` → `BENCH_5.json`).
//!
//! Parity contract: serving output is **bit-exact** vs the training
//! forward path at every pool size and batch composition — pinned by
//! `rust/tests/serving_parity.rs`.

pub mod engine;
pub mod layout;
pub mod loadgen;
pub mod metrics;

pub use engine::{ServeConfig, ServeEngine, ServeStats, SubmitError, Ticket};
pub use layout::{
    DENSE_CROSSOVER_DENSITY, LayerFormat, LayoutOptions, ServeLayer, ServeModel, ServeWorkspace,
};
pub use loadgen::{sweep, StepReport, SweepConfig};
pub use metrics::{LatencyRecorder, LatencySummary};
