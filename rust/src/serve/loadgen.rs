//! Closed-loop traffic replay + offered-QPS sweep (DESIGN.md §10.4).
//!
//! One submitter thread paces submissions on an absolute schedule
//! (`start + i / qps`), a collector thread waits each [`Ticket`] so the
//! number of un-reaped responses stays bounded; latency comes from the
//! engine's own recorder (enqueue → delivery). The sweep raises offered
//! QPS geometrically until the engine saturates — achieved throughput
//! falls below [`SweepConfig::saturation_ratio`] of offered, or
//! backpressure starts shedding — which is the measurement protocol of
//! `benches/perf_serving.rs` / `BENCH_5.json`.

use std::time::Instant;

use crate::serve::engine::{ServeEngine, SubmitError, Ticket};
use crate::serve::metrics::LatencySummary;

/// One offered-QPS measurement step.
#[derive(Debug, Clone, Copy)]
pub struct StepReport {
    /// Offered (paced) request rate.
    pub offered_qps: f64,
    /// Completed requests per second of wall clock (first submission →
    /// last delivery).
    pub achieved_qps: f64,
    /// Requests completed during the step.
    pub completed: u64,
    /// Submissions shed by backpressure during the step.
    pub rejected: u64,
    /// Engine latency digest for the step (enqueue → delivery).
    pub latency: LatencySummary,
    /// True when this step hit the saturation criterion.
    pub saturated: bool,
}

/// Sweep protocol knobs.
#[derive(Debug, Clone, Copy)]
pub struct SweepConfig {
    /// First offered rate.
    pub start_qps: f64,
    /// Multiplier between steps (> 1).
    pub growth: f64,
    /// Step ceiling (the sweep stops early at saturation).
    pub max_steps: usize,
    /// Replayed requests per step.
    pub requests_per_step: usize,
    /// A step saturates when `achieved < ratio × offered` (or anything
    /// was rejected).
    pub saturation_ratio: f64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            start_qps: 200.0,
            growth: 2.0,
            max_steps: 10,
            requests_per_step: 1000,
            saturation_ratio: 0.9,
        }
    }
}

/// Replay `requests` submissions from the rotating `features` pool
/// (row-major, `features.len() / n_feat` samples) at `offered_qps`,
/// resetting the engine's metrics first. Returns the step's report
/// (with `saturated` left `false` — the sweep judges that).
pub fn replay_step(
    engine: &ServeEngine,
    features: &[f32],
    n_feat: usize,
    offered_qps: f64,
    requests: usize,
) -> StepReport {
    assert!(offered_qps > 0.0 && n_feat > 0 && features.len() >= n_feat);
    let n_pool = features.len() / n_feat;
    engine.reset_metrics();
    let (tx, rx) = std::sync::mpsc::channel::<Ticket>();
    let collector = std::thread::spawn(move || {
        let mut last_done = None;
        while let Ok(ticket) = rx.recv() {
            if ticket.wait().is_ok() {
                last_done = Some(Instant::now());
            }
        }
        last_done
    });
    let start = Instant::now();
    let mut rejected = 0u64;
    for i in 0..requests {
        let target = start + std::time::Duration::from_secs_f64(i as f64 / offered_qps);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let s = (i % n_pool) * n_feat;
        match engine.submit(features[s..s + n_feat].to_vec()) {
            Ok(ticket) => {
                let _ = tx.send(ticket);
            }
            Err(SubmitError::QueueFull) => rejected += 1,
            Err(_) => break,
        }
    }
    drop(tx);
    let last_done = collector.join().unwrap();
    let stats = engine.stats();
    let elapsed = last_done
        .map(|t| t.duration_since(start).as_secs_f64())
        .unwrap_or(0.0)
        .max(1e-9);
    StepReport {
        offered_qps,
        achieved_qps: stats.completed as f64 / elapsed,
        completed: stats.completed,
        rejected: rejected.max(stats.rejected),
        latency: engine.latency(),
        saturated: false,
    }
}

/// Sweep offered QPS geometrically until saturation (or `max_steps`),
/// replaying `requests_per_step` requests per step. The saturating step
/// is included (flagged) so the report shows the knee.
pub fn sweep(
    engine: &ServeEngine,
    features: &[f32],
    n_feat: usize,
    cfg: &SweepConfig,
) -> Vec<StepReport> {
    let mut reports = Vec::new();
    let mut qps = cfg.start_qps;
    for _ in 0..cfg.max_steps {
        let mut report = replay_step(engine, features, n_feat, qps, cfg.requests_per_step);
        report.saturated =
            report.achieved_qps < cfg.saturation_ratio * report.offered_qps || report.rejected > 0;
        reports.push(report);
        if report.saturated {
            break;
        }
        qps *= cfg.growth;
    }
    reports
}
