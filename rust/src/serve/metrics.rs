//! Latency + throughput accounting for the serving engine
//! (DESIGN.md §10.3).
//!
//! [`LatencyRecorder::record`] is the per-request hot path: it writes
//! into a fixed-capacity sample window (ring overwrite once full) and
//! bumps scalar counters — no allocation in steady state, pinned at the
//! allocator level by `rust/tests/serve_alloc.rs` in the style of
//! `pool_alloc.rs`. Percentiles are nearest-rank over the retained
//! window and are computed off the hot path ([`LatencyRecorder::summary`]
//! sorts a scratch copy).

/// Fixed-window latency recorder (nanosecond samples).
#[derive(Debug, Clone)]
pub struct LatencyRecorder {
    /// Retained window; at most `cap` samples.
    samples: Vec<u64>,
    /// Window size (explicit — `Vec::with_capacity` only promises "at
    /// least", and the ring arithmetic needs the exact bound).
    cap: usize,
    /// Ring cursor once the window is full.
    next: usize,
    /// Lifetime sample count (not capped by the window).
    total: u64,
    /// Lifetime sum, for the mean.
    sum_ns: u128,
    /// Lifetime maximum.
    max_ns: u64,
}

/// Point-in-time digest of a [`LatencyRecorder`]: an empty window
/// reports `count == 0` and zeroed statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencySummary {
    /// Lifetime recorded samples.
    pub count: u64,
    /// Lifetime mean latency in nanoseconds.
    pub mean_ns: f64,
    /// Window p50 (nearest-rank).
    pub p50_ns: u64,
    /// Window p95 (nearest-rank).
    pub p95_ns: u64,
    /// Window p99 (nearest-rank).
    pub p99_ns: u64,
    /// Lifetime maximum in nanoseconds.
    pub max_ns: u64,
}

impl LatencyRecorder {
    /// Recorder retaining the last `capacity` samples (min 1).
    pub fn with_capacity(capacity: usize) -> LatencyRecorder {
        let cap = capacity.max(1);
        LatencyRecorder {
            samples: Vec::with_capacity(cap),
            cap,
            next: 0,
            total: 0,
            sum_ns: 0,
            max_ns: 0,
        }
    }

    /// Record one latency sample. Steady-state allocation-free: pushes
    /// within the fixed capacity, then overwrites ring-wise.
    #[inline]
    pub fn record(&mut self, ns: u64) {
        if self.samples.len() < self.cap {
            self.samples.push(ns);
        } else {
            self.samples[self.next] = ns;
            self.next += 1;
            if self.next == self.samples.len() {
                self.next = 0;
            }
        }
        self.total += 1;
        self.sum_ns += ns as u128;
        if ns > self.max_ns {
            self.max_ns = ns;
        }
    }

    /// Lifetime recorded samples (window retains at most `capacity`).
    pub fn count(&self) -> u64 {
        self.total
    }

    /// True when nothing has been recorded since construction/clear.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Reset all state, keeping the window's capacity (no realloc).
    pub fn clear(&mut self) {
        self.samples.clear();
        self.next = 0;
        self.total = 0;
        self.sum_ns = 0;
        self.max_ns = 0;
    }

    /// Nearest-rank percentile over the retained window: the
    /// `⌈p/100 · n⌉`-th smallest sample (1-based), `None` for an empty
    /// window. `p` is clamped to `[0, 100]`.
    pub fn percentile(&self, p: f64) -> Option<u64> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        Some(nearest_rank(&sorted, p))
    }

    /// Digest: lifetime count/mean/max plus window percentiles. One
    /// sort of one scratch copy — call off the hot path.
    pub fn summary(&self) -> LatencySummary {
        if self.samples.is_empty() {
            return LatencySummary::default();
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        LatencySummary {
            count: self.total,
            mean_ns: self.sum_ns as f64 / self.total as f64,
            p50_ns: nearest_rank(&sorted, 50.0),
            p95_ns: nearest_rank(&sorted, 95.0),
            p99_ns: nearest_rank(&sorted, 99.0),
            max_ns: self.max_ns,
        }
    }
}

/// Nearest-rank on an ascending-sorted non-empty slice.
fn nearest_rank(sorted: &[u64], p: f64) -> u64 {
    let n = sorted.len();
    let rank = (p.clamp(0.0, 100.0) / 100.0 * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_window_has_no_percentiles_and_zero_summary() {
        let r = LatencyRecorder::with_capacity(16);
        assert!(r.is_empty());
        assert_eq!(r.percentile(50.0), None);
        assert_eq!(r.summary(), LatencySummary::default());
        assert_eq!(r.summary().count, 0);
    }

    #[test]
    fn exact_ranks_on_small_samples() {
        // nearest-rank on [10, 20, 30, 40]: p≤25 → 10, p50 → 20,
        // p75 → 30, anything above → 40
        let mut r = LatencyRecorder::with_capacity(8);
        for v in [40u64, 10, 30, 20] {
            r.record(v);
        }
        assert_eq!(r.percentile(0.0), Some(10));
        assert_eq!(r.percentile(25.0), Some(10));
        assert_eq!(r.percentile(26.0), Some(20));
        assert_eq!(r.percentile(50.0), Some(20));
        assert_eq!(r.percentile(75.0), Some(30));
        assert_eq!(r.percentile(76.0), Some(40));
        assert_eq!(r.percentile(100.0), Some(40));
        // single sample: every percentile is that sample
        let mut one = LatencyRecorder::with_capacity(4);
        one.record(7);
        assert_eq!(one.percentile(1.0), Some(7));
        assert_eq!(one.percentile(50.0), Some(7));
        assert_eq!(one.percentile(99.0), Some(7));
    }

    #[test]
    fn known_distribution_percentiles() {
        // 1..=1000 permuted: p50 = 500, p95 = 950, p99 = 990, max = 1000
        let mut r = LatencyRecorder::with_capacity(1000);
        for i in 0..1000u64 {
            r.record((i * 617) % 1000 + 1); // 617 ⊥ 1000 → a permutation
        }
        let s = r.summary();
        assert_eq!(s.count, 1000);
        assert_eq!(s.p50_ns, 500);
        assert_eq!(s.p95_ns, 950);
        assert_eq!(s.p99_ns, 990);
        assert_eq!(s.max_ns, 1000);
        assert!((s.mean_ns - 500.5).abs() < 1e-9);
    }

    #[test]
    fn ring_overwrite_keeps_last_window_and_lifetime_counters() {
        let mut r = LatencyRecorder::with_capacity(4);
        for v in 1..=10u64 {
            r.record(v);
        }
        // window holds {7, 8, 9, 10}; lifetime stats see all ten
        assert_eq!(r.count(), 10);
        assert_eq!(r.percentile(1.0), Some(7));
        assert_eq!(r.percentile(100.0), Some(10));
        let s = r.summary();
        assert_eq!(s.max_ns, 10);
        assert!((s.mean_ns - 5.5).abs() < 1e-9);
        assert_eq!(s.p50_ns, 8);
    }

    #[test]
    fn clear_resets_without_losing_capacity() {
        let mut r = LatencyRecorder::with_capacity(4);
        for v in 1..=6u64 {
            r.record(v);
        }
        let cap = r.samples.capacity();
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.summary(), LatencySummary::default());
        assert_eq!(r.samples.capacity(), cap);
        r.record(42);
        assert_eq!(r.percentile(50.0), Some(42));
    }
}
