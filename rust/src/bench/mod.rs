//! Bench harness utilities shared by `rust/benches/*`: markdown/CSV table
//! emitters matching the paper's row formats, results-directory handling
//! and simple timing repetition (criterion is not available offline).

use std::path::PathBuf;

use crate::util::Timer;

/// A simple markdown/CSV table builder.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table title (printed above).
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as aligned markdown.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row.iter()) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = format!("### {}\n\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths.iter()) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Print markdown to stdout and write CSV under the results dir.
    pub fn emit(&self, csv_name: &str) {
        println!("{}", self.to_markdown());
        let dir = results_dir();
        if std::fs::create_dir_all(&dir).is_ok() {
            let path = dir.join(csv_name);
            if let Err(e) = std::fs::write(&path, self.to_csv()) {
                eprintln!("warn: could not write {}: {e}", path.display());
            } else {
                println!("(csv written to {})\n", path.display());
            }
        }
    }
}

/// Results directory: `$TSNN_RESULTS` or `./results`.
pub fn results_dir() -> PathBuf {
    std::env::var("TSNN_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"))
}

/// Write a raw artifact (e.g. learning-curve CSV) into the results dir.
pub fn write_artifact(name: &str, content: &str) -> std::io::Result<PathBuf> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    Ok(path)
}

/// Repository root (one level above the `rust/` package): where the
/// cross-PR machine-readable bench trackers (`BENCH_*.json`) live.
/// `$TSNN_REPO_ROOT` overrides (CI / out-of-tree runs).
pub fn repo_root() -> PathBuf {
    std::env::var("TSNN_REPO_ROOT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(".."))
}

/// Write a machine-readable bench tracker at the repository root (e.g.
/// `BENCH_2.json`) for cross-PR perf-trajectory tracking.
pub fn write_repo_root_json(name: &str, json: &crate::util::Json) -> std::io::Result<PathBuf> {
    let path = repo_root().join(name);
    let mut body = json.dump();
    body.push('\n');
    std::fs::write(&path, body)?;
    Ok(path)
}

/// Host descriptor embedded in every `BENCH_*.json` (core count, CPU
/// model, OS/arch): CI runs land on heterogeneous machines, so the
/// perf trajectory is only comparable across PRs when each artifact
/// says what it was measured on.
pub fn host_info() -> crate::util::Json {
    use crate::util::json::obj;
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let cpu = std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("model name"))
                .and_then(|l| l.split(':').nth(1))
                .map(|m| m.trim().to_string())
        })
        .unwrap_or_else(|| "unknown".to_string());
    obj(vec![
        ("cores", cores.into()),
        ("cpu", cpu.into()),
        ("os", std::env::consts::OS.into()),
        ("arch", std::env::consts::ARCH.into()),
    ])
}

/// Micro-bench: run `f` for `iters` iterations after `warmup`, returning
/// (mean_secs, min_secs) per iteration.
pub fn time_it<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> (f64, f64) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut total = 0.0f64;
    let mut min = f64::INFINITY;
    for _ in 0..iters.max(1) {
        let t = Timer::start();
        std::hint::black_box(f());
        let s = t.secs();
        total += s;
        min = min.min(s);
    }
    (total / iters.max(1) as f64, min)
}

/// Format seconds as the paper's "~ N min" style when large, else secs.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 60.0 {
        format!("~{:.1} min", secs / 60.0)
    } else if secs >= 1.0 {
        format!("{secs:.2} s")
    } else {
        format!("{:.2} ms", secs * 1e3)
    }
}

/// Scale an environment knob: `TSNN_SCALE=paper` selects full paper-scale
/// benches; anything else (default) runs the scaled-down suite.
pub fn paper_scale() -> bool {
    std::env::var("TSNN_SCALE").as_deref() == Ok("paper")
}

/// Integer environment override with default (e.g. `TSNN_EPOCHS`).
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Float environment override with default.
pub fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_markdown_and_csv() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| 1"));
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert_eq!(t.len(), 1);
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn time_it_returns_positive() {
        let (mean, min) = time_it(1, 3, || (0..1000).sum::<usize>());
        assert!(mean >= min);
        assert!(min >= 0.0);
    }

    #[test]
    fn repo_root_points_at_workspace() {
        // default: one level above the package dir, which contains rust/
        let root = repo_root();
        assert!(
            root.join("rust").join("Cargo.toml").exists()
                || std::env::var("TSNN_REPO_ROOT").is_ok()
        );
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(120.0).contains("min"));
        assert!(fmt_duration(2.0).contains("s"));
        assert!(fmt_duration(0.005).contains("ms"));
    }
}
