//! Guyon-style synthetic classification data (`make_classification`).
//!
//! A faithful re-implementation of the generator behind scikit-learn's
//! `make_classification` (Guyon 2003) — the algorithm that produced the
//! paper's Madelon dataset and its §2.4 "big artificial dataset":
//! class clusters at hypercube vertices in an informative subspace,
//! linearly-redundant features, pure-noise probe features, label noise,
//! and feature shuffling.

use crate::error::{Result, TsnnError};
use crate::util::Rng;

/// Parameters for [`make_classification`].
#[derive(Debug, Clone)]
pub struct SynthSpec {
    /// Total samples to generate.
    pub n_samples: usize,
    /// Total features (informative + redundant + probes).
    pub n_features: usize,
    /// Dimensionality of the informative subspace.
    pub n_informative: usize,
    /// Features that are random linear combinations of informative ones.
    pub n_redundant: usize,
    /// Number of classes.
    pub n_classes: usize,
    /// Gaussian clusters per class.
    pub n_clusters_per_class: usize,
    /// Distance scale between hypercube vertices (larger = easier).
    pub class_sep: f64,
    /// Fraction of labels randomly reassigned (irreducible error).
    pub flip_y: f64,
    /// Shuffle feature columns (hide which are informative).
    pub shuffle: bool,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec {
            n_samples: 100,
            n_features: 20,
            n_informative: 2,
            n_redundant: 2,
            n_classes: 2,
            n_clusters_per_class: 2,
            class_sep: 1.0,
            flip_y: 0.01,
            shuffle: true,
        }
    }
}

impl SynthSpec {
    /// Madelon's published recipe: 5 informative, 15 redundant, 480
    /// probes, 2 classes, 16 clusters per class on a hypercube.
    pub fn madelon(n_samples: usize) -> Self {
        SynthSpec {
            n_samples,
            n_features: 500,
            n_informative: 5,
            n_redundant: 15,
            n_classes: 2,
            n_clusters_per_class: 16,
            class_sep: 2.0,
            flip_y: 0.02,
            shuffle: true,
        }
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.n_informative + self.n_redundant > self.n_features {
            return Err(TsnnError::Data(format!(
                "informative {} + redundant {} exceed features {}",
                self.n_informative, self.n_redundant, self.n_features
            )));
        }
        if self.n_informative == 0 || self.n_classes < 2 || self.n_samples == 0 {
            return Err(TsnnError::Data("degenerate synth spec".into()));
        }
        let clusters = self.n_classes * self.n_clusters_per_class;
        // need enough hypercube corners (with sign choices) for clusters
        if (clusters as f64).log2() > 2.0 * self.n_informative as f64 {
            return Err(TsnnError::Data(format!(
                "{} clusters need more than 2^{} hypercube corners",
                clusters,
                2 * self.n_informative
            )));
        }
        Ok(())
    }
}

/// Gray-code style hypercube corner `index` in `dim` dims scaled by `sep`.
fn hypercube_vertex(index: usize, dim: usize, sep: f64) -> Vec<f64> {
    (0..dim)
        .map(|d| {
            if (index >> (d % (8 * std::mem::size_of::<usize>())).min(63)) & 1 == 1 {
                sep
            } else {
                -sep
            }
        })
        .collect()
}

/// Generate features (row-major `[n_samples, n_features]`) and labels.
pub fn make_classification(spec: &SynthSpec, rng: &mut Rng) -> Result<(Vec<f32>, Vec<u32>)> {
    spec.validate()?;
    let n = spec.n_samples;
    let nf = spec.n_features;
    let ni = spec.n_informative;
    let nr = spec.n_redundant;
    let n_clusters = spec.n_classes * spec.n_clusters_per_class;

    // cluster centroids at distinct hypercube vertices (shuffled corners)
    let corners = 1usize << ni.min(20);
    let mut corner_ids: Vec<usize> = (0..corners.max(n_clusters)).collect();
    rng.shuffle(&mut corner_ids);
    let centroids: Vec<Vec<f64>> = (0..n_clusters)
        .map(|c| hypercube_vertex(corner_ids[c % corner_ids.len()], ni, spec.class_sep))
        .collect();

    // per-cluster random covariance transform A (ni x ni)
    let transforms: Vec<Vec<f64>> = (0..n_clusters)
        .map(|_| (0..ni * ni).map(|_| rng.normal() as f64).collect())
        .collect();

    // redundant mixing matrix B (ni x nr)
    let mix: Vec<f64> = (0..ni * nr).map(|_| rng.normal() as f64).collect();

    let mut x = vec![0.0f32; n * nf];
    let mut y = vec![0u32; n];
    let mut informative = vec![0.0f64; ni];

    for s in 0..n {
        let cluster = rng.below_usize(n_clusters);
        let class = (cluster % spec.n_classes) as u32;
        y[s] = class;
        let centroid = &centroids[cluster];
        let a = &transforms[cluster];
        // raw gaussian, transformed by A, shifted to centroid
        let raw: Vec<f64> = (0..ni).map(|_| rng.normal() as f64).collect();
        for i in 0..ni {
            let mut acc = 0.0f64;
            for k in 0..ni {
                acc += raw[k] * a[k * ni + i];
            }
            informative[i] = centroid[i] + acc;
        }
        let row = &mut x[s * nf..(s + 1) * nf];
        for i in 0..ni {
            row[i] = informative[i] as f32;
        }
        // redundant = informative @ B
        for r in 0..nr {
            let mut acc = 0.0f64;
            for i in 0..ni {
                acc += informative[i] * mix[i * nr + r];
            }
            row[ni + r] = acc as f32;
        }
        // probes: pure noise
        for p in (ni + nr)..nf {
            row[p] = rng.normal();
        }
    }

    // label noise
    if spec.flip_y > 0.0 {
        for label in y.iter_mut() {
            if rng.bernoulli(spec.flip_y) {
                *label = rng.below(spec.n_classes as u64) as u32;
            }
        }
    }

    // shuffle feature columns so informative ones are hidden
    if spec.shuffle {
        let mut perm: Vec<usize> = (0..nf).collect();
        rng.shuffle(&mut perm);
        let mut shuffled = vec![0.0f32; n * nf];
        for s in 0..n {
            let src = &x[s * nf..(s + 1) * nf];
            let dst = &mut shuffled[s * nf..(s + 1) * nf];
            for (new_col, &old_col) in perm.iter().enumerate() {
                dst[new_col] = src[old_col];
            }
        }
        x = shuffled;
    }

    Ok((x, y))
}

/// Z-score standardisation: fit mean/std on train, apply to both splits
/// (the paper standardises every dataset to zero mean / unit variance).
pub fn standardize(x_train: &mut [f32], x_test: &mut [f32], n_features: usize) {
    let n_train = x_train.len() / n_features;
    if n_train == 0 {
        return;
    }
    let mut mean = vec![0.0f64; n_features];
    let mut var = vec![0.0f64; n_features];
    for s in 0..n_train {
        for f in 0..n_features {
            mean[f] += x_train[s * n_features + f] as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= n_train as f64;
    }
    for s in 0..n_train {
        for f in 0..n_features {
            let d = x_train[s * n_features + f] as f64 - mean[f];
            var[f] += d * d;
        }
    }
    let inv_std: Vec<f32> = var
        .iter()
        .map(|&v| {
            let std = (v / n_train as f64).sqrt();
            if std < 1e-12 {
                0.0
            } else {
                (1.0 / std) as f32
            }
        })
        .collect();
    let apply = |buf: &mut [f32]| {
        let rows = buf.len() / n_features;
        for s in 0..rows {
            for f in 0..n_features {
                let v = &mut buf[s * n_features + f];
                *v = (*v - mean[f] as f32) * inv_std[f];
            }
        }
    };
    apply(x_train);
    apply(x_test);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_label_range() {
        let spec = SynthSpec {
            n_samples: 200,
            n_features: 30,
            n_informative: 4,
            n_redundant: 3,
            n_classes: 3,
            ..Default::default()
        };
        let (x, y) = make_classification(&spec, &mut Rng::new(1)).unwrap();
        assert_eq!(x.len(), 200 * 30);
        assert_eq!(y.len(), 200);
        assert!(y.iter().all(|&c| c < 3));
        // all classes present
        for c in 0..3u32 {
            assert!(y.iter().any(|&v| v == c), "class {c} missing");
        }
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let s = SynthSpec {
            n_informative: 25, // > n_features
            ..Default::default()
        };
        assert!(s.validate().is_err());
        let s2 = SynthSpec {
            n_classes: 1,
            ..Default::default()
        };
        assert!(s2.validate().is_err());
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = SynthSpec::default();
        let a = make_classification(&spec, &mut Rng::new(5)).unwrap();
        let b = make_classification(&spec, &mut Rng::new(5)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn classes_are_separable_by_a_linear_probe() {
        // informative structure must be learnable: train a tiny logistic
        // regression via our own MLP later; here check class-conditional
        // means differ significantly in at least one feature.
        let spec = SynthSpec {
            n_samples: 600,
            n_features: 10,
            n_informative: 4,
            n_redundant: 0,
            n_classes: 2,
            n_clusters_per_class: 1,
            class_sep: 2.0,
            flip_y: 0.0,
            shuffle: false,
        };
        let (x, y) = make_classification(&spec, &mut Rng::new(7)).unwrap();
        let mut best_gap = 0.0f64;
        for f in 0..4 {
            let (mut m0, mut m1, mut c0, mut c1) = (0.0f64, 0.0f64, 0usize, 0usize);
            for s in 0..600 {
                let v = x[s * 10 + f] as f64;
                if y[s] == 0 {
                    m0 += v;
                    c0 += 1;
                } else {
                    m1 += v;
                    c1 += 1;
                }
            }
            let gap = (m0 / c0 as f64 - m1 / c1 as f64).abs();
            best_gap = best_gap.max(gap);
        }
        assert!(best_gap > 1.0, "gap {best_gap}");
    }

    #[test]
    fn flip_y_injects_noise() {
        let mut spec = SynthSpec {
            n_samples: 2000,
            class_sep: 5.0,
            n_clusters_per_class: 1,
            shuffle: false,
            ..Default::default()
        };
        spec.flip_y = 0.0;
        let (_, y_clean) = make_classification(&spec, &mut Rng::new(9)).unwrap();
        spec.flip_y = 0.3;
        let (_, y_noisy) = make_classification(&spec, &mut Rng::new(9)).unwrap();
        let diff = y_clean
            .iter()
            .zip(y_noisy.iter())
            .filter(|(a, b)| a != b)
            .count();
        assert!(diff > 100, "diff {diff}");
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut rng = Rng::new(11);
        let nf = 5;
        let mut train: Vec<f32> = (0..100 * nf).map(|_| rng.normal() * 3.0 + 7.0).collect();
        let mut test: Vec<f32> = (0..20 * nf).map(|_| rng.normal() * 3.0 + 7.0).collect();
        standardize(&mut train, &mut test, nf);
        for f in 0..nf {
            let mean: f64 = (0..100).map(|s| train[s * nf + f] as f64).sum::<f64>() / 100.0;
            let var: f64 =
                (0..100).map(|s| (train[s * nf + f] as f64 - mean).powi(2)).sum::<f64>() / 100.0;
            assert!(mean.abs() < 1e-4, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "var {var}");
        }
    }

    #[test]
    fn standardize_handles_constant_feature() {
        let mut train = vec![3.0f32; 10];
        let mut test = vec![3.0f32; 4];
        standardize(&mut train, &mut test, 1);
        assert!(train.iter().all(|&v| v == 0.0));
        assert!(test.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn madelon_spec_matches_published_recipe() {
        let s = SynthSpec::madelon(2000);
        assert_eq!(s.n_features, 500);
        assert_eq!(s.n_informative, 5);
        assert_eq!(s.n_redundant, 15);
        s.validate().unwrap();
    }
}
