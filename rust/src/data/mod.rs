//! Data substrate: Guyon-style synthetic generation ([`synth`]) and the
//! paper's dataset suite ([`datasets`]).

pub mod datasets;
pub mod synth;

pub use datasets::{generate, Dataset};
pub use synth::{make_classification, standardize, SynthSpec};
