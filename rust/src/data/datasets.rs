//! The paper's five evaluation datasets as deterministic synthetic
//! generators (Table 1), plus the §2.4 extreme-scale dataset.
//!
//! No network access is available in this environment, so each generator
//! reproduces the *shape* of its dataset (feature count, class count,
//! sample counts) and the qualitative property it contributes to the
//! evaluation (see DESIGN.md §3 Substitutions):
//!
//! | name        | shape (full)            | property reproduced          |
//! |-------------|-------------------------|------------------------------|
//! | leukemia    | 54675f / 18c / 1397+699 | high-dim, tiny-n microarray  |
//! | higgs       | 28f / 2c / 105k+50k     | low-dim, large-n, irreducible noise |
//! | madelon     | 500f / 2c / 2000+600    | 5 informative + 15 redundant + 480 probes |
//! | fashion     | 784f / 10c / 60k+10k    | image-like local correlation |
//! | cifar       | 3072f / 10c / 50k+10k   | 3-channel image-like         |
//! | extreme     | 65536f / 2c / 7000+3000 | §2.4 big artificial dataset  |
//!
//! All are standardised to zero mean / unit variance on the train split.

use crate::config::DatasetSpec;
use crate::error::Result;
use crate::util::Rng;

use super::synth::{make_classification, standardize, SynthSpec};

/// An in-memory dataset (row-major features).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Generator name.
    pub name: String,
    /// Feature dimensionality.
    pub n_features: usize,
    /// Class count.
    pub n_classes: usize,
    /// Train features `[n_train, n_features]`.
    pub x_train: Vec<f32>,
    /// Train labels.
    pub y_train: Vec<u32>,
    /// Test features.
    pub x_test: Vec<f32>,
    /// Test labels.
    pub y_test: Vec<u32>,
}

impl Dataset {
    /// Train sample count.
    pub fn n_train(&self) -> usize {
        self.y_train.len()
    }

    /// Test sample count.
    pub fn n_test(&self) -> usize {
        self.y_test.len()
    }

    /// Memory footprint of the feature arrays in MiB.
    pub fn memory_mib(&self) -> f64 {
        ((self.x_train.len() + self.x_test.len()) * 4) as f64 / (1024.0 * 1024.0)
    }

    fn from_split(
        name: &str,
        n_features: usize,
        n_classes: usize,
        mut x: Vec<f32>,
        y: Vec<u32>,
        n_train: usize,
    ) -> Dataset {
        let x_test = x.split_off(n_train * n_features);
        let y_test = y[n_train..].to_vec();
        let y_train = y[..n_train].to_vec();
        let mut x_train = x;
        let mut x_test = x_test;
        standardize(&mut x_train, &mut x_test, n_features);
        Dataset {
            name: name.to_string(),
            n_features,
            n_classes,
            x_train,
            y_train,
            x_test,
            y_test,
        }
    }
}

/// Dispatch by generator name in the spec.
pub fn generate(spec: &DatasetSpec, rng: &mut Rng) -> Result<Dataset> {
    match spec.generator.as_str() {
        "leukemia" => leukemia_like(spec, rng),
        "higgs" => higgs_like(spec, rng),
        "madelon" => madelon(spec, rng),
        "fashion" => fashion_like(spec, rng),
        "cifar" => cifar_like(spec, rng),
        "extreme" => extreme(spec, rng),
        "recommender" => recommender(spec, rng),
        other => Err(crate::error::TsnnError::Data(format!(
            "unknown dataset generator '{other}'"
        ))),
    }
}

/// Microarray-style: very high-dimensional, tiny sample count, many
/// classes, few informative genes.
pub fn leukemia_like(spec: &DatasetSpec, rng: &mut Rng) -> Result<Dataset> {
    let total = spec.n_train + spec.n_test;
    let synth = SynthSpec {
        n_samples: total,
        n_features: spec.n_features,
        n_informative: 64.min(spec.n_features / 4).max(8),
        n_redundant: 32.min(spec.n_features / 8),
        n_classes: spec.n_classes,
        n_clusters_per_class: 1,
        class_sep: 2.5,
        flip_y: 0.02,
        shuffle: true,
    };
    let (x, y) = make_classification(&synth, rng)?;
    Ok(Dataset::from_split(
        &spec.name,
        spec.n_features,
        spec.n_classes,
        x,
        y,
        spec.n_train,
    ))
}

/// Physics-like: 28 low-level/derived features, heavy class overlap so
/// accuracy plateaus in the low 70s like the real HIGGS task.
pub fn higgs_like(spec: &DatasetSpec, rng: &mut Rng) -> Result<Dataset> {
    let total = spec.n_train + spec.n_test;
    let synth = SynthSpec {
        n_samples: total,
        n_features: spec.n_features,
        n_informative: (spec.n_features * 2 / 3).max(2),
        n_redundant: spec.n_features / 6,
        n_classes: 2,
        n_clusters_per_class: 2,
        class_sep: 0.8, // hard problem: irreducible overlap
        flip_y: 0.12,
        shuffle: true,
    };
    let (x, y) = make_classification(&synth, rng)?;
    Ok(Dataset::from_split(
        &spec.name,
        spec.n_features,
        2,
        x,
        y,
        spec.n_train,
    ))
}

/// The actual Madelon recipe (Guyon 2003).
pub fn madelon(spec: &DatasetSpec, rng: &mut Rng) -> Result<Dataset> {
    let total = spec.n_train + spec.n_test;
    let mut synth = SynthSpec::madelon(total);
    synth.n_features = spec.n_features;
    // keep the informative/redundant recipe but never exceed n_features
    synth.n_informative = synth.n_informative.min(spec.n_features / 4).max(2);
    synth.n_redundant = synth.n_redundant.min(spec.n_features / 4);
    let (x, y) = make_classification(&synth, rng)?;
    Ok(Dataset::from_split(
        &spec.name,
        spec.n_features,
        2,
        x,
        y,
        spec.n_train,
    ))
}

/// Image-like generator: class prototypes are sums of smooth 2-D Gaussian
/// blobs on a `side × side` grid (× `channels`); samples add per-pixel
/// noise, a random global intensity jitter and a small translation —
/// giving the local pixel correlation structure real image data has.
fn image_like(
    name: &str,
    side: usize,
    channels: usize,
    n_classes: usize,
    n_train: usize,
    n_test: usize,
    rng: &mut Rng,
) -> Dataset {
    let n_features = side * side * channels;
    let total = n_train + n_test;

    // shared background blobs (present in every class — non-discriminative
    // structure, like garment/vehicle silhouettes sharing a canvas) plus a
    // small number of class-specific blobs. The shared mass plus heavy
    // pixel noise keeps the task non-trivial, like its real counterpart.
    let mut background = vec![0.0f32; n_features];
    let add_blobs = |buf: &mut [f32], n_blobs: usize, amp_scale: f32, rng: &mut Rng| {
        for _ in 0..n_blobs {
            let cx = rng.uniform(0.15, 0.85) * side as f32;
            let cy = rng.uniform(0.15, 0.85) * side as f32;
            let sigma = rng.uniform(0.08, 0.22) * side as f32;
            let amp = amp_scale * rng.uniform(0.5, 1.5);
            let ch = rng.below_usize(channels);
            for yy in 0..side {
                for xx in 0..side {
                    let d2 = ((xx as f32 - cx).powi(2) + (yy as f32 - cy).powi(2))
                        / (2.0 * sigma * sigma);
                    buf[ch * side * side + yy * side + xx] += amp * (-d2).exp();
                }
            }
        }
    };
    add_blobs(&mut background, 6, 1.0, rng);
    let mut prototypes = vec![0.0f32; n_classes * n_features];
    for c in 0..n_classes {
        let proto = &mut prototypes[c * n_features..(c + 1) * n_features];
        proto.copy_from_slice(&background);
        add_blobs(proto, 2 + rng.below_usize(2), 0.6, rng);
    }

    let mut x = vec![0.0f32; total * n_features];
    let mut y = vec![0u32; total];
    for s in 0..total {
        let c = rng.below_usize(n_classes);
        y[s] = c as u32;
        let proto = &prototypes[c * n_features..(c + 1) * n_features];
        let row = &mut x[s * n_features..(s + 1) * n_features];
        // translation (±3 px) + gain jitter to mimic intra-class variation
        let dx = rng.below_usize(7) as isize - 3;
        let dy = rng.below_usize(7) as isize - 3;
        let gain = rng.uniform(0.6, 1.4);
        for ch in 0..channels {
            for yy in 0..side {
                for xx in 0..side {
                    let sx = xx as isize + dx;
                    let sy = yy as isize + dy;
                    let v = if sx >= 0 && sx < side as isize && sy >= 0 && sy < side as isize
                    {
                        proto[ch * side * side + sy as usize * side + sx as usize]
                    } else {
                        0.0
                    };
                    row[ch * side * side + yy * side + xx] = gain * v + 0.9 * rng.normal();
                }
            }
        }
    }
    Dataset::from_split(name, n_features, n_classes, x, y, n_train)
}

/// FashionMNIST-like: 28×28×1 grayscale, 10 classes.
pub fn fashion_like(spec: &DatasetSpec, rng: &mut Rng) -> Result<Dataset> {
    let side = (spec.n_features as f64).sqrt().round() as usize;
    debug_assert_eq!(side * side, spec.n_features, "fashion expects square");
    Ok(image_like(
        &spec.name,
        side,
        1,
        spec.n_classes,
        spec.n_train,
        spec.n_test,
        rng,
    ))
}

/// CIFAR10-like: 32×32×3 RGB, 10 classes.
pub fn cifar_like(spec: &DatasetSpec, rng: &mut Rng) -> Result<Dataset> {
    let side = ((spec.n_features / 3) as f64).sqrt().round() as usize;
    debug_assert_eq!(side * side * 3, spec.n_features, "cifar expects 3-channel square");
    Ok(image_like(
        &spec.name,
        side,
        3,
        spec.n_classes,
        spec.n_train,
        spec.n_test,
        rng,
    ))
}

/// §2.4 "big artificial dataset": binary task over a very wide feature
/// space (65536 at paper scale), generated by the Madelon algorithm.
pub fn extreme(spec: &DatasetSpec, rng: &mut Rng) -> Result<Dataset> {
    let total = spec.n_train + spec.n_test;
    let synth = SynthSpec {
        n_samples: total,
        n_features: spec.n_features,
        n_informative: 32.min(spec.n_features / 8).max(2),
        n_redundant: 16.min(spec.n_features / 16),
        n_classes: 2,
        n_clusters_per_class: 4,
        class_sep: 1.5,
        flip_y: 0.05,
        shuffle: true,
    };
    let (x, y) = make_classification(&synth, rng)?;
    Ok(Dataset::from_split(
        &spec.name,
        spec.n_features,
        2,
        x,
        y,
        spec.n_train,
    ))
}

/// Recommender-style wide-sparse task: count-valued token features over
/// a very wide vocabulary (the out-of-core "bat brain" workload of
/// DESIGN.md §14.8 — input width is the axis that blows up the first
/// layer's parameter count). Each class has a small set of preferred
/// tokens; a sample activates a handful of tokens, drawn mostly from its
/// class's preferences plus shared background popularity, with small
/// interaction counts as values. Features stay raw counts — no
/// standardisation, which would destroy the sparsity that makes the
/// workload representative.
pub fn recommender(spec: &DatasetSpec, rng: &mut Rng) -> Result<Dataset> {
    let nf = spec.n_features;
    let nc = spec.n_classes.max(2);
    if nf < 16 {
        return Err(crate::error::TsnnError::Data(format!(
            "recommender needs >= 16 features, got {nf}"
        )));
    }
    // class preference profiles over the vocabulary
    let prefs_per_class = (nf / 8).clamp(8, 64);
    let prefs: Vec<Vec<usize>> = (0..nc)
        .map(|_| rng.sample_indices(nf, prefs_per_class))
        .collect();
    // shared popular tokens every class touches (non-discriminative mass)
    let background = rng.sample_indices(nf, (nf / 16).clamp(4, 32));
    let tokens_per_sample = (nf / 32).clamp(6, 48);

    let mut fill = |n_samples: usize, rng: &mut Rng| -> (Vec<f32>, Vec<u32>) {
        let mut x = vec![0.0f32; n_samples * nf];
        let mut y = vec![0u32; n_samples];
        for s in 0..n_samples {
            let c = rng.below_usize(nc);
            y[s] = c as u32;
            let row = &mut x[s * nf..(s + 1) * nf];
            for _ in 0..tokens_per_sample {
                // 60% preferred, 25% background, 15% uniform noise
                let roll = rng.f32();
                let tok = if roll < 0.60 {
                    prefs[c][rng.below_usize(prefs[c].len())]
                } else if roll < 0.85 {
                    background[rng.below_usize(background.len())]
                } else {
                    rng.below_usize(nf)
                };
                // interaction counts, not indicators
                row[tok] += 1.0 + rng.below_usize(3) as f32;
            }
        }
        (x, y)
    };
    let (x_train, y_train) = fill(spec.n_train, rng);
    let (x_test, y_test) = fill(spec.n_test, rng);
    Ok(Dataset {
        name: spec.name.clone(),
        n_features: nf,
        n_classes: nc,
        x_train,
        y_train,
        x_test,
        y_test,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;

    #[test]
    fn all_generators_produce_consistent_shapes() {
        for name in [
            "leukemia",
            "higgs",
            "madelon",
            "fashion",
            "cifar",
            "extreme",
            "recommender",
        ] {
            let spec = DatasetSpec::small(name);
            let d = generate(&spec, &mut Rng::new(1)).unwrap();
            assert_eq!(d.x_train.len(), d.n_train() * d.n_features, "{name}");
            assert_eq!(d.x_test.len(), d.n_test() * d.n_features, "{name}");
            assert!(d.y_train.iter().all(|&c| (c as usize) < d.n_classes));
            assert!(d.y_test.iter().all(|&c| (c as usize) < d.n_classes));
            assert_eq!(d.n_train(), spec.n_train, "{name}");
            assert_eq!(d.n_test(), spec.n_test, "{name}");
        }
    }

    #[test]
    fn generators_are_deterministic() {
        let spec = DatasetSpec::small("madelon");
        let a = generate(&spec, &mut Rng::new(3)).unwrap();
        let b = generate(&spec, &mut Rng::new(3)).unwrap();
        assert_eq!(a.x_train, b.x_train);
        assert_eq!(a.y_test, b.y_test);
    }

    #[test]
    fn train_split_is_standardised() {
        let spec = DatasetSpec::small("higgs");
        let d = generate(&spec, &mut Rng::new(5)).unwrap();
        let nf = d.n_features;
        let n = d.n_train();
        for f in 0..nf {
            let mean: f64 = (0..n).map(|s| d.x_train[s * nf + f] as f64).sum::<f64>() / n as f64;
            assert!(mean.abs() < 1e-3, "feature {f} mean {mean}");
        }
    }

    #[test]
    fn image_generator_has_local_correlation() {
        // neighbouring pixels must correlate more than distant ones
        let spec = DatasetSpec::small("fashion");
        let d = generate(&spec, &mut Rng::new(7)).unwrap();
        let side = (d.n_features as f64).sqrt() as usize;
        let n = d.n_train();
        let corr = |f1: usize, f2: usize| -> f64 {
            let (mut s1, mut s2, mut s11, mut s22, mut s12) = (0.0, 0.0, 0.0, 0.0, 0.0);
            for s in 0..n {
                let a = d.x_train[s * d.n_features + f1] as f64;
                let b = d.x_train[s * d.n_features + f2] as f64;
                s1 += a;
                s2 += b;
                s11 += a * a;
                s22 += b * b;
                s12 += a * b;
            }
            let nf = n as f64;
            let cov = s12 / nf - (s1 / nf) * (s2 / nf);
            let v1 = s11 / nf - (s1 / nf).powi(2);
            let v2 = s22 / nf - (s2 / nf).powi(2);
            cov / (v1 * v2).sqrt().max(1e-12)
        };
        let center = (side / 2) * side + side / 2;
        let neighbour = corr(center, center + 1).abs();
        let distant = corr(center, side + 1).abs();
        assert!(
            neighbour > distant,
            "neighbour {neighbour} vs distant {distant}"
        );
    }

    #[test]
    fn recommender_is_sparse_and_class_informative() {
        let spec = DatasetSpec::small("recommender");
        let d = generate(&spec, &mut Rng::new(11)).unwrap();
        // counts, not standardised: mostly zeros, all non-negative
        let zeros = d.x_train.iter().filter(|&&v| v == 0.0).count();
        assert!(
            zeros as f64 > 0.8 * d.x_train.len() as f64,
            "expected sparse rows, got {} / {} zeros",
            zeros,
            d.x_train.len()
        );
        assert!(d.x_train.iter().all(|&v| v >= 0.0));
        // class-preferred tokens must separate class-conditional means:
        // the top token of class 0 should be touched more by class-0 rows
        let nf = d.n_features;
        let mut mean0 = vec![0.0f64; nf];
        let mut mean1 = vec![0.0f64; nf];
        let (mut n0, mut n1) = (0usize, 0usize);
        for (s, &c) in d.y_train.iter().enumerate() {
            let row = &d.x_train[s * nf..(s + 1) * nf];
            if c == 0 {
                n0 += 1;
                for (m, &v) in mean0.iter_mut().zip(row) {
                    *m += v as f64;
                }
            } else if c == 1 {
                n1 += 1;
                for (m, &v) in mean1.iter_mut().zip(row) {
                    *m += v as f64;
                }
            }
        }
        let max_gap = (0..nf)
            .map(|f| (mean0[f] / n0 as f64 - mean1[f] / n1 as f64).abs())
            .fold(0.0f64, f64::max);
        assert!(
            max_gap > 0.1,
            "class-preferred tokens should separate the class-conditional \
             means (max gap {max_gap})"
        );
    }

    #[test]
    fn unknown_generator_errors() {
        let mut spec = DatasetSpec::small("higgs");
        spec.generator = "nope".into();
        assert!(generate(&spec, &mut Rng::new(0)).is_err());
    }

    #[test]
    fn memory_accounting() {
        let spec = DatasetSpec::small("madelon");
        let d = generate(&spec, &mut Rng::new(0)).unwrap();
        assert!(d.memory_mib() > 0.0);
    }
}
