//! Neuron importance and Importance Pruning (paper Eq. 4, Algorithm 2).
//!
//! Importance of hidden neuron `j` in layer `l` is its *node strength*:
//! `I_j = Σ_i |w_ij|` over incoming connections. During training (every
//! `p` epochs after epoch `τ`) all incoming weights of neurons whose
//! importance falls below a threshold are removed — hubs survive,
//! redundancy is eliminated, and both memory and epoch time shrink.
//!
//! Two modes mirror the paper's evaluation:
//! * **during-training** ([`prune_low_importance`]) — Algorithm 2, used
//!   by Table 2 / Table 3 runs;
//! * **post-training percentile sweep** ([`prune_percentile`]) — the
//!   §5.3 / Table 6 ablation showing why integration during training wins.

use crate::model::{SparseLayer, SparseMlp};

/// Importance of each output neuron of one layer (Eq. 4).
pub fn neuron_importance(layer: &SparseLayer) -> Vec<f32> {
    layer.weights.column_abs_sums()
}

/// Importance pruning schedule parameters.
#[derive(Debug, Clone, Copy)]
pub struct ImportanceConfig {
    /// First epoch at which pruning may run (paper: τ = 200).
    pub start_epoch: usize,
    /// Run every `period` epochs after `start_epoch` (paper: p).
    pub period: usize,
    /// Neurons below this percentile of the layer's importance
    /// distribution lose all incoming connections (paper uses an absolute
    /// threshold t; the percentile form is scale-free and is what the
    /// §5.3 sweep explores).
    pub percentile: f64,
    /// Never prune a layer below this many remaining connections.
    pub min_connections: usize,
}

impl Default for ImportanceConfig {
    fn default() -> Self {
        ImportanceConfig {
            start_epoch: 200,
            period: 40,
            percentile: 5.0,
            min_connections: 16,
        }
    }
}

impl ImportanceConfig {
    /// Whether pruning should run at `epoch` (Algorithm 2's
    /// `e % p == 0 && e >= τ`).
    pub fn due(&self, epoch: usize) -> bool {
        self.period > 0 && epoch >= self.start_epoch && epoch % self.period == 0
    }
}

/// The value at the given percentile (0–100) of `xs` (linear selection,
/// no interpolation — matches numpy's "lower" method).
pub fn percentile_value(xs: &[f32], pct: f64) -> f32 {
    let mut v: Vec<f32> = xs.to_vec();
    percentile_value_mut(&mut v, pct)
}

/// [`percentile_value`] operating in place (the slice is reordered) — the
/// allocation-free variant the evolution engine's workspace path uses.
pub fn percentile_value_mut(xs: &mut [f32], pct: f64) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let idx = ((pct / 100.0) * (xs.len() - 1) as f64).floor() as usize;
    let (_, val, _) = xs.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
    *val
}

/// The pruning threshold [`prune_low_importance`] would apply, given
/// precomputed importances: `None` when the layer is at (or below) the
/// `min_connections` floor or has no active neuron. `scratch` receives
/// the active (> 0) importances (a reusable buffer — the engine's
/// workspace path passes one with reserved capacity).
///
/// Factored out so the fused evolution engine (DESIGN.md §8) and the
/// sequential oracle cannot drift apart in threshold semantics.
pub fn importance_threshold_from(
    imp: &[f32],
    nnz: usize,
    cfg: &ImportanceConfig,
    scratch: &mut Vec<f32>,
) -> Option<f32> {
    if nnz <= cfg.min_connections {
        return None;
    }
    scratch.clear();
    scratch.extend(imp.iter().copied().filter(|&v| v > 0.0));
    if scratch.is_empty() {
        return None;
    }
    Some(percentile_value_mut(scratch, cfg.percentile))
}

/// Remove all incoming connections of output neurons with importance
/// `< threshold` on this layer. Returns connections removed.
///
/// The *output layer* of a classifier must keep its class neurons, so
/// callers exclude it (as the paper's Algorithm 2 operates on hidden
/// units).
pub fn prune_neurons_below(layer: &mut SparseLayer, threshold: f32) -> usize {
    let imp = neuron_importance(layer);
    let cols = layer.weights.col_idx.clone();
    layer.retain_entries(|k| imp[cols[k] as usize] >= threshold)
}

/// Percentile-based importance pruning of one layer, with a floor on
/// remaining connections. Returns connections removed.
pub fn prune_low_importance(layer: &mut SparseLayer, cfg: &ImportanceConfig) -> usize {
    if layer.weights.nnz() <= cfg.min_connections {
        return 0; // at the floor: skip the O(nnz) importance scan entirely
    }
    let imp = neuron_importance(layer);
    let mut active = Vec::new();
    match importance_threshold_from(&imp, layer.weights.nnz(), cfg, &mut active) {
        Some(thr) => {
            // reuse the importances already computed for the threshold
            // (prune_neurons_below would rescan the CSR to rebuild them)
            let cols = layer.weights.col_idx.clone();
            layer.retain_entries(|k| imp[cols[k] as usize] >= thr)
        }
        None => 0,
    }
}

/// During-training importance pruning across hidden layers (all layers
/// except the final classifier layer's output side).
pub fn prune_model(mlp: &mut SparseMlp, cfg: &ImportanceConfig) -> usize {
    let n_layers = mlp.layers.len();
    let mut removed = 0usize;
    for (l, layer) in mlp.layers.iter_mut().enumerate() {
        if l + 1 == n_layers {
            continue; // never prune class-output neurons
        }
        removed += prune_low_importance(layer, cfg);
    }
    removed
}

/// Post-training variant (§5.3 / Table 6): prune every hidden layer at a
/// fixed percentile once and return (removed, remaining).
pub fn prune_post_training(mlp: &mut SparseMlp, pct: f64) -> (usize, usize) {
    let cfg = ImportanceConfig {
        start_epoch: 0,
        period: 1,
        percentile: pct,
        min_connections: 0,
    };
    let removed = prune_model(mlp, &cfg);
    (removed, mlp.weight_count())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Activation;
    use crate::sparse::{CsrMatrix, WeightInit};
    use crate::util::Rng;

    fn layer_with(vals: Vec<(u32, u32, f32)>, n_in: usize, n_out: usize) -> SparseLayer {
        let weights = CsrMatrix::from_coo(n_in, n_out, vals).unwrap();
        let nnz = weights.nnz();
        SparseLayer {
            weights,
            bias: vec![0.0; n_out],
            velocity: vec![0.0; nnz].into(),
            bias_velocity: vec![0.0; n_out],
            activation: Activation::Relu,
            srelu: None,
        }
    }

    #[test]
    fn importance_is_column_strength() {
        let l = layer_with(vec![(0, 0, 1.0), (1, 0, -2.0), (0, 1, 0.5)], 2, 3);
        assert_eq!(neuron_importance(&l), vec![3.0, 0.5, 0.0]);
    }

    #[test]
    fn prune_below_removes_whole_neurons() {
        let mut l = layer_with(
            vec![(0, 0, 1.0), (1, 0, -2.0), (0, 1, 0.5), (1, 1, 0.1)],
            2,
            2,
        );
        // importances: col0 = 3.0, col1 = 0.6
        let removed = prune_neurons_below(&mut l, 1.0);
        assert_eq!(removed, 2);
        assert_eq!(l.weights.column_counts(), vec![2, 0]);
    }

    #[test]
    fn percentile_value_selects() {
        let xs = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile_value(&xs, 0.0), 1.0);
        assert_eq!(percentile_value(&xs, 100.0), 5.0);
        assert_eq!(percentile_value(&xs, 50.0), 3.0);
        assert_eq!(percentile_value(&[], 50.0), 0.0);
    }

    #[test]
    fn threshold_helper_mirrors_prune_low_importance_gates() {
        let cfg = ImportanceConfig {
            start_epoch: 0,
            period: 1,
            percentile: 50.0,
            min_connections: 4,
        };
        let mut scratch = Vec::new();
        // at/below the floor: no threshold
        assert_eq!(
            importance_threshold_from(&[1.0, 2.0], 4, &cfg, &mut scratch),
            None
        );
        // no active neuron: no threshold
        assert_eq!(
            importance_threshold_from(&[0.0, 0.0], 10, &cfg, &mut scratch),
            None
        );
        // zeros are excluded from the percentile population
        assert_eq!(
            importance_threshold_from(&[0.0, 5.0, 1.0, 3.0], 10, &cfg, &mut scratch),
            Some(3.0)
        );
        // in-place variant agrees with the copying one
        let xs = [5.0f32, 1.0, 3.0, 2.0, 4.0];
        let mut ys = xs;
        assert_eq!(percentile_value(&xs, 50.0), percentile_value_mut(&mut ys, 50.0));
    }

    #[test]
    fn schedule_due() {
        let cfg = ImportanceConfig {
            start_epoch: 200,
            period: 40,
            ..Default::default()
        };
        assert!(!cfg.due(199));
        assert!(cfg.due(200));
        assert!(!cfg.due(201));
        assert!(cfg.due(240));
    }

    #[test]
    fn min_connections_floor_holds() {
        let mut rng = Rng::new(1);
        let mut l = SparseLayer::erdos_renyi(
            10,
            10,
            1.0,
            Activation::Relu,
            &WeightInit::Normal(1.0),
            &mut rng,
        );
        let cfg = ImportanceConfig {
            min_connections: usize::MAX,
            ..Default::default()
        };
        assert_eq!(prune_low_importance(&mut l, &cfg), 0);
    }

    #[test]
    fn prune_model_spares_output_layer() {
        let mut rng = Rng::new(2);
        let mut mlp = SparseMlp::new(
            &[30, 40, 40, 5],
            6.0,
            Activation::Relu,
            &WeightInit::Normal(1.0),
            &mut rng,
        )
        .unwrap();
        let out_nnz = mlp.layers[2].weights.nnz();
        let cfg = ImportanceConfig {
            start_epoch: 0,
            period: 1,
            percentile: 25.0,
            min_connections: 0,
        };
        let removed = prune_model(&mut mlp, &cfg);
        assert!(removed > 0);
        assert_eq!(mlp.layers[2].weights.nnz(), out_nnz);
        for l in &mlp.layers {
            l.weights.validate().unwrap();
        }
    }

    #[test]
    fn post_training_sweep_monotone() {
        let mut rng = Rng::new(3);
        let base = SparseMlp::new(
            &[50, 60, 60, 4],
            8.0,
            Activation::Relu,
            &WeightInit::Normal(1.0),
            &mut rng,
        )
        .unwrap();
        let mut prev_remaining = usize::MAX;
        for pct in [5.0, 10.0, 15.0, 20.0, 25.0] {
            let mut m = base.clone();
            let (_, remaining) = prune_post_training(&mut m, pct);
            assert!(remaining <= prev_remaining, "pct {pct}");
            prev_remaining = remaining;
        }
    }
}
