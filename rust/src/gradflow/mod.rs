//! Gradient-flow measurement (paper Fig. 5).
//!
//! Gradient flow is "the first-order approximation of the decrease in the
//! loss expected after a gradient step" — for SGD with rate η the expected
//! decrease is `η·‖∇L‖²`, so we track `‖∇L‖²` (summed over all weight and
//! bias gradients) per evaluation point. Higher is better; the paper uses
//! it to show All-ReLU's advantage over ReLU on sparse models.

use crate::model::{SparseMlp, Workspace};
use crate::util::Rng;

/// One gradient-flow sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GradFlowPoint {
    /// Epoch at which the measurement was taken.
    pub epoch: usize,
    /// Σ‖∇‖² over all parameters, mean across measurement batches.
    pub grad_norm_sq: f64,
    /// Mean loss at measurement time.
    pub loss: f64,
}

/// Measures gradient flow on a fixed probe set at chosen epochs.
#[derive(Debug, Default)]
pub struct GradFlowTracker {
    /// Recorded series.
    pub points: Vec<GradFlowPoint>,
}

impl GradFlowTracker {
    /// Empty tracker.
    pub fn new() -> Self {
        Self::default()
    }

    /// Measure ‖∇L‖² on up to `max_batches` batches of `batch` samples
    /// from the probe set (no parameter update, no dropout) and record it.
    pub fn measure(
        &mut self,
        mlp: &SparseMlp,
        epoch: usize,
        x: &[f32],
        y: &[u32],
        batch: usize,
        max_batches: usize,
        ws: &mut Workspace,
    ) -> GradFlowPoint {
        let n = y.len();
        let n_feat = mlp.sizes[0];
        let mut rng = Rng::new(0); // unused (no dropout), but required by API
        let mut total_g = 0.0f64;
        let mut total_l = 0.0f64;
        let mut batches = 0usize;
        let mut start = 0usize;
        while start < n && batches < max_batches {
            let end = (start + batch).min(n);
            let stats = mlp.compute_gradients(
                &x[start * n_feat..end * n_feat],
                &y[start..end],
                None,
                ws,
                &mut rng,
            );
            total_g += stats.grad_norm_sq as f64;
            total_l += stats.loss as f64;
            batches += 1;
            start = end;
        }
        let point = GradFlowPoint {
            epoch,
            grad_norm_sq: total_g / batches.max(1) as f64,
            loss: total_l / batches.max(1) as f64,
        };
        self.points.push(point);
        point
    }

    /// CSV dump: `epoch,grad_norm_sq,loss` (Fig. 5 series).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("epoch,grad_norm_sq,loss\n");
        for p in &self.points {
            out.push_str(&format!("{},{},{}\n", p.epoch, p.grad_norm_sq, p.loss));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Activation;
    use crate::sparse::WeightInit;

    fn setup() -> (SparseMlp, Vec<f32>, Vec<u32>) {
        let mut rng = Rng::new(1);
        let mlp = SparseMlp::new(
            &[8, 16, 4],
            4.0,
            Activation::AllRelu { alpha: 0.6 },
            &WeightInit::HeUniform,
            &mut rng,
        )
        .unwrap();
        let x: Vec<f32> = (0..40 * 8).map(|_| rng.normal()).collect();
        let y: Vec<u32> = (0..40).map(|i| (i % 4) as u32).collect();
        (mlp, x, y)
    }

    #[test]
    fn measure_records_points() {
        let (mlp, x, y) = setup();
        let mut ws = mlp.alloc_workspace(16);
        let mut t = GradFlowTracker::new();
        let p = t.measure(&mlp, 0, &x, &y, 16, 2, &mut ws);
        assert!(p.grad_norm_sq > 0.0);
        assert!(p.loss > 0.0);
        assert_eq!(t.points.len(), 1);
    }

    #[test]
    fn measurement_is_deterministic_and_side_effect_free() {
        let (mlp, x, y) = setup();
        let mut ws = mlp.alloc_workspace(16);
        let before = mlp.layers[0].weights.values.clone();
        let mut t = GradFlowTracker::new();
        let p1 = t.measure(&mlp, 0, &x, &y, 16, 3, &mut ws);
        let p2 = t.measure(&mlp, 1, &x, &y, 16, 3, &mut ws);
        assert_eq!(p1.grad_norm_sq, p2.grad_norm_sq);
        assert_eq!(mlp.layers[0].weights.values, before);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let (mlp, x, y) = setup();
        let mut ws = mlp.alloc_workspace(16);
        let mut t = GradFlowTracker::new();
        t.measure(&mlp, 5, &x, &y, 16, 1, &mut ws);
        let csv = t.to_csv();
        assert!(csv.starts_with("epoch,grad_norm_sq,loss\n"));
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.lines().nth(1).unwrap().starts_with("5,"));
    }
}
