//! Neural-network building blocks for the truly-sparse engine:
//! activations (including the paper's All-ReLU), losses, momentum SGD,
//! dropout and metrics.

pub mod activations;
pub mod dropout;
pub mod loss;
pub mod metrics;
pub mod optimizer;

pub use activations::{Activation, SRelu};
pub use dropout::Dropout;
pub use loss::{accuracy, mse, softmax_cross_entropy};
pub use metrics::{ConfusionMatrix, Stats};
pub use optimizer::{remap_aligned, LrSchedule, MomentumSgd};
