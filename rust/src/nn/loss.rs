//! Loss functions: softmax cross-entropy (classification) and MSE.
//!
//! Both return the mean loss over the batch and write `d loss / d logits`
//! into a caller-provided buffer (the backward entry point of the MLP).

/// Numerically-stable softmax over one row, in place.
pub fn softmax_row(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// Mean softmax cross-entropy with integer labels.
///
/// `logits: [batch, n_classes]` (row-major), `labels: [batch]`.
/// Writes `dlogits = (softmax - onehot) / batch` and returns the loss.
pub fn softmax_cross_entropy(
    logits: &[f32],
    labels: &[u32],
    n_classes: usize,
    dlogits: &mut [f32],
) -> f32 {
    let batch = labels.len();
    debug_assert_eq!(logits.len(), batch * n_classes);
    debug_assert_eq!(dlogits.len(), logits.len());
    let inv_b = 1.0 / batch as f32;
    let mut loss = 0.0f32;
    for b in 0..batch {
        let row = &logits[b * n_classes..(b + 1) * n_classes];
        let drow = &mut dlogits[b * n_classes..(b + 1) * n_classes];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for (d, &v) in drow.iter_mut().zip(row.iter()) {
            *d = (v - max).exp();
            sum += *d;
        }
        let log_sum = sum.ln() + max;
        let y = labels[b] as usize;
        debug_assert!(y < n_classes);
        loss += log_sum - row[y];
        let inv = 1.0 / sum;
        for d in drow.iter_mut() {
            *d *= inv * inv_b;
        }
        drow[y] -= inv_b;
    }
    loss * inv_b
}

/// Mean squared error over a [batch, n] prediction; writes
/// `dpred = 2 (pred - target) / (batch * n)`.
pub fn mse(pred: &[f32], target: &[f32], batch: usize, dpred: &mut [f32]) -> f32 {
    debug_assert_eq!(pred.len(), target.len());
    debug_assert_eq!(pred.len(), dpred.len());
    let n = pred.len();
    let scale = 2.0 / n as f32;
    let _ = batch;
    let mut loss = 0.0f32;
    for ((d, &p), &t) in dpred.iter_mut().zip(pred.iter()).zip(target.iter()) {
        let diff = p - t;
        loss += diff * diff;
        *d = scale * diff;
    }
    loss / n as f32
}

/// Batch classification accuracy from logits.
pub fn accuracy(logits: &[f32], labels: &[u32], n_classes: usize) -> f32 {
    let batch = labels.len();
    if batch == 0 {
        return 0.0;
    }
    let mut correct = 0usize;
    for b in 0..batch {
        let row = &logits[b * n_classes..(b + 1) * n_classes];
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        if best as u32 == labels[b] {
            correct += 1;
        }
    }
    correct as f32 / batch as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_normalises() {
        let mut row = vec![1.0, 2.0, 3.0];
        softmax_row(&mut row);
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(row[2] > row[1] && row[1] > row[0]);
    }

    #[test]
    fn softmax_handles_large_logits() {
        let mut row = vec![1e4, -1e4];
        softmax_row(&mut row);
        assert!(row[0].is_finite() && (row[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cross_entropy_uniform_is_log_n() {
        let logits = vec![0.0f32; 4 * 10];
        let labels = vec![0u32, 3, 7, 9];
        let mut d = vec![0.0f32; 40];
        let loss = softmax_cross_entropy(&logits, &labels, 10, &mut d);
        assert!((loss - (10f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn cross_entropy_confident_is_zero() {
        let logits = vec![100.0, 0.0, 0.0, 100.0];
        let labels = vec![0u32, 1];
        let mut d = vec![0.0f32; 4];
        let loss = softmax_cross_entropy(&logits, &labels, 2, &mut d);
        assert!(loss < 1e-6);
    }

    #[test]
    fn cross_entropy_gradient_matches_finite_difference() {
        let logits = vec![0.3f32, -0.7, 1.1, 0.2, 0.9, -0.1];
        let labels = vec![2u32, 0];
        let mut d = vec![0.0f32; 6];
        let loss0 = softmax_cross_entropy(&logits, &labels, 3, &mut d);
        let _ = loss0;
        let eps = 1e-3f32;
        for k in 0..6 {
            let mut lp = logits.clone();
            lp[k] += eps;
            let mut lm = logits.clone();
            lm[k] -= eps;
            let mut scratch = vec![0.0f32; 6];
            let fp = softmax_cross_entropy(&lp, &labels, 3, &mut scratch);
            let fm = softmax_cross_entropy(&lm, &labels, 3, &mut scratch);
            let fd = (fp - fm) / (2.0 * eps);
            assert!((d[k] - fd).abs() < 1e-3, "k={k}: {} vs {fd}", d[k]);
        }
    }

    #[test]
    fn gradient_rows_sum_to_zero() {
        // softmax - onehot sums to zero per row
        let logits = vec![0.5f32, 1.5, -0.5, 2.0, 0.0, 1.0];
        let labels = vec![1u32, 2];
        let mut d = vec![0.0f32; 6];
        softmax_cross_entropy(&logits, &labels, 3, &mut d);
        for b in 0..2 {
            let s: f32 = d[b * 3..(b + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn mse_and_gradient() {
        let pred = vec![1.0f32, 2.0];
        let target = vec![0.0f32, 0.0];
        let mut d = vec![0.0f32; 2];
        let loss = mse(&pred, &target, 1, &mut d);
        assert!((loss - 2.5).abs() < 1e-6); // (1+4)/2
        assert!((d[0] - 1.0).abs() < 1e-6); // 2*1/2
        assert!((d[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn accuracy_counts_argmax() {
        let logits = vec![
            0.1, 0.9, // -> 1
            0.8, 0.2, // -> 0
            0.4, 0.6, // -> 1
        ];
        assert!((accuracy(&logits, &[1, 0, 0], 2) - 2.0 / 3.0).abs() < 1e-6);
        assert_eq!(accuracy(&logits, &[], 2), 0.0);
    }
}
