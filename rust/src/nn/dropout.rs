//! Inverted dropout on hidden activations (paper: rate 0.3 / 0.4).

use crate::util::Rng;

/// Inverted-dropout mask generator/applier.
#[derive(Debug, Clone, Copy)]
pub struct Dropout {
    /// Probability of *dropping* a unit.
    pub rate: f32,
}

impl Dropout {
    /// New dropout with the given drop probability (0 disables).
    pub fn new(rate: f32) -> Self {
        assert!((0.0..1.0).contains(&rate), "dropout rate must be in [0,1)");
        Dropout { rate }
    }

    /// Apply in place during training, recording the kept-scale mask into
    /// `mask` (1/(1-rate) for kept units, 0 for dropped) for backward.
    pub fn apply(&self, h: &mut [f32], mask: &mut Vec<f32>, rng: &mut Rng) {
        mask.clear();
        if self.rate == 0.0 {
            return; // empty mask signals identity to backward()
        }
        let keep_scale = 1.0 / (1.0 - self.rate);
        mask.reserve(h.len());
        for v in h.iter_mut() {
            if rng.bernoulli(self.rate as f64) {
                *v = 0.0;
                mask.push(0.0);
            } else {
                *v *= keep_scale;
                mask.push(keep_scale);
            }
        }
    }

    /// Backward: multiply dz by the recorded mask.
    pub fn backward(&self, dz: &mut [f32], mask: &[f32]) {
        if mask.is_empty() {
            return;
        }
        debug_assert_eq!(dz.len(), mask.len());
        for (d, &m) in dz.iter_mut().zip(mask.iter()) {
            *d *= m;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_is_identity() {
        let d = Dropout::new(0.0);
        let mut h = vec![1.0, 2.0, 3.0];
        let mut mask = Vec::new();
        d.apply(&mut h, &mut mask, &mut Rng::new(1));
        assert_eq!(h, vec![1.0, 2.0, 3.0]);
        assert!(mask.is_empty());
    }

    #[test]
    fn expectation_preserved() {
        let d = Dropout::new(0.4);
        let mut rng = Rng::new(2);
        let n = 100_000;
        let mut h = vec![1.0f32; n];
        let mut mask = Vec::new();
        d.apply(&mut h, &mut mask, &mut rng);
        let mean: f32 = h.iter().sum::<f32>() / n as f32;
        assert!((mean - 1.0).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn backward_uses_same_mask() {
        let d = Dropout::new(0.5);
        let mut rng = Rng::new(3);
        let mut h = vec![1.0f32; 64];
        let mut mask = Vec::new();
        d.apply(&mut h, &mut mask, &mut rng);
        let mut dz = vec![1.0f32; 64];
        d.backward(&mut dz, &mask);
        // gradient must be zero exactly where activation was dropped
        for (hv, dv) in h.iter().zip(dz.iter()) {
            assert_eq!(*hv == 0.0, *dv == 0.0);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_rate_one() {
        Dropout::new(1.0);
    }
}
