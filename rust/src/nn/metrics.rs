//! Evaluation metrics and running statistics.

/// Running mean/min/max accumulator for scalar streams (loss curves etc.).
#[derive(Debug, Clone, Default)]
pub struct Stats {
    n: usize,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Stats {
    /// Empty accumulator.
    pub fn new() -> Self {
        Stats {
            n: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Add a sample.
    pub fn push(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        self.sum_sq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.n
    }

    /// Mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let m = self.mean();
        (self.sum_sq / self.n as f64 - m * m).max(0.0).sqrt()
    }

    /// Minimum sample (inf if empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum sample (-inf if empty).
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Confusion matrix for k-class classification.
#[derive(Debug, Clone)]
pub struct ConfusionMatrix {
    k: usize,
    counts: Vec<usize>,
}

impl ConfusionMatrix {
    /// k-class confusion matrix (rows = true, cols = predicted).
    pub fn new(k: usize) -> Self {
        ConfusionMatrix {
            k,
            counts: vec![0; k * k],
        }
    }

    /// Record one (true, predicted) pair.
    pub fn record(&mut self, truth: usize, pred: usize) {
        debug_assert!(truth < self.k && pred < self.k);
        self.counts[truth * self.k + pred] += 1;
    }

    /// Total samples recorded.
    pub fn total(&self) -> usize {
        self.counts.iter().sum()
    }

    /// Overall accuracy.
    pub fn accuracy(&self) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        let correct: usize = (0..self.k).map(|i| self.counts[i * self.k + i]).sum();
        correct as f64 / total as f64
    }

    /// Per-class recall.
    pub fn recall(&self, class: usize) -> f64 {
        let row: usize = self.counts[class * self.k..(class + 1) * self.k].iter().sum();
        if row == 0 {
            0.0
        } else {
            self.counts[class * self.k + class] as f64 / row as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_moments() {
        let mut s = Stats::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.push(v);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn stats_empty() {
        let s = Stats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn confusion_accuracy_and_recall() {
        let mut c = ConfusionMatrix::new(2);
        c.record(0, 0);
        c.record(0, 1);
        c.record(1, 1);
        c.record(1, 1);
        assert_eq!(c.total(), 4);
        assert!((c.accuracy() - 0.75).abs() < 1e-12);
        assert!((c.recall(0) - 0.5).abs() < 1e-12);
        assert!((c.recall(1) - 1.0).abs() < 1e-12);
    }
}
