//! Momentum SGD with weight decay — the paper's update rule (Eq. 1).
//!
//! `v ← μ·v − η·(g + λ·w)` ; `w ← w + v`. This classical-momentum form
//! is algebraically the paper's `W_{t+1} = W_t + μ(W_t − W_{t−1}) − η∇W_t`
//! with `v_t = W_t − W_{t−1}`. Velocities are plain `Vec<f32>` aligned to
//! the CSR `values` array; topology evolution remaps them via the
//! old→new index maps the structural ops return.

/// Hyperparameters of the sparse momentum-SGD update.
#[derive(Debug, Clone, Copy)]
pub struct MomentumSgd {
    /// Momentum coefficient μ (paper: 0.9).
    pub momentum: f32,
    /// L2 weight decay λ.
    pub weight_decay: f32,
}

impl Default for MomentumSgd {
    fn default() -> Self {
        MomentumSgd {
            momentum: 0.9,
            weight_decay: 0.0002,
        }
    }
}

impl MomentumSgd {
    /// Update weights in place given aligned gradients and velocities.
    pub fn update(&self, weights: &mut [f32], grads: &[f32], velocity: &mut [f32], lr: f32) {
        debug_assert_eq!(weights.len(), grads.len());
        debug_assert_eq!(weights.len(), velocity.len());
        let (mu, wd) = (self.momentum, self.weight_decay);
        for ((w, &g), v) in weights.iter_mut().zip(grads.iter()).zip(velocity.iter_mut()) {
            *v = mu * *v - lr * (g + wd * *w);
            *w += *v;
        }
    }

    /// Bias update (no weight decay on biases, standard practice).
    pub fn update_bias(&self, bias: &mut [f32], grads: &[f32], velocity: &mut [f32], lr: f32) {
        debug_assert_eq!(bias.len(), grads.len());
        debug_assert_eq!(bias.len(), velocity.len());
        let mu = self.momentum;
        for ((b, &g), v) in bias.iter_mut().zip(grads.iter()).zip(velocity.iter_mut()) {
            *v = mu * *v - lr * g;
            *b += *v;
        }
    }
}

/// Remap an aligned state vector (e.g. velocity) through a structure
/// change described by `old_index_of_new[k] = Some(old)` for survivors and
/// `None` for newly-created entries (which get `fill`).
pub fn remap_aligned(state: &[f32], old_index_of_new: &[Option<usize>], fill: f32) -> Vec<f32> {
    old_index_of_new
        .iter()
        .map(|o| o.map(|k| state[k]).unwrap_or(fill))
        .collect()
}

/// Learning-rate schedules used by the experiments.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    /// Constant η.
    Constant(f32),
    /// Linear warmup from `base/k` to `base·k_scale` over `warmup` epochs,
    /// then constant — Goyal et al.'s gradual-warmup + linear-scaling rule,
    /// used by WASSP-SGD.
    Warmup {
        base: f32,
        scale: f32,
        warmup_epochs: usize,
    },
    /// Large initial rate for `hot_epochs`, then constant base rate —
    /// what the paper found effective for WASAP-SGD phase 1.
    HotStart {
        hot: f32,
        base: f32,
        hot_epochs: usize,
    },
}

impl LrSchedule {
    /// Learning rate at the given epoch.
    pub fn at(&self, epoch: usize) -> f32 {
        match *self {
            LrSchedule::Constant(eta) => eta,
            LrSchedule::Warmup {
                base,
                scale,
                warmup_epochs,
            } => {
                let target = base * scale;
                if warmup_epochs == 0 || epoch >= warmup_epochs {
                    target
                } else {
                    base + (target - base) * (epoch as f32 / warmup_epochs as f32)
                }
            }
            LrSchedule::HotStart {
                hot,
                base,
                hot_epochs,
            } => {
                if epoch < hot_epochs {
                    hot
                } else {
                    base
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_step_matches_formula() {
        let opt = MomentumSgd {
            momentum: 0.9,
            weight_decay: 0.0,
        };
        let mut w = vec![1.0f32];
        let mut v = vec![0.5f32];
        opt.update(&mut w, &[2.0], &mut v, 0.1);
        // v = 0.9*0.5 - 0.1*2 = 0.25 ; w = 1.25
        assert!((v[0] - 0.25).abs() < 1e-6);
        assert!((w[0] - 1.25).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let opt = MomentumSgd {
            momentum: 0.0,
            weight_decay: 0.1,
        };
        let mut w = vec![1.0f32];
        let mut v = vec![0.0f32];
        opt.update(&mut w, &[0.0], &mut v, 1.0);
        assert!((w[0] - 0.9).abs() < 1e-6);
    }

    #[test]
    fn equivalent_to_paper_eq1() {
        // W_{t+1} = W_t + mu (W_t - W_{t-1}) - eta g  with v_t = W_t - W_{t-1}
        let opt = MomentumSgd {
            momentum: 0.7,
            weight_decay: 0.0,
        };
        let mut w = vec![2.0f32];
        let mut v = vec![0.0f32];
        let gs = [0.3f32, -0.2, 0.8, 0.1];
        let (mut w_prev, mut w_ref) = (2.0f32, 2.0f32);
        for &g in &gs {
            opt.update(&mut w, &[g], &mut v, 0.05);
            let next = w_ref + 0.7 * (w_ref - w_prev) - 0.05 * g;
            w_prev = w_ref;
            w_ref = next;
            assert!((w[0] - w_ref).abs() < 1e-5);
        }
    }

    #[test]
    fn bias_update_has_no_decay() {
        let opt = MomentumSgd {
            momentum: 0.0,
            weight_decay: 0.5,
        };
        let mut b = vec![1.0f32];
        let mut v = vec![0.0f32];
        opt.update_bias(&mut b, &[0.0], &mut v, 1.0);
        assert_eq!(b[0], 1.0);
    }

    #[test]
    fn remap_keeps_survivors_zeroes_new() {
        let state = vec![1.0, 2.0, 3.0];
        let map = vec![Some(2), None, Some(0)];
        assert_eq!(remap_aligned(&state, &map, 0.0), vec![3.0, 0.0, 1.0]);
    }

    #[test]
    fn schedules() {
        assert_eq!(LrSchedule::Constant(0.01).at(100), 0.01);
        let w = LrSchedule::Warmup {
            base: 0.01,
            scale: 5.0,
            warmup_epochs: 10,
        };
        assert!((w.at(0) - 0.01).abs() < 1e-7);
        assert!((w.at(10) - 0.05).abs() < 1e-7);
        assert!(w.at(5) > 0.01 && w.at(5) < 0.05);
        let h = LrSchedule::HotStart {
            hot: 0.05,
            base: 0.01,
            hot_epochs: 3,
        };
        assert_eq!(h.at(2), 0.05);
        assert_eq!(h.at(3), 0.01);
    }
}
