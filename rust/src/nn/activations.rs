//! Activation functions for sparse MLPs.
//!
//! Includes the paper's contribution **All-ReLU** (Eq. 3): a Leaky-ReLU
//! variant whose negative-side slope *sign alternates with hidden-layer
//! parity*, breaking symmetry and preserving gradient flow without
//! SReLU's four trainable parameters per neuron. SReLU itself is
//! implemented (with trainable per-neuron parameters) as the comparator
//! the paper benchmarks against.

/// Parameter-free / fixed-parameter activations, applied element-wise.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Activation {
    /// max(0, x).
    Relu,
    /// x>0 ? x : alpha*x.
    LeakyRelu { alpha: f32 },
    /// Paper Eq. 3. `layer_index` is the 1-based hidden layer index;
    /// even layers use slope -alpha, odd layers +alpha on the negative side.
    AllRelu { alpha: f32 },
    /// Identity (output layers).
    Linear,
}

impl Activation {
    /// Parse from a config string ("relu", "lrelu:0.1", "allrelu:0.6").
    pub fn parse(s: &str) -> Option<Activation> {
        let (name, arg) = match s.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (s, None),
        };
        let alpha = |d: f32| arg.and_then(|a| a.parse().ok()).unwrap_or(d);
        match name {
            "relu" => Some(Activation::Relu),
            "lrelu" | "leaky_relu" => Some(Activation::LeakyRelu { alpha: alpha(0.01) }),
            "allrelu" | "all_relu" => Some(Activation::AllRelu { alpha: alpha(0.6) }),
            "linear" | "none" => Some(Activation::Linear),
            _ => None,
        }
    }

    /// Apply out of place, `out[k] = f(z[k])` — the pre-activation buffer
    /// `z` stays intact for backprop, so the forward pass needs no
    /// pre-activation copy (the old in-place form forced
    /// `copy_from_slice` before every activation). `layer_index` is the
    /// 1-based layer number (used by All-ReLU parity; ignored by the
    /// others).
    pub fn apply(&self, z: &[f32], out: &mut [f32], layer_index: usize) {
        debug_assert_eq!(z.len(), out.len());
        match *self {
            Activation::Relu => {
                for (o, &v) in out.iter_mut().zip(z.iter()) {
                    *o = if v < 0.0 { 0.0 } else { v };
                }
            }
            Activation::LeakyRelu { alpha } => {
                for (o, &v) in out.iter_mut().zip(z.iter()) {
                    *o = if v < 0.0 { v * alpha } else { v };
                }
            }
            Activation::AllRelu { alpha } => {
                let slope = if layer_index % 2 == 0 { -alpha } else { alpha };
                for (o, &v) in out.iter_mut().zip(z.iter()) {
                    *o = if v <= 0.0 { v * slope } else { v };
                }
            }
            Activation::Linear => out.copy_from_slice(z),
        }
    }

    /// Derivative w.r.t. pre-activation, given the **pre-activation** `z`,
    /// multiplied into `dz` in place (dz *= f'(z)).
    pub fn backprop(&self, z: &[f32], dz: &mut [f32], layer_index: usize) {
        debug_assert_eq!(z.len(), dz.len());
        match *self {
            Activation::Relu => {
                for (d, &v) in dz.iter_mut().zip(z.iter()) {
                    if v <= 0.0 {
                        *d = 0.0;
                    }
                }
            }
            Activation::LeakyRelu { alpha } => {
                for (d, &v) in dz.iter_mut().zip(z.iter()) {
                    if v <= 0.0 {
                        *d *= alpha;
                    }
                }
            }
            Activation::AllRelu { alpha } => {
                let slope = if layer_index % 2 == 0 { -alpha } else { alpha };
                for (d, &v) in dz.iter_mut().zip(z.iter()) {
                    if v <= 0.0 {
                        *d *= slope;
                    }
                }
            }
            Activation::Linear => {}
        }
    }
}

/// SReLU (Jin et al. 2016) with trainable per-neuron parameters
/// `(t_l, a_l, t_r, a_r)` — the comparator All-ReLU replaces. Carries
/// 4·n_out trainable parameters, which is exactly the overhead the paper
/// eliminates.
#[derive(Debug, Clone)]
pub struct SRelu {
    /// Left threshold per neuron.
    pub tl: Vec<f32>,
    /// Left slope per neuron.
    pub al: Vec<f32>,
    /// Right threshold per neuron.
    pub tr: Vec<f32>,
    /// Right slope per neuron.
    pub ar: Vec<f32>,
}

impl SRelu {
    /// Standard initialisation: identity in [0, 1], slopes 0.2 outside —
    /// mirrors the SET reference implementation.
    pub fn new(n: usize) -> Self {
        SRelu {
            tl: vec![0.0; n],
            al: vec![0.2; n],
            tr: vec![1.0; n],
            ar: vec![0.2; n],
        }
    }

    /// Trainable parameter count (the overhead All-ReLU removes).
    pub fn param_count(&self) -> usize {
        4 * self.tl.len()
    }

    /// Forward out of place over a [batch, n] buffer: `out[k] = f(z[k])`
    /// (pre-activations stay intact for backprop — no copy needed in the
    /// forward pass).
    pub fn apply(&self, z: &[f32], out: &mut [f32], n: usize) {
        debug_assert_eq!(z.len(), out.len());
        for (k, (o, &v)) in out.iter_mut().zip(z.iter()).enumerate() {
            let j = k % n;
            *o = if v <= self.tl[j] {
                self.tl[j] + self.al[j] * (v - self.tl[j])
            } else if v >= self.tr[j] {
                self.tr[j] + self.ar[j] * (v - self.tr[j])
            } else {
                v
            };
        }
    }

    /// Backward: scales dz in place and accumulates parameter grads.
    /// Returns (d_tl, d_al, d_tr, d_ar).
    pub fn backprop(
        &self,
        z: &[f32],
        dz: &mut [f32],
        n: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut dtl = vec![0.0f32; n];
        let mut dal = vec![0.0f32; n];
        let mut dtr = vec![0.0f32; n];
        let mut dar = vec![0.0f32; n];
        for (k, d) in dz.iter_mut().enumerate() {
            let j = k % n;
            let v = z[k];
            if v <= self.tl[j] {
                dtl[j] += *d * (1.0 - self.al[j]);
                dal[j] += *d * (v - self.tl[j]);
                *d *= self.al[j];
            } else if v >= self.tr[j] {
                dtr[j] += *d * (1.0 - self.ar[j]);
                dar[j] += *d * (v - self.tr[j]);
                *d *= self.ar[j];
            }
        }
        (dtl, dal, dtr, dar)
    }

    /// SGD step on the four parameter vectors.
    pub fn update(&mut self, grads: &(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>), lr: f32) {
        for (p, g) in self.tl.iter_mut().zip(grads.0.iter()) {
            *p -= lr * g;
        }
        for (p, g) in self.al.iter_mut().zip(grads.1.iter()) {
            *p -= lr * g;
        }
        for (p, g) in self.tr.iter_mut().zip(grads.2.iter()) {
            *p -= lr * g;
        }
        for (p, g) in self.ar.iter_mut().zip(grads.3.iter()) {
            *p -= lr * g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Out-of-place apply into a fresh buffer (test convenience).
    fn applied(act: Activation, z: &[f32], layer: usize) -> Vec<f32> {
        let mut out = vec![f32::NAN; z.len()];
        act.apply(z, &mut out, layer);
        out
    }

    #[test]
    fn relu_clamps_negative() {
        let z = vec![-1.0, 0.0, 2.0];
        assert_eq!(applied(Activation::Relu, &z, 1), vec![0.0, 0.0, 2.0]);
        // pre-activations untouched by the out-of-place form
        assert_eq!(z, vec![-1.0, 0.0, 2.0]);
    }

    #[test]
    fn allrelu_parity_flips_sign() {
        // paper Eq.3: even layer -> -alpha * x on negative side
        let a = Activation::AllRelu { alpha: 0.5 };
        assert_eq!(applied(a, &[-2.0, 1.0], 2), vec![1.0, 1.0]);
        assert_eq!(applied(a, &[-2.0, 1.0], 1), vec![-1.0, 1.0]);
    }

    #[test]
    fn allrelu_matches_python_ref_semantics() {
        // mirror python ref: parity = layer % 2; even->-alpha, odd->+alpha
        let z = [-2.0f32, -1.0, 0.0, 1.0];
        let a = Activation::AllRelu { alpha: 0.5 };
        assert_eq!(applied(a, &z, 0), vec![1.0, 0.5, 0.0, 1.0]);
        assert_eq!(applied(a, &z, 1), vec![-1.0, -0.5, 0.0, 1.0]);
    }

    #[test]
    fn backprop_gradients_match_finite_difference() {
        let acts = [
            Activation::Relu,
            Activation::LeakyRelu { alpha: 0.1 },
            Activation::AllRelu { alpha: 0.6 },
            Activation::Linear,
        ];
        let zs = [-1.5f32, -0.1, 0.3, 2.0];
        for act in acts {
            for layer in 1..=2 {
                for &z0 in &zs {
                    let eps = 1e-3f32;
                    let zp = applied(act, &[z0 + eps], layer);
                    let zm = applied(act, &[z0 - eps], layer);
                    let fd = (zp[0] - zm[0]) / (2.0 * eps);
                    let mut d = vec![1.0f32];
                    act.backprop(&[z0], &mut d, layer);
                    assert!(
                        (d[0] - fd).abs() < 1e-2,
                        "{act:?} layer {layer} z {z0}: {} vs fd {fd}",
                        d[0]
                    );
                }
            }
        }
    }

    #[test]
    fn parse_strings() {
        assert_eq!(Activation::parse("relu"), Some(Activation::Relu));
        assert_eq!(
            Activation::parse("allrelu:0.75"),
            Some(Activation::AllRelu { alpha: 0.75 })
        );
        assert_eq!(
            Activation::parse("lrelu"),
            Some(Activation::LeakyRelu { alpha: 0.01 })
        );
        assert_eq!(Activation::parse("garbage"), None);
    }

    #[test]
    fn srelu_identity_region() {
        let s = SRelu::new(2);
        let z = vec![0.5, 0.9, 0.1, 0.2];
        let mut out = vec![f32::NAN; 4];
        s.apply(&z, &mut out, 2);
        assert_eq!(out, z);
    }

    #[test]
    fn srelu_saturates_and_backprops() {
        let s = SRelu::new(1);
        let z = vec![-2.0f32, 3.0];
        let mut out = vec![f32::NAN; 2];
        s.apply(&z, &mut out, 1);
        // left: 0 + 0.2*(-2-0) = -0.4 ; right: 1 + 0.2*(3-1) = 1.4
        assert!((out[0] + 0.4).abs() < 1e-6);
        assert!((out[1] - 1.4).abs() < 1e-6);
        let mut dz = vec![1.0f32, 1.0];
        let grads = s.backprop(&[-2.0, 3.0], &mut dz, 1);
        assert!((dz[0] - 0.2).abs() < 1e-6);
        assert!((dz[1] - 0.2).abs() < 1e-6);
        assert!((grads.1[0] - (-2.0)).abs() < 1e-6); // dal = z - tl
    }

    #[test]
    fn srelu_param_count_is_4n() {
        assert_eq!(SRelu::new(100).param_count(), 400);
    }

    #[test]
    fn srelu_update_moves_params() {
        let mut s = SRelu::new(1);
        let g = (vec![1.0], vec![1.0], vec![1.0], vec![1.0]);
        s.update(&g, 0.1);
        assert!((s.tl[0] + 0.1).abs() < 1e-6);
        assert!((s.al[0] - 0.1).abs() < 1e-6);
    }
}
