//! Masked-dense baseline trainer — the paper's "Keras" comparator.
//!
//! Trains the SAME sparse topology as the truly-sparse engine, but the
//! way mainstream frameworks do it: dense weight matrices with a binary
//! mask, executed by XLA (the L2 artifacts, which embed the L1 Pallas
//! kernel where configured). Every step ships the full dense state
//! through the executable — exactly the overhead the paper's truly-sparse
//! engine avoids, which is what Tables 2–3 quantify.
//!
//! SET topology evolution still happens between steps: masks are runtime
//! *inputs* to the executable, so the Rust side prunes/regrows the dense
//! mask without recompiling.

use crate::data::Dataset;
use crate::error::{Result, TsnnError};
use crate::nn;
use crate::set::{prune_thresholds, sample_gap_ordinals};
use crate::util::{Rng, Timer};

use super::engine::{literal_f32, literal_i32, literal_scalar, to_scalar_f32, to_vec_f32, HloExecutable};
use super::manifest::ArchEntry;

/// Dense per-layer state for the masked baseline.
#[derive(Debug, Clone)]
pub struct MaskedLayer {
    /// Dense weights `[n_in, n_out]` (zeros outside mask).
    pub w: Vec<f32>,
    /// Bias `[n_out]`.
    pub b: Vec<f32>,
    /// Weight velocity.
    pub vw: Vec<f32>,
    /// Bias velocity.
    pub vb: Vec<f32>,
    /// Binary mask `[n_in, n_out]`.
    pub m: Vec<f32>,
    n_in: usize,
    n_out: usize,
}

impl MaskedLayer {
    /// Active (masked-in) connection count.
    pub fn nnz(&self) -> usize {
        self.m.iter().filter(|&&v| v != 0.0).count()
    }
}

/// Masked-dense trainer over AOT executables.
pub struct MaskedDenseTrainer {
    arch: ArchEntry,
    train_exe: HloExecutable,
    fwd_exe: HloExecutable,
    /// Per-layer dense state.
    pub layers: Vec<MaskedLayer>,
}

/// One masked-dense epoch report.
#[derive(Debug, Clone, Copy)]
pub struct MaskedEpoch {
    /// Mean train loss.
    pub loss: f32,
    /// Mean train accuracy.
    pub accuracy: f32,
    /// Seconds for the epoch.
    pub seconds: f64,
}

impl MaskedDenseTrainer {
    /// Load executables and Erdős–Rényi-initialise masked-dense state
    /// with the same ε/type of init the truly-sparse engine uses.
    pub fn new(arch: &ArchEntry, epsilon: f64, rng: &mut Rng) -> Result<Self> {
        let train_exe = HloExecutable::load(&arch.train_hlo)?;
        let fwd_exe = HloExecutable::load(&arch.forward_hlo)?;
        let mut layers = Vec::with_capacity(arch.n_layers());
        for l in 0..arch.n_layers() {
            let (ni, no) = (arch.sizes[l], arch.sizes[l + 1]);
            let density = crate::sparse::epsilon_density(epsilon, ni, no);
            let lim = (6.0f32 / ni as f32).sqrt();
            let mut w = vec![0.0f32; ni * no];
            let mut m = vec![0.0f32; ni * no];
            for k in 0..ni * no {
                if rng.bernoulli(density) {
                    m[k] = 1.0;
                    w[k] = rng.uniform(-lim, lim);
                }
            }
            layers.push(MaskedLayer {
                vw: vec![0.0; w.len()],
                vb: vec![0.0; no],
                b: vec![0.0; no],
                w,
                m,
                n_in: ni,
                n_out: no,
            });
        }
        Ok(MaskedDenseTrainer {
            arch: arch.clone(),
            train_exe,
            fwd_exe,
            layers,
        })
    }

    /// Active connections across layers.
    pub fn nnz(&self) -> usize {
        self.layers.iter().map(|l| l.nnz()).sum()
    }

    /// Dense parameter storage in bytes (w + vw + m + b + vb) — the
    /// masked-dense memory footprint Table 3 contrasts with CSR.
    pub fn memory_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| 4 * (3 * l.w.len() + 2 * l.b.len()))
            .sum()
    }

    fn train_inputs(
        &self,
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<Vec<xla::Literal>> {
        let batch = self.arch.batch;
        let nf = self.arch.sizes[0];
        let mut inputs = Vec::with_capacity(3 + 5 * self.layers.len());
        inputs.push(literal_f32(x, &[batch as i64, nf as i64])?);
        inputs.push(literal_i32(y, &[batch as i64])?);
        inputs.push(literal_scalar(lr));
        for l in &self.layers {
            let dims = [l.n_in as i64, l.n_out as i64];
            inputs.push(literal_f32(&l.w, &dims)?);
            inputs.push(literal_f32(&l.b, &[l.n_out as i64])?);
            inputs.push(literal_f32(&l.vw, &dims)?);
            inputs.push(literal_f32(&l.vb, &[l.n_out as i64])?);
            inputs.push(literal_f32(&l.m, &dims)?);
        }
        Ok(inputs)
    }

    /// One train step on a full batch (must equal the baked batch size).
    /// Updates the dense state in place; returns (loss, acc).
    pub fn step(&mut self, x: &[f32], y: &[i32], lr: f32) -> Result<(f32, f32)> {
        let inputs = self.train_inputs(x, y, lr)?;
        let out = self.train_exe.run(&inputs)?;
        if out.len() != 2 + 4 * self.layers.len() {
            return Err(TsnnError::Runtime(format!(
                "train step returned {} outputs, expected {}",
                out.len(),
                2 + 4 * self.layers.len()
            )));
        }
        let loss = to_scalar_f32(&out[0])?;
        let acc = to_scalar_f32(&out[1])?;
        for (i, l) in self.layers.iter_mut().enumerate() {
            l.w = to_vec_f32(&out[2 + 4 * i])?;
            l.b = to_vec_f32(&out[2 + 4 * i + 1])?;
            l.vw = to_vec_f32(&out[2 + 4 * i + 2])?;
            l.vb = to_vec_f32(&out[2 + 4 * i + 3])?;
        }
        Ok((loss, acc))
    }

    /// One epoch over the dataset (drops the ragged tail batch, as Keras
    /// `drop_remainder` does). Returns the epoch report.
    pub fn train_epoch(&mut self, data: &Dataset, lr: f32, rng: &mut Rng) -> Result<MaskedEpoch> {
        let timer = Timer::start();
        let batch = self.arch.batch;
        let nf = data.n_features;
        let n = data.n_train();
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut xbuf = vec![0.0f32; batch * nf];
        let mut ybuf = vec![0i32; batch];
        let (mut loss_sum, mut acc_sum, mut steps) = (0.0f64, 0.0f64, 0usize);
        for chunk in order.chunks_exact(batch) {
            for (k, &s) in chunk.iter().enumerate() {
                xbuf[k * nf..(k + 1) * nf].copy_from_slice(&data.x_train[s * nf..(s + 1) * nf]);
                ybuf[k] = data.y_train[s] as i32;
            }
            let (loss, acc) = self.step(&xbuf, &ybuf, lr)?;
            loss_sum += loss as f64;
            acc_sum += acc as f64;
            steps += 1;
        }
        Ok(MaskedEpoch {
            loss: (loss_sum / steps.max(1) as f64) as f32,
            accuracy: (acc_sum / steps.max(1) as f64) as f32,
            seconds: timer.secs(),
        })
    }

    /// Evaluate accuracy on the test set (pads the tail batch).
    pub fn evaluate(&self, data: &Dataset) -> Result<f32> {
        let batch = self.arch.batch;
        let nf = data.n_features;
        let nc = *self.arch.sizes.last().unwrap();
        let n = data.n_test();
        let mut correct = 0usize;
        let mut xbuf = vec![0.0f32; batch * nf];
        let mut start = 0usize;
        while start < n {
            let end = (start + batch).min(n);
            let bsz = end - start;
            xbuf.iter_mut().for_each(|v| *v = 0.0);
            xbuf[..bsz * nf].copy_from_slice(&data.x_test[start * nf..end * nf]);
            let mut inputs = vec![literal_f32(&xbuf, &[batch as i64, nf as i64])?];
            for l in &self.layers {
                let dims = [l.n_in as i64, l.n_out as i64];
                inputs.push(literal_f32(&l.w, &dims)?);
                inputs.push(literal_f32(&l.b, &[l.n_out as i64])?);
                inputs.push(literal_f32(&l.m, &dims)?);
            }
            let out = self.fwd_exe.run(&inputs)?;
            let logits = to_vec_f32(&out[0])?;
            let labels: Vec<u32> = data.y_test[start..end].to_vec();
            correct +=
                (nn::accuracy(&logits[..bsz * nc], &labels, nc) * bsz as f32).round() as usize;
            start = end;
        }
        Ok(correct as f32 / n.max(1) as f32)
    }

    /// SET topology evolution on the dense masks: prune ζ smallest
    /// positive / largest negative masked weights, regrow at random
    /// masked-out positions. Mirrors `set::evolve_layer` semantics.
    pub fn evolve(&mut self, zeta: f64, rng: &mut Rng) {
        for l in &mut self.layers {
            let active: Vec<f32> = l
                .w
                .iter()
                .zip(l.m.iter())
                .filter(|(_, &m)| m != 0.0)
                .map(|(&w, _)| w)
                .collect();
            if active.is_empty() {
                continue;
            }
            let (pos_cut, neg_cut) = prune_thresholds(&active, zeta);
            let mut pruned = 0usize;
            for k in 0..l.w.len() {
                if l.m[k] != 0.0 {
                    let v = l.w[k];
                    let keep = v > pos_cut || v < neg_cut;
                    if !keep {
                        l.m[k] = 0.0;
                        l.w[k] = 0.0;
                        l.vw[k] = 0.0;
                        pruned += 1;
                    }
                }
            }
            // regrow by gap sampling over the masked-out set — exactly
            // min(pruned, capacity) links, like the sparse path (no
            // rejection loop, no attempt cap)
            let lim = (6.0f32 / l.n_in as f32).sqrt();
            let empty = l.m.iter().filter(|&&m| m == 0.0).count();
            let to_grow = pruned.min(empty);
            let mut ordinals = Vec::with_capacity(to_grow);
            let mut seen = std::collections::HashSet::with_capacity(to_grow * 2);
            sample_gap_ordinals(rng, empty, to_grow, &mut ordinals, &mut seen);
            ordinals.sort_unstable();
            let mut oi = 0usize;
            let mut gap = 0usize;
            for k in 0..l.w.len() {
                if oi >= ordinals.len() {
                    break;
                }
                if l.m[k] == 0.0 {
                    if ordinals[oi] == gap {
                        l.m[k] = 1.0;
                        l.w[k] = rng.uniform(-lim, lim);
                        oi += 1;
                    }
                    gap += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetSpec;
    use crate::data::datasets;
    use crate::runtime::manifest::{default_artifacts_dir, Manifest};

    fn arch(name: &str) -> Option<ArchEntry> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping masked test: artifacts not built");
            return None;
        }
        Manifest::load(&dir).unwrap().get(name).cloned()
    }

    fn small_data() -> Dataset {
        // matches the "small" arch: 64 features, 10 classes
        let spec = DatasetSpec {
            name: "toy".into(),
            generator: "madelon".into(),
            n_features: 64,
            n_classes: 10,
            n_train: 256,
            n_test: 96,
        };
        let mut spec = spec;
        spec.n_classes = 10;
        let mut d = datasets::generate(&spec, &mut Rng::new(1)).unwrap();
        // madelon generator is binary; remap labels to 10 classes for shape
        for (i, y) in d.y_train.iter_mut().enumerate() {
            *y = (*y * 5 + (i % 5) as u32) % 10;
        }
        for (i, y) in d.y_test.iter_mut().enumerate() {
            *y = (*y * 5 + (i % 5) as u32) % 10;
        }
        d.n_classes = 10;
        d
    }

    #[test]
    fn masked_trainer_runs_and_updates_state() {
        let Some(e) = arch("small") else { return };
        let data = small_data();
        let mut t = MaskedDenseTrainer::new(&e, 8.0, &mut Rng::new(2)).unwrap();
        let w_before = t.layers[0].w.clone();
        let ep = t.train_epoch(&data, 0.05, &mut Rng::new(3)).unwrap();
        assert!(ep.loss.is_finite());
        assert_ne!(t.layers[0].w, w_before);
        // masks respected: no weight outside mask
        for l in &t.layers {
            for (w, m) in l.w.iter().zip(l.m.iter()) {
                if *m == 0.0 {
                    assert_eq!(*w, 0.0);
                }
            }
        }
    }

    #[test]
    fn masked_training_reduces_loss() {
        let Some(e) = arch("small") else { return };
        let data = small_data();
        let mut t = MaskedDenseTrainer::new(&e, 10.0, &mut Rng::new(4)).unwrap();
        let first = t.train_epoch(&data, 0.05, &mut Rng::new(5)).unwrap();
        let mut last = first;
        for i in 0..6 {
            last = t.train_epoch(&data, 0.05, &mut Rng::new(6 + i)).unwrap();
        }
        assert!(last.loss < first.loss, "{} -> {}", first.loss, last.loss);
        let acc = t.evaluate(&data).unwrap();
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn mask_evolution_preserves_nnz() {
        let Some(e) = arch("small") else { return };
        let mut t = MaskedDenseTrainer::new(&e, 8.0, &mut Rng::new(7)).unwrap();
        let before = t.nnz();
        t.evolve(0.3, &mut Rng::new(8));
        let after = t.nnz();
        assert!(
            (before as i64 - after as i64).abs() <= (before / 100).max(4) as i64,
            "{before} -> {after}"
        );
    }
}
