//! PJRT execution engine: load AOT HLO-text artifacts and run them.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (see `python/compile/aot.py`).
//!
//! The client is process-wide (PJRT CPU clients are heavyweight); all
//! executables share it.

use std::cell::RefCell;
use std::path::Path;

use crate::error::{Result, TsnnError};

fn xerr(e: xla::Error) -> TsnnError {
    TsnnError::Runtime(e.to_string())
}

thread_local! {
    // PJRT handles are Rc-based (not Send/Sync), so the shared client is
    // per-thread; the masked-dense baseline is single-threaded anyway.
    static CLIENT: RefCell<Option<xla::PjRtClient>> = const { RefCell::new(None) };
}

/// Run `f` with the thread-local PJRT CPU client (created on first use).
pub fn with_client<T>(f: impl FnOnce(&xla::PjRtClient) -> Result<T>) -> Result<T> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            *slot = Some(
                xla::PjRtClient::cpu()
                    .map_err(|e| TsnnError::Runtime(format!("PJRT cpu client: {e}")))?,
            );
        }
        f(slot.as_ref().unwrap())
    })
}

/// A compiled HLO executable with convenience execution.
pub struct HloExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Source path (diagnostics).
    pub path: String,
}

impl HloExecutable {
    /// Load HLO text from `path`, compile on the shared CPU client.
    pub fn load(path: &Path) -> Result<HloExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path).map_err(xerr)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = with_client(|client| client.compile(&comp).map_err(xerr))?;
        Ok(HloExecutable {
            exe,
            path: path.display().to_string(),
        })
    }

    /// Execute with literal inputs; returns the flattened output tuple
    /// (aot.py lowers everything with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs).map_err(xerr)?;
        let first = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| TsnnError::Runtime("empty execution result".into()))?;
        let literal = first.to_literal_sync().map_err(xerr)?;
        literal.to_tuple().map_err(xerr)
    }
}

/// Build an f32 literal of the given shape from a slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    if expect as usize != data.len() {
        return Err(TsnnError::Runtime(format!(
            "literal shape {dims:?} wants {expect} elements, got {}",
            data.len()
        )));
    }
    xla::Literal::vec1(data).reshape(dims).map_err(xerr)
}

/// Build an i32 literal (labels).
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    if expect as usize != data.len() {
        return Err(TsnnError::Runtime("literal shape mismatch".into()));
    }
    xla::Literal::vec1(data).reshape(dims).map_err(xerr)
}

/// Scalar f32 literal.
pub fn literal_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read an f32 literal back to a Vec.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(xerr)
}

/// Read a scalar f32 literal.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(xerr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{default_artifacts_dir, Manifest};

    /// These tests need `make artifacts` to have run; they skip otherwise
    /// (make test builds artifacts first, so CI always exercises them).
    fn manifest() -> Option<Manifest> {
        let dir = default_artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir).unwrap())
        } else {
            eprintln!("skipping runtime test: artifacts not built");
            None
        }
    }

    #[test]
    fn literal_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(literal_f32(&[1.0], &[2, 2]).is_err());
    }

    #[test]
    fn loads_and_runs_small_forward() {
        let Some(m) = manifest() else { return };
        let Some(e) = m.get("small") else { return };
        let exe = HloExecutable::load(&e.forward_hlo).unwrap();
        // build zero params -> logits should be all zeros (bias 0)
        let batch = e.batch;
        let mut inputs =
            vec![literal_f32(&vec![0.1f32; batch * e.sizes[0]], &[batch as i64, e.sizes[0] as i64])
                .unwrap()];
        for l in 0..e.n_layers() {
            let (ni, no) = (e.sizes[l], e.sizes[l + 1]);
            inputs.push(literal_f32(&vec![0.0f32; ni * no], &[ni as i64, no as i64]).unwrap());
            inputs.push(literal_f32(&vec![0.0f32; no], &[no as i64]).unwrap());
            inputs.push(literal_f32(&vec![1.0f32; ni * no], &[ni as i64, no as i64]).unwrap());
        }
        let out = exe.run(&inputs).unwrap();
        assert_eq!(out.len(), 1);
        let logits = to_vec_f32(&out[0]).unwrap();
        assert_eq!(logits.len(), batch * e.sizes[e.sizes.len() - 1]);
        assert!(logits.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn pallas_quickstart_artifact_runs() {
        // proves the L1 pallas kernel lowered into the L2 HLO and executes
        // via the rust PJRT runtime (the full three-layer composition).
        let Some(m) = manifest() else { return };
        let Some(e) = m.get("quickstart") else { return };
        assert!(e.use_pallas_first_layer);
        let exe = HloExecutable::load(&e.forward_hlo).unwrap();
        let batch = e.batch;
        let mut inputs =
            vec![
                literal_f32(&vec![0.5f32; batch * e.sizes[0]], &[batch as i64, e.sizes[0] as i64])
                    .unwrap(),
            ];
        for l in 0..e.n_layers() {
            let (ni, no) = (e.sizes[l], e.sizes[l + 1]);
            inputs.push(literal_f32(&vec![0.01f32; ni * no], &[ni as i64, no as i64]).unwrap());
            inputs.push(literal_f32(&vec![0.0f32; no], &[no as i64]).unwrap());
            inputs.push(literal_f32(&vec![1.0f32; ni * no], &[ni as i64, no as i64]).unwrap());
        }
        let out = exe.run(&inputs).unwrap();
        let logits = to_vec_f32(&out[0]).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        // x @ W: 64 inputs * 0.5 * 0.01 = 0.32 per hidden unit (AllReLU id
        // on positive side), then 128 * 0.32 * 0.01 per logit = 0.4096
        let expect = 64.0 * 0.5 * 0.01 * 128.0 * 0.01;
        assert!((logits[0] - expect).abs() < 1e-3, "{} vs {expect}", logits[0]);
    }
}
