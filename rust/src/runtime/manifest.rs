//! `artifacts/manifest.json` loader: describes the AOT-lowered
//! architectures (shapes, hyperparameters, file names) produced by
//! `python/compile/aot.py`.

use std::path::{Path, PathBuf};

use crate::error::{Result, TsnnError};
use crate::util::json;

/// One lowered architecture.
#[derive(Debug, Clone)]
pub struct ArchEntry {
    /// Architecture name ("small", "fashion", ...).
    pub name: String,
    /// Layer sizes including input/output.
    pub sizes: Vec<usize>,
    /// Batch size baked into the executables.
    pub batch: usize,
    /// All-ReLU slope baked into the graph.
    pub alpha: f64,
    /// Momentum baked into the train step.
    pub momentum: f64,
    /// Weight decay baked into the train step.
    pub weight_decay: f64,
    /// Whether the first layer routes through the Pallas kernel.
    pub use_pallas_first_layer: bool,
    /// Forward-pass HLO file (relative to the artifacts dir).
    pub forward_hlo: PathBuf,
    /// Train-step HLO file.
    pub train_hlo: PathBuf,
}

impl ArchEntry {
    /// Number of weight layers.
    pub fn n_layers(&self) -> usize {
        self.sizes.len() - 1
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifacts directory (absolute or cwd-relative).
    pub dir: PathBuf,
    /// Lowered architectures.
    pub entries: Vec<ArchEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest JSON (exposed for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = json::parse(text)
            .map_err(|e| TsnnError::Runtime(format!("manifest parse: {e}")))?;
        let entries = root
            .get("entries")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| TsnnError::Runtime("manifest missing entries".into()))?;
        let mut out = Vec::with_capacity(entries.len());
        for e in entries {
            let get_str = |k: &str| -> Result<String> {
                e.get(k)
                    .and_then(|v| v.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| TsnnError::Runtime(format!("manifest entry missing {k}")))
            };
            let get_num = |k: &str| -> Result<f64> {
                e.get(k)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| TsnnError::Runtime(format!("manifest entry missing {k}")))
            };
            let sizes: Vec<usize> = e
                .get("sizes")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| TsnnError::Runtime("entry missing sizes".into()))?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect();
            if sizes.len() < 2 {
                return Err(TsnnError::Runtime("entry sizes too short".into()));
            }
            out.push(ArchEntry {
                name: get_str("name")?,
                sizes,
                batch: get_num("batch")? as usize,
                alpha: get_num("alpha").unwrap_or(0.0),
                momentum: get_num("momentum").unwrap_or(0.9),
                weight_decay: get_num("weight_decay").unwrap_or(0.0),
                use_pallas_first_layer: e
                    .get("use_pallas_first_layer")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(false),
                forward_hlo: dir.join(get_str("forward_hlo")?),
                train_hlo: dir.join(get_str("train_hlo")?),
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            entries: out,
        })
    }

    /// Find an architecture by name.
    pub fn get(&self, name: &str) -> Option<&ArchEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

/// Default artifacts dir: `$TSNN_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("TSNN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "entries": [{
        "name": "tiny", "sizes": [4, 8, 2], "batch": 16, "alpha": 0.6,
        "momentum": 0.9, "weight_decay": 0.0002,
        "use_pallas_first_layer": true,
        "forward_hlo": "tiny_fwd.hlo.txt", "train_hlo": "tiny_train.hlo.txt"
      }]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/art"), SAMPLE).unwrap();
        let e = m.get("tiny").unwrap();
        assert_eq!(e.sizes, vec![4, 8, 2]);
        assert_eq!(e.batch, 16);
        assert_eq!(e.n_layers(), 2);
        assert!(e.use_pallas_first_layer);
        assert_eq!(e.forward_hlo, PathBuf::from("/art/tiny_fwd.hlo.txt"));
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse(Path::new("."), "{}").is_err());
        assert!(Manifest::parse(Path::new("."), "not json").is_err());
        let missing = r#"{"entries": [{"name": "x"}]}"#;
        assert!(Manifest::parse(Path::new("."), missing).is_err());
    }

    #[test]
    fn repo_manifest_parses_if_present() {
        let dir = default_artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.entries.is_empty());
        }
    }
}
