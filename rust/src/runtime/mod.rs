//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from Rust — no Python on
//! the training path. Hosts the masked-dense baseline (the paper's
//! "Keras with a binary mask" comparator).

pub mod engine;
pub mod manifest;
pub mod masked;

pub use engine::HloExecutable;
pub use manifest::{default_artifacts_dir, ArchEntry, Manifest};
pub use masked::{MaskedDenseTrainer, MaskedEpoch};
