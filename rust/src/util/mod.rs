//! Self-contained utilities: PRNG, JSON, timing, logging.
//!
//! The offline build environment pins us to a small vendored crate set
//! (no rand/serde/criterion), so these modules provide the equivalents
//! the rest of the crate builds on. Each has its own unit tests.

pub mod crc;
pub mod json;
pub mod logging;
pub mod rng;
pub mod timer;

pub use crc::{crc32, Crc32};
pub use json::Json;
pub use rng::Rng;
pub use timer::{cpu_time_secs, peak_rss_mib, rss_mib, PhaseTimes, Timer};
