//! Deterministic, dependency-free PRNG: splitmix64 seeding + xoshiro256**.
//!
//! The offline vendor set has no `rand` crate, so the whole stack (weight
//! init, Erdős–Rényi topologies, regrowth, dataset synthesis, worker
//! shuffles) runs on this generator. Streams are splittable so parallel
//! workers get independent, reproducible randomness.

/// splitmix64 — used to expand a single u64 seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** 1.0 — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single u64 (splitmix64 expansion, never all-zero).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Snapshot the raw 256-bit state (checkpoint/resume).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a snapshotted state. The all-zero state is
    /// a fixed point of xoshiro256** and can never be produced by
    /// `new`/`split`, so a zero snapshot means a corrupt checkpoint.
    pub fn from_state(s: [u64; 4]) -> Rng {
        assert!(s.iter().any(|&w| w != 0), "all-zero rng state");
        Rng { s }
    }

    /// Derive an independent stream (worker `i` of a seeded experiment).
    pub fn split(&self, stream: u64) -> Rng {
        // Mix the stream id through splitmix so nearby ids decorrelate.
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire reduction).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply-shift; bias negligible at our n, but reject anyway.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= x.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped to keep
    /// the generator state trivially clonable and branch-light).
    #[inline]
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (floyd's algorithm for small k,
    /// shuffle for large k).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            // Robert Floyd's sampling: O(k) expected.
            let mut chosen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.below_usize(j + 1);
                let v = if chosen.contains(&t) { j } else { t };
                chosen.insert(v);
                out.push(v);
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn split_streams_are_independent() {
        let base = Rng::new(7);
        let mut s1 = base.split(0);
        let mut s2 = base.split(1);
        let x: Vec<u64> = (0..8).map(|_| s1.next_u64()).collect();
        let y: Vec<u64> = (0..8).map(|_| s2.next_u64()).collect();
        assert_ne!(x, y);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(9);
        for (n, k) in [(100, 5), (50, 40), (10, 10), (1, 1)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn state_roundtrip_resumes_the_stream() {
        let mut a = Rng::new(21);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let resumed: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_eq!(tail, resumed);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
