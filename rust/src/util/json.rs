//! Minimal JSON reader/writer (no serde in the offline vendor set).
//!
//! Supports the subset the repo needs: objects, arrays, strings, numbers,
//! booleans, null. Used for `artifacts/manifest.json`, results CSV/JSON
//! emission and checkpoints' metadata headers.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric content, if a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer view of a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Array content, if an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Bool content, if a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

/// Build an object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad utf8")?,
                                16,
                            )
                            .map_err(|_| "bad hex")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = &self.b[self.i..];
                    let ch_len = utf8_len(s[0]);
                    let chunk =
                        std::str::from_utf8(&s[..ch_len.min(s.len())]).map_err(|_| "bad utf8")?;
                    out.push_str(chunk);
                    self.i += ch_len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.dump()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te".into());
        assert_eq!(parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo → ∑\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → ∑"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\"}").is_err());
    }

    #[test]
    fn integers_dump_without_fraction() {
        assert_eq!(Json::Num(5.0).dump(), "5");
        assert_eq!(Json::Num(5.5).dump(), "5.5");
    }

    #[test]
    fn obj_helper_orders_keys() {
        let o = obj(vec![("b", 1usize.into()), ("a", 2usize.into())]);
        assert_eq!(o.dump(), r#"{"a":2,"b":1}"#);
    }
}
