//! Wall-clock timing and process resource sampling for the bench harness.

use std::time::{Duration, Instant};

/// Simple scope timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a timer now.
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    /// Elapsed duration.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds as f64.
    pub fn millis(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Accumulates per-phase timings (init/train/test/evolution — Table 4 rows).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimes {
    entries: Vec<(String, f64)>,
}

impl PhaseTimes {
    /// New empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `secs` to phase `name` (creates it on first use).
    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 += secs;
        } else {
            self.entries.push((name.to_string(), secs));
        }
    }

    /// Total seconds recorded for `name`.
    pub fn get(&self, name: &str) -> f64 {
        self.entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }

    /// All phases in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, f64)> {
        self.entries.iter().map(|(n, s)| (n.as_str(), *s))
    }

    /// Run `f`, folding its wall time into phase `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add(name, t.secs());
        out
    }
}

/// Current resident set size in MiB (linux /proc; 0.0 if unavailable).
pub fn rss_mib() -> f64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/statm") {
        if let Some(pages) = s.split_whitespace().nth(1) {
            if let Ok(p) = pages.parse::<f64>() {
                return p * 4096.0 / (1024.0 * 1024.0);
            }
        }
    }
    0.0
}

/// Peak RSS in MiB from /proc/self/status (VmHWM), 0.0 if unavailable.
pub fn peak_rss_mib() -> f64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: f64 = rest
                    .trim()
                    .trim_end_matches("kB")
                    .trim()
                    .parse()
                    .unwrap_or(0.0);
                return kb / 1024.0;
            }
        }
    }
    0.0
}

/// Total CPU time (user+sys) consumed by this process, in seconds.
pub fn cpu_time_secs() -> f64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/stat") {
        // fields 14 (utime) and 15 (stime), 1-indexed, after comm which may
        // contain spaces — find the closing paren first.
        if let Some(close) = s.rfind(')') {
            let rest: Vec<&str> = s[close + 1..].split_whitespace().collect();
            if rest.len() > 13 {
                let utime: f64 = rest[11].parse().unwrap_or(0.0);
                let stime: f64 = rest[12].parse().unwrap_or(0.0);
                let hz = 100.0; // CLK_TCK on linux
                return (utime + stime) / hz;
            }
        }
    }
    0.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.millis() >= 4.0);
    }

    #[test]
    fn phases_accumulate() {
        let mut p = PhaseTimes::new();
        p.add("train", 1.0);
        p.add("train", 2.0);
        p.add("test", 0.5);
        assert_eq!(p.get("train"), 3.0);
        assert_eq!(p.get("test"), 0.5);
        assert_eq!(p.get("missing"), 0.0);
        assert_eq!(p.iter().count(), 2);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut p = PhaseTimes::new();
        let v = p.time("x", || 41 + 1);
        assert_eq!(v, 42);
        assert!(p.get("x") >= 0.0);
    }

    #[test]
    fn rss_sampling_positive_on_linux() {
        assert!(rss_mib() > 0.0);
        assert!(peak_rss_mib() > 0.0);
        assert!(cpu_time_secs() >= 0.0);
    }
}
