//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the integrity
//! trailer on durable checkpoints (DESIGN.md §13.1).
//!
//! The vendored crate set has no checksum crate, so this is the classic
//! byte-at-a-time table implementation. The table is built at first use
//! and cached behind a `OnceLock`; throughput is irrelevant next to the
//! `fsync` the trailer rides with.

use std::sync::OnceLock;

fn table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// Incremental CRC-32 over a byte stream.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    /// Fresh digest (initial state all-ones, per the IEEE spec).
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the digest.
    pub fn update(&mut self, bytes: &[u8]) {
        let t = table();
        for &b in bytes {
            self.state = t[((self.state ^ b as u32) & 0xFF) as usize] ^ (self.state >> 8);
        }
    }

    /// Finish (final xor); the digest can keep accepting updates — this
    /// just reads the current value.
    pub fn value(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.value()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard check value for "123456789" and a few others that any
        // IEEE CRC-32 implementation must reproduce.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let whole = crc32(&data);
        let mut inc = Crc32::new();
        for chunk in data.chunks(7) {
            inc.update(chunk);
        }
        assert_eq!(inc.value(), whole);
    }

    #[test]
    fn single_bit_flips_change_the_digest() {
        let data = vec![0xA5u8; 512];
        let base = crc32(&data);
        for bit in [0, 1, 7, 100, 511 * 8 + 7] {
            let mut mutated = data.clone();
            mutated[bit / 8] ^= 1 << (bit % 8);
            assert_ne!(crc32(&mutated), base, "bit {bit} not detected");
        }
    }
}
