//! Persistent kernel worker pool (DESIGN.md §9).
//!
//! Every sharded hot path used to pay a scoped-OS-thread spawn (~50 µs
//! per worker, §4.3) on **every** forward, fused-backward and evolution
//! dispatch, which forced the `PAR_MIN_WORK = 2²⁰` crossover and left
//! small/medium layers sequential. [`WorkerPool`] amortises that cost
//! across the whole training run: `threads − 1` workers are spawned
//! once, parked between dispatches on a Mutex+Condvar epoch barrier
//! (with a bounded spin phase so back-to-back kernel dispatches skip the
//! futex round-trip entirely), and woken with a single epoch bump.
//!
//! [`WorkerPool::run`]`(n_shards, f)` is a scatter-gather primitive with
//! the exact disjoint-write contract of the `std::thread::scope` blocks
//! it replaces: `f(s)` is invoked exactly once for every shard index
//! `s ∈ [0, n_shards)` (distributed over the workers *and* the calling
//! thread by an atomic claim counter), and `run` does not return until
//! every worker has checked out of the epoch — so shard closures may
//! borrow from the caller's stack frame even though the workers are
//! long-lived OS threads. No per-dispatch allocation is performed
//! (pinned by `rust/tests/pool_alloc.rs`).
//!
//! Memory-ordering argument for the disjoint-write handoff: a shard
//! closure's writes happen-before the caller's return from `run` because
//! every worker ends its epoch with a `Release` decrement of the active
//! counter, and the gather side reads that counter with `Acquire` (spin
//! phase) or under the same mutex the decrement's condvar notification
//! holds (park phase). Job publication is ordered by the state mutex:
//! workers only read the task pointer after acquiring the lock that the
//! dispatcher held while writing it. See DESIGN.md §9.2.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Bounded spin before parking (workers waiting for the next epoch) or
/// before blocking (the dispatcher gathering an epoch). Back-to-back
/// kernel dispatches — the steady-state training loop issues several per
/// step — land well inside this window, so the hot path never touches
/// the futex; an idle pool parks after a few microseconds.
const SPIN_LIMIT: u32 = 1 << 12;

/// Over-subscription factor for row-scheduled kernel dispatches
/// ([`WorkerPool::shard_budget`]): more shards than participants lets the
/// work-stealing claim loop absorb per-shard load imbalance that the
/// static nnz-balanced split cannot see (ragged rows, DESIGN.md §11.4).
/// 4 keeps the per-shard claim overhead negligible while giving the
/// steal loop enough granularity to smooth a 1-heavy-row skew.
pub(crate) const SHARD_OVERSUBSCRIPTION: usize = 4;

/// A dispatch's shard closure, lifetime-erased. Safe because `run` never
/// returns (even by unwinding) until every worker has checked out of the
/// epoch, so the erased reference cannot outlive the real closure.
type Task = &'static (dyn Fn(usize) + Sync);

/// The published job of the current epoch.
#[derive(Clone, Copy)]
struct Job {
    task: Task,
    n_shards: usize,
}

/// Mutex-protected barrier state.
struct State {
    /// Current epoch; a bump (always paired with a fresh `job`) wakes
    /// the workers.
    epoch: u64,
    /// The job of the current epoch (`None` between dispatches).
    job: Option<Job>,
    /// Set once by `Drop`; workers exit at the next wakeup.
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between epochs.
    work_cv: Condvar,
    /// The dispatcher parks here waiting for the epoch to drain.
    done_cv: Condvar,
    /// Next unclaimed shard index of the current epoch (work-stealing
    /// distribution: whichever thread gets there first takes the shard).
    next_shard: AtomicUsize,
    /// Workers that have not yet checked out of the current epoch.
    active: AtomicUsize,
    /// Lock-free copy of `state.epoch` for the workers' spin phase.
    epoch_hint: AtomicU64,
    /// A shard closure panicked on a worker (re-raised on the caller).
    panicked: AtomicBool,
    /// Re-entrance / concurrent-dispatch guard (a pool serves exactly
    /// one dispatch at a time; nesting would corrupt the barrier).
    dispatching: AtomicBool,
}

fn worker_loop(shared: Arc<Shared>) {
    let mut seen = 0u64;
    loop {
        // -- spin-then-park until the epoch moves past `seen` --
        let mut spins = 0u32;
        while shared.epoch_hint.load(Ordering::Acquire) == seen && spins < SPIN_LIMIT {
            std::hint::spin_loop();
            spins += 1;
        }
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
            st.job.expect("epoch advanced without a published job")
        };
        // -- claim-and-run shards until the epoch's supply drains --
        let ran = catch_unwind(AssertUnwindSafe(|| loop {
            let s = shared.next_shard.fetch_add(1, Ordering::Relaxed);
            if s >= job.n_shards {
                break;
            }
            (job.task)(s);
        }));
        if ran.is_err() {
            shared.panicked.store(true, Ordering::Release);
        }
        // -- check out: the Release pairs with the gather side's Acquire,
        //    publishing this worker's shard writes to the caller --
        if shared.active.fetch_sub(1, Ordering::Release) == 1 {
            // Last one out wakes the dispatcher if it parked. Taking the
            // mutex before notifying closes the lost-wakeup window: the
            // gather side re-checks `active` under this same mutex before
            // waiting.
            let _st = shared.state.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
}

/// Close out an epoch: block until every worker has checked out, retire
/// the erased task reference, consume the panic flag, and only THEN
/// reopen the pool for the next dispatch — the strict ordering
/// guarantees a worker panic can never be erased by a subsequent
/// dispatch before the current caller has observed it. Returns whether
/// a worker shard panicked during the epoch.
fn gather(shared: &Shared) -> bool {
    let mut spins = 0u32;
    while shared.active.load(Ordering::Acquire) != 0 {
        if spins < SPIN_LIMIT {
            std::hint::spin_loop();
            spins += 1;
        } else {
            let mut st = shared.state.lock().unwrap();
            while shared.active.load(Ordering::Acquire) != 0 {
                st = shared.done_cv.wait(st).unwrap();
            }
            break;
        }
    }
    shared.state.lock().unwrap().job = None;
    let panicked = shared.panicked.swap(false, Ordering::AcqRel);
    shared.dispatching.store(false, Ordering::Release);
    panicked
}

/// Unwind-safety net around the caller's own shard loop: if the
/// caller's shard closure panics, `Drop` still runs [`gather`] before
/// the closure (which the workers borrow) is dropped off the unwinding
/// stack. Disarmed on the normal path, where `run` gathers explicitly
/// so it can observe the worker-panic flag.
struct Gather<'a> {
    shared: &'a Shared,
    armed: bool,
}

impl Drop for Gather<'_> {
    fn drop(&mut self) {
        if self.armed {
            // already unwinding — swallow any worker-panic flag
            gather(self.shared);
        }
    }
}

/// Spawn-once / park-between-dispatches worker pool serving every
/// sharded kernel and evolution pass of a training run (DESIGN.md §9).
///
/// A pool of `threads` has `threads − 1` parked OS workers; the calling
/// thread is always the remaining participant, so a `threads = 1` pool
/// owns no workers and [`WorkerPool::run`] degenerates to an inline
/// sequential loop.
///
/// # Examples
///
/// ```
/// use std::sync::atomic::{AtomicUsize, Ordering};
/// use tsnn::sparse::WorkerPool;
///
/// let pool = WorkerPool::new(4);
/// let hits: Vec<AtomicUsize> = (0..32).map(|_| AtomicUsize::new(0)).collect();
/// pool.run(32, |s| {
///     hits[s].fetch_add(1, Ordering::Relaxed);
/// });
/// assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
/// ```
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
    dispatches: AtomicU64,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("threads", &self.threads)
            .field("dispatches", &self.dispatches.load(Ordering::Relaxed))
            .finish()
    }
}

impl WorkerPool {
    /// Pool with a total budget of `threads` participants (`0` = one per
    /// available core): the caller plus `threads − 1` spawned workers.
    pub fn new(threads: usize) -> Self {
        let threads = super::ops::resolve_threads(threads).max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            next_shard: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            epoch_hint: AtomicU64::new(0),
            panicked: AtomicBool::new(false),
            dispatching: AtomicBool::new(false),
        });
        let handles = (1..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("tsnn-pool-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        WorkerPool {
            shared,
            handles,
            threads,
            dispatches: AtomicU64::new(0),
        }
    }

    /// Total participant budget (workers + the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Dispatches that actually woke the workers (test hook; inline
    /// sequential fallbacks for `n_shards <= 1` are not counted).
    pub fn dispatch_events(&self) -> u64 {
        self.dispatches.load(Ordering::Relaxed)
    }

    /// Shard budget for row-scheduled kernels: oversubscribe the
    /// participant count by [`SHARD_OVERSUBSCRIPTION`] so the atomic
    /// shard-claim loop in [`WorkerPool::run`] can rebalance ragged rows
    /// (a worker that drew a light shard just claims another), capped at
    /// `max_shards` and never below 1. Extra shards cost one relaxed
    /// fetch-add each — noise next to a kernel shard's work (§11.4).
    pub fn shard_budget(&self, max_shards: usize) -> usize {
        (self.threads * SHARD_OVERSUBSCRIPTION).min(max_shards).max(1)
    }

    /// Scatter-gather: invoke `f(s)` exactly once for every shard index
    /// `s ∈ [0, n_shards)`, distributed over the parked workers and the
    /// calling thread, returning only when all shards have completed and
    /// every worker has checked out of the epoch.
    ///
    /// The disjoint-write contract matches the `thread::scope` blocks
    /// this replaces: distinct shard indices may write disjoint regions
    /// of caller-owned buffers without synchronisation, and all shard
    /// writes happen-before the return (§9.2).
    ///
    /// Panics if a shard closure panics (on any thread), and on nested /
    /// concurrent dispatch of the same pool — a pool serves one dispatch
    /// at a time (coordinator workers own separate sub-pools, §9.4).
    pub fn run<F: Fn(usize) + Sync>(&self, n_shards: usize, f: F) {
        if n_shards <= 1 || self.handles.is_empty() {
            for s in 0..n_shards {
                f(s);
            }
            return;
        }
        if self.shared.dispatching.swap(true, Ordering::AcqRel) {
            panic!("WorkerPool::run is not re-entrant (nested or concurrent dispatch)");
        }
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        let task: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: lifetime erasure only — the Gather guard below keeps
        // this function from returning (or unwinding past `f`) until
        // every worker has checked out, so no worker can observe the
        // reference after `f` is dead.
        let task: Task = unsafe { std::mem::transmute(task) };
        {
            let mut st = self.shared.state.lock().unwrap();
            self.shared.next_shard.store(0, Ordering::Relaxed);
            self.shared.active.store(self.handles.len(), Ordering::Relaxed);
            st.job = Some(Job { task, n_shards });
            st.epoch += 1;
            self.shared.epoch_hint.store(st.epoch, Ordering::Release);
            self.shared.work_cv.notify_all();
        }
        // guard first: see Gather docs
        let mut guard = Gather {
            shared: &self.shared,
            armed: true,
        };
        // the calling thread is a full participant
        loop {
            let s = self.shared.next_shard.fetch_add(1, Ordering::Relaxed);
            if s >= n_shards {
                break;
            }
            f(s);
        }
        guard.armed = false;
        if gather(&self.shared) {
            panic!("WorkerPool: a shard task panicked on a pool worker");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            // knock spinning workers out of the lock-free phase too
            self.shared.epoch_hint.fetch_add(1, Ordering::Release);
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_shard_exactly_once() {
        let pool = WorkerPool::new(4);
        for &n in &[0usize, 1, 2, 3, 7, 64, 257] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, |s| {
                hits[s].fetch_add(1, Ordering::Relaxed);
            });
            for (s, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "shard {s} of {n}");
            }
        }
    }

    #[test]
    fn shard_budget_oversubscribes_and_clamps() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.shard_budget(1000), 16);
        assert_eq!(pool.shard_budget(10), 10);
        assert_eq!(pool.shard_budget(0), 1);
        let one = WorkerPool::new(1);
        assert_eq!(one.shard_budget(1000), SHARD_OVERSUBSCRIPTION);
    }

    #[test]
    fn single_thread_pool_is_inline_sequential() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        // order is deterministic (caller runs all shards in sequence)
        let order = Mutex::new(Vec::new());
        pool.run(5, |s| order.lock().unwrap().push(s));
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
        assert_eq!(pool.dispatch_events(), 0);
    }

    #[test]
    fn reuse_across_many_dispatches() {
        let pool = WorkerPool::new(3);
        let sum = AtomicUsize::new(0);
        for _ in 0..200 {
            pool.run(8, |s| {
                sum.fetch_add(s + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(sum.load(Ordering::Relaxed), 200 * (1..=8).sum::<usize>());
        assert_eq!(pool.dispatch_events(), 200);
    }

    #[test]
    fn shard_writes_are_visible_after_run() {
        // the §9.2 handoff: plain (non-atomic) disjoint writes must be
        // visible to the caller once run() returns
        let pool = WorkerPool::new(4);
        let mut buf = vec![0u64; 1024];
        let ptr = buf.as_mut_ptr() as usize;
        pool.run(16, |s| {
            for i in 0..64 {
                // SAFETY: shard s writes only [s*64, (s+1)*64)
                unsafe { *(ptr as *mut u64).add(s * 64 + i) = (s * 64 + i) as u64 };
            }
        });
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, |s| {
                if s % 2 == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err());
        // the pool must still serve subsequent dispatches
        let n = AtomicUsize::new(0);
        pool.run(8, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn zero_threads_resolves_to_available_cores() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.threads(), super::super::ops::available_threads());
    }
}
