//! Sparse topology + weight initialisation.
//!
//! SET initialises each layer as an Erdős–Rényi random bipartite graph
//! whose expected edge count is `ε · (n_in + n_out)` (Mocanu et al. 2018),
//! i.e. density `p = ε (n_in + n_out) / (n_in · n_out)`. The paper found
//! naive entry-by-entry initialisation to be a bottleneck at scale
//! ("Matrix initialisation time", §2.4) — we build rows in one pass with
//! per-row sampled counts, which is O(nnz) rather than O(n_in · n_out).

use super::csr::CsrMatrix;
use crate::util::Rng;

/// Weight initialisation scheme (Table 7: normal / xavier / he_uniform).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WeightInit {
    /// N(0, std²).
    Normal(f32),
    /// U(-lim, lim) with lim = sqrt(6 / (fan_in + fan_out)).
    Xavier,
    /// U(-lim, lim) with lim = sqrt(6 / fan_in).
    HeUniform,
}

impl WeightInit {
    /// Parse from a config string (`normal:STD` sets an explicit std).
    pub fn parse(s: &str) -> Option<WeightInit> {
        if let Some(std) = s.strip_prefix("normal:") {
            return std.parse().ok().map(WeightInit::Normal);
        }
        match s {
            "normal" => Some(WeightInit::Normal(0.05)),
            "xavier" => Some(WeightInit::Xavier),
            "he_uniform" | "he" => Some(WeightInit::HeUniform),
            _ => None,
        }
    }

    /// Draw one weight for a layer with the given fan-in/out.
    #[inline]
    pub fn sample(&self, rng: &mut Rng, fan_in: usize, fan_out: usize) -> f32 {
        match *self {
            WeightInit::Normal(std) => rng.normal_ms(0.0, std),
            WeightInit::Xavier => {
                let lim = (6.0 / (fan_in + fan_out) as f32).sqrt();
                rng.uniform(-lim, lim)
            }
            WeightInit::HeUniform => {
                let lim = (6.0 / fan_in as f32).sqrt();
                rng.uniform(-lim, lim)
            }
        }
    }
}

/// Density implied by the SET epsilon parameter for an `n_in × n_out`
/// layer: `min(1, ε (n_in + n_out) / (n_in n_out))`.
pub fn epsilon_density(epsilon: f64, n_in: usize, n_out: usize) -> f64 {
    if n_in == 0 || n_out == 0 {
        return 0.0;
    }
    (epsilon * (n_in + n_out) as f64 / (n_in as f64 * n_out as f64)).min(1.0)
}

/// Sample a Binomial(n, p) count.
///
/// Exact inversion for small n, normal approximation for large n·p —
/// initialisation only needs the aggregate degree distribution to be
/// right, and this keeps 50M-neuron init O(nnz).
pub fn binomial(rng: &mut Rng, n: usize, p: f64) -> usize {
    if p <= 0.0 || n == 0 {
        return 0;
    }
    if p >= 1.0 {
        return n;
    }
    let np = n as f64 * p;
    if n < 64 {
        let mut c = 0usize;
        for _ in 0..n {
            if rng.bernoulli(p) {
                c += 1;
            }
        }
        c
    } else if np < 12.0 {
        // Poisson-style inversion on the binomial pmf.
        let q = 1.0 - p;
        let mut pmf = q.powi(n as i32);
        if pmf <= 0.0 {
            // underflow guard: fall back to normal approximation
            return binomial_normal(rng, n, p);
        }
        let mut cdf = pmf;
        let u = rng.f64();
        let mut k = 0usize;
        while u > cdf && k < n {
            k += 1;
            pmf *= (n - k + 1) as f64 / k as f64 * (p / q);
            cdf += pmf;
        }
        k
    } else {
        binomial_normal(rng, n, p)
    }
}

fn binomial_normal(rng: &mut Rng, n: usize, p: f64) -> usize {
    let mean = n as f64 * p;
    let std = (n as f64 * p * (1.0 - p)).sqrt();
    let v = mean + std * rng.normal() as f64;
    v.round().clamp(0.0, n as f64) as usize
}

/// Draw one Erdős–Rényi row into `cols_out`/`vals_out` (cleared first):
/// a Binomial(n_cols, density) degree, columns sampled without
/// replacement and sorted, then one weight per column in column order.
///
/// This is the exact per-row draw sequence of [`erdos_renyi`], split out
/// so the out-of-core initialiser (`bigmodel`) can stream rows straight
/// into a mapped segment while consuming the RNG identically to the
/// in-RAM builder — bit-for-bit the same topology and weights.
pub fn er_sample_row(
    rng: &mut Rng,
    n_rows: usize,
    n_cols: usize,
    density: f64,
    init: &WeightInit,
    cols_out: &mut Vec<u32>,
    vals_out: &mut Vec<f32>,
) {
    cols_out.clear();
    vals_out.clear();
    let k = binomial(rng, n_cols, density);
    let mut cols = rng.sample_indices(n_cols, k);
    cols.sort_unstable();
    for c in cols {
        cols_out.push(c as u32);
        vals_out.push(init.sample(rng, n_rows, n_cols));
    }
}

/// Erdős–Rényi sparse matrix with the given density; weights drawn from
/// `init`. Row degrees are Binomial(n_cols, density), columns sampled
/// without replacement and sorted — O(nnz log deg) total.
pub fn erdos_renyi(
    n_rows: usize,
    n_cols: usize,
    density: f64,
    rng: &mut Rng,
    init: &WeightInit,
) -> CsrMatrix {
    let mut row_ptr = Vec::with_capacity(n_rows + 1);
    row_ptr.push(0usize);
    let mut col_idx: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    let expected = (density * n_rows as f64 * n_cols as f64) as usize;
    col_idx.reserve(expected + n_rows);
    values.reserve(expected + n_rows);
    let (mut row_cols, mut row_vals) = (Vec::new(), Vec::new());
    for _ in 0..n_rows {
        er_sample_row(rng, n_rows, n_cols, density, init, &mut row_cols, &mut row_vals);
        col_idx.extend_from_slice(&row_cols);
        values.extend_from_slice(&row_vals);
        row_ptr.push(col_idx.len());
    }
    CsrMatrix {
        n_rows,
        n_cols,
        row_ptr: row_ptr.into(),
        col_idx: col_idx.into(),
        values: values.into(),
    }
}

/// Erdős–Rényi from a SET epsilon (the paper's knob).
pub fn erdos_renyi_epsilon(
    n_rows: usize,
    n_cols: usize,
    epsilon: f64,
    rng: &mut Rng,
    init: &WeightInit,
) -> CsrMatrix {
    erdos_renyi(n_rows, n_cols, epsilon_density(epsilon, n_rows, n_cols), rng, init)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epsilon_density_formula() {
        // ε=10, 100x100 -> 10*200/10000 = 0.2
        assert!((epsilon_density(10.0, 100, 100) - 0.2).abs() < 1e-12);
        assert_eq!(epsilon_density(1e9, 10, 10), 1.0); // clamped
        assert_eq!(epsilon_density(1.0, 0, 10), 0.0);
    }

    #[test]
    fn er_density_is_close() {
        let mut rng = Rng::new(1);
        let m = erdos_renyi(200, 300, 0.1, &mut rng, &WeightInit::Normal(0.05));
        m.validate().unwrap();
        let d = m.density();
        assert!((d - 0.1).abs() < 0.01, "density {d}");
    }

    #[test]
    fn er_epsilon_expected_nnz() {
        let mut rng = Rng::new(2);
        let m = erdos_renyi_epsilon(500, 400, 10.0, &mut rng, &WeightInit::Xavier);
        let expected = 10.0 * (500.0 + 400.0);
        let got = m.nnz() as f64;
        assert!((got - expected).abs() / expected < 0.1, "nnz {got} vs {expected}");
    }

    #[test]
    fn er_is_deterministic_per_seed() {
        let a = erdos_renyi(50, 50, 0.2, &mut Rng::new(9), &WeightInit::HeUniform);
        let b = erdos_renyi(50, 50, 0.2, &mut Rng::new(9), &WeightInit::HeUniform);
        assert_eq!(a, b);
    }

    #[test]
    fn binomial_moments() {
        let mut rng = Rng::new(3);
        // small-n exact path
        let n = 40;
        let p = 0.3;
        let trials = 20_000;
        let mean: f64 =
            (0..trials).map(|_| binomial(&mut rng, n, p) as f64).sum::<f64>() / trials as f64;
        assert!((mean - n as f64 * p).abs() < 0.2, "mean {mean}");
        // large-n normal path
        let mean2: f64 = (0..2_000)
            .map(|_| binomial(&mut rng, 10_000, 0.05) as f64)
            .sum::<f64>()
            / 2_000.0;
        assert!((mean2 - 500.0).abs() < 5.0, "mean2 {mean2}");
    }

    #[test]
    fn binomial_edge_cases() {
        let mut rng = Rng::new(4);
        assert_eq!(binomial(&mut rng, 10, 0.0), 0);
        assert_eq!(binomial(&mut rng, 10, 1.0), 10);
        assert_eq!(binomial(&mut rng, 0, 0.5), 0);
    }

    #[test]
    fn weight_init_ranges() {
        let mut rng = Rng::new(5);
        let he = WeightInit::HeUniform;
        let lim = (6.0f32 / 100.0).sqrt();
        for _ in 0..1000 {
            let v = he.sample(&mut rng, 100, 50);
            assert!(v.abs() <= lim);
        }
        let xa = WeightInit::Xavier;
        let lim2 = (6.0f32 / 150.0).sqrt();
        for _ in 0..1000 {
            assert!(xa.sample(&mut rng, 100, 50).abs() <= lim2);
        }
    }

    #[test]
    fn weight_init_parse() {
        assert_eq!(WeightInit::parse("normal"), Some(WeightInit::Normal(0.05)));
        assert_eq!(WeightInit::parse("normal:0.1"), Some(WeightInit::Normal(0.1)));
        assert_eq!(WeightInit::parse("normal:x"), None);
        assert_eq!(WeightInit::parse("xavier"), Some(WeightInit::Xavier));
        assert_eq!(WeightInit::parse("he_uniform"), Some(WeightInit::HeUniform));
        assert_eq!(WeightInit::parse("bogus"), None);
    }
}
