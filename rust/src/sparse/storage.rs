//! Layer storage abstraction: in-RAM `Vec` or mmap-backed segment slices.
//!
//! The out-of-core subsystem (DESIGN.md §14) needs `CsrMatrix` /
//! `SparseLayer` arrays to be backable by memory-mapped checkpoint
//! segments so model size is bounded by disk instead of RAM. [`Buf`] is
//! that seam: an owned, slice-like container that is either a plain
//! `Vec<T>` (the existing backing, and the only one most of the engine
//! ever sees) or a typed window into a shared [`MapRegion`] (one mapped
//! segment file per layer, `rust/src/bigmodel/`).
//!
//! Design rules:
//!
//! * **Reads and in-place writes are backing-agnostic.** `Buf` derefs to
//!   `[T]`, so indexing, slicing, `.iter()`, `.as_slice()` and deref
//!   coercion at `&[T]` call sites — i.e. all four CSR kernels, the SIMD
//!   dispatch table and the `WorkerPool` sharding — run unmodified over
//!   mapped memory.
//! * **Structural mutation spills to RAM.** Operations that reallocate
//!   (`push`, `pop`, assignment of a fresh `Vec`) turn a mapped buffer
//!   into a RAM one. The streaming evolution path in `bigmodel` never
//!   takes those paths; they exist so small-model code (tests, serving,
//!   transport decode) stays correct without caring about the backing.
//! * **`Clone` is deep.** Cloning a mapped buffer materialises it into
//!   RAM — two handles onto one mutable mapped range would alias writes,
//!   which `Vec` semantics (and the parity suites) forbid.
//!
//! The mmap layer itself is raw `extern "C"` FFI (the offline vendor set
//! has no `libc`/`memmap` crate): `mmap`/`munmap`/`msync`/`madvise`
//! against Linux ABI constants, compiled only on Linux; other targets
//! get a typed `Storage` error and the RAM backing keeps working.

use std::sync::Arc;

use crate::error::{Result, TsnnError};

// --- raw mmap FFI (Linux) ---------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::{c_int, c_long, c_void};

    pub const PROT_READ: c_int = 0x1;
    pub const PROT_WRITE: c_int = 0x2;
    pub const MAP_SHARED: c_int = 0x01;
    pub const MS_SYNC: c_int = 4;
    pub const MADV_DONTNEED: c_int = 4;
    pub const _SC_PAGESIZE: c_int = 30;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn msync(addr: *mut c_void, len: usize, flags: c_int) -> c_int;
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
        pub fn sysconf(name: c_int) -> c_long;
    }

    pub fn page_size() -> usize {
        let v = unsafe { sysconf(_SC_PAGESIZE) };
        if v <= 0 {
            4096
        } else {
            v as usize
        }
    }
}

/// A whole-file shared mapping (`PROT_READ | PROT_WRITE`, `MAP_SHARED`):
/// writes go through to the page cache and reach the file via
/// [`MapRegion::sync`]. Unmapped on drop. Shared between the typed
/// [`MapSlice`] windows of one segment via `Arc`.
pub struct MapRegion {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: the mapping is plain memory with a stable address for the
// region's lifetime; &self methods only read metadata, and mutable
// access is funnelled through `Buf`'s ownership discipline (each byte
// range belongs to exactly one `Buf`), mirroring what makes `Vec<T>`
// Send + Sync.
unsafe impl Send for MapRegion {}
unsafe impl Sync for MapRegion {}

impl MapRegion {
    /// Map `len` bytes of `file` read-write shared. `len == 0` maps
    /// nothing (a valid empty region).
    #[cfg(target_os = "linux")]
    pub fn map_file(file: &std::fs::File, len: usize) -> Result<Arc<MapRegion>> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            return Ok(Arc::new(MapRegion {
                ptr: std::ptr::null_mut(),
                len: 0,
            }));
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(TsnnError::Storage(format!(
                "mmap of {len} bytes failed: {}",
                std::io::Error::last_os_error()
            )));
        }
        Ok(Arc::new(MapRegion {
            ptr: ptr as *mut u8,
            len,
        }))
    }

    /// Unsupported-platform stub: mapped storage is Linux-only; the RAM
    /// backing (`Buf::Ram`) works everywhere.
    #[cfg(not(target_os = "linux"))]
    pub fn map_file(_file: &std::fs::File, _len: usize) -> Result<Arc<MapRegion>> {
        Err(TsnnError::Storage(
            "mmap-backed storage is only supported on Linux".into(),
        ))
    }

    /// Bytes mapped.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing is mapped.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Base pointer (null for an empty region).
    pub fn as_ptr(&self) -> *mut u8 {
        self.ptr
    }

    /// Synchronously write the page-aligned extent covering
    /// `[offset, offset + len)` back to the file (`msync(MS_SYNC)`).
    #[cfg(target_os = "linux")]
    pub fn sync(&self, offset: usize, len: usize) -> Result<()> {
        let Some((addr, span)) = self.aligned_extent(offset, len) else {
            return Ok(());
        };
        let rc = unsafe { sys::msync(addr, span, sys::MS_SYNC) };
        if rc != 0 {
            return Err(TsnnError::Storage(format!(
                "msync failed: {}",
                std::io::Error::last_os_error()
            )));
        }
        Ok(())
    }

    /// Drop the resident pages of the page-aligned extent covering
    /// `[offset, offset + len)` (`madvise(MADV_DONTNEED)`); the next
    /// access repopulates from the file. Callers must [`MapRegion::sync`]
    /// first if the range may hold dirty pages they cannot afford to
    /// leave to kernel writeback timing. Advisory: failure is ignored —
    /// residency trimming is an optimisation, never a correctness step.
    #[cfg(target_os = "linux")]
    pub fn advise_dontneed(&self, offset: usize, len: usize) {
        if let Some((addr, span)) = self.aligned_extent(offset, len) {
            unsafe {
                sys::madvise(addr, span, sys::MADV_DONTNEED);
            }
        }
    }

    #[cfg(not(target_os = "linux"))]
    pub fn sync(&self, _offset: usize, _len: usize) -> Result<()> {
        Ok(())
    }

    #[cfg(not(target_os = "linux"))]
    pub fn advise_dontneed(&self, _offset: usize, _len: usize) {}

    /// Page-align `[offset, offset+len)` downward/upward and clamp to the
    /// region; `None` when the clamped extent is empty.
    #[cfg(target_os = "linux")]
    fn aligned_extent(&self, offset: usize, len: usize) -> Option<(*mut std::os::raw::c_void, usize)> {
        if self.len == 0 || len == 0 || offset >= self.len {
            return None;
        }
        let page = sys::page_size();
        let start = (offset / page) * page;
        let end = (offset + len).min(self.len);
        if end <= start {
            return None;
        }
        Some((
            unsafe { self.ptr.add(start) } as *mut std::os::raw::c_void,
            end - start,
        ))
    }
}

impl Drop for MapRegion {
    fn drop(&mut self) {
        #[cfg(target_os = "linux")]
        if !self.ptr.is_null() && self.len > 0 {
            unsafe {
                sys::munmap(self.ptr as *mut std::os::raw::c_void, self.len);
            }
        }
    }
}

impl std::fmt::Debug for MapRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MapRegion({} bytes)", self.len)
    }
}

/// Marker for element types that may live in mapped segments: plain old
/// data with no drop glue, valid for any bit pattern we write (we only
/// ever read back bytes this crate wrote).
pub trait Pod: Copy + 'static {}
impl Pod for u8 {}
impl Pod for u32 {}
impl Pod for u64 {}
impl Pod for usize {}
impl Pod for f32 {}

/// A typed window into a [`MapRegion`]: `len` elements of `T` starting
/// at byte offset `byte_off`. Constructed only by the segment layout
/// code, which guarantees alignment and that windows never overlap.
pub struct MapSlice<T: Pod> {
    region: Arc<MapRegion>,
    byte_off: usize,
    len: usize,
    _marker: std::marker::PhantomData<T>,
}

// SAFETY: see MapRegion — the window is plain memory and mutable access
// is unique by construction (one Buf per window).
unsafe impl<T: Pod + Send> Send for MapSlice<T> {}
unsafe impl<T: Pod + Sync> Sync for MapSlice<T> {}

impl<T: Pod> MapSlice<T> {
    /// Window `len` elements at `byte_off` into `region`. Bounds and
    /// alignment are checked here once; the accessors below rely on it.
    pub fn new(region: Arc<MapRegion>, byte_off: usize, len: usize) -> Result<MapSlice<T>> {
        let elem = std::mem::size_of::<T>();
        let bytes = len
            .checked_mul(elem)
            .ok_or_else(|| TsnnError::IndexOverflow(format!("map window of {len} elements")))?;
        let end = byte_off
            .checked_add(bytes)
            .ok_or_else(|| TsnnError::IndexOverflow(format!("map window end at {byte_off}+{bytes}")))?;
        if end > region.len() {
            return Err(TsnnError::Storage(format!(
                "map window [{byte_off}, {end}) exceeds region of {} bytes",
                region.len()
            )));
        }
        if byte_off % std::mem::align_of::<T>() != 0 {
            return Err(TsnnError::Storage(format!(
                "map window at byte {byte_off} misaligned for element size {elem}"
            )));
        }
        Ok(MapSlice {
            region,
            byte_off,
            len,
            _marker: std::marker::PhantomData,
        })
    }

    fn as_slice(&self) -> &[T] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: bounds + alignment checked in `new`; the region lives
        // as long as `self` (Arc), and `T: Pod` accepts any bytes.
        unsafe {
            std::slice::from_raw_parts(
                self.region.as_ptr().add(self.byte_off) as *const T,
                self.len,
            )
        }
    }

    fn as_mut_slice(&mut self) -> &mut [T] {
        if self.len == 0 {
            return &mut [];
        }
        // SAFETY: as above; mutation is unique because each window is
        // owned by exactly one `Buf` and we hold `&mut self`.
        unsafe {
            std::slice::from_raw_parts_mut(
                self.region.as_ptr().add(self.byte_off) as *mut T,
                self.len,
            )
        }
    }

    /// The backing region (for residency sync/advise).
    pub fn region(&self) -> &Arc<MapRegion> {
        &self.region
    }

    /// Byte offset of the window inside the region.
    pub fn byte_off(&self) -> usize {
        self.byte_off
    }

    /// Byte length of the window.
    pub fn byte_len(&self) -> usize {
        self.len * std::mem::size_of::<T>()
    }
}

/// Owned layer storage: a `Vec<T>` or a typed mapped window. See the
/// module docs for the exact backing-transparency contract.
pub enum Buf<T: Pod> {
    /// Heap-allocated backing — the default everywhere.
    Ram(Vec<T>),
    /// Window into an mmap-backed segment file (`bigmodel`).
    Mapped(MapSlice<T>),
}

impl<T: Pod> Buf<T> {
    /// Empty RAM buffer.
    pub fn new() -> Buf<T> {
        Buf::Ram(Vec::new())
    }

    /// Contents as a slice (any backing).
    #[inline]
    pub fn as_slice(&self) -> &[T] {
        match self {
            Buf::Ram(v) => v.as_slice(),
            Buf::Mapped(m) => m.as_slice(),
        }
    }

    /// Contents as a mutable slice (any backing; mapped writes go
    /// through to the page cache).
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        match self {
            Buf::Ram(v) => v.as_mut_slice(),
            Buf::Mapped(m) => m.as_mut_slice(),
        }
    }

    /// True when backed by a mapped segment.
    pub fn is_mapped(&self) -> bool {
        matches!(self, Buf::Mapped(_))
    }

    /// Copy out into a fresh `Vec`.
    pub fn to_vec(&self) -> Vec<T> {
        self.as_slice().to_vec()
    }

    /// Turn a mapped buffer into a RAM one (no-op when already RAM).
    pub fn materialize(&mut self) {
        if self.is_mapped() {
            *self = Buf::Ram(self.to_vec());
        }
    }

    /// Shorten to `len` elements. RAM: `Vec::truncate`. Mapped: the
    /// window shrinks (file bytes past the window become dead until the
    /// next rebuild/swap).
    pub fn truncate(&mut self, len: usize) {
        match self {
            Buf::Ram(v) => v.truncate(len),
            Buf::Mapped(m) => m.len = m.len.min(len),
        }
    }

    /// Append (spills a mapped buffer to RAM).
    pub fn push(&mut self, value: T) {
        self.materialize();
        match self {
            Buf::Ram(v) => v.push(value),
            Buf::Mapped(_) => unreachable!("materialize() left a mapped buf"),
        }
    }

    /// Remove and return the last element (spills a mapped buffer to RAM).
    pub fn pop(&mut self) -> Option<T> {
        self.materialize();
        match self {
            Buf::Ram(v) => v.pop(),
            Buf::Mapped(_) => unreachable!("materialize() left a mapped buf"),
        }
    }

    /// Exchange contents with a `Vec`: the buffer takes `other`'s
    /// elements (as RAM backing) and `other` receives the buffer's old
    /// contents — copied out when the buffer was mapped. This is the
    /// structural-rebuild handshake (`SparseLayer::swap_storage`): the
    /// engine installs freshly built arrays and reclaims the old ones as
    /// scratch for the next layer.
    pub fn swap_vec(&mut self, other: &mut Vec<T>) {
        match self {
            Buf::Ram(v) => std::mem::swap(v, other),
            Buf::Mapped(m) => {
                let old = m.as_slice().to_vec();
                *self = Buf::Ram(std::mem::take(other));
                *other = old;
            }
        }
    }
}

impl<T: Pod> Default for Buf<T> {
    fn default() -> Buf<T> {
        Buf::new()
    }
}

impl<T: Pod> From<Vec<T>> for Buf<T> {
    fn from(v: Vec<T>) -> Buf<T> {
        Buf::Ram(v)
    }
}

impl<T: Pod> std::ops::Deref for Buf<T> {
    type Target = [T];
    #[inline]
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Pod> std::ops::DerefMut for Buf<T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut [T] {
        self.as_mut_slice()
    }
}

impl<T: Pod> Clone for Buf<T> {
    /// Deep: a mapped buffer clones into RAM (two handles onto one
    /// mutable mapped window would alias writes).
    fn clone(&self) -> Buf<T> {
        Buf::Ram(self.to_vec())
    }
}

impl<T: Pod + std::fmt::Debug> std::fmt::Debug for Buf<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.as_slice().fmt(f)
    }
}

impl<T: Pod + PartialEq> PartialEq for Buf<T> {
    fn eq(&self, other: &Buf<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Pod + PartialEq> PartialEq<Vec<T>> for Buf<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Pod + PartialEq> PartialEq<Buf<T>> for Vec<T> {
    fn eq(&self, other: &Buf<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Pod + PartialEq> PartialEq<&[T]> for Buf<T> {
    fn eq(&self, other: &&[T]) -> bool {
        self.as_slice() == *other
    }
}

impl<'a, T: Pod> IntoIterator for &'a Buf<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl<'a, T: Pod> IntoIterator for &'a mut Buf<T> {
    type Item = &'a mut T;
    type IntoIter = std::slice::IterMut<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_mut_slice().iter_mut()
    }
}

impl<T: Pod> FromIterator<T> for Buf<T> {
    fn from_iter<I: IntoIterator<Item = T>>(iter: I) -> Buf<T> {
        Buf::Ram(iter.into_iter().collect())
    }
}

/// Residency advisor hooks the training loop calls as it finishes with a
/// layer's arrays (DESIGN.md §14.4). The RAM path never installs one;
/// `bigmodel` installs one that trims mapped pages when resident memory
/// approaches the configured budget. Correctness-neutral by contract:
/// implementations may only sync/advise, never mutate data.
pub trait Residency: Send + Sync {
    /// Layer `l`'s weights were last read by the forward pass of one
    /// batch (they will be read again by the backward pass).
    fn after_forward(&self, l: usize);
    /// Layer `l`'s weights/velocity received their optimizer update for
    /// one batch — the last touch of this step.
    fn after_update(&self, l: usize);
}

/// Checked `usize → u32` conversion for index/nnz accounting: silent
/// truncation on a hypothetical >4B-edge model becomes a typed error.
pub fn checked_u32(v: usize, what: &str) -> Result<u32> {
    u32::try_from(v).map_err(|_| {
        TsnnError::IndexOverflow(format!("{what} {v} exceeds u32::MAX ({})", u32::MAX))
    })
}

/// Checked `u64 → usize` conversion (32-bit hosts / corrupt headers).
pub fn checked_usize(v: u64, what: &str) -> Result<usize> {
    usize::try_from(v).map_err(|_| {
        TsnnError::IndexOverflow(format!("{what} {v} exceeds usize::MAX on this host"))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ram_buf_behaves_like_vec() {
        let mut b: Buf<u32> = vec![1, 2, 3].into();
        assert_eq!(b.len(), 3);
        assert_eq!(b[1], 2);
        b[1] = 9;
        assert_eq!(b.as_slice(), &[1, 9, 3]);
        b.push(4);
        assert_eq!(b.pop(), Some(4));
        b.truncate(2);
        assert_eq!(b, vec![1, 9]);
        assert!(!b.is_mapped());
        let sum: u32 = (&b).into_iter().sum();
        assert_eq!(sum, 10);
        for v in &mut b {
            *v += 1;
        }
        assert_eq!(b, vec![2, 10]);
    }

    #[test]
    fn swap_vec_exchanges_contents() {
        let mut b: Buf<f32> = vec![1.0, 2.0].into();
        let mut v = vec![5.0, 6.0, 7.0];
        b.swap_vec(&mut v);
        assert_eq!(b, vec![5.0, 6.0, 7.0]);
        assert_eq!(v, vec![1.0, 2.0]);
    }

    #[cfg(target_os = "linux")]
    fn mapped_file(bytes: usize) -> (std::fs::File, std::path::PathBuf) {
        let dir = std::env::temp_dir().join("tsnn_storage_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!(
            "map_{}_{}.bin",
            std::process::id(),
            bytes
        ));
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .unwrap();
        file.set_len(bytes as u64).unwrap();
        (file, path)
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn mapped_buf_reads_writes_and_syncs() {
        let (file, path) = mapped_file(4096);
        let region = MapRegion::map_file(&file, 4096).unwrap();
        let mut b: Buf<u32> = Buf::Mapped(MapSlice::new(region.clone(), 64, 8).unwrap());
        assert!(b.is_mapped());
        assert_eq!(b.len(), 8);
        for (i, v) in (&mut b).into_iter().enumerate() {
            *v = (i * i) as u32;
        }
        assert_eq!(b[3], 9);
        region.sync(0, 4096).unwrap();
        drop(b);
        drop(region);
        // bytes reached the file
        let raw = std::fs::read(&path).unwrap();
        let v3 = u32::from_le_bytes([raw[64 + 12], raw[64 + 13], raw[64 + 14], raw[64 + 15]]);
        assert_eq!(v3, 9);
        std::fs::remove_file(&path).unwrap();
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn mapped_buf_spills_to_ram_on_structural_mutation() {
        let (file, path) = mapped_file(256);
        let region = MapRegion::map_file(&file, 256).unwrap();
        let mut b: Buf<f32> = Buf::Mapped(MapSlice::new(region, 0, 4).unwrap());
        b.as_mut_slice().copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let c = b.clone();
        assert!(!c.is_mapped(), "clone must be deep");
        assert_eq!(c, vec![1.0, 2.0, 3.0, 4.0]);
        b.push(5.0);
        assert!(!b.is_mapped(), "push must spill to RAM");
        assert_eq!(b, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        std::fs::remove_file(&path).unwrap();
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn map_slice_rejects_oob_and_misalignment() {
        let (file, path) = mapped_file(64);
        let region = MapRegion::map_file(&file, 64).unwrap();
        assert!(MapSlice::<u32>::new(region.clone(), 0, 17).is_err()); // 68 > 64
        assert!(MapSlice::<u32>::new(region.clone(), 2, 1).is_err()); // misaligned
        assert!(MapSlice::<u32>::new(region, 60, 1).is_ok());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn checked_casts_are_typed() {
        assert_eq!(checked_u32(7, "x").unwrap(), 7);
        let err = checked_u32(u32::MAX as usize + 1, "col count").unwrap_err();
        assert!(matches!(err, TsnnError::IndexOverflow(_)), "{err}");
        assert!(format!("{err}").contains("col count"));
        assert_eq!(checked_usize(9, "y").unwrap(), 9);
    }
}
