//! Truly-sparse matrix substrate.
//!
//! Everything the paper's "customised and modularized software framework
//! for sparse neural networks" needs at the matrix level: CSR storage
//! ([`csr`]), the training kernels ([`ops`]) — forward, the fused
//! one-pass backward, and the two-kernel parity oracles — with their
//! worker-sharded parallel variants (see `rust/DESIGN.md` §4–§5), the
//! persistent kernel worker pool that serves every sharded dispatch on
//! the hot path ([`pool`], `rust/DESIGN.md` §9), the runtime-dispatched
//! SIMD microkernels every kernel entry point routes through ([`simd`],
//! `rust/DESIGN.md` §11), and Erdős–Rényi / weight initialisation
//! ([`init`]). No dense weight matrix is ever materialised on the
//! training path.

pub mod csr;
pub mod init;
pub mod ops;
pub mod pool;
pub mod simd;
pub mod storage;

pub use csr::CsrMatrix;
pub use storage::{Buf, MapRegion, MapSlice, Residency};
pub use init::{epsilon_density, er_sample_row, erdos_renyi, erdos_renyi_epsilon, WeightInit};
pub use ops::{
    spmm_backward_fused, spmm_forward_threaded, spmm_grad_input_threaded,
    spmm_grad_weights_threaded, Exec,
};
pub use pool::WorkerPool;
pub use simd::{detected_isa, Isa};
