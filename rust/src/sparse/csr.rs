//! CSR sparse weight matrices — the truly-sparse substrate.
//!
//! A layer's weights `W ∈ R^{n_in × n_out}` are stored row-major CSR with
//! **rows = input neurons**. This orientation serves every hot operation:
//!
//! * forward  `z[b,:]  += x[b,i] · row_i`         (stream rows, write one
//!   contiguous output row per sample)
//! * grad-W   `dW[i,j] += x[b,i] · dz[b,j]`        (aligned with `values`,
//!   so gradients exist *only* on existing links — the paper's point)
//! * grad-X   `dx[b,i]  = Σ_j w[i,j] · dz[b,j]`    (row dot)
//!
//! Column indices within a row are kept sorted; all structural mutations
//! (SET prune/regrow, importance pruning) rebuild in one pass and report
//! an old-index mapping so aligned optimizer state (momentum) survives.

use crate::error::{Result, TsnnError};
use crate::sparse::storage::{checked_u32, Buf};

/// Sparse weight matrix in CSR layout (rows = inputs, cols = outputs).
///
/// The three arrays live in a [`Buf`] each: plain `Vec`s everywhere on
/// the normal path, or windows into one mmap-backed segment file under
/// the out-of-core subsystem (`bigmodel`, DESIGN.md §14). `Buf` derefs
/// to `[T]`, so kernels and analysis code index/slice these fields
/// exactly as before regardless of backing.
///
/// Index-width contract: `col_idx` stays `u32` (cache-footprint choice,
/// so a single layer is capped at 2^32 columns — checked, not assumed),
/// while row offsets and nnz totals are `usize`/`u64` end-to-end so
/// total edge counts past 4B are representable.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    /// Number of rows (input neurons / fan-in dimension).
    pub n_rows: usize,
    /// Number of columns (output neurons / fan-out dimension).
    pub n_cols: usize,
    /// Row start offsets, length `n_rows + 1`.
    pub row_ptr: Buf<usize>,
    /// Column index of each stored entry, sorted within each row.
    pub col_idx: Buf<u32>,
    /// Weight value of each stored entry, aligned with `col_idx`.
    pub values: Buf<f32>,
}

impl CsrMatrix {
    /// Empty matrix with the given shape (no stored entries).
    pub fn empty(n_rows: usize, n_cols: usize) -> Self {
        CsrMatrix {
            n_rows,
            n_cols,
            row_ptr: vec![0; n_rows + 1].into(),
            col_idx: Buf::new(),
            values: Buf::new(),
        }
    }

    /// Build from COO triplets (row, col, value). Duplicates are rejected.
    ///
    /// # Examples
    ///
    /// ```
    /// use tsnn::sparse::CsrMatrix;
    ///
    /// let m = CsrMatrix::from_coo(2, 3, vec![(0, 1, 0.5), (1, 0, -1.0)]).unwrap();
    /// assert_eq!(m.nnz(), 2);
    /// assert_eq!(m.get(0, 1), 0.5);
    /// assert_eq!(m.get(1, 2), 0.0); // absent entry
    ///
    /// // duplicate and out-of-bounds entries are rejected
    /// assert!(CsrMatrix::from_coo(1, 1, vec![(0, 0, 1.0), (0, 0, 2.0)]).is_err());
    /// assert!(CsrMatrix::from_coo(1, 1, vec![(0, 7, 1.0)]).is_err());
    /// ```
    pub fn from_coo(
        n_rows: usize,
        n_cols: usize,
        mut triplets: Vec<(u32, u32, f32)>,
    ) -> Result<Self> {
        checked_u32(n_rows, "CSR row count")?;
        checked_u32(n_cols, "CSR column count")?;
        triplets.sort_unstable_by_key(|&(r, c, _)| (r, c));
        for w in triplets.windows(2) {
            if w[0].0 == w[1].0 && w[0].1 == w[1].1 {
                return Err(TsnnError::Sparse(format!(
                    "duplicate entry at ({}, {})",
                    w[0].0, w[0].1
                )));
            }
        }
        let mut row_ptr = vec![0usize; n_rows + 1];
        let mut col_idx = Vec::with_capacity(triplets.len());
        let mut values = Vec::with_capacity(triplets.len());
        for &(r, c, v) in &triplets {
            if r as usize >= n_rows || c as usize >= n_cols {
                return Err(TsnnError::Sparse(format!(
                    "entry ({r}, {c}) out of bounds for {n_rows}x{n_cols}"
                )));
            }
            row_ptr[r as usize + 1] += 1;
            col_idx.push(c);
            values.push(v);
        }
        for i in 0..n_rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        Ok(CsrMatrix {
            n_rows,
            n_cols,
            row_ptr: row_ptr.into(),
            col_idx: col_idx.into(),
            values: values.into(),
        })
    }

    /// Number of stored entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of possible entries that are stored.
    pub fn density(&self) -> f64 {
        if self.n_rows == 0 || self.n_cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.n_rows as f64 * self.n_cols as f64)
    }

    /// Column/value slices of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[s..e], &self.values[s..e])
    }

    /// Row ids ordered by descending nnz length (ties keep ascending row
    /// order — the sort is stable), the processing order of the
    /// length-sorted LPT row schedule (DESIGN.md §11.4). Computed on
    /// demand: `CsrMatrix` derives `PartialEq`/`Clone`, so a cached
    /// permutation field would poison equality and rebuild invariants.
    pub fn rows_by_nnz_desc(&self) -> Vec<u32> {
        let mut order: Vec<u32> = (0..self.n_rows as u32).collect();
        order.sort_by_key(|&r| {
            let r = r as usize;
            std::cmp::Reverse(self.row_ptr[r + 1] - self.row_ptr[r])
        });
        order
    }

    /// Storage index of entry `(i, j)` if present (binary search).
    #[inline]
    pub fn find(&self, i: usize, j: u32) -> Option<usize> {
        let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
        self.col_idx[s..e].binary_search(&j).ok().map(|k| s + k)
    }

    /// Value of entry `(i, j)`, or 0.0 if absent.
    pub fn get(&self, i: usize, j: u32) -> f32 {
        self.find(i, j).map(|k| self.values[k]).unwrap_or(0.0)
    }

    /// Iterate all `(row, col, value)` triplets in order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, u32, f32)> + '_ {
        (0..self.n_rows).flat_map(move |i| {
            let (cols, vals) = self.row(i);
            cols.iter()
                .zip(vals.iter())
                .map(move |(&c, &v)| (i, c, v))
        })
    }

    /// Dense materialisation (row-major) — test/debug helper.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut d = vec![0.0f32; self.n_rows * self.n_cols];
        for (i, j, v) in self.iter() {
            d[i * self.n_cols + j as usize] = v;
        }
        d
    }

    /// Sum of |w| per column — the paper's neuron importance (Eq. 4):
    /// `I_j = Σ_i |w_ij|` over incoming connections of output neuron j.
    pub fn column_abs_sums(&self) -> Vec<f32> {
        let mut sums = vec![0.0f32; self.n_cols];
        for (&j, &v) in self.col_idx.iter().zip(self.values.iter()) {
            sums[j as usize] += v.abs();
        }
        sums
    }

    /// Number of stored entries per column (in-degree of output neurons).
    pub fn column_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_cols];
        for &j in &self.col_idx {
            counts[j as usize] += 1;
        }
        counts
    }

    /// Column index of the `g`-th (0-based) **empty** position of row `i`,
    /// counting empty columns in ascending order — the gap-selection
    /// primitive of SET regrowth (binary search over the row's stored
    /// columns, O(log deg)).
    ///
    /// `g` must be less than the row's empty count
    /// (`n_cols - row degree`); checked in debug builds only.
    ///
    /// # Examples
    ///
    /// ```
    /// use tsnn::sparse::CsrMatrix;
    ///
    /// // row 0 stores columns {1, 3}; empties are {0, 2, 4}
    /// let m = CsrMatrix::from_coo(1, 5, vec![(0, 1, 1.0), (0, 3, 1.0)]).unwrap();
    /// assert_eq!(m.nth_empty_in_row(0, 0), 0);
    /// assert_eq!(m.nth_empty_in_row(0, 1), 2);
    /// assert_eq!(m.nth_empty_in_row(0, 2), 4);
    /// ```
    pub fn nth_empty_in_row(&self, i: usize, g: usize) -> u32 {
        let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
        let cols = &self.col_idx[s..e];
        debug_assert!(g < self.n_cols - cols.len(), "gap ordinal out of range");
        // count stored columns c_t with c_t - t <= g: each such column
        // sits before the g-th empty, shifting it one slot right
        let (mut lo, mut hi) = (0usize, cols.len());
        while lo < hi {
            let mid = (lo + hi) / 2;
            if cols[mid] as usize - mid <= g {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        (g + lo) as u32
    }

    /// Validate structural invariants (sorted unique cols, monotone ptrs,
    /// dimensions within the u32 column-index width).
    pub fn validate(&self) -> Result<()> {
        checked_u32(self.n_rows, "CSR row count")?;
        checked_u32(self.n_cols, "CSR column count")?;
        if self.row_ptr.len() != self.n_rows + 1 {
            return Err(TsnnError::Sparse("row_ptr length".into()));
        }
        if self.row_ptr[0] != 0 || *self.row_ptr.last().unwrap() != self.nnz() {
            return Err(TsnnError::Sparse("row_ptr ends".into()));
        }
        if self.col_idx.len() != self.values.len() {
            return Err(TsnnError::Sparse("col/val length mismatch".into()));
        }
        for i in 0..self.n_rows {
            if self.row_ptr[i] > self.row_ptr[i + 1] {
                return Err(TsnnError::Sparse(format!("row_ptr not monotone at {i}")));
            }
            let (cols, _) = self.row(i);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    return Err(TsnnError::Sparse(format!(
                        "row {i} cols not sorted-unique"
                    )));
                }
            }
            if let Some(&last) = cols.last() {
                if last as usize >= self.n_cols {
                    return Err(TsnnError::Sparse(format!("row {i} col out of range")));
                }
            }
        }
        Ok(())
    }

    /// Keep only entries where `keep(storage_index)` is true. Returns the
    /// old storage index of each surviving entry (aligned to new `values`)
    /// so callers can remap aligned optimizer state.
    pub fn retain(&mut self, mut keep: impl FnMut(usize) -> bool) -> Vec<usize> {
        let mut kept = Vec::with_capacity(self.nnz());
        let mut new_ptr = vec![0usize; self.n_rows + 1];
        let mut w = 0usize;
        let row_ptr = self.row_ptr.as_slice();
        let cols = self.col_idx.as_mut_slice();
        let vals = self.values.as_mut_slice();
        for i in 0..self.n_rows {
            let (s, e) = (row_ptr[i], row_ptr[i + 1]);
            for k in s..e {
                if keep(k) {
                    cols[w] = cols[k];
                    vals[w] = vals[k];
                    kept.push(k);
                    w += 1;
                }
            }
            new_ptr[i + 1] = w;
        }
        self.col_idx.truncate(w);
        self.values.truncate(w);
        self.row_ptr = new_ptr.into();
        kept
    }

    /// Insert new entries given as `(row, col, value)`; positions must be
    /// currently empty and unique. Returns the new storage indices of the
    /// *pre-existing* entries (aligned old→new) so aligned state can be
    /// remapped; inserted entries occupy the remaining slots.
    pub fn insert(&mut self, mut additions: Vec<(u32, u32, f32)>) -> Result<Vec<usize>> {
        if additions.is_empty() {
            return Ok((0..self.nnz()).collect());
        }
        additions.sort_unstable_by_key(|&(r, c, _)| (r, c));
        for w in additions.windows(2) {
            if w[0].0 == w[1].0 && w[0].1 == w[1].1 {
                return Err(TsnnError::Sparse(format!(
                    "duplicate insertion at ({}, {})",
                    w[0].0, w[0].1
                )));
            }
        }
        for &(r, c, _) in &additions {
            if r as usize >= self.n_rows || c as usize >= self.n_cols {
                return Err(TsnnError::Sparse("insertion out of bounds".into()));
            }
            if self.find(r as usize, c).is_some() {
                return Err(TsnnError::Sparse(format!(
                    "insertion at occupied position ({r}, {c})"
                )));
            }
        }
        let new_nnz = self.nnz() + additions.len();
        let mut col_idx = Vec::with_capacity(new_nnz);
        let mut values = Vec::with_capacity(new_nnz);
        let mut row_ptr = vec![0usize; self.n_rows + 1];
        let mut old_to_new = vec![0usize; self.nnz()];
        let mut a = 0usize; // cursor into additions
        for i in 0..self.n_rows {
            let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
            let mut k = s;
            // merge sorted existing row with sorted additions for this row
            while k < e || (a < additions.len() && additions[a].0 as usize == i) {
                let take_add = if k >= e {
                    true
                } else if a >= additions.len() || additions[a].0 as usize != i {
                    false
                } else {
                    additions[a].1 < self.col_idx[k]
                };
                if take_add {
                    col_idx.push(additions[a].1);
                    values.push(additions[a].2);
                    a += 1;
                } else {
                    old_to_new[k] = col_idx.len();
                    col_idx.push(self.col_idx[k]);
                    values.push(self.values[k]);
                    k += 1;
                }
            }
            row_ptr[i + 1] = col_idx.len();
        }
        self.col_idx = col_idx.into();
        self.values = values.into();
        self.row_ptr = row_ptr.into();
        Ok(old_to_new)
    }

    /// Transposed copy (rows ↔ cols). Used by tests and analysis tools.
    pub fn transpose(&self) -> CsrMatrix {
        let mut row_ptr = vec![0usize; self.n_cols + 1];
        for &j in &self.col_idx {
            row_ptr[j as usize + 1] += 1;
        }
        for j in 0..self.n_cols {
            row_ptr[j + 1] += row_ptr[j];
        }
        let mut col_idx = vec![0u32; self.nnz()];
        let mut values = vec![0.0f32; self.nnz()];
        let mut cursor = row_ptr.clone();
        for (i, j, v) in self.iter() {
            let p = cursor[j as usize];
            col_idx[p] = i as u32;
            values[p] = v;
            cursor[j as usize] += 1;
        }
        CsrMatrix {
            n_rows: self.n_cols,
            n_cols: self.n_rows,
            row_ptr: row_ptr.into(),
            col_idx: col_idx.into(),
            values: values.into(),
        }
    }

    /// Memory footprint of the stored representation in bytes.
    pub fn memory_bytes(&self) -> usize {
        self.row_ptr.len() * std::mem::size_of::<usize>()
            + self.col_idx.len() * std::mem::size_of::<u32>()
            + self.values.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        // 3x4:
        // [1 0 2 0]
        // [0 0 0 3]
        // [0 4 0 5]
        CsrMatrix::from_coo(
            3,
            4,
            vec![(0, 0, 1.0), (0, 2, 2.0), (1, 3, 3.0), (2, 1, 4.0), (2, 3, 5.0)],
        )
        .unwrap()
    }

    #[test]
    fn from_coo_builds_sorted_csr() {
        let m = sample();
        m.validate().unwrap();
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.row_ptr, vec![0, 2, 3, 5]);
        assert_eq!(m.row(0), (&[0u32, 2][..], &[1.0f32, 2.0][..]));
    }

    #[test]
    fn from_coo_rejects_duplicates_and_oob() {
        assert!(CsrMatrix::from_coo(2, 2, vec![(0, 0, 1.0), (0, 0, 2.0)]).is_err());
        assert!(CsrMatrix::from_coo(2, 2, vec![(2, 0, 1.0)]).is_err());
        assert!(CsrMatrix::from_coo(2, 2, vec![(0, 5, 1.0)]).is_err());
    }

    #[test]
    fn rows_by_nnz_desc_is_stable_and_total() {
        let m = sample(); // row lengths 2, 1, 2
        assert_eq!(m.rows_by_nnz_desc(), vec![0, 2, 1]);
        // empty rows sort last but are still present
        let m = CsrMatrix::from_coo(4, 2, vec![(2, 0, 1.0), (2, 1, 2.0), (3, 0, 3.0)]).unwrap();
        assert_eq!(m.rows_by_nnz_desc(), vec![2, 3, 0, 1]);
        assert_eq!(CsrMatrix::empty(3, 3).rows_by_nnz_desc(), vec![0, 1, 2]);
    }

    #[test]
    fn get_and_find() {
        let m = sample();
        assert_eq!(m.get(0, 2), 2.0);
        assert_eq!(m.get(0, 1), 0.0);
        assert_eq!(m.find(2, 3), Some(4));
        assert_eq!(m.find(1, 0), None);
    }

    #[test]
    fn dense_roundtrip() {
        let m = sample();
        let d = m.to_dense();
        assert_eq!(
            d,
            vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0, 3.0, 0.0, 4.0, 0.0, 5.0]
        );
    }

    #[test]
    fn column_abs_sums_match_definition() {
        let m = sample();
        assert_eq!(m.column_abs_sums(), vec![1.0, 4.0, 2.0, 8.0]);
        assert_eq!(m.column_counts(), vec![1, 1, 1, 2]);
    }

    #[test]
    fn retain_keeps_mapping() {
        let mut m = sample();
        // drop all entries with value < 3
        let vals = m.values.clone();
        let kept = m.retain(|k| vals[k] >= 3.0);
        m.validate().unwrap();
        assert_eq!(m.nnz(), 3);
        assert_eq!(kept, vec![2, 3, 4]);
        assert_eq!(m.values, vec![3.0, 4.0, 5.0]);
        assert_eq!(m.get(0, 0), 0.0);
    }

    #[test]
    fn insert_merges_sorted() {
        let mut m = sample();
        let old_to_new = m
            .insert(vec![(0, 1, 9.0), (1, 0, 8.0), (2, 0, 7.0)])
            .unwrap();
        m.validate().unwrap();
        assert_eq!(m.nnz(), 8);
        assert_eq!(m.get(0, 1), 9.0);
        assert_eq!(m.get(1, 0), 8.0);
        // old entry 0 was (0,0): still storage index 0; old entry 1 was
        // (0,2): shifted by inserted (0,1)
        assert_eq!(old_to_new[0], 0);
        assert_eq!(old_to_new[1], 2);
        assert_eq!(m.values[old_to_new[4]], 5.0);
    }

    #[test]
    fn insert_rejects_occupied_and_duplicates() {
        let mut m = sample();
        assert!(m.insert(vec![(0, 0, 1.0)]).is_err());
        let mut m2 = sample();
        assert!(m2.insert(vec![(1, 1, 1.0), (1, 1, 2.0)]).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let m = sample();
        let t = m.transpose();
        t.validate().unwrap();
        assert_eq!(t.n_rows, 4);
        assert_eq!(t.get(3, 1), 3.0);
        assert_eq!(t.transpose().to_dense(), m.to_dense());
    }

    #[test]
    fn nth_empty_enumerates_all_gaps() {
        let m = sample(); // row 0: {0, 2} stored -> empties {1, 3}
        assert_eq!(m.nth_empty_in_row(0, 0), 1);
        assert_eq!(m.nth_empty_in_row(0, 1), 3);
        // row 1: {3} stored -> empties {0, 1, 2}
        for g in 0..3 {
            assert_eq!(m.nth_empty_in_row(1, g), g as u32);
        }
        // exhaustive cross-check against a scan, incl. an empty row
        let m2 = CsrMatrix::from_coo(3, 7, vec![(0, 0, 1.0), (0, 6, 1.0), (2, 3, 1.0)]).unwrap();
        for i in 0..3 {
            let stored: Vec<u32> = m2.row(i).0.to_vec();
            let empties: Vec<u32> =
                (0..7u32).filter(|c| !stored.contains(c)).collect();
            for (g, &c) in empties.iter().enumerate() {
                assert_eq!(m2.nth_empty_in_row(i, g), c, "row {i} gap {g}");
            }
        }
    }

    #[test]
    fn empty_matrix() {
        let m = CsrMatrix::empty(5, 7);
        m.validate().unwrap();
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.density(), 0.0);
        assert_eq!(m.column_abs_sums(), vec![0.0; 7]);
    }

    #[test]
    fn memory_accounting() {
        let m = sample();
        assert_eq!(m.memory_bytes(), 4 * 8 + 5 * 4 + 5 * 4);
    }

    #[cfg(target_pointer_width = "64")]
    #[test]
    fn dimensions_past_u32_are_typed_errors() {
        let too_wide = u32::MAX as usize + 1;
        let err = CsrMatrix::from_coo(2, too_wide, vec![]).unwrap_err();
        assert!(matches!(err, TsnnError::IndexOverflow(_)), "{err}");
        let err = CsrMatrix::from_coo(too_wide, 2, vec![]).unwrap_err();
        assert!(matches!(err, TsnnError::IndexOverflow(_)), "{err}");
        // validate applies the same guard to hand-built matrices
        let mut m = CsrMatrix::empty(1, 1);
        m.n_cols = too_wide;
        m.row_ptr = vec![0, 0].into();
        assert!(matches!(
            m.validate().unwrap_err(),
            TsnnError::IndexOverflow(_)
        ));
    }
}
