//! Runtime-dispatched SIMD microkernels (DESIGN.md §11).
//!
//! Every CSR hot kernel ([`spmm_forward`](super::ops::spmm_forward),
//! `grad_input`, `grad_weights`, `backward_fused`) and the serve-path
//! dense-fallback kernel exists in up to three bodies: the scalar
//! BLOCK=8 reference (the kernels in `ops.rs` / formerly
//! `serve/layout.rs`), an AVX2 body on x86_64, and a NEON body on
//! aarch64; AVX-512 additionally widens the dense kernel to 16 lanes
//! (its CSR entries reuse the AVX2 bodies — see §11.2 for why no
//! AVX-512 gathers). The ISA is detected **once per process**
//! ([`detected_isa`], `is_x86_feature_detected!`), overridable for
//! testing via the `TSNN_ISA` env var, and carried on
//! [`Exec`](super::ops::Exec) so every dispatch path — sequential,
//! scoped, pooled — routes through the same [`KernelTable`].
//!
//! **Tolerance policy: none.** Every SIMD body reproduces the scalar
//! kernel **bit-exactly**: no FMA contraction (separate multiply + add
//! intrinsics, matching rustc's non-contracted scalar codegen), no
//! horizontal reductions (lane `t` of a vector accumulator is exactly
//! the scalar kernel's `acc[t]`), and identical per-output-element
//! accumulation order. The parity suites assert `==`, never a
//! tolerance — see DESIGN.md §11.3 for the per-kernel argument.

#![allow(clippy::needless_range_loop)]

use super::csr::CsrMatrix;
use super::ops::{self, BLOCK, ShardPtr};
use std::sync::OnceLock;

// ---------------------------------------------------------------------------
// ISA detection and selection.

/// Instruction set a kernel table is built for. Detected once per
/// process ([`detected_isa`]); force a specific set with `TSNN_ISA`
/// (`scalar` / `avx2` / `avx512` / `neon` / `native`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Portable BLOCK=8 scalar kernels (every host; the parity oracle).
    Scalar,
    /// 256-bit AVX2 (+ gathers) on x86_64.
    Avx2,
    /// AVX-512F on x86_64: 16-lane dense kernel, CSR entries reuse AVX2.
    Avx512,
    /// 128-bit NEON pairs on aarch64.
    Neon,
}

impl Isa {
    /// Stable lowercase name (the `TSNN_ISA` spelling).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
            Isa::Neon => "neon",
        }
    }

    /// Parse a `TSNN_ISA` spelling (`native` is handled by the caller:
    /// it means [`best_isa`], not a fixed variant).
    fn parse(s: &str) -> Option<Isa> {
        match s {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "avx512" | "avx512f" => Some(Isa::Avx512),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }

    /// Whether the *running host* can execute this ISA's kernels
    /// (compile-target and runtime feature detection combined).
    pub fn supported(self) -> bool {
        match self {
            Isa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx512 => is_x86_feature_detected!("avx512f"),
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => true,
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// Every ISA the running host supports, scalar first. This is the
    /// host capability set — it is **not** filtered by `TSNN_ISA` (the
    /// parity suites iterate it to force every reachable path).
    pub fn available() -> Vec<Isa> {
        [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon]
            .into_iter()
            .filter(|isa| isa.supported())
            .collect()
    }
}

/// Widest ISA the running host supports.
fn best_isa() -> Isa {
    #[allow(unused_mut)] // stays Scalar on non-SIMD targets
    let mut best = Isa::Scalar;
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            best = Isa::Avx2;
        }
        if is_x86_feature_detected!("avx512f") {
            best = Isa::Avx512;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        best = Isa::Neon;
    }
    best
}

/// Process-wide selected ISA, resolved once: `TSNN_ISA` when set to a
/// *supported* ISA (`native` or empty = widest available; an
/// unsupported or unknown value warns on stderr and falls back to the
/// widest available — forcing an ISA the host cannot run would be UB,
/// not a test mode). Every [`Exec`](super::ops::Exec) constructor
/// defaults to this; [`Exec::with_isa`](super::ops::Exec::with_isa)
/// overrides it per-context without touching process state.
pub fn detected_isa() -> Isa {
    static CACHE: OnceLock<Isa> = OnceLock::new();
    *CACHE.get_or_init(|| {
        let Ok(raw) = std::env::var("TSNN_ISA") else {
            return best_isa();
        };
        let t = raw.trim().to_ascii_lowercase();
        if t.is_empty() || t == "native" {
            return best_isa();
        }
        match Isa::parse(&t) {
            Some(isa) if isa.supported() => isa,
            Some(isa) => {
                eprintln!(
                    "tsnn: TSNN_ISA={} is not supported on this host; using {}",
                    isa.name(),
                    best_isa().name()
                );
                best_isa()
            }
            None => {
                eprintln!(
                    "tsnn: TSNN_ISA={raw:?} not recognised (scalar/avx2/avx512/neon/native); \
                     using {}",
                    best_isa().name()
                );
                best_isa()
            }
        }
    })
}

// ---------------------------------------------------------------------------
// Kernel-selection table (ISA × kernel; format is the caller's axis).

/// Weight-storage format a microkernel serves — the second axis of the
/// selection table (the CSR kernels serve training + CSR-served layers,
/// the dense kernel serves dense-fallback layers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelFormat {
    /// Truly-sparse CSR storage.
    Csr,
    /// Row-major dense-fallback storage (serve path).
    Dense,
}

/// Name of the microkernel body that `isa` actually dispatches for
/// `format` — including silent fallbacks (an unsupported ISA resolves
/// to scalar; AVX-512's CSR entries are the AVX2 bodies). Printed by
/// `tsnn inspect` / `serve-bench` so dispatch is observable.
pub fn microkernel_name(isa: Isa, format: KernelFormat) -> &'static str {
    match (kernel_table(isa).isa, format) {
        (Isa::Scalar, KernelFormat::Csr) => "csr_block8_scalar",
        (Isa::Scalar, KernelFormat::Dense) => "dense_block8_scalar",
        (Isa::Avx2, KernelFormat::Csr) => "csr_block8_avx2",
        (Isa::Avx2, KernelFormat::Dense) => "dense_lanes8_avx2",
        (Isa::Avx512, KernelFormat::Csr) => "csr_block8_avx2", // CSR reuses AVX2 (§11.2)
        (Isa::Avx512, KernelFormat::Dense) => "dense_lanes16_avx512",
        (Isa::Neon, KernelFormat::Csr) => "csr_block8_neon",
        (Isa::Neon, KernelFormat::Dense) => "dense_lanes4x2_neon",
    }
}

/// `spmm_forward`-shaped entry: `(x, batch, w, out)`.
pub(crate) type ForwardFn = unsafe fn(&[f32], usize, &CsrMatrix, &mut [f32]);
/// `spmm_grad_input`-shaped entry: `(dz, batch, w, dx)`.
pub(crate) type GradInputFn = unsafe fn(&[f32], usize, &CsrMatrix, &mut [f32]);
/// `grad_weights_rows`-shaped entry: `(x, dz, batch, w, row0, row1, dw)`.
pub(crate) type GradWeightsRowsFn =
    unsafe fn(&[f32], &[f32], usize, &CsrMatrix, usize, usize, &mut [f32]);
/// `backward_fused_rows`-shaped entry:
/// `(x, dz, batch, w, row0, row1, dx, dw)`.
pub(crate) type BackwardFusedRowsFn =
    unsafe fn(&[f32], &[f32], usize, &CsrMatrix, usize, usize, ShardPtr<f32>, &mut [f32]);
/// Dense-fallback forward entry: `(x, batch, n_in, n_out, w, out)`.
pub(crate) type DenseForwardFn = unsafe fn(&[f32], usize, usize, usize, &[f32], &mut [f32]);

/// One ISA's bodies for every hot kernel. All entries are `unsafe fn`:
/// the caller (the `*_exec` dispatchers in `ops.rs` and
/// `serve/layout.rs`, or a test) guarantees the scalar kernels' length
/// / validated-CSR preconditions **and** that the table's ISA is
/// supported on the running host ([`kernel_table`] guarantees the
/// latter for every table it hands out).
pub(crate) struct KernelTable {
    /// ISA these bodies require (normalised: what actually runs).
    pub(crate) isa: Isa,
    /// Forward `out += x · W` over pre-zeroed/pre-biased `out`.
    pub(crate) forward: ForwardFn,
    /// Input gradient `dx = dz · Wᵀ` (overwrites `dx`).
    pub(crate) grad_input: GradInputFn,
    /// Pattern-restricted weight gradient over rows `[row0, row1)`.
    pub(crate) grad_weights_rows: GradWeightsRowsFn,
    /// Fused `dx` + `dw` over rows `[row0, row1)`.
    pub(crate) backward_fused_rows: BackwardFusedRowsFn,
    /// Dense-fallback forward over pre-biased `out`.
    pub(crate) dense_forward: DenseForwardFn,
}

/// The table serving `isa`, total over every variant: an ISA the
/// running host does not support resolves to the scalar table (cheap
/// runtime re-check — defense in depth on top of
/// [`Exec::with_isa`](super::ops::Exec::with_isa)'s clamp), and
/// AVX-512 reuses the AVX2 CSR bodies (every `avx512f` host also has
/// AVX2).
pub(crate) fn kernel_table(isa: Isa) -> &'static KernelTable {
    match isa {
        Isa::Scalar => &SCALAR_TABLE,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 if is_x86_feature_detected!("avx2") => &AVX2_TABLE,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx512 if is_x86_feature_detected!("avx512f") => &AVX512_TABLE,
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => &NEON_TABLE,
        _ => &SCALAR_TABLE,
    }
}

// ---------------------------------------------------------------------------
// Scalar entries: thin `unsafe fn` wrappers around the reference
// kernels (which stay safe `pub` fns — they are the parity oracles).

unsafe fn scalar_forward(x: &[f32], batch: usize, w: &CsrMatrix, out: &mut [f32]) {
    ops::spmm_forward(x, batch, w, out)
}

unsafe fn scalar_grad_input(dz: &[f32], batch: usize, w: &CsrMatrix, dx: &mut [f32]) {
    ops::spmm_grad_input(dz, batch, w, dx)
}

unsafe fn scalar_grad_weights_rows(
    x: &[f32],
    dz: &[f32],
    batch: usize,
    w: &CsrMatrix,
    row0: usize,
    row1: usize,
    dw: &mut [f32],
) {
    ops::grad_weights_rows(x, dz, batch, w, row0, row1, dw)
}

#[allow(clippy::too_many_arguments)]
unsafe fn scalar_backward_fused_rows(
    x: &[f32],
    dz: &[f32],
    batch: usize,
    w: &CsrMatrix,
    row0: usize,
    row1: usize,
    dx: ShardPtr<f32>,
    dw: &mut [f32],
) {
    ops::backward_fused_rows(x, dz, batch, w, row0, row1, dx, dw)
}

unsafe fn scalar_dense_forward(
    x: &[f32],
    batch: usize,
    n_in: usize,
    n_out: usize,
    w: &[f32],
    out: &mut [f32],
) {
    dense_forward_scalar(x, batch, n_in, n_out, w, out)
}

static SCALAR_TABLE: KernelTable = KernelTable {
    isa: Isa::Scalar,
    forward: scalar_forward,
    grad_input: scalar_grad_input,
    grad_weights_rows: scalar_grad_weights_rows,
    backward_fused_rows: scalar_backward_fused_rows,
    dense_forward: scalar_dense_forward,
};

/// Sequential dense-row forward (scalar reference): `out[b, :] +=
/// Σ_i x[b, i] * W[i, :]` over pre-biased `out`, mirroring the CSR
/// kernel's batch blocking and block-level activation-sparsity skip so
/// stored-entry contributions land in the training kernel's exact
/// floating-point order (the serving parity argument, DESIGN.md §10.1).
/// Lives here (moved from `serve/layout.rs`) so the dense format is a
/// first-class row of the kernel-selection table.
pub(crate) fn dense_forward_scalar(
    x: &[f32],
    batch: usize,
    n_in: usize,
    n_out: usize,
    w: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), batch * n_in);
    debug_assert_eq!(out.len(), batch * n_out);
    debug_assert_eq!(w.len(), n_in * n_out);
    let mut b0 = 0usize;
    while b0 < batch {
        let bl = (batch - b0).min(BLOCK);
        for i in 0..n_in {
            let mut xv = [0.0f32; BLOCK];
            let mut any = false;
            for (t, xvt) in xv.iter_mut().enumerate().take(bl) {
                let v = x[(b0 + t) * n_in + i];
                *xvt = v;
                any |= v != 0.0;
            }
            if !any {
                continue;
            }
            let row = &w[i * n_out..(i + 1) * n_out];
            for (t, &xvt) in xv.iter().enumerate().take(bl) {
                let o = &mut out[(b0 + t) * n_out..(b0 + t + 1) * n_out];
                for (oj, &wj) in o.iter_mut().zip(row.iter()) {
                    *oj += xvt * wj;
                }
            }
        }
        b0 += bl;
    }
}

// ---------------------------------------------------------------------------
// Per-thread transpose scratch for the vector CSR kernels. One buffer
// per thread, take/put around each kernel invocation: no closures are
// passed into `#[target_feature]` fns (feature inheritance into
// closures is a footgun) and a panicking kernel merely loses the
// buffer — never double-borrows or leaves it aliased.

std::thread_local! {
    static SCRATCH: std::cell::RefCell<Vec<f32>> = std::cell::RefCell::new(Vec::new());
}

/// Borrow this thread's scratch buffer, grown to at least `len`.
fn take_scratch(len: usize) -> Vec<f32> {
    let mut buf = SCRATCH.with(|c| std::mem::take(&mut *c.borrow_mut()));
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
    buf
}

/// Return the scratch buffer for reuse by the next kernel call.
fn put_scratch(buf: Vec<f32>) {
    SCRATCH.with(|c| *c.borrow_mut() = buf);
}

// ---------------------------------------------------------------------------
// x86_64: AVX2 bodies (+ an AVX-512 dense widening).
//
// Bit-exactness recipe (DESIGN.md §11.3): vector lane `t` carries
// exactly the scalar kernel's accumulator `acc[t]` (no horizontal
// reductions), every product+sum is a separate `_mm*_mul_ps` +
// `_mm*_add_ps` (rustc does not contract the scalar kernels into FMA,
// so neither may we), and loop nesting preserves the scalar kernel's
// per-output-element accumulation order. Ragged batch tails (< BLOCK
// samples) delegate to the scalar kernels on disjoint sample
// sub-slices, which keeps `dw`'s ascending batch-block order intact.

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::super::csr::CsrMatrix;
    use super::super::ops::{backward_fused_rows, spmm_forward, spmm_grad_input, BLOCK, ShardPtr};
    use super::{put_scratch, take_scratch, Isa, KernelTable};
    use std::arch::x86_64::*;

    /// Gathers index `col_idx` as sign-extended i32: col indices must
    /// stay below 2³¹ or the slot-vectorized kernels fall back to
    /// scalar (never hit in practice — layers are ≪ 2³¹ wide).
    const GATHER_MAX_COLS: usize = i32::MAX as usize;

    /// AVX2 forward: transposed per-block accumulator `outT[n_out][8]`
    /// in thread scratch. Transpose-in copies the pre-biased `out`
    /// block, each `(i, k)` contribution lands as one 8-lane
    /// `add(outT_j, mul(xv, set1(v)))` — lane `t` sees the scalar
    /// kernel's exact `(i, k)` order — then transpose-out stores back.
    ///
    /// # Safety
    /// AVX2 available; scalar `spmm_forward` preconditions.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn forward(x: &[f32], batch: usize, w: &CsrMatrix, out: &mut [f32]) {
        let (n_in, n_out) = (w.n_rows, w.n_cols);
        assert_eq!(x.len(), batch * n_in);
        assert_eq!(out.len(), batch * n_out);
        let full = batch - batch % BLOCK;
        if full > 0 && n_out > 0 {
            let row_ptr = w.row_ptr.as_slice();
            let col_idx = w.col_idx.as_slice();
            let values = w.values.as_slice();
            let mut scratch = take_scratch(n_out * BLOCK);
            let outt = &mut scratch[..n_out * BLOCK];
            let mut b0 = 0usize;
            while b0 < full {
                for j in 0..n_out {
                    for t in 0..BLOCK {
                        *outt.get_unchecked_mut(j * BLOCK + t) =
                            *out.get_unchecked((b0 + t) * n_out + j);
                    }
                }
                for i in 0..n_in {
                    let mut xv = [0.0f32; BLOCK];
                    let mut any = false;
                    for t in 0..BLOCK {
                        let v = *x.get_unchecked((b0 + t) * n_in + i);
                        xv[t] = v;
                        any |= v != 0.0;
                    }
                    if !any {
                        continue;
                    }
                    let xv_vec = _mm256_loadu_ps(xv.as_ptr());
                    let s = *row_ptr.get_unchecked(i);
                    let e = *row_ptr.get_unchecked(i + 1);
                    for k in s..e {
                        let j = *col_idx.get_unchecked(k) as usize;
                        let v = *values.get_unchecked(k);
                        let p = outt.as_mut_ptr().add(j * BLOCK);
                        let prod = _mm256_mul_ps(xv_vec, _mm256_set1_ps(v));
                        _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), prod));
                    }
                }
                for j in 0..n_out {
                    for t in 0..BLOCK {
                        *out.get_unchecked_mut((b0 + t) * n_out + j) =
                            *outt.get_unchecked(j * BLOCK + t);
                    }
                }
                b0 += BLOCK;
            }
            put_scratch(scratch);
        }
        let tail = batch - full;
        if tail > 0 {
            spmm_forward(&x[full * n_in..], tail, w, &mut out[full * n_out..]);
        }
    }

    /// AVX2 input gradient: per-block transposed `dzT[n_out][8]`
    /// (read-only), 8-lane accumulator over `k` ascending as
    /// `add(acc, mul(set1(v), dzT_j))` — lane `t` is the scalar
    /// kernel's `acc[t]` — stored per-lane into `dx`'s strided columns.
    ///
    /// # Safety
    /// AVX2 available; scalar `spmm_grad_input` preconditions.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn grad_input(dz: &[f32], batch: usize, w: &CsrMatrix, dx: &mut [f32]) {
        let (n_in, n_out) = (w.n_rows, w.n_cols);
        assert_eq!(dz.len(), batch * n_out);
        assert_eq!(dx.len(), batch * n_in);
        let full = batch - batch % BLOCK;
        if full > 0 {
            let row_ptr = w.row_ptr.as_slice();
            let col_idx = w.col_idx.as_slice();
            let values = w.values.as_slice();
            let mut scratch = take_scratch(n_out * BLOCK);
            let dzt = &mut scratch[..n_out * BLOCK];
            let mut b0 = 0usize;
            while b0 < full {
                for j in 0..n_out {
                    for t in 0..BLOCK {
                        *dzt.get_unchecked_mut(j * BLOCK + t) =
                            *dz.get_unchecked((b0 + t) * n_out + j);
                    }
                }
                for i in 0..n_in {
                    let s = *row_ptr.get_unchecked(i);
                    let e = *row_ptr.get_unchecked(i + 1);
                    let mut acc = _mm256_setzero_ps();
                    for k in s..e {
                        let j = *col_idx.get_unchecked(k) as usize;
                        let v = *values.get_unchecked(k);
                        let dzv = _mm256_loadu_ps(dzt.as_ptr().add(j * BLOCK));
                        acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(v), dzv));
                    }
                    let mut tmp = [0.0f32; BLOCK];
                    _mm256_storeu_ps(tmp.as_mut_ptr(), acc);
                    for t in 0..BLOCK {
                        *dx.get_unchecked_mut((b0 + t) * n_in + i) = tmp[t];
                    }
                }
                b0 += BLOCK;
            }
            put_scratch(scratch);
        }
        let tail = batch - full;
        if tail > 0 {
            spmm_grad_input(&dz[full * n_out..], tail, w, &mut dx[full * n_in..]);
        }
    }

    /// AVX2 weight gradient over rows `[row0, row1)`: vectorized over
    /// the **slot** axis — 8 `dw` slots per step, their `dz` operands
    /// fetched with `_mm256_i32gather_ps` per sample `t` (t ascending,
    /// sequential, so lane `m` accumulates in the scalar kernel's exact
    /// order: `acc += xv[t] * dz[...]`). The fresh 8-slot accumulator
    /// is added to `dw` once per batch block, like the scalar kernel's
    /// `dw[k - base] += acc`. Works at any batch-block width, so no
    /// batch-tail delegation; slot remainders (`row nnz % 8`) run
    /// scalar.
    ///
    /// # Safety
    /// AVX2 available; scalar `grad_weights_rows` preconditions.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn grad_weights_rows(
        x: &[f32],
        dz: &[f32],
        batch: usize,
        w: &CsrMatrix,
        row0: usize,
        row1: usize,
        dw: &mut [f32],
    ) {
        let (n_in, n_out) = (w.n_rows, w.n_cols);
        if n_out > GATHER_MAX_COLS {
            // full path: the scalar fn shares this fn's name
            return super::super::ops::grad_weights_rows(x, dz, batch, w, row0, row1, dw);
        }
        debug_assert!(row0 <= row1 && row1 <= n_in);
        debug_assert_eq!(x.len(), batch * n_in);
        debug_assert_eq!(dz.len(), batch * n_out);
        let row_ptr = w.row_ptr.as_slice();
        let col_idx = w.col_idx.as_slice();
        let base = row_ptr[row0];
        debug_assert_eq!(dw.len(), row_ptr[row1] - base);
        let mut b0 = 0usize;
        while b0 < batch {
            let bl = (batch - b0).min(BLOCK);
            for i in row0..row1 {
                let mut xv = [0.0f32; BLOCK];
                let mut any = false;
                for t in 0..bl {
                    let v = *x.get_unchecked((b0 + t) * n_in + i);
                    xv[t] = v;
                    any |= v != 0.0;
                }
                if !any {
                    continue;
                }
                let s = *row_ptr.get_unchecked(i);
                let e = *row_ptr.get_unchecked(i + 1);
                let mut k = s;
                while k + BLOCK <= e {
                    let idx = _mm256_loadu_si256(col_idx.as_ptr().add(k) as *const __m256i);
                    let mut acc = _mm256_setzero_ps();
                    for t in 0..bl {
                        let g = _mm256_i32gather_ps::<4>(dz.as_ptr().add((b0 + t) * n_out), idx);
                        acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(xv[t]), g));
                    }
                    let p = dw.as_mut_ptr().add(k - base);
                    _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), acc));
                    k += BLOCK;
                }
                for kk in k..e {
                    let j = *col_idx.get_unchecked(kk) as usize;
                    let mut acc = 0.0f32;
                    for t in 0..bl {
                        acc += xv[t] * *dz.get_unchecked((b0 + t) * n_out + j);
                    }
                    *dw.get_unchecked_mut(kk - base) += acc;
                }
            }
            b0 += bl;
        }
    }

    /// AVX2 fused backward over rows `[row0, row1)`: per full batch
    /// block and row, pass A computes the `dx` reduction
    /// grad-input-style off a transposed `dzT` (unconditional — empty
    /// and all-zero-x rows still own their `dx` columns), pass B
    /// accumulates the `dw` slots gather-style (skipped when the `x`
    /// block is all-zero, matching the oracle's activation-sparsity
    /// skip). Splitting the scalar kernel's interleaved loop into two
    /// passes leaves every per-output-element accumulation order
    /// unchanged. The ragged batch tail delegates to the scalar fused
    /// kernel on the remaining samples (ascending batch-block order
    /// for `dw` preserved: `+=` after the full blocks).
    ///
    /// # Safety
    /// AVX2 available; scalar `backward_fused_rows` preconditions.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn backward_fused(
        x: &[f32],
        dz: &[f32],
        batch: usize,
        w: &CsrMatrix,
        row0: usize,
        row1: usize,
        dx: ShardPtr<f32>,
        dw: &mut [f32],
    ) {
        let (n_in, n_out) = (w.n_rows, w.n_cols);
        if n_out > GATHER_MAX_COLS {
            return backward_fused_rows(x, dz, batch, w, row0, row1, dx, dw);
        }
        debug_assert!(row0 <= row1 && row1 <= n_in);
        debug_assert_eq!(x.len(), batch * n_in);
        debug_assert_eq!(dz.len(), batch * n_out);
        let row_ptr = w.row_ptr.as_slice();
        let col_idx = w.col_idx.as_slice();
        let values = w.values.as_slice();
        let base = row_ptr[row0];
        debug_assert_eq!(dw.len(), row_ptr[row1] - base);
        let full = batch - batch % BLOCK;
        if full > 0 {
            let mut scratch = take_scratch(n_out * BLOCK);
            let dzt = &mut scratch[..n_out * BLOCK];
            let mut b0 = 0usize;
            while b0 < full {
                for j in 0..n_out {
                    for t in 0..BLOCK {
                        *dzt.get_unchecked_mut(j * BLOCK + t) =
                            *dz.get_unchecked((b0 + t) * n_out + j);
                    }
                }
                for i in row0..row1 {
                    let mut xv = [0.0f32; BLOCK];
                    let mut any = false;
                    for t in 0..BLOCK {
                        let v = *x.get_unchecked((b0 + t) * n_in + i);
                        xv[t] = v;
                        any |= v != 0.0;
                    }
                    let s = *row_ptr.get_unchecked(i);
                    let e = *row_ptr.get_unchecked(i + 1);
                    // pass A: dx block reduction (k ascending, v * dzv)
                    let mut acc = _mm256_setzero_ps();
                    for k in s..e {
                        let j = *col_idx.get_unchecked(k) as usize;
                        let v = *values.get_unchecked(k);
                        let dzv = _mm256_loadu_ps(dzt.as_ptr().add(j * BLOCK));
                        acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(v), dzv));
                    }
                    let mut tmp = [0.0f32; BLOCK];
                    _mm256_storeu_ps(tmp.as_mut_ptr(), acc);
                    for t in 0..BLOCK {
                        *dx.0.add((b0 + t) * n_in + i) = tmp[t];
                    }
                    // pass B: dw slots (skipped on an all-zero x block,
                    // exactly like the oracle)
                    if any {
                        let mut k = s;
                        while k + BLOCK <= e {
                            let idx = _mm256_loadu_si256(col_idx.as_ptr().add(k) as *const __m256i);
                            let mut wacc = _mm256_setzero_ps();
                            for t in 0..BLOCK {
                                let g = _mm256_i32gather_ps::<4>(
                                    dz.as_ptr().add((b0 + t) * n_out),
                                    idx,
                                );
                                wacc =
                                    _mm256_add_ps(wacc, _mm256_mul_ps(_mm256_set1_ps(xv[t]), g));
                            }
                            let p = dw.as_mut_ptr().add(k - base);
                            _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), wacc));
                            k += BLOCK;
                        }
                        for kk in k..e {
                            let j = *col_idx.get_unchecked(kk) as usize;
                            let mut gacc = 0.0f32;
                            for t in 0..BLOCK {
                                gacc += xv[t] * *dz.get_unchecked((b0 + t) * n_out + j);
                            }
                            *dw.get_unchecked_mut(kk - base) += gacc;
                        }
                    }
                }
                b0 += BLOCK;
            }
            put_scratch(scratch);
        }
        let tail = batch - full;
        if tail > 0 {
            backward_fused_rows(
                &x[full * n_in..],
                &dz[full * n_out..],
                tail,
                w,
                row0,
                row1,
                ShardPtr(dx.0.add(full * n_in)),
                dw,
            );
        }
    }

    /// AVX2 dense-fallback forward: the contiguous `j` loop runs 8
    /// lanes wide (`out_j += xv[t] * row_j` as separate mul + add),
    /// scalar `j % 8` tail; batch blocking and the block-level
    /// zero-skip mirror the scalar body. Each `out[t, j]` is a single
    /// independent accumulator, so lane width cannot change its order.
    ///
    /// # Safety
    /// AVX2 available; `dense_forward_scalar` length contract.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dense_forward(
        x: &[f32],
        batch: usize,
        n_in: usize,
        n_out: usize,
        w: &[f32],
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), batch * n_in);
        assert_eq!(out.len(), batch * n_out);
        assert_eq!(w.len(), n_in * n_out);
        let mut b0 = 0usize;
        while b0 < batch {
            let bl = (batch - b0).min(BLOCK);
            for i in 0..n_in {
                let mut xv = [0.0f32; BLOCK];
                let mut any = false;
                for t in 0..bl {
                    let v = *x.get_unchecked((b0 + t) * n_in + i);
                    xv[t] = v;
                    any |= v != 0.0;
                }
                if !any {
                    continue;
                }
                let row = w.as_ptr().add(i * n_out);
                for t in 0..bl {
                    let xvt = _mm256_set1_ps(xv[t]);
                    let o = out.as_mut_ptr().add((b0 + t) * n_out);
                    let mut j = 0usize;
                    while j + 8 <= n_out {
                        let prod = _mm256_mul_ps(xvt, _mm256_loadu_ps(row.add(j)));
                        _mm256_storeu_ps(o.add(j), _mm256_add_ps(_mm256_loadu_ps(o.add(j)), prod));
                        j += 8;
                    }
                    while j < n_out {
                        *o.add(j) += xv[t] * *row.add(j);
                        j += 1;
                    }
                }
            }
            b0 += bl;
        }
    }

    /// AVX-512F dense-fallback forward: same shape as the AVX2 body
    /// with a 16-lane `j` loop. Only the dense kernel widens to 512
    /// bits — the CSR kernels' gather/transpose structure gains nothing
    /// from wider lanes at BLOCK=8 (§11.2).
    ///
    /// # Safety
    /// AVX-512F available; `dense_forward_scalar` length contract.
    #[target_feature(enable = "avx512f")]
    pub(super) unsafe fn dense_forward_512(
        x: &[f32],
        batch: usize,
        n_in: usize,
        n_out: usize,
        w: &[f32],
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), batch * n_in);
        assert_eq!(out.len(), batch * n_out);
        assert_eq!(w.len(), n_in * n_out);
        let mut b0 = 0usize;
        while b0 < batch {
            let bl = (batch - b0).min(BLOCK);
            for i in 0..n_in {
                let mut xv = [0.0f32; BLOCK];
                let mut any = false;
                for t in 0..bl {
                    let v = *x.get_unchecked((b0 + t) * n_in + i);
                    xv[t] = v;
                    any |= v != 0.0;
                }
                if !any {
                    continue;
                }
                let row = w.as_ptr().add(i * n_out);
                for t in 0..bl {
                    let xvt = _mm512_set1_ps(xv[t]);
                    let o = out.as_mut_ptr().add((b0 + t) * n_out);
                    let mut j = 0usize;
                    while j + 16 <= n_out {
                        let prod = _mm512_mul_ps(xvt, _mm512_loadu_ps(row.add(j)));
                        _mm512_storeu_ps(o.add(j), _mm512_add_ps(_mm512_loadu_ps(o.add(j)), prod));
                        j += 16;
                    }
                    while j < n_out {
                        *o.add(j) += xv[t] * *row.add(j);
                        j += 1;
                    }
                }
            }
            b0 += bl;
        }
    }

    // Thin non-feature wrappers so the table entries are plain
    // `unsafe fn` items (no target_feature fn-pointer coercion in
    // statics). The unsafe call is the feature contract hand-off.
    pub(super) unsafe fn forward_entry(x: &[f32], batch: usize, w: &CsrMatrix, out: &mut [f32]) {
        forward(x, batch, w, out)
    }
    pub(super) unsafe fn grad_input_entry(dz: &[f32], batch: usize, w: &CsrMatrix, dx: &mut [f32]) {
        grad_input(dz, batch, w, dx)
    }
    pub(super) unsafe fn grad_weights_rows_entry(
        x: &[f32],
        dz: &[f32],
        batch: usize,
        w: &CsrMatrix,
        row0: usize,
        row1: usize,
        dw: &mut [f32],
    ) {
        grad_weights_rows(x, dz, batch, w, row0, row1, dw)
    }
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn backward_fused_entry(
        x: &[f32],
        dz: &[f32],
        batch: usize,
        w: &CsrMatrix,
        row0: usize,
        row1: usize,
        dx: ShardPtr<f32>,
        dw: &mut [f32],
    ) {
        backward_fused(x, dz, batch, w, row0, row1, dx, dw)
    }
    pub(super) unsafe fn dense_forward_entry(
        x: &[f32],
        batch: usize,
        n_in: usize,
        n_out: usize,
        w: &[f32],
        out: &mut [f32],
    ) {
        dense_forward(x, batch, n_in, n_out, w, out)
    }
    pub(super) unsafe fn dense_forward_512_entry(
        x: &[f32],
        batch: usize,
        n_in: usize,
        n_out: usize,
        w: &[f32],
        out: &mut [f32],
    ) {
        dense_forward_512(x, batch, n_in, n_out, w, out)
    }

    pub(super) static AVX2_TABLE: KernelTable = KernelTable {
        isa: Isa::Avx2,
        forward: forward_entry,
        grad_input: grad_input_entry,
        grad_weights_rows: grad_weights_rows_entry,
        backward_fused_rows: backward_fused_entry,
        dense_forward: dense_forward_entry,
    };

    /// AVX-512 table: dense kernel at 16 lanes, CSR entries reuse the
    /// AVX2 bodies (every avx512f host supports AVX2; §11.2).
    pub(super) static AVX512_TABLE: KernelTable = KernelTable {
        isa: Isa::Avx512,
        forward: forward_entry,
        grad_input: grad_input_entry,
        grad_weights_rows: grad_weights_rows_entry,
        backward_fused_rows: backward_fused_entry,
        dense_forward: dense_forward_512_entry,
    };
}

#[cfg(target_arch = "x86_64")]
use x86::{AVX2_TABLE, AVX512_TABLE};

// ---------------------------------------------------------------------------
// aarch64: NEON bodies. 128-bit lanes, so every BLOCK=8 vector op is a
// pair of `float32x4_t` halves; multiply and add stay separate
// (`vmulq` + `vaddq`, never `vmlaq` — fused) for the same bit-exactness
// recipe as the AVX2 bodies. NEON has no hardware gather, so the
// slot-vectorized kernels (`grad_weights_rows`, `backward_fused_rows`)
// keep their scalar entries (§11.2).

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::super::csr::CsrMatrix;
    use super::super::ops::{spmm_forward, spmm_grad_input, BLOCK};
    use super::{
        put_scratch, scalar_backward_fused_rows, scalar_grad_weights_rows, take_scratch, Isa,
        KernelTable,
    };
    use std::arch::aarch64::*;

    /// NEON forward: the AVX2 transposed-accumulator structure with
    /// each 8-lane op as two `float32x4_t` halves.
    ///
    /// # Safety
    /// Scalar `spmm_forward` preconditions (NEON is baseline aarch64).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn forward(x: &[f32], batch: usize, w: &CsrMatrix, out: &mut [f32]) {
        let (n_in, n_out) = (w.n_rows, w.n_cols);
        assert_eq!(x.len(), batch * n_in);
        assert_eq!(out.len(), batch * n_out);
        let full = batch - batch % BLOCK;
        if full > 0 && n_out > 0 {
            let row_ptr = w.row_ptr.as_slice();
            let col_idx = w.col_idx.as_slice();
            let values = w.values.as_slice();
            let mut scratch = take_scratch(n_out * BLOCK);
            let outt = &mut scratch[..n_out * BLOCK];
            let mut b0 = 0usize;
            while b0 < full {
                for j in 0..n_out {
                    for t in 0..BLOCK {
                        *outt.get_unchecked_mut(j * BLOCK + t) =
                            *out.get_unchecked((b0 + t) * n_out + j);
                    }
                }
                for i in 0..n_in {
                    let mut xv = [0.0f32; BLOCK];
                    let mut any = false;
                    for t in 0..BLOCK {
                        let v = *x.get_unchecked((b0 + t) * n_in + i);
                        xv[t] = v;
                        any |= v != 0.0;
                    }
                    if !any {
                        continue;
                    }
                    let xlo = vld1q_f32(xv.as_ptr());
                    let xhi = vld1q_f32(xv.as_ptr().add(4));
                    let s = *row_ptr.get_unchecked(i);
                    let e = *row_ptr.get_unchecked(i + 1);
                    for k in s..e {
                        let j = *col_idx.get_unchecked(k) as usize;
                        let v = vdupq_n_f32(*values.get_unchecked(k));
                        let p = outt.as_mut_ptr().add(j * BLOCK);
                        vst1q_f32(p, vaddq_f32(vld1q_f32(p), vmulq_f32(xlo, v)));
                        let p4 = p.add(4);
                        vst1q_f32(p4, vaddq_f32(vld1q_f32(p4), vmulq_f32(xhi, v)));
                    }
                }
                for j in 0..n_out {
                    for t in 0..BLOCK {
                        *out.get_unchecked_mut((b0 + t) * n_out + j) =
                            *outt.get_unchecked(j * BLOCK + t);
                    }
                }
                b0 += BLOCK;
            }
            put_scratch(scratch);
        }
        let tail = batch - full;
        if tail > 0 {
            spmm_forward(&x[full * n_in..], tail, w, &mut out[full * n_out..]);
        }
    }

    /// NEON input gradient: transposed `dzT` + paired 4-lane
    /// accumulators, `k` ascending with `v * dzv` operand order.
    ///
    /// # Safety
    /// Scalar `spmm_grad_input` preconditions.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn grad_input(dz: &[f32], batch: usize, w: &CsrMatrix, dx: &mut [f32]) {
        let (n_in, n_out) = (w.n_rows, w.n_cols);
        assert_eq!(dz.len(), batch * n_out);
        assert_eq!(dx.len(), batch * n_in);
        let full = batch - batch % BLOCK;
        if full > 0 {
            let row_ptr = w.row_ptr.as_slice();
            let col_idx = w.col_idx.as_slice();
            let values = w.values.as_slice();
            let mut scratch = take_scratch(n_out * BLOCK);
            let dzt = &mut scratch[..n_out * BLOCK];
            let mut b0 = 0usize;
            while b0 < full {
                for j in 0..n_out {
                    for t in 0..BLOCK {
                        *dzt.get_unchecked_mut(j * BLOCK + t) =
                            *dz.get_unchecked((b0 + t) * n_out + j);
                    }
                }
                for i in 0..n_in {
                    let s = *row_ptr.get_unchecked(i);
                    let e = *row_ptr.get_unchecked(i + 1);
                    let mut alo = vdupq_n_f32(0.0);
                    let mut ahi = vdupq_n_f32(0.0);
                    for k in s..e {
                        let j = *col_idx.get_unchecked(k) as usize;
                        let v = vdupq_n_f32(*values.get_unchecked(k));
                        let p = dzt.as_ptr().add(j * BLOCK);
                        alo = vaddq_f32(alo, vmulq_f32(v, vld1q_f32(p)));
                        ahi = vaddq_f32(ahi, vmulq_f32(v, vld1q_f32(p.add(4))));
                    }
                    let mut tmp = [0.0f32; BLOCK];
                    vst1q_f32(tmp.as_mut_ptr(), alo);
                    vst1q_f32(tmp.as_mut_ptr().add(4), ahi);
                    for t in 0..BLOCK {
                        *dx.get_unchecked_mut((b0 + t) * n_in + i) = tmp[t];
                    }
                }
                b0 += BLOCK;
            }
            put_scratch(scratch);
        }
        let tail = batch - full;
        if tail > 0 {
            spmm_grad_input(&dz[full * n_out..], tail, w, &mut dx[full * n_in..]);
        }
    }

    /// NEON dense-fallback forward: paired 4-lane `j` loop, scalar
    /// `j % 8` tail; batch blocking and zero-skip as the scalar body.
    ///
    /// # Safety
    /// `dense_forward_scalar` length contract.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dense_forward(
        x: &[f32],
        batch: usize,
        n_in: usize,
        n_out: usize,
        w: &[f32],
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), batch * n_in);
        assert_eq!(out.len(), batch * n_out);
        assert_eq!(w.len(), n_in * n_out);
        let mut b0 = 0usize;
        while b0 < batch {
            let bl = (batch - b0).min(BLOCK);
            for i in 0..n_in {
                let mut xv = [0.0f32; BLOCK];
                let mut any = false;
                for t in 0..bl {
                    let v = *x.get_unchecked((b0 + t) * n_in + i);
                    xv[t] = v;
                    any |= v != 0.0;
                }
                if !any {
                    continue;
                }
                let row = w.as_ptr().add(i * n_out);
                for t in 0..bl {
                    let xvt = vdupq_n_f32(xv[t]);
                    let o = out.as_mut_ptr().add((b0 + t) * n_out);
                    let mut j = 0usize;
                    while j + 8 <= n_out {
                        let oj = o.add(j);
                        let plo = vmulq_f32(xvt, vld1q_f32(row.add(j)));
                        vst1q_f32(oj, vaddq_f32(vld1q_f32(oj), plo));
                        let oj4 = o.add(j + 4);
                        let phi = vmulq_f32(xvt, vld1q_f32(row.add(j + 4)));
                        vst1q_f32(oj4, vaddq_f32(vld1q_f32(oj4), phi));
                        j += 8;
                    }
                    while j < n_out {
                        *o.add(j) += xv[t] * *row.add(j);
                        j += 1;
                    }
                }
            }
            b0 += bl;
        }
    }

    pub(super) unsafe fn forward_entry(x: &[f32], batch: usize, w: &CsrMatrix, out: &mut [f32]) {
        forward(x, batch, w, out)
    }
    pub(super) unsafe fn grad_input_entry(dz: &[f32], batch: usize, w: &CsrMatrix, dx: &mut [f32]) {
        grad_input(dz, batch, w, dx)
    }
    pub(super) unsafe fn dense_forward_entry(
        x: &[f32],
        batch: usize,
        n_in: usize,
        n_out: usize,
        w: &[f32],
        out: &mut [f32],
    ) {
        dense_forward(x, batch, n_in, n_out, w, out)
    }

    /// NEON table: no hardware gather, so the slot-vectorized kernels
    /// stay scalar (documented in `microkernel_name` + §11.2).
    pub(super) static NEON_TABLE: KernelTable = KernelTable {
        isa: Isa::Neon,
        forward: forward_entry,
        grad_input: grad_input_entry,
        grad_weights_rows: scalar_grad_weights_rows,
        backward_fused_rows: scalar_backward_fused_rows,
        dense_forward: dense_forward_entry,
    };
}

#[cfg(target_arch = "aarch64")]
use neon::NEON_TABLE;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::init;
    use crate::util::Rng;

    #[test]
    fn isa_names_parse_back() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("avx512f"), Some(Isa::Avx512));
        assert_eq!(Isa::parse("mmx"), None);
        // "native" is deliberately not a variant spelling
        assert_eq!(Isa::parse("native"), None);
    }

    #[test]
    fn available_starts_with_scalar_and_is_all_supported() {
        let avail = Isa::available();
        assert_eq!(avail[0], Isa::Scalar);
        assert!(avail.iter().all(|isa| isa.supported()));
        assert!(best_isa().supported());
        assert!(avail.contains(&best_isa()));
    }

    #[test]
    fn detected_isa_is_supported() {
        assert!(detected_isa().supported());
    }

    #[test]
    fn kernel_table_is_total_and_clamps_unsupported() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon] {
            let table = kernel_table(isa);
            if isa.supported() {
                assert_eq!(table.isa, isa, "{}", isa.name());
            } else {
                assert_eq!(table.isa, Isa::Scalar, "{}", isa.name());
            }
            // every format has a name, and it encodes the real fallback
            let n = microkernel_name(isa, KernelFormat::Csr);
            let d = microkernel_name(isa, KernelFormat::Dense);
            assert!(!n.is_empty() && !d.is_empty());
            if !isa.supported() {
                assert!(n.ends_with("scalar") && d.ends_with("scalar"));
            }
        }
        // scalar names are fixed API (CLI prints them)
        assert_eq!(microkernel_name(Isa::Scalar, KernelFormat::Csr), "csr_block8_scalar");
        assert_eq!(microkernel_name(Isa::Scalar, KernelFormat::Dense), "dense_block8_scalar");
    }

    fn random_x(rng: &mut Rng, n: usize, zero_frac: f64) -> Vec<f32> {
        (0..n)
            .map(|_| if rng.bernoulli(zero_frac) { 0.0 } else { rng.normal() })
            .collect()
    }

    /// Grid of shapes that hits full-block, tail-only and mixed batch
    /// paths, slot remainders (row nnz % 8 ≠ 0) and skewed rows.
    fn cases() -> Vec<(usize, usize, f64, usize)> {
        vec![
            (17, 13, 0.3, 5),   // tail-only batch, ragged rows
            (64, 48, 0.2, 8),   // exactly one full block
            (64, 48, 0.2, 19),  // full blocks + tail
            (33, 70, 0.6, 16),  // dense-ish rows: slot-vector path
            (128, 96, 0.05, 9), // very sparse: mostly remainder slots
        ]
    }

    #[test]
    fn simd_tables_match_scalar_bit_exactly_on_every_kernel() {
        let mut rng = Rng::new(77);
        for isa in Isa::available() {
            let table = kernel_table(isa);
            for (n_in, n_out, density, batch) in cases() {
                let wi = init::WeightInit::Normal(0.5);
                let w = init::erdos_renyi(n_in, n_out, density, &mut rng, &wi);
                let x = random_x(&mut rng, batch * n_in, 0.3);
                let dz = random_x(&mut rng, batch * n_out, 0.0);
                let label = format!("{} {n_in}x{n_out} d{density} b{batch}", isa.name());

                // forward (out pre-biased, like the layer path)
                let bias = random_x(&mut rng, n_out, 0.0);
                let mut seq: Vec<f32> = (0..batch).flat_map(|_| bias.iter().copied()).collect();
                let mut got = seq.clone();
                ops::spmm_forward(&x, batch, &w, &mut seq);
                // SAFETY: lengths match, CSR validated, ISA supported
                // (Isa::available() only yields supported ISAs).
                unsafe { (table.forward)(&x, batch, &w, &mut got) };
                assert_eq!(seq, got, "forward {label}");

                // grad_input
                let mut seq = vec![f32::NAN; batch * n_in];
                let mut got = vec![f32::NAN; batch * n_in];
                ops::spmm_grad_input(&dz, batch, &w, &mut seq);
                unsafe { (table.grad_input)(&dz, batch, &w, &mut got) };
                assert_eq!(seq, got, "grad_input {label}");

                // grad_weights: full row range and a proper sub-range
                let mut seq = vec![0.0f32; w.nnz()];
                let mut got = vec![0.0f32; w.nnz()];
                ops::spmm_grad_weights(&x, &dz, batch, &w, &mut seq);
                unsafe { (table.grad_weights_rows)(&x, &dz, batch, &w, 0, n_in, &mut got) };
                assert_eq!(seq, got, "grad_weights {label}");
                let (r0, r1) = (n_in / 4, (3 * n_in) / 4);
                let (k0, k1) = (w.row_ptr[r0], w.row_ptr[r1]);
                let mut got = vec![0.0f32; k1 - k0];
                unsafe { (table.grad_weights_rows)(&x, &dz, batch, &w, r0, r1, &mut got) };
                assert_eq!(&seq[k0..k1], &got[..], "grad_weights rows {label}");

                // fused backward
                let mut dx_seq = vec![f32::NAN; batch * n_in];
                let mut dw_seq = vec![0.0f32; w.nnz()];
                ops::spmm_grad_input(&dz, batch, &w, &mut dx_seq);
                ops::spmm_grad_weights(&x, &dz, batch, &w, &mut dw_seq);
                let mut dx = vec![f32::NAN; batch * n_in];
                let mut dw = vec![0.0f32; w.nnz()];
                unsafe {
                    (table.backward_fused_rows)(
                        &x,
                        &dz,
                        batch,
                        &w,
                        0,
                        n_in,
                        ShardPtr(dx.as_mut_ptr()),
                        &mut dw,
                    )
                };
                assert_eq!(dx_seq, dx, "fused dx {label}");
                assert_eq!(dw_seq, dw, "fused dw {label}");

                // dense-fallback forward on the densified weights
                let wd = w.to_dense();
                let mut seq: Vec<f32> = (0..batch).flat_map(|_| bias.iter().copied()).collect();
                let mut got = seq.clone();
                dense_forward_scalar(&x, batch, n_in, n_out, &wd, &mut seq);
                unsafe { (table.dense_forward)(&x, batch, n_in, n_out, &wd, &mut got) };
                assert_eq!(seq, got, "dense {label}");
            }
        }
    }

    #[test]
    fn simd_tables_survive_degenerate_shapes() {
        for isa in Isa::available() {
            let table = kernel_table(isa);
            // empty matrix, zero batch
            let w = CsrMatrix::empty(4, 5);
            let x = vec![1.0f32; 2 * 4];
            let mut out = vec![0.0f32; 2 * 5];
            unsafe { (table.forward)(&x, 2, &w, &mut out) };
            assert!(out.iter().all(|&v| v == 0.0), "{}", isa.name());
            let mut dx = vec![f32::NAN; 2 * 4];
            unsafe { (table.grad_input)(&[0.5f32; 10], 2, &w, &mut dx) };
            assert!(dx.iter().all(|&v| v == 0.0), "{}", isa.name());
            unsafe { (table.forward)(&[], 0, &w, &mut []) };
            let mut dw: Vec<f32> = Vec::new();
            unsafe { (table.grad_weights_rows)(&[], &[], 0, &w, 0, 4, &mut dw) };
            // single-row matrix with a one-slot row (pure remainder)
            let w = CsrMatrix::from_coo(1, 3, vec![(0u32, 1u32, 2.0f32)]).unwrap();
            let x = [1.0f32, -1.0, 0.5, 0.0, 2.0, 3.0, -4.0, 5.0, 9.0]; // batch 9
            let mut seq = vec![0.0f32; 9 * 3];
            let mut got = vec![0.0f32; 9 * 3];
            ops::spmm_forward(&x, 9, &w, &mut seq);
            unsafe { (table.forward)(&x, 9, &w, &mut got) };
            assert_eq!(seq, got, "{}", isa.name());
        }
    }

    #[test]
    fn scratch_take_put_reuses_capacity() {
        let buf = take_scratch(64);
        assert!(buf.len() >= 64);
        let ptr = buf.as_ptr();
        put_scratch(buf);
        let buf = take_scratch(32);
        assert_eq!(buf.as_ptr(), ptr, "same thread must reuse its buffer");
        put_scratch(buf);
    }
}
