//! Truly-sparse compute kernels: the L3 hot path.
//!
//! All kernels stream CSR rows with one contiguous dense row per sample,
//! no allocation, no atomics:
//!
//! * [`spmm_forward`]        z = x · W          (B×n_in · n_in×n_out)
//! * [`spmm_backward_fused`] dx = dz · Wᵀ **and** dW = xᵀ · dz (pattern-
//!   restricted) in ONE traversal of W's rows — the hot backward path
//! * [`spmm_grad_input`]     dx = dz · Wᵀ          (parity oracle)
//! * [`spmm_grad_weights`]   dW = xᵀ · dz restricted (parity oracle, and
//!   still the layer-0 path where no input gradient is needed)
//!
//! The activation-sparsity shortcut (skip `x[b,i] == 0`, which ReLU-family
//! activations produce in volume) is what makes the truly-sparse engine
//! beat masked-dense at equal FLOP budgets.
//!
//! The forward and fused-backward kernels run a monomorphized
//! [`BLOCK`]-sample microkernel on full blocks (fixed trip counts the
//! autovectorizer can unroll into SIMD lanes) plus a monomorphized
//! remainder dispatch for the ragged tail — see `rust/DESIGN.md` §5.
//!
//! Each kernel also has (or embeds) a worker-sharded variant
//! ([`spmm_forward_threaded`], [`spmm_grad_input_threaded`],
//! [`spmm_grad_weights_threaded`]; [`spmm_backward_fused`] takes its
//! thread budget directly) that splits the work across disjoint-write
//! shards (no atomics, no locks) and falls back to the sequential path
//! below a crossover work threshold — see `rust/DESIGN.md` §4–§5 for
//! the sharding invariants.
//!
//! Sharded work is dispatched through an [`Exec`] context: on the hot
//! path the shards run on a persistent, parked [`WorkerPool`]
//! (DESIGN.md §9; crossover [`POOL_MIN_WORK`]); without a pool the cold
//! fallback spawns scoped OS threads per dispatch as before (crossover
//! [`PAR_MIN_WORK`]). Results are bit-identical either way.

use super::csr::CsrMatrix;
use super::pool::WorkerPool;
use super::simd::{detected_isa, kernel_table, Isa};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

/// Samples per block in the batch-blocked kernels: each W row is streamed
/// once per block instead of once per sample, cutting weight traffic
/// `BLOCK`-fold for layers larger than L2. Widened from 4 to 8 so the
/// monomorphized inner loops fill a full 256-bit SIMD register of f32
/// lanes (see DESIGN.md §5); [`tail_dispatch!`] enumerates 1..BLOCK and
/// must be extended if BLOCK grows.
pub(crate) const BLOCK: usize = 8;

// Compile-time guard: tail_dispatch! enumerates widths 1..8 only, so a
// larger BLOCK must extend the macro (or this becomes a runtime panic
// on the first ragged batch).
const _: () = assert!(BLOCK == 8, "extend tail_dispatch! before growing BLOCK");

/// Dispatch a `const BL: usize` microkernel over a runtime tail size in
/// `1..BLOCK`, monomorphizing every remainder width so even ragged
/// batches run fixed-trip-count inner loops.
macro_rules! tail_dispatch {
    ($bl:expr, $f:ident ( $($args:expr),* $(,)? )) => {
        match $bl {
            1 => $f::<1>($($args),*),
            2 => $f::<2>($($args),*),
            3 => $f::<3>($($args),*),
            4 => $f::<4>($($args),*),
            5 => $f::<5>($($args),*),
            6 => $f::<6>($($args),*),
            7 => $f::<7>($($args),*),
            _ => unreachable!("tail size must be in 1..BLOCK"),
        }
    };
}

/// Forward: `out[b, :] += Σ_i x[b, i] * W.row(i)`, with `out` pre-zeroed by
/// the caller (lets callers fuse bias init into the zeroing pass).
///
/// Shapes: `x: [batch, n_in]`, `out: [batch, n_out]`, both row-major.
///
/// # Examples
///
/// ```
/// use tsnn::sparse::{ops, CsrMatrix};
///
/// // W = [[1, 0], [0, 2]] stored sparse; one sample x = [3, 4].
/// let w = CsrMatrix::from_coo(2, 2, vec![(0, 0, 1.0), (1, 1, 2.0)]).unwrap();
/// let mut out = vec![0.0f32; 2];
/// ops::spmm_forward(&[3.0, 4.0], 1, &w, &mut out);
/// assert_eq!(out, vec![3.0, 8.0]);
/// ```
pub fn spmm_forward(x: &[f32], batch: usize, w: &CsrMatrix, out: &mut [f32]) {
    let (n_in, n_out) = (w.n_rows, w.n_cols);
    assert_eq!(x.len(), batch * n_in);
    assert_eq!(out.len(), batch * n_out);
    debug_assert!(w.validate().is_ok());
    // SAFETY: row_ptr has n_rows+1 monotone entries and every
    // col_idx < n_cols (validated CSR invariants), and the length asserts
    // above bound every `(b0 + t) * n_in + i` / `(b0 + t) * n_out + j`
    // access for `b0 + BL <= batch` — the microkernel contract.
    unsafe {
        let mut b0 = 0usize;
        while b0 + BLOCK <= batch {
            forward_block::<BLOCK>(x, b0, w, out);
            b0 += BLOCK;
        }
        let tail = batch - b0;
        if tail > 0 {
            tail_dispatch!(tail, forward_block(x, b0, w, out));
        }
    }
}

/// Forward microkernel over exactly `BL` samples starting at `b0`: the
/// fixed trip count lets the inner scatter loop autovectorize. Blocks of
/// [`BLOCK`] take the monomorphized fast path; the ragged tail goes
/// through [`tail_dispatch!`].
///
/// # Safety
/// Caller guarantees a validated CSR `w`, `b0 + BL <= batch`,
/// `x.len() == batch * w.n_rows` and `out.len() == batch * w.n_cols`.
#[inline(always)]
unsafe fn forward_block<const BL: usize>(x: &[f32], b0: usize, w: &CsrMatrix, out: &mut [f32]) {
    let (n_in, n_out) = (w.n_rows, w.n_cols);
    let row_ptr = w.row_ptr.as_slice();
    let col_idx = w.col_idx.as_slice();
    let values = w.values.as_slice();
    for i in 0..n_in {
        // gather this input across the block; skip fully-zero columns
        // (activation sparsity shortcut, block-wide)
        let mut xv = [0.0f32; BL];
        let mut any = false;
        for (t, xvt) in xv.iter_mut().enumerate() {
            let v = *x.get_unchecked((b0 + t) * n_in + i);
            *xvt = v;
            any |= v != 0.0;
        }
        if !any {
            continue;
        }
        let s = *row_ptr.get_unchecked(i);
        let e = *row_ptr.get_unchecked(i + 1);
        for k in s..e {
            let j = *col_idx.get_unchecked(k) as usize;
            let v = *values.get_unchecked(k);
            for t in 0..BL {
                *out.get_unchecked_mut((b0 + t) * n_out + j) += xv[t] * v;
            }
        }
    }
}

/// Input gradient: `dx[b, i] = Σ_j W[i, j] * dz[b, j]`.
///
/// Parity oracle for (and sequential fallback of) the input-gradient half
/// of [`spmm_backward_fused`]; kept runtime-blocked — the hot path is the
/// fused kernel.
pub fn spmm_grad_input(dz: &[f32], batch: usize, w: &CsrMatrix, dx: &mut [f32]) {
    let (n_in, n_out) = (w.n_rows, w.n_cols);
    assert_eq!(dz.len(), batch * n_out);
    assert_eq!(dx.len(), batch * n_in);
    debug_assert!(w.validate().is_ok());
    let row_ptr = w.row_ptr.as_slice();
    let col_idx = w.col_idx.as_slice();
    let values = w.values.as_slice();
    let mut b0 = 0usize;
    while b0 < batch {
        let bl = (batch - b0).min(BLOCK);
        for i in 0..n_in {
            // SAFETY: validated CSR invariants (see spmm_forward).
            unsafe {
                let s = *row_ptr.get_unchecked(i);
                let e = *row_ptr.get_unchecked(i + 1);
                let mut acc = [0.0f32; BLOCK];
                for k in s..e {
                    let j = *col_idx.get_unchecked(k) as usize;
                    let v = *values.get_unchecked(k);
                    for t in 0..bl {
                        acc[t] += v * *dz.get_unchecked((b0 + t) * n_out + j);
                    }
                }
                for t in 0..bl {
                    *dx.get_unchecked_mut((b0 + t) * n_in + i) = acc[t];
                }
            }
        }
        b0 += bl;
    }
}

/// Weight gradient restricted to W's sparsity pattern:
/// `dw[k] = Σ_b x[b, row(k)] * dz[b, col(k)]`, `dw` aligned with
/// `w.values` and pre-zeroed by the caller.
///
/// Parity oracle for the weight-gradient half of
/// [`spmm_backward_fused`], and still the layer-0 backward path (no
/// input gradient exists below the first layer).
pub fn spmm_grad_weights(
    x: &[f32],
    dz: &[f32],
    batch: usize,
    w: &CsrMatrix,
    dw: &mut [f32],
) {
    assert_eq!(x.len(), batch * w.n_rows);
    assert_eq!(dz.len(), batch * w.n_cols);
    assert_eq!(dw.len(), w.nnz());
    debug_assert!(w.validate().is_ok());
    grad_weights_rows(x, dz, batch, w, 0, w.n_rows, dw);
}

/// [`spmm_grad_weights`] restricted to rows `[row0, row1)`; `dw` covers the
/// value slots of exactly those rows (`row_ptr[row1] - row_ptr[row0]` long).
/// This is the per-shard core of the sharded weight-gradient kernel: the
/// batch loop runs in the same `BLOCK` order as the sequential kernel, so a
/// shard's `dw` slots are filled identically to a full sequential pass.
///
/// Callers guarantee `x.len() == batch * n_in`, `dz.len() == batch * n_out`,
/// `row0 <= row1 <= n_rows`, and a validated CSR `w`.
pub(crate) fn grad_weights_rows(
    x: &[f32],
    dz: &[f32],
    batch: usize,
    w: &CsrMatrix,
    row0: usize,
    row1: usize,
    dw: &mut [f32],
) {
    let (n_in, n_out) = (w.n_rows, w.n_cols);
    debug_assert!(row0 <= row1 && row1 <= n_in);
    debug_assert_eq!(x.len(), batch * n_in);
    debug_assert_eq!(dz.len(), batch * n_out);
    let row_ptr = w.row_ptr.as_slice();
    let col_idx = w.col_idx.as_slice();
    let base = row_ptr[row0];
    debug_assert_eq!(dw.len(), row_ptr[row1] - base);
    let mut b0 = 0usize;
    while b0 < batch {
        let bl = (batch - b0).min(BLOCK);
        for i in row0..row1 {
            let mut xv = [0.0f32; BLOCK];
            let mut any = false;
            for (t, xvt) in xv.iter_mut().enumerate().take(bl) {
                let v = x[(b0 + t) * n_in + i];
                *xvt = v;
                any |= v != 0.0;
            }
            if !any {
                continue;
            }
            // SAFETY: validated CSR invariants (see spmm_forward); dw spans
            // the value slots of rows [row0, row1), so `k - base` is
            // in-bounds for every k in this row range.
            unsafe {
                let s = *row_ptr.get_unchecked(i);
                let e = *row_ptr.get_unchecked(i + 1);
                for k in s..e {
                    let j = *col_idx.get_unchecked(k) as usize;
                    let mut acc = 0.0f32;
                    for t in 0..bl {
                        acc += *xv.get_unchecked(t) * *dz.get_unchecked((b0 + t) * n_out + j);
                    }
                    *dw.get_unchecked_mut(k - base) += acc;
                }
            }
        }
        b0 += bl;
    }
}

// ---------------------------------------------------------------------------
// Fused one-pass backward (DESIGN.md §5).
//
// The two-kernel backward streams every layer's CSR arrays twice per step
// (grad-weights pass, then grad-input pass). Both outputs are row-local —
// W row `i` fully determines dw slots [row_ptr[i], row_ptr[i+1]) AND dx
// column `i` — so one traversal of W's rows can produce both, halving CSR
// traffic per backward layer and eliminating one threaded dispatch per
// layer per step. Row sharding (balanced_row_bounds) then gives disjoint
// writes for BOTH outputs with no atomics: dw splits into contiguous
// value-slot ranges, dx into disjoint column ranges of the [batch, n_in]
// buffer (strided, hence the raw-pointer shard handle below).

// The fused kernel's `dx` is handed to shards as a raw [`ShardPtr`]
// base pointer: row-sharded workers write disjoint *column* ranges of
// the same `[batch, n_in]` buffer, which cannot be expressed as
// `split_at_mut` sub-slices. A shard only ever writes `dx[b*n_in + i]`
// for rows `i` inside its own `[row0, row1)` range — disjoint by
// construction (§5 proof sketch in DESIGN.md).

/// Fused backward: computes the input gradient `dx = dz · Wᵀ`
/// (overwritten) **and** the pattern-aligned weight gradient
/// `dw[k] += Σ_b x[b, row(k)] · dz[b, col(k)]` (`dw` pre-zeroed by the
/// caller, aligned with `w.values`) in a single traversal of W's rows.
///
/// `threads` is the worker budget (`0` = one per available core, `1` =
/// sequential); above the crossover the rows are nnz-balance-sharded and
/// each worker owns disjoint `dw` slots and disjoint `dx` columns.
/// Results are **exactly equal** (`==`, not tolerance) to the sequential
/// [`spmm_grad_input`] + [`spmm_grad_weights`] pair at every thread
/// count: per-slot accumulation order is identical (see DESIGN.md §5).
///
/// # Examples
///
/// ```
/// use tsnn::sparse::{ops, CsrMatrix};
///
/// let w = CsrMatrix::from_coo(2, 2, vec![(0, 0, 1.0), (1, 1, 2.0)]).unwrap();
/// let (x, dz) = ([3.0, 4.0], [0.5, -1.0]); // one sample
/// let mut dx = [0.0f32; 2];
/// let mut dw = vec![0.0f32; w.nnz()];
/// ops::spmm_backward_fused(&x, &dz, 1, &w, &mut dx, &mut dw, 1);
/// assert_eq!(dx, [0.5, -2.0]);           // dz · Wᵀ
/// assert_eq!(dw, vec![1.5, -4.0]);       // xᵀ · dz on W's pattern
/// ```
pub fn spmm_backward_fused(
    x: &[f32],
    dz: &[f32],
    batch: usize,
    w: &CsrMatrix,
    dx: &mut [f32],
    dw: &mut [f32],
    threads: usize,
) {
    spmm_backward_fused_exec(x, dz, batch, w, dx, dw, Exec::scoped(threads));
}

/// [`spmm_backward_fused`] with an explicit execution context.
#[allow(clippy::too_many_arguments)]
pub fn spmm_backward_fused_exec(
    x: &[f32],
    dz: &[f32],
    batch: usize,
    w: &CsrMatrix,
    dx: &mut [f32],
    dw: &mut [f32],
    exec: Exec<'_>,
) {
    let (n_in, n_out) = (w.n_rows, w.n_cols);
    assert_eq!(x.len(), batch * n_in);
    assert_eq!(dz.len(), batch * n_out);
    assert_eq!(dx.len(), batch * n_in);
    assert_eq!(dw.len(), w.nnz());
    debug_assert!(w.validate().is_ok());
    // The fused kernel does ~2 MACs per (slot, sample) — count both when
    // judging the dispatch crossover.
    let table = kernel_table(exec.isa);
    let shards = shard_count(exec, batch, w.nnz().saturating_mul(2), w.n_rows);
    let dx_ptr = ShardPtr(dx.as_mut_ptr());
    if shards <= 1 {
        // SAFETY: buffer lengths asserted above, full row range; table
        // ISA is host-supported (see spmm_forward_exec).
        unsafe { (table.backward_fused_rows)(x, dz, batch, w, 0, w.n_rows, dx_ptr, dw) };
        return;
    }
    let shards = exec.row_shard_budget(shards, w.n_rows);
    let dw_ptr = ShardPtr(dw.as_mut_ptr());
    match row_schedule(w, shards) {
        RowSchedule::Contiguous(bounds) => {
            let bounds = bounds.as_slice();
            exec.run(shards, |s| {
                let (r0, r1) = (bounds[s], bounds[s + 1]);
                if r0 == r1 {
                    return; // nnz-heavy row swallowed this shard's budget
                }
                // NOTE: a shard with rows but zero nnz (all-empty rows)
                // must still run — it owns those rows' dx columns.
                let (k0, k1) = (w.row_ptr[r0], w.row_ptr[r1]);
                // SAFETY: disjoint dw slot ranges (monotone row_ptr) and
                // disjoint dx columns (disjoint row ranges, §5.1); both
                // buffers outlive the dispatch.
                let head = unsafe { std::slice::from_raw_parts_mut(dw_ptr.0.add(k0), k1 - k0) };
                // SAFETY: dw sub-slice spans rows [r0, r1); table as above.
                unsafe { (table.backward_fused_rows)(x, dz, batch, w, r0, r1, dx_ptr, head) };
            });
        }
        RowSchedule::Balanced { starts, rows } => {
            exec.run(shards, |s| {
                for (r0, r1) in RowRuns::new(&rows[starts[s]..starts[s + 1]]) {
                    // Empty runs still dispatch: they own those rows' dx
                    // columns, which the fused kernel zero-fills.
                    let (k0, k1) = (w.row_ptr[r0], w.row_ptr[r1]);
                    // SAFETY: every row belongs to exactly one shard's
                    // list → disjoint dw slot ranges AND disjoint dx
                    // columns across the dispatch (§11.4); both buffers
                    // outlive it.
                    let head =
                        unsafe { std::slice::from_raw_parts_mut(dw_ptr.0.add(k0), k1 - k0) };
                    // SAFETY: dw sub-slice spans the run; table as above.
                    unsafe { (table.backward_fused_rows)(x, dz, batch, w, r0, r1, dx_ptr, head) };
                }
            });
        }
    }
}

/// Fused-backward core over rows `[row0, row1)`: batch-blocked like the
/// oracle kernels (full [`BLOCK`]s then a monomorphized tail), so every
/// `dw` slot sees batch blocks in the exact order of
/// [`spmm_grad_weights`] and every `dx[b, i]` reduction runs in the exact
/// `k` order of [`spmm_grad_input`].
///
/// # Safety
/// Caller guarantees a validated CSR `w`, `row0 <= row1 <= w.n_rows`,
/// `x.len() == batch * w.n_rows`, `dz.len() == batch * w.n_cols`, `dw`
/// spanning exactly the value slots of rows `[row0, row1)`, and `dx`
/// pointing at a live `[batch, w.n_rows]` buffer whose columns
/// `[row0, row1)` are not written by anyone else for the duration of the
/// call.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn backward_fused_rows(
    x: &[f32],
    dz: &[f32],
    batch: usize,
    w: &CsrMatrix,
    row0: usize,
    row1: usize,
    dx: ShardPtr<f32>,
    dw: &mut [f32],
) {
    debug_assert!(row0 <= row1 && row1 <= w.n_rows);
    debug_assert_eq!(dw.len(), w.row_ptr[row1] - w.row_ptr[row0]);
    let mut b0 = 0usize;
    while b0 + BLOCK <= batch {
        backward_fused_block::<BLOCK>(x, dz, b0, w, row0, row1, dx, dw);
        b0 += BLOCK;
    }
    let tail = batch - b0;
    if tail > 0 {
        tail_dispatch!(tail, backward_fused_block(x, dz, b0, w, row0, row1, dx, dw));
    }
}

/// Fused-backward microkernel over exactly `BL` samples starting at `b0`
/// for rows `[row0, row1)`. One pass over each row's slots accumulates
/// the `dx` block reduction and the `dw` partial sums together — dz is
/// loaded once per (slot, sample) instead of twice.
///
/// # Safety
/// Same contract as [`backward_fused_rows`], plus `b0 + BL <= batch`.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
unsafe fn backward_fused_block<const BL: usize>(
    x: &[f32],
    dz: &[f32],
    b0: usize,
    w: &CsrMatrix,
    row0: usize,
    row1: usize,
    dx: ShardPtr<f32>,
    dw: &mut [f32],
) {
    let (n_in, n_out) = (w.n_rows, w.n_cols);
    let row_ptr = w.row_ptr.as_slice();
    let col_idx = w.col_idx.as_slice();
    let values = w.values.as_slice();
    let base = *row_ptr.get_unchecked(row0);
    for i in row0..row1 {
        // gather x across the block: the activation-sparsity shortcut
        // applies to the dw half only (dx needs the row either way)
        let mut xv = [0.0f32; BL];
        let mut any = false;
        for (t, xvt) in xv.iter_mut().enumerate() {
            let v = *x.get_unchecked((b0 + t) * n_in + i);
            *xvt = v;
            any |= v != 0.0;
        }
        let s = *row_ptr.get_unchecked(i);
        let e = *row_ptr.get_unchecked(i + 1);
        let mut acc = [0.0f32; BL];
        if any {
            for k in s..e {
                let j = *col_idx.get_unchecked(k) as usize;
                let v = *values.get_unchecked(k);
                let mut gacc = 0.0f32;
                for t in 0..BL {
                    let dzv = *dz.get_unchecked((b0 + t) * n_out + j);
                    acc[t] += v * dzv;
                    gacc += xv[t] * dzv;
                }
                *dw.get_unchecked_mut(k - base) += gacc;
            }
        } else {
            // all-zero x block: dw untouched (matches the oracle's skip),
            // dx still reduced
            for k in s..e {
                let j = *col_idx.get_unchecked(k) as usize;
                let v = *values.get_unchecked(k);
                for t in 0..BL {
                    acc[t] += v * *dz.get_unchecked((b0 + t) * n_out + j);
                }
            }
        }
        for (t, &a) in acc.iter().enumerate() {
            *dx.0.add((b0 + t) * n_in + i) = a;
        }
    }
}

/// Bias gradient: `db[j] = Σ_b dz[b, j]` (pre-zeroed `db`).
///
/// Column accumulation runs over zipped row slices (no per-element bounds
/// checks, so the column loop autovectorizes) and folds two `dz` rows per
/// pass, halving `db` read/write traffic.
pub fn bias_grad(dz: &[f32], batch: usize, n_out: usize, db: &mut [f32]) {
    debug_assert_eq!(db.len(), n_out);
    if n_out == 0 || batch == 0 {
        return;
    }
    // honour `batch` even when the caller hands a capacity-slack buffer
    // (the pre-rewrite loop read exactly batch rows)
    let dz = &dz[..batch * n_out];
    let mut rows = dz.chunks_exact(2 * n_out);
    for pair in rows.by_ref() {
        let (r0, r1) = pair.split_at(n_out);
        for ((d, &a), &b) in db.iter_mut().zip(r0).zip(r1) {
            *d += a + b;
        }
    }
    let rem = rows.remainder();
    if !rem.is_empty() {
        for (d, &g) in db.iter_mut().zip(rem) {
            *d += g;
        }
    }
}

// ---------------------------------------------------------------------------
// Worker-sharded parallel backend (DESIGN.md §4).
//
// Sharding strategy per kernel:
//   * spmm_forward / spmm_grad_input — batch-sharded: each worker owns a
//     contiguous range of samples and therefore a disjoint range of output
//     rows. Per-sample accumulation order is identical to the sequential
//     kernel, so results match exactly (not just within tolerance).
//   * spmm_grad_weights — nnz-range-sharded: W's rows are partitioned into
//     contiguous ranges of roughly equal nnz; a shard's dw slots
//     [row_ptr[r0], row_ptr[r1]) are disjoint from every other shard's, and
//     each worker accumulates its partial sums privately into its own
//     sub-slice (batch loop order unchanged → exact-match results).
//   * spmm_backward_fused — same nnz-balanced row sharding, with each
//     shard owning its rows' dw slots AND dx columns (DESIGN.md §5).
//
// Dispatch falls back to the sequential kernel below a two-tier work
// threshold: [`POOL_MIN_WORK`] when a persistent [`WorkerPool`] serves
// the dispatch (warm wakeup, ~single-digit µs), [`PAR_MIN_WORK`] on the
// cold scoped-spawn fallback (tens of µs per worker).

/// Cold-path crossover: minimum multiply-accumulate count (`batch × nnz`)
/// at which **spawning scoped worker threads** beats the sequential
/// kernel. Below this the pool-less `*_threaded` entry points run
/// sequentially on the caller's thread (≈1 M MACs ≳ 0.5 ms sequential vs
/// ≈50 µs/thread spawn cost).
pub const PAR_MIN_WORK: usize = 1 << 20;

/// Warm-path crossover: minimum `batch × nnz` at which dispatching onto
/// a parked [`WorkerPool`] beats the sequential kernel. A warm-pool
/// dispatch costs single-digit microseconds (spin-phase wakeup; ~100×
/// below the scoped-spawn cost, DESIGN.md §9.3), so the threshold drops
/// accordingly: 2¹⁵ MACs ≈ 30–60 µs of sequential kernel time keeps the
/// dispatch overhead ≲ 10%. Re-derived by `benches/perf_pool.rs`'s
/// crossover sweep (`BENCH_4.json`).
pub const POOL_MIN_WORK: usize = 1 << 15;

/// Worker threads the machine can usefully run (1 when unknown). Cached.
pub fn available_threads() -> usize {
    static CACHE: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CACHE.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Resolve a `kernel_threads` knob: `0` = one worker per available core,
/// anything else is taken literally (`1` = always sequential).
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        available_threads()
    } else {
        requested
    }
}

/// Dispatches that fell back to per-call scoped OS-thread spawning
/// (process-wide). The steady-state training loop must never move this
/// counter — every hot-path shard runs on a persistent [`WorkerPool`] —
/// which `rust/tests/pool.rs` pins.
static SCOPED_DISPATCHES: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of scoped-spawn (pool-less) sharded dispatches.
pub fn scoped_dispatch_events() -> u64 {
    SCOPED_DISPATCHES.load(Ordering::Relaxed)
}

/// Kernel execution context: a resolved thread budget plus, on the hot
/// path, the persistent [`WorkerPool`] that serves it (DESIGN.md §9),
/// plus the instruction set the kernels dispatch to (DESIGN.md §11).
///
/// `Copy` so it threads freely through the layer/model call chain; the
/// lifetime ties it to the pool it borrows (a pool-less `Exec` is
/// `'static`).
#[derive(Clone, Copy)]
pub struct Exec<'p> {
    threads: usize,
    pool: Option<&'p WorkerPool>,
    isa: Isa,
}

impl<'p> Exec<'p> {
    /// Always-sequential context (the `threads = 1` identity).
    pub fn sequential() -> Exec<'static> {
        Exec {
            threads: 1,
            pool: None,
            isa: detected_isa(),
        }
    }

    /// Cold-path context: shards are spawned as scoped OS threads per
    /// dispatch (`0` = one per available core). Crossover
    /// [`PAR_MIN_WORK`]. Kept for pool-less callers and as the parity
    /// oracle of the pooled path.
    pub fn scoped(threads: usize) -> Exec<'static> {
        Exec {
            threads: resolve_threads(threads),
            pool: None,
            isa: detected_isa(),
        }
    }

    /// Hot-path context: shards run on `pool`'s parked workers (plus the
    /// calling thread). Crossover [`POOL_MIN_WORK`].
    pub fn pooled(pool: &'p WorkerPool) -> Exec<'p> {
        Exec {
            threads: pool.threads(),
            pool: Some(pool),
            isa: detected_isa(),
        }
    }

    /// Context from an optional pool: pooled when available, otherwise
    /// the scoped fallback at `threads`.
    pub fn with(threads: usize, pool: Option<&'p WorkerPool>) -> Exec<'p> {
        match pool {
            Some(p) => Exec::pooled(p),
            None => Exec::scoped(threads),
        }
    }

    /// Override the microkernel ISA (default: [`detected_isa`], i.e. the
    /// best supported set or the `TSNN_ISA` env override). An ISA the
    /// host does not support clamps to [`Isa::Scalar`] — results are
    /// bit-identical either way (§11.3), so forcing is always safe.
    pub fn with_isa(mut self, isa: Isa) -> Exec<'p> {
        self.isa = if isa.supported() { isa } else { Isa::Scalar };
        self
    }

    /// The microkernel ISA this context dispatches to.
    pub fn isa(&self) -> Isa {
        self.isa
    }

    /// Resolved worker budget (≥ 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// True when dispatches run on a persistent pool.
    pub fn is_pooled(&self) -> bool {
        self.pool.is_some()
    }

    /// Shard budget for the row-scheduled kernels once the crossover has
    /// passed: pooled dispatches oversubscribe the worker count
    /// ([`WorkerPool::shard_budget`]) so work-stealing can absorb ragged
    /// rows; the scoped fallback keeps one shard per spawned thread
    /// (spawns are the cost there, not stragglers).
    fn row_shard_budget(&self, shards: usize, max_shards: usize) -> usize {
        match self.pool {
            Some(p) => p.shard_budget(max_shards),
            None => shards,
        }
    }

    /// The crossover work threshold of this context (two-tier: warm pool
    /// vs cold scoped spawn).
    pub fn min_work(&self) -> usize {
        if self.pool.is_some() {
            POOL_MIN_WORK
        } else {
            PAR_MIN_WORK
        }
    }

    /// Scatter-gather `f` over `n_shards` disjoint-write shards: on the
    /// pool when present, else scoped OS threads (counted in
    /// [`scoped_dispatch_events`]), inline for `n_shards <= 1`. Exactly
    /// the contract of [`WorkerPool::run`].
    pub fn run<F: Fn(usize) + Sync>(&self, n_shards: usize, f: F) {
        match self.pool {
            Some(p) if n_shards > 1 => p.run(n_shards, f),
            _ => {
                if n_shards > 1 {
                    SCOPED_DISPATCHES.fetch_add(1, Ordering::Relaxed);
                    std::thread::scope(|scope| {
                        let f = &f;
                        for s in 1..n_shards {
                            scope.spawn(move || f(s));
                        }
                        f(0);
                    });
                } else {
                    for s in 0..n_shards {
                        f(s);
                    }
                }
            }
        }
    }
}

/// Raw mutable base pointer handed to shard closures that write
/// pairwise-disjoint regions of one caller-owned buffer. `Send + Sync`
/// because pool/scoped shards share the closure by reference; soundness
/// rests on the disjoint-region contract each call site documents.
pub(crate) struct ShardPtr<T>(pub(crate) *mut T);
// manual impls: the pointer is Copy regardless of T (a derive would
// wrongly bound `T: Copy`)
impl<T> Clone for ShardPtr<T> {
    fn clone(&self) -> Self {
        ShardPtr(self.0)
    }
}
impl<T> Copy for ShardPtr<T> {}
unsafe impl<T: Send> Send for ShardPtr<T> {}
unsafe impl<T: Send> Sync for ShardPtr<T> {}

/// Shard count for a kernel invocation: 1 (sequential) when the context
/// has one thread, the work is below the context's two-tier crossover
/// ([`Exec::min_work`]), or the shardable dimension cannot be split;
/// otherwise `min(threads, max_shards)`.
fn shard_count(exec: Exec<'_>, batch: usize, nnz: usize, max_shards: usize) -> usize {
    if exec.threads() <= 1 || max_shards <= 1 {
        return 1;
    }
    if batch.saturating_mul(nnz) < exec.min_work() {
        return 1;
    }
    exec.threads().min(max_shards)
}

/// Partition rows into `shards` contiguous ranges of roughly equal nnz.
/// Returns `shards + 1` monotone bounds with `bounds[0] == 0` and
/// `bounds[shards] == n_rows`; shard `s` owns rows
/// `[bounds[s], bounds[s+1])` and value slots
/// `[row_ptr[bounds[s]], row_ptr[bounds[s+1]])`.
///
/// Shared by the grad-weights / fused-backward kernels (DESIGN.md §4–§5)
/// and the topology-evolution engine's rebuild pass (DESIGN.md §8) —
/// any per-row output whose slots are contiguous in storage order can
/// shard on these bounds with disjoint `split_at_mut` sub-slices.
pub fn balanced_row_bounds(row_ptr: &[usize], shards: usize) -> Vec<usize> {
    let n_rows = row_ptr.len() - 1;
    let nnz = row_ptr[n_rows];
    let mut bounds = Vec::with_capacity(shards + 1);
    bounds.push(0usize);
    for s in 1..shards {
        let target = (nnz * s).div_ceil(shards);
        // row_ptr is monotone: first row whose start offset reaches the
        // cumulative-nnz target, clamped monotone and within [0, n_rows].
        let r = row_ptr
            .partition_point(|&p| p < target)
            .clamp(*bounds.last().unwrap(), n_rows);
        bounds.push(r);
    }
    bounds.push(n_rows);
    bounds
}

/// How the row-sharded kernels (grad-weights, fused backward) lay rows
/// onto shards (DESIGN.md §11.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowSchedulePolicy {
    /// Contiguous nnz-balanced ranges normally; switch to the
    /// length-sorted LPT schedule when the contiguous split is skewed
    /// (heaviest shard > 1.25× the mean). The default.
    Adaptive,
    /// Always contiguous [`balanced_row_bounds`] ranges — the pre-§11
    /// behaviour, kept as a kill switch and as the bench baseline.
    Contiguous,
}

/// Process-wide policy knob (0 = Adaptive, 1 = Contiguous). A scheduling
/// choice only — every schedule produces bit-identical results — so a
/// relaxed global is safe.
static ROW_SCHEDULE_POLICY: AtomicU8 = AtomicU8::new(0);

/// Set the process-wide row-scheduling policy (bench toggle/kill switch).
pub fn set_row_schedule_policy(policy: RowSchedulePolicy) {
    ROW_SCHEDULE_POLICY.store(policy as u8, Ordering::Relaxed);
}

/// The current process-wide row-scheduling policy.
pub fn row_schedule_policy() -> RowSchedulePolicy {
    if ROW_SCHEDULE_POLICY.load(Ordering::Relaxed) == RowSchedulePolicy::Contiguous as u8 {
        RowSchedulePolicy::Contiguous
    } else {
        RowSchedulePolicy::Adaptive
    }
}

/// A row→shard assignment for the row-sharded kernels.
pub(crate) enum RowSchedule {
    /// Shard `s` owns the contiguous row range `[bounds[s], bounds[s+1])`.
    Contiguous(Vec<usize>),
    /// Shard `s` owns the (ascending) explicit row list
    /// `rows[starts[s]..starts[s + 1]]` — built by longest-processing-time
    /// greedy assignment over the length-sorted rows, so skewed matrices
    /// stop straggling on whichever shard drew the heavy rows.
    Balanced { starts: Vec<usize>, rows: Vec<u32> },
}

/// Build the row schedule for `shards` shards over `w`'s rows.
///
/// Contiguous bounds are kept whenever they are already balanced (the
/// common quasi-uniform Erdős–Rényi case — no permutation, no extra
/// allocation beyond the bounds) or the policy forces them. Otherwise:
/// LPT greedy over [`CsrMatrix::rows_by_nnz_desc`], assigning each row to
/// the least-loaded shard. **Every** row is assigned — including empty
/// ones, whose dx columns the fused kernel still owns — and each shard's
/// list is sorted ascending so kernel calls walk storage in order.
pub(crate) fn row_schedule(w: &CsrMatrix, shards: usize) -> RowSchedule {
    let bounds = balanced_row_bounds(&w.row_ptr, shards);
    if row_schedule_policy() == RowSchedulePolicy::Contiguous {
        return RowSchedule::Contiguous(bounds);
    }
    let nnz = w.nnz();
    let max_shard_nnz = bounds
        .windows(2)
        .map(|b| w.row_ptr[b[1]] - w.row_ptr[b[0]])
        .max()
        .unwrap_or(0);
    // Skew test in integers: heaviest shard ≤ 1.25 × (nnz / shards) keeps
    // the contiguous split (straggler bounded at +25% of a shard's work).
    if max_shard_nnz.saturating_mul(shards).saturating_mul(4) <= nnz.saturating_mul(5) {
        return RowSchedule::Contiguous(bounds);
    }
    let order = w.rows_by_nnz_desc();
    let mut load = vec![0usize; shards];
    let mut lists: Vec<Vec<u32>> = vec![Vec::new(); shards];
    for &r in &order {
        let row = r as usize;
        let len = w.row_ptr[row + 1] - w.row_ptr[row];
        let mut best = 0usize;
        for s in 1..shards {
            if load[s] < load[best] {
                best = s;
            }
        }
        // Empty rows cost ~one batch column of dx writes in the fused
        // kernel — charge 1 so they spread instead of piling up.
        load[best] += len.max(1);
        lists[best].push(r);
    }
    let mut rows = Vec::with_capacity(order.len());
    let mut starts = Vec::with_capacity(shards + 1);
    starts.push(0);
    for mut list in lists {
        list.sort_unstable();
        rows.append(&mut list);
        starts.push(rows.len());
    }
    RowSchedule::Balanced { starts, rows }
}

/// Iterator over maximal runs of consecutive row ids in an ascending
/// list, yielding `(r0, r1)` half-open ranges — the balanced schedule's
/// unit of kernel dispatch, amortizing per-call batch-block setup (and
/// the SIMD kernels' scratch transposes) across each run.
struct RowRuns<'a> {
    rows: &'a [u32],
    pos: usize,
}

impl<'a> RowRuns<'a> {
    fn new(rows: &'a [u32]) -> RowRuns<'a> {
        RowRuns { rows, pos: 0 }
    }
}

impl Iterator for RowRuns<'_> {
    type Item = (usize, usize);

    fn next(&mut self) -> Option<(usize, usize)> {
        let r0 = *self.rows.get(self.pos)? as usize;
        let mut r1 = r0 + 1;
        self.pos += 1;
        while self.pos < self.rows.len() && self.rows[self.pos] as usize == r1 {
            r1 += 1;
            self.pos += 1;
        }
        Some((r0, r1))
    }
}

/// [`spmm_forward`] sharded over the batch dimension across up to
/// `threads` scoped workers (`0` = one per available core). Each worker
/// writes a disjoint contiguous range of `out` rows; results are exactly
/// equal to the sequential kernel. Falls back to [`spmm_forward`] below
/// the [`PAR_MIN_WORK`] crossover.
///
/// # Examples
///
/// ```
/// use tsnn::sparse::{ops, CsrMatrix};
///
/// let w = CsrMatrix::from_coo(2, 2, vec![(0, 0, 1.0), (1, 1, 2.0)]).unwrap();
/// let x = [3.0, 4.0, 5.0, 6.0]; // two samples
/// let mut seq = vec![0.0f32; 4];
/// let mut par = vec![0.0f32; 4];
/// ops::spmm_forward(&x, 2, &w, &mut seq);
/// ops::spmm_forward_threaded(&x, 2, &w, &mut par, 4);
/// assert_eq!(seq, par);
/// ```
pub fn spmm_forward_threaded(
    x: &[f32],
    batch: usize,
    w: &CsrMatrix,
    out: &mut [f32],
    threads: usize,
) {
    spmm_forward_exec(x, batch, w, out, Exec::scoped(threads));
}

/// [`spmm_forward_threaded`] with an explicit execution context: pooled
/// dispatch on the hot path, scoped spawns on the cold fallback, and the
/// context's microkernel ISA ([`Exec::isa`]) on every path —
/// bit-identical results either way (§11.3).
pub fn spmm_forward_exec(x: &[f32], batch: usize, w: &CsrMatrix, out: &mut [f32], exec: Exec<'_>) {
    let (n_in, n_out) = (w.n_rows, w.n_cols);
    assert_eq!(x.len(), batch * n_in);
    assert_eq!(out.len(), batch * n_out);
    debug_assert!(w.validate().is_ok());
    let table = kernel_table(exec.isa);
    let shards = shard_count(exec, batch, w.nnz(), batch);
    if shards <= 1 {
        // SAFETY: lengths asserted above, CSR validated; kernel_table
        // only hands out tables whose ISA the host supports.
        return unsafe { (table.forward)(x, batch, w, out) };
    }
    // shards > 1 implies batch ≥ 2 and nnz ≥ 1, hence n_in, n_out ≥ 1.
    let rows_per = batch.div_ceil(shards);
    let out_ptr = ShardPtr(out.as_mut_ptr());
    exec.run(shards, |s| {
        let b0 = (s * rows_per).min(batch);
        let b1 = ((s + 1) * rows_per).min(batch);
        if b0 >= b1 {
            return;
        }
        // SAFETY: shard s writes only out rows [b0, b1) — contiguous,
        // pairwise-disjoint sample ranges of a buffer that outlives the
        // dispatch (the run() gather is the release point, §9.2).
        let oc = unsafe {
            std::slice::from_raw_parts_mut(out_ptr.0.add(b0 * n_out), (b1 - b0) * n_out)
        };
        // SAFETY: sub-slice lengths match the sub-batch; table as above.
        unsafe { (table.forward)(&x[b0 * n_in..b1 * n_in], b1 - b0, w, oc) };
    });
}

/// [`spmm_grad_input`] sharded over the batch dimension (disjoint `dx`
/// rows per worker, exact-match results, sequential fallback below the
/// crossover). `threads == 0` means one worker per available core.
pub fn spmm_grad_input_threaded(
    dz: &[f32],
    batch: usize,
    w: &CsrMatrix,
    dx: &mut [f32],
    threads: usize,
) {
    spmm_grad_input_exec(dz, batch, w, dx, Exec::scoped(threads));
}

/// [`spmm_grad_input_threaded`] with an explicit execution context.
pub fn spmm_grad_input_exec(
    dz: &[f32],
    batch: usize,
    w: &CsrMatrix,
    dx: &mut [f32],
    exec: Exec<'_>,
) {
    let (n_in, n_out) = (w.n_rows, w.n_cols);
    assert_eq!(dz.len(), batch * n_out);
    assert_eq!(dx.len(), batch * n_in);
    debug_assert!(w.validate().is_ok());
    let table = kernel_table(exec.isa);
    let shards = shard_count(exec, batch, w.nnz(), batch);
    if shards <= 1 {
        // SAFETY: lengths asserted above, CSR validated; table ISA is
        // host-supported (see spmm_forward_exec).
        return unsafe { (table.grad_input)(dz, batch, w, dx) };
    }
    let rows_per = batch.div_ceil(shards);
    let dx_ptr = ShardPtr(dx.as_mut_ptr());
    exec.run(shards, |s| {
        let b0 = (s * rows_per).min(batch);
        let b1 = ((s + 1) * rows_per).min(batch);
        if b0 >= b1 {
            return;
        }
        // SAFETY: disjoint contiguous dx sample ranges per shard (see
        // spmm_forward_exec).
        let xc = unsafe {
            std::slice::from_raw_parts_mut(dx_ptr.0.add(b0 * n_in), (b1 - b0) * n_in)
        };
        // SAFETY: sub-slice lengths match the sub-batch; table as above.
        unsafe { (table.grad_input)(&dz[b0 * n_out..b1 * n_out], b1 - b0, w, xc) };
    });
}

/// [`spmm_grad_weights`] sharded over nnz ranges: W's rows are split into
/// contiguous ranges of roughly equal nnz and each worker accumulates the
/// batch reduction for its own disjoint `dw` sub-slice (no atomics, and
/// the batch loop order matches the sequential kernel, so results are
/// exactly equal). `threads == 0` means one worker per available core;
/// falls back to [`spmm_grad_weights`] below the crossover.
pub fn spmm_grad_weights_threaded(
    x: &[f32],
    dz: &[f32],
    batch: usize,
    w: &CsrMatrix,
    dw: &mut [f32],
    threads: usize,
) {
    spmm_grad_weights_exec(x, dz, batch, w, dw, Exec::scoped(threads));
}

/// [`spmm_grad_weights_threaded`] with an explicit execution context.
pub fn spmm_grad_weights_exec(
    x: &[f32],
    dz: &[f32],
    batch: usize,
    w: &CsrMatrix,
    dw: &mut [f32],
    exec: Exec<'_>,
) {
    assert_eq!(x.len(), batch * w.n_rows);
    assert_eq!(dz.len(), batch * w.n_cols);
    assert_eq!(dw.len(), w.nnz());
    debug_assert!(w.validate().is_ok());
    let table = kernel_table(exec.isa);
    let shards = shard_count(exec, batch, w.nnz(), w.n_rows);
    if shards <= 1 {
        // SAFETY: lengths asserted above, CSR validated; table ISA is
        // host-supported (see spmm_forward_exec).
        return unsafe { (table.grad_weights_rows)(x, dz, batch, w, 0, w.n_rows, dw) };
    }
    let shards = exec.row_shard_budget(shards, w.n_rows);
    let dw_ptr = ShardPtr(dw.as_mut_ptr());
    match row_schedule(w, shards) {
        RowSchedule::Contiguous(bounds) => {
            let bounds = bounds.as_slice();
            exec.run(shards, |s| {
                let (r0, r1) = (bounds[s], bounds[s + 1]);
                let (k0, k1) = (w.row_ptr[r0], w.row_ptr[r1]);
                if k0 == k1 {
                    return; // nnz-heavy row swallowed this shard's budget
                }
                // SAFETY: shard s writes only dw slots [k0, k1) — row_ptr
                // is monotone, so the value-slot ranges of disjoint row
                // ranges are disjoint (§4.1); the buffer outlives the
                // dispatch.
                let head = unsafe { std::slice::from_raw_parts_mut(dw_ptr.0.add(k0), k1 - k0) };
                // SAFETY: dw sub-slice spans rows [r0, r1); table as above.
                unsafe { (table.grad_weights_rows)(x, dz, batch, w, r0, r1, head) };
            });
        }
        RowSchedule::Balanced { starts, rows } => {
            exec.run(shards, |s| {
                for (r0, r1) in RowRuns::new(&rows[starts[s]..starts[s + 1]]) {
                    let (k0, k1) = (w.row_ptr[r0], w.row_ptr[r1]);
                    if k0 == k1 {
                        continue; // all-empty run: no dw slots to fill
                    }
                    // SAFETY: every row belongs to exactly one shard's
                    // list, so run slot ranges are pairwise disjoint
                    // across the dispatch (§11.4); buffer as above.
                    let head =
                        unsafe { std::slice::from_raw_parts_mut(dw_ptr.0.add(k0), k1 - k0) };
                    // SAFETY: dw sub-slice spans the run; table as above.
                    unsafe { (table.grad_weights_rows)(x, dz, batch, w, r0, r1, head) };
                }
            });
        }
    }
}

/// Dense reference matmul for the test oracle: `x[batch, n_in] @ w_dense`.
pub fn dense_matmul(x: &[f32], batch: usize, w: &[f32], n_in: usize, n_out: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; batch * n_out];
    for b in 0..batch {
        for i in 0..n_in {
            let xv = x[b * n_in + i];
            if xv == 0.0 {
                continue;
            }
            for j in 0..n_out {
                out[b * n_out + j] += xv * w[i * n_out + j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::init;
    use crate::util::Rng;

    fn close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "idx {i}: {x} vs {y}");
        }
    }

    fn random_x(rng: &mut Rng, batch: usize, n: usize, zero_frac: f64) -> Vec<f32> {
        (0..batch * n)
            .map(|_| {
                if rng.bernoulli(zero_frac) {
                    0.0
                } else {
                    rng.normal()
                }
            })
            .collect()
    }

    #[test]
    fn forward_matches_dense() {
        let mut rng = Rng::new(1);
        let w = init::erdos_renyi(17, 13, 0.3, &mut rng, &init::WeightInit::Normal(0.5));
        let x = random_x(&mut rng, 5, 17, 0.3);
        let mut out = vec![0.0f32; 5 * 13];
        spmm_forward(&x, 5, &w, &mut out);
        let dense = dense_matmul(&x, 5, &w.to_dense(), 17, 13);
        close(&out, &dense, 1e-5);
    }

    #[test]
    fn grad_input_matches_dense_transpose() {
        let mut rng = Rng::new(2);
        let w = init::erdos_renyi(9, 11, 0.4, &mut rng, &init::WeightInit::Normal(1.0));
        let dz = random_x(&mut rng, 4, 11, 0.0);
        let mut dx = vec![0.0f32; 4 * 9];
        spmm_grad_input(&dz, 4, &w, &mut dx);
        // oracle: dz @ W^T via dense
        let wt = w.transpose();
        let dense = dense_matmul(&dz, 4, &wt.to_dense(), 11, 9);
        close(&dx, &dense, 1e-5);
    }

    #[test]
    fn grad_weights_matches_dense_outer_product() {
        let mut rng = Rng::new(3);
        let w = init::erdos_renyi(8, 6, 0.5, &mut rng, &init::WeightInit::Normal(1.0));
        let x = random_x(&mut rng, 7, 8, 0.2);
        let dz = random_x(&mut rng, 7, 6, 0.0);
        let mut dw = vec![0.0f32; w.nnz()];
        spmm_grad_weights(&x, &dz, 7, &w, &mut dw);
        // oracle: full dense dW = x^T dz, then read pattern positions
        for (k, (i, j, _)) in w.iter().enumerate() {
            let mut acc = 0.0f32;
            for b in 0..7 {
                acc += x[b * 8 + i] * dz[b * 6 + j as usize];
            }
            assert!((dw[k] - acc).abs() < 1e-4, "k={k}");
        }
    }

    #[test]
    fn bias_grad_sums_batch() {
        let dz = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let mut db = vec![0.0f32; 3];
        bias_grad(&dz, 2, 3, &mut db);
        assert_eq!(db, vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn bias_grad_handles_odd_batches_and_degenerate_shapes() {
        // odd batch exercises the single-row remainder of the 2-row pass
        let dz = vec![1.0f32, 2.0, 10.0, 20.0, 100.0, 200.0]; // 3x2
        let mut db = vec![0.0f32; 2];
        bias_grad(&dz, 3, 2, &mut db);
        assert_eq!(db, vec![111.0, 222.0]);
        // batch 1: pure remainder path
        let mut db = vec![0.5f32; 2];
        bias_grad(&[3.0, 4.0], 1, 2, &mut db);
        assert_eq!(db, vec![3.5, 4.5]);
        // zero batch / zero width: no-ops, no panic
        bias_grad(&[], 0, 2, &mut [0.0, 0.0]);
        bias_grad(&[], 5, 0, &mut []);
    }

    /// Sequential two-kernel oracle for the fused backward.
    fn oracle_backward(
        x: &[f32],
        dz: &[f32],
        batch: usize,
        w: &CsrMatrix,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut dx = vec![0.0f32; batch * w.n_rows];
        let mut dw = vec![0.0f32; w.nnz()];
        spmm_grad_input(dz, batch, w, &mut dx);
        spmm_grad_weights(x, dz, batch, w, &mut dw);
        (dx, dw)
    }

    #[test]
    fn fused_backward_matches_two_kernel_oracle_exactly() {
        let mut rng = Rng::new(40);
        // batches chosen to hit full-block-only, tail-only and mixed
        // paths: batch % 8 ∈ {5, 0, 2, 4, 3, 6} here; widths 1 and 7 are
        // covered by the kernel_parity integration grid
        for &(n_in, n_out, density, batch) in &[
            (17usize, 13usize, 0.3f64, 5usize),
            (64, 48, 0.2, 8),
            (64, 48, 0.2, 10),
            (64, 48, 0.2, 12),
            (90, 70, 0.4, 19),
            (90, 70, 0.4, 22),
        ] {
            let w = erdos_renyi_like(n_in, n_out, density, &mut rng);
            let x = random_x(&mut rng, batch, n_in, 0.3);
            let dz = random_x(&mut rng, batch, n_out, 0.0);
            let (dx_o, dw_o) = oracle_backward(&x, &dz, batch, &w);
            for threads in [1usize, 2, 8] {
                let mut dx = vec![f32::NAN; batch * n_in]; // must be overwritten
                let mut dw = vec![0.0f32; w.nnz()];
                spmm_backward_fused(&x, &dz, batch, &w, &mut dx, &mut dw, threads);
                assert_eq!(dx, dx_o, "dx {n_in}x{n_out} b{batch} t{threads}");
                assert_eq!(dw, dw_o, "dw {n_in}x{n_out} b{batch} t{threads}");
            }
        }
    }

    fn erdos_renyi_like(n_in: usize, n_out: usize, density: f64, rng: &mut Rng) -> CsrMatrix {
        init::erdos_renyi(n_in, n_out, density, rng, &init::WeightInit::Normal(0.5))
    }

    #[test]
    fn fused_backward_shards_above_crossover_and_matches_exactly() {
        let mut rng = Rng::new(41);
        let w = erdos_renyi_like(256, 512, 0.35, &mut rng);
        let batch = 64;
        assert!(batch * w.nnz() >= PAR_MIN_WORK, "test must cross the threshold");
        let x = random_x(&mut rng, batch, 256, 0.3);
        let dz = random_x(&mut rng, batch, 512, 0.0);
        let (dx_o, dw_o) = oracle_backward(&x, &dz, batch, &w);
        for threads in [2usize, 3, 8] {
            let mut dx = vec![f32::NAN; batch * 256];
            let mut dw = vec![0.0f32; w.nnz()];
            spmm_backward_fused(&x, &dz, batch, &w, &mut dx, &mut dw, threads);
            assert_eq!(dx, dx_o, "dx threads={threads}");
            assert_eq!(dw, dw_o, "dw threads={threads}");
        }
    }

    #[test]
    fn fused_backward_zeroes_dx_for_empty_rows() {
        // rows 1 and 3 carry no links: their dx columns must still be
        // written (zeroed), including on the sharded path
        let w = CsrMatrix::from_coo(
            4,
            3,
            vec![(0u32, 0u32, 2.0f32), (2, 1, -1.0), (2, 2, 0.5)],
        )
        .unwrap();
        let batch = 9; // full block + tail
        let mut rng = Rng::new(42);
        let x = random_x(&mut rng, batch, 4, 0.2);
        let dz = random_x(&mut rng, batch, 3, 0.0);
        let (dx_o, dw_o) = oracle_backward(&x, &dz, batch, &w);
        for threads in [1usize, 8] {
            let mut dx = vec![f32::NAN; batch * 4];
            let mut dw = vec![0.0f32; w.nnz()];
            spmm_backward_fused(&x, &dz, batch, &w, &mut dx, &mut dw, threads);
            assert_eq!(dx, dx_o, "threads={threads}");
            assert_eq!(dw, dw_o, "threads={threads}");
            for b in 0..batch {
                assert_eq!(dx[b * 4 + 1], 0.0);
                assert_eq!(dx[b * 4 + 3], 0.0);
            }
        }
    }

    #[test]
    fn fused_backward_handles_empty_matrix_and_zero_batch() {
        let w = CsrMatrix::empty(4, 5);
        let x = vec![1.0f32; 2 * 4];
        let dz = vec![1.0f32; 2 * 5];
        let mut dx = vec![f32::NAN; 2 * 4];
        let mut dw: Vec<f32> = Vec::new();
        spmm_backward_fused(&x, &dz, 2, &w, &mut dx, &mut dw, 8);
        assert!(dx.iter().all(|&v| v == 0.0));
        let mut rng = Rng::new(43);
        let w = erdos_renyi_like(6, 6, 0.5, &mut rng);
        let mut dw = vec![0.0f32; w.nnz()];
        spmm_backward_fused(&[], &[], 0, &w, &mut [], &mut dw, 8);
        assert!(dw.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn zero_input_produces_zero_everything() {
        let mut rng = Rng::new(4);
        let w = init::erdos_renyi(6, 6, 0.5, &mut rng, &init::WeightInit::Normal(1.0));
        let x = vec![0.0f32; 3 * 6];
        let mut out = vec![0.0f32; 3 * 6];
        spmm_forward(&x, 3, &w, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
        let mut dw = vec![0.0f32; w.nnz()];
        spmm_grad_weights(&x, &out, 3, &w, &mut dw);
        assert!(dw.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn empty_matrix_is_noop() {
        let w = CsrMatrix::empty(4, 5);
        let x = vec![1.0f32; 2 * 4];
        let mut out = vec![0.0f32; 2 * 5];
        spmm_forward(&x, 2, &w, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn balanced_bounds_cover_all_rows_with_disjoint_nnz_ranges() {
        let mut rng = Rng::new(6);
        let w = init::erdos_renyi(97, 31, 0.23, &mut rng, &init::WeightInit::Normal(1.0));
        for shards in [1, 2, 3, 8, 97, 200] {
            let bounds = balanced_row_bounds(&w.row_ptr, shards);
            assert_eq!(bounds.len(), shards + 1);
            assert_eq!(bounds[0], 0);
            assert_eq!(bounds[shards], w.n_rows);
            let mut covered = 0usize;
            for win in bounds.windows(2) {
                assert!(win[0] <= win[1]);
                covered += w.row_ptr[win[1]] - w.row_ptr[win[0]];
            }
            assert_eq!(covered, w.nnz());
        }
    }

    #[test]
    fn threaded_kernels_fall_back_below_crossover_and_match_exactly() {
        // Small problem: work ≪ PAR_MIN_WORK, so the threaded entry points
        // must take the sequential path — and still be exactly equal.
        let mut rng = Rng::new(7);
        let w = init::erdos_renyi(23, 17, 0.4, &mut rng, &init::WeightInit::Normal(1.0));
        let batch = 9;
        let x = random_x(&mut rng, batch, 23, 0.2);
        let dz = random_x(&mut rng, batch, 17, 0.0);
        let (mut a, mut b) = (vec![0.0f32; batch * 17], vec![0.0f32; batch * 17]);
        spmm_forward(&x, batch, &w, &mut a);
        spmm_forward_threaded(&x, batch, &w, &mut b, 8);
        assert_eq!(a, b);
        let (mut a, mut b) = (vec![0.0f32; batch * 23], vec![0.0f32; batch * 23]);
        spmm_grad_input(&dz, batch, &w, &mut a);
        spmm_grad_input_threaded(&dz, batch, &w, &mut b, 8);
        assert_eq!(a, b);
        let (mut a, mut b) = (vec![0.0f32; w.nnz()], vec![0.0f32; w.nnz()]);
        spmm_grad_weights(&x, &dz, batch, &w, &mut a);
        spmm_grad_weights_threaded(&x, &dz, batch, &w, &mut b, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn threaded_kernels_shard_above_crossover_and_match_exactly() {
        // 256×512 at density 0.35 ≈ 46k nnz; batch 64 → ~2.9M MACs, which
        // crosses PAR_MIN_WORK so the sharded path genuinely runs.
        let mut rng = Rng::new(8);
        let w = init::erdos_renyi(256, 512, 0.35, &mut rng, &init::WeightInit::Normal(0.5));
        let batch = 64;
        assert!(batch * w.nnz() >= PAR_MIN_WORK, "test must cross the threshold");
        let x = random_x(&mut rng, batch, 256, 0.3);
        let dz = random_x(&mut rng, batch, 512, 0.0);
        for threads in [2, 3, 8] {
            let (mut a, mut b) = (vec![0.0f32; batch * 512], vec![0.0f32; batch * 512]);
            spmm_forward(&x, batch, &w, &mut a);
            spmm_forward_threaded(&x, batch, &w, &mut b, threads);
            assert_eq!(a, b, "forward threads={threads}");
            let (mut a, mut b) = (vec![0.0f32; batch * 256], vec![0.0f32; batch * 256]);
            spmm_grad_input(&dz, batch, &w, &mut a);
            spmm_grad_input_threaded(&dz, batch, &w, &mut b, threads);
            assert_eq!(a, b, "grad_input threads={threads}");
            let (mut a, mut b) = (vec![0.0f32; w.nnz()], vec![0.0f32; w.nnz()]);
            spmm_grad_weights(&x, &dz, batch, &w, &mut a);
            spmm_grad_weights_threaded(&x, &dz, batch, &w, &mut b, threads);
            assert_eq!(a, b, "grad_weights threads={threads}");
        }
    }

    #[test]
    fn threaded_kernels_handle_empty_matrix_and_zero_batch() {
        let w = CsrMatrix::empty(4, 5);
        let x = vec![1.0f32; 2 * 4];
        let mut out = vec![0.0f32; 2 * 5];
        spmm_forward_threaded(&x, 2, &w, &mut out, 8);
        assert!(out.iter().all(|&v| v == 0.0));
        let mut dw: Vec<f32> = Vec::new();
        spmm_grad_weights_threaded(&x, &out, 2, &w, &mut dw, 8);
        // zero-batch: all buffers empty, must not panic
        let mut rng = Rng::new(9);
        let w = init::erdos_renyi(6, 6, 0.5, &mut rng, &init::WeightInit::Normal(1.0));
        spmm_forward_threaded(&[], 0, &w, &mut [], 8);
        spmm_grad_input_threaded(&[], 0, &w, &mut [], 8);
        let mut dw = vec![0.0f32; w.nnz()];
        spmm_grad_weights_threaded(&[], &[], 0, &w, &mut dw, 8);
        assert!(dw.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn pooled_kernels_shard_in_the_old_subcrossover_gap_and_match_exactly() {
        // POOL_MIN_WORK <= batch·nnz < PAR_MIN_WORK: the pooled context
        // genuinely shards where the scoped fallback stays sequential —
        // and both produce bit-identical results.
        let mut rng = Rng::new(50);
        let w = init::erdos_renyi(128, 128, 0.25, &mut rng, &init::WeightInit::Normal(0.5));
        let batch = 64;
        let work = batch * w.nnz();
        assert!(
            (POOL_MIN_WORK..PAR_MIN_WORK).contains(&work),
            "test must sit in the old sub-crossover gap, work = {work}"
        );
        let x = random_x(&mut rng, batch, 128, 0.3);
        let dz = random_x(&mut rng, batch, 128, 0.0);
        let pool = WorkerPool::new(4);
        let exec = Exec::pooled(&pool);

        let (mut a, mut b) = (vec![0.0f32; batch * 128], vec![0.0f32; batch * 128]);
        spmm_forward(&x, batch, &w, &mut a);
        spmm_forward_exec(&x, batch, &w, &mut b, exec);
        assert_eq!(a, b, "forward");
        let (mut a, mut b) = (vec![0.0f32; batch * 128], vec![0.0f32; batch * 128]);
        spmm_grad_input(&dz, batch, &w, &mut a);
        spmm_grad_input_exec(&dz, batch, &w, &mut b, exec);
        assert_eq!(a, b, "grad_input");
        let (mut a, mut b) = (vec![0.0f32; w.nnz()], vec![0.0f32; w.nnz()]);
        spmm_grad_weights(&x, &dz, batch, &w, &mut a);
        spmm_grad_weights_exec(&x, &dz, batch, &w, &mut b, exec);
        assert_eq!(a, b, "grad_weights");
        let (dx_o, dw_o) = oracle_backward(&x, &dz, batch, &w);
        let mut dx = vec![f32::NAN; batch * 128];
        let mut dw = vec![0.0f32; w.nnz()];
        spmm_backward_fused_exec(&x, &dz, batch, &w, &mut dx, &mut dw, exec);
        assert_eq!(dx, dx_o, "fused dx");
        assert_eq!(dw, dw_o, "fused dw");
        // all four kernels really dispatched onto the pool
        assert_eq!(pool.dispatch_events(), 4);
    }

    #[test]
    fn scoped_dispatch_counter_moves_on_the_cold_path_only() {
        // The counter is process-global and other tests may add to it
        // concurrently, so both assertions are monotonic deltas.
        let mut rng = Rng::new(51);
        let w = init::erdos_renyi(256, 512, 0.35, &mut rng, &init::WeightInit::Normal(0.5));
        let batch = 64;
        assert!(batch * w.nnz() >= PAR_MIN_WORK);
        let x = random_x(&mut rng, batch, 256, 0.3);
        let mut out = vec![0.0f32; batch * 512];
        let before = scoped_dispatch_events();
        spmm_forward_threaded(&x, batch, &w, &mut out, 4);
        assert!(
            scoped_dispatch_events() > before,
            "pool-less sharded dispatch must count as a scoped spawn"
        );
        // pooled dispatch of the same problem moves the pool's counter,
        // not necessarily the global scoped one (cannot assert equality
        // under test concurrency, but the pool counter is private)
        let pool = WorkerPool::new(4);
        let d0 = pool.dispatch_events();
        spmm_forward_exec(&x, batch, &w, &mut out, Exec::pooled(&pool));
        assert_eq!(pool.dispatch_events(), d0 + 1);
    }

    #[test]
    fn exec_crossover_is_two_tier() {
        let pool = WorkerPool::new(8);
        assert_eq!(Exec::pooled(&pool).min_work(), POOL_MIN_WORK);
        assert_eq!(Exec::scoped(8).min_work(), PAR_MIN_WORK);
        assert!(POOL_MIN_WORK < PAR_MIN_WORK);
        // gap-sized work: pooled shards, scoped falls back
        let work = 1 << 18;
        assert_eq!(shard_count(Exec::pooled(&pool), work, 1, 64), 8);
        assert_eq!(shard_count(Exec::scoped(8), work, 1, 64), 1);
        assert_eq!(shard_count(Exec::sequential(), usize::MAX, 1, 64), 1);
    }

    #[test]
    fn resolve_threads_zero_is_auto() {
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(1), 1);
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(0), available_threads());
    }

    #[test]
    fn batch_one_consistency() {
        // result for a stacked batch equals per-sample results
        let mut rng = Rng::new(5);
        let w = init::erdos_renyi(10, 7, 0.35, &mut rng, &init::WeightInit::Normal(1.0));
        let x = random_x(&mut rng, 3, 10, 0.0);
        let mut full = vec![0.0f32; 3 * 7];
        spmm_forward(&x, 3, &w, &mut full);
        for b in 0..3 {
            let mut one = vec![0.0f32; 7];
            spmm_forward(&x[b * 10..(b + 1) * 10], 1, &w, &mut one);
            close(&one, &full[b * 7..(b + 1) * 7], 1e-6);
        }
    }

    #[test]
    fn row_runs_yield_maximal_consecutive_ranges() {
        let rows = [0u32, 1, 2, 5, 7, 8];
        let runs: Vec<_> = RowRuns::new(&rows).collect();
        assert_eq!(runs, vec![(0, 3), (5, 6), (7, 9)]);
        assert_eq!(RowRuns::new(&[]).count(), 0);
        assert_eq!(RowRuns::new(&[4u32]).collect::<Vec<_>>(), vec![(4, 5)]);
    }

    /// One heavy row dominating nnz: the adaptive schedule must switch to
    /// the balanced LPT assignment; forcing `Contiguous` must switch it
    /// back. Both live in one test because the policy knob is
    /// process-global (other tests only ever read the default).
    #[test]
    fn row_schedule_balances_skew_and_honours_the_policy_toggle() {
        let mut coo: Vec<(u32, u32, f32)> = Vec::new();
        for j in 0..600u32 {
            coo.push((3, j, 1.0));
        }
        for r in 0..32u32 {
            if r != 3 {
                coo.push((r, 600 + r, 0.5));
            }
        }
        // rows 32..40 are empty — they must still be scheduled (the
        // fused kernel owns their dx columns)
        let w = CsrMatrix::from_coo(40, 640, coo).unwrap();
        let shards = 4;
        match row_schedule(&w, shards) {
            RowSchedule::Balanced { starts, rows } => {
                assert_eq!(starts.len(), shards + 1);
                assert_eq!(rows.len(), w.n_rows, "every row must be scheduled");
                let mut seen = vec![false; w.n_rows];
                for s in 0..shards {
                    let list = &rows[starts[s]..starts[s + 1]];
                    assert!(list.windows(2).all(|p| p[0] < p[1]), "shard {s} not ascending");
                    for &r in list {
                        assert!(!seen[r as usize], "row {r} scheduled twice");
                        seen[r as usize] = true;
                    }
                }
                assert!(seen.iter().all(|&v| v), "row dropped from the schedule");
            }
            RowSchedule::Contiguous(_) => panic!("skewed matrix must trigger the LPT schedule"),
        }
        set_row_schedule_policy(RowSchedulePolicy::Contiguous);
        let forced = matches!(row_schedule(&w, shards), RowSchedule::Contiguous(_));
        set_row_schedule_policy(RowSchedulePolicy::Adaptive);
        assert!(forced, "Contiguous policy must suppress the LPT schedule");
        assert_eq!(row_schedule_policy(), RowSchedulePolicy::Adaptive);
        // quasi-uniform matrix: adaptive keeps the contiguous bounds
        let mut rng = Rng::new(60);
        let u = erdos_renyi_like(64, 64, 0.5, &mut rng);
        assert!(matches!(row_schedule(&u, 4), RowSchedule::Contiguous(_)));
    }

    #[test]
    fn row_scheduled_kernels_match_sequential_on_skewed_matrices() {
        // One row owns most of the nnz (the §11.4 straggler shape); the
        // pooled path oversubscribes and LPT-schedules, and must still be
        // bit-identical to the sequential kernels.
        let mut coo: Vec<(u32, u32, f32)> = Vec::new();
        for j in 0..1500u32 {
            coo.push((3, j, 0.01 * j as f32 - 5.0));
        }
        for r in 0..64u32 {
            if r == 3 {
                continue;
            }
            for t in 0..4u32 {
                coo.push((r, (r * 23 + t * 31) % 1500, 0.1 * (r + t) as f32 - 1.0));
            }
        }
        let w = CsrMatrix::from_coo(64, 1500, coo).unwrap();
        let batch = 32;
        assert!(batch * w.nnz() >= POOL_MIN_WORK, "must cross the warm crossover");
        let mut rng = Rng::new(61);
        let x = random_x(&mut rng, batch, 64, 0.2);
        let dz = random_x(&mut rng, batch, 1500, 0.0);
        let pool = WorkerPool::new(4);
        let exec = Exec::pooled(&pool);
        let (mut a, mut b) = (vec![0.0f32; w.nnz()], vec![0.0f32; w.nnz()]);
        spmm_grad_weights(&x, &dz, batch, &w, &mut a);
        spmm_grad_weights_exec(&x, &dz, batch, &w, &mut b, exec);
        assert_eq!(a, b, "grad_weights");
        let (dx_o, dw_o) = oracle_backward(&x, &dz, batch, &w);
        let mut dx = vec![f32::NAN; batch * 64];
        let mut dw = vec![0.0f32; w.nnz()];
        spmm_backward_fused_exec(&x, &dz, batch, &w, &mut dx, &mut dw, exec);
        assert_eq!(dx, dx_o, "fused dx");
        assert_eq!(dw, dw_o, "fused dw");
        // both kernels really dispatched onto the pool
        assert_eq!(pool.dispatch_events(), 2);
    }

    #[test]
    fn exec_isa_defaults_to_detected_and_clamps_unsupported_overrides() {
        assert_eq!(Exec::sequential().isa(), detected_isa());
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon] {
            let forced = Exec::scoped(2).with_isa(isa);
            assert!(forced.isa().supported(), "{isa:?} must clamp to a supported set");
            if isa.supported() {
                assert_eq!(forced.isa(), isa);
            } else {
                assert_eq!(forced.isa(), Isa::Scalar);
            }
        }
    }

    #[test]
    fn every_available_isa_matches_scalar_through_the_exec_path() {
        // Smoke-level ISA sweep on the sequential exec path; the full
        // shapes × densities × threads grid lives in kernel_parity.rs.
        let mut rng = Rng::new(62);
        let w = erdos_renyi_like(48, 40, 0.3, &mut rng);
        let batch = 13;
        let x = random_x(&mut rng, batch, 48, 0.2);
        let dz = random_x(&mut rng, batch, 40, 0.0);
        let mut out_s = vec![0.0f32; batch * 40];
        spmm_forward_exec(&x, batch, &w, &mut out_s, Exec::sequential().with_isa(Isa::Scalar));
        let (dx_s, dw_s) = oracle_backward(&x, &dz, batch, &w);
        for isa in Isa::available() {
            let exec = Exec::sequential().with_isa(isa);
            let mut out = vec![0.0f32; batch * 40];
            spmm_forward_exec(&x, batch, &w, &mut out, exec);
            assert_eq!(out, out_s, "forward {}", isa.name());
            let mut dx = vec![0.0f32; batch * 48];
            spmm_grad_input_exec(&dz, batch, &w, &mut dx, exec);
            assert_eq!(dx, dx_s, "grad_input {}", isa.name());
            let mut dw = vec![0.0f32; w.nnz()];
            spmm_grad_weights_exec(&x, &dz, batch, &w, &mut dw, exec);
            assert_eq!(dw, dw_s, "grad_weights {}", isa.name());
            let mut dx = vec![f32::NAN; batch * 48];
            let mut dw = vec![0.0f32; w.nnz()];
            spmm_backward_fused_exec(&x, &dz, batch, &w, &mut dx, &mut dw, exec);
            assert_eq!(dx, dx_s, "fused dx {}", isa.name());
            assert_eq!(dw, dw_s, "fused dw {}", isa.name());
        }
    }
}
