//! Truly-sparse compute kernels: the L3 hot path.
//!
//! All three training kernels stream CSR rows with one contiguous dense
//! row per sample, no allocation, no atomics:
//!
//! * [`spmm_forward`]      z = x · W          (B×n_in · n_in×n_out)
//! * [`spmm_grad_input`]   dx = dz · Wᵀ
//! * [`spmm_grad_weights`] dW = xᵀ · dz  restricted to W's pattern
//!
//! The activation-sparsity shortcut (skip `x[b,i] == 0`, which ReLU-family
//! activations produce in volume) is what makes the truly-sparse engine
//! beat masked-dense at equal FLOP budgets.

use super::csr::CsrMatrix;

/// Forward: `out[b, :] += Σ_i x[b, i] * W.row(i)`, with `out` pre-zeroed by
/// the caller (lets callers fuse bias init into the zeroing pass).
///
/// Shapes: `x: [batch, n_in]`, `out: [batch, n_out]`, both row-major.
pub fn spmm_forward(x: &[f32], batch: usize, w: &CsrMatrix, out: &mut [f32]) {
    let (n_in, n_out) = (w.n_rows, w.n_cols);
    assert_eq!(x.len(), batch * n_in);
    assert_eq!(out.len(), batch * n_out);
    debug_assert!(w.validate().is_ok());
    let row_ptr = w.row_ptr.as_slice();
    let col_idx = w.col_idx.as_slice();
    let values = w.values.as_slice();
    let mut b0 = 0usize;
    while b0 < batch {
        let bl = (batch - b0).min(BLOCK);
        for i in 0..n_in {
            // gather this input across the block; skip fully-zero columns
            // (activation sparsity shortcut, now block-wide)
            let mut xv = [0.0f32; BLOCK];
            let mut any = false;
            for (t, xvt) in xv.iter_mut().enumerate().take(bl) {
                let v = x[(b0 + t) * n_in + i];
                *xvt = v;
                any |= v != 0.0;
            }
            if !any {
                continue;
            }
            // SAFETY: row_ptr has n_rows+1 entries and is monotone; every
            // col_idx < n_cols (validated CSR invariant), so all indexing
            // below is in-bounds. Unchecked access removes the bounds
            // checks that dominate this scatter loop (§Perf changes 1+2:
            // unchecked + batch-blocked so each W row streams once per
            // block instead of once per sample).
            unsafe {
                let s = *row_ptr.get_unchecked(i);
                let e = *row_ptr.get_unchecked(i + 1);
                for k in s..e {
                    let j = *col_idx.get_unchecked(k) as usize;
                    let v = *values.get_unchecked(k);
                    for t in 0..bl {
                        *out.get_unchecked_mut((b0 + t) * n_out + j) +=
                            *xv.get_unchecked(t) * v;
                    }
                }
            }
        }
        b0 += bl;
    }
}

/// Input gradient: `dx[b, i] = Σ_j W[i, j] * dz[b, j]`.
/// Samples per block in the batch-blocked kernels: each W row is
/// streamed once per block instead of once per sample, cutting weight
/// traffic `BLOCK`-fold for layers larger than L2 (§Perf change 2).
const BLOCK: usize = 4;

pub fn spmm_grad_input(dz: &[f32], batch: usize, w: &CsrMatrix, dx: &mut [f32]) {
    let (n_in, n_out) = (w.n_rows, w.n_cols);
    assert_eq!(dz.len(), batch * n_out);
    assert_eq!(dx.len(), batch * n_in);
    debug_assert!(w.validate().is_ok());
    let row_ptr = w.row_ptr.as_slice();
    let col_idx = w.col_idx.as_slice();
    let values = w.values.as_slice();
    let mut b0 = 0usize;
    while b0 < batch {
        let bl = (batch - b0).min(BLOCK);
        for i in 0..n_in {
            // SAFETY: validated CSR invariants (see spmm_forward).
            unsafe {
                let s = *row_ptr.get_unchecked(i);
                let e = *row_ptr.get_unchecked(i + 1);
                let mut acc = [0.0f32; BLOCK];
                for k in s..e {
                    let j = *col_idx.get_unchecked(k) as usize;
                    let v = *values.get_unchecked(k);
                    for t in 0..bl {
                        acc[t] += v * *dz.get_unchecked((b0 + t) * n_out + j);
                    }
                }
                for t in 0..bl {
                    *dx.get_unchecked_mut((b0 + t) * n_in + i) = acc[t];
                }
            }
        }
        b0 += bl;
    }
}

/// Weight gradient restricted to W's sparsity pattern:
/// `dw[k] = Σ_b x[b, row(k)] * dz[b, col(k)]`, `dw` aligned with
/// `w.values` and pre-zeroed by the caller.
pub fn spmm_grad_weights(
    x: &[f32],
    dz: &[f32],
    batch: usize,
    w: &CsrMatrix,
    dw: &mut [f32],
) {
    let (n_in, n_out) = (w.n_rows, w.n_cols);
    assert_eq!(x.len(), batch * n_in);
    assert_eq!(dz.len(), batch * n_out);
    assert_eq!(dw.len(), w.nnz());
    debug_assert!(w.validate().is_ok());
    let row_ptr = w.row_ptr.as_slice();
    let col_idx = w.col_idx.as_slice();
    let mut b0 = 0usize;
    while b0 < batch {
        let bl = (batch - b0).min(BLOCK);
        for i in 0..n_in {
            let mut xv = [0.0f32; BLOCK];
            let mut any = false;
            for (t, xvt) in xv.iter_mut().enumerate().take(bl) {
                let v = x[(b0 + t) * n_in + i];
                *xvt = v;
                any |= v != 0.0;
            }
            if !any {
                continue;
            }
            // SAFETY: validated CSR invariants (see spmm_forward); dw is
            // asserted to be nnz-length above.
            unsafe {
                let s = *row_ptr.get_unchecked(i);
                let e = *row_ptr.get_unchecked(i + 1);
                for k in s..e {
                    let j = *col_idx.get_unchecked(k) as usize;
                    let mut acc = 0.0f32;
                    for t in 0..bl {
                        acc += *xv.get_unchecked(t) * *dz.get_unchecked((b0 + t) * n_out + j);
                    }
                    *dw.get_unchecked_mut(k) += acc;
                }
            }
        }
        b0 += bl;
    }
}

/// Bias gradient: `db[j] = Σ_b dz[b, j]` (pre-zeroed `db`).
pub fn bias_grad(dz: &[f32], batch: usize, n_out: usize, db: &mut [f32]) {
    debug_assert_eq!(dz.len(), batch * n_out);
    debug_assert_eq!(db.len(), n_out);
    for b in 0..batch {
        let dzrow = &dz[b * n_out..(b + 1) * n_out];
        for (j, &g) in dzrow.iter().enumerate() {
            db[j] += g;
        }
    }
}

/// Dense reference matmul for the test oracle: `x[batch, n_in] @ w_dense`.
pub fn dense_matmul(x: &[f32], batch: usize, w: &[f32], n_in: usize, n_out: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; batch * n_out];
    for b in 0..batch {
        for i in 0..n_in {
            let xv = x[b * n_in + i];
            if xv == 0.0 {
                continue;
            }
            for j in 0..n_out {
                out[b * n_out + j] += xv * w[i * n_out + j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::init;
    use crate::util::Rng;

    fn close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "idx {i}: {x} vs {y}");
        }
    }

    fn random_x(rng: &mut Rng, batch: usize, n: usize, zero_frac: f64) -> Vec<f32> {
        (0..batch * n)
            .map(|_| {
                if rng.bernoulli(zero_frac) {
                    0.0
                } else {
                    rng.normal()
                }
            })
            .collect()
    }

    #[test]
    fn forward_matches_dense() {
        let mut rng = Rng::new(1);
        let w = init::erdos_renyi(17, 13, 0.3, &mut rng, &init::WeightInit::Normal(0.5));
        let x = random_x(&mut rng, 5, 17, 0.3);
        let mut out = vec![0.0f32; 5 * 13];
        spmm_forward(&x, 5, &w, &mut out);
        let dense = dense_matmul(&x, 5, &w.to_dense(), 17, 13);
        close(&out, &dense, 1e-5);
    }

    #[test]
    fn grad_input_matches_dense_transpose() {
        let mut rng = Rng::new(2);
        let w = init::erdos_renyi(9, 11, 0.4, &mut rng, &init::WeightInit::Normal(1.0));
        let dz = random_x(&mut rng, 4, 11, 0.0);
        let mut dx = vec![0.0f32; 4 * 9];
        spmm_grad_input(&dz, 4, &w, &mut dx);
        // oracle: dz @ W^T via dense
        let wt = w.transpose();
        let dense = dense_matmul(&dz, 4, &wt.to_dense(), 11, 9);
        close(&dx, &dense, 1e-5);
    }

    #[test]
    fn grad_weights_matches_dense_outer_product() {
        let mut rng = Rng::new(3);
        let w = init::erdos_renyi(8, 6, 0.5, &mut rng, &init::WeightInit::Normal(1.0));
        let x = random_x(&mut rng, 7, 8, 0.2);
        let dz = random_x(&mut rng, 7, 6, 0.0);
        let mut dw = vec![0.0f32; w.nnz()];
        spmm_grad_weights(&x, &dz, 7, &w, &mut dw);
        // oracle: full dense dW = x^T dz, then read pattern positions
        for (k, (i, j, _)) in w.iter().enumerate() {
            let mut acc = 0.0f32;
            for b in 0..7 {
                acc += x[b * 8 + i] * dz[b * 6 + j as usize];
            }
            assert!((dw[k] - acc).abs() < 1e-4, "k={k}");
        }
    }

    #[test]
    fn bias_grad_sums_batch() {
        let dz = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let mut db = vec![0.0f32; 3];
        bias_grad(&dz, 2, 3, &mut db);
        assert_eq!(db, vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn zero_input_produces_zero_everything() {
        let mut rng = Rng::new(4);
        let w = init::erdos_renyi(6, 6, 0.5, &mut rng, &init::WeightInit::Normal(1.0));
        let x = vec![0.0f32; 3 * 6];
        let mut out = vec![0.0f32; 3 * 6];
        spmm_forward(&x, 3, &w, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
        let mut dw = vec![0.0f32; w.nnz()];
        spmm_grad_weights(&x, &out, 3, &w, &mut dw);
        assert!(dw.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn empty_matrix_is_noop() {
        let w = CsrMatrix::empty(4, 5);
        let x = vec![1.0f32; 2 * 4];
        let mut out = vec![0.0f32; 2 * 5];
        spmm_forward(&x, 2, &w, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn batch_one_consistency() {
        // result for a stacked batch equals per-sample results
        let mut rng = Rng::new(5);
        let w = init::erdos_renyi(10, 7, 0.35, &mut rng, &init::WeightInit::Normal(1.0));
        let x = random_x(&mut rng, 3, 10, 0.0);
        let mut full = vec![0.0f32; 3 * 7];
        spmm_forward(&x, 3, &w, &mut full);
        for b in 0..3 {
            let mut one = vec![0.0f32; 7];
            spmm_forward(&x[b * 10..(b + 1) * 10], 1, &w, &mut one);
            close(&one, &full[b * 7..(b + 1) * 7], 1e-6);
        }
    }
}
