//! The worker-sharded, in-place topology-evolution engine (DESIGN.md §8).
//!
//! One "evolution epoch" — importance pruning (paper Eq. 4 / Algorithm 2)
//! plus the SET prune–regrow cycle — touches each layer's CSR arrays
//! **once**: a single structural rebuild per layer replaces the oracle's
//! `values.clone()` + `retain` + COO-merge `insert` (three O(nnz) array
//! rebuilds and several transient allocations per layer per epoch).
//!
//! Parallel structure — both passes dispatch on the persistent kernel
//! [`WorkerPool`] (DESIGN.md §9; shared with the sparse kernels when the
//! training loop hands one in via [`EvolutionEngine::with_pool`]):
//! * **layer-level**: layers are planned in parallel (heaviest first,
//!   work-stealing balance), each on an independent RNG stream
//!   (`root.split(layer_index)`, the exact layout of the sequential
//!   oracle [`super::evolve_model`]); sub-crossover layers rebuild and
//!   swap inline on their planning worker;
//! * **row-level**: each remaining heavy layer's rebuild is sharded over
//!   contiguous, nnz-balanced row ranges ([`ops::balanced_row_bounds`])
//!   across the whole pool — a row range owns the contiguous output
//!   slots `[new_row_ptr[r0], new_row_ptr[r1])` for columns, values AND
//!   the remapped velocity, so shards write pairwise-disjoint sub-slices
//!   (no atomics, no locks).
//!
//! All randomness (gap-ordinal sampling + regrown-weight draws) happens
//! in the sequential per-layer planning step, so results are **invariant
//! to the thread count** and bit-exact against the sequential oracle —
//! the contract `rust/tests/evolution_parity.rs` pins.
//!
//! The engine owns per-layer workspace buffers that are reused across
//! epochs (capacity is reserved once at the first epoch's nnz, and nnz
//! never grows under SET since `regrown <= pruned`), so steady-state
//! evolution performs **zero heap allocation**; a growth counter
//! ([`EvolutionEngine::buffer_growth_events`]) lets tests verify it.

use std::collections::HashSet;
use std::sync::Arc;

use crate::error::Result;
use crate::importance::{importance_threshold_from, ImportanceConfig};
use crate::model::{SparseLayer, SparseMlp};
use crate::sparse::{ops, CsrMatrix, Exec, WorkerPool};
use crate::util::Rng;

use super::{partition_signs, sample_gap_ordinals, thresholds_from_partition, EvolutionConfig};

/// Minimum layer nnz at which the rebuild pass shards rows on the COLD
/// (pool-less, scoped-spawn) path. The rebuild is a memory-bound copy
/// (~16 bytes per slot), so below ~10⁵ slots the scoped-thread spawn
/// cost (tens of µs) dominates.
const EVOLVE_PAR_MIN_NNZ: usize = 1 << 17;

/// Warm-pool rebuild crossover: a parked-pool dispatch costs single-digit
/// microseconds (~100× below a scoped spawn, DESIGN.md §9.3), so row
/// sharding pays off from ~2¹⁴ slots (≈ 256 KiB of copies).
const EVOLVE_POOL_MIN_NNZ: usize = 1 << 14;

/// Per-layer outcome of one fused evolution epoch.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EpochStats {
    /// Connections removed because their output neuron's importance fell
    /// below the layer threshold (0 when importance pruning is off or
    /// skipped for this layer).
    pub importance_pruned: usize,
    /// Connections removed by SET magnitude pruning.
    pub pruned: usize,
    /// Connections regrown at random empty positions
    /// (`min(pruned, capacity)` — exact, no rejection sampling).
    pub regrown: usize,
}

/// Reusable per-layer workspace. Buffers are sized on first use and kept
/// across epochs; `grows` counts capacity-growth events (the steady-state
/// zero-allocation test hook).
#[derive(Debug, Default)]
struct LayerWs {
    /// Sign-partition scratch for the SET thresholds.
    part: Vec<f32>,
    /// Column importance sums (Eq. 4), length n_out.
    imp_sums: Vec<f32>,
    /// Active (> 0) importances for the percentile selection.
    imp_active: Vec<f32>,
    /// Per-row survivor counts after the fused keep predicate.
    keep_counts: Vec<usize>,
    /// Per-row regrowth counts.
    grow_counts: Vec<usize>,
    /// Prefix sums of per-row empty counts (gap-ordinal space).
    gap_prefix: Vec<usize>,
    /// Prefix sums of `grow_counts`.
    grow_ptr: Vec<usize>,
    /// Sampled gap ordinals (sorted).
    ordinals: Vec<usize>,
    /// Floyd-sampling membership set.
    seen: HashSet<usize>,
    /// Regrown columns, aligned with sorted ordinals (global order).
    grow_cols: Vec<u32>,
    /// Regrown weights, aligned with `grow_cols`.
    grow_vals: Vec<f32>,
    /// Output CSR row pointers (swapped into the layer).
    new_row_ptr: Vec<usize>,
    /// Output CSR columns (swapped into the layer).
    out_col: Vec<u32>,
    /// Output CSR values (swapped into the layer).
    out_val: Vec<f32>,
    /// Output velocity, remapped through the same merge (swapped in).
    out_vel: Vec<f32>,
    /// Buffer capacity-growth events (test hook).
    grows: usize,
}

/// Clear `buf`, growing its capacity to at least `cap_hint` (counted in
/// `grows`) and resizing it to `len` zero-initialised elements.
fn ensure_vec<T: Copy + Default>(buf: &mut Vec<T>, len: usize, cap_hint: usize, grows: &mut usize) {
    buf.clear();
    let want = len.max(cap_hint);
    if buf.capacity() < want {
        *grows += 1;
        buf.reserve(want);
    }
    buf.resize(len, T::default());
}

/// Clear `seen`, growing its capacity to hold `want` entries (counted).
fn ensure_set(seen: &mut HashSet<usize>, want: usize, grows: &mut usize) {
    seen.clear();
    if seen.capacity() < want {
        *grows += 1;
        seen.reserve(want);
    }
}

/// The fused keep predicate of one evolution epoch: an entry survives
/// when its output neuron's importance clears the layer threshold AND its
/// magnitude lies outside the SET prune bands. Plain copyable data so the
/// planning, mapping and sharded rebuild passes all evaluate the exact
/// same predicate. Crate-visible: the out-of-core streaming evolution
/// (`bigmodel::evolve`) builds the identical predicate so the mapped and
/// in-RAM paths prune the exact same entries.
#[derive(Clone, Copy)]
pub(crate) struct KeepSpec<'a> {
    /// `(importance_sums, threshold)` when importance pruning is active.
    pub(crate) imp: Option<(&'a [f32], f32)>,
    pub(crate) pos_cut: f32,
    pub(crate) neg_cut: f32,
    /// False when SET pruning is off (importance-only epoch).
    pub(crate) set_active: bool,
}

impl KeepSpec<'_> {
    #[inline]
    pub(crate) fn imp_ok(&self, col: u32) -> bool {
        match self.imp {
            Some((imp, thr)) => imp[col as usize] >= thr,
            None => true,
        }
    }

    #[inline]
    pub(crate) fn set_ok(&self, v: f32) -> bool {
        !self.set_active || v > self.pos_cut || v < self.neg_cut
    }

    #[inline]
    pub(crate) fn keep(&self, col: u32, v: f32) -> bool {
        self.imp_ok(col) && self.set_ok(v)
    }
}

/// Worker-sharded in-place topology evolution (DESIGN.md §8).
///
/// Reproduces the sequential oracles bit-for-bit at every thread count:
/// [`super::evolve_model`] (SET only) and
/// `importance::prune_model` + [`super::evolve_model`] (fused epoch).
///
/// # Examples
///
/// ```
/// use tsnn::prelude::*;
/// use tsnn::set::{EvolutionConfig, EvolutionEngine};
///
/// let mut rng = Rng::new(1);
/// let mut mlp = SparseMlp::new(
///     &[8, 16, 3],
///     4.0,
///     Activation::Relu,
///     &WeightInit::HeUniform,
///     &mut rng,
/// )
/// .unwrap();
/// let before = mlp.weight_count();
/// let mut engine = EvolutionEngine::new();
/// let stats = engine
///     .evolve_model(&mut mlp, &EvolutionConfig::default(), &mut rng, 2)
///     .unwrap();
/// assert_eq!(stats.len(), 2);
/// assert_eq!(
///     mlp.weight_count(),
///     before - stats.iter().map(|s| s.pruned - s.regrown).sum::<usize>()
/// );
/// ```
#[derive(Debug, Default)]
pub struct EvolutionEngine {
    per_layer: Vec<LayerWs>,
    /// Persistent worker pool for the layer- and row-level passes
    /// (DESIGN.md §9.4): shared with the kernel dispatches when built
    /// via [`EvolutionEngine::with_pool`], else owned and created lazily
    /// at the first multi-threaded epoch.
    pool: Option<Arc<WorkerPool>>,
}

impl EvolutionEngine {
    /// Engine with empty workspaces (sized lazily on first epoch) and an
    /// owned worker pool (spawned lazily at the first parallel epoch).
    pub fn new() -> Self {
        EvolutionEngine::default()
    }

    /// Engine sharing the training run's persistent kernel pool, so
    /// kernels and topology evolution dispatch onto the same parked
    /// workers (one pool for the whole run).
    pub fn with_pool(pool: Arc<WorkerPool>) -> Self {
        EvolutionEngine {
            per_layer: Vec::new(),
            pool: Some(pool),
        }
    }

    /// Total workspace-buffer capacity-growth events so far. Constant
    /// across steady-state epochs — the zero-allocation test hook.
    pub fn buffer_growth_events(&self) -> usize {
        self.per_layer.iter().map(|ws| ws.grows).sum()
    }

    /// The persistent pool serving this engine's dispatches at the
    /// resolved budget `threads`: the shared/owned pool when its size
    /// matches, else an owned pool (re)created once per budget change.
    fn pool_for(&mut self, threads: usize) -> Arc<WorkerPool> {
        match &self.pool {
            Some(p) if p.threads() == threads => Arc::clone(p),
            _ => {
                let p = Arc::new(WorkerPool::new(threads));
                self.pool = Some(Arc::clone(&p));
                p
            }
        }
    }

    /// SET evolution step over every layer — the in-place, worker-sharded
    /// equivalent of the sequential oracle [`super::evolve_model`]
    /// (bit-exact at every `threads` value; `0` = one worker per core,
    /// `1` = fully sequential).
    pub fn evolve_model(
        &mut self,
        mlp: &mut SparseMlp,
        cfg: &EvolutionConfig,
        rng: &mut Rng,
        threads: usize,
    ) -> Result<Vec<EpochStats>> {
        self.evolve_epoch(mlp, Some(cfg), None, rng, threads)
    }

    /// One fused evolution epoch: importance pruning (when `imp` is set;
    /// the final classifier layer is always exempt, as in Algorithm 2)
    /// and SET prune+regrow (when `evo` is set), in ONE structural pass
    /// per layer.
    ///
    /// Equivalent to `importance::prune_model` followed by
    /// [`super::evolve_model`] — exactly, including the caller-RNG
    /// consumption (one `u64` when `evo` is set, none otherwise).
    pub fn evolve_epoch(
        &mut self,
        mlp: &mut SparseMlp,
        evo: Option<&EvolutionConfig>,
        imp: Option<&ImportanceConfig>,
        rng: &mut Rng,
        threads: usize,
    ) -> Result<Vec<EpochStats>> {
        let n_layers = mlp.layers.len();
        if evo.is_none() && imp.is_none() {
            return Ok(vec![EpochStats::default(); n_layers]);
        }
        if self.per_layer.len() < n_layers {
            self.per_layer.resize_with(n_layers, LayerWs::default);
        }
        // one caller draw seeds the root stream (oracle layout); an
        // importance-only epoch consumes nothing, like prune_model
        let root = match evo {
            Some(_) => Rng::new(rng.next_u64()),
            None => Rng::new(0),
        };
        let threads = ops::resolve_threads(threads);
        if threads <= 1 {
            let mut stats = Vec::with_capacity(n_layers);
            for (l, (layer, ws)) in mlp
                .layers
                .iter_mut()
                .zip(self.per_layer.iter_mut())
                .enumerate()
            {
                let imp_l = if l + 1 == n_layers { None } else { imp };
                let layer_rng = root.split(l as u64);
                stats.push(evolve_layer_ws(layer, evo, imp_l, layer_rng, ws, Exec::sequential()));
            }
            return Ok(stats);
        }
        // Both evolution passes dispatch on the persistent pool. Phase A
        // plans every layer in parallel — heaviest first so the pool's
        // work-stealing claim order starts the dominant layers early —
        // and rebuilds+swaps the sub-crossover layers inline on their
        // planning worker (no second dispatch, and a pool worker never
        // nests a pool dispatch). Phase B then row-shards each remaining
        // heavy layer's rebuild across the whole pool — real models are
        // nnz-skewed, and this hands the dominant layers every worker
        // instead of the old scheme's "one batch-mate plus spare budget".
        let pool = self.pool_for(threads);
        let exec = Exec::pooled(&pool);
        let mut stats = vec![EpochStats::default(); n_layers];
        let mut items: Vec<Item<'_>> = mlp
            .layers
            .iter_mut()
            .zip(self.per_layer.iter_mut())
            .enumerate()
            .map(|(l, (layer, ws))| Item {
                l,
                layer,
                ws,
                plan: None,
                done: false,
            })
            .collect();
        items.sort_by_key(|it| std::cmp::Reverse(it.layer.weights.nnz()));
        let items_ptr = ops::ShardPtr(items.as_mut_ptr());
        exec.run(items.len(), |s| {
            // SAFETY: run() hands out every shard index exactly once, so
            // shard s has exclusive access to items[s]; the Vec outlives
            // the dispatch (the §9.2 gather is the release point).
            let it = unsafe { &mut *items_ptr.0.add(s) };
            let imp_l = if it.l + 1 == n_layers { None } else { imp };
            let layer_rng = root.split(it.l as u64);
            let plan = plan_layer(it.layer, evo, imp_l, layer_rng, it.ws);
            let heavy = !plan.skip
                && evolve_shard_count(
                    exec,
                    it.layer.weights.nnz().max(plan.new_nnz),
                    it.layer.n_in(),
                ) > 1;
            if !plan.skip && !heavy {
                rebuild_and_swap(it.layer, it.ws, &plan, Exec::sequential());
                it.done = true;
            }
            it.plan = Some(plan);
        });
        for it in items.iter_mut() {
            let plan = it.plan.take().expect("phase A planned every layer");
            if !plan.skip && !it.done {
                rebuild_and_swap(it.layer, it.ws, &plan, exec);
            }
            stats[it.l] = plan.stats;
        }
        Ok(stats)
    }
}

/// Per-layer work item of a parallel evolution epoch (phase A shard).
struct Item<'a> {
    l: usize,
    layer: &'a mut SparseLayer,
    ws: &'a mut LayerWs,
    plan: Option<LayerPlan>,
    done: bool,
}

/// Scalar outcome of one layer's sequential planning step. Slice views
/// (importance sums, regrowth plan, output buffers) stay in the layer's
/// workspace and are reborrowed at rebuild time, so the plan can cross
/// the phase A → phase B boundary by value.
struct LayerPlan {
    /// Importance threshold participates in the keep predicate.
    imp_active: bool,
    imp_thr: f32,
    pos_cut: f32,
    neg_cut: f32,
    set_active: bool,
    /// Slot count of the rebuilt CSR.
    new_nnz: usize,
    /// Provable no-op for this layer: skip the rebuild entirely.
    skip: bool,
    stats: EpochStats,
}

/// One layer's fused evolution epoch: plan sequentially (thresholds,
/// survivor counts, gap sampling, weight draws — all on the layer's own
/// RNG stream), then rebuild the CSR + velocity in one (optionally
/// row-sharded) pass and swap the result into the layer.
fn evolve_layer_ws(
    layer: &mut SparseLayer,
    evo: Option<&EvolutionConfig>,
    imp: Option<&ImportanceConfig>,
    rng: Rng,
    ws: &mut LayerWs,
    exec: Exec<'_>,
) -> EpochStats {
    let plan = plan_layer(layer, evo, imp, rng, ws);
    if !plan.skip {
        rebuild_and_swap(layer, ws, &plan, exec);
    }
    plan.stats
}

/// The sequential planning step of one layer's epoch: thresholds,
/// survivor counts, regrowth sampling and weight draws (all of the
/// layer's randomness), plus sizing of every output buffer — so the
/// rebuild pass that follows is pure, allocation-free data movement.
fn plan_layer(
    layer: &SparseLayer,
    evo: Option<&EvolutionConfig>,
    imp: Option<&ImportanceConfig>,
    mut rng: Rng,
    ws: &mut LayerWs,
) -> LayerPlan {
    let (n_in, n_out) = (layer.n_in(), layer.n_out());
    let nnz0 = layer.weights.nnz();
    let LayerWs {
        part,
        imp_sums,
        imp_active,
        keep_counts,
        grow_counts,
        gap_prefix,
        grow_ptr,
        ordinals,
        seen,
        grow_cols,
        grow_vals,
        new_row_ptr,
        out_col,
        out_val,
        out_vel,
        grows,
    } = ws;

    // --- importance threshold (Eq. 4), mirroring prune_low_importance
    //     (including its free min_connections early-out) ---
    let imp_thr: Option<f32> = match imp {
        Some(cfg) if nnz0 > cfg.min_connections => {
            ensure_vec(imp_sums, n_out, n_out, grows);
            for (&j, &v) in layer.weights.col_idx.iter().zip(layer.weights.values.iter()) {
                imp_sums[j as usize] += v.abs();
            }
            ensure_vec(imp_active, 0, n_out, grows);
            importance_threshold_from(imp_sums, nnz0, cfg, imp_active)
        }
        _ => None,
    };
    if evo.is_none() && imp_thr.is_none() {
        // Provable no-op for this layer (importance-exempt final layer,
        // min_connections floor, or no active neuron, with SET off):
        // skip the rebuild entirely — exactly what the prune_model
        // oracle does, and no RNG is consumed on this path either way.
        return LayerPlan {
            imp_active: false,
            imp_thr: 0.0,
            pos_cut: 0.0,
            neg_cut: 0.0,
            set_active: false,
            new_nnz: nnz0,
            skip: true,
            stats: EpochStats::default(),
        };
    }
    let imp_view: Option<(&[f32], f32)> = match imp_thr {
        Some(thr) => Some((imp_sums.as_slice(), thr)),
        None => None,
    };

    // --- SET prune cuts over the importance-surviving values (one pass,
    //     identical value sequence to the oracle's post-importance scan) ---
    let (pos_cut, neg_cut, set_active) = match evo {
        Some(cfg) => {
            ensure_vec(part, 0, nnz0, grows);
            let (lo, hi) = partition_signs(
                layer
                    .weights
                    .col_idx
                    .iter()
                    .zip(layer.weights.values.iter())
                    .filter(|(&j, _)| match imp_view {
                        Some((imp_s, thr)) => imp_s[j as usize] >= thr,
                        None => true,
                    })
                    .map(|(_, &v)| v),
                nnz0,
                part,
            );
            let (front, back) = part.split_at_mut(hi);
            let (p, n) = thresholds_from_partition(&mut front[..lo], back, cfg.zeta);
            (p, n, true)
        }
        None => (0.0, 0.0, false),
    };
    let keep = KeepSpec {
        imp: imp_view,
        pos_cut,
        neg_cut,
        set_active,
    };

    // --- pass 1: per-row survivor counts + removal tallies ---
    let w = &layer.weights;
    ensure_vec(keep_counts, n_in, n_in, grows);
    let (mut total_kept, mut imp_pruned, mut set_pruned) = (0usize, 0usize, 0usize);
    for i in 0..n_in {
        let (s, e) = (w.row_ptr[i], w.row_ptr[i + 1]);
        let mut kept = 0usize;
        for k in s..e {
            if !keep.imp_ok(w.col_idx[k]) {
                imp_pruned += 1;
            } else if !keep.set_ok(w.values[k]) {
                set_pruned += 1;
            } else {
                kept += 1;
            }
        }
        keep_counts[i] = kept;
        total_kept += kept;
    }

    // --- regrowth plan: sample gap ordinals over the post-prune empty
    //     set, map them to (row, col), draw the new weights ---
    let capacity = n_in * n_out - total_kept;
    let to_grow = if set_active {
        set_pruned.min(capacity)
    } else {
        0
    };
    ensure_vec(gap_prefix, n_in + 1, n_in + 1, grows);
    gap_prefix[0] = 0;
    for i in 0..n_in {
        gap_prefix[i + 1] = gap_prefix[i] + (n_out - keep_counts[i]);
    }
    debug_assert_eq!(gap_prefix[n_in], capacity);

    ensure_vec(ordinals, 0, nnz0, grows);
    ensure_set(seen, nnz0, grows);
    sample_gap_ordinals(&mut rng, capacity, to_grow, ordinals, seen);
    ordinals.sort_unstable();

    ensure_vec(grow_counts, n_in, n_in, grows);
    ensure_vec(grow_cols, 0, nnz0, grows);
    ensure_vec(grow_vals, 0, nnz0, grows);
    let mut oi = 0usize;
    for i in 0..n_in {
        if oi >= ordinals.len() {
            break;
        }
        let hi = gap_prefix[i + 1];
        if ordinals[oi] >= hi {
            continue;
        }
        let lo = gap_prefix[i];
        let (s, e) = (w.row_ptr[i], w.row_ptr[i + 1]);
        let row_start = grow_cols.len();
        // two-pointer gap selection over this row's (virtual) survivors:
        // the g-th empty column is g + #survivors c_t with c_t - t <= g
        let mut t = 0usize; // survivors consumed so far
        let mut k = s; // cursor into the old slots
        let mut next_surv: Option<usize> = None;
        while oi < ordinals.len() && ordinals[oi] < hi {
            let g = ordinals[oi] - lo;
            loop {
                if next_surv.is_none() {
                    while k < e {
                        if keep.keep(w.col_idx[k], w.values[k]) {
                            next_surv = Some(w.col_idx[k] as usize);
                            break;
                        }
                        k += 1;
                    }
                }
                match next_surv {
                    Some(c) if c - t <= g => {
                        t += 1;
                        k += 1;
                        next_surv = None;
                    }
                    _ => break,
                }
            }
            grow_cols.push((g + t) as u32);
            oi += 1;
        }
        grow_counts[i] = grow_cols.len() - row_start;
    }
    debug_assert_eq!(grow_cols.len(), to_grow);
    // weights drawn in sorted (row, col) order — the oracle's exact order
    if let Some(cfg) = evo {
        for _ in 0..to_grow {
            grow_vals.push(cfg.init.sample(&mut rng, n_in, n_out));
        }
    }

    ensure_vec(grow_ptr, n_in + 1, n_in + 1, grows);
    grow_ptr[0] = 0;
    ensure_vec(new_row_ptr, n_in + 1, n_in + 1, grows);
    new_row_ptr[0] = 0;
    for i in 0..n_in {
        grow_ptr[i + 1] = grow_ptr[i] + grow_counts[i];
        new_row_ptr[i + 1] = new_row_ptr[i] + keep_counts[i] + grow_counts[i];
    }
    let new_nnz = new_row_ptr[n_in];
    debug_assert_eq!(new_nnz, total_kept + to_grow);

    // size the rebuild outputs here so the rebuild pass itself is pure,
    // allocation-free data movement
    ensure_vec(out_col, new_nnz, nnz0, grows);
    ensure_vec(out_val, new_nnz, nnz0, grows);
    ensure_vec(out_vel, new_nnz, nnz0, grows);
    LayerPlan {
        imp_active: imp_thr.is_some(),
        imp_thr: imp_thr.unwrap_or(0.0),
        pos_cut,
        neg_cut,
        set_active,
        new_nnz,
        skip: false,
        stats: EpochStats {
            importance_pruned: imp_pruned,
            pruned: set_pruned,
            regrown: to_grow,
        },
    }
}

/// Pass 2 of one layer's epoch: compact survivors + merge regrowth into
/// the output arrays (velocity remapped through the same merge), row-
/// sharded on `exec` above the crossover, then swap the rebuilt storage
/// into the layer (the previous arrays stay in the workspace as next
/// epoch's buffers).
fn rebuild_and_swap(layer: &mut SparseLayer, ws: &mut LayerWs, plan: &LayerPlan, exec: Exec<'_>) {
    let n_in = layer.n_in();
    let nnz0 = layer.weights.nnz();
    let LayerWs {
        imp_sums,
        grow_cols,
        grow_vals,
        grow_ptr,
        new_row_ptr,
        out_col,
        out_val,
        out_vel,
        ..
    } = ws;
    {
        let keep = KeepSpec {
            imp: if plan.imp_active {
                Some((imp_sums.as_slice(), plan.imp_thr))
            } else {
                None
            },
            pos_cut: plan.pos_cut,
            neg_cut: plan.neg_cut,
            set_active: plan.set_active,
        };
        let w = &layer.weights;
        let old_vel = layer.velocity.as_slice();
        let shards = evolve_shard_count(exec, nnz0.max(plan.new_nnz), n_in);
        if shards <= 1 {
            rebuild_rows(
                w,
                old_vel,
                keep,
                grow_cols,
                grow_vals,
                grow_ptr,
                new_row_ptr,
                0,
                n_in,
                out_col,
                out_val,
                out_vel,
            );
        } else {
            let bounds = ops::balanced_row_bounds(&w.row_ptr, shards);
            let bounds = bounds.as_slice();
            // shared views of the plan buffers for the shard closures
            let gc: &[u32] = grow_cols;
            let gv: &[f32] = grow_vals;
            let gp: &[usize] = grow_ptr;
            let nrp: &[usize] = new_row_ptr;
            let pc = ops::ShardPtr(out_col.as_mut_ptr());
            let pv = ops::ShardPtr(out_val.as_mut_ptr());
            let pl = ops::ShardPtr(out_vel.as_mut_ptr());
            exec.run(shards, |s| {
                let (r0, r1) = (bounds[s], bounds[s + 1]);
                let (o0, o1) = (nrp[r0], nrp[r1]);
                if o0 == o1 {
                    return; // all-empty rows (or an nnz-heavy neighbour)
                }
                // SAFETY: new_row_ptr is monotone, so disjoint row
                // ranges own disjoint contiguous output-slot ranges
                // [o0, o1) of all three arrays (§8.4); the buffers
                // outlive the dispatch (§9.2 gather).
                let (hc, hv, hl) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(pc.0.add(o0), o1 - o0),
                        std::slice::from_raw_parts_mut(pv.0.add(o0), o1 - o0),
                        std::slice::from_raw_parts_mut(pl.0.add(o0), o1 - o0),
                    )
                };
                rebuild_rows(w, old_vel, keep, gc, gv, gp, nrp, r0, r1, hc, hv, hl);
            });
        }
    }
    layer.swap_storage(new_row_ptr, out_col, out_val, out_vel);
    debug_assert!(layer.weights.validate().is_ok());
    debug_assert_eq!(layer.velocity.len(), layer.weights.nnz());
}

/// Shard count for the rebuild pass: sequential when the row dimension
/// cannot split or below the two-tier copy-bound crossover (warm pool
/// vs cold scoped spawn, mirroring the kernels' [`ops::POOL_MIN_WORK`] /
/// [`ops::PAR_MIN_WORK`] split).
fn evolve_shard_count(exec: Exec<'_>, nnz: usize, n_rows: usize) -> usize {
    let min_nnz = if exec.is_pooled() {
        EVOLVE_POOL_MIN_NNZ
    } else {
        EVOLVE_PAR_MIN_NNZ
    };
    if exec.threads() <= 1 || n_rows <= 1 || nnz < min_nnz {
        return 1;
    }
    exec.threads().min(n_rows)
}

/// Rebuild rows `[r0, r1)`: stream the old slots once, keep survivors
/// (carrying their velocity), merge the pre-planned regrowth columns in
/// sorted order (zero velocity, pre-drawn weights). The output slices
/// span exactly `[new_row_ptr[r0], new_row_ptr[r1])` — contiguous and
/// disjoint across shards, so the sharded pass needs no synchronisation.
/// Crate-visible: `bigmodel::evolve` runs the same merge per row shard
/// with the output slices aimed at a memory-mapped fresh segment, which
/// is what makes out-of-core evolution bit-exact against this engine.
#[allow(clippy::too_many_arguments)]
pub(crate) fn rebuild_rows(
    w: &CsrMatrix,
    old_vel: &[f32],
    keep: KeepSpec<'_>,
    grow_cols: &[u32],
    grow_vals: &[f32],
    grow_ptr: &[usize],
    new_row_ptr: &[usize],
    r0: usize,
    r1: usize,
    out_col: &mut [u32],
    out_val: &mut [f32],
    out_vel: &mut [f32],
) {
    let base = new_row_ptr[r0];
    let mut wcur = 0usize;
    for i in r0..r1 {
        debug_assert_eq!(wcur, new_row_ptr[i] - base);
        let (s, e) = (w.row_ptr[i], w.row_ptr[i + 1]);
        let (gs, ge) = (grow_ptr[i], grow_ptr[i + 1]);
        let mut k = s;
        let mut g = gs;
        loop {
            // next surviving old entry
            while k < e && !keep.keep(w.col_idx[k], w.values[k]) {
                k += 1;
            }
            let take_grow = if k >= e {
                g < ge
            } else if g >= ge {
                false
            } else {
                // regrowth targets empty positions, so strict `<` suffices
                grow_cols[g] < w.col_idx[k]
            };
            if take_grow {
                out_col[wcur] = grow_cols[g];
                out_val[wcur] = grow_vals[g];
                out_vel[wcur] = 0.0;
                g += 1;
            } else if k < e {
                out_col[wcur] = w.col_idx[k];
                out_val[wcur] = w.values[k];
                out_vel[wcur] = old_vel[k];
                k += 1;
            } else {
                break;
            }
            wcur += 1;
        }
        debug_assert_eq!(g, ge);
    }
    debug_assert_eq!(wcur, new_row_ptr[r1] - base);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Activation;
    use crate::set;
    use crate::sparse::WeightInit;

    fn model(sizes: &[usize], seed: u64) -> SparseMlp {
        let mut rng = Rng::new(seed);
        let mut m = SparseMlp::new(
            sizes,
            6.0,
            Activation::Relu,
            &WeightInit::Normal(0.5),
            &mut rng,
        )
        .unwrap();
        for layer in m.layers.iter_mut() {
            for (k, v) in layer.velocity.iter_mut().enumerate() {
                *v = 0.25 * (k + 1) as f32;
            }
        }
        m
    }

    fn assert_same(a: &SparseMlp, b: &SparseMlp, label: &str) {
        for (l, (la, lb)) in a.layers.iter().zip(b.layers.iter()).enumerate() {
            assert_eq!(la.weights, lb.weights, "{label}: layer {l} weights");
            assert_eq!(la.velocity, lb.velocity, "{label}: layer {l} velocity");
        }
    }

    #[test]
    fn engine_matches_oracle_at_one_and_many_threads() {
        let base = model(&[24, 36, 8], 3);
        let cfg = EvolutionConfig::default();
        let mut oracle = base.clone();
        set::evolve_model(&mut oracle, &cfg, &mut Rng::new(5)).unwrap();
        for threads in [1usize, 4] {
            let mut m = base.clone();
            let mut engine = EvolutionEngine::new();
            let stats = engine
                .evolve_model(&mut m, &cfg, &mut Rng::new(5), threads)
                .unwrap();
            assert_same(&oracle, &m, &format!("threads {threads}"));
            assert!(stats.iter().all(|s| s.importance_pruned == 0));
            assert!(stats.iter().any(|s| s.pruned > 0));
        }
    }

    #[test]
    fn importance_only_epoch_matches_prune_model() {
        let base = model(&[20, 30, 30, 5], 4);
        let imp = ImportanceConfig {
            start_epoch: 0,
            period: 1,
            percentile: 30.0,
            min_connections: 0,
        };
        let mut oracle = base.clone();
        let removed = crate::importance::prune_model(&mut oracle, &imp);
        assert!(removed > 0);
        let mut m = base.clone();
        let mut engine = EvolutionEngine::new();
        let mut rng = Rng::new(6);
        let before = rng.clone();
        let stats = engine
            .evolve_epoch(&mut m, None, Some(&imp), &mut rng, 4)
            .unwrap();
        assert_same(&oracle, &m, "importance-only");
        let total: usize = stats.iter().map(|s| s.importance_pruned).sum();
        assert_eq!(total, removed);
        assert!(stats.iter().all(|s| s.pruned == 0 && s.regrown == 0));
        // importance-only epochs consume no caller randomness
        assert_eq!(rng.clone().next_u64(), before.clone().next_u64());
    }

    #[test]
    fn no_op_epoch_returns_defaults() {
        let base = model(&[10, 10], 7);
        let mut m = base.clone();
        let mut engine = EvolutionEngine::new();
        let stats = engine
            .evolve_epoch(&mut m, None, None, &mut Rng::new(1), 4)
            .unwrap();
        assert_eq!(stats, vec![EpochStats::default()]);
        assert_same(&base, &m, "no-op");
    }

    #[test]
    fn shard_count_respects_two_tier_crossover() {
        let scoped = Exec::scoped(8);
        assert_eq!(evolve_shard_count(Exec::sequential(), usize::MAX, 100), 1);
        assert_eq!(evolve_shard_count(scoped, EVOLVE_PAR_MIN_NNZ - 1, 100), 1);
        assert_eq!(evolve_shard_count(scoped, EVOLVE_PAR_MIN_NNZ, 100), 8);
        assert_eq!(evolve_shard_count(scoped, EVOLVE_PAR_MIN_NNZ, 1), 1);
        assert_eq!(evolve_shard_count(scoped, EVOLVE_PAR_MIN_NNZ, 3), 3);
        // warm pool: the crossover drops by ~8×
        let pool = WorkerPool::new(8);
        let pooled = Exec::pooled(&pool);
        assert_eq!(evolve_shard_count(pooled, EVOLVE_POOL_MIN_NNZ - 1, 100), 1);
        assert_eq!(evolve_shard_count(pooled, EVOLVE_POOL_MIN_NNZ, 100), 8);
        assert!(EVOLVE_POOL_MIN_NNZ < EVOLVE_PAR_MIN_NNZ);
    }

    #[test]
    fn engine_shares_a_training_run_pool() {
        let base = model(&[24, 36, 8], 3);
        let cfg = EvolutionConfig::default();
        let mut oracle = base.clone();
        set::evolve_model(&mut oracle, &cfg, &mut Rng::new(5)).unwrap();
        let pool = Arc::new(WorkerPool::new(4));
        let mut m = base.clone();
        let mut engine = EvolutionEngine::with_pool(Arc::clone(&pool));
        engine.evolve_model(&mut m, &cfg, &mut Rng::new(5), 4).unwrap();
        assert_same(&oracle, &m, "shared pool");
        // the shared pool (same budget) served the layer pass — no
        // private pool was created
        assert!(pool.dispatch_events() > 0);
        assert!(Arc::ptr_eq(&pool, &engine.pool.clone().unwrap()));
    }
}
