//! Sparse Evolutionary Training (SET) — dynamic topology evolution.
//!
//! At the end of each training epoch (Mocanu et al. 2018; Algorithm 2 of
//! the paper), every sparse layer:
//!
//! 1. removes a fraction ζ of the **smallest positive** weights,
//! 2. removes a fraction ζ of the **largest negative** weights (i.e. the
//!    negatives closest to zero — smallest magnitude on the negative side),
//! 3. regrows the same number of connections at uniformly-random empty
//!    positions with freshly-initialised weights and zero velocity.
//!
//! The prune thresholds are found with select-nth (O(nnz)), the regrowth
//! by rejection sampling against the CSR structure (O(k log deg)).

use crate::error::Result;
use crate::model::{SparseLayer, SparseMlp};
use crate::sparse::WeightInit;
use crate::util::Rng;

/// Topology-evolution hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct EvolutionConfig {
    /// Fraction ζ of each sign class pruned per evolution step (paper: 0.3).
    pub zeta: f64,
    /// Initialiser for regrown connections.
    pub init: WeightInit,
}

impl Default for EvolutionConfig {
    fn default() -> Self {
        EvolutionConfig {
            zeta: 0.3,
            init: WeightInit::HeUniform,
        }
    }
}

/// Outcome of one evolution step on one layer.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EvolutionStats {
    /// Connections removed.
    pub pruned: usize,
    /// Connections regrown.
    pub regrown: usize,
}

/// Magnitude-prune thresholds: remove the ζ-fraction smallest positive
/// values and the ζ-fraction of negatives closest to zero.
///
/// Returns `(pos_cut, neg_cut)`: prune entries with `0 < v <= pos_cut` or
/// `neg_cut <= v < 0`. Zero-valued entries are always pruned.
pub fn prune_thresholds(values: &[f32], zeta: f64) -> (f32, f32) {
    let mut pos: Vec<f32> = values.iter().copied().filter(|v| *v > 0.0).collect();
    let mut neg: Vec<f32> = values.iter().copied().filter(|v| *v < 0.0).collect();
    let kp = (pos.len() as f64 * zeta).floor() as usize;
    let kn = (neg.len() as f64 * zeta).floor() as usize;
    let pos_cut = if kp == 0 || pos.is_empty() {
        0.0
    } else {
        let idx = kp - 1;
        let (_, v, _) = pos.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
        *v
    };
    let neg_cut = if kn == 0 || neg.is_empty() {
        0.0
    } else {
        // largest negatives = closest to zero = descending order
        let idx = kn - 1;
        let (_, v, _) = neg.select_nth_unstable_by(idx, |a, b| b.partial_cmp(a).unwrap());
        *v
    };
    (pos_cut, neg_cut)
}

/// One SET evolution step on a single layer: prune + random regrow.
pub fn evolve_layer(
    layer: &mut SparseLayer,
    cfg: &EvolutionConfig,
    rng: &mut Rng,
) -> Result<EvolutionStats> {
    let (pos_cut, neg_cut) = prune_thresholds(&layer.weights.values, cfg.zeta);
    let values = layer.weights.values.clone();
    let pruned = layer.retain_entries(|k| {
        let v = values[k];
        // keep when outside the prune bands and non-zero
        (v > pos_cut) || (v < neg_cut)
    });

    // regrow the same amount at random empty positions
    let (n_in, n_out) = (layer.n_in(), layer.n_out());
    let capacity = n_in * n_out - layer.weights.nnz();
    let to_grow = pruned.min(capacity);
    let mut additions: Vec<(u32, u32, f32)> = Vec::with_capacity(to_grow);
    let mut chosen = std::collections::HashSet::with_capacity(to_grow * 2);
    let mut attempts = 0usize;
    let max_attempts = to_grow.saturating_mul(200) + 1000;
    while additions.len() < to_grow && attempts < max_attempts {
        attempts += 1;
        let i = rng.below_usize(n_in) as u32;
        let j = rng.below_usize(n_out) as u32;
        if chosen.contains(&(i, j)) || layer.weights.find(i as usize, j).is_some() {
            continue;
        }
        chosen.insert((i, j));
        additions.push((i, j, cfg.init.sample(rng, n_in, n_out)));
    }
    let regrown = additions.len();
    layer.insert_entries(additions)?;
    Ok(EvolutionStats { pruned, regrown })
}

/// Evolution step over every layer of the model.
pub fn evolve_model(
    mlp: &mut SparseMlp,
    cfg: &EvolutionConfig,
    rng: &mut Rng,
) -> Result<Vec<EvolutionStats>> {
    mlp.layers
        .iter_mut()
        .map(|l| evolve_layer(l, cfg, rng))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Activation;

    fn layer(seed: u64) -> SparseLayer {
        let mut rng = Rng::new(seed);
        SparseLayer::erdos_renyi(
            40,
            30,
            6.0,
            Activation::Relu,
            &WeightInit::Normal(0.5),
            &mut rng,
        )
    }

    #[test]
    fn thresholds_split_by_sign() {
        let values = vec![-4.0, -3.0, -0.1, 0.2, 1.0, 5.0, 0.3];
        let (p, n) = prune_thresholds(&values, 0.34);
        // 3 positives -> kp=1 -> smallest positive 0.2
        assert_eq!(p, 0.2);
        // 3 negatives -> kn=1 -> largest negative -0.1
        assert_eq!(n, -0.1);
    }

    #[test]
    fn thresholds_zeta_zero_prunes_nothing() {
        let (p, n) = prune_thresholds(&[1.0, -1.0], 0.0);
        assert_eq!((p, n), (0.0, 0.0));
    }

    #[test]
    fn evolve_preserves_nnz_and_validity() {
        let mut l = layer(1);
        let before = l.weights.nnz();
        let stats = evolve_layer(&mut l, &EvolutionConfig::default(), &mut Rng::new(2)).unwrap();
        l.weights.validate().unwrap();
        assert_eq!(l.weights.nnz(), before - stats.pruned + stats.regrown);
        assert_eq!(stats.pruned, stats.regrown);
        assert!(stats.pruned > 0);
        assert_eq!(l.velocity.len(), l.weights.nnz());
    }

    #[test]
    fn evolve_prunes_small_magnitudes() {
        let mut l = layer(3);
        // inject extreme values that must survive
        let k = l.weights.nnz();
        l.weights.values[0] = 100.0;
        l.weights.values[k - 1] = -100.0;
        evolve_layer(&mut l, &EvolutionConfig::default(), &mut Rng::new(4)).unwrap();
        let has_big_pos = l.weights.values.iter().any(|&v| v == 100.0);
        let has_big_neg = l.weights.values.iter().any(|&v| v == -100.0);
        assert!(has_big_pos && has_big_neg);
    }

    #[test]
    fn regrown_links_have_zero_velocity() {
        let mut l = layer(5);
        for v in l.velocity.iter_mut() {
            *v = 7.0;
        }
        let stats = evolve_layer(&mut l, &EvolutionConfig::default(), &mut Rng::new(6)).unwrap();
        let zeros = l.velocity.iter().filter(|&&v| v == 0.0).count();
        assert!(zeros >= stats.regrown);
    }

    #[test]
    fn evolution_is_deterministic() {
        let mut a = layer(7);
        let mut b = layer(7);
        evolve_layer(&mut a, &EvolutionConfig::default(), &mut Rng::new(9)).unwrap();
        evolve_layer(&mut b, &EvolutionConfig::default(), &mut Rng::new(9)).unwrap();
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn evolve_model_touches_all_layers() {
        let mut rng = Rng::new(11);
        let mut mlp = SparseMlp::new(
            &[20, 30, 20, 5],
            4.0,
            Activation::Relu,
            &WeightInit::Normal(0.5),
            &mut rng,
        )
        .unwrap();
        let stats = evolve_model(&mut mlp, &EvolutionConfig::default(), &mut rng).unwrap();
        assert_eq!(stats.len(), 3);
        for (l, s) in mlp.layers.iter().zip(stats.iter()) {
            l.weights.validate().unwrap();
            assert!(s.pruned > 0);
        }
    }

    #[test]
    fn nearly_full_layer_regrows_up_to_capacity() {
        // dense-ish layer: capacity constrains regrowth
        let mut rng = Rng::new(13);
        let mut l = SparseLayer::erdos_renyi(
            4,
            4,
            100.0, // density clamps to 1.0
            Activation::Relu,
            &WeightInit::Normal(0.5),
            &mut rng,
        );
        let stats = evolve_layer(&mut l, &EvolutionConfig::default(), &mut Rng::new(14)).unwrap();
        assert!(stats.regrown <= stats.pruned);
        l.weights.validate().unwrap();
    }
}
