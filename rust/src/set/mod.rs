//! Sparse Evolutionary Training (SET) — dynamic topology evolution.
//!
//! At the end of each training epoch (Mocanu et al. 2018; Algorithm 2 of
//! the paper), every sparse layer:
//!
//! 1. removes a fraction ζ of the **smallest positive** weights,
//! 2. removes a fraction ζ of the **largest negative** weights (i.e. the
//!    negatives closest to zero — smallest magnitude on the negative side),
//! 3. regrows the same number of connections at uniformly-random empty
//!    positions with freshly-initialised weights and zero velocity.
//!
//! The prune thresholds are found with select-nth over a one-pass sign
//! partition (O(nnz), one scratch allocation). Regrowth samples the empty
//! set **directly**: Floyd sampling draws exactly `min(pruned, capacity)`
//! distinct *gap ordinals* — indices into the row-major enumeration of
//! the post-prune empty positions — which are then mapped to `(row, col)`
//! through the CSR structure. No rejection against the matrix, no
//! `max_attempts` cap: a near-dense layer regrows exactly its entitled
//! link count with a bounded number of RNG draws.
//!
//! [`evolve_layer`] / [`evolve_model`] are the **sequential oracles**:
//! simple, allocation-heavy reference implementations whose observable
//! behaviour defines correctness. The training hot path is
//! [`EvolutionEngine`] (see [`engine`], DESIGN.md §8) — the
//! worker-sharded, in-place, workspace-reusing engine that reproduces
//! the oracles bit-for-bit at every thread count
//! (`rust/tests/evolution_parity.rs`), mirroring the fused-backward
//! vs two-kernel-oracle pattern of DESIGN.md §5.
//!
//! RNG stream layout (shared by oracle and engine): [`evolve_model`]
//! draws ONE `u64` from the caller's generator to seed a root stream;
//! layer `l` then evolves on the independent stream `root.split(l)`.
//! All of a layer's draws (gap ordinals first, then one weight per
//! regrown link in sorted position order) happen on its own stream, so
//! results are invariant to layer order *and* to the engine's thread
//! count.

use std::collections::HashSet;

use crate::error::Result;
use crate::model::{SparseLayer, SparseMlp};
use crate::sparse::WeightInit;
use crate::util::Rng;

pub mod engine;

pub use engine::{EpochStats, EvolutionEngine};

/// Topology-evolution hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct EvolutionConfig {
    /// Fraction ζ of each sign class pruned per evolution step (paper: 0.3).
    pub zeta: f64,
    /// Initialiser for regrown connections.
    pub init: WeightInit,
}

impl Default for EvolutionConfig {
    fn default() -> Self {
        EvolutionConfig {
            zeta: 0.3,
            init: WeightInit::HeUniform,
        }
    }
}

/// Outcome of one evolution step on one layer.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EvolutionStats {
    /// Connections removed.
    pub pruned: usize,
    /// Connections regrown.
    pub regrown: usize,
}

/// Partition a value stream by sign into one reusable buffer: positives
/// fill the front (`buf[..lo]` in stream order), negatives fill the back
/// (`buf[hi..]` in *reverse* stream order); zeros are dropped. `n_upper`
/// is an upper bound on the stream length (the buffer is resized to it).
/// Returns `(lo, hi)`.
///
/// One pass, one (reusable) allocation — shared by [`prune_thresholds`]
/// and the engine's workspace path so both see identical slices.
pub(crate) fn partition_signs<I: Iterator<Item = f32>>(
    values: I,
    n_upper: usize,
    buf: &mut Vec<f32>,
) -> (usize, usize) {
    buf.clear();
    buf.resize(n_upper, 0.0);
    let (mut lo, mut hi) = (0usize, n_upper);
    for v in values {
        if v > 0.0 {
            buf[lo] = v;
            lo += 1;
        } else if v < 0.0 {
            hi -= 1;
            buf[hi] = v;
        }
    }
    (lo, hi)
}

/// Select the prune cuts from an already sign-partitioned value set:
/// `pos` holds the positive values, `neg` the negative ones (any order —
/// selection is by rank). Both slices are reordered in place.
pub(crate) fn thresholds_from_partition(
    pos: &mut [f32],
    neg: &mut [f32],
    zeta: f64,
) -> (f32, f32) {
    let kp = (pos.len() as f64 * zeta).floor() as usize;
    let kn = (neg.len() as f64 * zeta).floor() as usize;
    let pos_cut = if kp == 0 {
        0.0
    } else {
        let idx = kp - 1;
        let (_, v, _) = pos.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
        *v
    };
    let neg_cut = if kn == 0 {
        0.0
    } else {
        // largest negatives = closest to zero = descending order
        let idx = kn - 1;
        let (_, v, _) = neg.select_nth_unstable_by(idx, |a, b| b.partial_cmp(a).unwrap());
        *v
    };
    (pos_cut, neg_cut)
}

/// Magnitude-prune thresholds: remove the ζ-fraction smallest positive
/// values and the ζ-fraction of negatives closest to zero.
///
/// Returns `(pos_cut, neg_cut)`: prune entries with `0 < v <= pos_cut` or
/// `neg_cut <= v < 0`. Zero-valued entries are always pruned.
pub fn prune_thresholds(values: &[f32], zeta: f64) -> (f32, f32) {
    let mut buf = Vec::new();
    let (lo, hi) = partition_signs(values.iter().copied(), values.len(), &mut buf);
    let (front, back) = buf.split_at_mut(hi);
    thresholds_from_partition(&mut front[..lo], back, zeta)
}

/// Draw `k` distinct ordinals from `[0, n)` — Robert Floyd's sampling,
/// exactly `k` RNG draws, uniform without replacement. `out` receives the
/// ordinals in insertion order (callers sort); `seen` is the reusable
/// membership set. This is the ONLY randomness in a layer's regrowth
/// besides the weight draws, and both the sequential oracle and the
/// parallel engine call it with identical arguments, which is what makes
/// their RNG streams line up exactly.
pub(crate) fn sample_gap_ordinals(
    rng: &mut Rng,
    n: usize,
    k: usize,
    out: &mut Vec<usize>,
    seen: &mut HashSet<usize>,
) {
    debug_assert!(k <= n, "cannot sample {k} from {n}");
    out.clear();
    seen.clear();
    for j in (n - k)..n {
        let t = rng.below_usize(j + 1);
        let v = if seen.contains(&t) { j } else { t };
        seen.insert(v);
        out.push(v);
    }
}

/// One SET evolution step on a single layer: prune + gap-sampled regrow.
///
/// This is the sequential oracle (simple and allocation-heavy by design);
/// the training hot path is [`EvolutionEngine`], which reproduces this
/// function bit-for-bit at every thread count.
pub fn evolve_layer(
    layer: &mut SparseLayer,
    cfg: &EvolutionConfig,
    rng: &mut Rng,
) -> Result<EvolutionStats> {
    let (pos_cut, neg_cut) = prune_thresholds(&layer.weights.values, cfg.zeta);
    let values = layer.weights.values.clone();
    let pruned = layer.retain_entries(|k| {
        let v = values[k];
        // keep when outside the prune bands and non-zero
        (v > pos_cut) || (v < neg_cut)
    });

    // Regrow the same amount at uniformly-random empty positions: sample
    // gap ordinals over the post-prune empty set, then map each ordinal
    // to its (row, col) through the CSR structure.
    let (n_in, n_out) = (layer.n_in(), layer.n_out());
    let capacity = n_in * n_out - layer.weights.nnz();
    let to_grow = pruned.min(capacity);
    let mut ordinals = Vec::with_capacity(to_grow);
    let mut seen = HashSet::with_capacity(to_grow * 2);
    sample_gap_ordinals(rng, capacity, to_grow, &mut ordinals, &mut seen);
    ordinals.sort_unstable();

    let mut additions: Vec<(u32, u32, f32)> = Vec::with_capacity(to_grow);
    let mut empties_before = 0usize;
    let mut oi = 0usize;
    for i in 0..n_in {
        if oi >= ordinals.len() {
            break;
        }
        let row_nnz = layer.weights.row_ptr[i + 1] - layer.weights.row_ptr[i];
        let hi = empties_before + (n_out - row_nnz);
        while oi < ordinals.len() && ordinals[oi] < hi {
            let g = ordinals[oi] - empties_before;
            let col = layer.weights.nth_empty_in_row(i, g);
            additions.push((i as u32, col, 0.0));
            oi += 1;
        }
        empties_before = hi;
    }
    debug_assert_eq!(additions.len(), to_grow);
    // weights drawn in sorted (row, col) order — the engine draws in the
    // same order, keeping the RNG streams identical
    for a in additions.iter_mut() {
        a.2 = cfg.init.sample(rng, n_in, n_out);
    }
    let regrown = additions.len();
    layer.insert_entries(additions)?;
    Ok(EvolutionStats { pruned, regrown })
}

/// Evolution step over every layer of the model (sequential oracle).
///
/// Draws one `u64` from `rng` to seed a root stream; layer `l` evolves on
/// `root.split(l)` — the stream layout [`EvolutionEngine`] reproduces.
pub fn evolve_model(
    mlp: &mut SparseMlp,
    cfg: &EvolutionConfig,
    rng: &mut Rng,
) -> Result<Vec<EvolutionStats>> {
    let root = Rng::new(rng.next_u64());
    mlp.layers
        .iter_mut()
        .enumerate()
        .map(|(l, layer)| {
            let mut layer_rng = root.split(l as u64);
            evolve_layer(layer, cfg, &mut layer_rng)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Activation;

    fn layer(seed: u64) -> SparseLayer {
        let mut rng = Rng::new(seed);
        SparseLayer::erdos_renyi(
            40,
            30,
            6.0,
            Activation::Relu,
            &WeightInit::Normal(0.5),
            &mut rng,
        )
    }

    #[test]
    fn thresholds_split_by_sign() {
        let values = vec![-4.0, -3.0, -0.1, 0.2, 1.0, 5.0, 0.3];
        let (p, n) = prune_thresholds(&values, 0.34);
        // 4 positives -> kp=1 -> smallest positive 0.2
        assert_eq!(p, 0.2);
        // 3 negatives -> kn=1 -> largest negative -0.1
        assert_eq!(n, -0.1);
    }

    #[test]
    fn thresholds_zeta_zero_prunes_nothing() {
        let (p, n) = prune_thresholds(&[1.0, -1.0], 0.0);
        assert_eq!((p, n), (0.0, 0.0));
    }

    #[test]
    fn partition_signs_splits_and_orders() {
        let mut buf = Vec::new();
        let vals = [1.0f32, -2.0, 0.0, 3.0, -4.0];
        let (lo, hi) = partition_signs(vals.iter().copied(), vals.len(), &mut buf);
        assert_eq!(&buf[..lo], &[1.0, 3.0]);
        assert_eq!(&buf[hi..], &[-4.0, -2.0]); // back-filled, reverse order
        assert!(lo <= hi);
    }

    #[test]
    fn gap_sampler_draws_exactly_k_distinct() {
        let mut rng = Rng::new(5);
        let mut out = Vec::new();
        let mut seen = HashSet::new();
        for (n, k) in [(10usize, 10usize), (100, 7), (1, 1), (5, 0)] {
            sample_gap_ordinals(&mut rng, n, k, &mut out, &mut seen);
            assert_eq!(out.len(), k);
            let distinct: HashSet<_> = out.iter().collect();
            assert_eq!(distinct.len(), k);
            assert!(out.iter().all(|&v| v < n));
        }
    }

    #[test]
    fn evolve_preserves_nnz_and_validity() {
        let mut l = layer(1);
        let before = l.weights.nnz();
        let stats = evolve_layer(&mut l, &EvolutionConfig::default(), &mut Rng::new(2)).unwrap();
        l.weights.validate().unwrap();
        assert_eq!(l.weights.nnz(), before - stats.pruned + stats.regrown);
        assert_eq!(stats.pruned, stats.regrown);
        assert!(stats.pruned > 0);
        assert_eq!(l.velocity.len(), l.weights.nnz());
    }

    #[test]
    fn evolve_prunes_small_magnitudes() {
        let mut l = layer(3);
        // inject extreme values that must survive
        let k = l.weights.nnz();
        l.weights.values[0] = 100.0;
        l.weights.values[k - 1] = -100.0;
        evolve_layer(&mut l, &EvolutionConfig::default(), &mut Rng::new(4)).unwrap();
        let has_big_pos = l.weights.values.iter().any(|&v| v == 100.0);
        let has_big_neg = l.weights.values.iter().any(|&v| v == -100.0);
        assert!(has_big_pos && has_big_neg);
    }

    #[test]
    fn regrown_links_have_zero_velocity() {
        let mut l = layer(5);
        for v in l.velocity.iter_mut() {
            *v = 7.0;
        }
        let stats = evolve_layer(&mut l, &EvolutionConfig::default(), &mut Rng::new(6)).unwrap();
        let zeros = l.velocity.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, stats.regrown);
    }

    #[test]
    fn evolution_is_deterministic() {
        let mut a = layer(7);
        let mut b = layer(7);
        evolve_layer(&mut a, &EvolutionConfig::default(), &mut Rng::new(9)).unwrap();
        evolve_layer(&mut b, &EvolutionConfig::default(), &mut Rng::new(9)).unwrap();
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn evolve_model_touches_all_layers() {
        let mut rng = Rng::new(11);
        let mut mlp = SparseMlp::new(
            &[20, 30, 20, 5],
            4.0,
            Activation::Relu,
            &WeightInit::Normal(0.5),
            &mut rng,
        )
        .unwrap();
        let stats = evolve_model(&mut mlp, &EvolutionConfig::default(), &mut rng).unwrap();
        assert_eq!(stats.len(), 3);
        for (l, s) in mlp.layers.iter().zip(stats.iter()) {
            l.weights.validate().unwrap();
            assert!(s.pruned > 0);
        }
    }

    #[test]
    fn evolve_model_consumes_one_caller_draw() {
        // the per-layer streams come from a root seeded by a single u64,
        // so the caller's generator advances identically regardless of
        // the model's depth
        let mut rng_small = Rng::new(13);
        let mut rng_deep = Rng::new(13);
        let mk = |sizes: &[usize], r: &mut Rng| {
            SparseMlp::new(sizes, 4.0, Activation::Relu, &WeightInit::Normal(0.5), r).unwrap()
        };
        let mut small = mk(&[10, 10], &mut Rng::new(1));
        let mut deep = mk(&[10, 10, 10, 10, 10], &mut Rng::new(1));
        evolve_model(&mut small, &EvolutionConfig::default(), &mut rng_small).unwrap();
        evolve_model(&mut deep, &EvolutionConfig::default(), &mut rng_deep).unwrap();
        assert_eq!(rng_small.next_u64(), rng_deep.next_u64());
    }

    #[test]
    fn fully_dense_layer_regrows_exactly_pruned() {
        // Dense layer: the post-prune empty set is exactly the pruned
        // slots, so gap sampling regrows exactly `pruned` links. The old
        // rejection sampler could exhaust max_attempts here.
        let mut rng = Rng::new(13);
        let mut l = SparseLayer::erdos_renyi(
            4,
            4,
            100.0, // density clamps to 1.0
            Activation::Relu,
            &WeightInit::Normal(0.5),
            &mut rng,
        );
        assert_eq!(l.weights.nnz(), 16);
        let stats = evolve_layer(&mut l, &EvolutionConfig::default(), &mut Rng::new(14)).unwrap();
        assert_eq!(stats.regrown, stats.pruned);
        assert_eq!(l.weights.nnz(), 16);
        l.weights.validate().unwrap();
    }
}
