//! Error types for the tsnn crate.

use thiserror::Error;

/// Unified error type across the sparse engine, coordinator and runtime.
#[derive(Debug, Error)]
pub enum TsnnError {
    /// Shape mismatch between tensors / layers.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Invalid configuration value.
    #[error("invalid config: {0}")]
    Config(String),

    /// Dataset generation / loading problem.
    #[error("data error: {0}")]
    Data(String),

    /// Sparse-matrix structural invariant violated.
    #[error("sparse structure error: {0}")]
    Sparse(String),

    /// PJRT / XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator / parallel-training failure.
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// Checkpoint serialization problems.
    #[error("checkpoint error: {0}")]
    Checkpoint(String),

    /// IO wrapper.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, TsnnError>;

impl TsnnError {
    /// Helper for shape errors with formatted context.
    pub fn shape(msg: impl Into<String>) -> Self {
        TsnnError::Shape(msg.into())
    }
}
