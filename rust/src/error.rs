//! Error types for the tsnn crate.
//!
//! Hand-implemented `Display`/`Error`/`From` (the offline build has no
//! `thiserror`; see DESIGN.md §3 Substitutions) with the same variant
//! messages a `#[derive(Error)]` would produce.

use std::fmt;

/// Unified error type across the sparse engine, coordinator and runtime.
#[derive(Debug)]
pub enum TsnnError {
    /// Shape mismatch between tensors / layers.
    Shape(String),

    /// Invalid configuration value.
    Config(String),

    /// Dataset generation / loading problem.
    Data(String),

    /// Sparse-matrix structural invariant violated.
    Sparse(String),

    /// PJRT / XLA runtime failure.
    Runtime(String),

    /// Coordinator / parallel-training failure.
    Coordinator(String),

    /// Checkpoint serialization problems.
    Checkpoint(String),

    /// Checkpoint integrity trailer mismatch (torn write / bit rot).
    ChecksumMismatch(String),

    /// Inference serving-engine failure.
    Serve(String),

    /// Coordinator transport failure (malformed frame, timeout, peer gone).
    Transport(String),

    /// Index / nnz counter would not fit the target integer width
    /// (silent-truncation guard for >4B-edge models).
    IndexOverflow(String),

    /// Out-of-core storage failure (mmap, segment layout, swap protocol).
    Storage(String),

    /// IO wrapper.
    Io(std::io::Error),
}

impl fmt::Display for TsnnError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TsnnError::Shape(m) => write!(f, "shape mismatch: {m}"),
            TsnnError::Config(m) => write!(f, "invalid config: {m}"),
            TsnnError::Data(m) => write!(f, "data error: {m}"),
            TsnnError::Sparse(m) => write!(f, "sparse structure error: {m}"),
            TsnnError::Runtime(m) => write!(f, "runtime error: {m}"),
            TsnnError::Coordinator(m) => write!(f, "coordinator error: {m}"),
            TsnnError::Checkpoint(m) => write!(f, "checkpoint error: {m}"),
            TsnnError::ChecksumMismatch(m) => write!(f, "checksum mismatch: {m}"),
            TsnnError::Serve(m) => write!(f, "serving error: {m}"),
            TsnnError::Transport(m) => write!(f, "transport error: {m}"),
            TsnnError::IndexOverflow(m) => write!(f, "index overflow: {m}"),
            TsnnError::Storage(m) => write!(f, "storage error: {m}"),
            // transparent: delegate straight to the wrapped error
            TsnnError::Io(e) => fmt::Display::fmt(e, f),
        }
    }
}

impl std::error::Error for TsnnError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            // transparent: Display already delegates to the inner error, so
            // forward its *source* (not the error itself) to keep chain
            // walkers from printing the same message twice.
            TsnnError::Io(e) => std::error::Error::source(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for TsnnError {
    fn from(e: std::io::Error) -> Self {
        TsnnError::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, TsnnError>;

impl TsnnError {
    /// Helper for shape errors with formatted context.
    pub fn shape(msg: impl Into<String>) -> Self {
        TsnnError::Shape(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_keep_prefixes() {
        assert_eq!(
            TsnnError::Config("bad".into()).to_string(),
            "invalid config: bad"
        );
        assert_eq!(
            TsnnError::shape("a vs b").to_string(),
            "shape mismatch: a vs b"
        );
    }

    #[test]
    fn io_errors_convert_and_stay_transparent() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: TsnnError = io.into();
        assert_eq!(e.to_string(), "gone");
        // transparent chain: the message appears once, not again via source()
        assert!(std::error::Error::source(&e).is_none());
    }
}
