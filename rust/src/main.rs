//! `tsnn` — CLI launcher for the truly-sparse training framework.
//!
//! Subcommands:
//!   datasets                         print the dataset inventory (Table 1)
//!   train <dataset> [k=v ...]        sequential SET training (§2.2)
//!   parallel <dataset> [k=v ...]     WASAP/WASSP parallel training (§2.3)
//!   worker --connect ADDR --worker K headless worker for a parallel run
//!   baseline <arch> [k=v ...]        masked-dense XLA baseline ("Keras")
//!   inspect <checkpoint>             print a checkpoint's structure
//!   serve-bench [checkpoint]         serving QPS sweep (DESIGN.md §10)
//!   extreme [k=v ...]                out-of-core mmap-backed training
//!                                    under a RAM budget (DESIGN.md §14)
//!
//! Common options: --paper (full paper-scale dataset), --seed N,
//! --save PATH, --workers K, --sync, --phase1 N, --phase2 N, --verbose.
//! `train --state PATH [--checkpoint-every N]` writes a durable training
//! state each epoch; `train --resume PATH` continues a killed run
//! bit-exactly (DESIGN.md §13). `parallel --transport
//! unix:PATH|tcp:HOST:PORT` serves the run over a socket and spawns the
//! workers as `tsnn worker` child processes (DESIGN.md §12);
//! `--supervise [--max-restarts N]` respawns crashed workers and holds
//! their shards for rejoin; `--fault drop=N,dup=N,...` injects faults.
//!
//! Multi-node: bind the coordinator to a non-loopback interface
//! (`parallel <dataset> --transport tcp:0.0.0.0:PORT`) — locally spawned
//! workers still connect over loopback, and workers on *other* hosts
//! join the same run with `tsnn worker --connect tcp:COORD_HOST:PORT
//! --worker K`. The job spec (config + dataset recipe + kernel budgets)
//! travels over the socket at join, so remote workers need no shared
//! filesystem; they regenerate their shard deterministically from the
//! spec (`tests/transport_parity.rs` pins a `0.0.0.0`-bound run
//! bit-equal to the in-process reference).

use std::time::Duration;

use tsnn::bench::fmt_duration;
use tsnn::cli::Args;
use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::coordinator::supervisor::{RestartPolicy, SpawnFn, Supervisor};
use tsnn::coordinator::transport::fault::{FaultCounters, FaultPlan, FaultyTransport};
use tsnn::coordinator::transport::socket::{parse_addr, Addr, SocketClient, SocketHub};
use tsnn::coordinator::transport::worker::run_worker_joined;
use tsnn::coordinator::transport::{Client, JobSpec, RetryPolicy, Transport};
use tsnn::coordinator::{
    run_parallel_listener, run_parallel_opts, worker_kernel_budgets, CoordinatorOptions,
    ParallelConfig, ParallelOptions, ParallelReport, SupervisionPolicy, WorkerJob,
};
use tsnn::data::datasets;
use tsnn::error::{Result, TsnnError};
use tsnn::prelude::Rng;
use tsnn::runtime::{default_artifacts_dir, Manifest, MaskedDenseTrainer};
use tsnn::serve::{
    sweep, LayerFormat, LayoutOptions, ServeConfig, ServeEngine, ServeModel, SweepConfig,
};
use tsnn::sparse::simd::{self, KernelFormat};
use tsnn::train::{
    load_state, train_resume, train_sequential_opts, CheckpointPolicy, TrainOptions, TrainState,
};
use tsnn::util::logging;

const DATASETS: &[&str] =
    &["leukemia", "higgs", "madelon", "fashion", "cifar", "extreme", "recommender"];

fn main() {
    logging::init();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "datasets" => cmd_datasets(args),
        "train" => cmd_train(args),
        "parallel" => cmd_parallel(args),
        "worker" => cmd_worker(args),
        "baseline" => cmd_baseline(args),
        "inspect" => cmd_inspect(args),
        "serve-bench" => cmd_serve_bench(args),
        #[cfg(target_pointer_width = "64")]
        "extreme" => cmd_extreme(args),
        #[cfg(not(target_pointer_width = "64"))]
        "extreme" => Err(TsnnError::Config(
            "the out-of-core subsystem needs a 64-bit build".into(),
        )),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => Err(TsnnError::Config(format!(
            "unknown subcommand '{other}' (try 'tsnn help')"
        ))),
    }
}

fn print_help() {
    println!(
        "tsnn — Truly Sparse Neural Networks at Scale (reproduction)\n\n\
         usage: tsnn <subcommand> [args]\n\n\
         subcommands:\n\
         \x20 datasets                      dataset inventory (Table 1)\n\
         \x20 train <dataset> [k=v ...]     sequential SET training\n\
         \x20   (--state PATH [--checkpoint-every N] writes durable\n\
         \x20    training state; --resume PATH continues a killed run\n\
         \x20    bit-exactly)\n\
         \x20 parallel <dataset> [k=v ...]  WASAP/WASSP parallel training\n\
         \x20   (--transport unix:PATH|tcp:HOST:PORT runs workers as\n\
         \x20    child processes; --supervise [--max-restarts N] respawns\n\
         \x20    crashed workers; --fault drop=N,dup=N,delay=N,drop_reply=N)\n\
         \x20 worker --connect ADDR --worker K   headless parallel worker\n\
         \x20 baseline <arch> [k=v ...]     masked-dense XLA baseline\n\
         \x20 inspect <checkpoint.tsnn>     checkpoint summary\n\
         \x20 serve-bench [checkpoint]      serving layout + offered-QPS sweep\n\
         \x20   (--qps N --steps N --requests N --batch N --queue N\n\
         \x20    --wait-us N --threads N)\n\
         \x20 extreme [k=v ...]             out-of-core mmap-backed training\n\
         \x20   (--dir PATH --budget-mb N --features N --train N --test N\n\
         \x20    --persist-every N --check-every N --assert --save PATH;\n\
         \x20    segments on disk may exceed the budget, resident memory\n\
         \x20    should not — --assert enforces both; defaults to\n\
         \x20    weight_decay=0 evolution=off so the activity-gated\n\
         \x20    update can leave inactive rows on disk, --set overrides)\n\
         multi-node: parallel ... --transport tcp:0.0.0.0:PORT, then on\n\
         \x20        other hosts: worker --connect tcp:COORD_HOST:PORT\n\
         \x20        --worker K\n\n\
         options: --paper --seed N --save PATH --workers K --sync\n\
         \x20        --phase1 N --phase2 N --verbose --gradflow N\n\
         overrides: epochs= batch= epsilon= lr= alpha= activation= init=\n\
         \x20          hidden=AxBxC zeta= dropout= importance=on|off\n\
         \x20          kernel_threads=N (0=all cores, 1=sequential) ...\n\
         datasets: {DATASETS:?}"
    );
}

fn dataset_spec(args: &Args, name: &str) -> DatasetSpec {
    if args.flag("paper") {
        DatasetSpec::paper(name)
    } else {
        DatasetSpec::small(name)
    }
}

fn build_config(args: &Args, dataset: &str) -> Result<TrainConfig> {
    let mut cfg = if args.flag("paper") {
        TrainConfig::paper_preset(dataset)
    } else {
        TrainConfig::small_preset(dataset)
    };
    if let Some(path) = args.opt("config") {
        let text = std::fs::read_to_string(path)?;
        cfg.apply_file(&text)?;
    }
    for (k, v) in &args.overrides {
        cfg.set(k, v)?;
    }
    if let Some(seed) = args.opt("seed") {
        cfg.set("seed", seed)?;
    }
    Ok(cfg)
}

fn cmd_datasets(args: &Args) -> Result<()> {
    let mut table = tsnn::bench::Table::new(
        "Table 1 — dataset inventory",
        &["dataset", "domain", "features", "train", "test", "classes", "size"],
    );
    let domains = [
        ("leukemia", "microarray (synthetic)"),
        ("higgs", "physics (synthetic)"),
        ("madelon", "artificial (Guyon)"),
        ("fashion", "images (synthetic)"),
        ("cifar", "RGB images (synthetic)"),
        ("extreme", "big artificial (§2.4)"),
        ("recommender", "wide sparse recsys (§14)"),
    ];
    for (name, domain) in domains {
        let spec = dataset_spec(args, name);
        let mib = (spec.n_train + spec.n_test) as f64 * spec.n_features as f64 * 4.0
            / (1024.0 * 1024.0);
        table.row(vec![
            name.into(),
            domain.into(),
            spec.n_features.to_string(),
            spec.n_train.to_string(),
            spec.n_test.to_string(),
            spec.n_classes.to_string(),
            format!("{mib:.0} MiB"),
        ]);
    }
    println!("{}", table.to_markdown());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let dataset = args
        .positional
        .first()
        .ok_or_else(|| TsnnError::Config("train needs a dataset name".into()))?;
    let spec = dataset_spec(args, dataset);
    let cfg = build_config(args, dataset)?;
    let mut rng = Rng::new(cfg.seed);
    log::info!(
        "generating {} ({} features, {} train)",
        spec.name,
        spec.n_features,
        spec.n_train
    );
    let data = datasets::generate(&spec, &mut rng)?;
    let checkpoint = match args.opt("state") {
        Some(p) => Some(CheckpointPolicy {
            path: std::path::PathBuf::from(p),
            every: args.opt_parse("checkpoint-every", 1usize)?,
        }),
        None => None,
    };
    let opts = TrainOptions {
        gradflow_every: args.opt_parse("gradflow", 0usize)?,
        verbose: args.flag("verbose"),
        checkpoint,
    };
    log::info!(
        "training {:?} ε={} act={:?} epochs={}",
        cfg.sizes(data.n_features, data.n_classes),
        cfg.epsilon,
        cfg.activation,
        cfg.epochs
    );
    let report = if let Some(resume_path) = args.opt("resume") {
        // a crash mid-save can leave a temp sibling; only the renamed
        // file is ever trusted, the temp is deleted
        let path = std::path::Path::new(resume_path);
        TrainState::clean_stale_tmp(path);
        let state = load_state(path)?;
        log::info!("resuming from {resume_path} at epoch {}", state.next_epoch);
        let mut phases = tsnn::util::PhaseTimes::new();
        train_resume(&cfg, &data, state, opts, &mut phases)?
    } else {
        train_sequential_opts(&cfg, &data, &mut rng, opts)?
    };
    println!(
        "dataset={} best_test_acc={:.4} final_test_acc={:.4} start_w={} end_w={} train_time={}",
        spec.name,
        report.best_test_accuracy,
        report.final_test_accuracy,
        report.start_weights,
        report.end_weights,
        fmt_duration(report.phases.get("train"))
    );
    for (phase, secs) in report.phases.iter() {
        println!("  phase {phase:<12} {}", fmt_duration(secs));
    }
    if let Some(path) = args.opt("save") {
        tsnn::model::checkpoint::save(&report.model, std::path::Path::new(path))?;
        println!("checkpoint written to {path}");
    }
    if let Some(path) = args.opt("curves") {
        std::fs::write(path, report.curves_csv())?;
        println!("curves written to {path}");
    }
    Ok(())
}

fn cmd_parallel(args: &Args) -> Result<()> {
    let dataset = args
        .positional
        .first()
        .ok_or_else(|| TsnnError::Config("parallel needs a dataset name".into()))?;
    let spec = dataset_spec(args, dataset);
    let cfg = build_config(args, dataset)?;
    let pcfg = ParallelConfig {
        workers: args.opt_parse("workers", 5usize)?,
        phase1_epochs: args
            .opt_parse("phase1", cfg.epochs.saturating_sub(cfg.epochs / 5).max(1))?,
        phase2_epochs: args.opt_parse("phase2", (cfg.epochs / 5).max(1))?,
        synchronous: args.flag("sync"),
        hot_start: true,
        grad_clip: 5.0,
    };
    let fault = match args.opt("fault") {
        Some(spec) => FaultPlan::parse(spec)?,
        None => FaultPlan::default(),
    };
    let mut rng = Rng::new(cfg.seed);
    let data = datasets::generate(&spec, &mut rng)?;
    log::info!(
        "{} with {} workers (phase1={} phase2={})",
        if pcfg.synchronous { "WASSP-SGD" } else { "WASAP-SGD" },
        pcfg.workers,
        pcfg.phase1_epochs,
        pcfg.phase2_epochs
    );
    let report = match args.opt("transport") {
        None | Some("inproc") => {
            let opts = ParallelOptions {
                fault,
                ..ParallelOptions::default()
            };
            run_parallel_opts(&cfg, &pcfg, &data, &mut rng, &opts)?
        }
        Some(addr_spec) => {
            run_parallel_multiprocess(&cfg, &pcfg, &spec, &data, &mut rng, addr_spec, args)?
        }
    };
    println!(
        "dataset={} algo={} workers={} phase1_acc={:.4} final_acc={:.4} \
         steps={} mean_staleness={:.2} dropped={} time={}",
        spec.name,
        if pcfg.synchronous { "WASSP" } else { "WASAP" },
        pcfg.workers,
        report.phase1_test_accuracy,
        report.final_test_accuracy,
        report.server_stats.steps,
        report.server_stats.mean_staleness,
        report.server_stats.dropped_entries,
        fmt_duration(report.phases.get("phase1") + report.phases.get("phase2"))
    );
    if report.server_stats.nonfinite_rejected > 0 || report.coord_stats.stragglers_flagged > 0 {
        println!(
            "  guards: nonfinite_rejected={} stragglers_flagged={}",
            report.server_stats.nonfinite_rejected, report.coord_stats.stragglers_flagged
        );
    }
    if let Some(path) = args.opt("save") {
        tsnn::model::checkpoint::save(&report.model, std::path::Path::new(path))?;
        println!("checkpoint written to {path}");
    }
    Ok(())
}

/// Serve a parallel run over a socket, spawning `tsnn worker` child
/// processes for every shard (DESIGN.md §12.5).
fn run_parallel_multiprocess(
    cfg: &TrainConfig,
    pcfg: &ParallelConfig,
    spec: &DatasetSpec,
    data: &tsnn::data::Dataset,
    rng: &mut Rng,
    addr_spec: &str,
    args: &Args,
) -> Result<ParallelReport> {
    let addr = parse_addr(addr_spec)?;
    let mut hub = SocketHub::bind(&addr)?;
    // `tcp:HOST:0` binds an OS-assigned port; children must get the real one
    let connect_addr = match (&addr, &hub.local_tcp) {
        (Addr::Tcp(_), Some(actual)) => Addr::Tcp(actual.clone()),
        _ => addr,
    };
    let budgets = worker_kernel_budgets(cfg, pcfg.workers);
    let job_json = JobSpec::new(cfg, spec, pcfg, budgets).to_json();

    let exe = std::env::current_exe()?;
    let fault = args.opt("fault").map(str::to_string);
    let connect_str = connect_addr.to_string();
    let spawn: Box<SpawnFn> = Box::new(move |k: u32| {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("worker")
            .arg("--connect")
            .arg(&connect_str)
            .arg("--worker")
            .arg(k.to_string());
        if let Some(f) = &fault {
            cmd.arg("--fault").arg(f);
        }
        cmd.spawn()
    });

    let mut coord_opts = CoordinatorOptions::default();
    if args.flag("supervise") {
        // supervised run: crashed workers are respawned (below) and the
        // coordinator holds their shards for rejoin instead of shrinking
        coord_opts.supervision = Some(SupervisionPolicy::default());
        let policy = RestartPolicy {
            max_restarts: args.opt_parse("max-restarts", 3usize)?,
            ..RestartPolicy::default()
        };
        let sup = Supervisor::start(pcfg.workers, policy, spawn)?;
        log::info!(
            "spawned {} supervised worker processes on {connect_addr}",
            pcfg.workers
        );
        let result =
            run_parallel_listener(cfg, pcfg, data, rng, &mut hub, Some(job_json), &coord_opts);
        for (k, r) in sup.finish(Duration::from_secs(10)).iter().enumerate() {
            if r.restarts > 0 || r.abandoned {
                log::info!("worker {k}: restarts={} abandoned={}", r.restarts, r.abandoned);
            }
        }
        return result;
    }

    let mut children = Vec::with_capacity(pcfg.workers);
    for k in 0..pcfg.workers {
        children.push(spawn(k as u32).map_err(|e| {
            TsnnError::Transport(format!("spawning worker {k}: {e}"))
        })?);
    }
    log::info!("spawned {} worker processes on {connect_addr}", pcfg.workers);

    let result =
        run_parallel_listener(cfg, pcfg, data, rng, &mut hub, Some(job_json), &coord_opts);
    for (k, mut child) in children.into_iter().enumerate() {
        match child.wait() {
            Ok(status) if !status.success() => {
                log::warn!("worker process {k} exited with {status}")
            }
            Err(e) => log::warn!("waiting on worker process {k}: {e}"),
            _ => {}
        }
    }
    result
}

/// Headless worker process: join a coordinator, receive the job spec,
/// regenerate the dataset shard deterministically, and run the standard
/// worker lifetime (phase-1 pushes, phase-2 replica).
fn cmd_worker(args: &Args) -> Result<()> {
    let connect = args
        .opt("connect")
        .ok_or_else(|| TsnnError::Config("worker needs --connect ADDR".into()))?;
    let worker: u32 = args.opt_parse("worker", u32::MAX)?;
    if worker == u32::MAX {
        return Err(TsnnError::Config("worker needs --worker K".into()));
    }
    let addr = parse_addr(connect)?;
    // retry with backoff: the worker may launch before the coordinator
    // binds (startup race), or a supervisor respawn may race a restart
    let connect_timeout = Duration::from_secs(args.opt_parse("connect-timeout", 30u64)?);
    let mut transport: Box<dyn Transport> =
        Box::new(SocketClient::connect_retry(&addr, connect_timeout)?);
    if let Some(fault_spec) = args.opt("fault") {
        let plan = FaultPlan::parse(fault_spec)?;
        if plan.is_active() {
            transport = Box::new(FaultyTransport::new(
                transport,
                plan,
                std::sync::Arc::new(FaultCounters::default()),
            ));
        }
    }
    let mut client = Client::new(transport, worker, RetryPolicy::default());
    let reply = client.join()?;
    let job_json = reply.job.as_deref().ok_or_else(|| {
        TsnnError::Transport("coordinator sent no job spec at join".into())
    })?;
    let spec = JobSpec::from_json(job_json)?;
    let mut cfg = TrainConfig::default();
    cfg.apply_file(&spec.config_kv)?;
    // identical stream prefix to the coordinator's own generation call
    let mut rng = Rng::new(cfg.seed);
    let data = datasets::generate(&spec.dataset, &mut rng)?;
    let kernel_threads = spec
        .budgets
        .get(worker as usize)
        .copied()
        .unwrap_or(1);
    let job = WorkerJob::new(worker, kernel_threads, &cfg, &spec.pcfg);
    if reply.resume_pushes > 0 {
        log::info!(
            "rejoined: fast-forwarding {} counted pushes",
            reply.resume_pushes
        );
    }
    let report = run_worker_joined(&mut client, &job, &data, &reply)?;
    println!(
        "worker={} pushes={} retries={} zeroed_nonfinite={}",
        worker, report.pushes, report.retries, report.zeroed_nonfinite
    );
    Ok(())
}

fn cmd_baseline(args: &Args) -> Result<()> {
    let arch_name = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("small");
    let manifest = Manifest::load(&default_artifacts_dir())?;
    let arch = manifest
        .get(arch_name)
        .ok_or_else(|| TsnnError::Config(format!("unknown architecture '{arch_name}'")))?;
    let epochs: usize = args.opt_parse("epochs", 3usize)?;
    let epsilon: f64 = args.opt_parse("epsilon", 10.0f64)?;
    let lr: f32 = args.opt_parse("lr", 0.01f32)?;
    let seed: u64 = args.opt_parse("seed", 42u64)?;

    // dataset shaped to the architecture
    let spec = DatasetSpec {
        name: format!("synthetic-for-{arch_name}"),
        generator: "madelon".into(),
        n_features: arch.sizes[0],
        n_classes: *arch.sizes.last().unwrap(),
        n_train: args.opt_parse("train", 2048usize)?,
        n_test: args.opt_parse("test", 512usize)?,
    };
    let mut rng = Rng::new(seed);
    let mut data = datasets::generate(&spec, &mut rng)?;
    // madelon generator is binary; fold labels into the arch's class count
    let nc = spec.n_classes as u32;
    for (i, y) in data.y_train.iter_mut().enumerate() {
        *y = (*y + (i as u32 % nc)) % nc;
    }
    for (i, y) in data.y_test.iter_mut().enumerate() {
        *y = (*y + (i as u32 % nc)) % nc;
    }

    log::info!("masked-dense baseline: arch={arch_name} epochs={epochs}");
    let mut trainer = MaskedDenseTrainer::new(arch, epsilon, &mut rng)?;
    println!(
        "arch={} dense_memory={} KiB nnz={}",
        arch_name,
        trainer.memory_bytes() / 1024,
        trainer.nnz()
    );
    for e in 0..epochs {
        let ep = trainer.train_epoch(&data, lr, &mut rng)?;
        trainer.evolve(0.3, &mut rng);
        println!(
            "epoch {e}: loss={:.4} acc={:.4} ({})",
            ep.loss,
            ep.accuracy,
            fmt_duration(ep.seconds)
        );
    }
    let acc = trainer.evaluate(&data)?;
    println!("baseline test accuracy: {acc:.4}");
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| TsnnError::Config("inspect needs a checkpoint path".into()))?;
    let model = tsnn::model::checkpoint::load(std::path::Path::new(path))?;
    let serve = ServeModel::from_mlp(&model, &LayoutOptions::default());
    println!("sizes: {:?}", model.sizes);
    println!("neurons: {}", model.neuron_count());
    println!("weights: {}", model.weight_count());
    println!("memory: {} KiB", model.memory_bytes() / 1024);
    println!("serve memory: {} KiB (weights-only layout)", serve.memory_bytes() / 1024);
    print_isa_line();
    for (l, layer) in model.layers.iter().enumerate() {
        println!(
            "  layer {l}: {}x{} nnz={} density={:.4} act={:?} serve={} kernel={}",
            layer.n_in(),
            layer.n_out(),
            layer.weights.nnz(),
            layer.weights.density(),
            layer.activation,
            format_name(serve.layers[l].format()),
            kernel_name_for(serve.layers[l].format())
        );
    }
    Ok(())
}

fn format_name(f: LayerFormat) -> &'static str {
    match f {
        LayerFormat::Csr => "csr",
        LayerFormat::Dense => "dense",
    }
}

/// The microkernel the process-detected ISA selects for a serve format
/// (training layers always dispatch the CSR kernel, DESIGN.md §11.2).
fn kernel_name_for(f: LayerFormat) -> &'static str {
    let fmt = match f {
        LayerFormat::Csr => KernelFormat::Csr,
        LayerFormat::Dense => KernelFormat::Dense,
    };
    simd::microkernel_name(simd::detected_isa(), fmt)
}

/// One line of ISA observability: what the dispatch tables selected and
/// whether a `TSNN_ISA` override drove the choice.
fn print_isa_line() {
    let isa = simd::detected_isa();
    match std::env::var("TSNN_ISA") {
        Ok(v) => println!("isa: {} (TSNN_ISA={v})", isa.name()),
        Err(_) => println!("isa: {} (runtime-detected)", isa.name()),
    }
}

/// Serving layout + closed-loop offered-QPS sweep on a checkpoint (or a
/// synthetic ε-sparse model when no path is given) — the CLI face of
/// `benches/perf_serving.rs`.
fn cmd_serve_bench(args: &Args) -> Result<()> {
    let opts = LayoutOptions::default();
    let model = match args.positional.first() {
        Some(path) => ServeModel::load(std::path::Path::new(path), &opts)?,
        None => {
            let mut rng = Rng::new(args.opt_parse("seed", 42u64)?);
            let mlp = tsnn::model::SparseMlp::new(
                &[256, 512, 10],
                20.0,
                tsnn::nn::Activation::AllRelu { alpha: 0.6 },
                &tsnn::sparse::WeightInit::HeUniform,
                &mut rng,
            )?;
            ServeModel::from_mlp(&mlp, &opts)
        }
    };
    println!("serving layout ({} KiB):", model.memory_bytes() / 1024);
    print_isa_line();
    for (l, layer) in model.layers.iter().enumerate() {
        println!(
            "  layer {l}: {}x{} nnz={} density={:.4} format={} kernel={}",
            layer.n_in(),
            layer.n_out(),
            layer.nnz(),
            layer.density,
            format_name(layer.format()),
            kernel_name_for(layer.format())
        );
    }

    let requests = args.opt_parse("requests", 200usize)?.max(1);
    let sweep_cfg = SweepConfig {
        start_qps: args.opt_parse("qps", 200.0f64)?,
        growth: 2.0,
        max_steps: args.opt_parse("steps", 6usize)?,
        requests_per_step: requests,
        saturation_ratio: 0.9,
    };
    let cfg = ServeConfig {
        max_batch: args.opt_parse("batch", 32usize)?,
        max_queue: args.opt_parse("queue", 1024usize)?,
        max_wait: Duration::from_micros(args.opt_parse("wait-us", 2000u64)?),
        kernel_threads: args.opt_parse("threads", 0usize)?,
        latency_window: requests,
    };
    let n_feat = model.n_features();
    let mut rng = Rng::new(7);
    let features: Vec<f32> = (0..64 * n_feat)
        .map(|_| if rng.bernoulli(0.3) { 0.0 } else { rng.normal() })
        .collect();

    let mut engine = ServeEngine::new(model, cfg);
    let reports = sweep(&engine, &features, n_feat, &sweep_cfg);
    engine.shutdown();

    let mut table = tsnn::bench::Table::new(
        "serving sweep — offered QPS to saturation",
        &["offered", "achieved", "completed", "rejected", "p50 µs", "p95 µs", "p99 µs", "sat"],
    );
    for r in &reports {
        table.row(vec![
            format!("{:.0}", r.offered_qps),
            format!("{:.0}", r.achieved_qps),
            r.completed.to_string(),
            r.rejected.to_string(),
            format!("{:.1}", r.latency.p50_ns as f64 / 1e3),
            format!("{:.1}", r.latency.p95_ns as f64 / 1e3),
            format!("{:.1}", r.latency.p99_ns as f64 / 1e3),
            if r.saturated { "*" } else { "" }.into(),
        ]);
    }
    println!("{}", table.to_markdown());
    if let Some(knee) = reports.iter().find(|r| r.saturated) {
        println!("saturation at ~{:.0} offered qps", knee.offered_qps);
    } else {
        println!("no saturation reached within the sweep (raise --qps or --steps)");
    }
    Ok(())
}

/// Out-of-core training under a RAM budget (DESIGN.md §14): build a
/// mapped [`tsnn::bigmodel::BigModel`] on the wide-sparse recommender
/// dataset and train it with segment files on disk allowed to exceed
/// the budget while resident memory is held near it. `--assert` turns
/// the two residency claims into hard errors (the extreme-smoke CI job
/// and `benches/perf_outofcore.rs` both lean on this).
#[cfg(target_pointer_width = "64")]
fn cmd_extreme(args: &Args) -> Result<()> {
    use tsnn::bigmodel::{train_big, vm_hwm_bytes, BigModel, BigTrainOptions};

    let dir = std::path::PathBuf::from(args.opt("dir").unwrap_or("extreme_model"));
    let budget_mb: u64 = args.opt_parse("budget-mb", 512u64)?;
    let budget_bytes = budget_mb.saturating_mul(1024 * 1024);

    let mut spec = dataset_spec(args, "recommender");
    spec.n_features = args.opt_parse("features", spec.n_features)?;
    spec.n_train = args.opt_parse("train", spec.n_train)?;
    spec.n_test = args.opt_parse("test", spec.n_test)?;
    let mut cfg = build_config(args, "recommender")?;
    // weight_decay = 0 arms the activity-gated optimizer update
    // (DESIGN.md §14.6) — without it every weight moves every step, the
    // whole model is touched per batch, and no residency budget below
    // the model size can hold. Explicit `--set weight_decay=...` wins.
    if !args.overrides.iter().any(|(k, _)| k.as_str() == "weight_decay") {
        cfg.set("weight_decay", "0")?;
    }
    // topology evolution's magnitude scan faults in every mapped page of
    // every layer, so an evolving run peaks at full model size; default
    // it off here and let `--set evolution=on` opt back in.
    if !args.overrides.iter().any(|(k, _)| k.as_str() == "evolution") {
        cfg.set("evolution", "off")?;
    }

    let mut rng = Rng::new(cfg.seed);
    log::info!(
        "generating {} ({} features, {} train)",
        spec.name,
        spec.n_features,
        spec.n_train
    );
    let data = datasets::generate(&spec, &mut rng)?;
    let sizes = cfg.sizes(data.n_features, data.n_classes);
    log::info!(
        "creating mapped model {:?} ε={} under {}",
        sizes,
        cfg.epsilon,
        dir.display()
    );
    let mut model = BigModel::create(&dir, &sizes, cfg.epsilon, cfg.activation, &cfg.init, &mut rng)?;
    let segment_bytes = model.total_segment_bytes();
    println!(
        "segments: {} files, {:.1} MiB on disk (budget {budget_mb} MiB, dataset {:.1} MiB)",
        sizes.len() - 1,
        segment_bytes as f64 / (1024.0 * 1024.0),
        data.memory_mib()
    );

    let opts = BigTrainOptions {
        soft_budget_bytes: Some(budget_bytes),
        residency_check_every: args.opt_parse("check-every", 16usize)?,
        persist_every: args.opt_parse("persist-every", 0usize)?,
        verbose: args.flag("verbose"),
    };
    let report = train_big(&cfg, &data, &mut model, &mut rng, &opts)?;

    let hwm = report.peak_rss_bytes.or_else(vm_hwm_bytes);
    println!(
        "dataset={} best_test_acc={:.4} final_test_acc={:.4} start_w={} end_w={}",
        spec.name,
        report.best_test_accuracy,
        report.final_test_accuracy,
        report.start_weights,
        report.end_weights
    );
    match hwm {
        Some(peak) => println!(
            "residency: segments {:.1} MiB, peak RSS {:.1} MiB, budget {budget_mb} MiB, trims {}",
            segment_bytes as f64 / (1024.0 * 1024.0),
            peak as f64 / (1024.0 * 1024.0),
            report.trim_events
        ),
        None => println!(
            "residency: segments {:.1} MiB, peak RSS unavailable (no /proc), trims {}",
            segment_bytes as f64 / (1024.0 * 1024.0),
            report.trim_events
        ),
    }
    if args.flag("assert") {
        if segment_bytes <= budget_bytes {
            return Err(TsnnError::Config(format!(
                "--assert: segment bytes {segment_bytes} do not exceed the budget \
                 {budget_bytes}; the run never left RAM scale (raise --features/hidden= \
                 or lower --budget-mb)"
            )));
        }
        let peak = hwm.ok_or_else(|| {
            TsnnError::Config("--assert needs /proc/self/status (Linux)".into())
        })?;
        if peak >= budget_bytes {
            return Err(TsnnError::Config(format!(
                "--assert: peak RSS {peak} B breached the budget {budget_bytes} B \
                 ({} trims)",
                report.trim_events
            )));
        }
        println!("asserted: disk {segment_bytes} B > budget > peak RSS {peak} B");
    }
    if let Some(path) = args.opt("save") {
        model.save_checkpoint(std::path::Path::new(path))?;
        println!("checkpoint written to {path}");
    }
    Ok(())
}
