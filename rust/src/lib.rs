//! # tsnn — Truly Sparse Neural Networks at Scale
//!
//! A Rust + JAX + Pallas reproduction of *"Truly Sparse Neural Networks
//! at Scale"* (Curci, Mocanu, Pechenizkiy, 2021): a truly-sparse (CSR,
//! never-dense) training engine with the paper's three contributions —
//! **WASAP-SGD** parallel training, the **All-ReLU** activation, and
//! **Importance Pruning** — plus the SET dynamic-sparse-training
//! substrate, synthetic dataset generators, a PJRT runtime for the
//! masked-dense comparator, and bench harnesses regenerating every table
//! and figure of the paper's evaluation.
//!
//! ## Layer map (see DESIGN.md)
//! - L3: this crate — coordinator, sparse engine, datasets, CLI.
//! - L2: `python/compile/model.py` — masked-dense MLP, AOT-lowered to
//!   HLO text in `artifacts/`, executed via [`runtime`].
//! - L1: `python/compile/kernels/` — Pallas masked-matmul + fused
//!   All-ReLU kernel, folded into the L2 artifacts.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod gradflow;
pub mod importance;
pub mod model;
pub mod nn;
pub mod runtime;
pub mod set;
pub mod sparse;
pub mod train;
pub mod util;

/// Convenient re-exports for downstream users.
pub mod prelude {
    pub use crate::config::{DatasetSpec, TrainConfig};
    pub use crate::data::datasets;
    pub use crate::error::{Result, TsnnError};
    pub use crate::model::{Batcher, SparseLayer, SparseMlp, Workspace};
    pub use crate::nn::{Activation, Dropout, LrSchedule, MomentumSgd};
    pub use crate::sparse::{CsrMatrix, WeightInit};
    pub use crate::train::{train_sequential, TrainReport};
    pub use crate::util::Rng;
}
