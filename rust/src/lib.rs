//! # tsnn — Truly Sparse Neural Networks at Scale
//!
//! A Rust + JAX + Pallas reproduction of *"Truly Sparse Neural Networks
//! at Scale"* (Curci, Mocanu, Pechenizkiy, 2021): a truly-sparse (CSR,
//! never-dense) training engine with the paper's three contributions —
//! **WASAP-SGD** parallel training, the **All-ReLU** activation, and
//! **Importance Pruning** — plus the SET dynamic-sparse-training
//! substrate, synthetic dataset generators, a PJRT runtime for the
//! masked-dense comparator, and bench harnesses regenerating every table
//! and figure of the paper's evaluation.
//!
//! ## Layer map (see DESIGN.md)
//! - L3: this crate — coordinator, sparse engine, datasets, CLI.
//! - L2: `python/compile/model.py` — masked-dense MLP, AOT-lowered to
//!   HLO text in `artifacts/`, executed via [`runtime`].
//! - L1: `python/compile/kernels/` — Pallas masked-matmul + fused
//!   All-ReLU kernel, folded into the L2 artifacts.
//!
//! The hot-path CSR kernels additionally ship worker-sharded parallel
//! variants (DESIGN.md §4) — disjoint-write sharding, exact-match
//! deterministic, selected end to end by the `kernel_threads` config
//! knob — dispatched on a persistent spawn-once/park worker pool
//! (DESIGN.md §9) that lives for the whole training run, and the
//! backward pass runs as a fused one-pass kernel (DESIGN.md §5): input
//! gradient and pattern-aligned weight gradient in a single CSR
//! traversal per layer.
//!
//! Trained checkpoints are served by the [`serve`] subsystem
//! (DESIGN.md §10): a weights-only inference layout with per-layer
//! CSR/dense format selection, a bounded-queue request-batching front
//! end on the same worker pool, and p50/p95/p99 latency accounting.
//!
//! ## Quick example
//!
//! Build a truly-sparse MLP, run a forward pass, and take one training
//! step — no dense weight matrix is ever materialised:
//!
//! ```
//! use tsnn::prelude::*;
//! use tsnn::nn::MomentumSgd;
//!
//! let mut rng = Rng::new(7);
//! let mut mlp = SparseMlp::new(
//!     &[4, 16, 3],                       // sizes: 4 features -> 3 classes
//!     2.0,                               // SET sparsity knob ε
//!     Activation::AllRelu { alpha: 0.6 },
//!     &WeightInit::HeUniform,
//!     &mut rng,
//! )
//! .unwrap();
//! assert!(mlp.weight_count() < 4 * 16 + 16 * 3); // truly sparse
//!
//! let mut ws = mlp.alloc_workspace(2);
//! ws.kernel_threads = 1; // 0 = one kernel worker per core (default)
//! let x = vec![0.5f32; 2 * 4];
//! let logits = mlp.forward(&x, 2, &mut ws, None);
//! assert_eq!(logits.len(), 2 * 3);
//!
//! let labels = vec![0u32, 2];
//! let stats = mlp.train_step(&x, &labels, &MomentumSgd::default(), 0.1, None, &mut ws, &mut rng);
//! assert!(stats.loss.is_finite());
//! ```

pub mod bench;
/// Out-of-core mmap-backed model storage (DESIGN.md §14). 64-bit only:
/// mapped `u64` row offsets are indexed through `usize`.
#[cfg(target_pointer_width = "64")]
pub mod bigmodel;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod gradflow;
pub mod importance;
pub mod model;
pub mod nn;
pub mod runtime;
pub mod serve;
pub mod set;
pub mod sparse;
pub mod train;
pub mod util;

/// Convenient re-exports for downstream users.
pub mod prelude {
    pub use crate::config::{DatasetSpec, TrainConfig};
    pub use crate::data::datasets;
    pub use crate::error::{Result, TsnnError};
    pub use crate::model::{Batcher, SparseLayer, SparseMlp, Workspace};
    pub use crate::nn::{Activation, Dropout, LrSchedule, MomentumSgd};
    pub use crate::serve::{ServeConfig, ServeEngine, ServeModel};
    pub use crate::sparse::{CsrMatrix, WeightInit};
    pub use crate::train::{train_sequential, TrainReport};
    pub use crate::util::Rng;
}
