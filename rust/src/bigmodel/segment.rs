//! `TSNS` per-layer segment files — the durable on-disk backing of an
//! out-of-core model (DESIGN.md §14.2).
//!
//! One segment file holds everything one [`crate::model::SparseLayer`]
//! owns: the CSR arrays (`row_ptr`/`col_idx`/`values`), the momentum
//! `velocity`, and the bias state. The CSR + velocity sections are
//! memory-mapped read-write ([`crate::sparse::MapRegion`]) and handed to
//! the layer as [`Buf::Mapped`] windows, so the kernels train directly
//! against the page cache; the O(n_out) bias vectors are read into RAM at
//! open and written back at [`Segment::seal`].
//!
//! Layout (little-endian, every section 8-byte aligned):
//!
//! ```text
//! off 0   magic "TSNS" | version u32 | state u32 | reserved u32
//! off 16  n_rows u64 | n_cols u64 | nnz u64
//! off 40  reserved (zero) .. HEADER_BYTES (64)
//! row_ptr        (n_rows + 1) × u64   (mapped as usize — 64-bit hosts)
//! col_idx        nnz × u32
//! values         nnz × f32
//! velocity       nnz × f32
//! bias           n_cols × f32
//! bias_velocity  n_cols × f32
//! crc            u32 over [0, crc_off)   (valid only when SEALED)
//! ```
//!
//! Durability protocol (mirrors `checkpoint::write_durable`): a segment
//! is built at `<path>.tmp`, filled through the mapping, then
//! [`Segment::seal`]ed — state flips to `SEALED`, the mapping is
//! msync'ed, a streaming CRC-32 is stamped, the file is fsync'ed and
//! atomically renamed over `<path>` (plus a best-effort directory
//! fsync). A crash at any point leaves either the old sealed file or a
//! `.tmp` that [`Segment::open`] refuses (state byte / CRC), never a
//! torn segment at the live path. SET evolution rebuilds into a fresh
//! `.tmp` the same way and the rename swaps generations atomically.
//!
//! All header arithmetic is u64 with checked ops ([`TsnnError::IndexOverflow`]
//! on a hypothetical overflow), so layouts past `u32::MAX` total slots
//! are computed exactly — see `layout_handles_past_u32_max_nnz`.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::error::{Result, TsnnError};
use crate::sparse::storage::checked_usize;
use crate::sparse::{Buf, MapRegion, MapSlice};
use crate::util::crc::Crc32;

/// Segment file magic.
pub const MAGIC: [u8; 4] = *b"TSNS";
/// Segment format version.
pub const VERSION: u32 = 1;
/// Fixed header span; sections start here.
pub const HEADER_BYTES: u64 = 64;
/// State byte of a segment still being written (no valid CRC).
pub const STATE_OPEN: u32 = 0;
/// State byte of a sealed segment (CRC trailer valid).
pub const STATE_SEALED: u32 = 1;
/// Chunk size of the streaming CRC / copy passes — this, not the segment
/// size, is what those passes keep resident.
pub const STREAM_CHUNK: usize = 1 << 20;

fn add(a: u64, b: u64, what: &str) -> Result<u64> {
    a.checked_add(b)
        .ok_or_else(|| TsnnError::IndexOverflow(format!("{what}: {a} + {b} overflows u64")))
}

fn mul(a: u64, b: u64, what: &str) -> Result<u64> {
    a.checked_mul(b)
        .ok_or_else(|| TsnnError::IndexOverflow(format!("{what}: {a} * {b} overflows u64")))
}

fn align8(v: u64, what: &str) -> Result<u64> {
    Ok(add(v, 7, what)? & !7)
}

/// Byte offsets of every section of one segment file, computed once with
/// checked u64 arithmetic and shared by create/open/window code.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegmentLayout {
    pub n_rows: u64,
    pub n_cols: u64,
    pub nnz: u64,
    pub row_ptr_off: u64,
    pub col_idx_off: u64,
    pub values_off: u64,
    pub velocity_off: u64,
    pub bias_off: u64,
    pub bias_velocity_off: u64,
    /// Offset of the CRC-32 trailer; the digest covers `[0, crc_off)`.
    pub crc_off: u64,
    pub file_len: u64,
}

impl SegmentLayout {
    /// Section offsets for a layer of shape `n_rows × n_cols` with `nnz`
    /// connections. Pure arithmetic — callable (and tested) at scales far
    /// past what the host could allocate.
    pub fn compute(n_rows: u64, n_cols: u64, nnz: u64) -> Result<SegmentLayout> {
        let row_ptr_off = HEADER_BYTES;
        let row_ptr_end = add(row_ptr_off, mul(add(n_rows, 1, "row count")?, 8, "row_ptr bytes")?, "row_ptr end")?;
        let col_idx_off = align8(row_ptr_end, "col_idx offset")?;
        let col_idx_end = add(col_idx_off, mul(nnz, 4, "col_idx bytes")?, "col_idx end")?;
        let values_off = align8(col_idx_end, "values offset")?;
        let values_end = add(values_off, mul(nnz, 4, "values bytes")?, "values end")?;
        let velocity_off = align8(values_end, "velocity offset")?;
        let velocity_end = add(velocity_off, mul(nnz, 4, "velocity bytes")?, "velocity end")?;
        let bias_off = align8(velocity_end, "bias offset")?;
        let bias_end = add(bias_off, mul(n_cols, 4, "bias bytes")?, "bias end")?;
        let bias_velocity_off = align8(bias_end, "bias_velocity offset")?;
        let bias_velocity_end =
            add(bias_velocity_off, mul(n_cols, 4, "bias_velocity bytes")?, "bias_velocity end")?;
        let crc_off = align8(bias_velocity_end, "crc offset")?;
        let file_len = add(crc_off, 4, "segment file length")?;
        Ok(SegmentLayout {
            n_rows,
            n_cols,
            nnz,
            row_ptr_off,
            col_idx_off,
            values_off,
            velocity_off,
            bias_off,
            bias_velocity_off,
            crc_off,
            file_len,
        })
    }

    fn header_image(&self, state: u32) -> [u8; HEADER_BYTES as usize] {
        let mut h = [0u8; HEADER_BYTES as usize];
        h[0..4].copy_from_slice(&MAGIC);
        h[4..8].copy_from_slice(&VERSION.to_le_bytes());
        h[8..12].copy_from_slice(&state.to_le_bytes());
        h[16..24].copy_from_slice(&self.n_rows.to_le_bytes());
        h[24..32].copy_from_slice(&self.n_cols.to_le_bytes());
        h[32..40].copy_from_slice(&self.nnz.to_le_bytes());
        h
    }
}

/// `<path>.tmp` — the build/rebuild staging name next to the live file.
fn staging_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

fn fsync_dir(path: &Path) {
    // best-effort parent-directory fsync so the rename itself is durable
    if let Some(dir) = path.parent() {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
}

/// One mapped layer-segment file. Holds the file handle, the shared
/// mapping every [`Buf::Mapped`] window of the layer points into, and the
/// section layout.
#[derive(Debug)]
pub struct Segment {
    file: File,
    region: Arc<MapRegion>,
    layout: SegmentLayout,
    /// The live path the segment belongs at (post-rename).
    path: PathBuf,
    /// True while the file still lives at `staging_path` (pre-seal).
    staged: bool,
}

impl Segment {
    /// Create a fresh segment at `<path>.tmp`, sized for `nnz` slots and
    /// zero-filled (`set_len` — velocity/bias sections need no explicit
    /// zeroing), with an `OPEN` header. [`Segment::seal`] stamps the CRC
    /// and renames it over `path`.
    pub fn create(path: &Path, n_rows: usize, n_cols: usize, nnz: usize) -> Result<Segment> {
        let layout = SegmentLayout::compute(n_rows as u64, n_cols as u64, nnz as u64)?;
        let map_len = checked_usize(layout.file_len, "segment file length")?;
        let staged_at = staging_path(path);
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&staged_at)?;
        file.set_len(layout.file_len)?;
        let region = MapRegion::map_file(&file, map_len)?;
        let mut seg = Segment {
            file,
            region,
            layout,
            path: path.to_path_buf(),
            staged: true,
        };
        seg.byte_window(0, HEADER_BYTES as usize)?
            .as_mut_slice()
            .copy_from_slice(&layout.header_image(STATE_OPEN));
        Ok(seg)
    }

    /// Open a sealed segment at `path`: header + length validated, the
    /// CRC-32 trailer re-verified by a streaming read (O([`STREAM_CHUNK`])
    /// resident), then mapped.
    pub fn open(path: &Path) -> Result<Segment> {
        let mut file = OpenOptions::new().read(true).write(true).open(path)?;
        let mut h = [0u8; HEADER_BYTES as usize];
        file.read_exact(&mut h)?;
        if h[0..4] != MAGIC {
            return Err(TsnnError::Storage(format!(
                "{}: bad magic (not a TSNS segment)",
                path.display()
            )));
        }
        let version = u32::from_le_bytes([h[4], h[5], h[6], h[7]]);
        if version != VERSION {
            return Err(TsnnError::Storage(format!(
                "{}: unsupported segment version {version}",
                path.display()
            )));
        }
        let state = u32::from_le_bytes([h[8], h[9], h[10], h[11]]);
        if state != STATE_SEALED {
            return Err(TsnnError::Storage(format!(
                "{}: segment was never sealed (state {state}) — crashed mid-build",
                path.display()
            )));
        }
        let n_rows = u64::from_le_bytes(h[16..24].try_into().unwrap());
        let n_cols = u64::from_le_bytes(h[24..32].try_into().unwrap());
        let nnz = u64::from_le_bytes(h[32..40].try_into().unwrap());
        let layout = SegmentLayout::compute(n_rows, n_cols, nnz)?;
        let disk_len = file.metadata()?.len();
        if disk_len != layout.file_len {
            return Err(TsnnError::Storage(format!(
                "{}: segment is {disk_len} bytes, layout demands {}",
                path.display(),
                layout.file_len
            )));
        }
        // streaming CRC over [0, crc_off), then the stored trailer
        file.seek(SeekFrom::Start(0))?;
        let mut digest = Crc32::new();
        let mut remaining = layout.crc_off;
        let mut chunk = vec![0u8; STREAM_CHUNK.min(checked_usize(layout.crc_off.max(1), "crc span")?)];
        while remaining > 0 {
            let take = checked_usize(remaining, "crc span")?.min(chunk.len());
            file.read_exact(&mut chunk[..take])?;
            digest.update(&chunk[..take]);
            remaining -= take as u64;
        }
        let mut trailer = [0u8; 4];
        file.read_exact(&mut trailer)?;
        let stored = u32::from_le_bytes(trailer);
        if digest.value() != stored {
            return Err(TsnnError::ChecksumMismatch(format!(
                "{}: segment CRC {stored:#010x} != computed {:#010x}",
                path.display(),
                digest.value()
            )));
        }
        let map_len = checked_usize(layout.file_len, "segment file length")?;
        let region = MapRegion::map_file(&file, map_len)?;
        Ok(Segment {
            file,
            region,
            layout,
            path: path.to_path_buf(),
            staged: false,
        })
    }

    /// Seal: flip the header state to `SEALED`, msync the whole mapping,
    /// stamp the streaming CRC-32 trailer, fsync, and (when the segment
    /// was freshly built) atomically rename `<path>.tmp` → `<path>`.
    pub fn seal(&mut self) -> Result<()> {
        let layout = self.layout;
        self.byte_window(8, 4)?
            .as_mut_slice()
            .copy_from_slice(&STATE_SEALED.to_le_bytes());
        let map_len = self.region.len();
        self.region.sync(0, map_len)?;
        // CRC over the now-clean mapped bytes, chunked with the pages
        // dropped behind the cursor so sealing a beyond-RAM segment never
        // faults the whole file resident at once.
        let crc_span = checked_usize(layout.crc_off, "crc span")?;
        let mut digest = Crc32::new();
        let mut off = 0usize;
        while off < crc_span {
            let take = STREAM_CHUNK.min(crc_span - off);
            digest.update(self.byte_window(off, take)?.as_slice());
            self.region.advise_dontneed(off, take);
            off += take;
        }
        self.byte_window(crc_span, 4)?
            .as_mut_slice()
            .copy_from_slice(&digest.value().to_le_bytes());
        self.region.sync(crc_span, 4)?;
        self.file.sync_all()?;
        if self.staged {
            std::fs::rename(staging_path(&self.path), &self.path)?;
            fsync_dir(&self.path);
            self.staged = false;
        }
        Ok(())
    }

    /// Replace the sealed segment at this segment's live path with `new`
    /// (which must be sealed, i.e. already renamed into place by
    /// [`Segment::seal`]) — the generation handover of an evolution
    /// rebuild. `self` becomes `new`; the old mapping dies with the old
    /// `Segment` value (the old inode stays alive until then).
    pub fn replace_with(&mut self, new: Segment) {
        debug_assert!(!new.staged, "replacement segment must be sealed");
        debug_assert_eq!(self.path, new.path);
        *self = new;
    }

    /// Section layout.
    pub fn layout(&self) -> &SegmentLayout {
        &self.layout
    }

    /// The live path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The shared mapping (residency sync/advise hooks).
    pub fn region(&self) -> &Arc<MapRegion> {
        &self.region
    }

    /// Total on-disk size.
    pub fn file_len(&self) -> u64 {
        self.layout.file_len
    }

    fn byte_window(&self, off: usize, len: usize) -> Result<Buf<u8>> {
        Ok(Buf::Mapped(MapSlice::new(Arc::clone(&self.region), off, len)?))
    }

    fn window<T: crate::sparse::storage::Pod>(&self, off: u64, len: u64) -> Result<Buf<T>> {
        Ok(Buf::Mapped(MapSlice::new(
            Arc::clone(&self.region),
            checked_usize(off, "section offset")?,
            checked_usize(len, "section length")?,
        )?))
    }

    /// Mapped `row_ptr` window. The on-disk section is u64; mapping it as
    /// `usize` is exact on the 64-bit hosts this module is compiled for
    /// (the `bigmodel` module is gated on `target_pointer_width = "64"`).
    pub fn row_ptr_buf(&self) -> Result<Buf<usize>> {
        self.window(self.layout.row_ptr_off, self.layout.n_rows + 1)
    }

    /// Mapped `col_idx` window.
    pub fn col_idx_buf(&self) -> Result<Buf<u32>> {
        self.window(self.layout.col_idx_off, self.layout.nnz)
    }

    /// Mapped `values` window.
    pub fn values_buf(&self) -> Result<Buf<f32>> {
        self.window(self.layout.values_off, self.layout.nnz)
    }

    /// Mapped `velocity` window.
    pub fn velocity_buf(&self) -> Result<Buf<f32>> {
        self.window(self.layout.velocity_off, self.layout.nnz)
    }

    /// Copy the bias sections out into RAM (`(bias, bias_velocity)`) —
    /// the O(n_out) state [`crate::model::SparseLayer`] keeps as plain
    /// `Vec`s between seals.
    pub fn read_bias(&self) -> Result<(Vec<f32>, Vec<f32>)> {
        let b: Buf<f32> = self.window(self.layout.bias_off, self.layout.n_cols)?;
        let bv: Buf<f32> = self.window(self.layout.bias_velocity_off, self.layout.n_cols)?;
        Ok((b.to_vec(), bv.to_vec()))
    }

    /// Write the RAM bias state back into the segment (pre-seal).
    pub fn write_bias(&mut self, bias: &[f32], bias_velocity: &[f32]) -> Result<()> {
        if bias.len() as u64 != self.layout.n_cols || bias_velocity.len() as u64 != self.layout.n_cols
        {
            return Err(TsnnError::Shape(format!(
                "bias write of {} / {} values into a segment with n_cols {}",
                bias.len(),
                bias_velocity.len(),
                self.layout.n_cols
            )));
        }
        let mut b: Buf<f32> = self.window(self.layout.bias_off, self.layout.n_cols)?;
        b.as_mut_slice().copy_from_slice(bias);
        let mut bv: Buf<f32> = self.window(self.layout.bias_velocity_off, self.layout.n_cols)?;
        bv.as_mut_slice().copy_from_slice(bias_velocity);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_handles_past_u32_max_nnz() {
        // pure header arithmetic at a scale no host could allocate: a
        // 3B-row layer with 2^33+5 connections — every offset exact,
        // 8-aligned, and ordered; nothing is allocated.
        let nnz = (1u64 << 33) + 5;
        let l = SegmentLayout::compute(3_000_000_000, 1 << 20, nnz).unwrap();
        assert_eq!(l.row_ptr_off, HEADER_BYTES);
        assert_eq!(l.col_idx_off, HEADER_BYTES + (3_000_000_001) * 8);
        assert_eq!(l.values_off - l.col_idx_off, ((nnz * 4) + 7) & !7);
        assert_eq!(l.velocity_off - l.values_off, nnz * 4);
        for off in [
            l.row_ptr_off,
            l.col_idx_off,
            l.values_off,
            l.velocity_off,
            l.bias_off,
            l.bias_velocity_off,
            l.crc_off,
        ] {
            assert_eq!(off % 8, 0, "section at {off} not 8-aligned");
        }
        assert!(l.file_len > u32::MAX as u64, "layout exceeds u32 accounting");
        assert_eq!(l.file_len, l.crc_off + 4);
    }

    #[test]
    fn layout_overflow_is_a_typed_error() {
        let err = SegmentLayout::compute(u64::MAX / 4, 8, 8).unwrap_err();
        assert!(matches!(err, TsnnError::IndexOverflow(_)), "{err}");
    }

    #[cfg(target_os = "linux")]
    fn test_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tsnn_segment_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn create_seal_open_roundtrips_all_sections() {
        let dir = test_dir("roundtrip");
        let path = dir.join("layer0.tsns");
        let (n_rows, n_cols, nnz) = (3usize, 4usize, 5usize);
        let mut seg = Segment::create(&path, n_rows, n_cols, nnz).unwrap();
        seg.row_ptr_buf()
            .unwrap()
            .as_mut_slice()
            .copy_from_slice(&[0, 2, 2, 5]);
        seg.col_idx_buf()
            .unwrap()
            .as_mut_slice()
            .copy_from_slice(&[0, 3, 1, 2, 3]);
        seg.values_buf()
            .unwrap()
            .as_mut_slice()
            .copy_from_slice(&[1.0, -2.0, 3.5, -0.25, 0.5]);
        seg.velocity_buf()
            .unwrap()
            .as_mut_slice()
            .copy_from_slice(&[0.1, 0.2, 0.3, 0.4, 0.5]);
        seg.write_bias(&[1.0, 2.0, 3.0, 4.0], &[0.0, -1.0, 0.0, 1.0])
            .unwrap();
        assert!(!path.exists(), "segment stays at .tmp until sealed");
        seg.seal().unwrap();
        assert!(path.exists());
        drop(seg);

        let seg = Segment::open(&path).unwrap();
        assert_eq!(seg.row_ptr_buf().unwrap().as_slice(), &[0, 2, 2, 5]);
        assert_eq!(seg.col_idx_buf().unwrap().as_slice(), &[0, 3, 1, 2, 3]);
        assert_eq!(
            seg.values_buf().unwrap().as_slice(),
            &[1.0, -2.0, 3.5, -0.25, 0.5]
        );
        assert_eq!(
            seg.velocity_buf().unwrap().as_slice(),
            &[0.1, 0.2, 0.3, 0.4, 0.5]
        );
        let (b, bv) = seg.read_bias().unwrap();
        assert_eq!(b, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(bv, vec![0.0, -1.0, 0.0, 1.0]);
        drop(seg);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn corruption_and_unsealed_segments_are_refused() {
        let dir = test_dir("refuse");
        let path = dir.join("layer.tsns");
        let mut seg = Segment::create(&path, 2, 2, 2).unwrap();
        seg.col_idx_buf().unwrap().as_mut_slice().copy_from_slice(&[0, 1]);
        seg.row_ptr_buf().unwrap().as_mut_slice().copy_from_slice(&[0, 1, 2]);
        seg.seal().unwrap();
        drop(seg);

        // flip one payload byte → ChecksumMismatch
        let mut bytes = std::fs::read(&path).unwrap();
        let i = HEADER_BYTES as usize + 3;
        bytes[i] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        match Segment::open(&path) {
            Err(TsnnError::ChecksumMismatch(_)) => {}
            other => panic!("corrupt segment must fail CRC, got {other:?}"),
        }

        // a never-sealed (state OPEN) file must be refused up front
        bytes[i] ^= 0x40;
        bytes[8..12].copy_from_slice(&STATE_OPEN.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        match Segment::open(&path) {
            Err(TsnnError::Storage(m)) => assert!(m.contains("never sealed"), "{m}"),
            other => panic!("unsealed segment must be refused, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn rebuild_rename_swaps_generations_atomically() {
        let dir = test_dir("swap");
        let path = dir.join("layer.tsns");
        let mut gen0 = Segment::create(&path, 1, 1, 1).unwrap();
        gen0.row_ptr_buf().unwrap().as_mut_slice().copy_from_slice(&[0, 1]);
        gen0.values_buf().unwrap().as_mut_slice()[0] = 7.0;
        gen0.seal().unwrap();

        // build the next generation at .tmp while gen0 stays live+mapped
        let mut gen1 = Segment::create(&path, 1, 1, 1).unwrap();
        gen1.row_ptr_buf().unwrap().as_mut_slice().copy_from_slice(&[0, 1]);
        gen1.values_buf().unwrap().as_mut_slice()[0] = 9.0;
        assert_eq!(gen0.values_buf().unwrap().as_slice(), &[7.0]);
        gen1.seal().unwrap(); // rename over the live path
        assert_eq!(
            gen0.values_buf().unwrap().as_slice(),
            &[7.0],
            "old mapping survives the rename (old inode pinned)"
        );
        gen0.replace_with(gen1);
        assert_eq!(gen0.values_buf().unwrap().as_slice(), &[9.0]);
        drop(gen0);
        let reopened = Segment::open(&path).unwrap();
        assert_eq!(reopened.values_buf().unwrap().as_slice(), &[9.0]);
        drop(reopened);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
